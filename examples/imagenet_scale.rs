//! "Scales to ImageNet-sized datasets" (§6): stream N = 200k synthetic
//! examples through Phase I + the strict O(ℓ)-memory Phase II and report
//! the peak selection state, which stays constant while N grows 100×.
//!
//! No training here — this exercises *selection* scalability: the FD
//! sketch (O(ℓD)), the streaming consensus (O(ℓ)) and the bounded top-k
//! heap (O(k)), versus what an explicit-store method would need (N×D).
//!
//!     cargo run --release --example imagenet_scale

use sage::data::{generate, BenchmarkKind, StreamBatches, SynthSpec};
use sage::grad::{MlpSpec, TrainHyper};
use sage::runtime::{ModelBackend, ReferenceModelBackend};
use sage::selection::{ConsensusAccumulator, StreamingSelector};
use sage::sketch::FdSketch;
use sage::tensor;
use sage::util::rng::Pcg64;

fn main() -> Result<(), String> {
    let backend = ReferenceModelBackend::new(
        MlpSpec::new(32, 32, 10),
        TrainHyper::default(),
        128,
        128,
        32,
    );
    let spec = backend.spec();
    let ell = backend.ell();
    let d = spec.d();
    let mut rng = Pcg64::seeded(1);
    let params = spec.init_params(&mut rng);

    println!(
        "model D={d}, sketch ell={ell}; streaming batches of {}",
        backend.score_batch()
    );
    println!(
        "{:>8} {:>14} {:>14} {:>16} {:>10}",
        "N", "sketch bytes", "phase2 bytes", "explicit N x D", "secs"
    );

    let synth = SynthSpec {
        classes: 10,
        ..BenchmarkKind::Cifar10.spec(32)
    };
    for n in [2_000usize, 20_000, 200_000] {
        let t0 = std::time::Instant::now();
        // Generate + stream in chunks so even the raw features never sit in
        // memory all at once beyond the current window.
        let chunk = 10_000.min(n);
        let mut sketch = FdSketch::new(ell, d);
        let k = n / 10;

        // Phase I
        for c in 0..n.div_ceil(chunk) {
            let ds = generate(&synth, chunk.min(n - c * chunk), 7 + c as u64, 0);
            for (_s, batch) in StreamBatches::new(&ds, backend.score_batch()) {
                let y = batch.one_hot();
                let (g, _) = backend.per_example_grads(&params, &batch.features, &y)?;
                sketch.insert_batch(&g);
            }
        }
        let s = sketch.sketch();

        // Phase II (strict streaming: consensus pass + scoring pass).
        let mut acc = ConsensusAccumulator::new(ell);
        let pass = |sink: &mut dyn FnMut(&[usize], &sage::tensor::Matrix)|
         -> Result<(), String> {
            let mut base = 0usize;
            for c in 0..n.div_ceil(chunk) {
                let ds = generate(&synth, chunk.min(n - c * chunk), 7 + c as u64, 0);
                for (start, batch) in StreamBatches::new(&ds, backend.score_batch()) {
                    let y = batch.one_hot();
                    let (zhat, _n2, _l) =
                        backend.score_fused(&params, &s, &batch.features, &y)?;
                    let idx: Vec<usize> =
                        (base + start..base + start + batch.len()).collect();
                    sink(&idx, &zhat);
                }
                base += ds.len();
            }
            Ok(())
        };
        pass(&mut |_i, z| acc.add(z))?;
        let mut selector = StreamingSelector::new(acc.consensus(), k);
        pass(&mut |i, z| selector.add(i, z))?;
        let picked = selector.finish();
        assert_eq!(picked.len(), k);

        let phase2_bytes = ell * 8 + k * 8; // consensus f64 + heap entries
        println!(
            "{:>8} {:>14} {:>14} {:>16} {:>10.1}",
            n,
            sketch.memory_bytes(),
            phase2_bytes,
            format!("{} MiB", n * d * 4 / (1 << 20)),
            t0.elapsed().as_secs_f64()
        );
        let _ = tensor::norm2(s.row(0)); // keep s alive for clarity
    }

    println!(
        "\nselection state is flat in N (sketch buffer + O(ell+k) scoring);\n\
         an explicit gradient store grows linearly and would cross this\n\
         host's RAM near N ~ 2.6M examples at this D."
    );
    Ok(())
}
