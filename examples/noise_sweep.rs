//! Diagnostic: SAGE-vs-Random gap as a function of label noise.
//!
//! The agreement score's claimed mechanism is "down-weighting inconsistent
//! or noisy samples" (§1). This sweep measures exactly that on the
//! simulated substrate: at a fixed 10% budget, how do SAGE and Random
//! subsets train as the label-noise rate grows? Used to calibrate the
//! benchmark presets in data/synth.rs (see DESIGN.md §3).
//!
//!     cargo run --release --example noise_sweep

use sage::config::Method;
use sage::data::{generate, BenchmarkKind, SynthSpec};
use sage::grad::{MlpSpec, TrainHyper};
use sage::pipeline::{run_selection, PipelineConfig};
use sage::runtime::ReferenceModelBackend;
use sage::trainer::{train, TrainConfig};

fn main() {
    let seeds = 3u64;
    println!(
        "{:>6} {:>10} {:>10} {:>10} {:>8}",
        "noise", "SAGE", "Random", "DROP", "gap"
    );
    for noise in [0.0, 0.05, 0.10, 0.20, 0.30] {
        let mut acc = std::collections::BTreeMap::new();
        for method in [Method::Sage, Method::Random, Method::Drop] {
            let mut xs = Vec::new();
            for seed in 0..seeds {
                let spec = SynthSpec {
                    classes: 10,
                    label_noise: noise,
                    ..BenchmarkKind::Cifar10.spec(16)
                };
                let tr = generate(&spec, 1500, seed, 0);
                // Test split without label noise: measures true-class acc.
                let clean = SynthSpec {
                    label_noise: 0.0,
                    ..spec
                };
                let te = generate(&clean, 700, seed, 1);
                let b = ReferenceModelBackend::new(
                    MlpSpec::new(16, 24, 10),
                    TrainHyper::default(),
                    32,
                    32,
                    16,
                );
                let pcfg = PipelineConfig {
                    workers: 2,
                    warmup_steps: 15,
                    seed,
                    ..Default::default()
                };
                let out = run_selection(&b, &tr, method, 150, &pcfg, None).unwrap();
                let res = train(
                    &b,
                    &tr.subset(&out.indices),
                    &te,
                    &TrainConfig {
                        epochs: 6,
                        base_lr: 0.08,
                        seed,
                        ..Default::default()
                    },
                )
                .unwrap();
                xs.push(res.test_accuracy);
            }
            acc.insert(method.name(), sage::bench::mean(&xs));
        }
        println!(
            "{:>6.2} {:>10.4} {:>10.4} {:>10.4} {:>+8.4}",
            noise,
            acc["SAGE"],
            acc["Random"],
            acc["DROP"],
            acc["SAGE"] - acc["Random"]
        );
    }
}
