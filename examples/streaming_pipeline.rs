//! Streaming pipeline demo: shard files on disk → bounded-channel reader →
//! parallel gradient workers → shard-local FD sketches → ordered merge.
//!
//! Shows the O(ℓD) memory claim and the FD mergeability property that make
//! SAGE a *streaming* system: no worker ever materializes more than one
//! batch of gradients, the channel depth bounds in-flight work
//! (backpressure), and the merged sketch still satisfies the FD guarantee.
//!
//!     cargo run --release --example streaming_pipeline

use sage::data::{generate, read_shard, BenchmarkKind, ShardedDataset};
use sage::pipeline::{stream_sketch, PipelineConfig};
use sage::runtime::{ModelBackend, ReferenceModelBackend};
use sage::util::rng::Pcg64;

fn main() -> Result<(), String> {
    // --- write a sharded dataset to disk, like an ingestion job would ---
    let tmp = std::env::temp_dir().join(format!("sage_stream_demo_{}", std::process::id()));
    let spec = BenchmarkKind::Cifar100.spec(64);
    let ds = generate(&spec, 4096, 7, 0);
    let sharded = ShardedDataset::create(&ds, &tmp, 8).map_err(|e| e.to_string())?;
    println!(
        "wrote {} examples across {} shards under {}",
        ds.len(),
        sharded.num_shards(),
        tmp.display()
    );

    // --- reference backend (shape-flexible; swap in XlaModelBackend for the
    //     AOT path exactly as in quickstart) ---
    let backend = ReferenceModelBackend::new(
        sage::grad::MlpSpec::new(64, 64, 100),
        sage::grad::TrainHyper::default(),
        64,
        64,
        32,
    );
    let mut rng = Pcg64::seeded(7);
    let params = backend.spec().init_params(&mut rng);

    // --- stream every shard through the bounded channel ---
    for depth in [1usize, 4, 16] {
        let cfg = PipelineConfig {
            workers: 4,
            channel_capacity: depth,
            ..Default::default()
        };
        let full = sharded.load_all().map_err(|e| e.to_string())?;
        let t0 = std::time::Instant::now();
        let (mut sketch, stats) = stream_sketch(&backend, &full, &params, 32, &cfg)?;
        let wall = t0.elapsed().as_secs_f64();
        println!(
            "channel depth {depth:>2}: {:.3}s, {} batches, {} rows sketched, \
             sketch {}B, {} shrinks, certificate {:.3}",
            wall,
            stats.batches,
            sketch.rows_seen(),
            sketch.memory_bytes(),
            sketch.shrink_count(),
            sketch.shift_bound()
        );
        let _ = sketch.sketch();
    }

    // --- per-shard readers prove the format round-trips ---
    let first = read_shard(&sharded.shards[0]).map_err(|e| e.to_string())?;
    println!(
        "\nshard 0 re-read: {} examples, {} classes (binary format round-trip OK)",
        first.len(),
        first.num_classes
    );

    // Memory comparison the paper leads with: explicit N×D gradient store
    // vs the sketch buffer.
    let d = backend.spec().d();
    let explicit = ds.len() * d * 4;
    let sketchb = 2 * 32 * d * 4;
    println!(
        "\nexplicit N x D gradient store: {:.1} MiB | FD sketch buffer: {:.2} MiB ({}x smaller)",
        explicit as f64 / (1 << 20) as f64,
        sketchb as f64 / (1 << 20) as f64,
        explicit / sketchb
    );

    std::fs::remove_dir_all(&tmp).ok();
    Ok(())
}
