//! CB-SAGE on long-tailed data (the Caltech-256 scenario).
//!
//! Generates a Zipf-imbalanced mixture, runs plain SAGE and CB-SAGE at the
//! same budget, and compares (a) class coverage of the selected subset and
//! (b) downstream test accuracy — reproducing the paper's §3 observation
//! that per-class centroids "improve subset representativeness and ensure
//! uniform label coverage" under severe imbalance.
//!
//!     cargo run --release --example class_balanced

use sage::config::Method;
use sage::data::{generate, BenchmarkKind, SynthSpec};
use sage::grad::{MlpSpec, TrainHyper};
use sage::pipeline::{run_selection, PipelineConfig};
use sage::runtime::ReferenceModelBackend;
use sage::trainer::{train, TrainConfig};

fn gini(counts: &[usize]) -> f64 {
    // Gini coefficient of the class histogram (0 = perfectly uniform).
    let mut xs: Vec<f64> = counts.iter().map(|&c| c as f64).collect();
    xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = xs.len() as f64;
    let sum: f64 = xs.iter().sum();
    if sum == 0.0 {
        return 0.0;
    }
    let mut acc = 0.0;
    for (i, x) in xs.iter().enumerate() {
        acc += (2.0 * (i as f64 + 1.0) - n - 1.0) * x;
    }
    acc / (n * sum)
}

fn main() -> Result<(), String> {
    // 32-class long-tail (Zipf 1.0) — same geometry as the caltech256 sim,
    // scaled so the example runs in seconds.
    let classes = 32;
    let spec = SynthSpec {
        classes,
        zipf: Some(1.0),
        ..BenchmarkKind::Caltech256.spec(32)
    };
    let train_ds = generate(&spec, 6000, 11, 0);
    let test_ds = generate(&spec, 2000, 11, 1);
    let counts = train_ds.class_counts();
    println!(
        "long-tail train set: {} examples, head class {} vs smallest nonzero {} (gini {:.3})",
        train_ds.len(),
        counts.iter().max().unwrap(),
        counts.iter().filter(|&&c| c > 0).min().unwrap(),
        gini(&counts)
    );

    let backend = ReferenceModelBackend::new(
        MlpSpec::new(32, 48, classes),
        TrainHyper::default(),
        64,
        64,
        32,
    );
    let k = train_ds.len() / 10; // aggressive 10% budget
    let pcfg = PipelineConfig {
        workers: 4,
        warmup_steps: 25,
        seed: 11,
        ..Default::default()
    };
    let tcfg = TrainConfig {
        epochs: 8,
        base_lr: 0.08,
        seed: 11,
        ..Default::default()
    };

    println!("\nbudget k = {k} ({}%)\n", 100 * k / train_ds.len());
    println!(
        "{:<10} {:>14} {:>12} {:>10} {:>10}",
        "method", "classes kept", "gini(sel)", "test acc", "tail acc"
    );
    for method in [Method::SageGlobal, Method::CbSage, Method::Random] {
        let out = run_selection(&backend, &train_ds, method, k, &pcfg, None)?;
        let subset = train_ds.subset(&out.indices);
        let sel_counts = subset.class_counts();
        let covered = sel_counts.iter().filter(|&&c| c > 0).count();
        let res = train(&backend, &subset, &test_ds, &tcfg)?;
        // Tail = classes in the bottom half of the frequency ranking.
        let mut order: Vec<usize> = (0..classes).collect();
        order.sort_by_key(|&c| counts[c]);
        let tail: std::collections::HashSet<usize> =
            order[..classes / 2].iter().copied().collect();
        let logits_acc = {
            let mut correct = 0usize;
            let mut total = 0usize;
            // accuracy restricted to tail-class test examples
            let idx: Vec<usize> = (0..test_ds.len())
                .filter(|&i| tail.contains(&(test_ds.labels[i] as usize)))
                .collect();
            if !idx.is_empty() {
                let sub = test_ds.subset(&idx);
                let acc = backend_accuracy(&backend, &res.params, &sub)?;
                correct = (acc * idx.len() as f64) as usize;
                total = idx.len();
            }
            if total == 0 { 0.0 } else { correct as f64 / total as f64 }
        };
        println!(
            "{:<10} {:>9}/{:<4} {:>12.3} {:>10.4} {:>10.4}",
            method.name(),
            covered,
            counts.iter().filter(|&&c| c > 0).count(),
            gini(&sel_counts),
            res.test_accuracy,
            logits_acc
        );
    }
    println!("\nCB-SAGE keeps every observed class at the same budget; the global-\nconsensus top-k (Algorithm 1 verbatim, 'SAGE-global') concentrates on a\nfew classes — the paper's motivation for per-class centroids on\nimbalanced data.");
    Ok(())
}

fn backend_accuracy(
    backend: &ReferenceModelBackend,
    params: &[f32],
    ds: &sage::data::Dataset,
) -> Result<f64, String> {
    use sage::runtime::ModelBackend;
    backend.accuracy(params, &ds.features, &ds.labels)
}
