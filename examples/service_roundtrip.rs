//! sage-serve round trip: spawn the session server in-process, stream
//! Phase-I gradients from four concurrent producer connections (one per
//! shard), freeze, score Phase II from four concurrent scorers, and run an
//! online TopK query — then verify the served result is IDENTICAL to the
//! offline `pipeline::run_selection` on the same `(seed, workers)` config.
//!
//!     cargo run --example service_roundtrip

use sage::config::Method;
use sage::data::{generate, BenchmarkKind};
use sage::grad::{MlpSpec, TrainHyper};
use sage::pipeline::{phase1_gradient_stream, phase2_score_stream, shard_ranges};
use sage::pipeline::{run_selection, PipelineConfig};
use sage::runtime::{ModelBackend, ReferenceModelBackend};
use sage::service::{RegistryConfig, Server, ServerConfig, ServiceClient};

fn main() {
    let workers = 4;
    let n = 400;
    let k = 100;
    let backend = ReferenceModelBackend::new(
        MlpSpec::new(12, 16, 10),
        TrainHyper::default(),
        32,
        32,
        8,
    );
    let ds = generate(&BenchmarkKind::Cifar10.spec(12), n, 3, 0);
    let cfg = PipelineConfig {
        workers,
        warmup_steps: 5,
        seed: 9,
        ..Default::default()
    };

    // --- Offline reference run ---
    let offline = run_selection(&backend, &ds, Method::Sage, k, &cfg, None).unwrap();
    println!(
        "offline: {} indices, sketch {}x{}, {} shrinks",
        offline.indices.len(),
        offline.sketch.rows(),
        offline.sketch.cols(),
        offline.shrinks
    );

    // --- Served run ---
    let server = Server::bind(&ServerConfig {
        addr: "127.0.0.1:0".into(), // free port
        threads: 8,
        compute_workers: 2, // parallel kernels; selections identical to serial
        registry: RegistryConfig::default(),
        ..ServerConfig::default()
    })
    .unwrap();
    let addr = server.local_addr().to_string();
    let handle = server.spawn();
    println!("server on {addr}");

    let mut control = ServiceClient::connect(&addr).unwrap();
    control
        .create_session("demo", backend.ell(), backend.spec().d(), workers)
        .unwrap();

    // Phase I: one concurrent producer connection per shard, reusing the
    // warm-up parameters the offline run computed.
    let ranges = shard_ranges(n, workers);
    let params = &offline.params;
    let backend_ref = &backend;
    let ds_ref = &ds;
    std::thread::scope(|scope| {
        for (shard, &range) in ranges.iter().enumerate() {
            let addr = addr.clone();
            scope.spawn(move || {
                let mut client = ServiceClient::connect(&addr).unwrap();
                let batches = phase1_gradient_stream(backend_ref, ds_ref, params, range, |g| {
                    client.ingest("demo", shard, g).map(|_| ())
                })
                .unwrap();
                println!("producer {shard}: {batches} gradient batches");
            });
        }
    });

    // Freeze: drains ingest, merges shard sketches in shard order.
    let frozen = control.freeze("demo").unwrap();
    assert_eq!(
        frozen.sketch.as_slice(),
        offline.sketch.as_slice(),
        "served sketch must be byte-identical to the offline sketch"
    );
    println!(
        "frozen: byte-identical sketch, shift bound {:.4} (offline {:.4})",
        frozen.shift_bound, offline.shift_bound
    );

    // Phase II: concurrent scorers per shard against the frozen sketch.
    std::thread::scope(|scope| {
        for (shard, &range) in ranges.iter().enumerate() {
            let addr = addr.clone();
            let sketch = &frozen.sketch;
            scope.spawn(move || {
                let mut client = ServiceClient::connect(&addr).unwrap();
                phase2_score_stream(backend_ref, ds_ref, params, sketch, range, |blk| {
                    client.score("demo", shard, &blk)
                })
                .unwrap();
            });
        }
    });

    // Online selection query.
    let (indices, _weights) = control.top_k("demo", "sage", k, 10, cfg.seed).unwrap();
    assert_eq!(
        indices, offline.indices,
        "served TopK must equal offline selection"
    );
    println!("TopK: {} indices, identical to offline ✓", indices.len());

    // A second online query at a different budget — no recompute needed.
    let (half, _) = control.top_k("demo", "sage", k / 2, 10, cfg.seed).unwrap();
    println!("online re-query at k={}: {} indices", k / 2, half.len());

    for (name, value) in control.stats(Some("demo")).unwrap() {
        println!("{name}: {value}");
    }

    handle.shutdown();
    println!("round trip complete");
}
