//! END-TO-END driver (DESIGN.md §5, EXPERIMENTS.md §E2E): proves all three
//! layers compose on a real small workload.
//!
//!   L1 Pallas kernels ──lowered into── L2 JAX model ──AOT──► HLO text
//!   ──► L3 Rust coordinator: streaming FD sketch → agreement selection →
//!       subset training on the PJRT runtime, with loss curves + wall-clock.
//!
//! Workload: the `medium` config (~102k-parameter MLP) on a simulated
//! CIFAR-10 corpus (N=8192). Compares Full data vs SAGE@25% vs Random@25%,
//! reporting test accuracy, end-to-end wall-clock (selection included) and
//! the speed-up — the paper's headline measurement.
//!
//!     make artifacts && cargo run --release --example e2e_train

use sage::config::Method;
use sage::data::{generate, BenchmarkKind};
use sage::pipeline::{run_selection, PipelineConfig};
use sage::runtime::{
    EngineActor, ModelBackend, XlaModelBackend, XlaShrinkBackend,
};
use sage::sketch::ShrinkBackend;
use sage::trainer::{train, TrainConfig};
use std::sync::Arc;

const MODEL: &str = "medium";
const N_TRAIN: usize = 8192;
const N_TEST: usize = 2048;
const EPOCHS: usize = 6;
const FRACTION: f64 = 0.25;

fn main() -> Result<(), String> {
    let actor = EngineActor::spawn("artifacts")
        .map_err(|e| format!("{e}\n(run `make artifacts` first)"))?;
    let backend = XlaModelBackend::new(actor.handle(), MODEL)?;
    let shrink: Arc<dyn ShrinkBackend> =
        Arc::new(XlaShrinkBackend::new(actor.handle(), MODEL)?);
    let spec = backend.spec();
    println!(
        "model: {} — D={} params (f={} h={} c={}), artifacts via PJRT CPU",
        backend.name(),
        spec.d(),
        spec.f,
        spec.h,
        spec.c
    );
    // Pre-compile everything so timing excludes XLA compilation.
    actor
        .handle()
        .warm(MODEL, &["grads", "train_step", "eval", "score_fused", "gram", "apply_rot"])?;

    let dspec = BenchmarkKind::Cifar10.spec(spec.f);
    let train_ds = generate(&dspec, N_TRAIN, 17, 0);
    let test_ds = generate(&dspec, N_TEST, 17, 1);
    println!(
        "corpus: {} train / {} test examples, {} classes\n",
        train_ds.len(),
        test_ds.len(),
        train_ds.num_classes
    );

    let tcfg = TrainConfig {
        epochs: EPOCHS,
        base_lr: 0.08,
        seed: 17,
        log_every: 20,
        ..Default::default()
    };

    let mut rows = Vec::new();
    let mut full_total = 0.0f64;

    for method in [Method::Full, Method::Sage, Method::Random] {
        let t0 = std::time::Instant::now();
        let (subset, select_secs, sketch_note) = if method == Method::Full {
            (train_ds.clone(), 0.0, String::from("-"))
        } else {
            let k = (FRACTION * train_ds.len() as f64) as usize;
            let pcfg = PipelineConfig {
                workers: 4,
                warmup_steps: 30,
                warmup_lr: 0.08,
                seed: 17,
                ..Default::default()
            };
            let out = run_selection(&backend, &train_ds, method, k, &pcfg, Some(shrink.clone()))?;
            let secs =
                out.warmup_seconds + out.phase1.seconds + out.phase2.seconds + out.select_seconds;
            let note = format!(
                "{}B sketch, {} shrinks",
                out.sketch_bytes, out.shrinks
            );
            (train_ds.subset(&out.indices), secs, note)
        };
        let res = train(&backend, &subset, &test_ds, &tcfg)?;
        let total = t0.elapsed().as_secs_f64();
        if method == Method::Full {
            full_total = total;
        }
        println!(
            "=== {} (n={}) ===",
            method.name(),
            subset.len()
        );
        println!(
            "  select {select_secs:.2}s + train {:.2}s = {total:.2}s total | {sketch_note}",
            res.train_seconds
        );
        println!("  final loss {:.4} | test accuracy {:.4}", res.final_loss, res.test_accuracy);
        print!("  loss curve:");
        for (step, loss) in res
            .loss_curve
            .iter()
            .step_by((res.loss_curve.len() / 8).max(1))
        {
            print!(" {step}:{loss:.3}");
        }
        println!("\n");
        rows.push((method.name(), subset.len(), res.test_accuracy, total, select_secs, res.train_seconds));
    }

    // --- report ---
    println!("=== summary (paper's Figure-1 measurement at f=25%) ===");
    println!(
        "{:<10} {:>6} {:>10} {:>12} {:>12} {:>12}",
        "method", "n", "test acc", "wall (s)", "e2e speedup", "train speedup"
    );
    let mut md = String::from(
        "# E2E run (medium, simulated CIFAR-10)\n\n| method | n | test acc | select s | train s | total s | e2e speed-up | train speed-up |\n|---|---|---|---|---|---|---|---|\n",
    );
    let full_train = rows[0].5;
    for (name, n, acc, total, sel, tr) in &rows {
        let speedup = full_total / total;
        let train_speedup = full_train / tr.max(1e-9);
        println!(
            "{name:<10} {n:>6} {acc:>10.4} {total:>12.2} {speedup:>11.2}x {train_speedup:>11.2}x"
        );
        md.push_str(&format!(
            "| {name} | {n} | {acc:.4} | {sel:.2} | {tr:.2} | {total:.2} | {speedup:.2}x | {train_speedup:.2}x |\n"
        ));
    }
    std::fs::create_dir_all("reports").ok();
    std::fs::write("reports/e2e_train.md", md).map_err(|e| e.to_string())?;
    println!("\nwrote reports/e2e_train.md");

    let sage_row = rows.iter().find(|r| r.0 == "SAGE").unwrap();
    let full_row = rows.iter().find(|r| r.0 == "Full data").unwrap();
    println!(
        "\nSAGE@25% retains {:.1}% of full-data accuracy at {:.2}x training speed-up\n\
         (e2e {:.2}x on this substrate: fused batch training is ~200x cheaper per\n\
         example than per-example-gradient scoring, so at {EPOCHS} epochs selection\n\
         dominates; in the paper's 200-epoch ResNet regime training dominates — see\n\
         EXPERIMENTS.md §E2E)",
        100.0 * sage_row.2 / full_row.2,
        full_train / sage_row.5.max(1e-9),
        full_total / sage_row.3
    );
    Ok(())
}
