//! Quickstart: the SAGE public API in ~60 lines of calling code.
//!
//! 1. Generate a simulated CIFAR-10-like benchmark.
//! 2. Run the two-pass streaming selection (FD sketch → agreement scores).
//! 3. Inspect what was selected.
//!
//! Uses the AOT/PJRT backend when `artifacts/` exists (run `make
//! artifacts`), otherwise falls back to the pure-Rust reference backend.
//!
//!     cargo run --release --example quickstart

use sage::config::Method;
use sage::data::{generate, BenchmarkKind};
use sage::pipeline::{run_selection, PipelineConfig};
use sage::runtime::{EngineActor, ModelBackend, ReferenceModelBackend, XlaModelBackend};

fn main() -> Result<(), String> {
    // --- backend: XLA artifacts if present, reference otherwise ---
    let (backend, _actor): (Box<dyn ModelBackend>, Option<EngineActor>) =
        if std::path::Path::new("artifacts/manifest.json").exists() {
            let actor = EngineActor::spawn("artifacts")?;
            let b = XlaModelBackend::new(actor.handle(), "small")?;
            println!("backend: {} (AOT artifacts via PJRT)", b.name());
            (Box::new(b), Some(actor))
        } else {
            let b = ReferenceModelBackend::new(
                sage::grad::MlpSpec::new(64, 64, 10),
                sage::grad::TrainHyper::default(),
                64,
                64,
                32,
            );
            println!("backend: reference (run `make artifacts` for the XLA path)");
            (Box::new(b), None)
        };

    // --- data: simulated CIFAR-10 (10-class Gaussian mixture) ---
    let spec = backend.spec();
    let train = generate(&BenchmarkKind::Cifar10.spec(spec.f), 2048, 42, 0);
    println!(
        "dataset: {} examples, {} classes, {} features",
        train.len(),
        train.num_classes,
        spec.f
    );

    // --- two-pass selection at a 25% budget ---
    let k = train.len() / 4;
    let cfg = PipelineConfig {
        workers: 4,
        warmup_steps: 20,
        seed: 42,
        ..Default::default()
    };
    let out = run_selection(backend.as_ref(), &train, Method::Sage, k, &cfg, None)?;

    println!("\n--- Phase I: Frequent-Directions sketch ---");
    println!("sketch memory: {} bytes (O(ell*D), N-independent)", out.sketch_bytes);
    println!("shrinks: {}  |  error certificate (sum of deltas): {:.4}", out.shrinks, out.shift_bound);
    println!("wall: {:.3}s over {} gradient batches", out.phase1.seconds, out.phase1.batches);

    println!("\n--- Phase II: agreement scoring ---");
    let alphas: Vec<f64> = out.scores.entries.iter().map(|e| e.alpha as f64).collect();
    println!("wall: {:.3}s", out.phase2.seconds);
    println!(
        "alpha distribution: mean {:.4}, min {:.4}, max {:.4}",
        sage::bench::mean(&alphas),
        alphas.iter().cloned().fold(f64::MAX, f64::min),
        alphas.iter().cloned().fold(f64::MIN, f64::max)
    );

    println!("\n--- selection (top-{k} by agreement) ---");
    let subset = train.subset(&out.indices);
    let counts = subset.class_counts();
    println!("selected {} examples; per-class counts: {:?}", subset.len(), counts);
    let sel_alpha: f64 = out
        .indices
        .iter()
        .map(|&i| out.scores.entries.iter().find(|e| e.index == i).unwrap().alpha as f64)
        .sum::<f64>()
        / k as f64;
    println!(
        "mean alpha of selected: {:.4} (vs {:.4} overall) — agreement ranking at work",
        sel_alpha,
        sage::bench::mean(&alphas)
    );
    println!("\nnext: examples/e2e_train.rs trains on this subset and measures speed-up");
    Ok(())
}
