//! Command-line parsing (from scratch — no clap offline).
//!
//! Grammar: `sage <subcommand> [--flag] [--key value] [positional...]`.
//! Subcommands are declared with their flags so `--help` is generated and
//! unknown flags fail loudly instead of being silently dropped.

use std::collections::BTreeMap;

/// One declared option.
#[derive(Clone, Debug)]
pub struct Opt {
    pub name: &'static str,
    pub takes_value: bool,
    pub help: &'static str,
    pub default: Option<&'static str>,
}

/// A declared subcommand.
#[derive(Clone, Debug)]
pub struct Command {
    pub name: &'static str,
    pub about: &'static str,
    pub opts: Vec<Opt>,
}

/// Parse result for a subcommand invocation.
#[derive(Clone, Debug, Default)]
pub struct Parsed {
    pub command: String,
    values: BTreeMap<String, String>,
    flags: Vec<String>,
    pub positional: Vec<String>,
}

impl Parsed {
    pub fn get(&self, name: &str) -> Option<&str> {
        self.values.get(name).map(|s| s.as_str())
    }

    pub fn get_or(&self, name: &str, default: &'static str) -> String {
        self.get(name).unwrap_or(default).to_string()
    }

    pub fn get_usize(&self, name: &str) -> Result<Option<usize>, String> {
        self.get(name)
            .map(|v| v.parse().map_err(|e| format!("--{name}: {e}")))
            .transpose()
    }

    pub fn get_f64(&self, name: &str) -> Result<Option<f64>, String> {
        self.get(name)
            .map(|v| v.parse().map_err(|e| format!("--{name}: {e}")))
            .transpose()
    }

    pub fn has_flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }
}

/// Application definition: subcommands + global help.
pub struct App {
    pub name: &'static str,
    pub about: &'static str,
    pub commands: Vec<Command>,
}

impl App {
    pub fn usage(&self) -> String {
        let mut out = format!("{} — {}\n\nUSAGE:\n  {} <command> [options]\n\nCOMMANDS:\n", self.name, self.about, self.name);
        for c in &self.commands {
            out.push_str(&format!("  {:<14} {}\n", c.name, c.about));
        }
        out.push_str("\nRun '<command> --help' for command options.\n");
        out
    }

    pub fn command_usage(&self, cmd: &Command) -> String {
        let mut out = format!("{} {} — {}\n\nOPTIONS:\n", self.name, cmd.name, cmd.about);
        for o in &cmd.opts {
            let arg = if o.takes_value {
                format!("--{} <v>", o.name)
            } else {
                format!("--{}", o.name)
            };
            let def = o
                .default
                .map(|d| format!(" [default: {d}]"))
                .unwrap_or_default();
            out.push_str(&format!("  {arg:<24} {}{def}\n", o.help));
        }
        out
    }

    /// Parse argv (excluding argv[0]). Returns Err(message) on bad input;
    /// the message for `--help` is the usage text (caller prints + exits 0).
    pub fn parse(&self, argv: &[String]) -> Result<Parsed, String> {
        let Some(cmd_name) = argv.first() else {
            return Err(self.usage());
        };
        if cmd_name == "--help" || cmd_name == "-h" || cmd_name == "help" {
            return Err(self.usage());
        }
        let cmd = self
            .commands
            .iter()
            .find(|c| c.name == cmd_name)
            .ok_or_else(|| format!("unknown command '{cmd_name}'\n\n{}", self.usage()))?;

        let mut parsed = Parsed {
            command: cmd.name.to_string(),
            ..Default::default()
        };
        // Seed defaults.
        for o in &cmd.opts {
            if let (true, Some(d)) = (o.takes_value, o.default) {
                parsed.values.insert(o.name.to_string(), d.to_string());
            }
        }

        let mut i = 1;
        while i < argv.len() {
            let tok = &argv[i];
            if tok == "--help" || tok == "-h" {
                return Err(self.command_usage(cmd));
            }
            if let Some(name) = tok.strip_prefix("--") {
                // Support --key=value.
                let (name, inline) = match name.split_once('=') {
                    Some((n, v)) => (n, Some(v.to_string())),
                    None => (name, None),
                };
                let opt = cmd
                    .opts
                    .iter()
                    .find(|o| o.name == name)
                    .ok_or_else(|| format!("unknown option '--{name}' for '{}'", cmd.name))?;
                if opt.takes_value {
                    let value = match inline {
                        Some(v) => v,
                        None => {
                            i += 1;
                            argv.get(i)
                                .cloned()
                                .ok_or_else(|| format!("--{name} requires a value"))?
                        }
                    };
                    parsed.values.insert(name.to_string(), value);
                } else {
                    if inline.is_some() {
                        return Err(format!("--{name} does not take a value"));
                    }
                    parsed.flags.push(name.to_string());
                }
            } else {
                parsed.positional.push(tok.clone());
            }
            i += 1;
        }
        Ok(parsed)
    }
}

/// Shared option rows used by several subcommands.
pub fn common_run_opts() -> Vec<Opt> {
    vec![
        Opt { name: "dataset", takes_value: true, help: "benchmark: cifar10|cifar100|fmnist|tinyimagenet|caltech256", default: Some("cifar10") },
        Opt { name: "model", takes_value: true, help: "artifact config name", default: Some("small") },
        Opt { name: "method", takes_value: true, help: "selection method", default: Some("sage") },
        Opt { name: "fraction", takes_value: true, help: "kept fraction f", default: Some("0.25") },
        Opt { name: "seed", takes_value: true, help: "experiment seed", default: Some("0") },
        Opt { name: "train-examples", takes_value: true, help: "N train", default: Some("4096") },
        Opt { name: "test-examples", takes_value: true, help: "N test", default: Some("1024") },
        Opt { name: "epochs", takes_value: true, help: "training epochs", default: Some("10") },
        Opt { name: "lr", takes_value: true, help: "base learning rate", default: Some("0.05") },
        Opt { name: "threads", takes_value: true, help: "worker threads", default: None },
        Opt { name: "kernel-tier", takes_value: true, help: "kernel dispatch tier: auto | scalar | simd (tiers are bit-identical)", default: Some("auto") },
        Opt { name: "artifacts", takes_value: true, help: "artifacts directory", default: Some("artifacts") },
        Opt { name: "config", takes_value: true, help: "INI config file (CLI overrides)", default: None },
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    fn app() -> App {
        App {
            name: "sage",
            about: "test app",
            commands: vec![
                Command {
                    name: "select",
                    about: "run selection",
                    opts: vec![
                        Opt { name: "fraction", takes_value: true, help: "f", default: Some("0.25") },
                        Opt { name: "verbose", takes_value: false, help: "chatty", default: None },
                    ],
                },
                Command { name: "train", about: "train", opts: common_run_opts() },
            ],
        }
    }

    fn args(s: &[&str]) -> Vec<String> {
        s.iter().map(|x| x.to_string()).collect()
    }

    #[test]
    fn parses_values_flags_positionals() {
        let p = app()
            .parse(&args(&["select", "--fraction", "0.05", "--verbose", "outfile"]))
            .unwrap();
        assert_eq!(p.command, "select");
        assert_eq!(p.get("fraction"), Some("0.05"));
        assert!(p.has_flag("verbose"));
        assert_eq!(p.positional, vec!["outfile"]);
    }

    #[test]
    fn key_equals_value_form() {
        let p = app().parse(&args(&["select", "--fraction=0.15"])).unwrap();
        assert_eq!(p.get_f64("fraction").unwrap(), Some(0.15));
    }

    #[test]
    fn defaults_applied() {
        let p = app().parse(&args(&["select"])).unwrap();
        assert_eq!(p.get("fraction"), Some("0.25"));
        assert!(!p.has_flag("verbose"));
    }

    #[test]
    fn unknown_option_rejected() {
        assert!(app().parse(&args(&["select", "--bogus"])).is_err());
    }

    #[test]
    fn missing_value_rejected() {
        assert!(app().parse(&args(&["select", "--fraction"])).is_err());
    }

    #[test]
    fn unknown_command_rejected_and_help_is_err() {
        assert!(app().parse(&args(&["frobnicate"])).is_err());
        let help = app().parse(&args(&["--help"])).unwrap_err();
        assert!(help.contains("COMMANDS"));
        let chelp = app().parse(&args(&["select", "--help"])).unwrap_err();
        assert!(chelp.contains("--fraction"));
    }

    #[test]
    fn flag_with_value_rejected() {
        assert!(app().parse(&args(&["select", "--verbose=1"])).is_err());
    }
}
