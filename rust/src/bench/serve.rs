//! `sage bench serve` — the service-layer I/O engine benchmark behind
//! `BENCH_serve.json`.
//!
//! Two measurements per engine (`--io threads` vs `--io epoll`), run
//! against a real in-process server at an equal `--threads` budget:
//!
//! 1. **Concurrency**: open `sessions` TCP connections at once, each
//!    issuing one Stats request and then *holding its connection open*
//!    behind a barrier until every peer has had its chance. An engine's
//!    score is how many of those connections got a response while all of
//!    them were open. Thread-per-connection caps near the pool size (the
//!    rest queue until they time out or are shed with the documented
//!    `connection rejected` frame); the reactor serves them all.
//! 2. **Churn**: sequential connect → CreateSession → CloseSession
//!    cycles on a few workers, yielding sessions/sec and p50/p99 cycle
//!    latency.
//! 3. **Throughput**: `frames` pipelined Stats requests down ONE
//!    connection, bursts kept in flight by a writer thread while the
//!    bench thread counts response frames — the phase where the reactor's
//!    outbox actually builds depth and `writev` batches. Yields
//!    frames/sec and bytes/sec per engine.
//!
//! The report records both engines side by side plus the concurrency
//! ratio (epoll / threads); `sage bench serve --quick` gates the ratio in
//! CI (the reactor must sustain at least [`MIN_CONCURRENCY_RATIO`]× the
//! threaded engine's concurrent sessions). It also re-runs the epoll
//! throughput phase with gathered writes disabled (`writev: false`) as a
//! per-frame baseline and gates batched/baseline ≥ [`MIN_WRITEV_RATIO`].

use crate::service::protocol::{
    encode_frame, op, read_frame, write_frame, FrameDecoder, Request, Response,
};
use crate::service::{IoMode, Server, ServerConfig, ServiceClient};
use crate::util::json::Json;
use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::sync::{Arc, Barrier};
use std::time::{Duration, Instant};

/// The CI gate: reactor concurrent sessions ≥ this × threaded engine's.
pub const MIN_CONCURRENCY_RATIO: f64 = 4.0;

/// The writev gate: batched epoll frames/sec ≥ this × the per-frame
/// baseline's. Below 1.0 so parity-with-noise passes while a real
/// regression (batching slower than one syscall per frame) fails.
pub const MIN_WRITEV_RATIO: f64 = 0.95;

/// Requests kept in flight per burst by the throughput writer thread —
/// deep enough that the reactor's outbox holds multiple frames per
/// `writev`, shallow enough to stay under socket buffers.
const PIPELINE_BURST: usize = 32;

/// Knobs for one `run_serve_bench` invocation.
#[derive(Clone, Debug)]
pub struct ServeBenchSpec {
    /// Thread budget handed to BOTH engines (threaded: pool size;
    /// reactor: 1 loop + threads-1 workers).
    pub threads: usize,
    /// Concurrent connections attempted in the concurrency phase.
    pub sessions: usize,
    /// Total connect→create→close cycles in the churn phase.
    pub churn: usize,
    /// Pipelined Stats requests in the throughput phase.
    pub frames: usize,
    /// Per-request client timeout; also bounds how long a queued-but-
    /// never-served connection counts against the threaded engine.
    pub timeout: Duration,
}

impl Default for ServeBenchSpec {
    fn default() -> Self {
        ServeBenchSpec {
            threads: 4,
            sessions: 64,
            churn: 200,
            frames: 6000,
            timeout: Duration::from_secs(2),
        }
    }
}

impl ServeBenchSpec {
    /// CI smoke sizing: fewer connections and cycles, shorter timeout.
    pub fn quick(mut self) -> Self {
        self.sessions = 32;
        self.churn = 80;
        self.frames = 2000;
        self.timeout = Duration::from_millis(1500);
        self
    }
}

/// One engine's results.
#[derive(Clone, Debug)]
pub struct EngineResult {
    /// `"threads"` or `"epoll"`.
    pub io: String,
    /// Connections attempted in the concurrency phase.
    pub attempted: usize,
    /// Connections that got a Stats response while all were held open.
    pub concurrent_ok: usize,
    /// Churn throughput (completed cycles / wall clock).
    pub sessions_per_sec: f64,
    /// Churn cycle latency percentiles, milliseconds.
    pub p50_ms: f64,
    pub p99_ms: f64,
    /// Churn cycles that errored (shed connections under pressure).
    pub churn_failed: usize,
    /// Throughput phase: pipelined Stats responses per second.
    pub frames_per_sec: f64,
    /// Throughput phase: response wire bytes per second.
    pub bytes_per_sec: f64,
}

impl EngineResult {
    fn to_json(&self) -> Json {
        let mut m = BTreeMap::new();
        m.insert("io".into(), Json::Str(self.io.clone()));
        m.insert("attempted".into(), Json::Num(self.attempted as f64));
        m.insert("concurrent_ok".into(), Json::Num(self.concurrent_ok as f64));
        m.insert(
            "sessions_per_sec".into(),
            Json::Num(self.sessions_per_sec),
        );
        m.insert("p50_ms".into(), Json::Num(self.p50_ms));
        m.insert("p99_ms".into(), Json::Num(self.p99_ms));
        m.insert("churn_failed".into(), Json::Num(self.churn_failed as f64));
        m.insert("frames_per_sec".into(), Json::Num(self.frames_per_sec));
        m.insert("bytes_per_sec".into(), Json::Num(self.bytes_per_sec));
        Json::Obj(m)
    }
}

/// Full report (serialize with [`ServeBenchReport::to_json_string`]).
#[derive(Clone, Debug)]
pub struct ServeBenchReport {
    pub threads: usize,
    pub sessions: usize,
    pub frames: usize,
    pub engines: Vec<EngineResult>,
    /// Epoll throughput with gathered writes forced OFF (`writev: false`)
    /// — the one-syscall-per-frame baseline the writev gate compares
    /// against. `None` when the host cannot run the reactor.
    pub perframe_frames_per_sec: Option<f64>,
}

impl ServeBenchReport {
    fn engine(&self, io: &str) -> Option<&EngineResult> {
        self.engines.iter().find(|e| e.io == io)
    }

    /// Concurrency ratio epoll / threads, when both engines ran.
    pub fn concurrency_ratio(&self) -> Option<f64> {
        let threads = self.engine("threads")?.concurrent_ok.max(1);
        let epoll = self.engine("epoll")?.concurrent_ok;
        Some(epoll as f64 / threads as f64)
    }

    /// Whether the reactor met the [`MIN_CONCURRENCY_RATIO`] gate (`None`
    /// when the host cannot run both engines).
    pub fn ratio_holds(&self) -> Option<bool> {
        self.concurrency_ratio().map(|r| r >= MIN_CONCURRENCY_RATIO)
    }

    /// Batched / per-frame throughput ratio for the reactor, when both
    /// epoll runs happened.
    pub fn writev_ratio(&self) -> Option<f64> {
        let baseline = self.perframe_frames_per_sec?.max(1e-9);
        let batched = self.engine("epoll")?.frames_per_sec;
        Some(batched / baseline)
    }

    /// Whether gathered writes met the [`MIN_WRITEV_RATIO`] gate (`None`
    /// when the host cannot run the reactor).
    pub fn writev_holds(&self) -> Option<bool> {
        self.writev_ratio().map(|r| r >= MIN_WRITEV_RATIO)
    }

    pub fn to_json(&self) -> Json {
        let mut m = BTreeMap::new();
        m.insert("suite".into(), Json::Str("serve".into()));
        m.insert("threads".into(), Json::Num(self.threads as f64));
        m.insert("sessions".into(), Json::Num(self.sessions as f64));
        m.insert("frames".into(), Json::Num(self.frames as f64));
        m.insert(
            "engines".into(),
            Json::Arr(self.engines.iter().map(|e| e.to_json()).collect()),
        );
        match self.concurrency_ratio() {
            Some(r) => m.insert("concurrency_ratio".into(), Json::Num(r)),
            None => m.insert("concurrency_ratio".into(), Json::Null),
        };
        match self.perframe_frames_per_sec {
            Some(f) => m.insert("perframe_frames_per_sec".into(), Json::Num(f)),
            None => m.insert("perframe_frames_per_sec".into(), Json::Null),
        };
        match self.writev_ratio() {
            Some(r) => m.insert("writev_ratio".into(), Json::Num(r)),
            None => m.insert("writev_ratio".into(), Json::Null),
        };
        Json::Obj(m)
    }

    pub fn to_json_string(&self) -> String {
        crate::util::json::write(&self.to_json())
    }
}

/// Run the suite: the threaded engine always, the reactor where the host
/// supports epoll. An engine that fails to start is skipped with a WARN
/// (the report then simply lacks its row).
pub fn run_serve_bench(spec: &ServeBenchSpec) -> ServeBenchReport {
    let mut engines = Vec::new();
    let mut modes = vec![IoMode::Threads];
    if crate::util::sys::epoll_supported() {
        modes.push(IoMode::Epoll);
    }
    for mode in modes {
        match bench_engine(spec, mode) {
            Ok(result) => engines.push(result),
            Err(e) => crate::log_warn!("serve bench ({}) failed: {e}", mode.name()),
        }
    }
    // Per-frame baseline: the reactor again, gathered writes disabled, so
    // the writev gate has an apples-to-apples syscall-per-frame number.
    let perframe_frames_per_sec = if crate::util::sys::epoll_supported() {
        match throughput_only(spec, IoMode::Epoll, false) {
            Ok(fps) => Some(fps),
            Err(e) => {
                crate::log_warn!("serve bench (epoll per-frame baseline) failed: {e}");
                None
            }
        }
    } else {
        None
    };
    ServeBenchReport {
        threads: spec.threads,
        sessions: spec.sessions,
        frames: spec.frames,
        engines,
        perframe_frames_per_sec,
    }
}

fn server_config(spec: &ServeBenchSpec, mode: IoMode, writev: bool) -> ServerConfig {
    ServerConfig {
        addr: "127.0.0.1:0".into(),
        threads: spec.threads.max(1),
        io: mode,
        compute_workers: 1,
        metrics_addr: None,
        slow_op_ms: 0,
        registry: Default::default(),
        writev,
        sndbuf: None,
    }
}

fn bench_engine(spec: &ServeBenchSpec, mode: IoMode) -> Result<EngineResult, String> {
    let server = Server::bind(&server_config(spec, mode, true))?;
    let addr = server.local_addr();
    let handle = server.spawn();

    let concurrent_ok = concurrency_phase(addr, spec);
    let (sessions_per_sec, p50_ms, p99_ms, churn_failed) = churn_phase(addr, spec);
    let (frames_per_sec, bytes_per_sec) = throughput_phase(addr, spec)?;

    handle.shutdown();
    Ok(EngineResult {
        io: mode.name().to_string(),
        attempted: spec.sessions,
        concurrent_ok,
        sessions_per_sec,
        p50_ms,
        p99_ms,
        churn_failed,
        frames_per_sec,
        bytes_per_sec,
    })
}

/// A fresh server running only the throughput phase — used for the
/// `writev: false` baseline leg of the gate.
fn throughput_only(spec: &ServeBenchSpec, mode: IoMode, writev: bool) -> Result<f64, String> {
    let server = Server::bind(&server_config(spec, mode, writev))?;
    let addr = server.local_addr();
    let handle = server.spawn();
    let result = throughput_phase(addr, spec);
    handle.shutdown();
    result.map(|(frames_per_sec, _)| frames_per_sec)
}

/// Pipelined Stats frames down one connection: a writer thread keeps
/// [`PIPELINE_BURST`]-deep bursts in flight while this thread counts
/// response frames off a [`FrameDecoder`]. Returns (frames/sec,
/// bytes/sec) over the whole exchange.
fn throughput_phase(addr: SocketAddr, spec: &ServeBenchSpec) -> Result<(f64, f64), String> {
    let frames = spec.frames.max(1);
    let mut stream = TcpStream::connect(addr).map_err(|e| e.to_string())?;
    let _ = stream.set_nodelay(true);
    stream
        .set_read_timeout(Some(spec.timeout))
        .map_err(|e| e.to_string())?;
    let mut writer = stream.try_clone().map_err(|e| e.to_string())?;
    let request = Request::Stats {
        session: String::new(),
    };
    let wire = encode_frame(op::STATS, 0, &request.encode());
    let t0 = Instant::now();
    let writer_join = std::thread::spawn(move || {
        let mut burst = Vec::with_capacity(wire.len() * PIPELINE_BURST);
        let mut sent = 0usize;
        while sent < frames {
            let n = PIPELINE_BURST.min(frames - sent);
            burst.clear();
            for _ in 0..n {
                burst.extend_from_slice(&wire);
            }
            if writer.write_all(&burst).is_err() {
                return;
            }
            sent += n;
        }
        let _ = writer.flush();
    });
    let mut decoder = FrameDecoder::new();
    let mut chunk = vec![0u8; 64 << 10];
    let mut got = 0usize;
    let mut bytes = 0usize;
    while got < frames {
        if decoder.next_frame()?.is_some() {
            got += 1;
            continue;
        }
        let n = stream.read(&mut chunk).map_err(|e| e.to_string())?;
        if n == 0 {
            return Err(format!("connection closed after {got}/{frames} frames"));
        }
        bytes += n;
        decoder.extend(&chunk[..n]);
    }
    let elapsed = t0.elapsed().as_secs_f64().max(1e-9);
    let _ = writer_join.join();
    Ok((got as f64 / elapsed, bytes as f64 / elapsed))
}

/// Open every connection, one Stats round trip each, all held open behind
/// a barrier so the engine really serves them *simultaneously*.
fn concurrency_phase(addr: SocketAddr, spec: &ServeBenchSpec) -> usize {
    let barrier = Arc::new(Barrier::new(spec.sessions));
    let joins: Vec<_> = (0..spec.sessions)
        .map(|_| {
            let barrier = barrier.clone();
            let timeout = spec.timeout;
            std::thread::spawn(move || {
                let ok = stats_roundtrip(addr, timeout).is_ok();
                // Hold the connection open until every peer has tried.
                barrier.wait();
                ok
            })
        })
        .collect();
    joins
        .into_iter()
        .map(|j| j.join().unwrap_or(false))
        .filter(|&ok| ok)
        .count()
}

/// One raw Stats round trip with a read deadline (a queued-but-unserved
/// connection must count as *not* concurrent, not hang the bench).
fn stats_roundtrip(addr: SocketAddr, timeout: Duration) -> Result<(), String> {
    let mut stream = TcpStream::connect(addr).map_err(|e| e.to_string())?;
    let _ = stream.set_nodelay(true);
    stream
        .set_read_timeout(Some(timeout))
        .map_err(|e| e.to_string())?;
    let request = Request::Stats {
        session: String::new(),
    };
    write_frame(&mut stream, op::STATS, 0, &request.encode())?;
    let frame = read_frame(&mut stream)?.ok_or_else(|| "connection closed".to_string())?;
    match Response::decode(&frame.payload)? {
        Response::Stats { .. } => Ok(()),
        Response::Error { message } => Err(message),
        other => Err(format!("unexpected response {other:?}")),
    }
}

/// Session-lifecycle churn: connect → CreateSession → CloseSession, a few
/// workers deep. Returns (sessions/sec, p50 ms, p99 ms, failures).
fn churn_phase(addr: SocketAddr, spec: &ServeBenchSpec) -> (f64, f64, f64, usize) {
    let workers = spec.threads.clamp(1, 4);
    let per_worker = (spec.churn / workers).max(1);
    let t0 = Instant::now();
    let joins: Vec<_> = (0..workers)
        .map(|w| {
            let addr = addr.to_string();
            std::thread::spawn(move || {
                let mut latencies = Vec::with_capacity(per_worker);
                let mut failed = 0usize;
                for i in 0..per_worker {
                    let name = format!("bench-serve-{w}-{i}");
                    let t = Instant::now();
                    let ok = (|| -> Result<(), String> {
                        let mut client = ServiceClient::connect(&addr)?;
                        client.create_session(&name, 4, 8, 1)?;
                        client.close_session(&name)
                    })();
                    match ok {
                        Ok(()) => latencies.push(t.elapsed().as_secs_f64() * 1e3),
                        Err(_) => failed += 1,
                    }
                }
                (latencies, failed)
            })
        })
        .collect();
    let mut latencies = Vec::new();
    let mut failed = 0usize;
    for j in joins {
        if let Ok((l, f)) = j.join() {
            latencies.extend(l);
            failed += f;
        }
    }
    let elapsed = t0.elapsed().as_secs_f64().max(1e-9);
    let per_sec = latencies.len() as f64 / elapsed;
    latencies.sort_by(|a, b| a.total_cmp(b));
    (per_sec, percentile(&latencies, 50), percentile(&latencies, 99), failed)
}

fn percentile(sorted_ms: &[f64], p: usize) -> f64 {
    if sorted_ms.is_empty() {
        return 0.0;
    }
    let idx = (sorted_ms.len() * p / 100).min(sorted_ms.len() - 1);
    sorted_ms[idx]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn serve_bench_smoke_and_json_shape() {
        let spec = ServeBenchSpec {
            threads: 2,
            sessions: 4,
            churn: 8,
            frames: 64,
            timeout: Duration::from_millis(800),
        };
        let report = run_serve_bench(&spec);
        assert!(!report.engines.is_empty(), "at least the threaded engine runs");
        for engine in &report.engines {
            assert_eq!(engine.attempted, 4);
            assert!(engine.concurrent_ok >= 1, "{engine:?}");
            assert!(engine.sessions_per_sec > 0.0, "{engine:?}");
            assert!(engine.p99_ms >= engine.p50_ms, "{engine:?}");
            assert!(engine.frames_per_sec > 0.0, "{engine:?}");
            assert!(engine.bytes_per_sec > engine.frames_per_sec, "{engine:?}");
        }
        // The reactor serves every connection when the host has epoll,
        // and the per-frame baseline leg ran for the writev gate.
        if crate::util::sys::epoll_supported() {
            let epoll = report.engine("epoll").expect("epoll engine ran");
            assert_eq!(epoll.concurrent_ok, 4);
            assert!(report.perframe_frames_per_sec.unwrap_or(0.0) > 0.0);
            assert!(report.writev_ratio().unwrap_or(0.0) > 0.0);
        }
        let parsed = crate::util::json::parse(&report.to_json_string()).expect("valid json");
        assert_eq!(parsed.get("suite").and_then(|j| j.as_str()), Some("serve"));
        let engines = parsed.get("engines").and_then(|j| j.as_arr()).unwrap();
        assert_eq!(engines.len(), report.engines.len());
        for engine in engines {
            assert!(engine.get("frames_per_sec").is_some());
            assert!(engine.get("bytes_per_sec").is_some());
        }
    }

    #[test]
    fn percentile_and_ratio_edges() {
        assert_eq!(percentile(&[], 99), 0.0);
        assert_eq!(percentile(&[1.0, 2.0, 3.0, 4.0], 50), 3.0);
        assert_eq!(percentile(&[1.0, 2.0, 3.0, 4.0], 99), 4.0);
        let mut report = ServeBenchReport {
            threads: 2,
            sessions: 8,
            frames: 64,
            engines: vec![
                EngineResult {
                    io: "threads".into(),
                    attempted: 8,
                    concurrent_ok: 2,
                    sessions_per_sec: 10.0,
                    p50_ms: 1.0,
                    p99_ms: 2.0,
                    churn_failed: 0,
                    frames_per_sec: 1000.0,
                    bytes_per_sec: 50_000.0,
                },
                EngineResult {
                    io: "epoll".into(),
                    attempted: 8,
                    concurrent_ok: 8,
                    sessions_per_sec: 10.0,
                    p50_ms: 1.0,
                    p99_ms: 2.0,
                    churn_failed: 0,
                    frames_per_sec: 2000.0,
                    bytes_per_sec: 100_000.0,
                },
            ],
            perframe_frames_per_sec: Some(2000.0),
        };
        assert_eq!(report.concurrency_ratio(), Some(4.0));
        assert_eq!(report.ratio_holds(), Some(true));
        // Parity passes the writev gate; a real regression fails it.
        assert_eq!(report.writev_ratio(), Some(1.0));
        assert_eq!(report.writev_holds(), Some(true));
        report.engines[1].frames_per_sec = 2000.0 * (MIN_WRITEV_RATIO - 0.05);
        assert_eq!(report.writev_holds(), Some(false));
        report.perframe_frames_per_sec = None;
        assert_eq!(report.writev_holds(), None);
    }
}
