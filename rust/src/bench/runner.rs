//! Experiment runner: one cell of the paper's evaluation grid.
//!
//! A *cell* is `(dataset, method, fraction, seed)`. Running it means:
//! generate the simulated benchmark → (if fraction < 1) run the two-pass
//! selection pipeline → train on the kept subset → evaluate top-1 accuracy
//! and wall-clock. "Full data" cells skip selection. Wall-clock matches the
//! paper's definition: *end-to-end including selection*.

use crate::config::Method;
use crate::data::{generate, BenchmarkKind, Dataset};
use crate::pipeline::{run_selection, PipelineConfig};
use crate::runtime::ModelBackend;
use crate::sketch::ShrinkBackend;
use crate::trainer::{train_weighted, TrainConfig};
use std::sync::Arc;

/// Specification of one experiment cell.
#[derive(Clone, Debug)]
pub struct CellSpec {
    pub dataset: BenchmarkKind,
    pub method: Method,
    pub fraction: f64,
    pub seed: u64,
    pub train_examples: usize,
    pub test_examples: usize,
    pub epochs: usize,
    pub base_lr: f64,
    pub workers: usize,
    pub warmup_steps: usize,
}

impl CellSpec {
    pub fn new(dataset: BenchmarkKind, method: Method, fraction: f64, seed: u64) -> Self {
        Self {
            dataset,
            method,
            fraction,
            seed,
            train_examples: 4096,
            test_examples: 1024,
            epochs: 10,
            base_lr: 0.05,
            workers: crate::util::threadpool::default_threads().min(4),
            warmup_steps: 30,
        }
    }
}

/// Result of one cell.
#[derive(Clone, Debug)]
pub struct CellResult {
    pub dataset: &'static str,
    pub method: &'static str,
    pub fraction: f64,
    pub seed: u64,
    pub accuracy: f64,
    pub select_seconds: f64,
    pub train_seconds: f64,
    /// End-to-end (selection + training), the paper's wall-clock.
    pub total_seconds: f64,
    pub subset_size: usize,
    pub sketch_bytes: usize,
}

/// Generate the (train, test) pair for a cell. Feature dim comes from the
/// backend so the same datasets work for reference and XLA backends.
pub fn cell_datasets(spec: &CellSpec, features: usize) -> (Dataset, Dataset) {
    let synth = spec.dataset.spec(features);
    let train = generate(&synth, spec.train_examples, spec.seed, 0);
    let test = generate(&synth, spec.test_examples, spec.seed, 1);
    (train, test)
}

/// Run one cell on the given backend.
pub fn run_cell(
    backend: &dyn ModelBackend,
    spec: &CellSpec,
    shrink: Option<Arc<dyn ShrinkBackend>>,
) -> Result<CellResult, String> {
    let mspec = backend.spec();
    if mspec.c != spec.dataset.num_classes() {
        return Err(format!(
            "backend classes {} != dataset {} ({})",
            mspec.c,
            spec.dataset.num_classes(),
            spec.dataset.name()
        ));
    }
    let (train_ds, test_ds) = cell_datasets(spec, mspec.f);
    let full = spec.method == Method::Full || spec.fraction >= 1.0;

    let (subset, weights, select_seconds, sketch_bytes) = if full {
        (train_ds.clone(), None, 0.0, 0)
    } else {
        let k = ((spec.fraction * train_ds.len() as f64).ceil() as usize)
            .clamp(1, train_ds.len());
        let pcfg = PipelineConfig {
            workers: spec.workers,
            warmup_steps: spec.warmup_steps,
            warmup_lr: spec.base_lr,
            seed: spec.seed,
            compute: crate::tensor::compute_backend(spec.workers),
            ..Default::default()
        };
        let out = run_selection(backend, &train_ds, spec.method, k, &pcfg, shrink)?;
        let secs = out.warmup_seconds + out.phase1.seconds + out.phase2.seconds + out.select_seconds;
        (
            train_ds.subset(&out.indices),
            out.weights,
            secs,
            out.sketch_bytes,
        )
    };

    let tcfg = TrainConfig {
        epochs: spec.epochs,
        base_lr: spec.base_lr,
        seed: spec.seed,
        ..Default::default()
    };
    let res = train_weighted(backend, &subset, &test_ds, &tcfg, weights.as_deref())?;

    Ok(CellResult {
        dataset: spec.dataset.name(),
        method: spec.method.name(),
        fraction: spec.fraction,
        seed: spec.seed,
        accuracy: res.test_accuracy,
        select_seconds,
        train_seconds: res.train_seconds,
        total_seconds: select_seconds + res.train_seconds,
        subset_size: subset.len(),
        sketch_bytes,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::grad::{MlpSpec, TrainHyper};
    use crate::runtime::ReferenceModelBackend;

    fn backend() -> ReferenceModelBackend {
        ReferenceModelBackend::new(MlpSpec::new(8, 12, 10), TrainHyper::default(), 16, 16, 8)
    }

    fn small_spec(method: Method, fraction: f64) -> CellSpec {
        CellSpec {
            train_examples: 200,
            test_examples: 100,
            epochs: 3,
            workers: 2,
            warmup_steps: 3,
            ..CellSpec::new(BenchmarkKind::Cifar10, method, fraction, 0)
        }
    }

    #[test]
    fn full_cell_runs_without_selection() {
        let r = run_cell(&backend(), &small_spec(Method::Full, 1.0), None).unwrap();
        assert_eq!(r.subset_size, 200);
        assert_eq!(r.select_seconds, 0.0);
        assert!(r.accuracy > 0.2);
    }

    #[test]
    fn sage_cell_selects_and_trains() {
        let r = run_cell(&backend(), &small_spec(Method::Sage, 0.25), None).unwrap();
        assert_eq!(r.subset_size, 50);
        assert!(r.select_seconds > 0.0);
        assert!(r.total_seconds >= r.train_seconds);
        assert!(r.sketch_bytes > 0);
    }

    #[test]
    fn class_mismatch_rejected() {
        let spec = CellSpec {
            train_examples: 100,
            ..CellSpec::new(BenchmarkKind::Cifar100, Method::Sage, 0.25, 0)
        };
        assert!(run_cell(&backend(), &spec, None).is_err());
    }
}
