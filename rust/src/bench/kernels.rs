//! Kernel-layer benchmark: the full backend × dispatch-tier matrix —
//! {serial, parallel} × {scalar, simd} — over the four hot contractions at
//! paper-scale shapes, emitted as the repo-root `BENCH_kernels.json` perf
//! trajectory (subsequent PRs beat these numbers).
//!
//! Ops measured (shapes from the paper's large configuration, ℓ = 256,
//! D = 16384 by default):
//!
//! * `gram`    — FD shrink Gram, `2ℓ × D` buffer → `2ℓ × 2ℓ`
//! * `project` — Phase-II projection `G·Sᵀ`, `B × D` · `(ℓ × D)ᵀ`
//! * `shrink`  — one full FD shrink (Gram + eig + rotation) end to end
//! * `score`   — consensus matvec `α = Ẑ·u` over `N × ℓ`
//!
//! Every cell of the matrix is checked bit-identical against the
//! serial-scalar reference before it is timed — the determinism contract
//! says the tier and the worker count may never change a bit, so a bench
//! that silently measured diverging kernels would be worthless as a perf
//! trajectory.
//!
//! Driven by `sage bench kernels [--quick]`; `--quick` additionally gates
//! (non-zero exit upstream) when a parallel kernel loses to serial or the
//! SIMD tier loses to scalar on `gram`/`project`.

use crate::sketch::FdSketch;
use crate::tensor::kernels::{self, KernelTier};
use crate::tensor::{ComputeBackend, Matrix, ParallelBackend, PinnedSerialBackend};
use crate::util::json::Json;
use crate::util::rng::Pcg64;
use std::collections::BTreeMap;
use std::sync::Arc;
use std::time::Instant;

/// Shapes + measurement knobs for one bench run.
#[derive(Clone, Debug)]
pub struct KernelBenchSpec {
    /// Sketch size ℓ (buffer rows = 2ℓ).
    pub ell: usize,
    /// Gradient dimension D.
    pub d: usize,
    /// Phase-II scoring batch B.
    pub batch: usize,
    /// Scored examples N for the consensus matvec.
    pub n_examples: usize,
    /// Parallel worker threads.
    pub workers: usize,
    /// Timed iterations per op (1 warmup on top).
    pub iters: usize,
}

impl Default for KernelBenchSpec {
    fn default() -> Self {
        Self {
            ell: 256,
            d: 16384,
            batch: 256,
            n_examples: 100_000,
            workers: crate::util::threadpool::default_threads(),
            iters: 5,
        }
    }
}

impl KernelBenchSpec {
    /// CI smoke shapes: same paper-scale dims, fewer iterations.
    pub fn quick(mut self) -> Self {
        self.iters = 3;
        self
    }
}

/// Serial + parallel nanoseconds for one dispatch tier.
#[derive(Clone, Copy, Debug)]
pub struct TierTiming {
    pub serial_ns: f64,
    pub parallel_ns: f64,
}

impl TierTiming {
    /// Parallel-over-serial speedup within this tier.
    pub fn parallel_speedup(&self) -> f64 {
        if self.parallel_ns <= 0.0 {
            0.0
        } else {
            self.serial_ns / self.parallel_ns
        }
    }
}

/// One op's measurement across the backend × tier matrix.
#[derive(Clone, Debug)]
pub struct OpResult {
    pub name: &'static str,
    pub shape: String,
    /// Multiply-adds per iteration (×2 = FLOPs).
    pub madds: f64,
    /// The scalar reference tier (always measured).
    pub scalar: TierTiming,
    /// The SIMD tier, when the host has one.
    pub simd: Option<TierTiming>,
    /// Every cell's output compared bit-for-bit against serial-scalar
    /// before timing.
    pub bits_equal: bool,
}

impl OpResult {
    /// Parallel-over-serial speedup on the scalar tier (the PR 3 gate).
    pub fn speedup(&self) -> f64 {
        self.scalar.parallel_speedup()
    }

    /// Serial SIMD over serial scalar — the tentpole's headline number.
    pub fn simd_speedup(&self) -> Option<f64> {
        let simd = self.simd?;
        if simd.serial_ns <= 0.0 {
            return Some(0.0);
        }
        Some(self.scalar.serial_ns / simd.serial_ns)
    }

    fn gflops(&self, ns: f64) -> f64 {
        if ns <= 0.0 {
            0.0
        } else {
            2.0 * self.madds / ns
        }
    }

    fn to_json(&self) -> Json {
        let mut m = BTreeMap::new();
        m.insert("op".into(), Json::Str(self.name.into()));
        m.insert("shape".into(), Json::Str(self.shape.clone()));
        m.insert("serial_scalar_ns".into(), Json::Num(self.scalar.serial_ns));
        m.insert(
            "parallel_scalar_ns".into(),
            Json::Num(self.scalar.parallel_ns),
        );
        m.insert("parallel_speedup".into(), Json::Num(self.speedup()));
        m.insert(
            "serial_scalar_gflops".into(),
            Json::Num(self.gflops(self.scalar.serial_ns)),
        );
        if let Some(simd) = self.simd {
            m.insert("serial_simd_ns".into(), Json::Num(simd.serial_ns));
            m.insert("parallel_simd_ns".into(), Json::Num(simd.parallel_ns));
            m.insert(
                "simd_speedup".into(),
                Json::Num(self.simd_speedup().unwrap_or(0.0)),
            );
            m.insert(
                "parallel_simd_gflops".into(),
                Json::Num(self.gflops(simd.parallel_ns)),
            );
        }
        m.insert("bits_equal".into(), Json::Bool(self.bits_equal));
        Json::Obj(m)
    }
}

/// Full bench report (serialize with [`KernelBenchReport::to_json_string`]).
pub struct KernelBenchReport {
    pub spec: KernelBenchSpec,
    pub host_threads: usize,
    /// The process-wide tier `sage` would select here (auto).
    pub active_tier: &'static str,
    /// Whether a SIMD tier exists on this host (the matrix has 4 columns
    /// when true, 2 when false).
    pub simd_available: bool,
    pub ops: Vec<OpResult>,
}

impl KernelBenchReport {
    /// Result row for `name`, if measured.
    pub fn op(&self, name: &str) -> Option<&OpResult> {
        self.ops.iter().find(|o| o.name == name)
    }

    /// All cells of the matrix bit-identical to the serial-scalar
    /// reference.
    pub fn bits_hold(&self) -> bool {
        !self.ops.is_empty() && self.ops.iter().all(|o| o.bits_equal)
    }

    /// CI quick-gate condition ("parallel must not lose"): the two pure
    /// paper-scale contractions — `gram` and `project` — must be at least
    /// as fast parallel as serial on the scalar tier, bit-equal
    /// everywhere. (`shrink` embeds a serial eigendecomposition and
    /// `score` is a sub-10 ms matvec; both are reported but too
    /// noise-prone to gate a shared runner on.)
    pub fn parallel_holds(&self) -> bool {
        self.bits_hold()
            && ["gram", "project"]
                .iter()
                .all(|name| self.op(name).is_some_and(|o| o.speedup() >= 1.0))
    }

    /// The tentpole gate ("SIMD must not lose to scalar"): serial SIMD at
    /// least as fast as serial scalar on `gram` and `project`. `None`
    /// when the host has no SIMD tier (nothing to gate).
    pub fn simd_holds(&self) -> Option<bool> {
        if !self.simd_available {
            return None;
        }
        Some(["gram", "project"].iter().all(|name| {
            self.op(name)
                .and_then(|o| o.simd_speedup())
                .is_some_and(|s| s >= 1.0)
        }))
    }

    pub fn to_json(&self) -> Json {
        let mut m = BTreeMap::new();
        m.insert("bench".into(), Json::Str("kernels".into()));
        m.insert("ell".into(), Json::Num(self.spec.ell as f64));
        m.insert("d".into(), Json::Num(self.spec.d as f64));
        m.insert("batch".into(), Json::Num(self.spec.batch as f64));
        m.insert("n_examples".into(), Json::Num(self.spec.n_examples as f64));
        m.insert("workers".into(), Json::Num(self.spec.workers as f64));
        m.insert("iters".into(), Json::Num(self.spec.iters as f64));
        m.insert("host_threads".into(), Json::Num(self.host_threads as f64));
        m.insert("active_tier".into(), Json::Str(self.active_tier.into()));
        m.insert("simd_available".into(), Json::Bool(self.simd_available));
        m.insert(
            "ops".into(),
            Json::Arr(self.ops.iter().map(|o| o.to_json()).collect()),
        );
        Json::Obj(m)
    }

    pub fn to_json_string(&self) -> String {
        crate::util::json::write(&self.to_json())
    }
}

/// Best-of-iters timing of `f` (1 unmeasured warmup). Best-of is the right
/// statistic for a regression gate: it is the least noise-sensitive.
fn best_ns(iters: usize, mut f: impl FnMut()) -> f64 {
    f();
    let mut best = f64::INFINITY;
    for _ in 0..iters.max(1) {
        let t0 = Instant::now();
        f();
        let ns = t0.elapsed().as_nanos() as f64;
        if ns < best {
            best = ns;
        }
    }
    best
}

fn bits_equal(a: &[f32], b: &[f32]) -> bool {
    a.len() == b.len() && a.iter().zip(b.iter()).all(|(x, y)| x.to_bits() == y.to_bits())
}

/// The serial + parallel backend pair pinned to one dispatch tier.
struct TierPair {
    dispatch: &'static kernels::KernelDispatch,
    serial: PinnedSerialBackend,
    parallel: ParallelBackend,
}

impl TierPair {
    fn new(dispatch: &'static kernels::KernelDispatch, workers: usize) -> Self {
        Self {
            dispatch,
            serial: PinnedSerialBackend(dispatch),
            parallel: ParallelBackend::with_threads(workers).with_dispatch(dispatch),
        }
    }
}

/// Measure one op across every tier: `run(backend)` computes the op on a
/// backend and returns its output as f32 bits for the identity check.
fn measure_op(
    tiers: &[TierPair],
    iters: usize,
    run: impl Fn(&dyn ComputeBackend) -> Vec<f32>,
) -> (TierTiming, Option<TierTiming>, bool) {
    let reference = run(&tiers[0].serial);
    let mut eq = true;
    let mut timings = Vec::with_capacity(tiers.len());
    for pair in tiers {
        eq &= bits_equal(&run(&pair.serial), &reference);
        eq &= bits_equal(&run(&pair.parallel), &reference);
        let serial_ns = best_ns(iters, || {
            std::hint::black_box(run(std::hint::black_box(&pair.serial)));
        });
        let parallel_ns = best_ns(iters, || {
            std::hint::black_box(run(std::hint::black_box(&pair.parallel)));
        });
        timings.push(TierTiming {
            serial_ns,
            parallel_ns,
        });
    }
    (timings[0], timings.get(1).copied(), eq)
}

/// Run the kernel bench over the full {serial, parallel} × {scalar, simd}
/// matrix, verifying bit-identity of every cell against the serial-scalar
/// reference before timing it.
pub fn run_kernel_bench(spec: &KernelBenchSpec) -> KernelBenchReport {
    // Scalar first: index 0 is the reference tier in every measurement.
    let mut tiers = vec![TierPair::new(kernels::scalar_dispatch(), spec.workers)];
    if let Some(simd) = kernels::simd_dispatch() {
        tiers.push(TierPair::new(simd, spec.workers));
    }

    let mut rng = Pcg64::seeded(0xBE7C);
    let m = 2 * spec.ell;

    let buf = Matrix::from_fn(m, spec.d, |_, _| rng.normal_f32());
    let grads = Matrix::from_fn(spec.batch, spec.d, |_, _| rng.normal_f32());
    let sketch = Matrix::from_fn(spec.ell, spec.d, |_, _| 0.1 * rng.normal_f32());
    let zhat = Matrix::from_fn(spec.n_examples, spec.ell, |_, _| rng.normal_f32());
    let u: Vec<f32> = (0..spec.ell).map(|_| rng.normal_f32()).collect();

    let mut ops = Vec::new();

    // --- gram: the FD shrink's m×m Gram over the 2ℓ×D buffer ---
    {
        let (scalar, simd, eq) = measure_op(&tiers, spec.iters, |backend| {
            backend.gram(&buf).as_slice().to_vec()
        });
        ops.push(OpResult {
            name: "gram",
            shape: format!("{m}x{} -> {m}x{m}", spec.d),
            madds: (m * m) as f64 / 2.0 * spec.d as f64,
            scalar,
            simd,
            bits_equal: eq,
        });
    }

    // --- project: Phase-II G·Sᵀ ---
    {
        let (scalar, simd, eq) = measure_op(&tiers, spec.iters, |backend| {
            backend.matmul_transb(&grads, &sketch).as_slice().to_vec()
        });
        ops.push(OpResult {
            name: "project",
            shape: format!("{}x{} @ ({}x{})T", spec.batch, spec.d, spec.ell, spec.d),
            madds: (spec.batch * spec.ell * spec.d) as f64,
            scalar,
            simd,
            bits_equal: eq,
        });
    }

    // --- shrink: one full FD contraction (gram + eig + apply_rot) ---
    {
        let refill = Matrix::from_fn(spec.ell, spec.d, |_, _| rng.normal_f32());
        // Bit-identity: sketches fed the same stream on every cell of the
        // matrix must agree with the serial-scalar reference.
        let stream_sketch = |backend: Arc<dyn ComputeBackend>| -> Vec<f32> {
            let mut fd = FdSketch::with_backend(spec.ell, spec.d, backend);
            fd.insert_batch(&buf);
            fd.sketch().as_slice().to_vec()
        };
        let reference = stream_sketch(Arc::new(PinnedSerialBackend(tiers[0].dispatch)));
        let mut eq = true;
        let mut timings = Vec::with_capacity(tiers.len());
        for pair in &tiers {
            eq &= bits_equal(
                &stream_sketch(Arc::new(PinnedSerialBackend(pair.dispatch))),
                &reference,
            );
            eq &= bits_equal(
                &stream_sketch(Arc::new(
                    ParallelBackend::with_threads(spec.workers).with_dispatch(pair.dispatch),
                )),
                &reference,
            );
            let shrink_once = |backend: Arc<dyn ComputeBackend>| {
                let mut fd = FdSketch::with_backend(spec.ell, spec.d, backend);
                fd.insert_batch(&buf); // fills 2ℓ rows exactly
                move |fd_refill: &Matrix| {
                    // Each call: refill ℓ rows (buffer ℓ -> 2ℓ), then one
                    // shrink via sketch().
                    fd.insert_batch(fd_refill);
                    std::hint::black_box(fd.sketch());
                }
            };
            let mut s_run = shrink_once(Arc::new(PinnedSerialBackend(pair.dispatch)));
            let serial_ns = best_ns(spec.iters, || s_run(&refill));
            let mut p_run = shrink_once(Arc::new(
                ParallelBackend::with_threads(spec.workers).with_dispatch(pair.dispatch),
            ));
            let parallel_ns = best_ns(spec.iters, || p_run(&refill));
            timings.push(TierTiming {
                serial_ns,
                parallel_ns,
            });
        }
        ops.push(OpResult {
            name: "shrink",
            shape: format!("ell={} D={}", spec.ell, spec.d),
            // Dominated by gram (m²D/2) + apply_rot (ℓ·m·D).
            madds: (m * m) as f64 / 2.0 * spec.d as f64 + (spec.ell * m * spec.d) as f64,
            scalar: timings[0],
            simd: timings.get(1).copied(),
            bits_equal: eq,
        });
    }

    // --- score: consensus matvec over all scored examples ---
    {
        let (scalar, simd, eq) = measure_op(&tiers, spec.iters, |backend| {
            backend.matvec(&zhat, &u)
        });
        ops.push(OpResult {
            name: "score",
            shape: format!("{}x{} matvec", spec.n_examples, spec.ell),
            madds: (spec.n_examples * spec.ell) as f64,
            scalar,
            simd,
            bits_equal: eq,
        });
    }

    KernelBenchReport {
        spec: spec.clone(),
        host_threads: crate::util::threadpool::default_threads(),
        active_tier: kernels::active().tier().name(),
        simd_available: kernels::simd_dispatch().is_some(),
        ops,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tiny_bench_produces_all_ops_and_valid_json() {
        // Tiny shapes: exercises the full bench path in milliseconds.
        let spec = KernelBenchSpec {
            ell: 4,
            d: 64,
            batch: 8,
            n_examples: 64,
            workers: 2,
            iters: 1,
        };
        let report = run_kernel_bench(&spec);
        assert_eq!(report.ops.len(), 4);
        assert!(report.bits_hold());
        for op in &report.ops {
            assert!(op.bits_equal, "{} diverged", op.name);
            assert!(
                op.scalar.serial_ns > 0.0 && op.scalar.parallel_ns > 0.0,
                "{}",
                op.name
            );
            // SIMD rows exist exactly when the host has the tier.
            assert_eq!(op.simd.is_some(), report.simd_available, "{}", op.name);
            if let Some(simd) = op.simd {
                assert!(simd.serial_ns > 0.0 && simd.parallel_ns > 0.0, "{}", op.name);
            }
        }
        for name in ["gram", "project", "shrink", "score"] {
            assert!(report.op(name).is_some(), "missing {name}");
        }
        let text = report.to_json_string();
        let parsed = crate::util::json::parse(&text).expect("valid json");
        assert_eq!(parsed.get("bench").and_then(|j| j.as_str()), Some("kernels"));
        assert_eq!(parsed.get("ops").and_then(|j| j.as_arr()).map(|a| a.len()), Some(4));
        assert!(parsed.get("active_tier").and_then(|j| j.as_str()).is_some());
    }

    #[test]
    fn empty_ops_fails_the_bits_gate() {
        // Satellite: an empty `ops` array must never read as a passing
        // report (the placeholder-bootstrap bug this PR closes).
        let report = KernelBenchReport {
            spec: KernelBenchSpec::default(),
            host_threads: 1,
            active_tier: "scalar",
            simd_available: false,
            ops: Vec::new(),
        };
        assert!(!report.bits_hold());
        assert!(!report.parallel_holds());
    }
}
