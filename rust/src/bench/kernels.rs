//! Kernel-layer benchmark: serial vs threadpool-parallel throughput of the
//! four hot contractions at paper-scale shapes, emitted as the repo-root
//! `BENCH_kernels.json` perf trajectory (subsequent PRs beat these numbers).
//!
//! Ops measured (shapes from the paper's large configuration, ℓ = 256,
//! D = 16384 by default):
//!
//! * `gram`    — FD shrink Gram, `2ℓ × D` buffer → `2ℓ × 2ℓ`
//! * `project` — Phase-II projection `G·Sᵀ`, `B × D` · `(ℓ × D)ᵀ`
//! * `shrink`  — one full FD shrink (Gram + eig + rotation) end to end
//! * `score`   — consensus matvec `α = Ẑ·u` over `N × ℓ`
//!
//! Every parallel result is checked bit-identical against serial before it
//! is timed — a bench that silently measured diverging kernels would be
//! worthless as a perf trajectory.
//!
//! Driven by `sage bench kernels [--quick]`; `--quick` additionally gates
//! (non-zero exit upstream) when a parallel kernel loses to serial.

use crate::sketch::FdSketch;
use crate::tensor::{ComputeBackend, Matrix, ParallelBackend, SerialBackend};
use crate::util::json::Json;
use crate::util::rng::Pcg64;
use std::collections::BTreeMap;
use std::time::Instant;

/// Shapes + measurement knobs for one bench run.
#[derive(Clone, Debug)]
pub struct KernelBenchSpec {
    /// Sketch size ℓ (buffer rows = 2ℓ).
    pub ell: usize,
    /// Gradient dimension D.
    pub d: usize,
    /// Phase-II scoring batch B.
    pub batch: usize,
    /// Scored examples N for the consensus matvec.
    pub n_examples: usize,
    /// Parallel worker threads.
    pub workers: usize,
    /// Timed iterations per op (1 warmup on top).
    pub iters: usize,
}

impl Default for KernelBenchSpec {
    fn default() -> Self {
        Self {
            ell: 256,
            d: 16384,
            batch: 256,
            n_examples: 100_000,
            workers: crate::util::threadpool::default_threads(),
            iters: 5,
        }
    }
}

impl KernelBenchSpec {
    /// CI smoke shapes: same paper-scale dims, fewer iterations.
    pub fn quick(mut self) -> Self {
        self.iters = 3;
        self
    }
}

/// One op's serial vs parallel measurement.
#[derive(Clone, Debug)]
pub struct OpResult {
    pub name: &'static str,
    pub shape: String,
    /// Multiply-adds per iteration (×2 = FLOPs).
    pub madds: f64,
    pub serial_ns: f64,
    pub parallel_ns: f64,
    /// Outputs compared bit-for-bit before timing.
    pub bits_equal: bool,
}

impl OpResult {
    pub fn speedup(&self) -> f64 {
        if self.parallel_ns <= 0.0 {
            0.0
        } else {
            self.serial_ns / self.parallel_ns
        }
    }

    fn gflops(&self, ns: f64) -> f64 {
        if ns <= 0.0 {
            0.0
        } else {
            2.0 * self.madds / ns
        }
    }

    fn to_json(&self) -> Json {
        let mut m = BTreeMap::new();
        m.insert("op".into(), Json::Str(self.name.into()));
        m.insert("shape".into(), Json::Str(self.shape.clone()));
        m.insert("serial_ns".into(), Json::Num(self.serial_ns));
        m.insert("parallel_ns".into(), Json::Num(self.parallel_ns));
        m.insert("speedup".into(), Json::Num(self.speedup()));
        m.insert("serial_gflops".into(), Json::Num(self.gflops(self.serial_ns)));
        m.insert(
            "parallel_gflops".into(),
            Json::Num(self.gflops(self.parallel_ns)),
        );
        m.insert("bits_equal".into(), Json::Bool(self.bits_equal));
        Json::Obj(m)
    }
}

/// Full bench report (serialize with [`KernelBenchReport::to_json_string`]).
pub struct KernelBenchReport {
    pub spec: KernelBenchSpec,
    pub host_threads: usize,
    pub ops: Vec<OpResult>,
}

impl KernelBenchReport {
    /// Result row for `name`, if measured.
    pub fn op(&self, name: &str) -> Option<&OpResult> {
        self.ops.iter().find(|o| o.name == name)
    }

    /// CI quick-gate condition ("parallel must not lose"): the two pure
    /// paper-scale contractions — `gram` and `project` — must be at least
    /// as fast parallel as serial, bit-equal everywhere. (`shrink` embeds a
    /// serial eigendecomposition and `score` is a sub-10 ms matvec; both
    /// are reported but too noise-prone to gate a shared runner on.)
    pub fn parallel_holds(&self) -> bool {
        self.ops.iter().all(|o| o.bits_equal)
            && ["gram", "project"]
                .iter()
                .all(|name| self.op(name).is_some_and(|o| o.speedup() >= 1.0))
    }

    pub fn to_json(&self) -> Json {
        let mut m = BTreeMap::new();
        m.insert("bench".into(), Json::Str("kernels".into()));
        m.insert("ell".into(), Json::Num(self.spec.ell as f64));
        m.insert("d".into(), Json::Num(self.spec.d as f64));
        m.insert("batch".into(), Json::Num(self.spec.batch as f64));
        m.insert("n_examples".into(), Json::Num(self.spec.n_examples as f64));
        m.insert("workers".into(), Json::Num(self.spec.workers as f64));
        m.insert("iters".into(), Json::Num(self.spec.iters as f64));
        m.insert("host_threads".into(), Json::Num(self.host_threads as f64));
        m.insert(
            "ops".into(),
            Json::Arr(self.ops.iter().map(|o| o.to_json()).collect()),
        );
        Json::Obj(m)
    }

    pub fn to_json_string(&self) -> String {
        crate::util::json::write(&self.to_json())
    }
}

/// Best-of-iters timing of `f` (1 unmeasured warmup). Best-of is the right
/// statistic for a regression gate: it is the least noise-sensitive.
fn best_ns(iters: usize, mut f: impl FnMut()) -> f64 {
    f();
    let mut best = f64::INFINITY;
    for _ in 0..iters.max(1) {
        let t0 = Instant::now();
        f();
        let ns = t0.elapsed().as_nanos() as f64;
        if ns < best {
            best = ns;
        }
    }
    best
}

fn bits_equal(a: &[f32], b: &[f32]) -> bool {
    a.len() == b.len() && a.iter().zip(b.iter()).all(|(x, y)| x.to_bits() == y.to_bits())
}

/// Run the kernel bench: serial reference vs a `workers`-thread
/// [`ParallelBackend`], verifying bit-identity per op before timing it.
pub fn run_kernel_bench(spec: &KernelBenchSpec) -> KernelBenchReport {
    let serial = SerialBackend;
    let parallel = ParallelBackend::with_threads(spec.workers);
    let mut rng = Pcg64::seeded(0xBE7C);
    let m = 2 * spec.ell;

    let buf = Matrix::from_fn(m, spec.d, |_, _| rng.normal_f32());
    let grads = Matrix::from_fn(spec.batch, spec.d, |_, _| rng.normal_f32());
    let sketch = Matrix::from_fn(spec.ell, spec.d, |_, _| 0.1 * rng.normal_f32());
    let zhat = Matrix::from_fn(spec.n_examples, spec.ell, |_, _| rng.normal_f32());
    let u: Vec<f32> = (0..spec.ell).map(|_| rng.normal_f32()).collect();

    let mut ops = Vec::new();

    // --- gram: the FD shrink's m×m Gram over the 2ℓ×D buffer ---
    {
        let s_out = serial.gram(&buf);
        let p_out = parallel.gram(&buf);
        let eq = bits_equal(s_out.as_slice(), p_out.as_slice());
        let serial_ns = best_ns(spec.iters, || {
            std::hint::black_box(serial.gram(std::hint::black_box(&buf)));
        });
        let parallel_ns = best_ns(spec.iters, || {
            std::hint::black_box(parallel.gram(std::hint::black_box(&buf)));
        });
        ops.push(OpResult {
            name: "gram",
            shape: format!("{m}x{} -> {m}x{m}", spec.d),
            madds: (m * m) as f64 / 2.0 * spec.d as f64,
            serial_ns,
            parallel_ns,
            bits_equal: eq,
        });
    }

    // --- project: Phase-II G·Sᵀ ---
    {
        let s_out = serial.matmul_transb(&grads, &sketch);
        let p_out = parallel.matmul_transb(&grads, &sketch);
        let eq = bits_equal(s_out.as_slice(), p_out.as_slice());
        let serial_ns = best_ns(spec.iters, || {
            std::hint::black_box(
                serial.matmul_transb(std::hint::black_box(&grads), std::hint::black_box(&sketch)),
            );
        });
        let parallel_ns = best_ns(spec.iters, || {
            std::hint::black_box(
                parallel.matmul_transb(std::hint::black_box(&grads), std::hint::black_box(&sketch)),
            );
        });
        ops.push(OpResult {
            name: "project",
            shape: format!("{}x{} @ ({}x{})T", spec.batch, spec.d, spec.ell, spec.d),
            madds: (spec.batch * spec.ell * spec.d) as f64,
            serial_ns,
            parallel_ns,
            bits_equal: eq,
        });
    }

    // --- shrink: one full FD contraction (gram + eig + apply_rot) ---
    {
        let refill = Matrix::from_fn(spec.ell, spec.d, |_, _| rng.normal_f32());
        let shrink_once = |backend: std::sync::Arc<dyn ComputeBackend>| {
            let mut fd = FdSketch::with_backend(spec.ell, spec.d, backend);
            fd.insert_batch(&buf); // fills 2ℓ rows exactly
            move |fd_refill: &Matrix| {
                // Each call: refill ℓ rows (buffer ℓ -> 2ℓ), then one
                // shrink via sketch().
                fd.insert_batch(fd_refill);
                std::hint::black_box(fd.sketch());
            }
        };
        // Bit-identity: two sketches fed the same stream on each backend.
        let eq = {
            let mut a =
                FdSketch::with_backend(spec.ell, spec.d, std::sync::Arc::new(SerialBackend));
            let mut b = FdSketch::with_backend(
                spec.ell,
                spec.d,
                std::sync::Arc::new(ParallelBackend::with_threads(spec.workers)),
            );
            a.insert_batch(&buf);
            b.insert_batch(&buf);
            bits_equal(a.sketch().as_slice(), b.sketch().as_slice())
        };
        let mut s_run = shrink_once(std::sync::Arc::new(SerialBackend));
        let serial_ns = best_ns(spec.iters, || s_run(&refill));
        let mut p_run = shrink_once(std::sync::Arc::new(ParallelBackend::with_threads(
            spec.workers,
        )));
        let parallel_ns = best_ns(spec.iters, || p_run(&refill));
        ops.push(OpResult {
            name: "shrink",
            shape: format!("ell={} D={}", spec.ell, spec.d),
            // Dominated by gram (m²D/2) + apply_rot (ℓ·m·D).
            madds: (m * m) as f64 / 2.0 * spec.d as f64 + (spec.ell * m * spec.d) as f64,
            serial_ns,
            parallel_ns,
            bits_equal: eq,
        });
    }

    // --- score: consensus matvec over all scored examples ---
    {
        let s_out = serial.matvec(&zhat, &u);
        let p_out = parallel.matvec(&zhat, &u);
        let eq = bits_equal(&s_out, &p_out);
        let serial_ns = best_ns(spec.iters, || {
            std::hint::black_box(serial.matvec(std::hint::black_box(&zhat), &u));
        });
        let parallel_ns = best_ns(spec.iters, || {
            std::hint::black_box(parallel.matvec(std::hint::black_box(&zhat), &u));
        });
        ops.push(OpResult {
            name: "score",
            shape: format!("{}x{} matvec", spec.n_examples, spec.ell),
            madds: (spec.n_examples * spec.ell) as f64,
            serial_ns,
            parallel_ns,
            bits_equal: eq,
        });
    }

    KernelBenchReport {
        spec: spec.clone(),
        host_threads: crate::util::threadpool::default_threads(),
        ops,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tiny_bench_produces_all_ops_and_valid_json() {
        // Tiny shapes: exercises the full bench path in milliseconds.
        let spec = KernelBenchSpec {
            ell: 4,
            d: 64,
            batch: 8,
            n_examples: 64,
            workers: 2,
            iters: 1,
        };
        let report = run_kernel_bench(&spec);
        assert_eq!(report.ops.len(), 4);
        for op in &report.ops {
            assert!(op.bits_equal, "{} diverged", op.name);
            assert!(op.serial_ns > 0.0 && op.parallel_ns > 0.0, "{}", op.name);
        }
        for name in ["gram", "project", "shrink", "score"] {
            assert!(report.op(name).is_some(), "missing {name}");
        }
        let text = report.to_json_string();
        let parsed = crate::util::json::parse(&text).expect("valid json");
        assert_eq!(parsed.get("bench").and_then(|j| j.as_str()), Some("kernels"));
        assert_eq!(parsed.get("ops").and_then(|j| j.as_arr()).map(|a| a.len()), Some(4));
    }
}
