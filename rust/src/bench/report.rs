//! Report writers: markdown tables (Table-1 style) and CSV series
//! (Figure-1 style) under `reports/`.

use std::io::Write;
use std::path::Path;

/// Write a markdown table: `headers` then rows of cells.
pub fn write_markdown_table(
    path: &Path,
    title: &str,
    headers: &[String],
    rows: &[Vec<String>],
) -> std::io::Result<()> {
    if let Some(parent) = path.parent() {
        std::fs::create_dir_all(parent)?;
    }
    let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
    writeln!(f, "# {title}\n")?;
    writeln!(f, "| {} |", headers.join(" | "))?;
    writeln!(
        f,
        "|{}|",
        headers.iter().map(|_| "---").collect::<Vec<_>>().join("|")
    )?;
    for row in rows {
        writeln!(f, "| {} |", row.join(" | "))?;
    }
    f.flush()
}

/// Write a CSV file with a header row.
pub fn write_csv(path: &Path, headers: &[String], rows: &[Vec<String>]) -> std::io::Result<()> {
    if let Some(parent) = path.parent() {
        std::fs::create_dir_all(parent)?;
    }
    let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
    writeln!(f, "{}", headers.join(","))?;
    for row in rows {
        writeln!(f, "{}", row.join(","))?;
    }
    f.flush()
}

/// Render a crude ASCII scatter of (x, y) series for terminal reports —
/// the Figure-1 "accuracy vs speed-up" panel without a plotting stack.
pub fn ascii_plot(series: &[(&str, Vec<(f64, f64)>)], width: usize, height: usize) -> String {
    let all: Vec<(f64, f64)> = series.iter().flat_map(|(_, pts)| pts.clone()).collect();
    if all.is_empty() {
        return String::from("(no data)\n");
    }
    let (mut xmin, mut xmax, mut ymin, mut ymax) = (f64::MAX, f64::MIN, f64::MAX, f64::MIN);
    for &(x, y) in &all {
        xmin = xmin.min(x);
        xmax = xmax.max(x);
        ymin = ymin.min(y);
        ymax = ymax.max(y);
    }
    if (xmax - xmin).abs() < 1e-12 {
        xmax = xmin + 1.0;
    }
    if (ymax - ymin).abs() < 1e-12 {
        ymax = ymin + 1.0;
    }
    let mut grid = vec![vec![' '; width]; height];
    let marks = ['S', 'r', 'd', 'g', 'c', 'm', 'f', 'w', 'x', 'o'];
    for (si, (_name, pts)) in series.iter().enumerate() {
        for &(x, y) in pts {
            let col = (((x - xmin) / (xmax - xmin)) * (width - 1) as f64).round() as usize;
            let row = (((y - ymin) / (ymax - ymin)) * (height - 1) as f64).round() as usize;
            grid[height - 1 - row][col] = marks[si % marks.len()];
        }
    }
    let mut out = String::new();
    for row in grid {
        out.push('|');
        out.extend(row);
        out.push('\n');
    }
    out.push('+');
    out.push_str(&"-".repeat(width));
    out.push('\n');
    out.push_str(&format!(
        "x: {:.2}..{:.2}  y: {:.3}..{:.3}  legend: {}\n",
        xmin,
        xmax,
        ymin,
        ymax,
        series
            .iter()
            .enumerate()
            .map(|(i, (n, _))| format!("{}={}", marks[i % marks.len()], n))
            .collect::<Vec<_>>()
            .join(" ")
    ));
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn markdown_table_round_trip() {
        let dir = std::env::temp_dir().join(format!("sage_report_{}", std::process::id()));
        let path = dir.join("t.md");
        write_markdown_table(
            &path,
            "Table 1",
            &["Method".into(), "5%".into()],
            &[vec!["SAGE".into(), "59.2".into()]],
        )
        .unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.contains("# Table 1"));
        assert!(text.contains("| SAGE | 59.2 |"));
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn csv_written() {
        let dir = std::env::temp_dir().join(format!("sage_csv_{}", std::process::id()));
        let path = dir.join("f.csv");
        write_csv(
            &path,
            &["a".into(), "b".into()],
            &[vec!["1".into(), "2".into()]],
        )
        .unwrap();
        assert_eq!(std::fs::read_to_string(&path).unwrap(), "a,b\n1,2\n");
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn ascii_plot_has_marks() {
        let s = ascii_plot(
            &[("SAGE", vec![(1.0, 0.5), (2.0, 0.9)]), ("Random", vec![(1.5, 0.3)])],
            40,
            10,
        );
        assert!(s.contains('S'));
        assert!(s.contains('r'));
        assert!(s.contains("legend"));
    }
}
