//! Micro-bench timing harness (criterion is unavailable offline): warmup +
//! repeated timed runs with mean / p50 / min / max over iterations.

use std::time::Instant;

/// Timing summary in nanoseconds.
#[derive(Clone, Copy, Debug)]
pub struct Timing {
    pub iters: usize,
    pub mean_ns: f64,
    pub median_ns: f64,
    pub min_ns: f64,
    pub max_ns: f64,
}

impl Timing {
    pub fn per_sec(&self) -> f64 {
        if self.mean_ns <= 0.0 {
            0.0
        } else {
            1e9 / self.mean_ns
        }
    }
}

impl std::fmt::Display for Timing {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        fn fmt_ns(ns: f64) -> String {
            if ns >= 1e9 {
                format!("{:.2}s", ns / 1e9)
            } else if ns >= 1e6 {
                format!("{:.2}ms", ns / 1e6)
            } else if ns >= 1e3 {
                format!("{:.2}us", ns / 1e3)
            } else {
                format!("{ns:.0}ns")
            }
        }
        write!(
            f,
            "mean {} | p50 {} | min {} | max {} ({} iters)",
            fmt_ns(self.mean_ns),
            fmt_ns(self.median_ns),
            fmt_ns(self.min_ns),
            fmt_ns(self.max_ns),
            self.iters
        )
    }
}

/// Run `f` `iters` times after `warmup` unmeasured runs.
pub fn time_fn(warmup: usize, iters: usize, mut f: impl FnMut()) -> Timing {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_nanos() as f64);
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let mean = samples.iter().sum::<f64>() / samples.len() as f64;
    Timing {
        iters,
        mean_ns: mean,
        median_ns: samples[samples.len() / 2],
        min_ns: samples[0],
        max_ns: *samples.last().unwrap(),
    }
}

/// Convenience: print a labelled timing row.
pub fn report(label: &str, t: &Timing) {
    println!("{label:<44} {t}");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timing_counts_iters() {
        let mut n = 0;
        let t = time_fn(2, 10, || n += 1);
        assert_eq!(n, 12);
        assert_eq!(t.iters, 10);
        assert!(t.min_ns <= t.median_ns && t.median_ns <= t.max_ns);
        assert!(t.per_sec() > 0.0);
    }

    #[test]
    fn display_formats_units() {
        let t = Timing {
            iters: 1,
            mean_ns: 2.5e6,
            median_ns: 2.5e6,
            min_ns: 1e3,
            max_ns: 3e9,
        };
        let s = format!("{t}");
        assert!(s.contains("ms") && s.contains("us") && s.contains('s'));
    }
}
