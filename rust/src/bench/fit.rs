//! Generalized exponential response fit — Figure 1's "empirical response
//! curves are modeled using a generalized exponential fit, and all results
//! include R² fit quality".
//!
//! Model: `y(x) = a − b·exp(−c·x)` (saturating accuracy vs subset fraction).
//! For fixed `c` the model is linear in `(a, b)`, so we grid-search `c` and
//! solve the 2×2 normal equations exactly — robust for the 4-point curves
//! the paper fits, no iterative optimizer needed.

/// Fitted parameters + quality.
#[derive(Clone, Copy, Debug)]
pub struct ExpFit {
    pub a: f64,
    pub b: f64,
    pub c: f64,
    pub r2: f64,
}

impl ExpFit {
    pub fn predict(&self, x: f64) -> f64 {
        self.a - self.b * (-self.c * x).exp()
    }
}

/// Coefficient of determination of predictions vs observations.
pub fn r_squared(ys: &[f64], preds: &[f64]) -> f64 {
    assert_eq!(ys.len(), preds.len());
    let n = ys.len();
    if n == 0 {
        return 0.0;
    }
    let mean = ys.iter().sum::<f64>() / n as f64;
    let ss_tot: f64 = ys.iter().map(|y| (y - mean).powi(2)).sum();
    let ss_res: f64 = ys.iter().zip(preds).map(|(y, p)| (y - p).powi(2)).sum();
    if ss_tot <= 1e-18 {
        return if ss_res <= 1e-18 { 1.0 } else { 0.0 };
    }
    1.0 - ss_res / ss_tot
}

/// Fit `y = a − b·exp(−c·x)` over (xs, ys). Grid-searches c ∈ [0.01, 100]
/// (log-spaced) and returns the best-R² fit.
pub fn exp_fit(xs: &[f64], ys: &[f64]) -> ExpFit {
    assert_eq!(xs.len(), ys.len());
    assert!(xs.len() >= 2, "need at least 2 points");
    let mut best = ExpFit {
        a: ys.iter().sum::<f64>() / ys.len() as f64,
        b: 0.0,
        c: 0.0,
        r2: f64::NEG_INFINITY,
    };
    let steps = 200;
    for i in 0..=steps {
        // log grid 0.01 .. 100
        let c = 10f64.powf(-2.0 + 4.0 * i as f64 / steps as f64);
        // Linear LS for (a, b) with basis [1, -exp(-c x)].
        let n = xs.len() as f64;
        let mut s_e = 0.0; // Σ e_i,  e_i = -exp(-c x_i)
        let mut s_ee = 0.0;
        let mut s_y = 0.0;
        let mut s_ye = 0.0;
        for (&x, &y) in xs.iter().zip(ys) {
            let e = -(-c * x).exp();
            s_e += e;
            s_ee += e * e;
            s_y += y;
            s_ye += y * e;
        }
        // Normal equations: [n, s_e; s_e, s_ee] [a; b] = [s_y; s_ye]
        let det = n * s_ee - s_e * s_e;
        if det.abs() < 1e-12 {
            continue;
        }
        let a = (s_y * s_ee - s_e * s_ye) / det;
        let b = (n * s_ye - s_e * s_y) / det;
        let preds: Vec<f64> = xs.iter().map(|&x| a - b * (-c * x).exp()).collect();
        let r2 = r_squared(ys, &preds);
        if r2 > best.r2 {
            best = ExpFit { a, b, c, r2 };
        }
    }
    if best.r2 == f64::NEG_INFINITY {
        best.r2 = 0.0;
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::check::forall;

    #[test]
    fn recovers_known_curve() {
        forall("exp_fit_recover", 10, |rng| {
            let a = 0.5 + rng.next_f64();
            let b = 0.1 + rng.next_f64();
            let c = 0.5 + 8.0 * rng.next_f64();
            let xs = [0.05, 0.15, 0.25, 0.5, 1.0];
            let ys: Vec<f64> = xs.iter().map(|&x| a - b * (-c * x).exp()).collect();
            let fit = exp_fit(&xs, &ys);
            assert!(fit.r2 > 0.999, "r2 {}", fit.r2);
            for (&x, &y) in xs.iter().zip(&ys) {
                assert!((fit.predict(x) - y).abs() < 5e-3, "{x}");
            }
        });
    }

    #[test]
    fn noisy_curve_reasonable_r2() {
        forall("exp_fit_noise", 10, |rng| {
            let xs = [0.05, 0.15, 0.25, 1.0];
            let ys: Vec<f64> = xs
                .iter()
                .map(|&x: &f64| 0.9 - 0.5 * (-6.0 * x).exp() + 0.01 * rng.normal())
                .collect();
            let fit = exp_fit(&xs, &ys);
            assert!(fit.r2 > 0.8, "r2 {}", fit.r2);
        });
    }

    #[test]
    fn r_squared_bounds() {
        let ys = [1.0, 2.0, 3.0];
        assert!((r_squared(&ys, &ys) - 1.0).abs() < 1e-12);
        let bad = [3.0, 1.0, 2.0];
        assert!(r_squared(&ys, &bad) < 1.0);
        // Constant target, perfect prediction.
        assert_eq!(r_squared(&[2.0, 2.0], &[2.0, 2.0]), 1.0);
    }

    #[test]
    fn monotone_saturating_prediction() {
        let xs = [0.05, 0.15, 0.25, 1.0];
        let ys = [0.4, 0.7, 0.8, 0.9];
        let fit = exp_fit(&xs, &ys);
        assert!(fit.r2 > 0.9);
        assert!(fit.predict(0.05) < fit.predict(0.25));
        assert!(fit.predict(1.0) <= fit.a + 1e-9);
    }
}
