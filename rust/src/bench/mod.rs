//! Benchmark harness library: experiment runner (one (dataset, method,
//! fraction, seed) cell of the paper's evaluation), the generalized
//! exponential fit + R² used by Figure 1, small-sample statistics, the
//! kernel-layer serial-vs-parallel bench behind `sage bench kernels`
//! (emits `BENCH_kernels.json`), the service I/O-engine bench behind
//! `sage bench serve` (emits `BENCH_serve.json`), and markdown/CSV
//! report writers. The `cargo bench` targets in `rust/benches/` are thin
//! drivers over this module.

pub mod fit;
pub mod kernels;
pub mod report;
pub mod runner;
pub mod serve;
pub mod timing;

pub use fit::{exp_fit, r_squared, ExpFit};
pub use kernels::{run_kernel_bench, KernelBenchReport, KernelBenchSpec};
pub use serve::{run_serve_bench, ServeBenchReport, ServeBenchSpec};
pub use report::{write_csv, write_markdown_table};
pub use runner::{run_cell, CellResult, CellSpec};
pub use timing::{time_fn, Timing};

/// Mean of a slice.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Sample standard deviation.
pub fn std_dev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m).powi(2)).sum::<f64>() / (xs.len() - 1) as f64).sqrt()
}

/// 95% confidence half-width with small-sample t quantiles (the paper
/// reports mean ± 95% CI over 3 seeds).
pub fn ci95(xs: &[f64]) -> f64 {
    let n = xs.len();
    if n < 2 {
        return 0.0;
    }
    // t_{0.975, n-1} for tiny n; 1.96 beyond the table.
    let t = match n - 1 {
        1 => 12.706,
        2 => 4.303,
        3 => 3.182,
        4 => 2.776,
        5 => 2.571,
        6 => 2.447,
        7 => 2.365,
        8 => 2.306,
        9 => 2.262,
        _ => 1.96,
    };
    t * std_dev(xs) / (n as f64).sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_std_ci() {
        let xs = [1.0, 2.0, 3.0];
        assert!((mean(&xs) - 2.0).abs() < 1e-12);
        assert!((std_dev(&xs) - 1.0).abs() < 1e-12);
        // t=4.303, sd=1, n=3 -> 4.303/sqrt(3).
        assert!((ci95(&xs) - 4.303 / 3f64.sqrt()).abs() < 1e-9);
        assert_eq!(ci95(&[5.0]), 0.0);
    }
}
