//! Agreement scoring & subset selection — Algorithm 1, Phase II.
//!
//! Given the frozen FD sketch `S`, every example's gradient is projected to
//! `z_i = S g_i ∈ R^ℓ`, normalized (`ẑ_i`), and scored by cosine agreement
//! with the consensus direction `u = z̄/‖z̄‖`:
//!
//! ```text
//! α_i = ⟨ẑ_i, u⟩ ∈ [-1, 1]
//! ```
//!
//! [`AgreementScorer`] accumulates the consensus in a streaming fashion
//! (ℓ-dim state), while caching projected rows so scoring is a single pass;
//! [`select_top_k`] / [`select_class_balanced`] implement plain SAGE and
//! CB-SAGE (per-class centroids `u_c`, per-class budgets `k_c`).
//!
//! The module verifies Lemma 1 (consensus-direction energy) and the
//! mean-alignment corollary as property tests.

mod scorer;
pub mod streaming;
mod topk;

pub use scorer::{
    scorer_state_bytes, scores_state_bytes, AgreementScorer, ProjectionScratch, ScoreEntry,
    ScorerState, Scores, ScoresState, ENTRY_BYTES,
};
pub use streaming::{streaming_select, ConsensusAccumulator, StreamingSelector};
pub use topk::{top_k_indices, TopK};

use crate::tensor::Matrix;

/// Select indices of the k highest-agreement examples (Algorithm 1 line 20).
pub fn select_top_k(scores: &Scores, k: usize) -> Vec<usize> {
    let mut tk = TopK::new(k);
    for e in &scores.entries {
        tk.push(e.alpha, e.index);
    }
    tk.into_sorted_indices()
}

/// CB-SAGE (Algorithm 1 lines 16-18): per-class unit centroids `u_c`,
/// select top-`k_c` per class by `⟨ẑ_i, u_c⟩`, with `Σ_c k_c = k` allocated
/// proportionally to class frequency (each nonempty class gets ≥ 1).
pub fn select_class_balanced(scores: &Scores, num_classes: usize, k: usize) -> Vec<usize> {
    let budgets = class_budgets(scores, num_classes, k);
    let ell = scores.ell;

    // Per-class centroids from the cached normalized projections.
    let mut centroid = vec![vec![0.0f64; ell]; num_classes];
    let mut count = vec![0usize; num_classes];
    for (row, e) in scores.entries.iter().enumerate() {
        let z = scores.zhat.row(row);
        let c = e.label as usize;
        count[c] += 1;
        for (j, &v) in z.iter().enumerate() {
            centroid[c][j] += v as f64;
        }
    }
    let mut unit: Vec<Option<Vec<f32>>> = Vec::with_capacity(num_classes);
    for c in 0..num_classes {
        if count[c] == 0 {
            unit.push(None);
            continue;
        }
        let mut u: Vec<f32> = centroid[c].iter().map(|&v| (v / count[c] as f64) as f32).collect();
        let n = crate::tensor::normalize_in_place(&mut u);
        unit.push(if n > 0.0 { Some(u) } else { None });
    }

    // Per-class top-k_c by ⟨ẑ_i, u_c⟩ (falls back to global α when the
    // class centroid is degenerate/zero).
    let mut heaps: Vec<TopK> = budgets.iter().map(|&b| TopK::new(b)).collect();
    for (row, e) in scores.entries.iter().enumerate() {
        let c = e.label as usize;
        if budgets[c] == 0 {
            continue;
        }
        let score = match &unit[c] {
            Some(u) => crate::tensor::dot(scores.zhat.row(row), u),
            None => e.alpha,
        };
        heaps[c].push(score, e.index);
    }
    let mut out: Vec<usize> = heaps
        .into_iter()
        .flat_map(|h| h.into_sorted_indices())
        .collect();
    out.sort_unstable();
    out
}

/// Proportional per-class budgets: `k_c ∝ n_c`, every nonempty class gets at
/// least one slot, total exactly `min(k, N)`.
pub fn class_budgets(scores: &Scores, num_classes: usize, k: usize) -> Vec<usize> {
    let mut counts = vec![0usize; num_classes];
    for e in &scores.entries {
        counts[e.label as usize] += 1;
    }
    let n: usize = counts.iter().sum();
    let k = k.min(n);
    let mut budgets = vec![0usize; num_classes];
    if k == 0 {
        return budgets;
    }
    // Largest-remainder apportionment with a floor of 1 for nonempty classes.
    let nonempty = counts.iter().filter(|&&c| c > 0).count();
    let base_total = k.max(nonempty.min(k));
    let mut rema: Vec<(f64, usize)> = Vec::new();
    let mut assigned = 0usize;
    for c in 0..num_classes {
        if counts[c] == 0 {
            continue;
        }
        let ideal = base_total as f64 * counts[c] as f64 / n as f64;
        let mut floor = ideal.floor() as usize;
        if floor == 0 {
            floor = 1;
        }
        let floor = floor.min(counts[c]);
        budgets[c] = floor;
        assigned += floor;
        rema.push((ideal - ideal.floor(), c));
    }
    // Fix up to exactly k: add by largest remainder, remove from largest
    // budgets (above 1) if we overshot.
    rema.sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap());
    let mut i = 0;
    while assigned < k {
        let c = rema[i % rema.len()].1;
        if budgets[c] < counts[c] {
            budgets[c] += 1;
            assigned += 1;
        }
        i += 1;
        if i > 4 * (rema.len() + k) {
            break; // all classes saturated
        }
    }
    while assigned > k {
        // Remove from the class with the largest budget > 1 (or > 0 if must).
        let (c, _) = budgets
            .iter()
            .enumerate()
            .max_by_key(|(_, &b)| b)
            .unwrap();
        if budgets[c] == 0 {
            break;
        }
        budgets[c] -= 1;
        assigned -= 1;
    }
    budgets
}

/// Lemma-1 check helper: given raw (un-normalized) projections `z` for a
/// subset with scores `alpha ≥ ξ`, verify
/// `‖mean z‖ ≥ ξ · mean ‖z‖` (mean-alignment corollary).
pub fn mean_alignment_holds(z: &Matrix, alphas: &[f32], xi: f32) -> bool {
    let k = z.rows();
    if k == 0 {
        return true;
    }
    assert!(alphas.iter().all(|&a| a >= xi));
    let mut mean = vec![0.0f64; z.cols()];
    let mut norm_sum = 0.0f64;
    for i in 0..k {
        let row = z.row(i);
        for (j, &v) in row.iter().enumerate() {
            mean[j] += v as f64;
        }
        norm_sum += crate::tensor::norm2(row);
    }
    let mean_norm = (mean.iter().map(|v| v * v).sum::<f64>()).sqrt() / k as f64;
    mean_norm + 1e-9 >= xi as f64 * norm_sum / k as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::check::forall;
    use crate::util::rng::Pcg64;

    /// Build Scores from synthetic ẑ clustered around a direction.
    fn synthetic_scores(rng: &mut Pcg64, n: usize, ell: usize, classes: u32) -> Scores {
        let mut scorer = AgreementScorer::new(ell);
        let mut dir = vec![0.0f32; ell];
        rng.fill_normal(&mut dir, 1.0);
        crate::tensor::normalize_in_place(&mut dir);
        let mut z = Matrix::zeros(n, ell);
        let mut norms = vec![0.0f32; n];
        let mut idx = Vec::new();
        let mut labels = Vec::new();
        for i in 0..n {
            let spread = 0.3 + rng.next_f32();
            let row = z.row_mut(i);
            for (j, &d) in dir.iter().enumerate() {
                row[j] = d + spread * rng.normal_f32();
            }
            norms[i] = crate::tensor::normalize_in_place(row) as f32;
            idx.push(i);
            labels.push(rng.below(classes as u64) as u32);
        }
        scorer.add_batch(&idx, &labels, &z, &norms, &vec![1.0; n]);
        scorer.finalize()
    }

    #[test]
    fn top_k_returns_best_alphas() {
        forall("sel_topk", 10, |rng| {
            let scores = synthetic_scores(rng, 100, 8, 4);
            let k = 1 + rng.below(50) as usize;
            let sel = select_top_k(&scores, k);
            assert_eq!(sel.len(), k);
            let mut alphas: Vec<f32> = scores.entries.iter().map(|e| e.alpha).collect();
            alphas.sort_by(|a, b| b.partial_cmp(a).unwrap());
            let threshold = alphas[k - 1];
            for &i in &sel {
                let e = scores.entries.iter().find(|e| e.index == i).unwrap();
                assert!(e.alpha >= threshold - 1e-6);
            }
        });
    }

    #[test]
    fn class_balanced_budgets_sum_to_k() {
        forall("sel_budgets", 10, |rng| {
            let classes = 2 + rng.below(6) as u32;
            let scores = synthetic_scores(rng, 150, 8, classes);
            let k = 1 + rng.below(120) as usize;
            let budgets = class_budgets(&scores, classes as usize, k);
            assert_eq!(budgets.iter().sum::<usize>(), k.min(150));
            // No budget exceeds class count.
            let mut counts = vec![0usize; classes as usize];
            for e in &scores.entries {
                counts[e.label as usize] += 1;
            }
            for (c, &b) in budgets.iter().enumerate() {
                assert!(b <= counts[c], "class {c}: {b} > {}", counts[c]);
            }
        });
    }

    #[test]
    fn class_balanced_selection_covers_classes() {
        forall("sel_cb_cover", 8, |rng| {
            let classes = 4u32;
            let scores = synthetic_scores(rng, 200, 8, classes);
            let sel = select_class_balanced(&scores, 4, 40);
            assert_eq!(sel.len(), 40);
            let mut hit = vec![false; 4];
            for &i in &sel {
                let e = scores.entries.iter().find(|e| e.index == i).unwrap();
                hit[e.label as usize] = true;
            }
            assert!(hit.iter().all(|&h| h), "all classes covered");
        });
    }

    #[test]
    fn lemma1_mean_alignment_on_selected_subsets() {
        forall("lemma1", 10, |rng| {
            let ell = 6;
            let n = 80;
            // Raw z_i (not normalized): cluster + magnitudes.
            let mut dir = vec![0.0f32; ell];
            rng.fill_normal(&mut dir, 1.0);
            crate::tensor::normalize_in_place(&mut dir);
            let mut z = Matrix::zeros(n, ell);
            for i in 0..n {
                let mag = 0.5 + 2.0 * rng.next_f32();
                let spread = 0.4;
                let row = z.row_mut(i);
                for (j, &d) in dir.iter().enumerate() {
                    row[j] = mag * (d + spread * rng.normal_f32());
                }
            }
            // Consensus from normalized copies.
            let mut u = vec![0.0f64; ell];
            for i in 0..n {
                let mut r = z.row(i).to_vec();
                crate::tensor::normalize_in_place(&mut r);
                for (j, &v) in r.iter().enumerate() {
                    u[j] += v as f64;
                }
            }
            let mut uf: Vec<f32> = u.iter().map(|&v| v as f32).collect();
            crate::tensor::normalize_in_place(&mut uf);
            // Alphas.
            let alphas: Vec<f32> = (0..n)
                .map(|i| {
                    let mut r = z.row(i).to_vec();
                    crate::tensor::normalize_in_place(&mut r);
                    crate::tensor::dot(&r, &uf)
                })
                .collect();
            let xi = 0.5f32;
            let keep: Vec<usize> = (0..n).filter(|&i| alphas[i] >= xi).collect();
            if keep.is_empty() {
                return;
            }
            let zsub = {
                let mut m = Matrix::zeros(keep.len(), ell);
                for (r, &i) in keep.iter().enumerate() {
                    m.row_mut(r).copy_from_slice(z.row(i));
                }
                m
            };
            let asub: Vec<f32> = keep.iter().map(|&i| alphas[i]).collect();
            assert!(mean_alignment_holds(&zsub, &asub, xi));
        });
    }

    #[test]
    fn degenerate_all_same_direction() {
        // All ẑ identical -> α_i = 1 for all; top-k arbitrary but valid.
        let ell = 4;
        let mut scorer = AgreementScorer::new(ell);
        let mut z = Matrix::zeros(10, ell);
        for i in 0..10 {
            z.set(i, 0, 1.0);
        }
        let idx: Vec<usize> = (0..10).collect();
        let labels = vec![0u32; 10];
        let norms = vec![1.0f32; 10];
        scorer.add_batch(&idx, &labels, &z, &norms, &vec![1.0; 10]);
        let scores = scorer.finalize();
        for e in &scores.entries {
            assert!((e.alpha - 1.0).abs() < 1e-6);
        }
        assert_eq!(select_top_k(&scores, 3).len(), 3);
    }

    #[test]
    fn zero_projections_score_zero() {
        let ell = 4;
        let mut scorer = AgreementScorer::new(ell);
        let mut z = Matrix::zeros(3, ell);
        z.set(0, 0, 1.0); // one real row, two zero rows
        scorer.add_batch(&[0, 1, 2], &[0, 0, 0], &z, &[1.0, 0.0, 0.0], &[1.0, 1.0, 1.0]);
        let scores = scorer.finalize();
        assert!((scores.entries[1].alpha).abs() < 1e-6);
        assert!((scores.entries[2].alpha).abs() < 1e-6);
    }
}
