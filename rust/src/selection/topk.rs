//! Streaming top-k selection — `O(N log k)` time, `O(k)` memory (the
//! `N log k` term in the paper's complexity claim).

use std::cmp::Ordering;
use std::collections::BinaryHeap;

/// (score, index) with min-at-top ordering so the heap root is the current
/// k-th best; ties break on the smaller index (determinism).
#[derive(Clone, Copy, Debug)]
struct HeapItem {
    score: f32,
    index: usize,
}

impl PartialEq for HeapItem {
    fn eq(&self, other: &Self) -> bool {
        self.cmp(other) == Ordering::Equal
    }
}
impl Eq for HeapItem {}

impl Ord for HeapItem {
    fn cmp(&self, other: &Self) -> Ordering {
        // Reverse on score -> min-heap; then reverse on index so the larger
        // index is evicted first among ties.
        other
            .score
            .partial_cmp(&self.score)
            .unwrap_or(Ordering::Equal)
            .then_with(|| self.index.cmp(&other.index))
    }
}
impl PartialOrd for HeapItem {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

/// Bounded max-score tracker.
pub struct TopK {
    k: usize,
    heap: BinaryHeap<HeapItem>,
}

impl TopK {
    pub fn new(k: usize) -> Self {
        Self {
            k,
            heap: BinaryHeap::with_capacity(k + 1),
        }
    }

    /// Offer one (score, index); keeps only the k best.
    pub fn push(&mut self, score: f32, index: usize) {
        if self.k == 0 {
            return;
        }
        debug_assert!(!score.is_nan(), "NaN score for index {index}");
        if self.heap.len() < self.k {
            self.heap.push(HeapItem { score, index });
        } else if let Some(&root) = self.heap.peek() {
            if score > root.score || (score == root.score && index < root.index) {
                self.heap.pop();
                self.heap.push(HeapItem { score, index });
            }
        }
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    /// Indices sorted by descending score (ties: ascending index).
    pub fn into_sorted_indices(self) -> Vec<usize> {
        let mut items: Vec<HeapItem> = self.heap.into_vec();
        items.sort_by(|a, b| {
            b.score
                .partial_cmp(&a.score)
                .unwrap_or(Ordering::Equal)
                .then_with(|| a.index.cmp(&b.index))
        });
        items.into_iter().map(|it| it.index).collect()
    }
}

/// Convenience: top-k indices of a score slice.
pub fn top_k_indices(scores: &[f32], k: usize) -> Vec<usize> {
    let mut tk = TopK::new(k);
    for (i, &s) in scores.iter().enumerate() {
        tk.push(s, i);
    }
    tk.into_sorted_indices()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::check::forall;

    #[test]
    fn matches_full_sort() {
        forall("topk_sort", 20, |rng| {
            let n = 1 + rng.below(300) as usize;
            let k = 1 + rng.below(n as u64) as usize;
            let scores: Vec<f32> = (0..n).map(|_| rng.normal_f32()).collect();
            let got = top_k_indices(&scores, k);
            let mut want: Vec<usize> = (0..n).collect();
            want.sort_by(|&a, &b| {
                scores[b]
                    .partial_cmp(&scores[a])
                    .unwrap()
                    .then(a.cmp(&b))
            });
            want.truncate(k);
            assert_eq!(got, want);
        });
    }

    #[test]
    fn k_larger_than_n() {
        let got = top_k_indices(&[1.0, 3.0, 2.0], 10);
        assert_eq!(got, vec![1, 2, 0]);
    }

    #[test]
    fn k_zero() {
        assert!(top_k_indices(&[1.0, 2.0], 0).is_empty());
    }

    #[test]
    fn deterministic_tie_break_prefers_small_index() {
        let got = top_k_indices(&[5.0, 5.0, 5.0, 5.0], 2);
        assert_eq!(got, vec![0, 1]);
    }

    #[test]
    fn streaming_matches_batch() {
        forall("topk_stream", 10, |rng| {
            let scores: Vec<f32> = (0..200).map(|_| rng.normal_f32()).collect();
            let mut tk = TopK::new(17);
            for (i, &s) in scores.iter().enumerate() {
                tk.push(s, i);
            }
            assert_eq!(tk.into_sorted_indices(), top_k_indices(&scores, 17));
        });
    }
}
