//! Streaming consensus accumulation + agreement scoring.

use crate::tensor::{self, Matrix};

/// Metadata for one scored example.
#[derive(Clone, Copy, Debug)]
pub struct ScoreEntry {
    /// Global dataset index.
    pub index: usize,
    pub label: u32,
    /// ‖S g_i‖₂ (projection magnitude before normalization).
    pub norm: f32,
    /// Per-example training loss at the scoring parameters (DROP's proxy).
    pub loss: f32,
    /// Agreement score α_i = ⟨ẑ_i, u⟩.
    pub alpha: f32,
}

/// Finalized Phase-II output.
pub struct Scores {
    pub ell: usize,
    /// Unit consensus u (zero vector if z̄ = 0).
    pub consensus: Vec<f32>,
    pub entries: Vec<ScoreEntry>,
    /// Cached normalized projections, row r ↔ entries[r].
    pub zhat: Matrix,
}

/// Accumulates normalized projections ẑ_i and the running mean z̄ in a
/// streaming pass (Algorithm 1 lines 13-15). The consensus state is ℓ-dim;
/// ẑ rows are cached so the subsequent scoring pass needs no recompute
/// (`O(Nℓ)` cache — see the `streaming` ablation bench for the two-pass
/// `O(ℓ)` variant).
pub struct AgreementScorer {
    ell: usize,
    /// Σ ẑ_i in f64 (drift across N ~ 1e5 terms must not perturb ranks).
    consensus_acc: Vec<f64>,
    count: u64,
    entries: Vec<ScoreEntry>,
    rows: Vec<f32>,
}

impl AgreementScorer {
    pub fn new(ell: usize) -> Self {
        assert!(ell > 0);
        Self {
            ell,
            consensus_acc: vec![0.0; ell],
            count: 0,
            entries: Vec::new(),
            rows: Vec::new(),
        }
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    /// Add a batch of *already normalized* projections (`zhat [b × ℓ]`,
    /// zero rows for zero projections) with their pre-normalization norms.
    pub fn add_batch(
        &mut self,
        indices: &[usize],
        labels: &[u32],
        zhat: &Matrix,
        norms: &[f32],
        losses: &[f32],
    ) {
        assert_eq!(zhat.rows(), indices.len());
        assert_eq!(indices.len(), labels.len());
        assert_eq!(indices.len(), norms.len());
        assert_eq!(indices.len(), losses.len());
        assert_eq!(zhat.cols(), self.ell, "projection dim");
        for r in 0..zhat.rows() {
            let row = zhat.row(r);
            for (j, &v) in row.iter().enumerate() {
                self.consensus_acc[j] += v as f64;
            }
            self.count += 1;
            self.entries.push(ScoreEntry {
                index: indices[r],
                label: labels[r],
                norm: norms[r],
                loss: losses[r],
                alpha: 0.0, // filled by finalize
            });
            self.rows.extend_from_slice(row);
        }
    }

    /// Merge another scorer's partial state (pipeline shard aggregation).
    pub fn merge(&mut self, other: AgreementScorer) {
        assert_eq!(self.ell, other.ell);
        for (a, b) in self.consensus_acc.iter_mut().zip(&other.consensus_acc) {
            *a += b;
        }
        self.count += other.count;
        self.entries.extend(other.entries);
        self.rows.extend(other.rows);
    }

    /// Compute u and all α_i (Algorithm 1 lines 14-15).
    pub fn finalize(mut self) -> Scores {
        let n = self.count.max(1) as f64;
        let mut u: Vec<f32> = self.consensus_acc.iter().map(|&v| (v / n) as f32).collect();
        let norm = tensor::normalize_in_place(&mut u);
        let consensus = if norm > 0.0 { u } else { vec![0.0; self.ell] };

        let zhat = Matrix::from_vec(self.entries.len(), self.ell, std::mem::take(&mut self.rows));
        for (r, e) in self.entries.iter_mut().enumerate() {
            e.alpha = tensor::dot(zhat.row(r), &consensus);
        }
        Scores {
            ell: self.ell,
            consensus,
            entries: self.entries,
            zhat,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn unit(v: &mut [f32]) {
        tensor::normalize_in_place(v);
    }

    #[test]
    fn consensus_is_mean_direction() {
        let mut scorer = AgreementScorer::new(2);
        // Two points symmetric about the x-axis -> consensus = x-axis.
        let mut z = Matrix::zeros(2, 2);
        let mut a = [1.0f32, 0.5];
        let mut b = [1.0f32, -0.5];
        unit(&mut a);
        unit(&mut b);
        z.row_mut(0).copy_from_slice(&a);
        z.row_mut(1).copy_from_slice(&b);
        scorer.add_batch(&[0, 1], &[0, 0], &z, &[1.0, 1.0], &[0.5, 0.5]);
        let s = scorer.finalize();
        assert!((s.consensus[0] - 1.0).abs() < 1e-6);
        assert!(s.consensus[1].abs() < 1e-6);
        // Both examples have equal alpha.
        assert!((s.entries[0].alpha - s.entries[1].alpha).abs() < 1e-6);
    }

    #[test]
    fn alpha_in_unit_interval() {
        let mut scorer = AgreementScorer::new(3);
        let mut rng = crate::util::rng::Pcg64::seeded(3);
        let mut z = Matrix::zeros(50, 3);
        let mut norms = vec![0.0f32; 50];
        for i in 0..50 {
            let row = z.row_mut(i);
            rng.fill_normal(row, 1.0);
            norms[i] = tensor::normalize_in_place(row) as f32;
        }
        let idx: Vec<usize> = (0..50).collect();
        let labels = vec![0u32; 50];
        scorer.add_batch(&idx, &labels, &z, &norms, &vec![1.0; 50]);
        for e in scorer.finalize().entries {
            assert!((-1.0 - 1e-5..=1.0 + 1e-5).contains(&e.alpha), "{}", e.alpha);
        }
    }

    #[test]
    fn merge_equals_single_stream() {
        let mut rng = crate::util::rng::Pcg64::seeded(5);
        let mut z = Matrix::zeros(40, 4);
        let mut norms = vec![0.0f32; 40];
        for i in 0..40 {
            let row = z.row_mut(i);
            rng.fill_normal(row, 1.0);
            norms[i] = tensor::normalize_in_place(row) as f32;
        }
        let idx: Vec<usize> = (0..40).collect();
        let labels: Vec<u32> = (0..40).map(|i| (i % 3) as u32).collect();

        let mut whole = AgreementScorer::new(4);
        whole.add_batch(&idx, &labels, &z, &norms, &vec![1.0; 40]);
        let s1 = whole.finalize();

        let mut a = AgreementScorer::new(4);
        let mut b = AgreementScorer::new(4);
        let za = z.slice_rows(0, 25);
        let zb = z.slice_rows(25, 40);
        a.add_batch(&idx[..25], &labels[..25], &za, &norms[..25], &vec![1.0; 25]);
        b.add_batch(&idx[25..], &labels[25..], &zb, &norms[25..], &vec![1.0; 15]);
        a.merge(b);
        let s2 = a.finalize();

        for (e1, e2) in s1.entries.iter().zip(&s2.entries) {
            assert_eq!(e1.index, e2.index);
            assert!((e1.alpha - e2.alpha).abs() < 1e-6);
        }
    }

    #[test]
    fn zero_consensus_gives_zero_scores() {
        // Two exactly opposite directions cancel: z̄ = 0 -> u = 0 -> α = 0.
        let mut scorer = AgreementScorer::new(2);
        let mut z = Matrix::zeros(2, 2);
        z.set(0, 0, 1.0);
        z.set(1, 0, -1.0);
        scorer.add_batch(&[0, 1], &[0, 1], &z, &[1.0, 1.0], &[0.5, 0.5]);
        let s = scorer.finalize();
        assert!(s.consensus.iter().all(|&v| v == 0.0));
        assert!(s.entries.iter().all(|e| e.alpha == 0.0));
    }

    #[test]
    #[should_panic]
    fn dim_mismatch_panics() {
        let mut scorer = AgreementScorer::new(3);
        let z = Matrix::zeros(1, 2);
        scorer.add_batch(&[0], &[0], &z, &[1.0], &[1.0]);
    }
}
