//! Streaming consensus accumulation + agreement scoring (Phase II).
//!
//! [`AgreementScorer`] accumulates normalized projections in a streaming
//! pass and [`Scores`] is its finalized output. Both have bit-exact
//! serializable forms ([`ScorerState`], [`ScoresState`]) so the service can
//! checkpoint, spill, and recover Phase-II state without perturbing ranks:
//! the consensus accumulators are `f64` and round-trip as raw bits.
//!
//! The resident footprint of scorer state is `O(Nℓ)` (one cached ℓ-dim row
//! plus [`ENTRY_BYTES`] of metadata per scored example). The service's
//! admission control accounts it with [`scorer_state_bytes`] /
//! [`scores_state_bytes`] — keep those formulas in sync with the struct
//! layouts below.

use crate::tensor::{self, kernels, ComputeBackend, Matrix};

/// Reusable Phase-II projection buffer. Callers streaming batches hold one
/// per shard and pass it to `ModelBackend::score_fused_with`, so each
/// batch's `b × ℓ` ẑ matrix reuses a single allocation instead of
/// reallocating per batch: `take` shapes the buffer into a Matrix, and
/// `recycle` returns the storage once the batch is consumed.
#[derive(Default)]
pub struct ProjectionScratch {
    buf: Vec<f32>,
}

impl ProjectionScratch {
    pub fn new() -> Self {
        Self::default()
    }

    /// Shape the scratch storage into a zeroed `rows × cols` matrix
    /// (allocation-free once the buffer has grown to the working size).
    pub fn take(&mut self, rows: usize, cols: usize) -> Matrix {
        let mut buf = std::mem::take(&mut self.buf);
        buf.clear();
        buf.resize(rows * cols, 0.0);
        Matrix::from_vec(rows, cols, buf)
    }

    /// Return a matrix's storage for the next batch.
    pub fn recycle(&mut self, m: Matrix) {
        self.buf = m.into_vec();
    }
}

/// Accounted metadata bytes per scored example (index 8 + label 4 + norm 4
/// + loss 4 + alpha 4) — the unit of the service's scorer-byte admission
/// formula, deliberately layout-independent.
pub const ENTRY_BYTES: usize = 24;

/// Resident/serialized bytes of an [`AgreementScorer`] holding `n` entries
/// of ℓ-dim rows: `n·(ENTRY_BYTES + 4ℓ)` for entries + cached rows, plus
/// `8ℓ` for the f64 consensus accumulator.
pub fn scorer_state_bytes(n: usize, ell: usize) -> usize {
    n.saturating_mul(ENTRY_BYTES + 4 * ell)
        .saturating_add(8 * ell)
}

/// Resident/serialized bytes of finalized [`Scores`] over `n` entries:
/// `n·(ENTRY_BYTES + 4ℓ)` for entries + the ẑ cache, plus `4ℓ` for the f32
/// consensus direction. Never exceeds [`scorer_state_bytes`] for the same
/// `n`, so finalizing can only shrink the admission footprint.
pub fn scores_state_bytes(n: usize, ell: usize) -> usize {
    n.saturating_mul(ENTRY_BYTES + 4 * ell)
        .saturating_add(4 * ell)
}

/// Metadata for one scored example.
#[derive(Clone, Copy, Debug)]
pub struct ScoreEntry {
    /// Global dataset index.
    pub index: usize,
    pub label: u32,
    /// ‖S g_i‖₂ (projection magnitude before normalization).
    pub norm: f32,
    /// Per-example training loss at the scoring parameters (DROP's proxy).
    pub loss: f32,
    /// Agreement score α_i = ⟨ẑ_i, u⟩.
    pub alpha: f32,
}

/// Finalized Phase-II output.
pub struct Scores {
    pub ell: usize,
    /// Unit consensus u (zero vector if z̄ = 0).
    pub consensus: Vec<f32>,
    pub entries: Vec<ScoreEntry>,
    /// Cached normalized projections, row r ↔ entries[r].
    pub zhat: Matrix,
}

/// Bit-exact serializable form of an (un-finalized) [`AgreementScorer`] —
/// the service's checkpoint/spill representation of raw Phase-II state.
/// Fields are parallel arrays over the scored entries; `rows` is the
/// flattened `count × ℓ` ẑ cache. Entry `alpha` values are not carried
/// (they are 0 until finalize fills them).
#[derive(Clone, Debug, PartialEq)]
pub struct ScorerState {
    pub ell: u32,
    pub count: u64,
    /// f64 consensus accumulator — raw-bit round-trip keeps ranks exact.
    pub consensus_acc: Vec<f64>,
    pub indices: Vec<u64>,
    pub labels: Vec<u32>,
    pub norms: Vec<f32>,
    pub losses: Vec<f32>,
    pub rows: Vec<f32>,
}

/// Bit-exact serializable form of finalized [`Scores`] (the TopK cache).
#[derive(Clone, Debug, PartialEq)]
pub struct ScoresState {
    pub ell: u32,
    pub consensus: Vec<f32>,
    pub indices: Vec<u64>,
    pub labels: Vec<u32>,
    pub norms: Vec<f32>,
    pub losses: Vec<f32>,
    pub alphas: Vec<f32>,
    /// `n × ℓ` cached normalized projections, row r ↔ indices[r].
    pub zhat: Matrix,
}

impl Scores {
    /// Accounted resident bytes of this cache ([`scores_state_bytes`]).
    pub fn state_bytes(&self) -> usize {
        scores_state_bytes(self.entries.len(), self.ell)
    }

    /// Export into the serializable checkpoint form. Bit-exact inverse of
    /// [`Scores::from_state`].
    pub fn export_state(&self) -> ScoresState {
        ScoresState {
            ell: self.ell as u32,
            consensus: self.consensus.clone(),
            indices: self.entries.iter().map(|e| e.index as u64).collect(),
            labels: self.entries.iter().map(|e| e.label).collect(),
            norms: self.entries.iter().map(|e| e.norm).collect(),
            losses: self.entries.iter().map(|e| e.loss).collect(),
            alphas: self.entries.iter().map(|e| e.alpha).collect(),
            zhat: self.zhat.clone(),
        }
    }

    /// Rebuild finalized scores from a checkpoint.
    ///
    /// # Errors
    /// Rejects states whose parallel arrays or ẑ matrix dims disagree.
    pub fn from_state(state: &ScoresState) -> Result<Scores, String> {
        let ell = state.ell as usize;
        if ell == 0 {
            return Err("scores state: ell must be positive".into());
        }
        let n = state.indices.len();
        if state.labels.len() != n
            || state.norms.len() != n
            || state.losses.len() != n
            || state.alphas.len() != n
            || state.consensus.len() != ell
            || state.zhat.rows() != n
            || state.zhat.cols() != ell
        {
            return Err("scores state: field lengths disagree".into());
        }
        let entries = (0..n)
            .map(|r| ScoreEntry {
                index: state.indices[r] as usize,
                label: state.labels[r],
                norm: state.norms[r],
                loss: state.losses[r],
                alpha: state.alphas[r],
            })
            .collect();
        Ok(Scores {
            ell,
            consensus: state.consensus.clone(),
            entries,
            zhat: state.zhat.clone(),
        })
    }
}

/// Accumulates normalized projections ẑ_i and the running mean z̄ in a
/// streaming pass (Algorithm 1 lines 13-15). The consensus state is ℓ-dim;
/// ẑ rows are cached so the subsequent scoring pass needs no recompute
/// (`O(Nℓ)` cache — see the `streaming` ablation bench for the two-pass
/// `O(ℓ)` variant).
pub struct AgreementScorer {
    ell: usize,
    /// Σ ẑ_i in f64 (drift across N ~ 1e5 terms must not perturb ranks).
    consensus_acc: Vec<f64>,
    count: u64,
    entries: Vec<ScoreEntry>,
    rows: Vec<f32>,
}

impl AgreementScorer {
    pub fn new(ell: usize) -> Self {
        assert!(ell > 0);
        Self {
            ell,
            consensus_acc: vec![0.0; ell],
            count: 0,
            entries: Vec::new(),
            rows: Vec::new(),
        }
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    /// Accounted resident bytes of this scorer ([`scorer_state_bytes`]) —
    /// grows by `ENTRY_BYTES + 4ℓ` per scored entry.
    pub fn state_bytes(&self) -> usize {
        scorer_state_bytes(self.entries.len(), self.ell)
    }

    /// Export into the serializable checkpoint form. Bit-exact inverse of
    /// [`AgreementScorer::from_state`]: a recovered scorer finalizes to the
    /// same ranks as the original.
    pub fn export_state(&self) -> ScorerState {
        ScorerState {
            ell: self.ell as u32,
            count: self.count,
            consensus_acc: self.consensus_acc.clone(),
            indices: self.entries.iter().map(|e| e.index as u64).collect(),
            labels: self.entries.iter().map(|e| e.label).collect(),
            norms: self.entries.iter().map(|e| e.norm).collect(),
            losses: self.entries.iter().map(|e| e.loss).collect(),
            rows: self.rows.clone(),
        }
    }

    /// Rebuild a scorer from a checkpoint.
    ///
    /// # Errors
    /// Rejects states whose parallel arrays, row cache, or accumulator
    /// dims disagree.
    pub fn from_state(state: &ScorerState) -> Result<AgreementScorer, String> {
        let ell = state.ell as usize;
        if ell == 0 {
            return Err("scorer state: ell must be positive".into());
        }
        let n = state.indices.len();
        if state.count != n as u64
            || state.labels.len() != n
            || state.norms.len() != n
            || state.losses.len() != n
            || state.consensus_acc.len() != ell
            || state.rows.len() != n.saturating_mul(ell)
        {
            return Err("scorer state: field lengths disagree".into());
        }
        let entries = (0..n)
            .map(|r| ScoreEntry {
                index: state.indices[r] as usize,
                label: state.labels[r],
                norm: state.norms[r],
                loss: state.losses[r],
                alpha: 0.0, // filled by finalize
            })
            .collect();
        Ok(AgreementScorer {
            ell,
            consensus_acc: state.consensus_acc.clone(),
            count: state.count,
            entries,
            rows: state.rows.clone(),
        })
    }

    /// Add a batch of *already normalized* projections (`zhat [b × ℓ]`,
    /// zero rows for zero projections) with their pre-normalization norms.
    pub fn add_batch(
        &mut self,
        indices: &[usize],
        labels: &[u32],
        zhat: &Matrix,
        norms: &[f32],
        losses: &[f32],
    ) {
        assert_eq!(zhat.rows(), indices.len());
        assert_eq!(indices.len(), labels.len());
        assert_eq!(indices.len(), norms.len());
        assert_eq!(indices.len(), losses.len());
        assert_eq!(zhat.cols(), self.ell, "projection dim");
        // Row-sequential f64 column sums — the kernel layer's accumulator
        // op, whose fixed order the exactness guarantee pins down.
        kernels::accumulate_col_sums(zhat, &mut self.consensus_acc);
        for r in 0..zhat.rows() {
            self.count += 1;
            self.entries.push(ScoreEntry {
                index: indices[r],
                label: labels[r],
                norm: norms[r],
                loss: losses[r],
                alpha: 0.0, // filled by finalize
            });
            self.rows.extend_from_slice(zhat.row(r));
        }
    }

    /// Merge another scorer's partial state (pipeline shard aggregation).
    pub fn merge(&mut self, other: AgreementScorer) {
        assert_eq!(self.ell, other.ell);
        for (a, b) in self.consensus_acc.iter_mut().zip(&other.consensus_acc) {
            *a += b;
        }
        self.count += other.count;
        self.entries.extend(other.entries);
        self.rows.extend(other.rows);
    }

    /// Compute u and all α_i (Algorithm 1 lines 14-15) on the serial
    /// kernel backend.
    pub fn finalize(self) -> Scores {
        self.finalize_with(tensor::serial().as_ref())
    }

    /// [`AgreementScorer::finalize`] with an explicit kernel backend: the
    /// `N × ℓ` consensus matvec (`α = Ẑ·u`) runs through `compute`, and is
    /// bit-identical across serial/parallel backends and worker counts by
    /// the determinism contract — served TopK equals offline TopK no
    /// matter which backend either side runs.
    pub fn finalize_with(mut self, compute: &dyn ComputeBackend) -> Scores {
        let n = self.count.max(1) as f64;
        let mut u: Vec<f32> = self.consensus_acc.iter().map(|&v| (v / n) as f32).collect();
        // Normalize on the backend's own dispatch tier so a pinned backend
        // (bench / parity tests) keeps the whole finalize tier-coherent.
        let norm = compute.dispatch().normalize_in_place(&mut u);
        let consensus = if norm > 0.0 { u } else { vec![0.0; self.ell] };

        let zhat = Matrix::from_vec(self.entries.len(), self.ell, std::mem::take(&mut self.rows));
        let alphas = compute.matvec(&zhat, &consensus);
        for (e, alpha) in self.entries.iter_mut().zip(alphas) {
            e.alpha = alpha;
        }
        Scores {
            ell: self.ell,
            consensus,
            entries: self.entries,
            zhat,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn unit(v: &mut [f32]) {
        tensor::normalize_in_place(v);
    }

    #[test]
    fn consensus_is_mean_direction() {
        let mut scorer = AgreementScorer::new(2);
        // Two points symmetric about the x-axis -> consensus = x-axis.
        let mut z = Matrix::zeros(2, 2);
        let mut a = [1.0f32, 0.5];
        let mut b = [1.0f32, -0.5];
        unit(&mut a);
        unit(&mut b);
        z.row_mut(0).copy_from_slice(&a);
        z.row_mut(1).copy_from_slice(&b);
        scorer.add_batch(&[0, 1], &[0, 0], &z, &[1.0, 1.0], &[0.5, 0.5]);
        let s = scorer.finalize();
        assert!((s.consensus[0] - 1.0).abs() < 1e-6);
        assert!(s.consensus[1].abs() < 1e-6);
        // Both examples have equal alpha.
        assert!((s.entries[0].alpha - s.entries[1].alpha).abs() < 1e-6);
    }

    #[test]
    fn alpha_in_unit_interval() {
        let mut scorer = AgreementScorer::new(3);
        let mut rng = crate::util::rng::Pcg64::seeded(3);
        let mut z = Matrix::zeros(50, 3);
        let mut norms = vec![0.0f32; 50];
        for i in 0..50 {
            let row = z.row_mut(i);
            rng.fill_normal(row, 1.0);
            norms[i] = tensor::normalize_in_place(row) as f32;
        }
        let idx: Vec<usize> = (0..50).collect();
        let labels = vec![0u32; 50];
        scorer.add_batch(&idx, &labels, &z, &norms, &vec![1.0; 50]);
        for e in scorer.finalize().entries {
            assert!((-1.0 - 1e-5..=1.0 + 1e-5).contains(&e.alpha), "{}", e.alpha);
        }
    }

    #[test]
    fn merge_equals_single_stream() {
        let mut rng = crate::util::rng::Pcg64::seeded(5);
        let mut z = Matrix::zeros(40, 4);
        let mut norms = vec![0.0f32; 40];
        for i in 0..40 {
            let row = z.row_mut(i);
            rng.fill_normal(row, 1.0);
            norms[i] = tensor::normalize_in_place(row) as f32;
        }
        let idx: Vec<usize> = (0..40).collect();
        let labels: Vec<u32> = (0..40).map(|i| (i % 3) as u32).collect();

        let mut whole = AgreementScorer::new(4);
        whole.add_batch(&idx, &labels, &z, &norms, &vec![1.0; 40]);
        let s1 = whole.finalize();

        let mut a = AgreementScorer::new(4);
        let mut b = AgreementScorer::new(4);
        let za = z.slice_rows(0, 25);
        let zb = z.slice_rows(25, 40);
        a.add_batch(&idx[..25], &labels[..25], &za, &norms[..25], &vec![1.0; 25]);
        b.add_batch(&idx[25..], &labels[25..], &zb, &norms[25..], &vec![1.0; 15]);
        a.merge(b);
        let s2 = a.finalize();

        for (e1, e2) in s1.entries.iter().zip(&s2.entries) {
            assert_eq!(e1.index, e2.index);
            assert!((e1.alpha - e2.alpha).abs() < 1e-6);
        }
    }

    #[test]
    fn zero_consensus_gives_zero_scores() {
        // Two exactly opposite directions cancel: z̄ = 0 -> u = 0 -> α = 0.
        let mut scorer = AgreementScorer::new(2);
        let mut z = Matrix::zeros(2, 2);
        z.set(0, 0, 1.0);
        z.set(1, 0, -1.0);
        scorer.add_batch(&[0, 1], &[0, 1], &z, &[1.0, 1.0], &[0.5, 0.5]);
        let s = scorer.finalize();
        assert!(s.consensus.iter().all(|&v| v == 0.0));
        assert!(s.entries.iter().all(|e| e.alpha == 0.0));
    }

    #[test]
    #[should_panic]
    fn dim_mismatch_panics() {
        let mut scorer = AgreementScorer::new(3);
        let z = Matrix::zeros(1, 2);
        scorer.add_batch(&[0], &[0], &z, &[1.0], &[1.0]);
    }

    fn populated_scorer(rng: &mut crate::util::rng::Pcg64, n: usize, ell: usize) -> AgreementScorer {
        let mut scorer = AgreementScorer::new(ell);
        let mut z = Matrix::zeros(n, ell);
        let mut norms = vec![0.0f32; n];
        for i in 0..n {
            let row = z.row_mut(i);
            rng.fill_normal(row, 1.0);
            norms[i] = tensor::normalize_in_place(row) as f32;
        }
        let idx: Vec<usize> = (0..n).collect();
        let labels: Vec<u32> = (0..n).map(|i| (i % 4) as u32).collect();
        scorer.add_batch(&idx, &labels, &z, &norms, &vec![0.5; n]);
        scorer
    }

    #[test]
    fn scorer_state_round_trip_finalizes_identically() {
        let mut rng = crate::util::rng::Pcg64::seeded(17);
        let scorer = populated_scorer(&mut rng, 33, 5);
        let state = scorer.export_state();
        assert_eq!(state.count, 33);
        let back = AgreementScorer::from_state(&state).unwrap();
        assert_eq!(back.export_state(), state); // bit-exact both ways
        let s1 = scorer.finalize();
        let s2 = back.finalize();
        assert_eq!(s1.consensus, s2.consensus);
        for (a, b) in s1.entries.iter().zip(&s2.entries) {
            assert_eq!(a.index, b.index);
            assert_eq!(a.alpha.to_bits(), b.alpha.to_bits());
        }
    }

    #[test]
    fn scores_state_round_trip_is_bit_exact() {
        let mut rng = crate::util::rng::Pcg64::seeded(23);
        let scores = populated_scorer(&mut rng, 21, 4).finalize();
        let state = scores.export_state();
        let back = Scores::from_state(&state).unwrap();
        assert_eq!(back.export_state(), state);
        assert_eq!(back.zhat.as_slice(), scores.zhat.as_slice());
        for (a, b) in scores.entries.iter().zip(&back.entries) {
            assert_eq!(a.alpha.to_bits(), b.alpha.to_bits());
        }
    }

    #[test]
    fn state_validation_rejects_inconsistent_fields() {
        let mut rng = crate::util::rng::Pcg64::seeded(29);
        let scorer = populated_scorer(&mut rng, 8, 3);
        let mut st = scorer.export_state();
        st.labels.pop();
        assert!(AgreementScorer::from_state(&st).is_err());
        let mut st2 = scorer.export_state();
        st2.rows.pop();
        assert!(AgreementScorer::from_state(&st2).is_err());
        let mut st3 = scorer.export_state();
        st3.ell = 0;
        assert!(AgreementScorer::from_state(&st3).is_err());

        let scores = populated_scorer(&mut rng, 8, 3).finalize();
        let mut ss = scores.export_state();
        ss.alphas.pop();
        assert!(Scores::from_state(&ss).is_err());
    }

    #[test]
    fn byte_accounting_formulas_track_growth() {
        let mut rng = crate::util::rng::Pcg64::seeded(31);
        let ell = 6;
        let fresh = AgreementScorer::new(ell);
        assert_eq!(fresh.state_bytes(), scorer_state_bytes(0, ell));
        assert_eq!(scorer_state_bytes(0, ell), 8 * ell);
        let scorer = populated_scorer(&mut rng, 10, ell);
        assert_eq!(
            scorer.state_bytes(),
            10 * (ENTRY_BYTES + 4 * ell) + 8 * ell
        );
        let scores = populated_scorer(&mut rng, 10, ell).finalize();
        assert_eq!(scores.state_bytes(), scores_state_bytes(10, ell));
        // Finalizing never grows the accounted footprint.
        assert!(scores.state_bytes() <= scorer.state_bytes());
    }
}
