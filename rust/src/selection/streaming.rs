//! Strictly-streaming Phase II: O(ℓ) state, no ẑ cache.
//!
//! [`AgreementScorer`](super::AgreementScorer) caches the `N × ℓ` normalized
//! projections so consensus + scoring need one model pass. This module
//! implements the paper's strict constant-memory reading instead: pass 2a
//! accumulates only the ℓ-dim consensus; pass 2b recomputes each projection
//! and scores it on the fly, feeding a bounded top-k heap. Total extra state
//! is `O(ℓ + k)` — the trade is one additional backward pass over the data
//! (quantified in `cargo bench --bench ablation`, section F).

use super::topk::TopK;
use crate::tensor::{self, kernels, Matrix};

/// Pass 2a: consensus accumulation (ℓ-dim, mergeable).
pub struct ConsensusAccumulator {
    ell: usize,
    acc: Vec<f64>,
    count: u64,
}

impl ConsensusAccumulator {
    pub fn new(ell: usize) -> Self {
        Self {
            ell,
            acc: vec![0.0; ell],
            count: 0,
        }
    }

    /// Fold in a batch of normalized projections.
    pub fn add(&mut self, zhat: &Matrix) {
        assert_eq!(zhat.cols(), self.ell);
        for r in 0..zhat.rows() {
            for (j, &v) in zhat.row(r).iter().enumerate() {
                self.acc[j] += v as f64;
            }
            self.count += 1;
        }
    }

    pub fn merge(&mut self, other: &ConsensusAccumulator) {
        assert_eq!(self.ell, other.ell);
        for (a, b) in self.acc.iter_mut().zip(&other.acc) {
            *a += b;
        }
        self.count += other.count;
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    /// Unit consensus u (zero if the mean is zero).
    pub fn consensus(&self) -> Vec<f32> {
        let n = self.count.max(1) as f64;
        let mut u: Vec<f32> = self.acc.iter().map(|&v| (v / n) as f32).collect();
        let norm = tensor::normalize_in_place(&mut u);
        if norm > 0.0 {
            u
        } else {
            vec![0.0; self.ell]
        }
    }

    /// State size in bytes — the O(ℓ) claim, measurable.
    pub fn memory_bytes(&self) -> usize {
        self.acc.len() * std::mem::size_of::<f64>()
    }
}

/// Pass 2b: streaming scoring + bounded selection against a fixed u.
pub struct StreamingSelector {
    consensus: Vec<f32>,
    heap: TopK,
    scored: u64,
}

impl StreamingSelector {
    pub fn new(consensus: Vec<f32>, k: usize) -> Self {
        Self {
            consensus,
            heap: TopK::new(k),
            scored: 0,
        }
    }

    /// Score one batch of normalized projections with global indices.
    /// Alphas come from the same active-tier `dot` microkernel as
    /// `AgreementScorer::finalize_with`'s consensus matvec, keeping the
    /// streaming and cached scoring paths bit-identical.
    pub fn add(&mut self, indices: &[usize], zhat: &Matrix) {
        assert_eq!(indices.len(), zhat.rows());
        assert_eq!(zhat.cols(), self.consensus.len());
        for (r, &idx) in indices.iter().enumerate() {
            let alpha = kernels::dot(zhat.row(r), &self.consensus);
            self.heap.push(alpha, idx);
            self.scored += 1;
        }
    }

    pub fn scored(&self) -> u64 {
        self.scored
    }

    /// Selected indices, best-first.
    pub fn finish(self) -> Vec<usize> {
        self.heap.into_sorted_indices()
    }
}

/// Convenience: run both streaming passes over an iterator of batches.
/// `batches` yields `(global_indices, zhat)` and must be re-playable
/// (called twice — this is the second backward pass the paper counts).
pub fn streaming_select<F>(ell: usize, k: usize, mut replay: F) -> Vec<usize>
where
    F: FnMut(&mut dyn FnMut(&[usize], &Matrix)),
{
    let mut acc = ConsensusAccumulator::new(ell);
    replay(&mut |_idx, zhat| acc.add(zhat));
    let mut sel = StreamingSelector::new(acc.consensus(), k);
    replay(&mut |idx, zhat| sel.add(idx, zhat));
    sel.finish()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::selection::AgreementScorer;
    use crate::util::rng::Pcg64;

    fn normalized_batch(rng: &mut Pcg64, n: usize, ell: usize) -> Matrix {
        let mut z = Matrix::zeros(n, ell);
        let mut dir = vec![0.0f32; ell];
        rng.fill_normal(&mut dir, 1.0);
        tensor::normalize_in_place(&mut dir);
        for i in 0..n {
            let row = z.row_mut(i);
            for (j, &d) in dir.iter().enumerate() {
                row[j] = d + 0.7 * rng.normal_f32();
            }
            tensor::normalize_in_place(row);
        }
        z
    }

    #[test]
    fn streaming_matches_cached_selection() {
        let mut rng = Pcg64::seeded(1);
        let ell = 8;
        let z = normalized_batch(&mut rng, 200, ell);
        let idx: Vec<usize> = (0..200).collect();

        // Cached path.
        let mut scorer = AgreementScorer::new(ell);
        scorer.add_batch(
            &idx,
            &vec![0u32; 200],
            &z,
            &vec![1.0f32; 200],
            &vec![1.0f32; 200],
        );
        let scores = scorer.finalize();
        let cached = crate::selection::select_top_k(&scores, 40);

        // Streaming path replaying the same batches.
        let streamed = streaming_select(ell, 40, |f| {
            for chunk in 0..4 {
                let lo = chunk * 50;
                let zc = z.slice_rows(lo, lo + 50);
                let ic: Vec<usize> = (lo..lo + 50).collect();
                f(&ic, &zc);
            }
        });
        let mut a = cached.clone();
        let mut b = streamed.clone();
        a.sort_unstable();
        b.sort_unstable();
        assert_eq!(a, b);
    }

    #[test]
    fn consensus_accumulator_merge_equals_single() {
        let mut rng = Pcg64::seeded(2);
        let z = normalized_batch(&mut rng, 60, 6);
        let mut whole = ConsensusAccumulator::new(6);
        whole.add(&z);
        let mut p1 = ConsensusAccumulator::new(6);
        let mut p2 = ConsensusAccumulator::new(6);
        p1.add(&z.slice_rows(0, 25));
        p2.add(&z.slice_rows(25, 60));
        p1.merge(&p2);
        assert_eq!(p1.count(), whole.count());
        let a = whole.consensus();
        let b = p1.consensus();
        for (x, y) in a.iter().zip(&b) {
            assert!((x - y).abs() < 1e-6);
        }
    }

    #[test]
    fn memory_is_ell_only() {
        let acc = ConsensusAccumulator::new(64);
        assert_eq!(acc.memory_bytes(), 64 * 8);
        // Adding data never grows the state.
        let mut acc = ConsensusAccumulator::new(16);
        let mut rng = Pcg64::seeded(3);
        for _ in 0..50 {
            acc.add(&normalized_batch(&mut rng, 32, 16));
        }
        assert_eq!(acc.memory_bytes(), 16 * 8);
        assert_eq!(acc.count(), 1600);
    }

    #[test]
    fn zero_consensus_selects_deterministically() {
        let mut z = Matrix::zeros(2, 4);
        z.set(0, 0, 1.0);
        z.set(1, 0, -1.0);
        let sel = streaming_select(4, 1, |f| {
            f(&[0, 1], &z);
        });
        assert_eq!(sel.len(), 1);
        assert_eq!(sel[0], 0); // tie on alpha=0 -> smallest index
    }
}
