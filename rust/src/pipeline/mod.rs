//! Streaming selection pipeline — the L3 coordinator tying Algorithm 1
//! together over the runtime:
//!
//! ```text
//! shards ──► grad workers ──► shard-local FD sketches ──► ordered merge ──► S
//!          (Phase I: one streaming pass, O(ℓD) per worker)
//! shards ──► score workers (fused grads+projection) ──► scorer merge ──► α
//!          (Phase II: second pass against frozen S)
//! α ──► top-k / CB top-k / baseline rule ──► subset indices
//! ```
//!
//! Two execution modes:
//! * [`run_selection`] — shard-parallel: each worker owns a contiguous shard
//!   and a local sketch; sketches merge in shard order (FD mergeability), so
//!   results are deterministic for a fixed `(seed, workers)`.
//! * [`stream_sketch`] — demand-driven: a reader thread pushes batches into
//!   a bounded channel (backpressure) and workers pull; used by the
//!   streaming example and the backpressure tests.
//!
//! The per-shard loops are exposed as [`phase1_gradient_stream`] and
//! [`phase2_score_stream`] so the `service` subsystem drives the *same*
//! implementation over the wire: a served session fed shard-by-shard
//! produces byte-identical sketches and scores to the offline path.

use crate::baselines::{select_weighted, SelectionInputs};
use crate::config::Method;
use crate::data::{Dataset, StreamBatches};
use crate::selection::{AgreementScorer, ProjectionScratch, Scores};
use crate::sketch::{FdSketch, ShrinkBackend};
use crate::runtime::ModelBackend;
use crate::tensor::{ComputeBackend, Matrix};
use crate::util::channel::bounded;
use std::sync::Arc;
use std::time::Instant;

/// Pipeline knobs.
#[derive(Clone, Debug)]
pub struct PipelineConfig {
    /// Worker threads (= shards in shard-parallel mode).
    pub workers: usize,
    /// Bounded channel capacity for streaming mode (backpressure depth).
    pub channel_capacity: usize,
    /// Warm-up SGD steps before selection gradients are taken.
    pub warmup_steps: usize,
    pub warmup_lr: f64,
    /// Held-out fraction used for GLISTER's validation direction.
    pub val_fraction: f64,
    pub seed: u64,
    /// Kernel backend for the hot contractions (FD shrink, projection,
    /// consensus matvec, selection-rule scans). Serial by default;
    /// `main.rs` threads a shared `tensor::ParallelBackend` down here.
    /// Selections are bit-identical across backends and worker counts.
    pub compute: Arc<dyn ComputeBackend>,
}

impl Default for PipelineConfig {
    fn default() -> Self {
        Self {
            workers: crate::util::threadpool::default_threads().min(4),
            channel_capacity: 8,
            warmup_steps: 30,
            warmup_lr: 0.05,
            val_fraction: 0.1,
            seed: 0,
            compute: crate::tensor::serial(),
        }
    }
}

/// Wall-clock + volume stats for one phase.
#[derive(Clone, Copy, Debug, Default)]
pub struct PhaseStats {
    pub seconds: f64,
    pub batches: u64,
    pub examples: u64,
}

/// Everything the selection pass produces.
pub struct SelectionOutcome {
    /// Selected global indices (sorted for SAGE/CB and baselines that sort).
    pub indices: Vec<usize>,
    /// Per-selected-example training weights (CRAIG cluster sizes), aligned
    /// with `indices`; None for methods without weights.
    pub weights: Option<Vec<f32>>,
    /// Phase-II scores for every example.
    pub scores: Scores,
    /// Frozen sketch S.
    pub sketch: Matrix,
    /// O(ℓD) footprint of the sketch buffer in bytes.
    pub sketch_bytes: usize,
    pub shrinks: u64,
    pub shift_bound: f64,
    pub phase1: PhaseStats,
    pub phase2: PhaseStats,
    pub select_seconds: f64,
    pub warmup_seconds: f64,
    /// Parameters the selection gradients were computed at.
    pub params: Vec<f32>,
}

/// Contiguous `[start, end)` shard ranges covering `n` examples across
/// `workers` shards — the unit of work for both the offline pipeline and
/// the service's per-shard sessions. Deterministic for fixed `(n, workers)`.
pub fn shard_ranges(n: usize, workers: usize) -> Vec<(usize, usize)> {
    let w = workers.max(1).min(n.max(1));
    let per = n.div_ceil(w);
    (0..w)
        .map(|i| (i * per, ((i + 1) * per).min(n)))
        .filter(|(a, b)| a < b)
        .collect()
}

/// One block of Phase-II scoring output, borrowed from the producing loop:
/// global indices, labels, normalized projections `ẑ [b × ℓ]`, projection
/// norms and per-example losses for one batch.
pub struct ScoreBlock<'a> {
    pub indices: &'a [usize],
    pub labels: &'a [u32],
    pub zhat: &'a Matrix,
    pub norms: &'a [f32],
    pub losses: &'a [f32],
}

/// Phase-I gradient stream over one contiguous shard `[range.0, range.1)`:
/// compute per-example gradients batch-by-batch and hand each `[b × D]`
/// gradient block to `sink` in deterministic order. Returns the number of
/// batches streamed.
///
/// This is THE Phase-I ingest loop — [`run_selection`] drives it into a
/// shard-local [`FdSketch`], and the service client drives it into
/// `IngestBatch` wire frames, so offline and served selection share one
/// implementation (and therefore produce identical sketches).
pub fn phase1_gradient_stream(
    backend: &dyn ModelBackend,
    ds: &Dataset,
    params: &[f32],
    range: (usize, usize),
    mut sink: impl FnMut(&Matrix) -> Result<(), String>,
) -> Result<u64, String> {
    let idx: Vec<usize> = (range.0..range.1).collect();
    let shard = ds.subset(&idx);
    let b = backend.score_batch();
    let mut batches = 0u64;
    let hist = crate::util::metrics::global().histogram("pipeline.phase1.batch.ns");
    for (_start, batch) in StreamBatches::new(&shard, b) {
        let _t = crate::util::metrics::ScopedTimer::new(hist);
        let y = batch.one_hot();
        let (g, _losses) = backend.per_example_grads(params, &batch.features, &y)?;
        sink(&g)?;
        batches += 1;
    }
    crate::util::metrics::global()
        .counter("pipeline.phase1.examples")
        .add((range.1 - range.0) as u64);
    Ok(batches)
}

/// Phase-II scoring stream over one contiguous shard against the frozen
/// sketch `S`: fused grads+projection per batch, handing each
/// [`ScoreBlock`] (global indices, labels, ẑ, norms, losses) to `sink` in
/// deterministic order. Returns the number of batches streamed.
///
/// Shared by [`run_selection`] (sink = [`AgreementScorer::add_batch`]) and
/// the service client (sink = `Score` wire frames).
pub fn phase2_score_stream(
    backend: &dyn ModelBackend,
    ds: &Dataset,
    params: &[f32],
    sketch: &Matrix,
    range: (usize, usize),
    mut sink: impl FnMut(ScoreBlock<'_>) -> Result<(), String>,
) -> Result<u64, String> {
    let idx: Vec<usize> = (range.0..range.1).collect();
    let shard = ds.subset(&idx);
    let b = backend.score_batch();
    let mut batches = 0u64;
    let hist = crate::util::metrics::global().histogram("pipeline.phase2.batch.ns");
    // One projection buffer for the whole shard stream: each batch's ẑ is
    // written into it and recycled after the sink consumed the block.
    let mut scratch = ProjectionScratch::new();
    for (start, batch) in StreamBatches::new(&shard, b) {
        let _t = crate::util::metrics::ScopedTimer::new(hist);
        let y = batch.one_hot();
        let (zhat, norms, losses) =
            backend.score_fused_with(params, sketch, &batch.features, &y, &mut scratch)?;
        let global: Vec<usize> = (0..batch.len()).map(|r| range.0 + start + r).collect();
        sink(ScoreBlock {
            indices: &global,
            labels: &batch.labels,
            zhat: &zhat,
            norms: &norms,
            losses: &losses,
        })?;
        scratch.recycle(zhat);
        batches += 1;
    }
    Ok(batches)
}

/// Phase I over one shard: stream batches, push per-example grads into a
/// local FD sketch.
fn phase1_shard(
    backend: &dyn ModelBackend,
    ds: &Dataset,
    params: &[f32],
    range: (usize, usize),
    ell: usize,
    shrink_backend: Option<Arc<dyn ShrinkBackend>>,
) -> Result<(FdSketch, u64), String> {
    let d = backend.spec().d();
    let mut sketch = match shrink_backend {
        Some(b) => FdSketch::with_backend(ell, d, b),
        None => FdSketch::new(ell, d),
    };
    let batches = phase1_gradient_stream(backend, ds, params, range, |g| {
        sketch.insert_batch(g);
        Ok(())
    })?;
    Ok((sketch, batches))
}

/// Phase II over one shard: fused grads+projection against frozen S.
fn phase2_shard(
    backend: &dyn ModelBackend,
    ds: &Dataset,
    params: &[f32],
    sketch: &Matrix,
    range: (usize, usize),
) -> Result<(AgreementScorer, u64), String> {
    let mut scorer = AgreementScorer::new(backend.ell());
    let batches = phase2_score_stream(backend, ds, params, sketch, range, |blk| {
        scorer.add_batch(blk.indices, blk.labels, blk.zhat, blk.norms, blk.losses);
        Ok(())
    })?;
    Ok((scorer, batches))
}

/// Run the full two-pass selection (Algorithm 1) and apply `method`.
///
/// `shrink_backend = None` uses the pure-Rust FD shrink; pass an
/// [`crate::runtime::XlaShrinkBackend`] to route the shrink contractions
/// through the L1 Pallas artifacts.
pub fn run_selection(
    backend: &dyn ModelBackend,
    ds: &Dataset,
    method: Method,
    k: usize,
    cfg: &PipelineConfig,
    shrink_backend: Option<Arc<dyn ShrinkBackend>>,
) -> Result<SelectionOutcome, String> {
    let ell = backend.ell();
    let n = ds.len();
    if n == 0 {
        return Err("empty dataset".into());
    }

    // Warm-up the model so selection gradients carry label signal.
    let t0 = Instant::now();
    let warmup_span = crate::util::trace::span("pipeline.warmup");
    let params = crate::trainer::warmup_params(
        backend,
        ds,
        cfg.warmup_steps,
        cfg.warmup_lr,
        cfg.seed,
    )?;
    drop(warmup_span);
    let warmup_elapsed = t0.elapsed();
    let warmup_seconds = warmup_elapsed.as_secs_f64();
    crate::util::metrics::global()
        .histogram("pipeline.warmup.ns")
        .record(warmup_elapsed.as_nanos() as u64);

    // --- Phase I: sharded streaming sketch + ordered merge ---
    // Shard sketches shrink on the explicit shrink backend when given (the
    // XLA artifacts), otherwise on the pipeline's kernel backend.
    let shrink: Arc<dyn ShrinkBackend> = shrink_backend.unwrap_or_else(|| cfg.compute.clone());
    let t1 = Instant::now();
    let phase1_span = crate::util::trace::span("pipeline.phase1");
    let ranges = shard_ranges(n, cfg.workers);
    let mut results: Vec<Option<Result<(FdSketch, u64), String>>> =
        Vec::with_capacity(ranges.len());
    results.resize_with(ranges.len(), || None);
    {
        let results = std::sync::Mutex::new(&mut results);
        std::thread::scope(|scope| {
            for (i, &range) in ranges.iter().enumerate() {
                let results = &results;
                let params = &params;
                let sb = Some(shrink.clone());
                scope.spawn(move || {
                    let r = phase1_shard(backend, ds, params, range, ell, sb);
                    results.lock().unwrap()[i] = Some(r);
                });
            }
        });
    }
    let mut sketches: Vec<FdSketch> = Vec::with_capacity(ranges.len());
    let mut p1_batches = 0u64;
    for r in results.into_iter() {
        let (s, b) = r.expect("shard not run")?;
        p1_batches += b;
        sketches.push(s);
    }
    let mut merged = sketches.remove(0);
    for mut s in sketches {
        merged.merge(&mut s);
    }
    let sketch_matrix = merged.sketch();
    drop(phase1_span);
    let phase1 = PhaseStats {
        seconds: t1.elapsed().as_secs_f64(),
        batches: p1_batches,
        examples: n as u64,
    };
    crate::util::metrics::global()
        .histogram("pipeline.phase1.ns")
        .record(t1.elapsed().as_nanos() as u64);

    // --- Phase II: fused scoring against the frozen sketch ---
    let t2 = Instant::now();
    let phase2_span = crate::util::trace::span("pipeline.phase2");
    let mut results2: Vec<Option<Result<(AgreementScorer, u64), String>>> =
        Vec::with_capacity(ranges.len());
    results2.resize_with(ranges.len(), || None);
    {
        let results2 = std::sync::Mutex::new(&mut results2);
        std::thread::scope(|scope| {
            for (i, &range) in ranges.iter().enumerate() {
                let results2 = &results2;
                let params = &params;
                let sketch_matrix = &sketch_matrix;
                scope.spawn(move || {
                    let r = phase2_shard(backend, ds, params, sketch_matrix, range);
                    results2.lock().unwrap()[i] = Some(r);
                });
            }
        });
    }
    let mut scorer: Option<AgreementScorer> = None;
    let mut p2_batches = 0u64;
    for r in results2.into_iter() {
        let (s, b) = r.expect("shard not run")?;
        p2_batches += b;
        scorer = Some(match scorer {
            None => s,
            Some(mut acc) => {
                acc.merge(s);
                acc
            }
        });
    }
    let scores = scorer.unwrap().finalize_with(cfg.compute.as_ref());
    drop(phase2_span);
    let phase2 = PhaseStats {
        seconds: t2.elapsed().as_secs_f64(),
        batches: p2_batches,
        examples: n as u64,
    };
    crate::util::metrics::global()
        .histogram("pipeline.phase2.ns")
        .record(t2.elapsed().as_nanos() as u64);

    // --- validation consensus for GLISTER ---
    let val_consensus = if method == Method::Glister && cfg.val_fraction > 0.0 {
        let val_n = ((n as f64 * cfg.val_fraction) as usize).clamp(1, n);
        let mut rng = crate::util::rng::Pcg64::new(cfg.seed, 0x7A1);
        let val_idx = rng.sample_indices(n, val_n);
        let val = ds.subset(&val_idx);
        let mut acc = vec![0.0f64; ell];
        let b = backend.score_batch();
        let mut scratch = ProjectionScratch::new();
        for (_s, batch) in StreamBatches::new(&val, b) {
            let y = batch.one_hot();
            let (zhat, _norms, _l) = backend
                .score_fused_with(&params, &sketch_matrix, &batch.features, &y, &mut scratch)?;
            cfg.compute.accumulate_col_sums(&zhat, &mut acc);
            scratch.recycle(zhat);
        }
        let mut u: Vec<f32> = acc.iter().map(|&v| v as f32).collect();
        crate::tensor::normalize_in_place(&mut u);
        Some(u)
    } else {
        None
    };

    // --- selection rule ---
    let t3 = Instant::now();
    let select_span = crate::util::trace::span("pipeline.select");
    let inputs = SelectionInputs {
        scores: &scores,
        val_consensus,
        num_classes: ds.num_classes,
        seed: cfg.seed,
        compute: cfg.compute.as_ref(),
    };
    let (indices, weights) = select_weighted(method, &inputs, k);
    drop(select_span);
    let select_seconds = t3.elapsed().as_secs_f64();
    crate::util::metrics::global()
        .histogram("pipeline.select.ns")
        .record(t3.elapsed().as_nanos() as u64);

    Ok(SelectionOutcome {
        indices,
        weights,
        scores,
        sketch: sketch_matrix,
        sketch_bytes: merged.memory_bytes(),
        shrinks: merged.shrink_count(),
        shift_bound: merged.shift_bound(),
        phase1,
        phase2,
        select_seconds,
        warmup_seconds,
        params,
    })
}

/// Streaming Phase I with explicit backpressure: a reader thread pushes
/// `(global_start, batch)` into a bounded channel; `workers` consumers pull
/// and sketch. Returns the merged sketch (worker-order merge) and stats.
pub fn stream_sketch(
    backend: &dyn ModelBackend,
    ds: &Dataset,
    params: &[f32],
    ell: usize,
    cfg: &PipelineConfig,
) -> Result<(FdSketch, PhaseStats), String> {
    let d = backend.spec().d();
    let b = backend.score_batch();
    let t0 = Instant::now();
    let (tx, rx) = bounded::<(usize, Dataset)>(cfg.channel_capacity);

    let mut worker_sketches: Vec<Option<Result<(FdSketch, u64), String>>> =
        Vec::with_capacity(cfg.workers);
    worker_sketches.resize_with(cfg.workers.max(1), || None);

    let ws = std::sync::Mutex::new(&mut worker_sketches);
    std::thread::scope(|scope| {
        // Reader: stream batches (blocks when the channel is full).
        scope.spawn(|| {
            for item in StreamBatches::new(ds, b) {
                if tx.send(item).is_err() {
                    break;
                }
            }
            tx.close();
        });
        // Workers: pull, grad, sketch.
        for w in 0..cfg.workers.max(1) {
            let rx = rx.clone();
            let ws = &ws;
            let params = &params;
            scope.spawn(move || {
                let mut sk = FdSketch::with_backend(ell, d, cfg.compute.clone());
                let mut batches = 0u64;
                let mut failed: Option<String> = None;
                while let Some((_start, batch)) = rx.recv() {
                    let y = batch.one_hot();
                    match backend.per_example_grads(params, &batch.features, &y) {
                        Ok((g, _)) => {
                            sk.insert_batch(&g);
                            batches += 1;
                        }
                        Err(e) => {
                            failed = Some(e);
                            break;
                        }
                    }
                }
                ws.lock().unwrap()[w] = Some(match failed {
                    None => Ok((sk, batches)),
                    Some(e) => Err(e),
                });
            });
        }
    });

    drop(ws);
    let mut merged: Option<FdSketch> = None;
    let mut batches = 0u64;
    for r in worker_sketches.into_iter() {
        let (s, bt) = r.expect("worker missing")?;
        batches += bt;
        merged = Some(match merged {
            None => s,
            Some(mut acc) => {
                let mut s = s;
                acc.merge(&mut s);
                acc
            }
        });
    }
    let stats = PhaseStats {
        seconds: t0.elapsed().as_secs_f64(),
        batches,
        examples: ds.len() as u64,
    };
    Ok((merged.unwrap(), stats))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{generate, BenchmarkKind};
    use crate::grad::{MlpSpec, TrainHyper};
    use crate::runtime::ReferenceModelBackend;

    fn backend() -> ReferenceModelBackend {
        ReferenceModelBackend::new(MlpSpec::new(8, 12, 10), TrainHyper::default(), 16, 16, 8)
    }

    fn dataset(n: usize) -> Dataset {
        generate(&BenchmarkKind::Cifar10.spec(8), n, 5, 0)
    }

    #[test]
    fn selection_returns_k_indices_and_stats() {
        let ds = dataset(200);
        let cfg = PipelineConfig {
            workers: 3,
            warmup_steps: 5,
            ..Default::default()
        };
        let out = run_selection(&backend(), &ds, Method::Sage, 50, &cfg, None).unwrap();
        assert_eq!(out.indices.len(), 50);
        assert!(out.indices.iter().all(|&i| i < 200));
        assert_eq!(out.scores.entries.len(), 200);
        assert_eq!(out.phase1.examples, 200);
        assert!(out.phase1.batches >= 13); // ceil-splits across 3 shards
        assert_eq!(out.sketch.rows(), 8);
        assert!(out.sketch_bytes > 0);
    }

    #[test]
    fn deterministic_for_fixed_workers_and_seed() {
        let ds = dataset(120);
        let cfg = PipelineConfig {
            workers: 2,
            warmup_steps: 3,
            seed: 11,
            ..Default::default()
        };
        let b = backend();
        let a = run_selection(&b, &ds, Method::Sage, 30, &cfg, None).unwrap();
        let c = run_selection(&b, &ds, Method::Sage, 30, &cfg, None).unwrap();
        assert_eq!(a.indices, c.indices);
    }

    #[test]
    fn single_worker_matches_sequential_scoring() {
        // With 1 worker the pipeline is exactly the sequential algorithm;
        // with more workers only the FD merge order changes, so scores stay
        // within sketch-error of each other — here we pin the 1-worker path.
        let ds = dataset(100);
        let cfg = PipelineConfig {
            workers: 1,
            warmup_steps: 2,
            seed: 2,
            ..Default::default()
        };
        let b = backend();
        let out = run_selection(&b, &ds, Method::Sage, 25, &cfg, None).unwrap();
        // Recompute scores sequentially with the same params + sketch.
        let (scorer, _) = phase2_shard(&b, &ds, &out.params, &out.sketch, (0, 100)).unwrap();
        let seq = scorer.finalize();
        for (a, b2) in out.scores.entries.iter().zip(seq.entries.iter()) {
            assert_eq!(a.index, b2.index);
            assert!((a.alpha - b2.alpha).abs() < 1e-6);
        }
    }

    #[test]
    fn all_methods_run_through_pipeline() {
        let ds = dataset(90);
        let cfg = PipelineConfig {
            workers: 2,
            warmup_steps: 2,
            ..Default::default()
        };
        let b = backend();
        for m in [
            Method::Sage,
            Method::SageGlobal,
            Method::CbSage,
            Method::Random,
            Method::Drop,
            Method::Glister,
            Method::Craig,
            Method::GradMatch,
            Method::Graft,
            Method::GraftWarm,
        ] {
            let out = run_selection(&b, &ds, m, 20, &cfg, None).unwrap();
            assert_eq!(out.indices.len(), 20, "{m:?}");
        }
    }

    #[test]
    fn stream_sketch_covers_all_batches() {
        let ds = dataset(150);
        let b = backend();
        let mut rng = crate::util::rng::Pcg64::seeded(1);
        let params = b.spec().init_params(&mut rng);
        let cfg = PipelineConfig {
            workers: 3,
            channel_capacity: 2, // force backpressure
            ..Default::default()
        };
        let (sketch, stats) = stream_sketch(&b, &ds, &params, 8, &cfg).unwrap();
        assert_eq!(stats.batches, 150u64.div_ceil(16));
        assert_eq!(sketch.rows_seen(), 150);
    }

    #[test]
    fn shard_ranges_cover_exactly() {
        for (n, w) in [(10, 3), (1, 4), (100, 7), (16, 16)] {
            let ranges = shard_ranges(n, w);
            let mut covered = vec![false; n];
            for (a, b) in ranges {
                for i in a..b {
                    assert!(!covered[i]);
                    covered[i] = true;
                }
            }
            assert!(covered.iter().all(|&c| c), "n={n} w={w}");
        }
    }
}
