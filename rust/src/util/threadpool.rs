//! Minimal work-stealing-free thread pool + structured parallel map
//! (from scratch — no rayon offline).
//!
//! [`ThreadPool`] feeds boxed jobs through the bounded channel (so job
//! submission itself backpressures), and [`parallel_map_chunks`] gives the
//! common "split a big slice across cores" pattern on std scoped threads
//! with zero allocation of intermediate Vecs beyond the output.

use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

use super::channel::{bounded, Sender};

type Job = Box<dyn FnOnce() + Send + 'static>;

/// Error returned by [`ThreadPool::execute`] when the worker threads are
/// gone (pool shut down, or every worker died). Callers — the service's
/// connection acceptor in particular — reject the work gracefully instead
/// of crashing the submitting thread.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct PoolClosed;

impl std::fmt::Display for PoolClosed {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "thread pool is shut down (worker threads gone)")
    }
}

impl std::error::Error for PoolClosed {}

/// Error returned by [`ThreadPool::try_execute`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SubmitError {
    /// Worker threads gone (pool shut down or every worker died).
    Closed,
    /// Job queue full — all workers busy and the backlog is at capacity.
    Busy,
}

impl std::fmt::Display for SubmitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SubmitError::Closed => write!(f, "thread pool is shut down (worker threads gone)"),
            SubmitError::Busy => write!(f, "thread pool is at capacity (queue full)"),
        }
    }
}

impl std::error::Error for SubmitError {}

/// Decrements the pool's pending-job count on drop (normal completion AND
/// panic unwind take the same path).
struct PendingGuard<'a>(&'a (Mutex<usize>, Condvar));

impl Drop for PendingGuard<'_> {
    fn drop(&mut self) {
        let (lock, cv) = self.0;
        let mut n = lock.lock().unwrap();
        *n -= 1;
        if *n == 0 {
            cv.notify_all();
        }
    }
}

/// Fixed-size pool. Jobs run FIFO; `wait_idle` blocks until all submitted
/// jobs completed (the pipeline's phase barrier).
pub struct ThreadPool {
    tx: Option<Sender<Job>>,
    workers: Vec<JoinHandle<()>>,
    pending: Arc<(Mutex<usize>, Condvar)>,
}

impl ThreadPool {
    pub fn new(threads: usize) -> Self {
        assert!(threads > 0);
        let (tx, rx) = bounded::<Job>(threads * 4);
        let pending = Arc::new((Mutex::new(0usize), Condvar::new()));
        let mut workers = Vec::with_capacity(threads);
        for _ in 0..threads {
            let rx = rx.clone();
            let pending = pending.clone();
            workers.push(std::thread::spawn(move || {
                while let Some(job) = rx.recv() {
                    // Guard so a panicking job still decrements the pending
                    // count during unwind — wait_idle must not deadlock on
                    // jobs that will never report completion.
                    let _done = PendingGuard(&*pending);
                    job();
                }
            }));
        }
        Self {
            tx: Some(tx),
            workers,
            pending,
        }
    }

    /// Submit a job (blocks if the queue is full — backpressure).
    ///
    /// Returns `Err(PoolClosed)` instead of panicking when the worker
    /// threads are gone (e.g. every worker died, or the pool was shut
    /// down), so submitters can degrade gracefully.
    pub fn execute<F: FnOnce() + Send + 'static>(&self, f: F) -> Result<(), PoolClosed> {
        {
            let (lock, _) = &*self.pending;
            *lock.lock().unwrap() += 1;
        }
        let sent = match self.tx.as_ref() {
            Some(tx) => tx.send(Box::new(f) as Job).is_ok(),
            None => false,
        };
        if sent {
            Ok(())
        } else {
            // The job never reached a worker: roll back the pending count so
            // wait_idle does not hang forever on a job that will never run.
            let (lock, cv) = &*self.pending;
            let mut n = lock.lock().unwrap();
            *n -= 1;
            if *n == 0 {
                cv.notify_all();
            }
            Err(PoolClosed)
        }
    }

    /// Non-blocking submit: `Err(Busy)` when the queue is full instead of
    /// blocking the caller. For submitters that must never stall — the
    /// service's accept loop uses this so a saturated pool rejects new
    /// connections instead of wedging accept (and shutdown) behind
    /// long-lived connection jobs.
    pub fn try_execute<F: FnOnce() + Send + 'static>(&self, f: F) -> Result<(), SubmitError> {
        {
            let (lock, _) = &*self.pending;
            *lock.lock().unwrap() += 1;
        }
        // The channel's try_send cannot distinguish a full queue from a
        // closed one (all workers dead), so a dead pool also surfaces as
        // Busy here; callers reject the work either way.
        let outcome = match self.tx.as_ref() {
            Some(tx) => tx.try_send(Box::new(f) as Job).map_err(|_| SubmitError::Busy),
            None => Err(SubmitError::Closed),
        };
        if outcome.is_err() {
            let (lock, cv) = &*self.pending;
            let mut n = lock.lock().unwrap();
            *n -= 1;
            if *n == 0 {
                cv.notify_all();
            }
        }
        outcome
    }

    /// Run `f(0)..f(chunks-1)` cooperatively across the pool and the
    /// calling thread, returning once every chunk has completed. This is
    /// the scoped fork/join primitive under `tensor::ParallelBackend`: the
    /// chunk *grid* is fixed by the caller (it must depend only on the
    /// problem shape), while which thread executes which chunk is dynamic —
    /// safe for bitwise determinism as long as each chunk's output is
    /// independent of the others.
    ///
    /// Scheduling is work-stealing-free: chunk indices are popped from a
    /// shared counter. Helper jobs are submitted with [`try_execute`]
    /// (never blocking), and the caller participates, so a saturated or
    /// shut-down pool degrades to inline serial execution instead of
    /// deadlocking — including when `run_chunks` is called from inside a
    /// pool job.
    ///
    /// Panics in `f` are propagated to the caller after all in-flight
    /// chunks finish (a panicking chunk also kills the worker thread that
    /// ran it, matching `execute`'s contract for panicking jobs).
    ///
    /// [`try_execute`]: ThreadPool::try_execute
    pub fn run_chunks(&self, chunks: usize, f: &(dyn Fn(usize) + Sync)) {
        if chunks == 0 {
            return;
        }
        if chunks == 1 || self.threads() <= 1 {
            for i in 0..chunks {
                f(i);
            }
            return;
        }
        let region = Arc::new(ChunkRegion {
            next: AtomicUsize::new(0),
            total: chunks,
            state: Mutex::new(RegionState {
                in_flight: 0,
                done: 0,
                cancelled: false,
            }),
            cv: Condvar::new(),
            poisoned: AtomicBool::new(false),
        });
        // SAFETY of the lifetime erasure below: `f` is dereferenced only
        // between an `in_flight` increment and the matching decrement (both
        // under the region mutex, decrement on the unwind path too), and
        // `RegionWait` pins this frame — on return AND on unwind — until
        // either every chunk completed (`done == total`) or, when
        // unwinding, `cancelled` is set under the mutex and `in_flight`
        // drained; a straggler job observing `cancelled` or an exhausted
        // index exits without ever touching the pointer.
        let fp = RawChunkFn(f as *const (dyn Fn(usize) + Sync));
        let helpers = self.threads().min(chunks - 1);
        for _ in 0..helpers {
            let region = region.clone();
            if self.try_execute(move || region.work(fp)).is_err() {
                break; // pool saturated/closed: remaining chunks run here
            }
        }
        let wait = RegionWait { region: &region };
        region.work(fp);
        drop(wait); // blocks until the region is quiescent
        if region.poisoned.load(Ordering::Relaxed) {
            panic!("ThreadPool::run_chunks: a parallel chunk panicked");
        }
    }

    /// Block until every submitted job has finished.
    pub fn wait_idle(&self) {
        let (lock, cv) = &*self.pending;
        let mut n = lock.lock().unwrap();
        while *n > 0 {
            n = cv.wait(n).unwrap();
        }
    }

    pub fn threads(&self) -> usize {
        self.workers.len()
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        drop(self.tx.take());
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// Type-erased pointer to the chunk closure of one [`ThreadPool::run_chunks`]
/// region. Only dereferenced under the region's liveness protocol (see the
/// SAFETY comment in `run_chunks`).
#[derive(Clone, Copy)]
struct RawChunkFn(*const (dyn Fn(usize) + Sync));

// SAFETY: the pointee is `Sync` (shared calls from many threads are fine)
// and the region protocol guarantees it outlives every dereference.
unsafe impl Send for RawChunkFn {}
unsafe impl Sync for RawChunkFn {}

struct RegionState {
    /// Chunks popped but not yet finished (bounds the waiter on unwind).
    in_flight: usize,
    /// Chunks finished (executed or unwound).
    done: usize,
    /// Set by an unwinding waiter: stop popping new chunks.
    cancelled: bool,
}

/// Shared state of one `run_chunks` region.
struct ChunkRegion {
    next: AtomicUsize,
    total: usize,
    state: Mutex<RegionState>,
    cv: Condvar,
    poisoned: AtomicBool,
}

impl ChunkRegion {
    /// Pop-and-execute until the grid is exhausted (or cancelled).
    fn work(&self, f: RawChunkFn) {
        loop {
            {
                let mut s = self.state.lock().unwrap();
                if s.cancelled {
                    return;
                }
                s.in_flight += 1;
            }
            let i = self.next.fetch_add(1, Ordering::Relaxed);
            if i >= self.total {
                let mut s = self.state.lock().unwrap();
                s.in_flight -= 1;
                self.cv.notify_all();
                return;
            }
            // Guard fires on unwind too, so the waiter never hangs on a
            // panicked chunk.
            let _done = ChunkDoneGuard { region: self };
            // SAFETY: in_flight > 0 for this thread and i < total, so the
            // waiter is still pinned inside `run_chunks` (see SAFETY there).
            let f = unsafe { &*f.0 };
            f(i);
        }
    }
}

struct ChunkDoneGuard<'a> {
    region: &'a ChunkRegion,
}

impl Drop for ChunkDoneGuard<'_> {
    fn drop(&mut self) {
        if std::thread::panicking() {
            self.region.poisoned.store(true, Ordering::Relaxed);
        }
        let mut s = self.region.state.lock().unwrap();
        s.in_flight -= 1;
        s.done += 1;
        self.region.cv.notify_all();
    }
}

/// Pins a `run_chunks` frame until its region is quiescent: all chunks done
/// on the normal path, or (on unwind) the region cancelled and every
/// in-flight chunk finished.
struct RegionWait<'a> {
    region: &'a ChunkRegion,
}

impl Drop for RegionWait<'_> {
    fn drop(&mut self) {
        let region = self.region;
        let mut s = region.state.lock().unwrap();
        if std::thread::panicking() {
            s.cancelled = true;
            while s.in_flight > 0 {
                s = region.cv.wait(s).unwrap();
            }
        } else {
            while s.done < region.total {
                s = region.cv.wait(s).unwrap();
            }
        }
    }
}

/// Reasonable default parallelism for this host.
pub fn default_threads() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(4)
        .min(16)
}

/// Parallel map over chunks of `items`: `f(chunk_start_index, chunk)` for
/// each contiguous chunk, results concatenated in order.
pub fn parallel_map_chunks<T: Sync, R: Send>(
    items: &[T],
    threads: usize,
    f: impl Fn(usize, &[T]) -> Vec<R> + Sync,
) -> Vec<R> {
    let threads = threads.max(1).min(items.len().max(1));
    if threads <= 1 || items.len() < 2 {
        return f(0, items);
    }
    let chunk = items.len().div_ceil(threads);
    let next = AtomicUsize::new(0);
    let mut parts: Vec<Option<Vec<R>>> = Vec::new();
    parts.resize_with(threads, || None);
    let parts_mutex = Mutex::new(&mut parts);
    std::thread::scope(|scope| {
        for _ in 0..threads {
            scope.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                let start = i * chunk;
                if start >= items.len() {
                    break;
                }
                let end = (start + chunk).min(items.len());
                let out = f(start, &items[start..end]);
                parts_mutex.lock().unwrap()[i] = Some(out);
            });
        }
    });
    parts.into_iter().flatten().flatten().collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn pool_runs_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..100 {
            let c = counter.clone();
            pool.execute(move || {
                c.fetch_add(1, Ordering::Relaxed);
            })
            .unwrap();
        }
        pool.wait_idle();
        assert_eq!(counter.load(Ordering::Relaxed), 100);
    }

    #[test]
    fn try_execute_rejects_when_queue_full() {
        // 1 worker blocked on a gate + fill the 4-deep queue: the next
        // try_execute must return Busy immediately instead of blocking.
        let pool = ThreadPool::new(1);
        let gate = Arc::new((Mutex::new(false), std::sync::Condvar::new()));
        let g = gate.clone();
        pool.execute(move || {
            let (lock, cv) = &*g;
            let mut open = lock.lock().unwrap();
            while !*open {
                open = cv.wait(open).unwrap();
            }
        })
        .unwrap();
        let mut busy = false;
        for _ in 0..64 {
            if pool.try_execute(|| {}) == Err(SubmitError::Busy) {
                busy = true;
                break;
            }
        }
        assert!(busy, "queue should fill and reject");
        // Open the gate so drop can drain the queue and join.
        let (lock, cv) = &*gate;
        *lock.lock().unwrap() = true;
        cv.notify_all();
        pool.wait_idle();
    }

    #[test]
    fn execute_rejects_gracefully_when_workers_gone() {
        // Kill the only worker via a panicking job; subsequent submissions
        // must return Err(PoolClosed) instead of panicking the caller.
        let pool = ThreadPool::new(1);
        let _ = pool.execute(|| panic!("worker down"));
        let mut rejected = false;
        for _ in 0..200 {
            if pool.execute(|| {}).is_err() {
                rejected = true;
                break;
            }
            std::thread::sleep(std::time::Duration::from_millis(5));
        }
        assert!(rejected, "execute should fail once the worker is gone");
    }

    #[test]
    fn wait_idle_on_empty_pool_returns() {
        let pool = ThreadPool::new(2);
        pool.wait_idle();
    }

    #[test]
    fn drop_joins_workers() {
        let counter = Arc::new(AtomicU64::new(0));
        {
            let pool = ThreadPool::new(2);
            for _ in 0..10 {
                let c = counter.clone();
                pool.execute(move || {
                    c.fetch_add(1, Ordering::Relaxed);
                })
                .unwrap();
            }
        } // drop waits for queue drain via channel close + join
        assert_eq!(counter.load(Ordering::Relaxed), 10);
    }

    #[test]
    fn run_chunks_executes_every_chunk_exactly_once() {
        let pool = ThreadPool::new(4);
        for chunks in [1usize, 2, 3, 7, 64, 129] {
            let hits: Vec<AtomicU64> = (0..chunks).map(|_| AtomicU64::new(0)).collect();
            pool.run_chunks(chunks, &|i| {
                hits[i].fetch_add(1, Ordering::Relaxed);
            });
            for (i, h) in hits.iter().enumerate() {
                assert_eq!(h.load(Ordering::Relaxed), 1, "chunk {i} of {chunks}");
            }
        }
    }

    #[test]
    fn run_chunks_on_saturated_pool_degrades_to_caller() {
        // Block every worker behind a gate: helper jobs stay queued, the
        // caller runs every chunk itself and returns. The queued helpers
        // then fire as stragglers after the region is gone — they must pop
        // an exhausted grid and exit without touching anything.
        let pool = ThreadPool::new(2);
        let gate = Arc::new((Mutex::new(false), Condvar::new()));
        for _ in 0..2 {
            let g = gate.clone();
            pool.execute(move || {
                let (lock, cv) = &*g;
                let mut open = lock.lock().unwrap();
                while !*open {
                    open = cv.wait(open).unwrap();
                }
            })
            .unwrap();
        }
        let counter = AtomicU64::new(0);
        pool.run_chunks(32, &|_| {
            counter.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(counter.load(Ordering::Relaxed), 32);
        // Release the workers; the straggler helper jobs must drain cleanly.
        let (lock, cv) = &*gate;
        *lock.lock().unwrap() = true;
        cv.notify_all();
        pool.wait_idle();
        assert_eq!(counter.load(Ordering::Relaxed), 32, "stragglers re-ran chunks");
    }

    #[test]
    fn run_chunks_propagates_chunk_panic() {
        let pool = ThreadPool::new(2);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            pool.run_chunks(8, &|i| {
                if i == 5 {
                    panic!("boom");
                }
            });
        }));
        assert!(result.is_err(), "chunk panic must reach the caller");
    }

    #[test]
    fn parallel_map_chunks_preserves_order() {
        let items: Vec<u64> = (0..1000).collect();
        let out = parallel_map_chunks(&items, 7, |_start, chunk| {
            chunk.iter().map(|x| x * 2).collect()
        });
        assert_eq!(out, (0..1000).map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn parallel_map_chunks_passes_offsets() {
        let items: Vec<u64> = vec![0; 100];
        let out = parallel_map_chunks(&items, 3, |start, chunk| {
            (start..start + chunk.len()).collect()
        });
        assert_eq!(out, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn parallel_map_single_thread_and_empty() {
        let out = parallel_map_chunks(&[1, 2, 3], 1, |_s, c| c.to_vec());
        assert_eq!(out, vec![1, 2, 3]);
        let empty: Vec<i32> = parallel_map_chunks(&[], 4, |_s, c: &[i32]| c.to_vec());
        assert!(empty.is_empty());
    }
}
