//! Deterministic pseudo-random number generation (from scratch — the offline
//! build has no `rand` crate).
//!
//! [`Pcg64`] implements PCG-XSL-RR 128/64 (O'Neill 2014): a small, fast,
//! statistically strong generator with a jumpable stream parameter, which the
//! pipeline uses to give every shard worker an independent stream from one
//! experiment seed. [`SplitMix64`] seeds it (and is handy for hashing).
//!
//! Everything in the repo that consumes randomness (dataset synthesis,
//! selection baselines, property tests) goes through this module, so every
//! experiment is reproducible from a single `u64` seed recorded in the
//! report.

/// SplitMix64 — used for seeding and cheap stateless mixing.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

/// One-shot stateless mix — stable hashing of (seed, index) pairs.
#[inline]
pub fn mix64(x: u64) -> u64 {
    SplitMix64::new(x).next_u64()
}

/// PCG-XSL-RR 128/64.
#[derive(Clone, Debug)]
pub struct Pcg64 {
    state: u128,
    inc: u128,
}

const PCG_MULT: u128 = 0x2360_ED05_1FC6_5DA4_4385_DF64_9FCC_F645;

impl Pcg64 {
    /// Construct from a seed; `stream` selects an independent sequence
    /// (used to decorrelate shard workers deterministically).
    pub fn new(seed: u64, stream: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        let s0 = sm.next_u64() as u128;
        let s1 = sm.next_u64() as u128;
        let mut sm2 = SplitMix64::new(stream ^ 0xDA3E_39CB_94B9_5BDB);
        let i0 = sm2.next_u64() as u128;
        let i1 = sm2.next_u64() as u128;
        let mut rng = Self {
            state: (s0 << 64) | s1,
            inc: ((i0 << 64) | i1) | 1, // must be odd
        };
        rng.next_u64();
        rng
    }

    pub fn seeded(seed: u64) -> Self {
        Self::new(seed, 0)
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self
            .state
            .wrapping_mul(PCG_MULT)
            .wrapping_add(self.inc);
        // XSL-RR output function.
        let xored = ((self.state >> 64) as u64) ^ (self.state as u64);
        let rot = (self.state >> 122) as u32;
        xored.rotate_right(rot)
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        // 53 random mantissa bits.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        self.next_f64() as f32
    }

    /// Uniform integer in [0, n) without modulo bias (Lemire rejection).
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0, "below(0)");
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut lo = m as u64;
        if lo < n {
            let t = n.wrapping_neg() % n;
            while lo < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                lo = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Standard normal via Box–Muller (cached second value).
    pub fn normal(&mut self) -> f64 {
        // Marsaglia polar method: numerically tame, no trig.
        loop {
            let u = 2.0 * self.next_f64() - 1.0;
            let v = 2.0 * self.next_f64() - 1.0;
            let s = u * u + v * v;
            if s > 0.0 && s < 1.0 {
                return u * (-2.0 * s.ln() / s).sqrt();
            }
        }
    }

    #[inline]
    pub fn normal_f32(&mut self) -> f32 {
        self.normal() as f32
    }

    /// Fill a slice with iid N(0, sigma^2) samples.
    pub fn fill_normal(&mut self, out: &mut [f32], sigma: f32) {
        for v in out.iter_mut() {
            *v = self.normal_f32() * sigma;
        }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below((i + 1) as u64) as usize;
            xs.swap(i, j);
        }
    }

    /// k distinct indices from [0, n) (partial Fisher–Yates).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n, "sample_indices k > n");
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = i + self.below((n - i) as u64) as usize;
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }

    /// Sample from a Zipf(s) distribution over ranks 1..=n — used for the
    /// Caltech-256-style long-tail class priors.
    pub fn zipf_weights(n: usize, s: f64) -> Vec<f64> {
        let mut w: Vec<f64> = (1..=n).map(|r| 1.0 / (r as f64).powf(s)).collect();
        let total: f64 = w.iter().sum();
        for v in w.iter_mut() {
            *v /= total;
        }
        w
    }

    /// Categorical draw from (unnormalized) non-negative weights.
    pub fn categorical(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        let mut t = self.next_f64() * total;
        for (i, w) in weights.iter().enumerate() {
            t -= w;
            if t <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Pcg64::seeded(42);
        let mut b = Pcg64::seeded(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn streams_are_decorrelated() {
        let mut a = Pcg64::new(42, 0);
        let mut b = Pcg64::new(42, 1);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn uniform_moments() {
        let mut rng = Pcg64::seeded(7);
        let n = 20_000;
        let mut sum = 0.0;
        let mut sumsq = 0.0;
        for _ in 0..n {
            let x = rng.next_f64();
            assert!((0.0..1.0).contains(&x));
            sum += x;
            sumsq += x * x;
        }
        let mean = sum / n as f64;
        let var = sumsq / n as f64 - mean * mean;
        assert!((mean - 0.5).abs() < 0.01, "mean {mean}");
        assert!((var - 1.0 / 12.0).abs() < 0.005, "var {var}");
    }

    #[test]
    fn normal_moments() {
        let mut rng = Pcg64::seeded(9);
        let n = 20_000;
        let (mut sum, mut sumsq, mut sum3) = (0.0, 0.0, 0.0);
        for _ in 0..n {
            let x = rng.normal();
            sum += x;
            sumsq += x * x;
            sum3 += x * x * x;
        }
        let mean = sum / n as f64;
        let var = sumsq / n as f64 - mean * mean;
        let skew = sum3 / n as f64;
        assert!(mean.abs() < 0.03, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
        assert!(skew.abs() < 0.1, "skew {skew}");
    }

    #[test]
    fn below_is_unbiased_and_in_range() {
        let mut rng = Pcg64::seeded(11);
        let n = 7u64;
        let mut counts = [0usize; 7];
        for _ in 0..70_000 {
            counts[rng.below(n) as usize] += 1;
        }
        for c in counts {
            assert!((c as f64 - 10_000.0).abs() < 500.0, "count {c}");
        }
    }

    #[test]
    fn sample_indices_distinct() {
        let mut rng = Pcg64::seeded(13);
        let idx = rng.sample_indices(100, 40);
        let mut sorted = idx.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), 40);
        assert!(sorted.iter().all(|&i| i < 100));
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Pcg64::seeded(17);
        let mut xs: Vec<usize> = (0..50).collect();
        rng.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn zipf_weights_normalized_and_decreasing() {
        let w = Pcg64::zipf_weights(10, 1.2);
        assert!((w.iter().sum::<f64>() - 1.0).abs() < 1e-12);
        for i in 1..w.len() {
            assert!(w[i] < w[i - 1]);
        }
    }

    #[test]
    fn categorical_respects_weights() {
        let mut rng = Pcg64::seeded(19);
        let w = [1.0, 0.0, 3.0];
        let mut counts = [0usize; 3];
        for _ in 0..40_000 {
            counts[rng.categorical(&w)] += 1;
        }
        assert_eq!(counts[1], 0);
        let ratio = counts[2] as f64 / counts[0] as f64;
        assert!((ratio - 3.0).abs() < 0.3, "ratio {ratio}");
    }
}

/// Walker's alias method: O(1) weighted sampling after O(n) setup — used
/// by the trainer for CRAIG-style weighted subset sampling.
#[derive(Clone, Debug)]
pub struct AliasSampler {
    prob: Vec<f64>,
    alias: Vec<usize>,
}

impl AliasSampler {
    /// Build from non-negative weights (not all zero).
    pub fn new(weights: &[f64]) -> Result<AliasSampler, String> {
        let n = weights.len();
        if n == 0 {
            return Err("alias sampler: empty weights".into());
        }
        if weights.iter().any(|&w| w < 0.0 || !w.is_finite()) {
            return Err("alias sampler: negative or non-finite weight".into());
        }
        let total: f64 = weights.iter().sum();
        if total <= 0.0 {
            return Err("alias sampler: all-zero weights".into());
        }
        let mut prob: Vec<f64> = weights.iter().map(|&w| w * n as f64 / total).collect();
        let mut alias = vec![0usize; n];
        let mut small: Vec<usize> = Vec::new();
        let mut large: Vec<usize> = Vec::new();
        for (i, &p) in prob.iter().enumerate() {
            if p < 1.0 {
                small.push(i);
            } else {
                large.push(i);
            }
        }
        while let (Some(s), Some(l)) = (small.pop(), large.pop()) {
            alias[s] = l;
            prob[l] = (prob[l] + prob[s]) - 1.0;
            if prob[l] < 1.0 {
                small.push(l);
            } else {
                large.push(l);
            }
        }
        // Leftovers are numerically 1.0.
        for i in small.into_iter().chain(large) {
            prob[i] = 1.0;
        }
        Ok(AliasSampler { prob, alias })
    }

    /// Draw one index.
    pub fn sample(&self, rng: &mut Pcg64) -> usize {
        let n = self.prob.len();
        let i = rng.below(n as u64) as usize;
        if rng.next_f64() < self.prob[i] {
            i
        } else {
            self.alias[i]
        }
    }

    pub fn len(&self) -> usize {
        self.prob.len()
    }

    pub fn is_empty(&self) -> bool {
        self.prob.is_empty()
    }
}

#[cfg(test)]
mod alias_tests {
    use super::*;

    #[test]
    fn matches_weights_statistically() {
        let weights = [1.0, 2.0, 4.0, 0.0, 1.0];
        let sampler = AliasSampler::new(&weights).unwrap();
        let mut rng = Pcg64::seeded(21);
        let mut counts = [0usize; 5];
        let n = 80_000;
        for _ in 0..n {
            counts[sampler.sample(&mut rng)] += 1;
        }
        assert_eq!(counts[3], 0);
        let total: f64 = weights.iter().sum();
        for (i, &w) in weights.iter().enumerate() {
            let expect = n as f64 * w / total;
            let got = counts[i] as f64;
            assert!(
                (got - expect).abs() < 0.05 * n as f64 / 5.0 + 3.0 * expect.sqrt(),
                "idx {i}: {got} vs {expect}"
            );
        }
    }

    #[test]
    fn uniform_weights_uniform_draws() {
        let sampler = AliasSampler::new(&[1.0; 7]).unwrap();
        let mut rng = Pcg64::seeded(22);
        let mut counts = [0usize; 7];
        for _ in 0..70_000 {
            counts[sampler.sample(&mut rng)] += 1;
        }
        for c in counts {
            assert!((c as i64 - 10_000).abs() < 600, "{c}");
        }
    }

    #[test]
    fn rejects_bad_inputs() {
        assert!(AliasSampler::new(&[]).is_err());
        assert!(AliasSampler::new(&[0.0, 0.0]).is_err());
        assert!(AliasSampler::new(&[1.0, -1.0]).is_err());
        assert!(AliasSampler::new(&[1.0, f64::NAN]).is_err());
    }

    #[test]
    fn single_element() {
        let s = AliasSampler::new(&[3.0]).unwrap();
        let mut rng = Pcg64::seeded(23);
        assert_eq!(s.sample(&mut rng), 0);
        assert_eq!(s.len(), 1);
    }
}
