//! Minimal JSON parser + writer (from scratch — no serde offline).
//!
//! Scope: everything `artifacts/manifest.json` and the report files need —
//! objects, arrays, strings with escapes, numbers, bools, null. Parsing is
//! recursive descent over bytes; numbers are kept as f64 (manifest values
//! are small integers and floats, well within f64's exact-integer range).

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A parsed JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn idx(&self, i: usize) -> Option<&Json> {
        match self {
            Json::Arr(v) => v.get(i),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// `[1,2]` -> `vec![1, 2]` — shape lists in the manifest.
    pub fn as_usize_vec(&self) -> Option<Vec<usize>> {
        self.as_arr()?
            .iter()
            .map(|j| j.as_usize())
            .collect::<Option<Vec<_>>>()
    }
}

/// Parse a JSON document.
pub fn parse(text: &str) -> Result<Json, String> {
    let bytes = text.as_bytes();
    let mut p = Parser { bytes, pos: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != bytes.len() {
        return Err(format!("trailing garbage at byte {}", p.pos));
    }
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.pos += 1;
        }
        b
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), String> {
        if self.bump() == Some(b) {
            Ok(())
        } else {
            Err(format!("expected '{}' at byte {}", b as char, self.pos))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => Err(format!("unexpected {:?} at byte {}", other.map(|c| c as char), self.pos)),
        }
    }

    fn literal(&mut self, word: &str, val: Json) -> Result<Json, String> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(val)
        } else {
            Err(format!("bad literal at byte {}", self.pos))
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(map)),
                _ => return Err(format!("expected ',' or '}}' at byte {}", self.pos)),
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(items)),
                _ => return Err(format!("expected ',' or ']' at byte {}", self.pos)),
            }
        }
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump() {
                None => return Err("unterminated string".into()),
                Some(b'"') => return Ok(out),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'n') => out.push('\n'),
                    Some(b't') => out.push('\t'),
                    Some(b'r') => out.push('\r'),
                    Some(b'b') => out.push('\u{0008}'),
                    Some(b'f') => out.push('\u{000C}'),
                    Some(b'u') => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let c = self.bump().ok_or("bad \\u escape")? as char;
                            code = code * 16
                                + c.to_digit(16).ok_or("bad hex in \\u escape")?;
                        }
                        out.push(char::from_u32(code).unwrap_or('\u{FFFD}'));
                    }
                    other => return Err(format!("bad escape {other:?}")),
                },
                Some(c) if c < 0x80 => out.push(c as char),
                Some(c) => {
                    // Re-decode multi-byte UTF-8: back up and take the char.
                    let start = self.pos - 1;
                    let s = std::str::from_utf8(&self.bytes[start..])
                        .map_err(|_| "invalid utf-8".to_string())?;
                    let ch = s.chars().next().unwrap();
                    self.pos = start + ch.len_utf8();
                    let _ = c;
                    out.push(ch);
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let s = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|e| format!("bad number '{s}': {e}"))
    }
}

/// Serialize (stable key order via BTreeMap; used by the report writers).
pub fn write(value: &Json) -> String {
    let mut out = String::new();
    write_into(value, &mut out);
    out
}

fn write_into(value: &Json, out: &mut String) {
    match value {
        Json::Null => out.push_str("null"),
        Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
        Json::Num(n) => {
            if n.fract() == 0.0 && n.abs() < 9e15 {
                let _ = write!(out, "{}", *n as i64);
            } else {
                let _ = write!(out, "{n}");
            }
        }
        Json::Str(s) => {
            out.push('"');
            for c in s.chars() {
                match c {
                    '"' => out.push_str("\\\""),
                    '\\' => out.push_str("\\\\"),
                    '\n' => out.push_str("\\n"),
                    '\t' => out.push_str("\\t"),
                    '\r' => out.push_str("\\r"),
                    c if (c as u32) < 0x20 => {
                        let _ = write!(out, "\\u{:04x}", c as u32);
                    }
                    c => out.push(c),
                }
            }
            out.push('"');
        }
        Json::Arr(items) => {
            out.push('[');
            for (i, v) in items.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_into(v, out);
            }
            out.push(']');
        }
        Json::Obj(map) => {
            out.push('{');
            for (i, (k, v)) in map.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_into(&Json::Str(k.clone()), out);
                out.push(':');
                write_into(v, out);
            }
            out.push('}');
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_manifest_like_document() {
        let doc = r#"{
          "version": 1,
          "configs": {
            "tiny": {"d": 676, "artifacts": {"grads": {"file": "g.hlo.txt",
              "inputs": [[676], [8, 16], [8, 4]], "outputs": [[8, 676], [8]]}}}
          }
        }"#;
        let j = parse(doc).unwrap();
        assert_eq!(j.get("version").unwrap().as_usize(), Some(1));
        let tiny = j.get("configs").unwrap().get("tiny").unwrap();
        assert_eq!(tiny.get("d").unwrap().as_usize(), Some(676));
        let grads = tiny.get("artifacts").unwrap().get("grads").unwrap();
        assert_eq!(
            grads.get("inputs").unwrap().idx(1).unwrap().as_usize_vec(),
            Some(vec![8, 16])
        );
    }

    #[test]
    fn round_trip() {
        let doc = r#"{"a":[1,2.5,-3e2],"b":"x\"y\n","c":true,"d":null,"e":{}}"#;
        let j = parse(doc).unwrap();
        let s = write(&j);
        assert_eq!(parse(&s).unwrap(), j);
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("{\"a\" 1}").is_err());
        assert!(parse("12 34").is_err());
        assert!(parse("\"unterminated").is_err());
        assert!(parse("nul").is_err());
    }

    #[test]
    fn unicode_and_escapes() {
        let j = parse(r#""café — ünïcode""#).unwrap();
        assert_eq!(j.as_str(), Some("café — ünïcode"));
    }

    #[test]
    fn numbers() {
        assert_eq!(parse("-0.5").unwrap().as_f64(), Some(-0.5));
        assert_eq!(parse("1e3").unwrap().as_f64(), Some(1000.0));
        assert_eq!(parse("42").unwrap().as_usize(), Some(42));
    }

    #[test]
    fn integers_written_without_decimal_point() {
        assert_eq!(write(&Json::Num(3.0)), "3");
        assert_eq!(write(&Json::Num(3.5)), "3.5");
    }
}
