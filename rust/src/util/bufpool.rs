//! Thread-striped recycling pool for the serve hot path's byte buffers.
//!
//! Every frame the service touches — decoded request payloads, encoded
//! response payloads, fully framed wire bytes, push deltas — is a plain
//! `Vec<u8>`. Before this pool, each one was allocated fresh and dropped
//! after a single use; at service rates that is two allocator round trips
//! per frame on the hottest path in the process. [`BufferPool`] keeps
//! retired buffers on per-thread stripes so a steady-state request reuses
//! capacity instead of allocating.
//!
//! Design rules:
//!
//! - **Striped, not global.** [`STRIPES`] independent free lists, each
//!   behind its own mutex; threads are assigned a home stripe round-robin.
//!   `put` targets the home stripe, so the common same-thread
//!   encode→write→recycle cycle never contends.
//! - **Cross-thread flows still hit.** The reactor's pool workers `take`
//!   buffers that the event-loop thread `put` back (and vice versa), so
//!   `take` scans *all* stripes starting from the caller's, using
//!   `try_lock` — a contended stripe is skipped, never waited on.
//! - **The pool bounds memory, it does not grow it.** At most
//!   [`PER_STRIPE`] buffers per stripe are kept, and any buffer whose
//!   capacity exceeds [`MAX_POOLED_CAPACITY`] is dropped on `put` (one
//!   giant IngestBatch must not turn the pool into a balloon). Overflow
//!   and oversize buffers fall back to the allocator's `drop`.
//!
//! Observability: `sage.bufpool.hits` / `sage.bufpool.misses` count
//! `take` outcomes (a miss is a fresh allocation) and
//! `sage.bufpool.dropped_oversize` counts buffers refused at `put` for
//! capacity; see docs/OBSERVABILITY.md.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Mutex, OnceLock};

use super::metrics::{global as metrics, Counter};

/// Independent free lists; power of two, sized for "a few event loops
/// plus a worker pool" worth of threads.
const STRIPES: usize = 8;

/// Buffers parked per stripe before `put` starts dropping.
const PER_STRIPE: usize = 64;

/// Buffers with more capacity than this are never pooled: recycling is
/// for steady-state frames, not for the occasional 256 MiB ingest batch.
pub const MAX_POOLED_CAPACITY: usize = 1 << 20;

struct PoolCounters {
    hits: &'static Counter,
    misses: &'static Counter,
    dropped_oversize: &'static Counter,
}

fn pool_counters() -> &'static PoolCounters {
    static COUNTERS: OnceLock<PoolCounters> = OnceLock::new();
    COUNTERS.get_or_init(|| PoolCounters {
        hits: metrics().counter("sage.bufpool.hits"),
        misses: metrics().counter("sage.bufpool.misses"),
        dropped_oversize: metrics().counter("sage.bufpool.dropped_oversize"),
    })
}

/// The caller's home stripe: assigned round-robin on first use so threads
/// spread across stripes without any registration step.
fn stripe_index() -> usize {
    use std::cell::Cell;
    thread_local! {
        static STRIPE: Cell<usize> = const { Cell::new(usize::MAX) };
    }
    STRIPE.with(|s| {
        let mut v = s.get();
        if v == usize::MAX {
            static NEXT: AtomicUsize = AtomicUsize::new(0);
            v = NEXT.fetch_add(1, Ordering::Relaxed) % STRIPES;
            s.set(v);
        }
        v
    })
}

/// See the module docs. Most callers use the process-wide [`global`]
/// pool; constructing a private pool is only interesting in tests.
pub struct BufferPool {
    stripes: Vec<Mutex<Vec<Vec<u8>>>>,
}

impl BufferPool {
    pub fn new() -> BufferPool {
        BufferPool {
            stripes: (0..STRIPES).map(|_| Mutex::new(Vec::new())).collect(),
        }
    }

    /// A cleared buffer: recycled when any stripe has one (hit), freshly
    /// allocated otherwise (miss). Never blocks — contended stripes are
    /// skipped.
    pub fn take(&self) -> Vec<u8> {
        let start = stripe_index();
        for i in 0..STRIPES {
            if let Ok(mut stripe) = self.stripes[(start + i) % STRIPES].try_lock() {
                if let Some(mut buf) = stripe.pop() {
                    drop(stripe);
                    buf.clear();
                    pool_counters().hits.inc();
                    return buf;
                }
            }
        }
        pool_counters().misses.inc();
        Vec::new()
    }

    /// Return a buffer for reuse. Zero-capacity buffers are pointless to
    /// pool, oversize ones are refused (see [`MAX_POOLED_CAPACITY`]), and
    /// when every stripe is full or contended the buffer just drops —
    /// `put` never blocks and never grows the pool past its caps.
    pub fn put(&self, buf: Vec<u8>) {
        if buf.capacity() == 0 {
            return;
        }
        if buf.capacity() > MAX_POOLED_CAPACITY {
            pool_counters().dropped_oversize.inc();
            return;
        }
        let start = stripe_index();
        for i in 0..STRIPES {
            if let Ok(mut stripe) = self.stripes[(start + i) % STRIPES].try_lock() {
                if stripe.len() < PER_STRIPE {
                    stripe.push(buf);
                    return;
                }
            }
        }
    }

    #[cfg(test)]
    fn pooled(&self) -> usize {
        self.stripes.iter().map(|s| s.lock().unwrap().len()).sum()
    }
}

impl Default for BufferPool {
    fn default() -> Self {
        BufferPool::new()
    }
}

/// The process-wide pool shared by both serve engines (frame encode,
/// payload encode, decoder payloads, push deltas).
pub fn global() -> &'static BufferPool {
    static POOL: OnceLock<BufferPool> = OnceLock::new();
    POOL.get_or_init(BufferPool::new)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn take_recycles_returned_capacity() {
        let pool = BufferPool::new();
        let mut buf = Vec::with_capacity(4096);
        buf.extend_from_slice(b"stale contents");
        pool.put(buf);

        let got = pool.take();
        assert!(got.is_empty(), "pooled buffers must come back cleared");
        assert!(got.capacity() >= 4096, "capacity was not recycled");

        // Nothing left: the next take allocates fresh.
        assert_eq!(pool.take().capacity(), 0);
    }

    #[test]
    fn oversize_buffers_are_refused() {
        let pool = BufferPool::new();
        pool.put(Vec::with_capacity(MAX_POOLED_CAPACITY + 1));
        assert_eq!(pool.pooled(), 0);
        // At the cap is fine.
        pool.put(Vec::with_capacity(MAX_POOLED_CAPACITY));
        assert_eq!(pool.pooled(), 1);
    }

    #[test]
    fn pool_size_is_bounded() {
        let pool = BufferPool::new();
        let cap = STRIPES * PER_STRIPE;
        for _ in 0..cap + 100 {
            pool.put(Vec::with_capacity(64));
        }
        assert!(pool.pooled() <= cap, "pool grew past its stripe caps");
    }

    #[test]
    fn cross_stripe_take_finds_buffers_from_other_threads() {
        use std::sync::Arc;
        let pool = Arc::new(BufferPool::new());
        // Park buffers from several threads so they land on stripes other
        // than this thread's home stripe.
        let handles: Vec<_> = (0..4)
            .map(|_| {
                let pool = pool.clone();
                std::thread::spawn(move || pool.put(Vec::with_capacity(512)))
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        let parked = pool.pooled();
        assert!(parked > 0);
        // This thread must be able to drain them all regardless of which
        // stripe they sit on.
        let mut recovered = 0;
        for _ in 0..parked {
            if pool.take().capacity() >= 512 {
                recovered += 1;
            }
        }
        assert_eq!(recovered, parked);
    }
}
