//! Micro property-testing harness (proptest is unavailable offline).
//!
//! `forall(name, cases, |rng| { ... })` runs a closure over `cases`
//! independent deterministic PRNG streams; a panic in any case is reported
//! with the case index and the exact seed so the failure replays with
//! `replay(name, seed, f)`. Shrinking is out of scope — cases are kept small
//! instead.

use super::rng::{mix64, Pcg64};

/// Run `f` across `cases` deterministic random cases.
///
/// The per-case seed is derived from a stable hash of `name` and the case
/// index, so adding tests never reshuffles other tests' cases.
pub fn forall(name: &str, cases: usize, f: impl Fn(&mut Pcg64)) {
    for case in 0..cases {
        let seed = case_seed(name, case as u64);
        let mut rng = Pcg64::seeded(seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            f(&mut rng);
        }));
        if let Err(payload) = result {
            eprintln!(
                "property '{name}' failed at case {case} (seed {seed:#x}); \
                 replay with check::replay(\"{name}\", {seed:#x}, f)"
            );
            std::panic::resume_unwind(payload);
        }
    }
}

/// Replay a single failing case by seed.
pub fn replay(_name: &str, seed: u64, f: impl Fn(&mut Pcg64)) {
    let mut rng = Pcg64::seeded(seed);
    f(&mut rng);
}

fn case_seed(name: &str, case: u64) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64; // FNV offset
    for b in name.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x100_0000_01b3);
    }
    mix64(h ^ case.wrapping_mul(0x9E37_79B9_7F4A_7C15))
}

/// Assert two f32 slices match within absolute + relative tolerance.
pub fn assert_allclose(a: &[f32], b: &[f32], atol: f32, rtol: f32, what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length {} vs {}", a.len(), b.len());
    for (i, (x, y)) in a.iter().zip(b.iter()).enumerate() {
        let tol = atol + rtol * y.abs();
        assert!(
            (x - y).abs() <= tol,
            "{what}[{i}]: {x} vs {y} (tol {tol})"
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn forall_runs_requested_cases() {
        let mut seen = std::collections::HashSet::new();
        // Seeds must be distinct across cases.
        for case in 0..50u64 {
            assert!(seen.insert(case_seed("x", case)));
        }
        let count = std::cell::Cell::new(0);
        forall("count", 10, |_rng| count.set(count.get() + 1));
        assert_eq!(count.get(), 10);
    }

    #[test]
    fn seeds_stable_across_runs() {
        assert_eq!(case_seed("stable", 3), case_seed("stable", 3));
        assert_ne!(case_seed("stable", 3), case_seed("other", 3));
    }

    #[test]
    #[should_panic]
    fn failing_property_panics() {
        forall("fails", 5, |rng| {
            assert!(rng.next_f64() < 0.0);
        });
    }

    #[test]
    fn allclose_passes_and_fails() {
        assert_allclose(&[1.0, 2.0], &[1.0, 2.0 + 1e-7], 1e-6, 1e-6, "ok");
        let r = std::panic::catch_unwind(|| {
            assert_allclose(&[1.0], &[1.1], 1e-6, 1e-6, "bad");
        });
        assert!(r.is_err());
    }
}
