//! Metrics substrate: counters, gauges, wall-clock timers and streaming
//! histograms, aggregated in a registry the pipeline/trainer/benches report
//! from. From scratch (no prometheus/metrics crates offline).
//!
//! Histograms are fixed-layout log-linear (powers of two, 4 sub-buckets) so
//! merging across worker threads is exact and allocation-free.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Instant;

/// Monotonic counter.
#[derive(Default)]
pub struct Counter {
    value: AtomicU64,
}

impl Counter {
    pub fn inc(&self) {
        self.add(1);
    }

    pub fn add(&self, n: u64) {
        self.value.fetch_add(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.value.load(Ordering::Relaxed)
    }
}

/// Last-write-wins gauge: a current value rather than a running sum
/// (registry-shard occupancy, resident bytes, queue depths). Writers race
/// benignly — the owner of the underlying state publishes the value it just
/// computed after each mutation.
#[derive(Default)]
pub struct Gauge {
    value: AtomicU64,
}

impl Gauge {
    pub fn set(&self, v: u64) {
        self.value.store(v, Ordering::Relaxed);
    }

    pub fn add(&self, n: u64) {
        self.value.fetch_add(n, Ordering::Relaxed);
    }

    /// Saturating decrement (a gauge never wraps below zero).
    pub fn sub(&self, n: u64) {
        let _ = self
            .value
            .fetch_update(Ordering::Relaxed, Ordering::Relaxed, |v| {
                Some(v.saturating_sub(n))
            });
    }

    pub fn get(&self) -> u64 {
        self.value.load(Ordering::Relaxed)
    }
}

/// Log-linear histogram of nanosecond (or arbitrary u64) samples.
/// 64 power-of-two decades x 4 sub-buckets; relative error <= 25%.
pub struct Histogram {
    buckets: Vec<AtomicU64>,
    count: AtomicU64,
    sum: AtomicU64,
    max: AtomicU64,
}

const SUB: usize = 4;
const NBUCKETS: usize = 64 * SUB;

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

impl Histogram {
    pub fn new() -> Self {
        Self {
            buckets: (0..NBUCKETS).map(|_| AtomicU64::new(0)).collect(),
            count: AtomicU64::new(0),
            sum: AtomicU64::new(0),
            max: AtomicU64::new(0),
        }
    }

    fn bucket_of(v: u64) -> usize {
        if v == 0 {
            return 0;
        }
        let log2 = 63 - v.leading_zeros() as usize;
        let frac = if log2 >= 2 {
            ((v >> (log2 - 2)) & 0b11) as usize
        } else {
            0
        };
        (log2 * SUB + frac).min(NBUCKETS - 1)
    }

    /// Lower edge of a bucket (inverse of `bucket_of`, approximate).
    fn bucket_low(idx: usize) -> u64 {
        let log2 = idx / SUB;
        let frac = idx % SUB;
        if log2 >= 2 {
            (1u64 << log2) + ((frac as u64) << (log2 - 2))
        } else {
            1u64 << log2
        }
    }

    pub fn record(&self, v: u64) {
        self.buckets[Self::bucket_of(v)].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum.fetch_add(v, Ordering::Relaxed);
        self.max.fetch_max(v, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    pub fn mean(&self) -> f64 {
        let c = self.count();
        if c == 0 {
            0.0
        } else {
            self.sum.load(Ordering::Relaxed) as f64 / c as f64
        }
    }

    pub fn max(&self) -> u64 {
        self.max.load(Ordering::Relaxed)
    }

    /// Approximate quantile (q in [0,1]) from the bucket layout.
    pub fn quantile(&self, q: f64) -> u64 {
        let total = self.count();
        if total == 0 {
            return 0;
        }
        let target = ((q.clamp(0.0, 1.0)) * total as f64).ceil() as u64;
        let mut seen = 0u64;
        for (i, b) in self.buckets.iter().enumerate() {
            seen += b.load(Ordering::Relaxed);
            if seen >= target.max(1) {
                return Self::bucket_low(i);
            }
        }
        self.max()
    }

    pub fn merge_from(&self, other: &Histogram) {
        for (a, b) in self.buckets.iter().zip(other.buckets.iter()) {
            a.fetch_add(b.load(Ordering::Relaxed), Ordering::Relaxed);
        }
        self.count
            .fetch_add(other.count.load(Ordering::Relaxed), Ordering::Relaxed);
        self.sum
            .fetch_add(other.sum.load(Ordering::Relaxed), Ordering::Relaxed);
        self.max
            .fetch_max(other.max.load(Ordering::Relaxed), Ordering::Relaxed);
    }
}

/// Times a scope and records nanoseconds into a histogram on drop.
pub struct ScopedTimer<'a> {
    hist: &'a Histogram,
    start: Instant,
}

impl<'a> ScopedTimer<'a> {
    pub fn new(hist: &'a Histogram) -> Self {
        Self {
            hist,
            start: Instant::now(),
        }
    }
}

impl Drop for ScopedTimer<'_> {
    fn drop(&mut self) {
        self.hist.record(self.start.elapsed().as_nanos() as u64);
    }
}

/// Named registry. Coarse-grained Mutex is fine: lookup happens at setup;
/// hot paths hold `&Counter`/`&Histogram` directly.
#[derive(Default)]
pub struct Registry {
    counters: Mutex<BTreeMap<String, &'static Counter>>,
    gauges: Mutex<BTreeMap<String, &'static Gauge>>,
    hists: Mutex<BTreeMap<String, &'static Histogram>>,
}

impl Registry {
    pub fn counter(&self, name: &str) -> &'static Counter {
        let mut m = self.counters.lock().unwrap();
        m.entry(name.to_string())
            .or_insert_with(|| Box::leak(Box::new(Counter::default())))
    }

    /// Interned gauge. Like counters, gauge names live forever — callers
    /// must use a bounded name set (e.g. the service's per-registry-shard
    /// gauges, capped at the shard count), never client-chosen strings.
    pub fn gauge(&self, name: &str) -> &'static Gauge {
        let mut m = self.gauges.lock().unwrap();
        m.entry(name.to_string())
            .or_insert_with(|| Box::leak(Box::new(Gauge::default())))
    }

    pub fn histogram(&self, name: &str) -> &'static Histogram {
        let mut m = self.hists.lock().unwrap();
        m.entry(name.to_string())
            .or_insert_with(|| Box::leak(Box::new(Histogram::new())))
    }

    /// Snapshot of all counters whose name starts with `prefix`, sorted by
    /// name. The service's per-session counters live under
    /// `service.session.<name>.` and the `Stats` wire op reports them from
    /// here; an empty prefix returns every counter.
    pub fn snapshot_counters(&self, prefix: &str) -> Vec<(String, u64)> {
        self.counters
            .lock()
            .unwrap()
            .iter()
            .filter(|(name, _)| name.starts_with(prefix))
            .map(|(name, c)| (name.clone(), c.get()))
            .collect()
    }

    /// Snapshot of all gauges whose name starts with `prefix`, sorted by
    /// name (see [`Registry::snapshot_counters`]).
    pub fn snapshot_gauges(&self, prefix: &str) -> Vec<(String, u64)> {
        self.gauges
            .lock()
            .unwrap()
            .iter()
            .filter(|(name, _)| name.starts_with(prefix))
            .map(|(name, g)| (name.clone(), g.get()))
            .collect()
    }

    /// Human-readable dump (sorted by name).
    pub fn report(&self) -> String {
        let mut out = String::new();
        for (name, c) in self.counters.lock().unwrap().iter() {
            out.push_str(&format!("{name}: {}\n", c.get()));
        }
        for (name, g) in self.gauges.lock().unwrap().iter() {
            out.push_str(&format!("{name}: {}\n", g.get()));
        }
        for (name, h) in self.hists.lock().unwrap().iter() {
            out.push_str(&format!(
                "{name}: n={} mean={:.0}ns p50={} p99={} max={}\n",
                h.count(),
                h.mean(),
                h.quantile(0.5),
                h.quantile(0.99),
                h.max()
            ));
        }
        out
    }
}

/// Process-global registry.
pub fn global() -> &'static Registry {
    static REG: std::sync::OnceLock<Registry> = std::sync::OnceLock::new();
    REG.get_or_init(Registry::default)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_basics() {
        let c = Counter::default();
        c.inc();
        c.add(4);
        assert_eq!(c.get(), 5);
    }

    #[test]
    fn histogram_quantiles_bracket_samples() {
        let h = Histogram::new();
        for v in 1..=1000u64 {
            h.record(v);
        }
        assert_eq!(h.count(), 1000);
        let p50 = h.quantile(0.5);
        // Log-linear bucketing: <=25% relative error.
        assert!((350..=650).contains(&p50), "p50={p50}");
        let p99 = h.quantile(0.99);
        assert!((700..=1000).contains(&p99), "p99={p99}");
        assert_eq!(h.max(), 1000);
        assert!((h.mean() - 500.5).abs() < 1.0);
    }

    #[test]
    fn histogram_zero_and_huge() {
        let h = Histogram::new();
        h.record(0);
        h.record(u64::MAX);
        assert_eq!(h.count(), 2);
        assert_eq!(h.max(), u64::MAX);
    }

    #[test]
    fn histogram_merge() {
        let a = Histogram::new();
        let b = Histogram::new();
        for v in 0..100 {
            a.record(v);
            b.record(v + 100);
        }
        a.merge_from(&b);
        assert_eq!(a.count(), 200);
        assert_eq!(a.max(), 199);
    }

    #[test]
    fn scoped_timer_records() {
        let h = Histogram::new();
        {
            let _t = ScopedTimer::new(&h);
            std::hint::black_box(1 + 1);
        }
        assert_eq!(h.count(), 1);
    }

    #[test]
    fn registry_dedups_names() {
        let r = Registry::default();
        let c1 = r.counter("x") as *const _;
        let c2 = r.counter("x") as *const _;
        assert_eq!(c1, c2);
        r.counter("x").inc();
        assert!(r.report().contains("x: 1"));
    }

    #[test]
    fn gauge_set_add_sub_saturates() {
        let g = Gauge::default();
        g.set(10);
        g.add(5);
        assert_eq!(g.get(), 15);
        g.sub(20); // saturates at zero, never wraps
        assert_eq!(g.get(), 0);
    }

    #[test]
    fn registry_gauges_snapshot_and_report() {
        let r = Registry::default();
        r.gauge("shard.0.sessions").set(3);
        r.gauge("shard.1.sessions").set(4);
        r.gauge("other.depth").set(9);
        let snap = r.snapshot_gauges("shard.");
        assert_eq!(
            snap,
            vec![
                ("shard.0.sessions".to_string(), 3),
                ("shard.1.sessions".to_string(), 4)
            ]
        );
        let g1 = r.gauge("shard.0.sessions") as *const _;
        let g2 = r.gauge("shard.0.sessions") as *const _;
        assert_eq!(g1, g2);
        assert!(r.report().contains("other.depth: 9"));
    }

    #[test]
    fn snapshot_counters_filters_by_prefix() {
        let r = Registry::default();
        r.counter("service.session.a.rows").add(7);
        r.counter("service.session.b.rows").add(9);
        r.counter("other.rows").add(1);
        let snap = r.snapshot_counters("service.session.a.");
        assert_eq!(snap, vec![("service.session.a.rows".to_string(), 7)]);
        let all = r.snapshot_counters("");
        assert_eq!(all.len(), 3);
    }

    #[test]
    fn bucket_of_monotone() {
        let mut last = 0;
        for v in [1u64, 2, 3, 5, 9, 100, 5000, 1 << 40] {
            let b = Histogram::bucket_of(v);
            assert!(b >= last);
            last = b;
        }
    }
}
