//! Metrics substrate: counters, gauges, wall-clock timers and streaming
//! histograms, aggregated in a registry the pipeline/trainer/benches report
//! from. From scratch (no prometheus/metrics crates offline).
//!
//! Histograms are fixed-layout log-linear (powers of two, 4 sub-buckets) so
//! merging across worker threads is exact and allocation-free.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Instant;

/// Monotonic counter.
#[derive(Default)]
pub struct Counter {
    value: AtomicU64,
}

impl Counter {
    pub fn inc(&self) {
        self.add(1);
    }

    pub fn add(&self, n: u64) {
        self.value.fetch_add(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.value.load(Ordering::Relaxed)
    }
}

/// Last-write-wins gauge: a current value rather than a running sum
/// (registry-shard occupancy, resident bytes, queue depths). Writers race
/// benignly — the owner of the underlying state publishes the value it just
/// computed after each mutation.
#[derive(Default)]
pub struct Gauge {
    value: AtomicU64,
}

impl Gauge {
    pub fn set(&self, v: u64) {
        self.value.store(v, Ordering::Relaxed);
    }

    pub fn add(&self, n: u64) {
        self.value.fetch_add(n, Ordering::Relaxed);
    }

    /// Saturating decrement (a gauge never wraps below zero).
    pub fn sub(&self, n: u64) {
        let _ = self
            .value
            .fetch_update(Ordering::Relaxed, Ordering::Relaxed, |v| {
                Some(v.saturating_sub(n))
            });
    }

    pub fn get(&self) -> u64 {
        self.value.load(Ordering::Relaxed)
    }
}

/// Log-linear histogram of nanosecond (or arbitrary u64) samples.
/// 64 power-of-two decades x 4 sub-buckets; relative error <= 25%.
pub struct Histogram {
    buckets: Vec<AtomicU64>,
    count: AtomicU64,
    sum: AtomicU64,
    max: AtomicU64,
}

const SUB: usize = 4;
const NBUCKETS: usize = 64 * SUB;

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

impl Histogram {
    pub fn new() -> Self {
        Self {
            buckets: (0..NBUCKETS).map(|_| AtomicU64::new(0)).collect(),
            count: AtomicU64::new(0),
            sum: AtomicU64::new(0),
            max: AtomicU64::new(0),
        }
    }

    fn bucket_of(v: u64) -> usize {
        if v == 0 {
            return 0;
        }
        let log2 = 63 - v.leading_zeros() as usize;
        let frac = if log2 >= 2 {
            ((v >> (log2 - 2)) & 0b11) as usize
        } else {
            0
        };
        (log2 * SUB + frac).min(NBUCKETS - 1)
    }

    /// Lower edge of a bucket (inverse of `bucket_of`, approximate).
    fn bucket_low(idx: usize) -> u64 {
        let log2 = idx / SUB;
        let frac = idx % SUB;
        if log2 >= 2 {
            (1u64 << log2) + ((frac as u64) << (log2 - 2))
        } else {
            1u64 << log2
        }
    }

    /// Largest value that maps to bucket `idx` — the inclusive `le` upper
    /// edge for Prometheus. Decades 0 and 1 have no sub-bucket resolution
    /// (`bucket_of` pins frac to 0 there), so their whole decade collapses
    /// into the frac=0 bucket; the last bucket's edge saturates to u64::MAX.
    fn bucket_high(idx: usize) -> u64 {
        let log2 = idx / SUB;
        if log2 >= 2 {
            let width = 1u64 << (log2 - 2);
            Self::bucket_low(idx).saturating_add(width - 1)
        } else {
            (1u64 << (log2 + 1)) - 1
        }
    }

    pub fn record(&self, v: u64) {
        self.buckets[Self::bucket_of(v)].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        // Saturating: a u64::MAX sample must pin `sum` at the ceiling, not
        // wrap it back past zero and corrupt `mean()`.
        let _ = self
            .sum
            .fetch_update(Ordering::Relaxed, Ordering::Relaxed, |s| {
                Some(s.saturating_add(v))
            });
        self.max.fetch_max(v, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    pub fn mean(&self) -> f64 {
        let c = self.count();
        if c == 0 {
            0.0
        } else {
            self.sum.load(Ordering::Relaxed) as f64 / c as f64
        }
    }

    pub fn max(&self) -> u64 {
        self.max.load(Ordering::Relaxed)
    }

    /// Approximate quantile (q in [0,1]) from the bucket layout.
    pub fn quantile(&self, q: f64) -> u64 {
        let total = self.count();
        if total == 0 {
            return 0;
        }
        let target = ((q.clamp(0.0, 1.0)) * total as f64).ceil() as u64;
        let mut seen = 0u64;
        for (i, b) in self.buckets.iter().enumerate() {
            seen += b.load(Ordering::Relaxed);
            if seen >= target.max(1) {
                return Self::bucket_low(i);
            }
        }
        self.max()
    }

    pub fn merge_from(&self, other: &Histogram) {
        for (a, b) in self.buckets.iter().zip(other.buckets.iter()) {
            a.fetch_add(b.load(Ordering::Relaxed), Ordering::Relaxed);
        }
        self.count
            .fetch_add(other.count.load(Ordering::Relaxed), Ordering::Relaxed);
        let other_sum = other.sum.load(Ordering::Relaxed);
        let _ = self
            .sum
            .fetch_update(Ordering::Relaxed, Ordering::Relaxed, |s| {
                Some(s.saturating_add(other_sum))
            });
        self.max
            .fetch_max(other.max.load(Ordering::Relaxed), Ordering::Relaxed);
    }

    pub fn sum(&self) -> u64 {
        self.sum.load(Ordering::Relaxed)
    }

    /// Cumulative-bucket snapshot for Prometheus exposition. Each entry is
    /// `(inclusive upper edge, cumulative count of samples <= edge)` for an
    /// occupied bucket. `count` is derived from the bucket sweep itself (not
    /// the separate `count` atomic) so the `le="+Inf"` cumulative count and
    /// `_count` agree by construction even under concurrent `record` calls.
    pub fn snapshot(&self) -> HistogramSnapshot {
        let mut buckets = Vec::new();
        let mut cumulative = 0u64;
        for (idx, b) in self.buckets.iter().enumerate() {
            let n = b.load(Ordering::Relaxed);
            if n == 0 {
                continue;
            }
            cumulative += n;
            buckets.push((Self::bucket_high(idx), cumulative));
        }
        HistogramSnapshot {
            buckets,
            count: cumulative,
            sum: self.sum(),
            max: self.max(),
        }
    }

    /// Scalar summary used by the `MetricsSnapshot` wire op.
    pub fn stats(&self) -> HistogramStats {
        HistogramStats {
            count: self.count(),
            sum: self.sum(),
            max: self.max(),
            mean: self.mean(),
            p50: self.quantile(0.5),
            p99: self.quantile(0.99),
        }
    }
}

/// See [`Histogram::snapshot`].
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct HistogramSnapshot {
    pub buckets: Vec<(u64, u64)>,
    pub count: u64,
    pub sum: u64,
    pub max: u64,
}

/// See [`Histogram::stats`]. This is the per-histogram record the service's
/// `MetricsSnapshot` wire response carries.
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct HistogramStats {
    pub count: u64,
    pub sum: u64,
    pub max: u64,
    pub mean: f64,
    pub p50: u64,
    pub p99: u64,
}

/// Times a scope and records nanoseconds into a histogram on drop.
pub struct ScopedTimer<'a> {
    hist: &'a Histogram,
    start: Instant,
}

impl<'a> ScopedTimer<'a> {
    pub fn new(hist: &'a Histogram) -> Self {
        Self {
            hist,
            start: Instant::now(),
        }
    }
}

impl Drop for ScopedTimer<'_> {
    fn drop(&mut self) {
        self.hist.record(self.start.elapsed().as_nanos() as u64);
    }
}

/// Named registry. Coarse-grained Mutex is fine: lookup happens at setup;
/// hot paths hold `&Counter`/`&Histogram` directly.
#[derive(Default)]
pub struct Registry {
    counters: Mutex<BTreeMap<String, &'static Counter>>,
    gauges: Mutex<BTreeMap<String, &'static Gauge>>,
    hists: Mutex<BTreeMap<String, &'static Histogram>>,
}

impl Registry {
    pub fn counter(&self, name: &str) -> &'static Counter {
        let mut m = self.counters.lock().unwrap();
        m.entry(name.to_string())
            .or_insert_with(|| Box::leak(Box::new(Counter::default())))
    }

    /// Interned gauge. Like counters, gauge names live forever — callers
    /// must use a bounded name set (e.g. the service's per-registry-shard
    /// gauges, capped at the shard count), never client-chosen strings.
    pub fn gauge(&self, name: &str) -> &'static Gauge {
        let mut m = self.gauges.lock().unwrap();
        m.entry(name.to_string())
            .or_insert_with(|| Box::leak(Box::new(Gauge::default())))
    }

    pub fn histogram(&self, name: &str) -> &'static Histogram {
        let mut m = self.hists.lock().unwrap();
        m.entry(name.to_string())
            .or_insert_with(|| Box::leak(Box::new(Histogram::new())))
    }

    /// Snapshot of all counters whose name starts with `prefix`, sorted by
    /// name. The service's per-session counters live under
    /// `service.session.<name>.` and the `Stats` wire op reports them from
    /// here; an empty prefix returns every counter.
    pub fn snapshot_counters(&self, prefix: &str) -> Vec<(String, u64)> {
        self.counters
            .lock()
            .unwrap()
            .iter()
            .filter(|(name, _)| name.starts_with(prefix))
            .map(|(name, c)| (name.clone(), c.get()))
            .collect()
    }

    /// Snapshot of all gauges whose name starts with `prefix`, sorted by
    /// name (see [`Registry::snapshot_counters`]).
    pub fn snapshot_gauges(&self, prefix: &str) -> Vec<(String, u64)> {
        self.gauges
            .lock()
            .unwrap()
            .iter()
            .filter(|(name, _)| name.starts_with(prefix))
            .map(|(name, g)| (name.clone(), g.get()))
            .collect()
    }

    /// Snapshot of all histograms whose name starts with `prefix`, sorted
    /// by name, as scalar summaries (see [`Registry::snapshot_counters`]).
    pub fn snapshot_histograms(&self, prefix: &str) -> Vec<(String, HistogramStats)> {
        self.hists
            .lock()
            .unwrap()
            .iter()
            .filter(|(name, _)| name.starts_with(prefix))
            .map(|(name, h)| (name.clone(), h.stats()))
            .collect()
    }

    /// Prometheus text exposition (format version 0.0.4) of every metric in
    /// the registry, sorted by name within each kind (counters, then gauges,
    /// then histograms — the underlying `BTreeMap`s make the order stable).
    /// Dots in metric names become underscores; histogram values keep their
    /// native u64 unit (nanoseconds for timers — the `_ns` suffix in the
    /// source name carries through rather than rescaling to seconds).
    pub fn render_prometheus(&self) -> String {
        let mut out = String::new();
        for (name, c) in self.counters.lock().unwrap().iter() {
            let n = prom_name(name);
            out.push_str(&format!("# TYPE {n} counter\n{n} {}\n", c.get()));
        }
        for (name, g) in self.gauges.lock().unwrap().iter() {
            let n = prom_name(name);
            out.push_str(&format!("# TYPE {n} gauge\n{n} {}\n", g.get()));
        }
        for (name, h) in self.hists.lock().unwrap().iter() {
            let n = prom_name(name);
            let snap = h.snapshot();
            out.push_str(&format!("# TYPE {n} histogram\n"));
            for (upper, cumulative) in &snap.buckets {
                if *upper == u64::MAX {
                    continue; // open-ended bucket folds into +Inf below
                }
                out.push_str(&format!("{n}_bucket{{le=\"{upper}\"}} {cumulative}\n"));
            }
            out.push_str(&format!("{n}_bucket{{le=\"+Inf\"}} {}\n", snap.count));
            out.push_str(&format!("{n}_sum {}\n", snap.sum));
            out.push_str(&format!("{n}_count {}\n", snap.count));
        }
        out
    }

    /// Human-readable dump (sorted by name).
    pub fn report(&self) -> String {
        let mut out = String::new();
        for (name, c) in self.counters.lock().unwrap().iter() {
            out.push_str(&format!("{name}: {}\n", c.get()));
        }
        for (name, g) in self.gauges.lock().unwrap().iter() {
            out.push_str(&format!("{name}: {}\n", g.get()));
        }
        for (name, h) in self.hists.lock().unwrap().iter() {
            out.push_str(&format!(
                "{name}: n={} mean={:.0}ns p50={} p99={} max={}\n",
                h.count(),
                h.mean(),
                h.quantile(0.5),
                h.quantile(0.99),
                h.max()
            ));
        }
        out
    }
}

/// Sanitize an internal dotted metric name into the Prometheus identifier
/// charset `[a-zA-Z_:][a-zA-Z0-9_:]*`.
fn prom_name(name: &str) -> String {
    let mut out = String::with_capacity(name.len() + 1);
    for (i, ch) in name.chars().enumerate() {
        let ok = ch.is_ascii_alphanumeric() || ch == '_' || ch == ':';
        if i == 0 && ch.is_ascii_digit() {
            out.push('_');
        }
        out.push(if ok { ch } else { '_' });
    }
    out
}

/// Process-global registry.
pub fn global() -> &'static Registry {
    static REG: std::sync::OnceLock<Registry> = std::sync::OnceLock::new();
    REG.get_or_init(Registry::default)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_basics() {
        let c = Counter::default();
        c.inc();
        c.add(4);
        assert_eq!(c.get(), 5);
    }

    #[test]
    fn histogram_quantiles_bracket_samples() {
        let h = Histogram::new();
        for v in 1..=1000u64 {
            h.record(v);
        }
        assert_eq!(h.count(), 1000);
        let p50 = h.quantile(0.5);
        // Log-linear bucketing: <=25% relative error.
        assert!((350..=650).contains(&p50), "p50={p50}");
        let p99 = h.quantile(0.99);
        assert!((700..=1000).contains(&p99), "p99={p99}");
        assert_eq!(h.max(), 1000);
        assert!((h.mean() - 500.5).abs() < 1.0);
    }

    #[test]
    fn histogram_zero_and_huge() {
        let h = Histogram::new();
        h.record(0);
        h.record(u64::MAX);
        assert_eq!(h.count(), 2);
        assert_eq!(h.max(), u64::MAX);
    }

    #[test]
    fn histogram_merge() {
        let a = Histogram::new();
        let b = Histogram::new();
        for v in 0..100 {
            a.record(v);
            b.record(v + 100);
        }
        a.merge_from(&b);
        assert_eq!(a.count(), 200);
        assert_eq!(a.max(), 199);
    }

    #[test]
    fn scoped_timer_records() {
        let h = Histogram::new();
        {
            let _t = ScopedTimer::new(&h);
            std::hint::black_box(1 + 1);
        }
        assert_eq!(h.count(), 1);
    }

    #[test]
    fn registry_dedups_names() {
        let r = Registry::default();
        let c1 = r.counter("x") as *const _;
        let c2 = r.counter("x") as *const _;
        assert_eq!(c1, c2);
        r.counter("x").inc();
        assert!(r.report().contains("x: 1"));
    }

    #[test]
    fn gauge_set_add_sub_saturates() {
        let g = Gauge::default();
        g.set(10);
        g.add(5);
        assert_eq!(g.get(), 15);
        g.sub(20); // saturates at zero, never wraps
        assert_eq!(g.get(), 0);
    }

    #[test]
    fn registry_gauges_snapshot_and_report() {
        let r = Registry::default();
        r.gauge("shard.0.sessions").set(3);
        r.gauge("shard.1.sessions").set(4);
        r.gauge("other.depth").set(9);
        let snap = r.snapshot_gauges("shard.");
        assert_eq!(
            snap,
            vec![
                ("shard.0.sessions".to_string(), 3),
                ("shard.1.sessions".to_string(), 4)
            ]
        );
        let g1 = r.gauge("shard.0.sessions") as *const _;
        let g2 = r.gauge("shard.0.sessions") as *const _;
        assert_eq!(g1, g2);
        assert!(r.report().contains("other.depth: 9"));
    }

    #[test]
    fn snapshot_counters_filters_by_prefix() {
        let r = Registry::default();
        r.counter("service.session.a.rows").add(7);
        r.counter("service.session.b.rows").add(9);
        r.counter("other.rows").add(1);
        let snap = r.snapshot_counters("service.session.a.");
        assert_eq!(snap, vec![("service.session.a.rows".to_string(), 7)]);
        let all = r.snapshot_counters("");
        assert_eq!(all.len(), 3);
    }

    #[test]
    fn histogram_sum_saturates_instead_of_wrapping() {
        // Regression: `record(u64::MAX)` used to wrap `sum` and corrupt
        // `mean()` (it came out near zero after a max-value sample).
        let h = Histogram::new();
        h.record(100);
        h.record(u64::MAX);
        assert_eq!(h.sum(), u64::MAX, "sum must saturate, not wrap");
        let mean = h.mean();
        assert!(
            mean >= u64::MAX as f64 / 2.1,
            "mean must stay sane after a max-value sample, got {mean}"
        );
        // merge_from has the same saturation contract.
        let other = Histogram::new();
        other.record(u64::MAX);
        h.merge_from(&other);
        assert_eq!(h.sum(), u64::MAX);
        assert_eq!(h.count(), 3);
    }

    #[test]
    fn snapshot_cumulative_buckets_monotone_and_consistent() {
        let h = Histogram::new();
        for v in [0u64, 1, 7, 7, 900, 1 << 30] {
            h.record(v);
        }
        let snap = h.snapshot();
        assert_eq!(snap.count, 6);
        assert_eq!(snap.sum, 15 + 900 + (1 << 30));
        assert_eq!(snap.max, 1 << 30);
        let mut last_upper = 0u64;
        let mut last_cum = 0u64;
        for &(upper, cum) in &snap.buckets {
            assert!(upper > last_upper || last_cum == 0, "upper edges ascend");
            assert!(cum > last_cum, "cumulative counts strictly ascend");
            last_upper = upper;
            last_cum = cum;
        }
        // The final cumulative count is the +Inf bucket == _count invariant.
        assert_eq!(last_cum, snap.count);
        // Every sample is <= its bucket's inclusive upper edge.
        assert!(snap.buckets.iter().any(|&(u, _)| 7 <= u));
    }

    #[test]
    fn prometheus_exposition_golden() {
        let r = Registry::default();
        r.counter("service.server.requests").add(3);
        r.gauge("service.ingest.queue_depth").set(2);
        let h = r.histogram("pipeline.phase1.batch.ns");
        h.record(5);
        h.record(6);
        let text = r.render_prometheus();
        // Golden: exact output, which also pins the stable sort order
        // (counters, gauges, histograms; BTreeMap order within each kind)
        // and the cumulative `le="+Inf"` == `_count` invariant.
        // Samples 5 and 6 land in log-linear buckets [5,6) and [6,7):
        // inclusive upper edges 5 and 6.
        let expected = "\
# TYPE service_server_requests counter
service_server_requests 3
# TYPE service_ingest_queue_depth gauge
service_ingest_queue_depth 2
# TYPE pipeline_phase1_batch_ns histogram
pipeline_phase1_batch_ns_bucket{le=\"5\"} 1
pipeline_phase1_batch_ns_bucket{le=\"6\"} 2
pipeline_phase1_batch_ns_bucket{le=\"+Inf\"} 2
pipeline_phase1_batch_ns_sum 11
pipeline_phase1_batch_ns_count 2
";
        assert_eq!(text, expected);
    }

    #[test]
    fn prometheus_plus_inf_equals_count_even_for_huge_samples() {
        let r = Registry::default();
        let h = r.histogram("x.ns");
        h.record(u64::MAX); // lands in the open-ended last bucket
        h.record(1);
        let text = r.render_prometheus();
        assert!(text.contains("x_ns_bucket{le=\"+Inf\"} 2\n"), "{text}");
        assert!(text.contains("x_ns_count 2\n"), "{text}");
        // The open-ended bucket must not leak a u64::MAX-edged series.
        assert!(!text.contains(&format!("le=\"{}\"", u64::MAX)), "{text}");
    }

    #[test]
    fn histogram_stats_summary() {
        let h = Histogram::new();
        for v in 1..=100u64 {
            h.record(v);
        }
        let s = h.stats();
        assert_eq!(s.count, 100);
        assert_eq!(s.sum, 5050);
        assert_eq!(s.max, 100);
        assert!((s.mean - 50.5).abs() < 1e-9);
        assert!(s.p50 >= 32 && s.p50 <= 72, "p50={}", s.p50);
        assert!(s.p99 >= 64 && s.p99 <= 100, "p99={}", s.p99);
    }

    #[test]
    fn snapshot_histograms_filters_by_prefix() {
        let r = Registry::default();
        r.histogram("a.ns").record(4);
        r.histogram("b.ns").record(9);
        let snap = r.snapshot_histograms("a.");
        assert_eq!(snap.len(), 1);
        assert_eq!(snap[0].0, "a.ns");
        assert_eq!(snap[0].1.count, 1);
        assert_eq!(r.snapshot_histograms("").len(), 2);
    }

    #[test]
    fn prom_name_sanitizes() {
        assert_eq!(prom_name("service.server.requests"), "service_server_requests");
        assert_eq!(prom_name("kernel.gram.ns"), "kernel_gram_ns");
        assert_eq!(prom_name("9lives"), "_9lives");
    }

    #[test]
    fn bucket_of_monotone() {
        let mut last = 0;
        for v in [1u64, 2, 3, 5, 9, 100, 5000, 1 << 40] {
            let b = Histogram::bucket_of(v);
            assert!(b >= last);
            last = b;
        }
    }
}
