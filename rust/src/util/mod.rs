//! Infrastructure substrates built from scratch for the offline environment:
//! PRNG, JSON, logging, metrics, bounded channels, thread pool, and a tiny
//! property-testing harness. Nothing here depends on the paper — these are
//! the libraries the coordinator would normally pull from crates.io.

pub mod bufpool;
pub mod channel;
pub mod check;
pub mod json;
pub mod log;
pub mod metrics;
pub mod rng;
pub mod sys;
pub mod threadpool;
pub mod trace;
