//! Tiny leveled logger (from scratch — no `log`/`env_logger` facade at
//! runtime). Level comes from `SAGE_LOG` (error|warn|info|debug|trace),
//! default `info`. Timestamps are seconds since process start to keep
//! output deterministic-ish and diffable in CI logs.

use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::OnceLock;
use std::time::Instant;

#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
#[repr(u8)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
    Trace = 4,
}

impl Level {
    pub fn from_str(s: &str) -> Option<Level> {
        Some(match s.to_ascii_lowercase().as_str() {
            "error" => Level::Error,
            "warn" => Level::Warn,
            "info" => Level::Info,
            "debug" => Level::Debug,
            "trace" => Level::Trace,
            _ => return None,
        })
    }

    pub fn tag(self) -> &'static str {
        match self {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        }
    }
}

static LEVEL: AtomicU8 = AtomicU8::new(2);
static START: OnceLock<Instant> = OnceLock::new();
static INIT: OnceLock<()> = OnceLock::new();

/// Initialize from `SAGE_LOG`; called lazily by the first log line.
pub fn init() {
    INIT.get_or_init(|| {
        START.get_or_init(Instant::now);
        if let Ok(v) = std::env::var("SAGE_LOG") {
            if let Some(l) = Level::from_str(&v) {
                LEVEL.store(l as u8, Ordering::Relaxed);
            }
        }
    });
}

pub fn set_level(level: Level) {
    init();
    LEVEL.store(level as u8, Ordering::Relaxed);
}

pub fn enabled(level: Level) -> bool {
    init();
    level as u8 <= LEVEL.load(Ordering::Relaxed)
}

pub fn log(level: Level, module: &str, msg: &str) {
    if !enabled(level) {
        return;
    }
    let t = START.get_or_init(Instant::now).elapsed().as_secs_f64();
    eprintln!("[{t:9.3}s {} {module}] {msg}", level.tag());
}

#[macro_export]
macro_rules! log_error { ($($a:tt)*) => { $crate::util::log::log($crate::util::log::Level::Error, module_path!(), &format!($($a)*)) } }
#[macro_export]
macro_rules! log_warn { ($($a:tt)*) => { $crate::util::log::log($crate::util::log::Level::Warn, module_path!(), &format!($($a)*)) } }
#[macro_export]
macro_rules! log_info { ($($a:tt)*) => { $crate::util::log::log($crate::util::log::Level::Info, module_path!(), &format!($($a)*)) } }
#[macro_export]
macro_rules! log_debug { ($($a:tt)*) => { $crate::util::log::log($crate::util::log::Level::Debug, module_path!(), &format!($($a)*)) } }
#[macro_export]
macro_rules! log_trace { ($($a:tt)*) => { $crate::util::log::log($crate::util::log::Level::Trace, module_path!(), &format!($($a)*)) } }

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_parsing() {
        assert_eq!(Level::from_str("warn"), Some(Level::Warn));
        assert_eq!(Level::from_str("TRACE"), Some(Level::Trace));
        assert_eq!(Level::from_str("bogus"), None);
    }

    #[test]
    fn level_gating() {
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        set_level(Level::Info);
    }
}
