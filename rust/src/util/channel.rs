//! Bounded MPMC channel with blocking send — the backpressure primitive of
//! the streaming pipeline (from scratch; no tokio/crossbeam offline).
//!
//! Semantics:
//!   * `send` blocks while the queue is at capacity (backpressure) and fails
//!     only if every receiver is gone.
//!   * `recv` blocks while empty and returns `None` once the channel is
//!     closed AND drained — so producers finishing never lose items.
//!   * Any number of producers and consumers may share the endpoints by
//!     cloning; the channel closes when all senders drop or on `close()`.

use std::collections::VecDeque;
use std::sync::{Arc, Condvar, Mutex};

struct Inner<T> {
    queue: Mutex<State<T>>,
    not_full: Condvar,
    not_empty: Condvar,
    capacity: usize,
}

struct State<T> {
    items: VecDeque<T>,
    senders: usize,
    receivers: usize,
    closed: bool,
}

/// Sending endpoint (clonable).
pub struct Sender<T> {
    inner: Arc<Inner<T>>,
}

/// Receiving endpoint (clonable).
pub struct Receiver<T> {
    inner: Arc<Inner<T>>,
}

/// Error returned when the send side outlives all receivers.
#[derive(Debug, PartialEq, Eq)]
pub struct SendError<T>(pub T);

pub fn bounded<T>(capacity: usize) -> (Sender<T>, Receiver<T>) {
    assert!(capacity > 0, "channel capacity must be > 0");
    let inner = Arc::new(Inner {
        queue: Mutex::new(State {
            items: VecDeque::with_capacity(capacity),
            senders: 1,
            receivers: 1,
            closed: false,
        }),
        not_full: Condvar::new(),
        not_empty: Condvar::new(),
        capacity,
    });
    (
        Sender {
            inner: inner.clone(),
        },
        Receiver { inner },
    )
}

impl<T> Sender<T> {
    /// Blocking send with backpressure.
    pub fn send(&self, item: T) -> Result<(), SendError<T>> {
        let mut st = self.inner.queue.lock().unwrap();
        loop {
            if st.receivers == 0 || st.closed {
                return Err(SendError(item));
            }
            if st.items.len() < self.inner.capacity {
                st.items.push_back(item);
                self.inner.not_empty.notify_one();
                return Ok(());
            }
            st = self.inner.not_full.wait(st).unwrap();
        }
    }

    /// Non-blocking send; returns the item back if full/closed.
    pub fn try_send(&self, item: T) -> Result<(), SendError<T>> {
        let mut st = self.inner.queue.lock().unwrap();
        if st.receivers == 0 || st.closed || st.items.len() >= self.inner.capacity {
            return Err(SendError(item));
        }
        st.items.push_back(item);
        self.inner.not_empty.notify_one();
        Ok(())
    }

    /// Explicitly close (wakes all blocked parties).
    pub fn close(&self) {
        let mut st = self.inner.queue.lock().unwrap();
        st.closed = true;
        self.inner.not_empty.notify_all();
        self.inner.not_full.notify_all();
    }

    /// Current queue depth (diagnostics / backpressure metrics).
    pub fn len(&self) -> usize {
        self.inner.queue.lock().unwrap().items.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

impl<T> Clone for Sender<T> {
    fn clone(&self) -> Self {
        self.inner.queue.lock().unwrap().senders += 1;
        Sender {
            inner: self.inner.clone(),
        }
    }
}

impl<T> Drop for Sender<T> {
    fn drop(&mut self) {
        let mut st = self.inner.queue.lock().unwrap();
        st.senders -= 1;
        if st.senders == 0 {
            st.closed = true;
            self.inner.not_empty.notify_all();
        }
    }
}

impl<T> Receiver<T> {
    /// Blocking receive; `None` = closed and fully drained.
    pub fn recv(&self) -> Option<T> {
        let mut st = self.inner.queue.lock().unwrap();
        loop {
            if let Some(item) = st.items.pop_front() {
                self.inner.not_full.notify_one();
                return Some(item);
            }
            if st.closed || st.senders == 0 {
                return None;
            }
            st = self.inner.not_empty.wait(st).unwrap();
        }
    }

    /// Non-blocking receive.
    pub fn try_recv(&self) -> Option<T> {
        let mut st = self.inner.queue.lock().unwrap();
        let item = st.items.pop_front();
        if item.is_some() {
            self.inner.not_full.notify_one();
        }
        item
    }

    /// Drain into an iterator (blocking until closed).
    pub fn iter(&self) -> RecvIter<'_, T> {
        RecvIter { rx: self }
    }
}

impl<T> Clone for Receiver<T> {
    fn clone(&self) -> Self {
        self.inner.queue.lock().unwrap().receivers += 1;
        Receiver {
            inner: self.inner.clone(),
        }
    }
}

impl<T> Drop for Receiver<T> {
    fn drop(&mut self) {
        let mut st = self.inner.queue.lock().unwrap();
        st.receivers -= 1;
        if st.receivers == 0 {
            // Unblock producers so they can observe the error.
            self.inner.not_full.notify_all();
        }
    }
}

pub struct RecvIter<'a, T> {
    rx: &'a Receiver<T>,
}

impl<T> Iterator for RecvIter<'_, T> {
    type Item = T;

    fn next(&mut self) -> Option<T> {
        self.rx.recv()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::thread;
    use std::time::Duration;

    #[test]
    fn fifo_order() {
        let (tx, rx) = bounded(8);
        for i in 0..5 {
            tx.send(i).unwrap();
        }
        drop(tx);
        let got: Vec<i32> = rx.iter().collect();
        assert_eq!(got, vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn backpressure_blocks_until_recv() {
        let (tx, rx) = bounded(1);
        tx.send(1).unwrap();
        let t = thread::spawn(move || {
            tx.send(2).unwrap(); // blocks until the main thread receives
            tx.send(3).unwrap();
        });
        thread::sleep(Duration::from_millis(20));
        assert_eq!(rx.recv(), Some(1));
        assert_eq!(rx.recv(), Some(2));
        assert_eq!(rx.recv(), Some(3));
        t.join().unwrap();
        assert_eq!(rx.recv(), None);
    }

    #[test]
    fn try_send_full() {
        let (tx, _rx) = bounded(1);
        assert!(tx.try_send(1).is_ok());
        assert_eq!(tx.try_send(2), Err(SendError(2)));
    }

    #[test]
    fn send_fails_when_receiver_gone() {
        let (tx, rx) = bounded(1);
        drop(rx);
        assert_eq!(tx.send(7), Err(SendError(7)));
    }

    #[test]
    fn close_drains_remaining() {
        let (tx, rx) = bounded(4);
        tx.send(1).unwrap();
        tx.send(2).unwrap();
        tx.close();
        assert_eq!(tx.send(3), Err(SendError(3)));
        assert_eq!(rx.recv(), Some(1));
        assert_eq!(rx.recv(), Some(2));
        assert_eq!(rx.recv(), None);
    }

    #[test]
    fn close_then_drain_loses_nothing_with_concurrent_senders() {
        // The service freezes a session by closing its ingest channel while
        // producer connections may still be mid-send. Correctness contract:
        // every send that returned Ok() is drained by the consumer exactly
        // once, and every send after (or interrupted by) close returns the
        // item back via SendError — nothing is silently dropped.
        for round in 0..20 {
            let (tx, rx) = bounded::<usize>(2); // tiny: senders block often
            let n_senders = 4;
            let per = 50;
            let mut senders = Vec::new();
            for p in 0..n_senders {
                let tx = tx.clone();
                senders.push(thread::spawn(move || {
                    let mut acked = Vec::new();
                    for i in 0..per {
                        let item = p * per + i;
                        match tx.send(item) {
                            Ok(()) => acked.push(item),
                            Err(SendError(rejected)) => {
                                assert_eq!(rejected, item);
                                break; // closed mid-stream
                            }
                        }
                    }
                    acked
                }));
            }
            // Consumer drains concurrently (like a session's ingest worker);
            // `None` only after close + fully drained.
            let consumer = thread::spawn(move || {
                let mut got = Vec::new();
                while let Some(v) = rx.recv() {
                    got.push(v);
                }
                got
            });
            // Let both sides make progress, then freeze at an arbitrary point.
            thread::sleep(Duration::from_millis(round % 5));
            tx.close();
            drop(tx);
            let mut acked: Vec<usize> = senders
                .into_iter()
                .flat_map(|s| s.join().unwrap())
                .collect();
            let mut drained = consumer.join().unwrap();
            acked.sort_unstable();
            drained.sort_unstable();
            assert_eq!(drained, acked, "round {round}: close lost items");
        }
    }

    #[test]
    fn mpmc_all_items_delivered_once() {
        let (tx, rx) = bounded(4);
        let n_producers = 4;
        let per = 250;
        let mut producers = Vec::new();
        for p in 0..n_producers {
            let tx = tx.clone();
            producers.push(thread::spawn(move || {
                for i in 0..per {
                    tx.send(p * per + i).unwrap();
                }
            }));
        }
        drop(tx);
        let mut consumers = Vec::new();
        for _ in 0..3 {
            let rx = rx.clone();
            consumers.push(thread::spawn(move || {
                let mut got = Vec::new();
                while let Some(v) = rx.recv() {
                    got.push(v);
                }
                got
            }));
        }
        drop(rx);
        for p in producers {
            p.join().unwrap();
        }
        let mut all: Vec<usize> = consumers
            .into_iter()
            .flat_map(|c| c.join().unwrap())
            .collect();
        all.sort_unstable();
        assert_eq!(all, (0..n_producers * per).collect::<Vec<_>>());
    }
}
