//! Lightweight span tracing: process-unique trace IDs minted per request,
//! hierarchical spans recorded into lock-cheap thread-striped ring buffers,
//! exportable as Chrome `trace_event` JSON (`chrome://tracing`, Perfetto).
//!
//! Design constraints, in order:
//!
//! 1. **Zero cost when idle.** `span()` checks a thread-local `Cell` and
//!    returns `None` unless a trace is active on the calling thread — no
//!    allocation, no clock read, no lock. Traces only become active when a
//!    client frame carries a trace context (see `service::protocol`) or a
//!    root span is opened explicitly.
//! 2. **Lock-cheap recording.** Finished spans go into one of a fixed set
//!    of ring buffers striped by thread id. A thread almost always has its
//!    stripe to itself, so the per-record `Mutex` is uncontended; striping
//!    (rather than a leaked ring per thread) keeps memory bounded under the
//!    server's thread-per-connection model. Each ring caps at
//!    [`RING_CAPACITY`] spans, dropping the oldest.
//! 3. **Mergeable across processes.** Span IDs are derived from a per-process
//!    seed so client and server spans can be unioned into one trace without
//!    collisions; timestamps are Unix nanoseconds (a per-process monotonic
//!    clock pinned to the wall clock once at startup) so cross-process spans
//!    land on a shared axis.
//!
//! Span exit lines are routed through `log_trace!` — run with
//! `SAGE_LOG=trace` to watch spans close in real time.

use crate::log_trace;
use crate::util::json::Json;
use crate::util::log;
use std::cell::Cell;
use std::collections::{BTreeMap, VecDeque};
use std::sync::atomic::{AtomicU32, AtomicU64, Ordering};
use std::sync::{Mutex, OnceLock};
use std::time::{Instant, SystemTime, UNIX_EPOCH};

/// Max spans retained per ring stripe (oldest dropped first).
pub const RING_CAPACITY: usize = 4096;
const STRIPES: usize = 64;

/// The identity a span executes under: which trace it belongs to and which
/// span is the current parent. This is what rides the wire in the frame
/// trace extension.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct TraceCtx {
    pub trace_id: u64,
    pub span_id: u64,
}

/// One finished span, as stored in the rings and shipped by the
/// `TraceExport` wire op.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct SpanRecord {
    pub name: String,
    pub trace_id: u64,
    pub span_id: u64,
    /// 0 for a root span.
    pub parent_id: u64,
    pub start_unix_ns: u64,
    pub dur_ns: u64,
    pub pid: u32,
    pub tid: u32,
}

// ---------------------------------------------------------------------------
// Clocks and IDs
// ---------------------------------------------------------------------------

/// (monotonic anchor, wall-clock at the anchor in unix ns), captured once so
/// span timestamps are monotone within the process but comparable across
/// processes.
fn epoch() -> &'static (Instant, u64) {
    static EPOCH: OnceLock<(Instant, u64)> = OnceLock::new();
    EPOCH.get_or_init(|| {
        let wall = SystemTime::now()
            .duration_since(UNIX_EPOCH)
            .map(|d| d.as_nanos() as u64)
            .unwrap_or(0);
        (Instant::now(), wall)
    })
}

fn now_unix_ns() -> u64 {
    let (anchor, wall) = epoch();
    wall.saturating_add(anchor.elapsed().as_nanos() as u64)
}

fn splitmix64(mut x: u64) -> u64 {
    x = x.wrapping_add(0x9e37_79b9_7f4a_7c15);
    x = (x ^ (x >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    x = (x ^ (x >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    x ^ (x >> 31)
}

/// Process-unique, never-zero ID. A per-process seed (pid mixed with the
/// wall clock) is folded into a sequence counter so IDs minted by a client
/// and a server do not collide when their spans are merged into one export.
fn next_id() -> u64 {
    static SEQ: AtomicU64 = AtomicU64::new(0);
    static SEED: OnceLock<u64> = OnceLock::new();
    let seed = *SEED.get_or_init(|| {
        splitmix64((std::process::id() as u64) << 32 ^ epoch().1)
    });
    let id = splitmix64(seed.wrapping_add(SEQ.fetch_add(1, Ordering::Relaxed)));
    if id == 0 {
        1
    } else {
        id
    }
}

fn tid() -> u32 {
    static NEXT: AtomicU32 = AtomicU32::new(1);
    thread_local! {
        static TID: u32 = NEXT.fetch_add(1, Ordering::Relaxed);
    }
    TID.with(|t| *t)
}

// ---------------------------------------------------------------------------
// Rings
// ---------------------------------------------------------------------------

fn rings() -> &'static [Mutex<VecDeque<SpanRecord>>] {
    static RINGS: OnceLock<Vec<Mutex<VecDeque<SpanRecord>>>> = OnceLock::new();
    RINGS.get_or_init(|| (0..STRIPES).map(|_| Mutex::new(VecDeque::new())).collect())
}

fn record(rec: SpanRecord) {
    let ring = &rings()[rec.tid as usize % STRIPES];
    let mut g = ring.lock().unwrap();
    if g.len() >= RING_CAPACITY {
        g.pop_front();
    }
    g.push_back(rec);
}

/// Snapshot every recorded span (all stripes), sorted by start time. Does
/// not drain the rings; they keep rolling.
pub fn collect() -> Vec<SpanRecord> {
    let mut out = Vec::new();
    for ring in rings() {
        out.extend(ring.lock().unwrap().iter().cloned());
    }
    out.sort_by_key(|s| (s.start_unix_ns, s.span_id));
    out
}

/// Drop every recorded span.
pub fn clear() {
    for ring in rings() {
        ring.lock().unwrap().clear();
    }
}

// ---------------------------------------------------------------------------
// Spans
// ---------------------------------------------------------------------------

thread_local! {
    static CURRENT: Cell<Option<TraceCtx>> = const { Cell::new(None) };
}

/// The trace context active on this thread, if any. The service client
/// attaches this to outgoing frames.
pub fn current() -> Option<TraceCtx> {
    CURRENT.with(|c| c.get())
}

/// An open span. Records itself into the ring (and restores the previous
/// thread-local context) on drop.
pub struct Span {
    name: String,
    ctx: TraceCtx,
    parent_id: u64,
    prev: Option<TraceCtx>,
    start_unix_ns: u64,
    start: Instant,
}

impl Span {
    fn begin(name: String, trace_id: u64, parent_id: u64) -> Span {
        let ctx = TraceCtx {
            trace_id,
            span_id: next_id(),
        };
        let prev = CURRENT.with(|c| c.replace(Some(ctx)));
        Span {
            name,
            ctx,
            parent_id,
            prev,
            start_unix_ns: now_unix_ns(),
            start: Instant::now(),
        }
    }

    pub fn ctx(&self) -> TraceCtx {
        self.ctx
    }
}

impl Drop for Span {
    fn drop(&mut self) {
        CURRENT.with(|c| c.set(self.prev));
        let dur_ns = self.start.elapsed().as_nanos() as u64;
        if log::enabled(log::Level::Trace) {
            log_trace!(
                "span exit {} trace={:016x} span={:016x} dur={}ns",
                self.name,
                self.ctx.trace_id,
                self.ctx.span_id,
                dur_ns
            );
        }
        record(SpanRecord {
            name: std::mem::take(&mut self.name),
            trace_id: self.ctx.trace_id,
            span_id: self.ctx.span_id,
            parent_id: self.parent_id,
            start_unix_ns: self.start_unix_ns,
            dur_ns,
            pid: std::process::id(),
            tid: tid(),
        });
    }
}

/// Open a root span under a freshly minted trace ID and make it the
/// thread's active context.
pub fn start_trace(name: &str) -> Span {
    Span::begin(name.to_string(), next_id(), 0)
}

/// Open a root-on-this-process span adopting a caller-supplied context —
/// the server side of trace propagation: the client's span becomes the
/// parent, the client's trace ID is kept.
pub fn adopt(name: &str, ctx: TraceCtx) -> Span {
    Span::begin(name.to_string(), ctx.trace_id, ctx.span_id)
}

/// Open a child of the thread's active span, or `None` (a no-op, nothing
/// allocated or locked) when no trace is active. Bind the result to keep
/// the span open: `let _s = trace::span("registry.ingest");`
pub fn span(name: &str) -> Option<Span> {
    current().map(|c| Span::begin(name.to_string(), c.trace_id, c.span_id))
}

// ---------------------------------------------------------------------------
// Chrome trace_event export
// ---------------------------------------------------------------------------

/// Render spans (typically `collect()`, or a client/server merge) as Chrome
/// `trace_event` JSON — complete events (`"ph":"X"`), microsecond
/// timestamps, IDs as zero-padded hex strings (u64 does not survive a
/// round-trip through JSON's f64 numbers).
pub fn chrome_trace_json(spans: &[SpanRecord]) -> String {
    let events: Vec<Json> = spans
        .iter()
        .map(|s| {
            let mut args = BTreeMap::new();
            args.insert("trace_id".to_string(), Json::Str(format!("{:016x}", s.trace_id)));
            args.insert("span_id".to_string(), Json::Str(format!("{:016x}", s.span_id)));
            args.insert(
                "parent_id".to_string(),
                Json::Str(format!("{:016x}", s.parent_id)),
            );
            let mut ev = BTreeMap::new();
            ev.insert("name".to_string(), Json::Str(s.name.clone()));
            ev.insert("cat".to_string(), Json::Str("sage".to_string()));
            ev.insert("ph".to_string(), Json::Str("X".to_string()));
            ev.insert("ts".to_string(), Json::Num(s.start_unix_ns as f64 / 1_000.0));
            ev.insert("dur".to_string(), Json::Num(s.dur_ns as f64 / 1_000.0));
            ev.insert("pid".to_string(), Json::Num(s.pid as f64));
            ev.insert("tid".to_string(), Json::Num(s.tid as f64));
            ev.insert("args".to_string(), Json::Obj(args));
            Json::Obj(ev)
        })
        .collect();
    let mut root = BTreeMap::new();
    root.insert("traceEvents".to_string(), Json::Arr(events));
    root.insert(
        "displayTimeUnit".to_string(),
        Json::Str("ms".to_string()),
    );
    crate::util::json::write(&Json::Obj(root))
}

#[cfg(test)]
mod tests {
    use super::*;

    // The rings are global and thread-striped; tests that record and then
    // collect serialize here so a concurrent test filling a shared stripe
    // cannot evict their spans mid-assertion.
    static RING_LOCK: Mutex<()> = Mutex::new(());

    #[test]
    fn span_is_inert_without_active_trace() {
        assert!(current().is_none());
        assert!(span("nothing").is_none());
        assert!(current().is_none());
    }

    #[test]
    fn spans_nest_and_restore_context() {
        let _g = RING_LOCK.lock().unwrap_or_else(|e| e.into_inner());
        let root = start_trace("test.root");
        let root_ctx = root.ctx();
        assert_eq!(current(), Some(root_ctx));
        {
            let child = span("test.child").expect("trace active");
            let child_ctx = child.ctx();
            assert_eq!(child_ctx.trace_id, root_ctx.trace_id);
            assert_ne!(child_ctx.span_id, root_ctx.span_id);
            assert_eq!(current(), Some(child_ctx));
        }
        assert_eq!(current(), Some(root_ctx), "child drop restores parent");
        drop(root);
        assert!(current().is_none());

        let spans: Vec<SpanRecord> = collect()
            .into_iter()
            .filter(|s| s.trace_id == root_ctx.trace_id)
            .collect();
        assert_eq!(spans.len(), 2);
        let child_rec = spans.iter().find(|s| s.name == "test.child").unwrap();
        assert_eq!(child_rec.parent_id, root_ctx.span_id);
        let root_rec = spans.iter().find(|s| s.name == "test.root").unwrap();
        assert_eq!(root_rec.parent_id, 0);
    }

    #[test]
    fn adopt_preserves_remote_trace_and_parent() {
        let _g = RING_LOCK.lock().unwrap_or_else(|e| e.into_inner());
        let remote = TraceCtx {
            trace_id: 0xabcd,
            span_id: 0x1234,
        };
        let ctx = {
            let s = adopt("serve.request", remote);
            s.ctx()
        };
        assert_eq!(ctx.trace_id, 0xabcd);
        let rec = collect()
            .into_iter()
            .find(|s| s.span_id == ctx.span_id)
            .unwrap();
        assert_eq!(rec.parent_id, 0x1234);
        assert_eq!(rec.trace_id, 0xabcd);
    }

    #[test]
    fn ring_caps_at_capacity() {
        let _g = RING_LOCK.lock().unwrap_or_else(|e| e.into_inner());
        // All spans from one thread land in one stripe.
        let root = start_trace("cap.root");
        let trace_id = root.ctx().trace_id;
        for _ in 0..RING_CAPACITY + 10 {
            let _s = span("cap.filler");
        }
        drop(root);
        let mine = collect()
            .into_iter()
            .filter(|s| s.trace_id == trace_id)
            .count();
        assert!(mine <= RING_CAPACITY, "ring must cap, kept {mine}");
    }

    #[test]
    fn ids_are_unique_and_nonzero() {
        let mut seen = std::collections::HashSet::new();
        for _ in 0..1000 {
            let id = next_id();
            assert_ne!(id, 0);
            assert!(seen.insert(id), "duplicate id {id}");
        }
    }

    #[test]
    fn chrome_export_is_valid_json_with_ids() {
        let rec = SpanRecord {
            name: "serve.decode".to_string(),
            trace_id: 0xdead_beef,
            span_id: 5,
            parent_id: 3,
            start_unix_ns: 2_000_000,
            dur_ns: 1_500,
            pid: 42,
            tid: 7,
        };
        let out = chrome_trace_json(&[rec]);
        let parsed = crate::util::json::parse(&out).expect("valid json");
        let events = parsed.get("traceEvents").unwrap().as_arr().unwrap();
        assert_eq!(events.len(), 1);
        let ev = &events[0];
        assert_eq!(ev.get("name").unwrap().as_str(), Some("serve.decode"));
        assert_eq!(ev.get("ph").unwrap().as_str(), Some("X"));
        assert_eq!(ev.get("ts").unwrap().as_f64(), Some(2000.0));
        assert_eq!(ev.get("dur").unwrap().as_f64(), Some(1.5));
        assert_eq!(
            ev.get("args").unwrap().get("trace_id").unwrap().as_str(),
            Some("00000000deadbeef")
        );
    }
}
