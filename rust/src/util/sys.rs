//! Zero-dependency Linux readiness and scatter/gather primitives:
//! `epoll`, `eventfd`, `writev`, `accept4`.
//!
//! The crate has no external dependencies, so the reactor cannot lean on
//! mio or tokio. Instead this module declares the syscalls the event
//! loop needs (`epoll_create1`, `epoll_ctl`, `epoll_wait`, `eventfd`,
//! `writev`, `accept4`, `setsockopt`) plus `fcntl` for `O_NONBLOCK`,
//! straight against the system libc that `std` already links. Everything
//! is gated on `target_os = "linux"`; other platforms get a stub whose
//! [`epoll_supported`] returns `false` so callers fall back to the
//! portable threaded server (the gather-write helpers return
//! `Unsupported` there and callers keep the per-frame write loop).
//!
//! Safety model: every wrapper owns its fd (`close` on `Drop`), all raw
//! pointers passed across the FFI boundary come from stack or `Vec`
//! storage that outlives the call, and interest registration is keyed by
//! a caller-chosen `u64` token rather than a pointer (so no lifetime
//! escapes into the kernel).

#[cfg(target_os = "linux")]
mod linux {
    use std::io;
    use std::os::unix::io::{AsRawFd, RawFd};

    // Values from the Linux UAPI headers (asm-generic); stable ABI.
    pub const EPOLLIN: u32 = 0x001;
    pub const EPOLLOUT: u32 = 0x004;
    pub const EPOLLERR: u32 = 0x008;
    pub const EPOLLHUP: u32 = 0x010;
    pub const EPOLLRDHUP: u32 = 0x2000;

    const EPOLL_CTL_ADD: i32 = 1;
    const EPOLL_CTL_DEL: i32 = 2;
    const EPOLL_CTL_MOD: i32 = 3;
    const EPOLL_CLOEXEC: i32 = 0x80000;
    const EFD_CLOEXEC: i32 = 0x80000;
    const EFD_NONBLOCK: i32 = 0x800;
    const F_GETFL: i32 = 3;
    const F_SETFL: i32 = 4;
    const O_NONBLOCK: i32 = 0x800;
    const EINTR: i32 = 4;
    const SOCK_NONBLOCK: i32 = 0x800;
    const SOCK_CLOEXEC: i32 = 0x80000;
    const SOL_SOCKET: i32 = 1;
    const SO_SNDBUF: i32 = 7;

    /// Linux's `UIO_MAXIOV`: the kernel rejects longer iovec arrays, so
    /// [`writev`] truncates its batch to this many entries.
    pub const MAX_IOV: usize = 1024;

    /// Mirror of the kernel's `struct epoll_event`. On x86 the kernel
    /// declares it packed; elsewhere it uses natural alignment.
    #[repr(C)]
    #[cfg_attr(any(target_arch = "x86", target_arch = "x86_64"), repr(packed))]
    #[derive(Clone, Copy)]
    pub struct Event {
        events: u32,
        data: u64,
    }

    impl Event {
        pub fn zeroed() -> Self {
            Event { events: 0, data: 0 }
        }
        /// Readiness bits reported by the kernel.
        pub fn events(&self) -> u32 {
            // Copy out of the (possibly packed) struct before use.
            let e = self.events;
            e
        }
        /// The token supplied at registration time.
        pub fn token(&self) -> u64 {
            let d = self.data;
            d
        }
    }

    extern "C" {
        fn epoll_create1(flags: i32) -> i32;
        fn epoll_ctl(epfd: i32, op: i32, fd: i32, event: *mut Event) -> i32;
        fn epoll_wait(epfd: i32, events: *mut Event, maxevents: i32, timeout_ms: i32) -> i32;
        fn eventfd(initval: u32, flags: i32) -> i32;
        fn fcntl(fd: i32, cmd: i32, arg: i32) -> i32;
        fn read(fd: i32, buf: *mut u8, count: usize) -> isize;
        fn write(fd: i32, buf: *const u8, count: usize) -> isize;
        fn close(fd: i32) -> i32;
        // Aliased so the safe wrappers below can use the canonical names.
        #[link_name = "writev"]
        fn sys_writev(fd: i32, iov: *const std::ffi::c_void, iovcnt: i32) -> isize;
        #[link_name = "accept4"]
        fn sys_accept4(
            fd: i32,
            addr: *mut std::ffi::c_void,
            addrlen: *mut u32,
            flags: i32,
        ) -> i32;
        #[link_name = "setsockopt"]
        fn sys_setsockopt(
            fd: i32,
            level: i32,
            optname: i32,
            optval: *const std::ffi::c_void,
            optlen: u32,
        ) -> i32;
    }

    /// Mirror of `struct iovec` for [`writev`]. A trailing `PhantomData`
    /// ZST does not change the `repr(C)` layout, and its lifetime ties
    /// each entry to the buffer it points into, so a batch cannot outlive
    /// the frames it references (the same trick as `std::io::IoSlice`,
    /// which is not usable here because raw-fd `writev` is not exposed by
    /// std without a crate dependency).
    #[repr(C)]
    #[derive(Clone, Copy)]
    pub struct IoVec<'a> {
        base: *const u8,
        len: usize,
        _buf: std::marker::PhantomData<&'a [u8]>,
    }

    impl<'a> IoVec<'a> {
        pub fn new(buf: &'a [u8]) -> IoVec<'a> {
            IoVec {
                base: buf.as_ptr(),
                len: buf.len(),
                _buf: std::marker::PhantomData,
            }
        }

        /// Placeholder for initializing fixed-size batch arrays; callers
        /// slice the array to the filled prefix before the syscall.
        pub fn empty() -> IoVec<'static> {
            IoVec {
                base: std::ptr::null(),
                len: 0,
                _buf: std::marker::PhantomData,
            }
        }
    }

    /// Gathered write: one syscall over up to [`MAX_IOV`] buffers.
    /// Returns the byte count the kernel accepted — short counts are
    /// normal and the caller resumes from where the kernel stopped, which
    /// may be mid-buffer. `EAGAIN` surfaces as `WouldBlock` and `EINTR`
    /// as `Interrupted`, exactly like `TcpStream::write`.
    pub fn writev(fd: RawFd, iovs: &[IoVec<'_>]) -> io::Result<usize> {
        let n = iovs.len().min(MAX_IOV);
        let wrote = unsafe { sys_writev(fd, iovs.as_ptr() as *const std::ffi::c_void, n as i32) };
        if wrote < 0 {
            Err(io::Error::last_os_error())
        } else {
            Ok(wrote as usize)
        }
    }

    /// `accept4(2)` with `SOCK_NONBLOCK | SOCK_CLOEXEC`: the accepted fd
    /// is born nonblocking, skipping the `fcntl` get/set pair that
    /// `TcpListener::accept` + `set_nonblocking` costs per connection.
    /// `EINTR` retries internally; `WouldBlock` means the backlog is
    /// empty. The caller takes ownership of the returned fd.
    pub fn accept_nonblocking(listener: RawFd) -> io::Result<RawFd> {
        loop {
            let fd = unsafe {
                sys_accept4(
                    listener,
                    std::ptr::null_mut(),
                    std::ptr::null_mut(),
                    SOCK_NONBLOCK | SOCK_CLOEXEC,
                )
            };
            if fd >= 0 {
                return Ok(fd);
            }
            let err = io::Error::last_os_error();
            if err.raw_os_error() == Some(EINTR) {
                continue;
            }
            return Err(err);
        }
    }

    /// Set `SO_SNDBUF` on a socket (the kernel doubles the value for
    /// bookkeeping and clamps it to its configured range). The serve
    /// tests use tiny buffers to force short writes through the
    /// partial-write resume path.
    pub fn set_sndbuf(fd: RawFd, bytes: usize) -> io::Result<()> {
        let val = bytes.min(i32::MAX as usize) as i32;
        cvt(unsafe {
            sys_setsockopt(
                fd,
                SOL_SOCKET,
                SO_SNDBUF,
                &val as *const i32 as *const std::ffi::c_void,
                std::mem::size_of::<i32>() as u32,
            )
        })?;
        Ok(())
    }

    fn cvt(ret: i32) -> io::Result<i32> {
        if ret < 0 {
            Err(io::Error::last_os_error())
        } else {
            Ok(ret)
        }
    }

    /// Put any fd into nonblocking mode (sockets, listeners, eventfds).
    pub fn set_nonblocking(fd: RawFd) -> io::Result<()> {
        unsafe {
            let flags = cvt(fcntl(fd, F_GETFL, 0))?;
            cvt(fcntl(fd, F_SETFL, flags | O_NONBLOCK))?;
        }
        Ok(())
    }

    /// An owned `eventfd(2)` used to wake `epoll_wait` from other threads.
    ///
    /// Writes are async-signal-safe and never block (`EFD_NONBLOCK`): the
    /// counter saturates rather than queueing, which is exactly the
    /// "at-least-one wakeup" semantic a reactor wake channel needs.
    pub struct EventFd {
        fd: RawFd,
    }

    impl EventFd {
        pub fn new() -> io::Result<EventFd> {
            let fd = cvt(unsafe { eventfd(0, EFD_CLOEXEC | EFD_NONBLOCK) })?;
            Ok(EventFd { fd })
        }

        /// Wake any thread blocked in `epoll_wait` watching this fd.
        /// Safe to call from any thread, any number of times.
        pub fn wake(&self) {
            let one: u64 = 1;
            // EAGAIN means the counter is already nonzero — the wakeup is
            // pending, so losing this write is fine.
            let _ = unsafe { write(self.fd, &one as *const u64 as *const u8, 8) };
        }

        /// Consume pending wakeups so level-triggered epoll stops
        /// reporting the fd readable. Returns how many `wake` calls were
        /// coalesced (0 if none were pending).
        pub fn drain(&self) -> u64 {
            let mut buf: u64 = 0;
            let n = unsafe { read(self.fd, &mut buf as *mut u64 as *mut u8, 8) };
            if n == 8 {
                buf
            } else {
                0
            }
        }
    }

    impl AsRawFd for EventFd {
        fn as_raw_fd(&self) -> RawFd {
            self.fd
        }
    }

    impl Drop for EventFd {
        fn drop(&mut self) {
            unsafe {
                close(self.fd);
            }
        }
    }

    /// An owned epoll instance (level-triggered; the reactor re-arms
    /// interest explicitly, which keeps the state machine auditable).
    pub struct Epoll {
        fd: RawFd,
    }

    impl Epoll {
        pub fn new() -> io::Result<Epoll> {
            let fd = cvt(unsafe { epoll_create1(EPOLL_CLOEXEC) })?;
            Ok(Epoll { fd })
        }

        /// Register `fd` with the given interest mask and token.
        pub fn add(&self, fd: RawFd, token: u64, events: u32) -> io::Result<()> {
            self.ctl(EPOLL_CTL_ADD, fd, token, events)
        }

        /// Change the interest mask for an already-registered fd.
        pub fn modify(&self, fd: RawFd, token: u64, events: u32) -> io::Result<()> {
            self.ctl(EPOLL_CTL_MOD, fd, token, events)
        }

        /// Remove `fd` from the interest list.
        pub fn delete(&self, fd: RawFd) -> io::Result<()> {
            // Pre-2.6.9 kernels demanded a non-null event for DEL; pass
            // one unconditionally — it costs nothing.
            let mut ev = Event::zeroed();
            cvt(unsafe { epoll_ctl(self.fd, EPOLL_CTL_DEL, fd, &mut ev) })?;
            Ok(())
        }

        fn ctl(&self, op: i32, fd: RawFd, token: u64, events: u32) -> io::Result<()> {
            let mut ev = Event { events, data: token };
            cvt(unsafe { epoll_ctl(self.fd, op, fd, &mut ev) })?;
            Ok(())
        }

        /// Block for up to `timeout_ms` (-1 = forever) and fill `events`.
        /// Returns the number of ready entries; EINTR retries internally.
        pub fn wait(&self, events: &mut [Event], timeout_ms: i32) -> io::Result<usize> {
            loop {
                let n = unsafe {
                    epoll_wait(
                        self.fd,
                        events.as_mut_ptr(),
                        events.len() as i32,
                        timeout_ms,
                    )
                };
                if n >= 0 {
                    return Ok(n as usize);
                }
                let err = io::Error::last_os_error();
                if err.raw_os_error() == Some(EINTR) {
                    continue;
                }
                return Err(err);
            }
        }
    }

    impl Drop for Epoll {
        fn drop(&mut self) {
            unsafe {
                close(self.fd);
            }
        }
    }

    /// Whether the readiness-driven reactor can run on this host.
    pub fn epoll_supported() -> bool {
        true
    }
}

#[cfg(target_os = "linux")]
pub use linux::*;

/// Non-Linux stub: the reactor is unavailable; `sage serve --io auto`
/// falls back to the threaded server.
#[cfg(not(target_os = "linux"))]
mod fallback {
    use std::io;
    use std::os::unix::io::RawFd;

    pub const EPOLLIN: u32 = 0x001;
    pub const EPOLLOUT: u32 = 0x004;
    pub const EPOLLERR: u32 = 0x008;
    pub const EPOLLHUP: u32 = 0x010;
    pub const EPOLLRDHUP: u32 = 0x2000;

    #[derive(Clone, Copy)]
    pub struct Event;

    impl Event {
        pub fn zeroed() -> Self {
            Event
        }
        pub fn events(&self) -> u32 {
            0
        }
        pub fn token(&self) -> u64 {
            0
        }
    }

    fn unsupported() -> io::Error {
        io::Error::new(io::ErrorKind::Unsupported, "epoll is Linux-only")
    }

    pub fn set_nonblocking(_fd: RawFd) -> io::Result<()> {
        Err(unsupported())
    }

    pub const MAX_IOV: usize = 1024;

    #[derive(Clone, Copy)]
    pub struct IoVec<'a> {
        _buf: std::marker::PhantomData<&'a [u8]>,
    }

    impl<'a> IoVec<'a> {
        pub fn new(_buf: &'a [u8]) -> IoVec<'a> {
            IoVec {
                _buf: std::marker::PhantomData,
            }
        }
        pub fn empty() -> IoVec<'static> {
            IoVec {
                _buf: std::marker::PhantomData,
            }
        }
    }

    pub fn writev(_fd: RawFd, _iovs: &[IoVec<'_>]) -> io::Result<usize> {
        Err(unsupported())
    }

    pub fn accept_nonblocking(_listener: RawFd) -> io::Result<RawFd> {
        Err(unsupported())
    }

    pub fn set_sndbuf(_fd: RawFd, _bytes: usize) -> io::Result<()> {
        Err(unsupported())
    }

    pub struct EventFd;

    impl EventFd {
        pub fn new() -> io::Result<EventFd> {
            Err(unsupported())
        }
        pub fn wake(&self) {}
        pub fn drain(&self) -> u64 {
            0
        }
    }

    pub struct Epoll;

    impl Epoll {
        pub fn new() -> io::Result<Epoll> {
            Err(unsupported())
        }
        pub fn add(&self, _fd: RawFd, _token: u64, _events: u32) -> io::Result<()> {
            Err(unsupported())
        }
        pub fn modify(&self, _fd: RawFd, _token: u64, _events: u32) -> io::Result<()> {
            Err(unsupported())
        }
        pub fn delete(&self, _fd: RawFd) -> io::Result<()> {
            Err(unsupported())
        }
        pub fn wait(&self, _events: &mut [Event], _timeout_ms: i32) -> io::Result<usize> {
            Err(unsupported())
        }
    }

    pub fn epoll_supported() -> bool {
        false
    }
}

#[cfg(not(target_os = "linux"))]
pub use fallback::*;

#[cfg(all(test, target_os = "linux"))]
mod tests {
    use super::*;
    use std::io::{Read as _, Write as _};
    use std::net::{TcpListener, TcpStream};
    use std::os::unix::io::AsRawFd;

    #[test]
    fn eventfd_wakes_epoll_and_drains() {
        let ep = Epoll::new().unwrap();
        let ev = EventFd::new().unwrap();
        ep.add(ev.as_raw_fd(), 7, EPOLLIN).unwrap();

        let mut events = vec![Event::zeroed(); 8];
        // Nothing pending: a zero-timeout wait reports no readiness.
        assert_eq!(ep.wait(&mut events, 0).unwrap(), 0);

        ev.wake();
        ev.wake(); // coalesces with the first
        let n = ep.wait(&mut events, 1000).unwrap();
        assert_eq!(n, 1);
        assert_eq!(events[0].token(), 7);
        assert!(events[0].events() & EPOLLIN != 0);
        assert_eq!(ev.drain(), 2);
        // Drained: level-triggered readiness clears.
        assert_eq!(ep.wait(&mut events, 0).unwrap(), 0);
        assert_eq!(ev.drain(), 0);
    }

    #[test]
    fn wake_from_another_thread_unblocks_wait() {
        let ep = Epoll::new().unwrap();
        let ev = std::sync::Arc::new(EventFd::new().unwrap());
        ep.add(ev.as_raw_fd(), 1, EPOLLIN).unwrap();
        let ev2 = ev.clone();
        let t = std::thread::spawn(move || {
            std::thread::sleep(std::time::Duration::from_millis(50));
            ev2.wake();
        });
        let mut events = vec![Event::zeroed(); 4];
        let n = ep.wait(&mut events, 5000).unwrap();
        assert_eq!(n, 1);
        t.join().unwrap();
    }

    #[test]
    fn socket_readiness_and_interest_rewrites() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let ep = Epoll::new().unwrap();
        ep.add(listener.as_raw_fd(), 10, EPOLLIN).unwrap();

        let mut events = vec![Event::zeroed(); 8];
        assert_eq!(ep.wait(&mut events, 0).unwrap(), 0);

        let mut client = TcpStream::connect(addr).unwrap();
        let n = ep.wait(&mut events, 5000).unwrap();
        assert_eq!(n, 1);
        assert_eq!(events[0].token(), 10);

        let (mut server_side, _) = listener.accept().unwrap();
        set_nonblocking(server_side.as_raw_fd()).unwrap();
        ep.add(server_side.as_raw_fd(), 11, EPOLLIN | EPOLLRDHUP)
            .unwrap();
        client.write_all(b"ping").unwrap();
        // Wait until the connection token reports readable.
        let mut saw = false;
        for _ in 0..100 {
            let n = ep.wait(&mut events, 100).unwrap();
            if events[..n].iter().any(|e| e.token() == 11) {
                saw = true;
                break;
            }
        }
        assert!(saw, "connection never became readable");
        let mut buf = [0u8; 16];
        assert_eq!(server_side.read(&mut buf).unwrap(), 4);

        // MOD to write interest, then DEL; both must succeed.
        ep.modify(server_side.as_raw_fd(), 11, EPOLLOUT).unwrap();
        let n = ep.wait(&mut events, 1000).unwrap();
        assert!(events[..n].iter().any(|e| e.token() == 11));
        ep.delete(server_side.as_raw_fd()).unwrap();
        assert_eq!(ep.wait(&mut events, 0).unwrap(), 0);
    }

    #[test]
    fn accept4_yields_nonblocking_fd_and_writev_gathers() {
        use std::os::unix::io::FromRawFd;

        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        set_nonblocking(listener.as_raw_fd()).unwrap();

        // Empty backlog: accept4 reports WouldBlock instead of blocking.
        let err = accept_nonblocking(listener.as_raw_fd()).unwrap_err();
        assert_eq!(err.kind(), std::io::ErrorKind::WouldBlock);

        let mut client = TcpStream::connect(addr).unwrap();
        let fd = loop {
            match accept_nonblocking(listener.as_raw_fd()) {
                Ok(fd) => break fd,
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    std::thread::sleep(std::time::Duration::from_millis(5));
                }
                Err(e) => panic!("accept4 failed: {e}"),
            }
        };
        let server_side = unsafe { TcpStream::from_raw_fd(fd) };

        // SOCK_NONBLOCK held: a read with no pending data must not block.
        let mut probe = [0u8; 1];
        let err = (&server_side).read(&mut probe).unwrap_err();
        assert_eq!(err.kind(), std::io::ErrorKind::WouldBlock);

        set_sndbuf(server_side.as_raw_fd(), 4096).unwrap();

        // One gathered write over three buffers arrives as one byte run.
        let parts: [&[u8]; 3] = [b"hel", b"lo ", b"iovec"];
        let iovs: Vec<IoVec<'_>> = parts.iter().map(|p| IoVec::new(p)).collect();
        let n = writev(server_side.as_raw_fd(), &iovs).unwrap();
        assert_eq!(n, 11);
        let mut got = [0u8; 11];
        client.read_exact(&mut got).unwrap();
        assert_eq!(&got, b"hello iovec");
    }

    #[test]
    fn nonblocking_read_returns_would_block() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let _client = TcpStream::connect(addr).unwrap();
        let (mut server_side, _) = listener.accept().unwrap();
        set_nonblocking(server_side.as_raw_fd()).unwrap();
        let mut buf = [0u8; 4];
        let err = server_side.read(&mut buf).unwrap_err();
        assert_eq!(err.kind(), std::io::ErrorKind::WouldBlock);
    }
}
