//! Subset trainer: trains the L2 model on a selected subset via a
//! [`ModelBackend`], following the paper's recipe (SGD + momentum 0.9,
//! weight decay 5e-4, label smoothing 0.1 — baked into the artifacts — and
//! a cosine LR schedule owned here).
//!
//! Batching uses wrap-around sampling so every step feeds the artifact's
//! static `bt`-row batch exactly (no padding bias in the mean loss).

pub mod checkpoint;

pub use checkpoint::Checkpoint;

use crate::data::Dataset;
use crate::runtime::ModelBackend;
use crate::tensor::Matrix;
use crate::util::rng::{AliasSampler, Pcg64};
use std::time::Instant;

/// Trainer configuration (model hyper-params live in the backend/manifest).
#[derive(Clone, Debug)]
pub struct TrainConfig {
    pub epochs: usize,
    pub base_lr: f64,
    pub seed: u64,
    /// Record the loss every `log_every` steps (0 = only epoch ends).
    pub log_every: usize,
    /// Cosine floor as a fraction of base_lr.
    pub min_lr_frac: f64,
    /// Periodic checkpointing (None = off).
    pub checkpoint_path: Option<std::path::PathBuf>,
    /// Save every this many steps (also saved at the end). 0 = end only.
    pub checkpoint_every: usize,
    /// Resume from checkpoint_path when it exists and matches the schedule.
    pub resume: bool,
}

impl Default for TrainConfig {
    fn default() -> Self {
        Self {
            epochs: 10,
            base_lr: 0.05,
            seed: 0,
            log_every: 0,
            min_lr_frac: 0.01,
            checkpoint_path: None,
            checkpoint_every: 0,
            resume: false,
        }
    }
}

/// Output of one training run.
#[derive(Clone, Debug)]
pub struct TrainResult {
    pub steps: usize,
    pub final_loss: f32,
    /// (step, loss) samples.
    pub loss_curve: Vec<(usize, f32)>,
    /// Top-1 accuracy on the test set.
    pub test_accuracy: f64,
    /// Wall-clock training seconds (excludes selection).
    pub train_seconds: f64,
    /// Final parameters (for further eval / checkpointing).
    pub params: Vec<f32>,
}

/// Cosine learning rate at step `t` of `total`.
pub fn cosine_lr(base: f64, min_frac: f64, t: usize, total: usize) -> f64 {
    if total <= 1 {
        return base;
    }
    let min_lr = base * min_frac;
    let progress = t as f64 / (total - 1) as f64;
    min_lr + 0.5 * (base - min_lr) * (1.0 + (std::f64::consts::PI * progress).cos())
}

/// Assemble a `bt`-row batch from dataset rows (wrap-around indices).
fn gather_batch(ds: &Dataset, order: &[usize], start: usize, bt: usize) -> (Matrix, Matrix) {
    let n = order.len();
    let f = ds.features.cols();
    let c = ds.num_classes;
    let mut x = Matrix::zeros(bt, f);
    let mut y = Matrix::zeros(bt, c);
    for r in 0..bt {
        let i = order[(start + r) % n];
        x.row_mut(r).copy_from_slice(ds.features.row(i));
        y.set(r, ds.labels[i] as usize, 1.0);
    }
    (x, y)
}

/// Train on `train` (already the selected subset), evaluate on `test`.
pub fn train(
    backend: &dyn ModelBackend,
    train_ds: &Dataset,
    test_ds: &Dataset,
    cfg: &TrainConfig,
) -> Result<TrainResult, String> {
    train_weighted(backend, train_ds, test_ds, cfg, None)
}

/// Weighted variant: when `weights` (one per subset example, non-negative)
/// is given, batches are assembled by weighted sampling with replacement
/// (Walker alias method) instead of shuffled epochs — CRAIG's weighted
/// coreset training, equivalent in expectation to weighting the loss.
pub fn train_weighted(
    backend: &dyn ModelBackend,
    train_ds: &Dataset,
    test_ds: &Dataset,
    cfg: &TrainConfig,
    weights: Option<&[f32]>,
) -> Result<TrainResult, String> {
    if train_ds.is_empty() {
        return Err("empty training set".into());
    }
    let spec = backend.spec();
    if train_ds.features.cols() != spec.f {
        return Err(format!(
            "dataset features {} != model {}",
            train_ds.features.cols(),
            spec.f
        ));
    }
    if train_ds.num_classes != spec.c {
        return Err(format!(
            "dataset classes {} != model {}",
            train_ds.num_classes, spec.c
        ));
    }
    let bt = backend.train_batch();
    let steps_per_epoch = train_ds.len().div_ceil(bt).max(1);
    let total_steps = steps_per_epoch * cfg.epochs;

    let sampler = match weights {
        Some(w) => {
            if w.len() != train_ds.len() {
                return Err(format!(
                    "weights len {} != subset len {}",
                    w.len(),
                    train_ds.len()
                ));
            }
            let w64: Vec<f64> = w.iter().map(|&x| x as f64).collect();
            Some(AliasSampler::new(&w64)?)
        }
        None => None,
    };

    let mut rng = Pcg64::new(cfg.seed, 0x7E41);
    let mut params = spec.init_params(&mut rng);
    let mut mom = vec![0.0f32; spec.d()];
    let mut order: Vec<usize> = (0..train_ds.len()).collect();

    // Resume from a valid matching checkpoint if asked.
    let mut resume_step = 0usize;
    if cfg.resume {
        if let Some(path) = &cfg.checkpoint_path {
            if path.exists() {
                let ck = Checkpoint::load(path)?;
                if ck.params.len() == spec.d() && ck.total_steps == total_steps as u64 {
                    params = ck.params;
                    mom = ck.momentum;
                    resume_step = ck.step as usize;
                    crate::log_info!(
                        "resumed from {} at step {resume_step}/{total_steps}",
                        path.display()
                    );
                } else {
                    return Err(format!(
                        "checkpoint {} does not match schedule (d={} total={})",
                        path.display(),
                        ck.params.len(),
                        ck.total_steps
                    ));
                }
            }
        }
    }

    let mut loss_curve = Vec::new();
    let mut final_loss = f32::NAN;
    let start = Instant::now();
    let mut step = 0usize;
    let mut widx = vec![0usize; bt];
    'epochs: for _epoch in 0..cfg.epochs {
        rng.shuffle(&mut order);
        for s in 0..steps_per_epoch {
            if step >= total_steps {
                break 'epochs;
            }
            // Keep the RNG stream identical when resuming: draw sampling
            // indices regardless, skip the compute for replayed steps.
            let (x, y) = if let Some(sampler) = &sampler {
                for slot in widx.iter_mut() {
                    *slot = sampler.sample(&mut rng);
                }
                gather_batch(train_ds, &widx, 0, bt)
            } else {
                gather_batch(train_ds, &order, s * bt, bt)
            };
            if step < resume_step {
                step += 1;
                continue;
            }
            let lr = cosine_lr(cfg.base_lr, cfg.min_lr_frac, step, total_steps) as f32;
            let loss = backend.train_step(&mut params, &mut mom, &x, &y, lr)?;
            final_loss = loss;
            if (cfg.log_every > 0 && step % cfg.log_every == 0) || s + 1 == steps_per_epoch {
                loss_curve.push((step, loss));
            }
            step += 1;
            if let (Some(path), every) = (&cfg.checkpoint_path, cfg.checkpoint_every) {
                if every > 0 && step % every == 0 {
                    Checkpoint::new(step as u64, total_steps as u64, params.clone(), mom.clone())
                        .save(path)
                        .map_err(|e| format!("checkpoint save: {e}"))?;
                }
            }
        }
    }
    if let Some(path) = &cfg.checkpoint_path {
        Checkpoint::new(step as u64, total_steps as u64, params.clone(), mom.clone())
            .save(path)
            .map_err(|e| format!("checkpoint save: {e}"))?;
    }
    let train_seconds = start.elapsed().as_secs_f64();

    let test_accuracy = backend.accuracy(&params, &test_ds.features, &test_ds.labels)?;
    Ok(TrainResult {
        steps: step,
        final_loss,
        loss_curve,
        test_accuracy,
        train_seconds,
        params,
    })
}

/// Warm up a fresh model for selection-time gradients: a few steps on
/// random batches so per-example gradients carry label signal. Returns the
/// warmed parameters (the paper computes selection gradients at the current
/// model state before freezing the subset).
pub fn warmup_params(
    backend: &dyn ModelBackend,
    ds: &Dataset,
    steps: usize,
    base_lr: f64,
    seed: u64,
) -> Result<Vec<f32>, String> {
    let spec = backend.spec();
    let mut rng = Pcg64::new(seed, 0x3A97);
    let mut params = spec.init_params(&mut rng);
    let mut mom = vec![0.0f32; spec.d()];
    let bt = backend.train_batch();
    let mut order: Vec<usize> = (0..ds.len()).collect();
    rng.shuffle(&mut order);
    for s in 0..steps {
        let (x, y) = gather_batch(ds, &order, s * bt, bt);
        backend.train_step(&mut params, &mut mom, &x, &y, base_lr as f32)?;
    }
    Ok(params)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{generate, BenchmarkKind};
    use crate::grad::{MlpSpec, TrainHyper};
    use crate::runtime::ReferenceModelBackend;

    fn backend() -> ReferenceModelBackend {
        ReferenceModelBackend::new(MlpSpec::new(8, 16, 10), TrainHyper::default(), 16, 16, 8)
    }

    fn datasets() -> (Dataset, Dataset) {
        let spec = BenchmarkKind::Cifar10.spec(8);
        (generate(&spec, 256, 1, 0), generate(&spec, 128, 1, 1))
    }

    #[test]
    fn cosine_schedule_endpoints_and_monotonicity() {
        let total = 100;
        let first = cosine_lr(0.1, 0.01, 0, total);
        let mid = cosine_lr(0.1, 0.01, 50, total);
        let last = cosine_lr(0.1, 0.01, 99, total);
        assert!((first - 0.1).abs() < 1e-12);
        assert!((last - 0.001).abs() < 1e-9);
        assert!(first > mid && mid > last);
    }

    #[test]
    fn training_learns_synthetic_mixture() {
        let (tr, te) = datasets();
        let cfg = TrainConfig {
            epochs: 8,
            base_lr: 0.1,
            seed: 3,
            ..Default::default()
        };
        let res = train(&backend(), &tr, &te, &cfg).unwrap();
        assert!(res.test_accuracy > 0.5, "acc {}", res.test_accuracy);
        assert!(res.final_loss < 2.0, "loss {}", res.final_loss);
        assert_eq!(res.steps, 8 * 16);
        assert!(!res.loss_curve.is_empty());
    }

    #[test]
    fn deterministic_given_seed() {
        let (tr, te) = datasets();
        let cfg = TrainConfig {
            epochs: 2,
            seed: 9,
            ..Default::default()
        };
        let a = train(&backend(), &tr, &te, &cfg).unwrap();
        let b = train(&backend(), &tr, &te, &cfg).unwrap();
        assert_eq!(a.params, b.params);
        assert_eq!(a.test_accuracy, b.test_accuracy);
    }

    #[test]
    fn subset_smaller_than_batch_still_trains() {
        let (tr, te) = datasets();
        let sub = tr.subset(&(0..5).collect::<Vec<_>>());
        let cfg = TrainConfig {
            epochs: 2,
            ..Default::default()
        };
        let res = train(&backend(), &sub, &te, &cfg).unwrap();
        assert_eq!(res.steps, 2); // 1 wrap-around step per epoch
    }

    #[test]
    fn mismatched_dataset_rejected() {
        let (tr, te) = datasets();
        let bad = ReferenceModelBackend::new(
            MlpSpec::new(99, 16, 10),
            TrainHyper::default(),
            16,
            16,
            8,
        );
        assert!(train(&bad, &tr, &te, &TrainConfig::default()).is_err());
    }

    #[test]
    fn warmup_changes_params() {
        let (tr, _te) = datasets();
        let b = backend();
        let warmed = warmup_params(&b, &tr, 10, 0.05, 1).unwrap();
        let mut rng = Pcg64::new(1, 0x3A97);
        let fresh = b.spec().init_params(&mut rng);
        assert_eq!(warmed.len(), fresh.len());
        assert!(warmed.iter().zip(&fresh).any(|(a, b)| a != b));
    }
}
