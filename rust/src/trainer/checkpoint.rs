//! Training checkpoints: params + momentum + schedule position, with an
//! integrity checksum so a torn write never resumes silently.
//!
//! Layout (little-endian):
//!
//! ```text
//! magic    8B  "SAGECKPT"
//! version  u32
//! step     u64
//! total    u64   (total steps of the schedule being resumed)
//! d        u64
//! params   d x f32
//! mom      d x f32
//! fnv64    u64   (checksum of everything above)
//! ```

use std::io::{Read, Write};
use std::path::Path;

const MAGIC: &[u8; 8] = b"SAGECKPT";
const VERSION: u32 = 1;

/// A resumable training state.
#[derive(Clone, Debug, PartialEq)]
pub struct Checkpoint {
    pub step: u64,
    pub total_steps: u64,
    pub params: Vec<f32>,
    pub momentum: Vec<f32>,
}

fn fnv64(bytes: &[u8]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100_0000_01b3);
    }
    h
}

impl Checkpoint {
    pub fn new(step: u64, total_steps: u64, params: Vec<f32>, momentum: Vec<f32>) -> Self {
        assert_eq!(params.len(), momentum.len());
        Self {
            step,
            total_steps,
            params,
            momentum,
        }
    }

    fn body_bytes(&self) -> Vec<u8> {
        let d = self.params.len();
        let mut out = Vec::with_capacity(8 + 4 + 8 + 8 + 8 + d * 8);
        out.extend_from_slice(MAGIC);
        out.extend_from_slice(&VERSION.to_le_bytes());
        out.extend_from_slice(&self.step.to_le_bytes());
        out.extend_from_slice(&self.total_steps.to_le_bytes());
        out.extend_from_slice(&(d as u64).to_le_bytes());
        for &v in &self.params {
            out.extend_from_slice(&v.to_le_bytes());
        }
        for &v in &self.momentum {
            out.extend_from_slice(&v.to_le_bytes());
        }
        out
    }

    /// Write atomically (tmp file + rename).
    pub fn save(&self, path: &Path) -> std::io::Result<()> {
        if let Some(parent) = path.parent() {
            if !parent.as_os_str().is_empty() {
                std::fs::create_dir_all(parent)?;
            }
        }
        let body = self.body_bytes();
        let sum = fnv64(&body);
        let tmp = path.with_extension("tmp");
        {
            let mut f = std::io::BufWriter::new(std::fs::File::create(&tmp)?);
            f.write_all(&body)?;
            f.write_all(&sum.to_le_bytes())?;
            f.flush()?;
        }
        std::fs::rename(&tmp, path)
    }

    pub fn load(path: &Path) -> Result<Checkpoint, String> {
        let mut bytes = Vec::new();
        std::fs::File::open(path)
            .map_err(|e| format!("{}: {e}", path.display()))?
            .read_to_end(&mut bytes)
            .map_err(|e| format!("{}: {e}", path.display()))?;
        if bytes.len() < 8 + 4 + 8 + 8 + 8 + 8 {
            return Err("checkpoint truncated".into());
        }
        let (body, sum_bytes) = bytes.split_at(bytes.len() - 8);
        let stored = u64::from_le_bytes(sum_bytes.try_into().unwrap());
        if fnv64(body) != stored {
            return Err("checkpoint checksum mismatch (torn write?)".into());
        }
        if &body[..8] != MAGIC {
            return Err("bad checkpoint magic".into());
        }
        let version = u32::from_le_bytes(body[8..12].try_into().unwrap());
        if version != VERSION {
            return Err(format!("checkpoint version {version} != {VERSION}"));
        }
        let step = u64::from_le_bytes(body[12..20].try_into().unwrap());
        let total_steps = u64::from_le_bytes(body[20..28].try_into().unwrap());
        let d = u64::from_le_bytes(body[28..36].try_into().unwrap()) as usize;
        let expect = 36 + d * 8;
        if body.len() != expect {
            return Err(format!(
                "checkpoint length {} != expected {expect} for d={d}",
                body.len()
            ));
        }
        let mut params = Vec::with_capacity(d);
        let mut momentum = Vec::with_capacity(d);
        for i in 0..d {
            let off = 36 + i * 4;
            params.push(f32::from_le_bytes(body[off..off + 4].try_into().unwrap()));
        }
        for i in 0..d {
            let off = 36 + (d + i) * 4;
            momentum.push(f32::from_le_bytes(body[off..off + 4].try_into().unwrap()));
        }
        Ok(Checkpoint {
            step,
            total_steps,
            params,
            momentum,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("sage_ckpt_{name}_{}", std::process::id()))
    }

    fn sample() -> Checkpoint {
        Checkpoint::new(
            42,
            100,
            vec![1.0, -2.5, 3.25, 0.0],
            vec![0.1, 0.2, -0.3, 0.0],
        )
    }

    #[test]
    fn round_trip() {
        let path = tmp("rt");
        let ck = sample();
        ck.save(&path).unwrap();
        let back = Checkpoint::load(&path).unwrap();
        assert_eq!(back, ck);
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn detects_corruption() {
        let path = tmp("corrupt");
        sample().save(&path).unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0xFF;
        std::fs::write(&path, &bytes).unwrap();
        assert!(Checkpoint::load(&path).unwrap_err().contains("checksum"));
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn detects_truncation() {
        let path = tmp("trunc");
        sample().save(&path).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        std::fs::write(&path, &bytes[..bytes.len() - 9]).unwrap();
        assert!(Checkpoint::load(&path).is_err());
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn rejects_wrong_magic() {
        let path = tmp("magic");
        let mut bytes = sample().body_bytes();
        bytes[0] = b'X';
        let sum = super::fnv64(&bytes);
        bytes.extend_from_slice(&sum.to_le_bytes());
        std::fs::write(&path, &bytes).unwrap();
        assert!(Checkpoint::load(&path).unwrap_err().contains("magic"));
        std::fs::remove_file(&path).unwrap();
    }
}
