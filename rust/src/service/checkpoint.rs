//! Session persistence — the service's durable form of a sketch session,
//! using the same framing discipline as `trainer::checkpoint`: versioned
//! magic header, little-endian body, FNV-64 trailer, atomic tmp+rename
//! writes. A torn write never recovers silently.
//!
//! Layout:
//!
//! ```text
//! magic    8B   "SAGESES1"
//! body          PayloadWriter fields:
//!   version u32
//!   name    str
//!   ell     u32
//!   d       u32
//!   shards  u32
//!   frozen  u8
//!   if frozen == 0:  shards × SketchState
//!   if frozen == 1:  sketch matrix + shift_bound f64 + shrinks u64
//!                    + rows_seen u64 + sketch_bytes u64
//! fnv64    8B   checksum of magic + body
//! ```

use super::protocol::{fnv64, FrozenSketch, PayloadReader, PayloadWriter};
use crate::sketch::SketchState;
use std::io::Write;
use std::path::Path;

const MAGIC: &[u8; 8] = b"SAGESES1";
const VERSION: u32 = 1;

/// Durable snapshot of one session (either still ingesting — per-shard
/// sketch states — or frozen — the merged sketch and its certificate).
#[derive(Clone, Debug, PartialEq)]
pub struct SessionCheckpoint {
    pub name: String,
    pub ell: u32,
    pub d: u32,
    pub shards: u32,
    /// Per-shard sketch states; empty when `frozen` is set.
    pub shard_states: Vec<SketchState>,
    pub frozen: Option<FrozenSketch>,
}

impl SessionCheckpoint {
    fn body_bytes(&self) -> Vec<u8> {
        let mut w = PayloadWriter::new();
        w.put_u32(VERSION);
        w.put_str(&self.name);
        w.put_u32(self.ell);
        w.put_u32(self.d);
        w.put_u32(self.shards);
        match &self.frozen {
            None => {
                w.put_u8(0);
                for st in &self.shard_states {
                    w.put_u32(st.ell);
                    w.put_u32(st.d);
                    w.put_u32(st.next_row);
                    w.put_u64(st.shrink_count);
                    w.put_u64(st.rows_seen);
                    w.put_f64(st.delta_sum);
                    w.put_f64(st.energy_seen);
                    w.put_f32_slice(&st.buf);
                }
            }
            Some(f) => {
                w.put_u8(1);
                w.put_matrix(&f.sketch);
                w.put_f64(f.shift_bound);
                w.put_u64(f.shrinks);
                w.put_u64(f.rows_seen);
                w.put_u64(f.sketch_bytes);
            }
        }
        w.into_bytes()
    }

    /// Write atomically (tmp file + rename), creating parent dirs.
    pub fn save(&self, path: &Path) -> Result<(), String> {
        if let Some(parent) = path.parent() {
            if !parent.as_os_str().is_empty() {
                std::fs::create_dir_all(parent)
                    .map_err(|e| format!("{}: {e}", parent.display()))?;
            }
        }
        let body = self.body_bytes();
        let mut out = Vec::with_capacity(8 + body.len() + 8);
        out.extend_from_slice(MAGIC);
        out.extend_from_slice(&body);
        let sum = fnv64(&out);
        out.extend_from_slice(&sum.to_le_bytes());
        let tmp = path.with_extension("tmp");
        {
            let mut f = std::io::BufWriter::new(
                std::fs::File::create(&tmp).map_err(|e| format!("{}: {e}", tmp.display()))?,
            );
            f.write_all(&out).map_err(|e| e.to_string())?;
            f.flush().map_err(|e| e.to_string())?;
        }
        std::fs::rename(&tmp, path).map_err(|e| format!("{}: {e}", path.display()))
    }

    pub fn load(path: &Path) -> Result<SessionCheckpoint, String> {
        let bytes = std::fs::read(path).map_err(|e| format!("{}: {e}", path.display()))?;
        if bytes.len() < 8 + 8 {
            return Err("session checkpoint truncated".into());
        }
        let (body_with_magic, sum_bytes) = bytes.split_at(bytes.len() - 8);
        let stored = u64::from_le_bytes(sum_bytes.try_into().unwrap());
        if fnv64(body_with_magic) != stored {
            return Err("session checkpoint checksum mismatch (torn write?)".into());
        }
        if &body_with_magic[..8] != MAGIC {
            return Err("bad session checkpoint magic".into());
        }
        let mut r = PayloadReader::new(&body_with_magic[8..]);
        let version = r.u32()?;
        if version != VERSION {
            return Err(format!("session checkpoint version {version} != {VERSION}"));
        }
        let name = r.str()?;
        let ell = r.u32()?;
        let d = r.u32()?;
        let shards = r.u32()?;
        let (shard_states, frozen) = match r.u8()? {
            0 => {
                let mut states = Vec::with_capacity((shards as usize).min(1024));
                for _ in 0..shards {
                    states.push(SketchState {
                        ell: r.u32()?,
                        d: r.u32()?,
                        next_row: r.u32()?,
                        shrink_count: r.u64()?,
                        rows_seen: r.u64()?,
                        delta_sum: r.f64()?,
                        energy_seen: r.f64()?,
                        buf: r.f32_slice()?,
                    });
                }
                (states, None)
            }
            1 => {
                let frozen = FrozenSketch {
                    sketch: r.matrix()?,
                    shift_bound: r.f64()?,
                    shrinks: r.u64()?,
                    rows_seen: r.u64()?,
                    sketch_bytes: r.u64()?,
                };
                (Vec::new(), Some(frozen))
            }
            other => return Err(format!("session checkpoint: bad frozen tag {other}")),
        };
        r.finish()?;
        Ok(SessionCheckpoint {
            name,
            ell,
            d,
            shards,
            shard_states,
            frozen,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sketch::FdSketch;
    use crate::tensor::Matrix;
    use std::path::PathBuf;

    fn tmp(name: &str) -> PathBuf {
        std::env::temp_dir().join(format!("sage_sess_ckpt_{name}_{}", std::process::id()))
    }

    fn active_sample() -> SessionCheckpoint {
        let mut s0 = FdSketch::new(2, 4);
        let mut s1 = FdSketch::new(2, 4);
        s0.insert_batch(&Matrix::from_fn(5, 4, |r, c| (r * 4 + c) as f32 * 0.1));
        s1.insert_batch(&Matrix::from_fn(3, 4, |r, c| (r + c) as f32 * -0.2));
        SessionCheckpoint {
            name: "act".into(),
            ell: 2,
            d: 4,
            shards: 2,
            shard_states: vec![s0.export_state(), s1.export_state()],
            frozen: None,
        }
    }

    fn frozen_sample() -> SessionCheckpoint {
        SessionCheckpoint {
            name: "frz".into(),
            ell: 2,
            d: 4,
            shards: 2,
            shard_states: Vec::new(),
            frozen: Some(FrozenSketch {
                sketch: Matrix::from_fn(2, 4, |r, c| (r * 4 + c) as f32),
                shift_bound: 0.5,
                shrinks: 2,
                rows_seen: 8,
                sketch_bytes: 64,
            }),
        }
    }

    #[test]
    fn active_round_trip() {
        let path = tmp("act");
        let ck = active_sample();
        ck.save(&path).unwrap();
        let back = SessionCheckpoint::load(&path).unwrap();
        assert_eq!(back, ck);
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn frozen_round_trip() {
        let path = tmp("frz");
        let ck = frozen_sample();
        ck.save(&path).unwrap();
        let back = SessionCheckpoint::load(&path).unwrap();
        assert_eq!(back, ck);
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn corruption_detected() {
        let path = tmp("corrupt");
        active_sample().save(&path).unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0x55;
        std::fs::write(&path, &bytes).unwrap();
        assert!(SessionCheckpoint::load(&path)
            .unwrap_err()
            .contains("checksum"));
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn truncation_detected() {
        let path = tmp("trunc");
        frozen_sample().save(&path).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        std::fs::write(&path, &bytes[..bytes.len() - 11]).unwrap();
        assert!(SessionCheckpoint::load(&path).is_err());
        std::fs::remove_file(&path).unwrap();
    }
}
