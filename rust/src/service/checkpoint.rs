//! Session persistence — the service's durable form of a sketch session,
//! using the same framing discipline as `trainer::checkpoint`: versioned
//! magic header, little-endian body, FNV-64 trailer, atomic tmp+rename
//! writes. A torn write never recovers silently.
//!
//! Version 2 appends the session's Phase-II state (per-shard
//! [`ScorerState`] slots and the finalized [`ScoresState`] cache) so a
//! checkpoint→recover cycle restores scoring **bit-exactly**: the f64
//! consensus accumulators round-trip as raw bits and a recovered session's
//! TopK equals the pre-crash TopK. The same file doubles as the spill
//! target when the registry evicts score caches under scorer-budget
//! pressure (see `service::registry`). Version 3 appends the session's WAL
//! replay watermark (`wal_seq`): on recovery the registry skips log
//! records at or below it (see `service::wal`). Version-1 files (no
//! Phase-II section) and version-2 files (no watermark) still load;
//! scoring then starts fresh / replay starts from the log's beginning.
//!
//! Writes are atomic against crashes: the image goes to a sibling temp
//! file, is fsynced, and only then renamed over the previous checkpoint —
//! a crash at any byte leaves either the complete old file or the complete
//! new one, never a torn mix (`mid_write_failure_never_corrupts_...`
//! injects exactly that crash).
//!
//! Layout:
//!
//! ```text
//! magic    8B   "SAGESES1"
//! body          PayloadWriter fields:
//!   version u32   (3; readers accept 1 and 2)
//!   name    str
//!   ell     u32
//!   d       u32
//!   shards  u32
//!   frozen  u8
//!   if frozen == 0:  shards × SketchState
//!   if frozen == 1:  sketch matrix + shift_bound f64 + shrinks u64
//!                    + rows_seen u64 + sketch_bytes u64
//!   -- version ≥ 2 only --
//!   scorer_slots u32
//!   scorer_slots × (present u8; if 1: ScorerState fields)
//!   scores_present u8; if 1: ScoresState fields
//!   -- version ≥ 3 only --
//!   wal_seq u64
//! fnv64    8B   checksum of magic + body
//! ```

use super::protocol::{fnv64, FrozenSketch, PayloadReader, PayloadWriter};
use crate::selection::{ScorerState, ScoresState};
use crate::sketch::SketchState;
use std::io::Write;
use std::path::Path;

const MAGIC: &[u8; 8] = b"SAGESES1";
const VERSION: u32 = 3;

/// Durable snapshot of one session: Phase-I state (either still ingesting —
/// per-shard sketch states — or frozen — the merged sketch and its
/// certificate) plus the Phase-II scorer state (v2).
#[derive(Clone, Debug, PartialEq)]
pub struct SessionCheckpoint {
    pub name: String,
    pub ell: u32,
    pub d: u32,
    pub shards: u32,
    /// Per-shard sketch states; empty when `frozen` is set.
    pub shard_states: Vec<SketchState>,
    pub frozen: Option<FrozenSketch>,
    /// Per-shard Phase-II scorer slots (`None` once finalized). Empty for
    /// legacy v1 files — recovery then starts scoring fresh.
    pub scorers: Vec<Option<ScorerState>>,
    /// Finalized score cache, present after a served TopK finalized scores.
    pub scores: Option<ScoresState>,
    /// WAL replay watermark: the highest log sequence number whose effect
    /// is already contained in this snapshot. Recovery skips records at or
    /// below it. 0 for pre-v3 files and for sessions without a WAL.
    pub wal_seq: u64,
}

fn write_scorer_state(w: &mut PayloadWriter, st: &ScorerState) {
    w.put_u32(st.ell);
    w.put_u64(st.count);
    w.put_f64_slice(&st.consensus_acc);
    w.put_u64_slice(&st.indices);
    w.put_u32_slice(&st.labels);
    w.put_f32_slice(&st.norms);
    w.put_f32_slice(&st.losses);
    w.put_f32_slice(&st.rows);
}

fn read_scorer_state(r: &mut PayloadReader<'_>) -> Result<ScorerState, String> {
    Ok(ScorerState {
        ell: r.u32()?,
        count: r.u64()?,
        consensus_acc: r.f64_slice()?,
        indices: r.u64_slice()?,
        labels: r.u32_slice()?,
        norms: r.f32_slice()?,
        losses: r.f32_slice()?,
        rows: r.f32_slice()?,
    })
}

fn write_scores_state(w: &mut PayloadWriter, st: &ScoresState) {
    w.put_u32(st.ell);
    w.put_f32_slice(&st.consensus);
    w.put_u64_slice(&st.indices);
    w.put_u32_slice(&st.labels);
    w.put_f32_slice(&st.norms);
    w.put_f32_slice(&st.losses);
    w.put_f32_slice(&st.alphas);
    w.put_matrix(&st.zhat);
}

fn read_scores_state(r: &mut PayloadReader<'_>) -> Result<ScoresState, String> {
    Ok(ScoresState {
        ell: r.u32()?,
        consensus: r.f32_slice()?,
        indices: r.u64_slice()?,
        labels: r.u32_slice()?,
        norms: r.f32_slice()?,
        losses: r.f32_slice()?,
        alphas: r.f32_slice()?,
        zhat: r.matrix()?,
    })
}

impl SessionCheckpoint {
    fn body_bytes(&self) -> Vec<u8> {
        let mut w = PayloadWriter::new();
        w.put_u32(VERSION);
        w.put_str(&self.name);
        w.put_u32(self.ell);
        w.put_u32(self.d);
        w.put_u32(self.shards);
        match &self.frozen {
            None => {
                w.put_u8(0);
                for st in &self.shard_states {
                    w.put_u32(st.ell);
                    w.put_u32(st.d);
                    w.put_u32(st.next_row);
                    w.put_u64(st.shrink_count);
                    w.put_u64(st.rows_seen);
                    w.put_f64(st.delta_sum);
                    w.put_f64(st.energy_seen);
                    w.put_f32_slice(&st.buf);
                }
            }
            Some(f) => {
                w.put_u8(1);
                w.put_matrix(&f.sketch);
                w.put_f64(f.shift_bound);
                w.put_u64(f.shrinks);
                w.put_u64(f.rows_seen);
                w.put_u64(f.sketch_bytes);
            }
        }
        // v2 Phase-II section.
        w.put_u32(self.scorers.len() as u32);
        for slot in &self.scorers {
            match slot {
                Some(st) => {
                    w.put_u8(1);
                    write_scorer_state(&mut w, st);
                }
                None => w.put_u8(0),
            }
        }
        match &self.scores {
            Some(st) => {
                w.put_u8(1);
                write_scores_state(&mut w, st);
            }
            None => w.put_u8(0),
        }
        w.put_u64(self.wal_seq);
        w.into_bytes()
    }

    /// The complete on-disk image: magic + body + fnv64 trailer.
    fn file_bytes(&self) -> Vec<u8> {
        let body = self.body_bytes();
        let mut out = Vec::with_capacity(8 + body.len() + 8);
        out.extend_from_slice(MAGIC);
        out.extend_from_slice(&body);
        let sum = fnv64(&out);
        out.extend_from_slice(&sum.to_le_bytes());
        out
    }

    /// Write atomically (tmp file + fsync + rename), creating parent dirs.
    /// A crash at any point leaves either the previous complete checkpoint
    /// or the new complete checkpoint at `path`, never a torn mix.
    ///
    /// # Errors
    /// I/O failures creating the directory, writing or syncing the tmp
    /// file, or renaming it into place.
    pub fn save(&self, path: &Path) -> Result<(), String> {
        write_atomic(path, &self.file_bytes(), None)
    }

    /// Load and verify a checkpoint (v1, v2, or v3).
    ///
    /// # Errors
    /// I/O failures, checksum mismatches (torn writes), bad magic,
    /// unsupported versions, and malformed bodies.
    pub fn load(path: &Path) -> Result<SessionCheckpoint, String> {
        let bytes = std::fs::read(path).map_err(|e| format!("{}: {e}", path.display()))?;
        if bytes.len() < 8 + 8 {
            return Err("session checkpoint truncated".into());
        }
        let (body_with_magic, sum_bytes) = bytes.split_at(bytes.len() - 8);
        let stored = u64::from_le_bytes(sum_bytes.try_into().unwrap());
        if fnv64(body_with_magic) != stored {
            return Err("session checkpoint checksum mismatch (torn write?)".into());
        }
        if &body_with_magic[..8] != MAGIC {
            return Err("bad session checkpoint magic".into());
        }
        let mut r = PayloadReader::new(&body_with_magic[8..]);
        let version = r.u32()?;
        if version == 0 || version > VERSION {
            return Err(format!(
                "session checkpoint version {version} unsupported (max {VERSION})"
            ));
        }
        let name = r.str()?;
        let ell = r.u32()?;
        let d = r.u32()?;
        let shards = r.u32()?;
        let (shard_states, frozen) = match r.u8()? {
            0 => {
                let mut states = Vec::with_capacity((shards as usize).min(1024));
                for _ in 0..shards {
                    states.push(SketchState {
                        ell: r.u32()?,
                        d: r.u32()?,
                        next_row: r.u32()?,
                        shrink_count: r.u64()?,
                        rows_seen: r.u64()?,
                        delta_sum: r.f64()?,
                        energy_seen: r.f64()?,
                        buf: r.f32_slice()?,
                    });
                }
                (states, None)
            }
            1 => {
                let frozen = FrozenSketch {
                    sketch: r.matrix()?,
                    shift_bound: r.f64()?,
                    shrinks: r.u64()?,
                    rows_seen: r.u64()?,
                    sketch_bytes: r.u64()?,
                };
                (Vec::new(), Some(frozen))
            }
            other => return Err(format!("session checkpoint: bad frozen tag {other}")),
        };
        let (scorers, scores) = if version >= 2 {
            let slots = r.u32()? as usize;
            if slots > shards as usize {
                return Err(format!(
                    "session checkpoint: {slots} scorer slots for {shards} shards"
                ));
            }
            let mut scorers = Vec::with_capacity(slots.min(1024));
            for _ in 0..slots {
                scorers.push(match r.u8()? {
                    0 => None,
                    1 => Some(read_scorer_state(&mut r)?),
                    other => {
                        return Err(format!("session checkpoint: bad scorer tag {other}"))
                    }
                });
            }
            let scores = match r.u8()? {
                0 => None,
                1 => Some(read_scores_state(&mut r)?),
                other => return Err(format!("session checkpoint: bad scores tag {other}")),
            };
            (scorers, scores)
        } else {
            (Vec::new(), None)
        };
        let wal_seq = if version >= 3 { r.u64()? } else { 0 };
        r.finish()?;
        Ok(SessionCheckpoint {
            name,
            ell,
            d,
            shards,
            shard_states,
            frozen,
            scorers,
            scores,
            wal_seq,
        })
    }
}

/// Crash-safe write: the image goes to a sibling `.tmp` file which is
/// fsynced *before* being renamed over `path`, so power loss at any byte
/// leaves either the old complete file or the new complete file.
///
/// `fail_after` is a test-only injection point: write that many bytes of
/// the image, then fail as if the process died mid-write — the torn
/// `.tmp` is left behind and `path` is untouched.
fn write_atomic(path: &Path, bytes: &[u8], fail_after: Option<usize>) -> Result<(), String> {
    if let Some(parent) = path.parent() {
        if !parent.as_os_str().is_empty() {
            std::fs::create_dir_all(parent).map_err(|e| format!("{}: {e}", parent.display()))?;
        }
    }
    let tmp = path.with_extension("tmp");
    let mut f = std::fs::File::create(&tmp).map_err(|e| format!("{}: {e}", tmp.display()))?;
    if let Some(n) = fail_after {
        let n = n.min(bytes.len());
        f.write_all(&bytes[..n]).map_err(|e| e.to_string())?;
        let _ = f.sync_all();
        return Err(format!(
            "injected failure after {n} of {} bytes ({})",
            bytes.len(),
            tmp.display()
        ));
    }
    f.write_all(bytes).map_err(|e| e.to_string())?;
    f.sync_all().map_err(|e| format!("{}: {e}", tmp.display()))?;
    drop(f);
    std::fs::rename(&tmp, path).map_err(|e| format!("{}: {e}", path.display()))?;
    // Make the rename itself crash-durable: without the directory fsync a
    // host crash can roll the entry back to the old file — or, for a first
    // checkpoint, to no file at all — even though the bytes were synced.
    if let Some(parent) = path.parent() {
        if !parent.as_os_str().is_empty() {
            super::storage::fsync_dir(parent)?;
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::selection::AgreementScorer;
    use crate::sketch::FdSketch;
    use crate::tensor::Matrix;
    use std::path::PathBuf;

    fn tmp(name: &str) -> PathBuf {
        std::env::temp_dir().join(format!("sage_sess_ckpt_{name}_{}", std::process::id()))
    }

    fn active_sample() -> SessionCheckpoint {
        let mut s0 = FdSketch::new(2, 4);
        let mut s1 = FdSketch::new(2, 4);
        s0.insert_batch(&Matrix::from_fn(5, 4, |r, c| (r * 4 + c) as f32 * 0.1));
        s1.insert_batch(&Matrix::from_fn(3, 4, |r, c| (r + c) as f32 * -0.2));
        SessionCheckpoint {
            name: "act".into(),
            ell: 2,
            d: 4,
            shards: 2,
            shard_states: vec![s0.export_state(), s1.export_state()],
            frozen: None,
            scorers: vec![
                Some(AgreementScorer::new(2).export_state()),
                Some(AgreementScorer::new(2).export_state()),
            ],
            scores: None,
            wal_seq: 7,
        }
    }

    fn scored_sample() -> SessionCheckpoint {
        let mut rng = crate::util::rng::Pcg64::seeded(77);
        let ell = 3usize;
        let mk_scorer = |rng: &mut crate::util::rng::Pcg64, n: usize| {
            let mut scorer = AgreementScorer::new(ell);
            let mut z = Matrix::zeros(n, ell);
            let mut norms = vec![0.0f32; n];
            for i in 0..n {
                let row = z.row_mut(i);
                rng.fill_normal(row, 1.0);
                norms[i] = crate::tensor::normalize_in_place(row) as f32;
            }
            let idx: Vec<usize> = (0..n).collect();
            let labels: Vec<u32> = (0..n).map(|i| (i % 2) as u32).collect();
            scorer.add_batch(&idx, &labels, &z, &norms, &vec![1.0; n]);
            scorer
        };
        let finalized = mk_scorer(&mut rng, 9).finalize();
        SessionCheckpoint {
            name: "frz".into(),
            ell: ell as u32,
            d: 4,
            shards: 2,
            shard_states: Vec::new(),
            frozen: Some(FrozenSketch {
                sketch: Matrix::from_fn(ell, 4, |r, c| (r * 4 + c) as f32),
                shift_bound: 0.5,
                shrinks: 2,
                rows_seen: 8,
                sketch_bytes: 96,
            }),
            scorers: vec![Some(mk_scorer(&mut rng, 7).export_state()), None],
            scores: Some(finalized.export_state()),
            wal_seq: 41,
        }
    }

    #[test]
    fn active_round_trip() {
        let path = tmp("act");
        let ck = active_sample();
        ck.save(&path).unwrap();
        let back = SessionCheckpoint::load(&path).unwrap();
        assert_eq!(back, ck);
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn scored_round_trip_is_bit_exact() {
        let path = tmp("frz");
        let ck = scored_sample();
        ck.save(&path).unwrap();
        let back = SessionCheckpoint::load(&path).unwrap();
        assert_eq!(back, ck);
        // f64 consensus accumulators survive as raw bits.
        let orig = ck.scorers[0].as_ref().unwrap();
        let rec = back.scorers[0].as_ref().unwrap();
        for (a, b) in orig.consensus_acc.iter().zip(&rec.consensus_acc) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn legacy_v1_body_loads_without_scorer_section() {
        // Hand-build a v1 body (no Phase-II section) and verify it loads
        // with empty scorer state — recovery of old checkpoints must not
        // break when the format moves forward.
        let path = tmp("v1");
        let f = FrozenSketch {
            sketch: Matrix::from_fn(2, 4, |r, c| (r * 4 + c) as f32),
            shift_bound: 0.25,
            shrinks: 1,
            rows_seen: 6,
            sketch_bytes: 64,
        };
        let mut w = PayloadWriter::new();
        w.put_u32(1); // version 1
        w.put_str("old");
        w.put_u32(2);
        w.put_u32(4);
        w.put_u32(1);
        w.put_u8(1);
        w.put_matrix(&f.sketch);
        w.put_f64(f.shift_bound);
        w.put_u64(f.shrinks);
        w.put_u64(f.rows_seen);
        w.put_u64(f.sketch_bytes);
        let body = w.into_bytes();
        let mut out = Vec::new();
        out.extend_from_slice(MAGIC);
        out.extend_from_slice(&body);
        let sum = fnv64(&out);
        out.extend_from_slice(&sum.to_le_bytes());
        std::fs::write(&path, &out).unwrap();

        let back = SessionCheckpoint::load(&path).unwrap();
        assert_eq!(back.name, "old");
        assert_eq!(back.frozen, Some(f));
        assert!(back.scorers.is_empty());
        assert!(back.scores.is_none());
        assert_eq!(back.wal_seq, 0);
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn legacy_v2_body_loads_with_zero_wal_watermark() {
        // A v2 body carries the Phase-II section but no trailing wal_seq;
        // it must keep loading and report watermark 0 (replay from the
        // log's beginning).
        let path = tmp("v2");
        let f = FrozenSketch {
            sketch: Matrix::from_fn(2, 4, |r, c| (r + c) as f32 * 0.5),
            shift_bound: 0.75,
            shrinks: 3,
            rows_seen: 12,
            sketch_bytes: 64,
        };
        let mut w = PayloadWriter::new();
        w.put_u32(2); // version 2
        w.put_str("mid");
        w.put_u32(2);
        w.put_u32(4);
        w.put_u32(1);
        w.put_u8(1);
        w.put_matrix(&f.sketch);
        w.put_f64(f.shift_bound);
        w.put_u64(f.shrinks);
        w.put_u64(f.rows_seen);
        w.put_u64(f.sketch_bytes);
        w.put_u32(0); // no scorer slots
        w.put_u8(0); // no score cache
        let body = w.into_bytes();
        let mut out = Vec::new();
        out.extend_from_slice(MAGIC);
        out.extend_from_slice(&body);
        let sum = fnv64(&out);
        out.extend_from_slice(&sum.to_le_bytes());
        std::fs::write(&path, &out).unwrap();

        let back = SessionCheckpoint::load(&path).unwrap();
        assert_eq!(back.name, "mid");
        assert_eq!(back.frozen, Some(f));
        assert_eq!(back.wal_seq, 0);
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn mid_write_failure_never_corrupts_the_previous_checkpoint() {
        // Satellite: crash during checkpoint must never corrupt the
        // previous good .sagesess. Inject a death at several points inside
        // the write — including "everything written but not renamed" —
        // and verify the old image still loads byte-for-byte.
        let path = tmp("midwrite");
        let old = scored_sample();
        old.save(&path).unwrap();

        let mut newer = old.clone();
        newer.wal_seq = 999;
        newer.frozen.as_mut().unwrap().rows_seen = 1000;
        let image = newer.file_bytes();
        for cut in [0usize, 1, image.len() / 2, image.len()] {
            let err = write_atomic(&path, &image, Some(cut)).unwrap_err();
            assert!(err.contains("injected"), "unexpected error: {err}");
            assert_eq!(SessionCheckpoint::load(&path).unwrap(), old);
        }
        // A retry after the crash replaces the checkpoint cleanly.
        newer.save(&path).unwrap();
        assert_eq!(SessionCheckpoint::load(&path).unwrap(), newer);
        std::fs::remove_file(&path).unwrap();
        let _ = std::fs::remove_file(path.with_extension("tmp"));
    }

    #[test]
    fn corruption_detected() {
        let path = tmp("corrupt");
        active_sample().save(&path).unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0x55;
        std::fs::write(&path, &bytes).unwrap();
        assert!(SessionCheckpoint::load(&path)
            .unwrap_err()
            .contains("checksum"));
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn truncation_detected() {
        let path = tmp("trunc");
        scored_sample().save(&path).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        std::fs::write(&path, &bytes[..bytes.len() - 11]).unwrap();
        assert!(SessionCheckpoint::load(&path).is_err());
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn future_version_rejected() {
        let path = tmp("future");
        scored_sample().save(&path).unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        // Version field is the first u32 after the 8-byte magic.
        bytes[8..12].copy_from_slice(&99u32.to_le_bytes());
        let body_len = bytes.len() - 8;
        let sum = fnv64(&bytes[..body_len]);
        let end = bytes.len();
        bytes[body_len..end].copy_from_slice(&sum.to_le_bytes());
        std::fs::write(&path, &bytes).unwrap();
        assert!(SessionCheckpoint::load(&path)
            .unwrap_err()
            .contains("version"));
        std::fs::remove_file(&path).unwrap();
    }
}
