//! Readiness-driven reactor: the `--io epoll` engine behind `sage serve`.
//!
//! One event-loop thread multiplexes every connection over `util::sys`'s
//! raw epoll bindings (no mio/tokio offline); registry dispatch — the part
//! that runs kernels — happens on a compute [`ThreadPool`] so a long
//! finalize never stalls accept, reads, or another connection's writes.
//! The threaded engine in `service::server` remains the portable fallback;
//! both speak the identical wire protocol and produce byte-identical
//! responses (the integration suite runs every service test under both).
//!
//! # Connection state machine
//!
//! Each protocol connection owns an incremental [`FrameDecoder`] (reads
//! never block: whatever bytes arrive are buffered until a frame
//! completes) and a bounded outbox of fully-encoded frames. Responses are
//! re-sequenced: every decoded request gets a per-connection sequence
//! number, compute completions land in a `BTreeMap`, and frames leave in
//! request order no matter how the pool schedules them. At most one
//! request per connection is in flight at a time — the same
//! one-request-at-a-time semantics as the threaded engine, so pipelined
//! mutations (Create → Ingest → Freeze on one socket) apply in order.
//!
//! # Backpressure
//!
//! The outbox is watermarked: past [`HIGH_WATER`] the loop stops *reading*
//! that connection (level-triggered interest drops `EPOLLIN`), so a slow
//! reader throttles only itself — the TCP window fills and its producer
//! blocks, exactly like the threaded engine's blocking-write composition.
//! Push subscribers (see `service::subs`) ride the same outbox through a
//! [`ReactorSink`]: when queued-plus-outbox bytes exceed the sink budget
//! the hub's delta is refused (`PushOutcome::Busy`) and coalesced — a slow
//! subscriber receives a fresh cumulative delta later, never an unbounded
//! queue. Draining below [`LOW_WATER`] re-arms reads and kicks the hub.
//!
//! # Wire hot path
//!
//! Connections are accepted with `accept4(SOCK_NONBLOCK | SOCK_CLOEXEC)`
//! (no per-connection `fcntl` pair; `TCP_NODELAY` set once at accept) and
//! outboxes drain through a single gathered `writev(2)` per readiness
//! event — up to `MAX_WRITEV_BATCH` frames per call, resuming mid-frame
//! after short writes. Every buffer on the path (request payloads,
//! encoded responses, framed bytes) cycles through `util::bufpool`, so a
//! steady-state request allocates nothing. See docs/ARCHITECTURE.md §5.2.
//!
//! # Shutdown
//!
//! `ServerHandle` wakes the loop through its eventfd (no self-connect):
//! the loop broadcasts GoingAway to subscribers, flushes what it can
//! within a short grace window, and exits. Completions for connections
//! that died in the meantime are dropped by token — tokens are never
//! reused, so a stale completion can never reach the wrong peer.

use super::registry::SessionRegistry;
use super::subs::SubscriptionHub;
use crate::util::sys::EventFd;
use std::net::TcpListener;
use std::sync::atomic::AtomicBool;
use std::sync::Arc;

/// Everything the reactor needs from `Server::run`. The `threads` budget
/// covers the event loop itself plus the compute pool (`threads - 1`
/// workers), so `--io epoll` and `--io threads` are comparable at equal
/// `--threads`.
pub(crate) struct ReactorConfig {
    pub listener: TcpListener,
    pub metrics_listener: Option<TcpListener>,
    pub registry: Arc<SessionRegistry>,
    pub hub: Arc<SubscriptionHub>,
    pub wake: Arc<EventFd>,
    pub threads: usize,
    pub slow_op_ms: u64,
    /// Gathered-write batching on the outbox; `false` keeps the
    /// historical one-`write(2)`-per-frame loop (the bench baseline).
    pub writev: bool,
    /// `SO_SNDBUF` for accepted protocol sockets (tests force short
    /// writes with tiny values).
    pub sndbuf: Option<usize>,
}

#[cfg(target_os = "linux")]
pub(crate) fn run(cfg: ReactorConfig, stop: Arc<AtomicBool>) -> Result<(), String> {
    linux_impl::run(cfg, stop)
}

#[cfg(not(target_os = "linux"))]
pub(crate) fn run(cfg: ReactorConfig, stop: Arc<AtomicBool>) -> Result<(), String> {
    let _ = (cfg, stop);
    Err("the epoll reactor requires Linux; run with --io threads".to_string())
}

#[cfg(target_os = "linux")]
mod linux_impl {
    use super::ReactorConfig;
    use crate::service::metrics_http;
    use crate::service::protocol::{
        encode_frame_traced_into, op, Frame, FrameDecoder, Request, Response,
    };
    use crate::service::registry::SessionRegistry;
    use crate::service::server::server_hists;
    use crate::service::subs::{PushOutcome, PushSink};
    use crate::util::bufpool;
    use crate::util::metrics::global as metrics;
    use crate::util::metrics::Histogram;
    use crate::util::sys::{self, Epoll, Event, EventFd};
    use crate::util::threadpool::ThreadPool;
    use crate::util::trace::{self, TraceCtx};
    use std::collections::{BTreeMap, HashMap, VecDeque};
    use std::io::{ErrorKind, Read, Write};
    use std::net::{TcpListener, TcpStream};
    use std::os::unix::io::{AsRawFd, FromRawFd};
    use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
    use std::sync::{Arc, Mutex, OnceLock};
    use std::time::{Duration, Instant};

    /// Wakes the loop: eventfd written by pool completions, push sinks,
    /// and `ServerHandle` shutdown.
    const TOKEN_WAKE: u64 = 0;
    /// The protocol listener.
    const TOKEN_LISTENER: u64 = 1;
    /// The optional `/metrics` HTTP listener.
    const TOKEN_METRICS: u64 = 2;
    /// Connections start here; the counter is monotone and tokens are
    /// never reused, so completions for closed connections drop safely.
    const FIRST_CONN_TOKEN: u64 = 3;

    /// Outbox bytes past which the loop stops reading the connection.
    pub(super) const HIGH_WATER: usize = 1 << 20;
    /// Outbox bytes below which reads re-arm and Busy subscribers retry.
    pub(super) const LOW_WATER: usize = 256 << 10;
    /// Queued-plus-outbox bytes past which a push sink reports Busy (the
    /// hub then coalesces instead of queuing another delta).
    pub(super) const PUSH_BUSY: usize = 256 << 10;

    const READ_CHUNK: usize = 16 << 10;
    const MAX_EVENTS: usize = 256;
    /// Frames gathered into one `writev` call. Well under the kernel's
    /// `UIO_MAXIOV`; bounds both the stack-allocated iovec array and the
    /// latency of a single syscall on a deep outbox.
    pub(super) const MAX_WRITEV_BATCH: usize = 64;
    /// Safety-net wait timeout; every real transition also writes the
    /// eventfd, so this only bounds lost-wakeup damage.
    const WAIT_MS: i32 = 250;
    /// How long shutdown waits for in-flight responses and GoingAway
    /// frames to flush before dropping the remaining connections.
    const SHUTDOWN_GRACE: Duration = Duration::from_millis(250);

    struct ReactorHists {
        /// `sage.reactor.wait.ns` — time blocked in `epoll_wait`.
        wait: &'static Histogram,
        /// `sage.reactor.dispatch.ns` — pool-side wall clock of one
        /// request (decode → handle → encode → frame).
        dispatch: &'static Histogram,
        /// `sage.reactor.write_queue.depth` — outbox depth in frames,
        /// sampled at each enqueue.
        depth: &'static Histogram,
        /// `sage.reactor.writev.frames_per_call` — complete frames
        /// retired by one gathered write (partially written frames don't
        /// count until a later call finishes them).
        writev_frames: &'static Histogram,
        /// `sage.reactor.writev.ns` — wall clock of one `writev(2)` call.
        writev_ns: &'static Histogram,
    }

    fn reactor_hists() -> &'static ReactorHists {
        static HISTS: OnceLock<ReactorHists> = OnceLock::new();
        HISTS.get_or_init(|| {
            let reg = metrics();
            ReactorHists {
                wait: reg.histogram("sage.reactor.wait.ns"),
                dispatch: reg.histogram("sage.reactor.dispatch.ns"),
                depth: reg.histogram("sage.reactor.write_queue.depth"),
                writev_frames: reg.histogram("sage.reactor.writev.frames_per_call"),
                writev_ns: reg.histogram("sage.reactor.writev.ns"),
            }
        })
    }

    /// One finished pool job: the fully-encoded response frame, routed
    /// back to its connection by token and slotted by sequence number.
    struct Completion {
        token: u64,
        seq: u64,
        frame: Vec<u8>,
    }

    /// State shared between the loop, pool workers, and push sinks.
    struct Shared {
        wake: Arc<EventFd>,
        completions: Mutex<Vec<Completion>>,
        /// Tokens with freshly queued push frames to drain into outboxes.
        push_pending: Mutex<Vec<u64>>,
    }

    /// The hub's nonblocking path into one connection's outbox. The loop
    /// mirrors the outbox byte count into `outbox_bytes` so Busy reflects
    /// the *total* unsent backlog, not just the staging queue.
    struct ReactorSink {
        token: u64,
        shared: Arc<Shared>,
        gone: AtomicBool,
        queue: Mutex<VecDeque<Vec<u8>>>,
        queued_bytes: AtomicUsize,
        outbox_bytes: AtomicUsize,
    }

    impl PushSink for ReactorSink {
        fn try_push(&self, frame: Vec<u8>) -> PushOutcome {
            if self.gone.load(Ordering::Acquire) {
                crate::util::bufpool::global().put(frame);
                return PushOutcome::Gone;
            }
            let backlog = self.queued_bytes.load(Ordering::Relaxed)
                + self.outbox_bytes.load(Ordering::Relaxed);
            if backlog > PUSH_BUSY {
                crate::util::bufpool::global().put(frame);
                return PushOutcome::Busy;
            }
            self.queued_bytes.fetch_add(frame.len(), Ordering::Relaxed);
            self.queue.lock().unwrap().push_back(frame);
            self.shared.push_pending.lock().unwrap().push(self.token);
            self.shared.wake.wake();
            PushOutcome::Sent
        }
    }

    /// A request headed for (or parked before) the compute pool.
    struct DispatchJob {
        token: u64,
        seq: u64,
        opcode: u8,
        payload: Vec<u8>,
        trace: Option<TraceCtx>,
    }

    struct FrameState {
        decoder: FrameDecoder,
        /// Sequence assigned to the next decoded request.
        next_req_seq: u64,
        /// Sequence whose response leaves the outbox next.
        next_resp_seq: u64,
        /// Out-of-order completions parked until their turn.
        ready: BTreeMap<u64, Vec<u8>>,
        /// One request on the pool at a time (per-connection ordering).
        inflight: bool,
        /// Decoded requests waiting for the in-flight one to finish.
        pending: VecDeque<DispatchJob>,
        /// Created lazily on the first Subscribe.
        sink: Option<Arc<ReactorSink>>,
    }

    impl FrameState {
        fn new() -> FrameState {
            FrameState {
                decoder: FrameDecoder::new(),
                next_req_seq: 0,
                next_resp_seq: 0,
                ready: BTreeMap::new(),
                inflight: false,
                pending: VecDeque::new(),
                sink: None,
            }
        }
    }

    enum ConnKind {
        /// A protocol connection (SGW1 frames).
        Frames(FrameState),
        /// A `/metrics` scrape: buffer the request head, answer, close.
        Http { request: Vec<u8> },
    }

    struct Conn {
        stream: TcpStream,
        kind: ConnKind,
        /// Complete frames (or the HTTP response) awaiting the socket.
        outbox: VecDeque<Vec<u8>>,
        /// Bytes of `outbox.front()` already written.
        front_written: usize,
        outbox_bytes: usize,
        /// Currently registered epoll interest mask.
        interest: u32,
        close_after_flush: bool,
        /// Peer EOF'd its write side; serve what is owed, then close.
        peer_gone: bool,
    }

    enum After {
        Keep,
        Close,
    }

    fn frames_mut(conn: &mut Conn) -> &mut FrameState {
        match &mut conn.kind {
            ConnKind::Frames(fs) => fs,
            ConnKind::Http { .. } => unreachable!("frame op on metrics connection"),
        }
    }

    /// Append one complete frame to the outbox and keep the sink's mirror
    /// of the backlog honest.
    fn enqueue_frame(conn: &mut Conn, frame: Vec<u8>) {
        conn.outbox_bytes += frame.len();
        conn.outbox.push_back(frame);
        reactor_hists().depth.record(conn.outbox.len() as u64);
        mirror_outbox(conn);
    }

    fn mirror_outbox(conn: &Conn) {
        if let ConnKind::Frames(fs) = &conn.kind {
            if let Some(sink) = &fs.sink {
                sink.outbox_bytes.store(conn.outbox_bytes, Ordering::Relaxed);
            }
        }
    }

    /// Write as much of the outbox as the socket accepts right now.
    /// `Ok(())` means either drained or `WouldBlock`; errors mean the
    /// peer is gone.
    ///
    /// The batched path gathers up to [`MAX_WRITEV_BATCH`] frames into a
    /// single `writev(2)`. A short count is resumed exactly: fully
    /// written frames pop (and their buffers return to the pool), the
    /// first unfinished frame records its progress in `front_written`,
    /// and the next call's iovec starts mid-frame from there — so EAGAIN
    /// in the middle of a frame never reorders or duplicates a byte.
    /// `batched = false` (config `writev: false`, the serve bench's
    /// baseline) keeps the historical one-write-per-frame loop.
    fn flush_outbox(conn: &mut Conn, batched: bool) -> std::io::Result<()> {
        if !batched {
            return flush_outbox_per_frame(conn);
        }
        let fd = conn.stream.as_raw_fd();
        let hists = reactor_hists();
        while !conn.outbox.is_empty() {
            let mut iovs = [sys::IoVec::empty(); MAX_WRITEV_BATCH];
            let mut n_iovs = 0;
            let mut batch_bytes = 0usize;
            for frame in conn.outbox.iter().take(MAX_WRITEV_BATCH) {
                let skip = if n_iovs == 0 { conn.front_written } else { 0 };
                iovs[n_iovs] = sys::IoVec::new(&frame[skip..]);
                batch_bytes += frame.len() - skip;
                n_iovs += 1;
            }
            let t = Instant::now();
            let wrote = sys::writev(fd, &iovs[..n_iovs]);
            hists.writev_ns.record(t.elapsed().as_nanos() as u64);
            match wrote {
                Ok(0) => return Err(ErrorKind::WriteZero.into()),
                Ok(mut n) => {
                    let short = n < batch_bytes;
                    let mut retired = 0u64;
                    while n > 0 {
                        let front_len = conn.outbox.front().map_or(0, |f| f.len());
                        let remaining = front_len - conn.front_written;
                        if n >= remaining {
                            n -= remaining;
                            conn.outbox_bytes -= remaining;
                            conn.front_written = 0;
                            if let Some(done) = conn.outbox.pop_front() {
                                bufpool::global().put(done);
                            }
                            retired += 1;
                        } else {
                            conn.front_written += n;
                            conn.outbox_bytes -= n;
                            n = 0;
                        }
                    }
                    hists.writev_frames.record(retired);
                    if short {
                        // The socket buffer filled mid-batch: another call
                        // would just collect EAGAIN. Let EPOLLOUT re-arm.
                        return Ok(());
                    }
                }
                Err(e) if e.kind() == ErrorKind::WouldBlock => return Ok(()),
                Err(e) if e.kind() == ErrorKind::Interrupted => continue,
                Err(e) => return Err(e),
            }
        }
        Ok(())
    }

    /// The pre-writev baseline: one `write(2)` per frame. Kept callable
    /// (not just as dead history) so `sage bench serve` can measure the
    /// gathered path against it.
    fn flush_outbox_per_frame(conn: &mut Conn) -> std::io::Result<()> {
        while let Some(front) = conn.outbox.front() {
            match conn.stream.write(&front[conn.front_written..]) {
                Ok(0) => return Err(ErrorKind::WriteZero.into()),
                Ok(n) => {
                    conn.front_written += n;
                    conn.outbox_bytes -= n;
                    if conn.front_written == front.len() {
                        if let Some(done) = conn.outbox.pop_front() {
                            bufpool::global().put(done);
                        }
                        conn.front_written = 0;
                    }
                }
                Err(e) if e.kind() == ErrorKind::WouldBlock => return Ok(()),
                Err(e) if e.kind() == ErrorKind::Interrupted => continue,
                Err(e) => return Err(e),
            }
        }
        Ok(())
    }

    /// Interest follows state: reads stay armed until the outbox passes
    /// the high watermark (or the conn is draining), writes arm only
    /// while the outbox is nonempty (level-triggered — an always-armed
    /// `EPOLLOUT` would spin).
    fn desired_interest(conn: &Conn) -> u32 {
        let mut mask = sys::EPOLLRDHUP;
        let reading =
            !conn.peer_gone && !conn.close_after_flush && conn.outbox_bytes < HIGH_WATER;
        if reading {
            mask |= sys::EPOLLIN;
        }
        if !conn.outbox.is_empty() {
            mask |= sys::EPOLLOUT;
        }
        mask
    }

    /// True when nothing more will ever leave this connection.
    fn conn_finished(conn: &Conn) -> bool {
        if !conn.outbox.is_empty() {
            return false;
        }
        // A response is still owed (in flight on the pool or parked
        // out-of-order): deliver it before closing, even when draining.
        let owed = match &conn.kind {
            ConnKind::Http { .. } => false,
            ConnKind::Frames(fs) => fs.inflight || !fs.ready.is_empty(),
        };
        if owed {
            return false;
        }
        if conn.close_after_flush {
            return true;
        }
        if !conn.peer_gone {
            return false;
        }
        match &conn.kind {
            ConnKind::Http { .. } => true,
            ConnKind::Frames(fs) => fs.pending.is_empty(),
        }
    }

    /// Pool-side request execution: mirrors the threaded engine's
    /// decode → dispatch → encode stages (same histograms, same slow-op
    /// warning, same trace adoption), then hands the encoded frame back
    /// to the loop as a completion.
    fn run_job(registry: &SessionRegistry, shared: &Shared, slow_op_ms: u64, job: DispatchJob) {
        let hists = server_hists();
        let total = Instant::now();
        let _request_span = job
            .trace
            .map(|ctx| trace::adopt(&format!("serve.{}", op::name(job.opcode)), ctx));

        let t = Instant::now();
        let decoded = {
            let _s = trace::span("serve.decode");
            Request::decode(job.opcode, &job.payload)
        };
        hists.decode.record(t.elapsed().as_nanos() as u64);
        // Request::decode copies out everything it needs, so the wire
        // payload can recycle before the (possibly long) handle stage.
        bufpool::global().put(job.payload);

        let t = Instant::now();
        let response = match decoded {
            Ok(request) => {
                let _s = trace::span("serve.handle");
                crate::service::server::dispatch(registry, request)
            }
            Err(e) => Response::Error {
                message: format!("bad request: {e}"),
            },
        };
        let handle_ns = t.elapsed().as_nanos() as u64;
        hists.handle.record(handle_ns);
        if let Some(h) = hists.per_op.get(job.opcode as usize) {
            h.record(handle_ns);
        }
        if slow_op_ms > 0 && handle_ns >= slow_op_ms.saturating_mul(1_000_000) {
            crate::log_warn!(
                "slow op {}: {:.1}ms (threshold {slow_op_ms}ms) trace={:016x}",
                op::name(job.opcode),
                handle_ns as f64 / 1e6,
                job.trace.map(|c| c.trace_id).unwrap_or(0)
            );
        }
        if matches!(response, Response::Error { .. }) {
            metrics().counter("service.server.errors").inc();
        }

        let t = Instant::now();
        let mut payload = bufpool::global().take();
        {
            let _s = trace::span("serve.encode");
            response.encode_into(&mut payload);
        }
        hists.encode.record(t.elapsed().as_nanos() as u64);

        let mut frame = bufpool::global().take();
        encode_frame_traced_into(&mut frame, job.opcode, response.status(), &payload, job.trace);
        bufpool::global().put(payload);
        reactor_hists().dispatch.record(total.elapsed().as_nanos() as u64);
        shared
            .completions
            .lock()
            .unwrap()
            .push(Completion {
                token: job.token,
                seq: job.seq,
                frame,
            });
        shared.wake.wake();
    }

    struct Reactor {
        epoll: Epoll,
        listener: TcpListener,
        metrics_listener: Option<TcpListener>,
        registry: Arc<SessionRegistry>,
        hub: Arc<crate::service::subs::SubscriptionHub>,
        shared: Arc<Shared>,
        pool: ThreadPool,
        slow_op_ms: u64,
        /// Gathered-write batching (false = per-frame bench baseline).
        writev: bool,
        /// `SO_SNDBUF` applied to accepted protocol sockets.
        sndbuf: Option<usize>,
        conns: HashMap<u64, Conn>,
        next_token: u64,
        /// Connections whose next job bounced off a saturated pool;
        /// retried once completions free a slot (or on the next tick).
        stalled: Vec<u64>,
    }

    pub(super) fn run(cfg: ReactorConfig, stop: Arc<AtomicBool>) -> Result<(), String> {
        let epoll = Epoll::new().map_err(|e| format!("epoll_create1: {e}"))?;
        cfg.listener
            .set_nonblocking(true)
            .map_err(|e| format!("listener nonblocking: {e}"))?;
        epoll
            .add(cfg.wake.as_raw_fd(), TOKEN_WAKE, sys::EPOLLIN)
            .map_err(|e| format!("register wake eventfd: {e}"))?;
        epoll
            .add(cfg.listener.as_raw_fd(), TOKEN_LISTENER, sys::EPOLLIN)
            .map_err(|e| format!("register listener: {e}"))?;
        if let Some(l) = &cfg.metrics_listener {
            l.set_nonblocking(true)
                .map_err(|e| format!("metrics listener nonblocking: {e}"))?;
            epoll
                .add(l.as_raw_fd(), TOKEN_METRICS, sys::EPOLLIN)
                .map_err(|e| format!("register metrics listener: {e}"))?;
            if let Ok(addr) = l.local_addr() {
                crate::log_info!("metrics exposition on http://{addr}/metrics");
            }
        }
        let workers = cfg.threads.saturating_sub(1).max(1);
        crate::log_info!(
            "sage-serve reactor on {} (1 event loop + {workers} compute workers)",
            cfg.listener
                .local_addr()
                .map(|a| a.to_string())
                .unwrap_or_else(|_| "?".to_string())
        );
        let mut reactor = Reactor {
            epoll,
            listener: cfg.listener,
            metrics_listener: cfg.metrics_listener,
            registry: cfg.registry,
            hub: cfg.hub,
            shared: Arc::new(Shared {
                wake: cfg.wake,
                completions: Mutex::new(Vec::new()),
                push_pending: Mutex::new(Vec::new()),
            }),
            pool: ThreadPool::new(workers),
            slow_op_ms: cfg.slow_op_ms,
            writev: cfg.writev,
            sndbuf: cfg.sndbuf,
            conns: HashMap::new(),
            next_token: FIRST_CONN_TOKEN,
            stalled: Vec::new(),
        };

        let mut events = vec![Event::zeroed(); MAX_EVENTS];
        loop {
            if stop.load(Ordering::Relaxed) {
                break;
            }
            let t = Instant::now();
            let n = reactor
                .epoll
                .wait(&mut events, WAIT_MS)
                .map_err(|e| format!("epoll_wait: {e}"))?;
            reactor_hists().wait.record(t.elapsed().as_nanos() as u64);
            for ev in &events[..n] {
                match ev.token() {
                    TOKEN_WAKE => {
                        reactor.shared.wake.drain();
                    }
                    TOKEN_LISTENER => reactor.accept_main(),
                    TOKEN_METRICS => reactor.accept_metrics(),
                    token => reactor.conn_event(token, ev.events()),
                }
            }
            reactor.drain_completions();
            reactor.drain_pushes();
            reactor.retry_stalled();
        }
        reactor.shutdown();
        Ok(())
    }

    impl Reactor {
        /// Accept with `accept4(SOCK_NONBLOCK | SOCK_CLOEXEC)`: the fd is
        /// born nonblocking (no fcntl get/set pair per connection) and
        /// socket options are applied exactly once, here.
        fn accept_main(&mut self) {
            loop {
                match sys::accept_nonblocking(self.listener.as_raw_fd()) {
                    Ok(fd) => {
                        // SAFETY: accept4 just returned this connected
                        // socket fd; nothing else owns it.
                        let stream = unsafe { TcpStream::from_raw_fd(fd) };
                        metrics().counter("service.server.connections").inc();
                        let _ = stream.set_nodelay(true);
                        if let Some(bytes) = self.sndbuf {
                            let _ = sys::set_sndbuf(fd, bytes);
                        }
                        self.register(stream, ConnKind::Frames(FrameState::new()), true);
                    }
                    Err(e) if e.kind() == ErrorKind::WouldBlock => break,
                    Err(e) => {
                        crate::log_warn!("accept failed: {e}");
                        break;
                    }
                }
            }
        }

        fn accept_metrics(&mut self) {
            loop {
                let fd = match &self.metrics_listener {
                    Some(l) => l.as_raw_fd(),
                    None => return,
                };
                match sys::accept_nonblocking(fd) {
                    Ok(fd) => {
                        // SAFETY: as in `accept_main` — a fresh owned fd.
                        let stream = unsafe { TcpStream::from_raw_fd(fd) };
                        self.register(stream, ConnKind::Http { request: Vec::new() }, false);
                    }
                    Err(e) if e.kind() == ErrorKind::WouldBlock => break,
                    Err(e) => {
                        crate::log_warn!("metrics accept failed: {e}");
                        break;
                    }
                }
            }
        }

        fn register(&mut self, stream: TcpStream, kind: ConnKind, counted: bool) {
            let token = self.next_token;
            self.next_token += 1;
            let interest = sys::EPOLLIN | sys::EPOLLRDHUP;
            if self.epoll.add(stream.as_raw_fd(), token, interest).is_err() {
                return; // conn dropped; nothing registered yet
            }
            if counted {
                metrics().gauge("sage.server.connections").add(1);
            }
            self.conns.insert(
                token,
                Conn {
                    stream,
                    kind,
                    outbox: VecDeque::new(),
                    front_written: 0,
                    outbox_bytes: 0,
                    interest,
                    close_after_flush: false,
                    peer_gone: false,
                },
            );
        }

        fn conn_event(&mut self, token: u64, bits: u32) {
            let mut conn = match self.conns.remove(&token) {
                Some(c) => c,
                None => return, // stale event for a token closed this tick
            };
            let mut after = After::Keep;
            if bits & (sys::EPOLLERR | sys::EPOLLHUP) != 0 {
                after = After::Close;
            } else {
                if bits & (sys::EPOLLIN | sys::EPOLLRDHUP) != 0 {
                    after = self.readable(token, &mut conn);
                }
                if matches!(after, After::Keep)
                    && (bits & sys::EPOLLOUT != 0 || !conn.outbox.is_empty())
                {
                    after = self.flush(&mut conn);
                }
            }
            self.finish(token, conn, after);
        }

        /// Re-register interest and either park the connection back in
        /// the map or tear it down.
        fn finish(&mut self, token: u64, mut conn: Conn, after: After) {
            let after = match after {
                After::Keep if conn_finished(&conn) => After::Close,
                a => a,
            };
            match after {
                After::Keep => {
                    let want = desired_interest(&conn);
                    if want != conn.interest {
                        conn.interest = want;
                        let _ = self.epoll.modify(conn.stream.as_raw_fd(), token, want);
                    }
                    self.conns.insert(token, conn);
                }
                After::Close => self.close(token, conn),
            }
        }

        fn close(&mut self, token: u64, mut conn: Conn) {
            let _ = self.epoll.delete(conn.stream.as_raw_fd());
            // Undelivered frames still recycle — a churny workload of
            // short-lived connections would otherwise leak pool hits.
            for frame in conn.outbox.drain(..) {
                bufpool::global().put(frame);
            }
            if let ConnKind::Frames(fs) = &conn.kind {
                if let Some(sink) = &fs.sink {
                    sink.gone.store(true, Ordering::Release);
                    for frame in sink.queue.lock().unwrap().drain(..) {
                        bufpool::global().put(frame);
                    }
                    sink.queued_bytes.store(0, Ordering::Relaxed);
                }
                self.hub.drop_conn(token);
                metrics().gauge("sage.server.connections").sub(1);
            }
        }

        fn readable(&mut self, token: u64, conn: &mut Conn) -> After {
            match conn.kind {
                ConnKind::Http { .. } => self.readable_http(conn),
                ConnKind::Frames(_) => self.readable_frames(token, conn),
            }
        }

        fn readable_frames(&mut self, token: u64, conn: &mut Conn) -> After {
            let mut buf = [0u8; READ_CHUNK];
            loop {
                // Watermark throttle: a backed-up outbox parks the read
                // side; `desired_interest` drops EPOLLIN until it drains.
                if conn.outbox_bytes >= HIGH_WATER {
                    break;
                }
                let n = match conn.stream.read(&mut buf) {
                    Ok(0) => {
                        conn.peer_gone = true;
                        break;
                    }
                    Ok(n) => n,
                    Err(e) if e.kind() == ErrorKind::WouldBlock => break,
                    Err(e) if e.kind() == ErrorKind::Interrupted => continue,
                    Err(e) => {
                        crate::log_debug!("connection read failed: {e}");
                        return After::Close;
                    }
                };
                frames_mut(conn).decoder.extend(&buf[..n]);
                if let Err(e) = self.pump_frames(token, conn) {
                    crate::log_debug!("connection framing error: {e}");
                    return After::Close;
                }
            }
            After::Keep
        }

        /// Decode every complete frame buffered so far and route it:
        /// Subscribe/Unsubscribe/Stats run inline on the loop
        /// (Subscribe/Unsubscribe only touch hub state, Stats is a cheap
        /// registry read — never kernels; and inline Stats lets a
        /// pipelined burst build a multi-frame outbox for one gathered
        /// write instead of ping-ponging through the pool one frame at a
        /// time); everything else becomes a pool job. Both paths go
        /// through the sequence machinery, so responses interleave in
        /// request order.
        fn pump_frames(&mut self, token: u64, conn: &mut Conn) -> Result<(), String> {
            loop {
                let frame = match frames_mut(conn).decoder.next_frame()? {
                    Some(f) => f,
                    None => return Ok(()),
                };
                metrics().counter("service.server.requests").inc();
                let seq = {
                    let fs = frames_mut(conn);
                    let s = fs.next_req_seq;
                    fs.next_req_seq += 1;
                    s
                };
                if matches!(frame.opcode, op::SUBSCRIBE | op::UNSUBSCRIBE | op::STATS) {
                    let encoded = self.control_response(token, conn, &frame);
                    bufpool::global().put(frame.payload);
                    frames_mut(conn).ready.insert(seq, encoded);
                    self.pump_ready(conn);
                } else {
                    frames_mut(conn).pending.push_back(DispatchJob {
                        token,
                        seq,
                        opcode: frame.opcode,
                        payload: frame.payload,
                        trace: frame.trace,
                    });
                    self.submit_next(token, conn);
                }
            }
        }

        /// Handle one Subscribe/Unsubscribe on the loop thread and return
        /// the fully-encoded response frame. Mirrors the threaded
        /// engine's stage histograms so per-op latency stays comparable.
        fn control_response(&mut self, token: u64, conn: &mut Conn, frame: &Frame) -> Vec<u8> {
            let hists = server_hists();
            let _request_span = frame
                .trace
                .map(|ctx| trace::adopt(&format!("serve.{}", op::name(frame.opcode)), ctx));
            let t = Instant::now();
            let decoded = Request::decode(frame.opcode, &frame.payload);
            hists.decode.record(t.elapsed().as_nanos() as u64);

            let t = Instant::now();
            let response = match decoded {
                Ok(Request::Subscribe {
                    session,
                    method,
                    k,
                    num_classes,
                    seed,
                }) => {
                    let sink = self.conn_sink(token, conn);
                    match self.hub.subscribe(
                        token,
                        sink,
                        &session,
                        &method,
                        k as usize,
                        num_classes as usize,
                        seed,
                    ) {
                        Ok(()) => Response::Ok,
                        Err(message) => Response::Error { message },
                    }
                }
                Ok(Request::Unsubscribe { session }) => {
                    // Removing a subscription that never existed is not an
                    // error (unsubscribe races session close).
                    self.hub.unsubscribe(token, &session);
                    Response::Ok
                }
                // Stats never touches kernels; answering on the loop is
                // cheaper than a pool round trip and lets pipelined Stats
                // bursts batch into one writev (see `pump_frames`).
                Ok(req @ Request::Stats { .. }) => {
                    crate::service::server::dispatch(&self.registry, req)
                }
                Ok(_) => Response::Error {
                    message: "bad request: not a subscription op".to_string(),
                },
                Err(e) => Response::Error {
                    message: format!("bad request: {e}"),
                },
            };
            let handle_ns = t.elapsed().as_nanos() as u64;
            hists.handle.record(handle_ns);
            if let Some(h) = hists.per_op.get(frame.opcode as usize) {
                h.record(handle_ns);
            }
            if matches!(response, Response::Error { .. }) {
                metrics().counter("service.server.errors").inc();
            }

            let t = Instant::now();
            let mut payload = bufpool::global().take();
            response.encode_into(&mut payload);
            hists.encode.record(t.elapsed().as_nanos() as u64);
            let mut out = bufpool::global().take();
            encode_frame_traced_into(
                &mut out,
                frame.opcode,
                response.status(),
                &payload,
                frame.trace,
            );
            bufpool::global().put(payload);
            out
        }

        /// The connection's push sink, created on first use. Created
        /// before `SubscriptionHub::subscribe` can validate, so a failed
        /// Subscribe may leave an idle sink behind — harmless, it holds
        /// no subscription.
        fn conn_sink(&self, token: u64, conn: &mut Conn) -> Arc<dyn PushSink> {
            let shared = self.shared.clone();
            let fs = frames_mut(conn);
            let sink = fs.sink.get_or_insert_with(|| {
                Arc::new(ReactorSink {
                    token,
                    shared,
                    gone: AtomicBool::new(false),
                    queue: Mutex::new(VecDeque::new()),
                    queued_bytes: AtomicUsize::new(0),
                    outbox_bytes: AtomicUsize::new(0),
                })
            });
            sink.clone()
        }

        /// Move consecutive ready responses (in request order) into the
        /// outbox.
        fn pump_ready(&mut self, conn: &mut Conn) {
            loop {
                let frame = {
                    let fs = frames_mut(conn);
                    match fs.ready.remove(&fs.next_resp_seq) {
                        Some(f) => {
                            fs.next_resp_seq += 1;
                            f
                        }
                        None => break,
                    }
                };
                enqueue_frame(conn, frame);
            }
        }

        /// Submit the connection's next pending request if nothing is in
        /// flight. A saturated pool parks the job back at the queue head
        /// and marks the connection stalled — never dropped.
        fn submit_next(&mut self, token: u64, conn: &mut Conn) {
            let fs = frames_mut(conn);
            if fs.inflight {
                return;
            }
            let job = match fs.pending.pop_front() {
                Some(j) => j,
                None => return,
            };
            match self.submit(job) {
                None => fs.inflight = true,
                Some(job) => {
                    fs.pending.push_front(job);
                    if !self.stalled.contains(&token) {
                        self.stalled.push(token);
                    }
                }
            }
        }

        /// Nonblocking pool submit that hands the job back on failure
        /// (the closure parks it in a shared slot, so a refused submit
        /// loses nothing).
        fn submit(&self, job: DispatchJob) -> Option<DispatchJob> {
            let slot = Arc::new(Mutex::new(Some(job)));
            let task_slot = slot.clone();
            let registry = self.registry.clone();
            let shared = self.shared.clone();
            let slow_op_ms = self.slow_op_ms;
            let submitted = self.pool.try_execute(move || {
                if let Some(job) = task_slot.lock().unwrap().take() {
                    run_job(&registry, &shared, slow_op_ms, job);
                }
            });
            match submitted {
                Ok(()) => None,
                Err(_) => slot.lock().unwrap().take(),
            }
        }

        fn readable_http(&mut self, conn: &mut Conn) -> After {
            let mut buf = [0u8; 4096];
            loop {
                match conn.stream.read(&mut buf) {
                    Ok(0) => {
                        conn.peer_gone = true;
                        break;
                    }
                    Ok(n) => {
                        let ConnKind::Http { request } = &mut conn.kind else {
                            unreachable!()
                        };
                        let room = 4096usize.saturating_sub(request.len());
                        request.extend_from_slice(&buf[..n.min(room)]);
                    }
                    Err(e) if e.kind() == ErrorKind::WouldBlock => break,
                    Err(e) if e.kind() == ErrorKind::Interrupted => continue,
                    Err(_) => return After::Close,
                }
                let ConnKind::Http { request } = &conn.kind else {
                    unreachable!()
                };
                if head_complete(request) {
                    break;
                }
            }
            if conn.close_after_flush {
                return After::Keep; // already answered; just draining
            }
            let ConnKind::Http { request } = &conn.kind else {
                unreachable!()
            };
            if !head_complete(request) && !conn.peer_gone {
                return After::Keep; // more head bytes still coming
            }
            if request.is_empty() {
                return After::Close;
            }
            let head = String::from_utf8_lossy(request).into_owned();
            let response = metrics_http::respond(&head);
            enqueue_frame(conn, response);
            conn.close_after_flush = true;
            self.flush(conn)
        }

        fn flush(&mut self, conn: &mut Conn) -> After {
            if conn.outbox.is_empty() {
                return After::Keep;
            }
            let before = conn.outbox_bytes;
            let t = Instant::now();
            let result = flush_outbox(conn, self.writev);
            server_hists().write.record(t.elapsed().as_nanos() as u64);
            mirror_outbox(conn);
            if let Err(e) = result {
                crate::log_debug!("connection write failed: {e}");
                return After::Close;
            }
            // Crossing the low watermark downward: Busy subscribers can
            // fit a fresh delta now, so kick the hub's retry.
            if before >= LOW_WATER && conn.outbox_bytes < LOW_WATER {
                if let ConnKind::Frames(fs) = &conn.kind {
                    if fs.sink.is_some() {
                        self.hub.kick();
                    }
                }
            }
            After::Keep
        }

        fn drain_completions(&mut self) {
            let completions = {
                let mut q = self.shared.completions.lock().unwrap();
                std::mem::take(&mut *q)
            };
            for c in completions {
                let mut conn = match self.conns.remove(&c.token) {
                    Some(conn) => conn,
                    None => {
                        // Connection died while computing; the orphaned
                        // frame still recycles.
                        bufpool::global().put(c.frame);
                        continue;
                    }
                };
                {
                    let fs = frames_mut(&mut conn);
                    fs.inflight = false;
                    fs.ready.insert(c.seq, c.frame);
                }
                self.pump_ready(&mut conn);
                self.submit_next(c.token, &mut conn);
                let after = self.flush(&mut conn);
                self.finish(c.token, conn, after);
            }
        }

        fn drain_pushes(&mut self) {
            let tokens = {
                let mut q = self.shared.push_pending.lock().unwrap();
                std::mem::take(&mut *q)
            };
            for token in tokens {
                let mut conn = match self.conns.remove(&token) {
                    Some(c) => c,
                    None => continue,
                };
                let frames: Vec<Vec<u8>> = {
                    let fs = frames_mut(&mut conn);
                    match &fs.sink {
                        Some(sink) => {
                            let mut q = sink.queue.lock().unwrap();
                            let drained: Vec<Vec<u8>> = q.drain(..).collect();
                            let bytes: usize = drained.iter().map(|f| f.len()).sum();
                            sink.queued_bytes.fetch_sub(bytes, Ordering::Relaxed);
                            drained
                        }
                        None => Vec::new(),
                    }
                };
                for frame in frames {
                    enqueue_frame(&mut conn, frame);
                }
                let after = self.flush(&mut conn);
                self.finish(token, conn, after);
            }
        }

        fn retry_stalled(&mut self) {
            if self.stalled.is_empty() {
                return;
            }
            let stalled = std::mem::take(&mut self.stalled);
            for token in stalled {
                let mut conn = match self.conns.remove(&token) {
                    Some(c) => c,
                    None => continue,
                };
                self.submit_next(token, &mut conn);
                self.finish(token, conn, After::Keep);
            }
        }

        /// Graceful drain: broadcast GoingAway to subscribers (idempotent
        /// with `ServerHandle`'s own broadcast), deliver what in-flight
        /// work completes within the grace window, then drop the rest.
        fn shutdown(&mut self) {
            self.hub.going_away();
            self.drain_completions();
            self.drain_pushes();
            let tokens: Vec<u64> = self.conns.keys().copied().collect();
            for token in tokens {
                let mut conn = match self.conns.remove(&token) {
                    Some(c) => c,
                    None => continue,
                };
                conn.close_after_flush = true;
                let after = self.flush(&mut conn);
                self.finish(token, conn, after);
            }
            let deadline = Instant::now() + SHUTDOWN_GRACE;
            let mut events = vec![Event::zeroed(); MAX_EVENTS];
            while !self.conns.is_empty() && Instant::now() < deadline {
                let n = match self.epoll.wait(&mut events, 25) {
                    Ok(n) => n,
                    Err(_) => break,
                };
                for ev in &events[..n] {
                    match ev.token() {
                        TOKEN_WAKE => {
                            self.shared.wake.drain();
                        }
                        TOKEN_LISTENER | TOKEN_METRICS => {} // no new conns
                        token => self.conn_event(token, ev.events()),
                    }
                }
                self.drain_completions();
                self.drain_pushes();
            }
            let leftovers: Vec<u64> = self.conns.keys().copied().collect();
            for token in leftovers {
                if let Some(conn) = self.conns.remove(&token) {
                    self.close(token, conn);
                }
            }
            // Dropping the pool joins the workers; any still-running job
            // finishes and its completion is discarded with the loop.
        }
    }

    fn head_complete(request: &[u8]) -> bool {
        request.len() >= 4096 || request.windows(4).any(|w| w == b"\r\n\r\n")
    }

    #[cfg(test)]
    mod tests {
        use super::*;

        #[test]
        fn sink_busy_reflects_queue_plus_outbox() {
            let shared = Arc::new(Shared {
                wake: Arc::new(EventFd::new().unwrap()),
                completions: Mutex::new(Vec::new()),
                push_pending: Mutex::new(Vec::new()),
            });
            let sink = ReactorSink {
                token: 7,
                shared,
                gone: AtomicBool::new(false),
                queue: Mutex::new(VecDeque::new()),
                queued_bytes: AtomicUsize::new(0),
                outbox_bytes: AtomicUsize::new(0),
            };
            assert_eq!(sink.try_push(vec![0u8; 16]), PushOutcome::Sent);
            assert_eq!(sink.queued_bytes.load(Ordering::Relaxed), 16);
            // Mirrored outbox bytes alone can trip the busy threshold.
            sink.outbox_bytes.store(PUSH_BUSY + 1, Ordering::Relaxed);
            assert_eq!(sink.try_push(vec![0u8; 16]), PushOutcome::Busy);
            sink.outbox_bytes.store(0, Ordering::Relaxed);
            assert_eq!(sink.try_push(vec![0u8; 16]), PushOutcome::Sent);
            sink.gone.store(true, Ordering::Release);
            assert_eq!(sink.try_push(vec![0u8; 16]), PushOutcome::Gone);
            // Refused pushes must not leak queued bytes.
            assert_eq!(sink.queued_bytes.load(Ordering::Relaxed), 32);
            assert_eq!(
                sink.shared.push_pending.lock().unwrap().as_slice(),
                &[7, 7]
            );
        }

        #[test]
        fn head_complete_on_crlf_or_cap() {
            assert!(!head_complete(b"GET /metrics HTTP/1.0\r\n"));
            assert!(head_complete(b"GET /metrics HTTP/1.0\r\n\r\n"));
            assert!(head_complete(&[b'x'; 4096]));
        }
    }
}
