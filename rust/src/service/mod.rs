//! `sage-serve` — the multi-tenant streaming sketch service.
//!
//! The offline pipeline runs SAGE as a batch, single-process, two-pass job;
//! this subsystem promotes the FD sketch from a local variable to a served,
//! sessioned resource: external producers stream gradients in over a
//! length-prefixed binary protocol, and consumers run online selection
//! queries (Freeze / Score / TopK) against the evolving state. The full
//! design is written up in docs/ARCHITECTURE.md; the wire format in
//! docs/PROTOCOL.md.
//!
//! Layers:
//! * [`protocol`] — versioned, checksummed wire frames and the typed op
//!   surface (CreateSession / IngestBatch / MergeSketch / Freeze / Score /
//!   TopK / Checkpoint / Stats / CloseSession).
//! * [`registry`] — **sharded** session registry (power-of-two shard array
//!   keyed by session-name hash, per-shard `RwLock`, no cross-shard lock
//!   ever held) with exact lock-free admission control over three budgets:
//!   session slots, resident ℓ×D sketch bytes, and resident O(Nℓ) Phase-II
//!   scorer bytes. Scorer state spills to disk under budget pressure and
//!   reloads transparently.
//! * [`checkpoint`] — session persistence/recovery (FNV-checksummed,
//!   temp-file + fsync + atomic-rename framing in the style of
//!   `trainer::checkpoint`); v2 round-trips Phase-II scorer state
//!   bit-exactly, v3 adds the WAL watermark.
//! * [`wal`] / [`storage`] — the durability layer (`sage serve
//!   --durability {none,async,sync}`): every state-mutating op appends a
//!   length-prefixed, FNV-checksummed, globally-sequenced record to a
//!   per-shard write-ahead log behind the [`storage::StorageBackend`]
//!   trait. Because FD insertion, shard-order merging, and scoring are
//!   deterministic, replaying the log on top of the newest checkpoint
//!   reproduces session state *bit-exactly*. Torn tails truncate with a
//!   WARN; segments compact into checkpoints past `--wal-compact-mb`.
//!   Design notes in docs/ARCHITECTURE.md §Durability, record format in
//!   docs/PROTOCOL.md §9.
//! * [`server`] — TCP serving with two interchangeable I/O engines
//!   (`sage serve --io {auto,threads,epoll}`): thread-per-connection on
//!   `util::threadpool` with graceful load-shedding when the pool is
//!   saturated (one `connection rejected` error frame, then close), or
//!   the [`reactor`] below.
//! * [`reactor`] — readiness-driven event loop over `util::sys`'s raw
//!   epoll bindings: one thread multiplexes every connection
//!   (incremental frame decode, bounded watermarked write queues),
//!   registry dispatch runs on a compute pool, and concurrency is
//!   bounded by memory instead of threads.
//! * [`subs`] — push TopK subscriptions (Subscribe/Unsubscribe ops,
//!   RESP_TOPK_DELTA frames): a notifier thread watches the registry for
//!   selection changes and streams coalescing-under-backpressure deltas
//!   to subscribers; on shutdown they receive a final GoingAway frame.
//! * [`metrics_http`] — minimal HTTP/1.0 Prometheus exposition endpoint
//!   (`sage serve --metrics-addr`): `GET /metrics` + `GET /healthz`. The
//!   metric catalog lives in docs/OBSERVABILITY.md.
//! * [`client`] — blocking client used by the CLI, the example, and tests,
//!   plus the documented retry/backoff helper
//!   [`client::request_with_retry`].
//!
//! Observability: every request frame may carry a trace extension
//! (`util::trace` context, docs/PROTOCOL.md §7); the server adopts it as a
//! `serve.<op>` → `registry.<op>` → `kernel.<op>` span hierarchy, echoes
//! it on the response (error frames included), and serves recorded spans
//! back through the TraceExport op (`sage trace export`).
//!
//! Exactness contract: a session fed shard-by-shard through
//! `pipeline::phase1_gradient_stream` / `phase2_score_stream` (one producer
//! per shard, shards assigned by `pipeline::shard_ranges`) yields the SAME
//! selected indices as `pipeline::run_selection` for the same
//! `(seed, workers)` configuration — verified end-to-end by
//! `tests/integration_service.rs`, including across registry shards and
//! through a checkpoint→recover cycle.
//!
//! # Quickstart (in-process)
//!
//! ```
//! use sage::service::{RegistryConfig, Server, ServerConfig, ServiceClient};
//! use sage::tensor::Matrix;
//!
//! let server = Server::bind(&ServerConfig {
//!     addr: "127.0.0.1:0".into(), // port 0: pick a free port
//!     threads: 2,
//!     compute_workers: 1, // serial kernels (any value selects identically)
//!     registry: RegistryConfig::default(),
//!     ..ServerConfig::default()
//! })
//! .unwrap();
//! let addr = server.local_addr().to_string();
//! let handle = server.spawn();
//!
//! let mut client = ServiceClient::connect(&addr).unwrap();
//! client.create_session("quickstart", 4, 8, 1).unwrap();
//! client
//!     .ingest("quickstart", 0, &Matrix::from_fn(16, 8, |r, c| (r + c) as f32))
//!     .unwrap();
//! let frozen = client.freeze("quickstart").unwrap();
//! assert_eq!(frozen.rows_seen, 16);
//! assert_eq!(frozen.sketch.rows(), 4);
//! client.close_session("quickstart").unwrap();
//! handle.shutdown();
//! ```

pub mod checkpoint;
pub mod client;
pub mod metrics_http;
pub mod protocol;
pub mod reactor;
pub mod registry;
pub mod server;
pub mod storage;
pub mod subs;
pub mod wal;

pub use checkpoint::SessionCheckpoint;
pub use client::{is_going_away, is_rejection, request_with_retry, ServiceClient};
pub use protocol::{apply_topk_delta, FrozenSketch, Request, Response, ScoreBatch};
pub use registry::{
    ByteBudget, RegistryConfig, RegistryWatcher, Session, SessionRegistry, SCORER_ADMISSION,
};
pub use server::{IoMode, Server, ServerConfig, ServerHandle};
pub use subs::{PushOutcome, PushSink, SubscriptionHub, GOING_AWAY};
pub use storage::{LocalDirBackend, MemStorage, StorageBackend};
pub use wal::{Durability, Wal, WalConfig, WalFaultPlan};
