//! `sage-serve` — the multi-tenant streaming sketch service.
//!
//! The offline pipeline runs SAGE as a batch, single-process, two-pass job;
//! this subsystem promotes the FD sketch from a local variable to a served,
//! sessioned resource: external producers stream gradients in over a
//! length-prefixed binary protocol, and consumers run online selection
//! queries (Freeze / Score / TopK) against the evolving state.
//!
//! Layers:
//! * [`protocol`] — versioned, checksummed wire frames and the typed op
//!   surface (CreateSession / IngestBatch / MergeSketch / Freeze / Score /
//!   TopK / Checkpoint / Stats / CloseSession).
//! * [`registry`] — concurrent session registry: per-session bounded-channel
//!   ingest with backpressure, shard-ordered deterministic merges, admission
//!   control (max sessions, max resident ℓ×D bytes).
//! * [`checkpoint`] — session persistence/recovery (FNV-checksummed,
//!   atomic-rename framing in the style of `trainer::checkpoint`).
//! * [`server`] — TCP accept loop, thread-per-connection on
//!   `util::threadpool`, graceful rejection when the pool is gone.
//! * [`client`] — blocking client used by the CLI, the example, and tests.
//!
//! Exactness contract: a session fed shard-by-shard through
//! `pipeline::phase1_gradient_stream` / `phase2_score_stream` (one producer
//! per shard, shards assigned by `pipeline::shard_ranges`) yields the SAME
//! selected indices as `pipeline::run_selection` for the same
//! `(seed, workers)` configuration — verified end-to-end by
//! `tests/integration_service.rs`.

pub mod checkpoint;
pub mod client;
pub mod protocol;
pub mod registry;
pub mod server;

pub use checkpoint::SessionCheckpoint;
pub use client::ServiceClient;
pub use protocol::{FrozenSketch, Request, Response, ScoreBatch};
pub use registry::{RegistryConfig, Session, SessionRegistry};
pub use server::{Server, ServerConfig, ServerHandle};
