//! `sage-serve` wire protocol — length-prefixed binary frames with a
//! versioned header and an FNV-64 integrity checksum (same style as
//! `trainer::checkpoint`), so a torn or corrupted frame is always detected
//! and never half-applied.
//!
//! Frame layout (little-endian):
//!
//! ```text
//! magic    4B   "SGW1"
//! version  u16
//! opcode   u8    (response frames echo the request opcode)
//! flags    u8    (bit 0 = trace extension present; other bits reserved, 0)
//! status   u16   (0 = ok; requests always 0)
//! len      u32   payload byte length
//! [trace   16B]  when flags bit 0 is set: trace_id u64 + span_id u64
//! payload  len bytes
//! fnv64    u64   checksum of header + extensions + payload
//! ```
//!
//! The trace extension (see `util::trace` and docs/PROTOCOL.md §7) is a
//! versioned frame extension: frames without it are byte-identical to the
//! pre-extension wire format, so it is a payload-compatible addition under
//! the §7 versioning policy. Servers echo a request's trace context on the
//! response frame — including error frames — so client→server causality
//! survives failures. Unknown flag bits are rejected (torn stream), which
//! is what makes future extensions *versioned* rather than silent.
//!
//! Payloads are flat field sequences written by [`PayloadWriter`] and read
//! back by [`PayloadReader`]; strings are `u32` length + UTF-8, slices are
//! `u32`/`u64` element count + raw little-endian values. [`Request`] and
//! [`Response`] give the typed op surface: CreateSession / IngestBatch /
//! MergeSketch / Freeze / Score / TopK / Checkpoint / Stats / CloseSession
//! / MetricsSnapshot / TraceExport / Subscribe / Unsubscribe.
//!
//! Subscribe(12) opens the protocol's first *unsolicited* channel: after a
//! successful Subscribe the server may emit `TopKDelta` push frames
//! (response kind tag 9, carried on opcode 12 with status 0) at any point
//! between a client's request/response pairs. Clients therefore demux by
//! payload kind tag — see [`Response::is_topk_delta`] — rather than
//! assuming strict alternation. [`FrameDecoder`] is the incremental
//! (nonblocking-socket) counterpart of [`read_frame_event`], used by the
//! readiness-driven reactor.

use crate::sketch::SketchState;
use crate::tensor::Matrix;
use crate::util::metrics::HistogramStats;
use crate::util::trace::{SpanRecord, TraceCtx};
use std::io::{Read, Write};

pub const MAGIC: &[u8; 4] = b"SGW1";
pub const VERSION: u16 = 1;
/// Hard cap on a single frame payload (256 MiB) — protects the server from
/// unbounded allocation on a corrupt or hostile length field.
pub const MAX_PAYLOAD: usize = 256 << 20;
const HEADER_LEN: usize = 14;
/// Flags bit 0: a 16-byte trace extension (trace_id + span_id, both u64 LE)
/// sits between the header and the payload.
pub const FLAG_TRACE: u8 = 0x01;
const TRACE_EXT_LEN: usize = 16;

/// FNV-1a 64-bit, shared by framing and session checkpoints.
pub(crate) fn fnv64(bytes: &[u8]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100_0000_01b3);
    }
    h
}

// ---------------------------------------------------------------------------
// Framing
// ---------------------------------------------------------------------------

/// One decoded frame (request or response — direction is contextual).
#[derive(Clone, Debug, PartialEq)]
pub struct Frame {
    pub opcode: u8,
    pub status: u16,
    pub payload: Vec<u8>,
    /// Trace context carried in the frame's trace extension, if any.
    pub trace: Option<TraceCtx>,
}

/// Serialize a frame into one contiguous buffer (header + payload + fnv64).
/// Emits the pre-extension wire format byte for byte (flags = 0).
pub fn encode_frame(opcode: u8, status: u16, payload: &[u8]) -> Vec<u8> {
    encode_frame_traced(opcode, status, payload, None)
}

/// [`encode_frame`] with an optional trace extension. `trace: None` is
/// byte-identical to the historical format; `Some` sets flags bit 0 and
/// inserts the 16-byte extension between header and payload (covered by
/// the checksum; the `len` field still counts payload bytes only).
pub fn encode_frame_traced(
    opcode: u8,
    status: u16,
    payload: &[u8],
    trace: Option<TraceCtx>,
) -> Vec<u8> {
    let mut out = Vec::new();
    encode_frame_traced_into(&mut out, opcode, status, payload, trace);
    out
}

/// [`encode_frame`] into a caller-supplied buffer (see
/// [`encode_frame_traced_into`]).
pub fn encode_frame_into(out: &mut Vec<u8>, opcode: u8, status: u16, payload: &[u8]) {
    encode_frame_traced_into(out, opcode, status, payload, None);
}

/// [`encode_frame_traced`] into a caller-supplied buffer. The buffer is
/// cleared first, so the checksum covers exactly the frame bytes and the
/// result is byte-identical to the allocating variant (which delegates
/// here — one body, no way to diverge). The serve hot paths pair this
/// with [`crate::util::bufpool`] so steady-state encodes reuse capacity
/// instead of allocating per frame.
pub fn encode_frame_traced_into(
    out: &mut Vec<u8>,
    opcode: u8,
    status: u16,
    payload: &[u8],
    trace: Option<TraceCtx>,
) {
    let ext = if trace.is_some() { TRACE_EXT_LEN } else { 0 };
    out.clear();
    out.reserve(HEADER_LEN + ext + payload.len() + 8);
    out.extend_from_slice(MAGIC);
    out.extend_from_slice(&VERSION.to_le_bytes());
    out.push(opcode);
    out.push(if trace.is_some() { FLAG_TRACE } else { 0 });
    out.extend_from_slice(&status.to_le_bytes());
    out.extend_from_slice(&(payload.len() as u32).to_le_bytes());
    if let Some(t) = trace {
        out.extend_from_slice(&t.trace_id.to_le_bytes());
        out.extend_from_slice(&t.span_id.to_le_bytes());
    }
    out.extend_from_slice(payload);
    let sum = fnv64(out);
    out.extend_from_slice(&sum.to_le_bytes());
}

/// Write one frame. Rejects payloads over [`MAX_PAYLOAD`] locally with a
/// descriptive error — the receiver would tear the connection on them
/// anyway, and above u32 range the length field would silently truncate.
///
/// # Errors
/// Over-cap payloads and I/O failures on write/flush.
pub fn write_frame(
    w: &mut impl Write,
    opcode: u8,
    status: u16,
    payload: &[u8],
) -> Result<(), String> {
    write_frame_traced(w, opcode, status, payload, None)
}

/// [`write_frame`] with an optional trace extension (see
/// [`encode_frame_traced`]).
///
/// # Errors
/// Over-cap payloads and I/O failures on write/flush.
pub fn write_frame_traced(
    w: &mut impl Write,
    opcode: u8,
    status: u16,
    payload: &[u8],
    trace: Option<TraceCtx>,
) -> Result<(), String> {
    if payload.len() > MAX_PAYLOAD {
        return Err(format!(
            "frame payload {} bytes exceeds the {MAX_PAYLOAD}-byte wire cap; \
             split the batch into smaller blocks",
            payload.len()
        ));
    }
    let buf = encode_frame_traced(opcode, status, payload, trace);
    w.write_all(&buf).map_err(|e| format!("frame write: {e}"))?;
    w.flush().map_err(|e| format!("frame flush: {e}"))
}

/// Outcome of one frame-read attempt on a connection.
pub enum ReadEvent {
    Frame(Frame),
    /// Clean EOF before any header byte (peer closed between requests).
    Eof,
    /// The socket read timed out with NO frame in progress — the server's
    /// shutdown poll point. Only occurs when a read timeout is set.
    Idle,
}

/// Read one frame. `Ok(None)` on clean EOF before any header byte.
///
/// # Errors
/// Anything torn: bad magic, version mismatch, over-cap length, truncated
/// payload/checksum, checksum mismatch, I/O errors, and an idle timeout on
/// a reader without timeout handling (use [`read_frame_event`] to poll).
pub fn read_frame(r: &mut impl Read) -> Result<Option<Frame>, String> {
    match read_frame_event(r)? {
        ReadEvent::Frame(f) => Ok(Some(f)),
        ReadEvent::Eof => Ok(None),
        ReadEvent::Idle => Err("frame: idle timeout".into()),
    }
}

/// Read one frame, surfacing idle timeouts (sockets with a read timeout)
/// as [`ReadEvent::Idle`] so callers can poll a shutdown flag. Once a
/// frame's first byte arrives, timeouts mid-frame keep waiting instead of
/// tearing the stream.
pub fn read_frame_event(r: &mut impl Read) -> Result<ReadEvent, String> {
    let mut header = [0u8; HEADER_LEN];
    match fill(r, &mut header, true)? {
        Fill::Full => {}
        Fill::EofAtStart => return Ok(ReadEvent::Eof),
        Fill::IdleAtStart => return Ok(ReadEvent::Idle),
    }
    if &header[0..4] != MAGIC {
        return Err("frame: bad magic".into());
    }
    let version = u16::from_le_bytes([header[4], header[5]]);
    if version != VERSION {
        return Err(format!("frame: version {version} != {VERSION}"));
    }
    let opcode = header[6];
    let flags = header[7];
    if flags & !FLAG_TRACE != 0 {
        return Err(format!("frame: unknown flags {flags:#04x}"));
    }
    let status = u16::from_le_bytes([header[8], header[9]]);
    let len = u32::from_le_bytes([header[10], header[11], header[12], header[13]]) as usize;
    if len > MAX_PAYLOAD {
        return Err(format!("frame: payload {len} exceeds cap {MAX_PAYLOAD}"));
    }
    let mut ext = [0u8; TRACE_EXT_LEN];
    let trace = if flags & FLAG_TRACE != 0 {
        if !matches!(fill(r, &mut ext, false)?, Fill::Full) {
            return Err("frame: truncated trace extension".into());
        }
        Some(TraceCtx {
            trace_id: u64::from_le_bytes(ext[0..8].try_into().unwrap()),
            span_id: u64::from_le_bytes(ext[8..16].try_into().unwrap()),
        })
    } else {
        None
    };
    let mut payload = vec![0u8; len];
    if !matches!(fill(r, &mut payload, false)?, Fill::Full) {
        return Err("frame: truncated payload".into());
    }
    let mut sum_bytes = [0u8; 8];
    if !matches!(fill(r, &mut sum_bytes, false)?, Fill::Full) {
        return Err("frame: truncated checksum".into());
    }
    let stored = u64::from_le_bytes(sum_bytes);
    let mut check = Vec::with_capacity(HEADER_LEN + TRACE_EXT_LEN + len);
    check.extend_from_slice(&header);
    if trace.is_some() {
        check.extend_from_slice(&ext);
    }
    check.extend_from_slice(&payload);
    if fnv64(&check) != stored {
        return Err("frame: checksum mismatch (corrupt frame)".into());
    }
    Ok(ReadEvent::Frame(Frame {
        opcode,
        status,
        payload,
        trace,
    }))
}

enum Fill {
    Full,
    EofAtStart,
    IdleAtStart,
}

/// Consecutive mid-frame read timeouts tolerated before the stream is
/// declared stalled (with a 200 ms socket timeout ≈ 60 s of silence).
const MAX_MIDFRAME_TIMEOUTS: u32 = 300;

/// Fill `buf` completely. EOF or a read timeout before the first byte are
/// reported to the caller; EOF mid-buffer is a torn frame, and a bounded
/// number of mid-buffer timeouts keep waiting (a started frame is finished
/// unless the peer stalls outright).
fn fill(r: &mut impl Read, buf: &mut [u8], at_frame_start: bool) -> Result<Fill, String> {
    let mut filled = 0usize;
    let mut timeouts = 0u32;
    while filled < buf.len() {
        match r.read(&mut buf[filled..]) {
            Ok(0) => {
                if filled == 0 && at_frame_start {
                    return Ok(Fill::EofAtStart);
                }
                return Err("frame: truncated (peer closed mid-frame)".into());
            }
            Ok(n) => {
                filled += n;
                timeouts = 0;
            }
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(e)
                if matches!(
                    e.kind(),
                    std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                ) =>
            {
                if filled == 0 && at_frame_start {
                    return Ok(Fill::IdleAtStart);
                }
                timeouts += 1;
                if timeouts > MAX_MIDFRAME_TIMEOUTS {
                    return Err("frame: peer stalled mid-frame".into());
                }
            }
            Err(e) => return Err(format!("frame read: {e}")),
        }
    }
    Ok(Fill::Full)
}

/// Incremental frame decoder for nonblocking sockets: feed whatever bytes
/// `read(2)` produced via [`FrameDecoder::extend`], then drain complete
/// frames with [`FrameDecoder::next_frame`]. Validation (magic, version,
/// flags, length cap, checksum) matches [`read_frame_event`] exactly — a
/// stream is either accepted identically by both or torn by both.
#[derive(Default)]
pub struct FrameDecoder {
    buf: Vec<u8>,
    pos: usize,
}

/// Keep-capacity watermark for [`FrameDecoder`]'s internal buffer: once a
/// frame drains the buffer completely, capacity above this is released.
/// A single giant IngestBatch (up to the 256 MiB [`MAX_PAYLOAD`]) must
/// not pin its buffer for the life of the connection, while steady-state
/// small frames never pay a realloc.
const DECODER_KEEP_CAPACITY: usize = 256 << 10;

impl FrameDecoder {
    pub fn new() -> Self {
        Self::default()
    }

    /// Append freshly read bytes to the decode buffer.
    pub fn extend(&mut self, bytes: &[u8]) {
        // Compact before growing: drop the consumed prefix once it
        // dominates the buffer so a long-lived connection stays O(frame).
        if self.pos > 4096 && self.pos * 2 >= self.buf.len() {
            self.buf.drain(..self.pos);
            self.pos = 0;
        }
        self.buf.extend_from_slice(bytes);
    }

    /// Bytes buffered but not yet consumed by a decoded frame.
    pub fn buffered(&self) -> usize {
        self.buf.len() - self.pos
    }

    /// Current capacity of the internal buffer (tests pin the shrink
    /// watermark through this).
    pub fn capacity(&self) -> usize {
        self.buf.capacity()
    }

    /// Post-frame shrink policy: only when the buffer is fully drained
    /// (no partial frame in flight — shrinking mid-frame would memmove
    /// pending bytes for nothing) and capacity sits above the watermark.
    fn maybe_shrink(&mut self) {
        if self.pos == self.buf.len() && self.buf.capacity() > DECODER_KEEP_CAPACITY {
            self.buf.clear();
            self.pos = 0;
            self.buf.shrink_to(DECODER_KEEP_CAPACITY);
        }
    }

    /// Decode the next complete frame, if the buffer holds one.
    /// `Ok(None)` means "need more bytes"; errors are torn streams and
    /// must close the connection (resynchronization is impossible).
    pub fn next_frame(&mut self) -> Result<Option<Frame>, String> {
        let avail = &self.buf[self.pos..];
        if avail.len() < HEADER_LEN {
            return Ok(None);
        }
        if &avail[0..4] != MAGIC {
            return Err("frame: bad magic".into());
        }
        let version = u16::from_le_bytes([avail[4], avail[5]]);
        if version != VERSION {
            return Err(format!("frame: version {version} != {VERSION}"));
        }
        let opcode = avail[6];
        let flags = avail[7];
        if flags & !FLAG_TRACE != 0 {
            return Err(format!("frame: unknown flags {flags:#04x}"));
        }
        let status = u16::from_le_bytes([avail[8], avail[9]]);
        let len = u32::from_le_bytes([avail[10], avail[11], avail[12], avail[13]]) as usize;
        if len > MAX_PAYLOAD {
            return Err(format!("frame: payload {len} exceeds cap {MAX_PAYLOAD}"));
        }
        let ext = if flags & FLAG_TRACE != 0 {
            TRACE_EXT_LEN
        } else {
            0
        };
        let total = HEADER_LEN + ext + len + 8;
        if avail.len() < total {
            return Ok(None);
        }
        let body = &avail[..HEADER_LEN + ext + len];
        let stored = u64::from_le_bytes(avail[total - 8..total].try_into().unwrap());
        if fnv64(body) != stored {
            return Err("frame: checksum mismatch (corrupt frame)".into());
        }
        let trace = if ext != 0 {
            Some(TraceCtx {
                trace_id: u64::from_le_bytes(
                    avail[HEADER_LEN..HEADER_LEN + 8].try_into().unwrap(),
                ),
                span_id: u64::from_le_bytes(
                    avail[HEADER_LEN + 8..HEADER_LEN + 16].try_into().unwrap(),
                ),
            })
        } else {
            None
        };
        // Payload buffers come from (and are returned to) the pool by the
        // serve engines, so a steady-state decode allocates nothing.
        let mut payload = crate::util::bufpool::global().take();
        payload.extend_from_slice(&avail[HEADER_LEN + ext..HEADER_LEN + ext + len]);
        self.pos += total;
        self.maybe_shrink();
        Ok(Some(Frame {
            opcode,
            status,
            payload,
            trace,
        }))
    }
}

/// Apply one TopKDelta to a reconstructed selection: remove `evicted`
/// preserving order, then append `added` in order. This is the one
/// definition of the client-side reconstruction contract — the server's
/// diffing inverts exactly this.
///
/// # Errors
/// Malformed deltas: an evicted index absent from `base`, or an added
/// index already present after eviction. `base` is left unmodified on
/// error, so a client can fall back to a fresh TopK snapshot.
pub fn apply_topk_delta(
    base: &mut Vec<u64>,
    added: &[u64],
    evicted: &[u64],
) -> Result<(), String> {
    let have: std::collections::HashSet<u64> = base.iter().copied().collect();
    if let Some(missing) = evicted.iter().find(|i| !have.contains(i)) {
        return Err(format!("delta evicts index {missing} not in the selection"));
    }
    let gone: std::collections::HashSet<u64> = evicted.iter().copied().collect();
    if let Some(dup) = added
        .iter()
        .find(|i| have.contains(i) && !gone.contains(i))
    {
        return Err(format!("delta adds index {dup} already in the selection"));
    }
    if !gone.is_empty() {
        base.retain(|i| !gone.contains(i));
    }
    base.extend_from_slice(added);
    Ok(())
}

// ---------------------------------------------------------------------------
// Payload encoding helpers
// ---------------------------------------------------------------------------

/// Flat little-endian payload builder.
#[derive(Default)]
pub struct PayloadWriter {
    buf: Vec<u8>,
}

impl PayloadWriter {
    pub fn new() -> Self {
        Self::default()
    }

    /// Build on top of an existing (e.g. pooled) buffer, reusing its
    /// capacity. The buffer is cleared first.
    pub fn wrap(mut buf: Vec<u8>) -> Self {
        buf.clear();
        Self { buf }
    }

    pub fn into_bytes(self) -> Vec<u8> {
        self.buf
    }

    pub fn put_u8(&mut self, v: u8) {
        self.buf.push(v);
    }

    pub fn put_u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn put_u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn put_f64(&mut self, v: f64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn put_str(&mut self, s: &str) {
        self.put_u32(s.len() as u32);
        self.buf.extend_from_slice(s.as_bytes());
    }

    pub fn put_f32_slice(&mut self, xs: &[f32]) {
        self.put_u64(xs.len() as u64);
        for &v in xs {
            self.buf.extend_from_slice(&v.to_le_bytes());
        }
    }

    pub fn put_u32_slice(&mut self, xs: &[u32]) {
        self.put_u64(xs.len() as u64);
        for &v in xs {
            self.buf.extend_from_slice(&v.to_le_bytes());
        }
    }

    pub fn put_u64_slice(&mut self, xs: &[u64]) {
        self.put_u64(xs.len() as u64);
        for &v in xs {
            self.buf.extend_from_slice(&v.to_le_bytes());
        }
    }

    pub fn put_f64_slice(&mut self, xs: &[f64]) {
        self.put_u64(xs.len() as u64);
        for &v in xs {
            self.buf.extend_from_slice(&v.to_le_bytes());
        }
    }

    pub fn put_matrix(&mut self, m: &Matrix) {
        self.put_u32(m.rows() as u32);
        self.put_u32(m.cols() as u32);
        for &v in m.as_slice() {
            self.buf.extend_from_slice(&v.to_le_bytes());
        }
    }
}

/// Flat little-endian payload parser with strict bounds checking.
pub struct PayloadReader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> PayloadReader<'a> {
    pub fn new(buf: &'a [u8]) -> Self {
        Self { buf, pos: 0 }
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8], String> {
        if self.pos + n > self.buf.len() {
            return Err(format!(
                "payload: need {n} bytes at offset {}, have {}",
                self.pos,
                self.buf.len() - self.pos
            ));
        }
        let out = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(out)
    }

    pub fn u8(&mut self) -> Result<u8, String> {
        Ok(self.take(1)?[0])
    }

    pub fn u32(&mut self) -> Result<u32, String> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    pub fn u64(&mut self) -> Result<u64, String> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    pub fn f64(&mut self) -> Result<f64, String> {
        Ok(f64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    pub fn str(&mut self) -> Result<String, String> {
        let n = self.u32()? as usize;
        let bytes = self.take(n)?;
        String::from_utf8(bytes.to_vec()).map_err(|e| format!("payload: bad utf8: {e}"))
    }

    fn slice_len(&mut self) -> Result<usize, String> {
        let n = self.u64()? as usize;
        // Each element is ≥ 4 bytes; reject counts the buffer cannot hold.
        if n > self.buf.len() / 4 + 1 {
            return Err(format!("payload: implausible slice length {n}"));
        }
        Ok(n)
    }

    pub fn f32_slice(&mut self) -> Result<Vec<f32>, String> {
        let n = self.slice_len()?;
        let bytes = self.take(n * 4)?;
        Ok(bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
            .collect())
    }

    pub fn u32_slice(&mut self) -> Result<Vec<u32>, String> {
        let n = self.slice_len()?;
        let bytes = self.take(n * 4)?;
        Ok(bytes
            .chunks_exact(4)
            .map(|c| u32::from_le_bytes(c.try_into().unwrap()))
            .collect())
    }

    pub fn u64_slice(&mut self) -> Result<Vec<u64>, String> {
        let n = self.slice_len()?;
        let bytes = self.take(n * 8)?;
        Ok(bytes
            .chunks_exact(8)
            .map(|c| u64::from_le_bytes(c.try_into().unwrap()))
            .collect())
    }

    pub fn f64_slice(&mut self) -> Result<Vec<f64>, String> {
        let n = self.slice_len()?;
        let bytes = self.take(n * 8)?;
        Ok(bytes
            .chunks_exact(8)
            .map(|c| f64::from_le_bytes(c.try_into().unwrap()))
            .collect())
    }

    pub fn matrix(&mut self) -> Result<Matrix, String> {
        let rows = self.u32()? as usize;
        let cols = self.u32()? as usize;
        let count = rows
            .checked_mul(cols)
            .filter(|&c| c <= MAX_PAYLOAD / 4)
            .ok_or_else(|| "payload: matrix dims overflow".to_string())?;
        let bytes = self.take(count * 4)?;
        let data: Vec<f32> = bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
            .collect();
        Ok(Matrix::from_vec(rows, cols, data))
    }

    /// Assert the payload is fully consumed (catches layout drift).
    pub fn finish(&self) -> Result<(), String> {
        if self.pos != self.buf.len() {
            return Err(format!(
                "payload: {} trailing bytes",
                self.buf.len() - self.pos
            ));
        }
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// Ops
// ---------------------------------------------------------------------------

/// Request opcodes.
pub mod op {
    pub const CREATE_SESSION: u8 = 1;
    pub const INGEST_BATCH: u8 = 2;
    pub const MERGE_SKETCH: u8 = 3;
    pub const FREEZE: u8 = 4;
    pub const SCORE: u8 = 5;
    pub const TOP_K: u8 = 6;
    pub const CHECKPOINT: u8 = 7;
    pub const STATS: u8 = 8;
    pub const CLOSE_SESSION: u8 = 9;
    pub const METRICS_SNAPSHOT: u8 = 10;
    pub const TRACE_EXPORT: u8 = 11;
    pub const SUBSCRIBE: u8 = 12;
    pub const UNSUBSCRIBE: u8 = 13;

    /// Stable op name for logs, per-op latency metrics, and trace span
    /// names (`serve.<name>`). A bounded set — safe to embed in interned
    /// metric names.
    pub fn name(opcode: u8) -> &'static str {
        match opcode {
            CREATE_SESSION => "create_session",
            INGEST_BATCH => "ingest_batch",
            MERGE_SKETCH => "merge_sketch",
            FREEZE => "freeze",
            SCORE => "score",
            TOP_K => "top_k",
            CHECKPOINT => "checkpoint",
            STATS => "stats",
            CLOSE_SESSION => "close_session",
            METRICS_SNAPSHOT => "metrics_snapshot",
            TRACE_EXPORT => "trace_export",
            SUBSCRIBE => "subscribe",
            UNSUBSCRIBE => "unsubscribe",
            _ => "unknown",
        }
    }
}

/// One Phase-II scoring batch on the wire (mirrors
/// `AgreementScorer::add_batch` / `pipeline::ScoreBlock`).
#[derive(Clone, Debug, PartialEq)]
pub struct ScoreBatch {
    pub indices: Vec<u64>,
    pub labels: Vec<u32>,
    pub norms: Vec<f32>,
    pub losses: Vec<f32>,
    /// Normalized projections `[b × ℓ]`, row r ↔ indices[r].
    pub zhat: Matrix,
}

/// A decoded client request.
#[derive(Clone, Debug, PartialEq)]
pub enum Request {
    /// Register a session of `shards` independent shard slots, each holding
    /// an `ℓ × d` FD sketch (subject to the registry's admission control).
    CreateSession {
        name: String,
        ell: u32,
        d: u32,
        shards: u32,
    },
    /// Stream raw gradient rows `[b × d]` into one shard slot.
    IngestBatch {
        session: String,
        shard: u32,
        rows: Matrix,
    },
    /// Merge a client-side FD sketch into one shard slot (FD mergeability).
    MergeSketch {
        session: String,
        shard: u32,
        state: SketchState,
    },
    /// Drain ingest, merge shard sketches in shard order, return frozen S.
    /// Idempotent: later calls return the cached frozen sketch.
    Freeze { session: String },
    /// Stream Phase-II scoring entries for one shard (requires Freeze).
    Score {
        session: String,
        shard: u32,
        batch: ScoreBatch,
    },
    /// Finalize scores (first call) and run a selection rule online.
    TopK {
        session: String,
        method: String,
        k: u64,
        num_classes: u32,
        seed: u64,
    },
    /// Persist the session to the server's checkpoint directory.
    Checkpoint { session: String },
    /// Per-session counters (empty session name = server-wide stats).
    Stats { session: String },
    /// Tear the session down and release its admission budget.
    CloseSession { session: String },
    /// Histogram-grade metrics: every counter, gauge, and histogram summary
    /// (p50/p99/max/mean) in the server's registry whose name starts with
    /// `prefix` (empty = everything).
    MetricsSnapshot { prefix: String },
    /// Snapshot the server's span rings (for `sage trace export`).
    TraceExport,
    /// Register for push [`Response::TopKDelta`] frames whenever this
    /// session's selection changes under the given selection parameters
    /// (same field meanings as [`Request::TopK`]). Idempotent per
    /// (connection, session): a second Subscribe replaces the parameters
    /// and resets the delta epoch.
    Subscribe {
        session: String,
        method: String,
        k: u64,
        num_classes: u32,
        seed: u64,
    },
    /// Stop push deltas for this session on this connection.
    Unsubscribe { session: String },
}

/// Borrow-encoding fast path for the hot Phase-I op: serialize an
/// IngestBatch payload straight from a borrowed matrix. `Request::encode`
/// delegates here so the wire layout has exactly one definition.
pub fn encode_ingest_batch(session: &str, shard: u32, rows: &Matrix) -> Vec<u8> {
    let mut w = PayloadWriter::new();
    w.put_str(session);
    w.put_u32(shard);
    w.put_matrix(rows);
    w.into_bytes()
}

/// Borrow-encoding path for MergeSketch (see [`encode_ingest_batch`]):
/// serialize the payload straight from a borrowed sketch state. The WAL
/// logs merge ops through this helper so log records and wire frames
/// share one layout definition.
pub fn encode_merge_sketch(session: &str, shard: u32, state: &SketchState) -> Vec<u8> {
    let mut w = PayloadWriter::new();
    w.put_str(session);
    w.put_u32(shard);
    w.put_u32(state.ell);
    w.put_u32(state.d);
    w.put_u32(state.next_row);
    w.put_u64(state.shrink_count);
    w.put_u64(state.rows_seen);
    w.put_f64(state.delta_sum);
    w.put_f64(state.energy_seen);
    w.put_f32_slice(&state.buf);
    w.into_bytes()
}

/// Borrow-encoding fast path for the hot Phase-II op (see
/// [`encode_ingest_batch`]).
pub fn encode_score(
    session: &str,
    shard: u32,
    indices: &[u64],
    labels: &[u32],
    norms: &[f32],
    losses: &[f32],
    zhat: &Matrix,
) -> Vec<u8> {
    let mut w = PayloadWriter::new();
    w.put_str(session);
    w.put_u32(shard);
    w.put_u64_slice(indices);
    w.put_u32_slice(labels);
    w.put_f32_slice(norms);
    w.put_f32_slice(losses);
    w.put_matrix(zhat);
    w.into_bytes()
}

impl Request {
    pub fn opcode(&self) -> u8 {
        match self {
            Request::CreateSession { .. } => op::CREATE_SESSION,
            Request::IngestBatch { .. } => op::INGEST_BATCH,
            Request::MergeSketch { .. } => op::MERGE_SKETCH,
            Request::Freeze { .. } => op::FREEZE,
            Request::Score { .. } => op::SCORE,
            Request::TopK { .. } => op::TOP_K,
            Request::Checkpoint { .. } => op::CHECKPOINT,
            Request::Stats { .. } => op::STATS,
            Request::CloseSession { .. } => op::CLOSE_SESSION,
            Request::MetricsSnapshot { .. } => op::METRICS_SNAPSHOT,
            Request::TraceExport => op::TRACE_EXPORT,
            Request::Subscribe { .. } => op::SUBSCRIBE,
            Request::Unsubscribe { .. } => op::UNSUBSCRIBE,
        }
    }

    pub fn encode(&self) -> Vec<u8> {
        let mut w = PayloadWriter::new();
        match self {
            Request::CreateSession {
                name,
                ell,
                d,
                shards,
            } => {
                w.put_str(name);
                w.put_u32(*ell);
                w.put_u32(*d);
                w.put_u32(*shards);
            }
            Request::IngestBatch {
                session,
                shard,
                rows,
            } => return encode_ingest_batch(session, *shard, rows),
            Request::MergeSketch {
                session,
                shard,
                state,
            } => return encode_merge_sketch(session, *shard, state),
            Request::Freeze { session } => w.put_str(session),
            Request::Score {
                session,
                shard,
                batch,
            } => {
                return encode_score(
                    session,
                    *shard,
                    &batch.indices,
                    &batch.labels,
                    &batch.norms,
                    &batch.losses,
                    &batch.zhat,
                )
            }
            Request::TopK {
                session,
                method,
                k,
                num_classes,
                seed,
            } => {
                w.put_str(session);
                w.put_str(method);
                w.put_u64(*k);
                w.put_u32(*num_classes);
                w.put_u64(*seed);
            }
            Request::Checkpoint { session } => w.put_str(session),
            Request::Stats { session } => w.put_str(session),
            Request::CloseSession { session } => w.put_str(session),
            Request::MetricsSnapshot { prefix } => w.put_str(prefix),
            Request::TraceExport => {}
            Request::Subscribe {
                session,
                method,
                k,
                num_classes,
                seed,
            } => {
                w.put_str(session);
                w.put_str(method);
                w.put_u64(*k);
                w.put_u32(*num_classes);
                w.put_u64(*seed);
            }
            Request::Unsubscribe { session } => w.put_str(session),
        }
        w.into_bytes()
    }

    /// Decode a request payload for `opcode`.
    ///
    /// # Errors
    /// Unknown opcodes and malformed payloads (wrong field layout,
    /// out-of-bounds reads, bad UTF-8, trailing bytes).
    pub fn decode(opcode: u8, payload: &[u8]) -> Result<Request, String> {
        let mut r = PayloadReader::new(payload);
        let req = match opcode {
            op::CREATE_SESSION => Request::CreateSession {
                name: r.str()?,
                ell: r.u32()?,
                d: r.u32()?,
                shards: r.u32()?,
            },
            op::INGEST_BATCH => Request::IngestBatch {
                session: r.str()?,
                shard: r.u32()?,
                rows: r.matrix()?,
            },
            op::MERGE_SKETCH => {
                let session = r.str()?;
                let shard = r.u32()?;
                let state = SketchState {
                    ell: r.u32()?,
                    d: r.u32()?,
                    next_row: r.u32()?,
                    shrink_count: r.u64()?,
                    rows_seen: r.u64()?,
                    delta_sum: r.f64()?,
                    energy_seen: r.f64()?,
                    buf: r.f32_slice()?,
                };
                Request::MergeSketch {
                    session,
                    shard,
                    state,
                }
            }
            op::FREEZE => Request::Freeze { session: r.str()? },
            op::SCORE => Request::Score {
                session: r.str()?,
                shard: r.u32()?,
                batch: ScoreBatch {
                    indices: r.u64_slice()?,
                    labels: r.u32_slice()?,
                    norms: r.f32_slice()?,
                    losses: r.f32_slice()?,
                    zhat: r.matrix()?,
                },
            },
            op::TOP_K => Request::TopK {
                session: r.str()?,
                method: r.str()?,
                k: r.u64()?,
                num_classes: r.u32()?,
                seed: r.u64()?,
            },
            op::CHECKPOINT => Request::Checkpoint { session: r.str()? },
            op::STATS => Request::Stats { session: r.str()? },
            op::CLOSE_SESSION => Request::CloseSession { session: r.str()? },
            op::METRICS_SNAPSHOT => Request::MetricsSnapshot { prefix: r.str()? },
            op::TRACE_EXPORT => Request::TraceExport,
            op::SUBSCRIBE => Request::Subscribe {
                session: r.str()?,
                method: r.str()?,
                k: r.u64()?,
                num_classes: r.u32()?,
                seed: r.u64()?,
            },
            op::UNSUBSCRIBE => Request::Unsubscribe { session: r.str()? },
            other => return Err(format!("unknown opcode {other}")),
        };
        r.finish()?;
        Ok(req)
    }
}

/// Frozen-sketch payload returned by Freeze.
#[derive(Clone, Debug, PartialEq)]
pub struct FrozenSketch {
    /// The frozen `ℓ × d` sketch S.
    pub sketch: Matrix,
    /// Online covariance-error certificate Σδ.
    pub shift_bound: f64,
    pub shrinks: u64,
    pub rows_seen: u64,
    /// O(ℓD) resident bytes of the session's merge buffer.
    pub sketch_bytes: u64,
}

/// A decoded server response.
#[derive(Clone, Debug, PartialEq)]
pub enum Response {
    Ok,
    Error { message: String },
    Ingested { rows_seen: u64 },
    Frozen(FrozenSketch),
    Selected { indices: Vec<u64>, weights: Vec<f32> },
    Stats { pairs: Vec<(String, u64)> },
    Checkpointed {
        path: String,
        /// Highest WAL sequence number the checkpoint covers (0 when the
        /// server runs with `--durability none`).
        wal_seq: u64,
    },
    /// Full registry snapshot: counters + gauges as name/value pairs,
    /// histograms as scalar summaries (the MetricsSnapshot reply).
    Metrics {
        counters: Vec<(String, u64)>,
        gauges: Vec<(String, u64)>,
        hists: Vec<(String, HistogramStats)>,
    },
    /// Recorded spans from the server's trace rings (the TraceExport reply).
    Trace { spans: Vec<SpanRecord> },
    /// **Unsolicited push frame** (docs/PROTOCOL.md §3.14): the subscribed
    /// session's selection changed. Carried on opcode [`op::SUBSCRIBE`]
    /// with status 0; demux by kind tag ([`Response::is_topk_delta`]).
    ///
    /// Reconstruction contract: starting from the previous epoch's index
    /// list, remove `evicted` (order-preserving), then append `added` in
    /// order — the result is byte-identical to the server's selection at
    /// this epoch. Epoch 1's base is the empty list. Under backpressure
    /// deltas coalesce: epochs may skip, but each delta is cumulative
    /// since the last one actually delivered, so the invariant holds.
    TopKDelta {
        session: String,
        /// Monotone per-subscription delta sequence number (starts at 1).
        epoch: u64,
        /// Indices entering the selection, in selection order.
        added: Vec<u64>,
        /// Indices leaving the selection, in previous-selection order.
        evicted: Vec<u64>,
        /// Minimum consensus-agreement score α over the current selection
        /// (NaN encoded as-is when the selection is empty).
        watermark: f64,
    },
}

const RESP_OK: u8 = 0;
const RESP_ERROR: u8 = 1;
const RESP_INGESTED: u8 = 2;
const RESP_FROZEN: u8 = 3;
const RESP_SELECTED: u8 = 4;
const RESP_STATS: u8 = 5;
const RESP_CHECKPOINTED: u8 = 6;
const RESP_METRICS: u8 = 7;
const RESP_TRACE: u8 = 8;
const RESP_TOPK_DELTA: u8 = 9;

fn put_pairs(w: &mut PayloadWriter, pairs: &[(String, u64)]) {
    w.put_u32(pairs.len() as u32);
    for (name, v) in pairs {
        w.put_str(name);
        w.put_u64(*v);
    }
}

fn get_pairs(r: &mut PayloadReader) -> Result<Vec<(String, u64)>, String> {
    let n = r.u32()? as usize;
    let mut pairs = Vec::with_capacity(n.min(4096));
    for _ in 0..n {
        let name = r.str()?;
        let v = r.u64()?;
        pairs.push((name, v));
    }
    Ok(pairs)
}

impl Response {
    /// Frame status word: 0 ok, 1 application error.
    pub fn status(&self) -> u16 {
        match self {
            Response::Error { .. } => 1,
            _ => 0,
        }
    }

    /// Whether an encoded response payload is a push [`Response::TopKDelta`]
    /// frame. Subscribed clients call this on every ok frame to separate
    /// unsolicited pushes from the reply they are waiting for.
    pub fn is_topk_delta(payload: &[u8]) -> bool {
        payload.first() == Some(&RESP_TOPK_DELTA)
    }

    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::new();
        self.encode_into(&mut out);
        out
    }

    /// [`Response::encode`] into a caller-supplied buffer (cleared
    /// first), reusing its capacity — the serve hot paths feed pooled
    /// buffers through here. One body backs both variants, so the bytes
    /// cannot diverge.
    pub fn encode_into(&self, out: &mut Vec<u8>) {
        let mut w = PayloadWriter::wrap(std::mem::take(out));
        match self {
            Response::Ok => w.put_u8(RESP_OK),
            Response::Error { message } => {
                w.put_u8(RESP_ERROR);
                w.put_str(message);
            }
            Response::Ingested { rows_seen } => {
                w.put_u8(RESP_INGESTED);
                w.put_u64(*rows_seen);
            }
            Response::Frozen(f) => {
                w.put_u8(RESP_FROZEN);
                w.put_matrix(&f.sketch);
                w.put_f64(f.shift_bound);
                w.put_u64(f.shrinks);
                w.put_u64(f.rows_seen);
                w.put_u64(f.sketch_bytes);
            }
            Response::Selected { indices, weights } => {
                w.put_u8(RESP_SELECTED);
                w.put_u64_slice(indices);
                w.put_f32_slice(weights);
            }
            Response::Stats { pairs } => {
                w.put_u8(RESP_STATS);
                put_pairs(&mut w, pairs);
            }
            Response::Checkpointed { path, wal_seq } => {
                w.put_u8(RESP_CHECKPOINTED);
                w.put_str(path);
                w.put_u64(*wal_seq);
            }
            Response::Metrics {
                counters,
                gauges,
                hists,
            } => {
                w.put_u8(RESP_METRICS);
                put_pairs(&mut w, counters);
                put_pairs(&mut w, gauges);
                w.put_u32(hists.len() as u32);
                for (name, h) in hists {
                    w.put_str(name);
                    w.put_u64(h.count);
                    w.put_u64(h.sum);
                    w.put_u64(h.max);
                    w.put_f64(h.mean);
                    w.put_u64(h.p50);
                    w.put_u64(h.p99);
                }
            }
            Response::Trace { spans } => {
                w.put_u8(RESP_TRACE);
                w.put_u32(spans.len() as u32);
                for s in spans {
                    w.put_str(&s.name);
                    w.put_u64(s.trace_id);
                    w.put_u64(s.span_id);
                    w.put_u64(s.parent_id);
                    w.put_u64(s.start_unix_ns);
                    w.put_u64(s.dur_ns);
                    w.put_u32(s.pid);
                    w.put_u32(s.tid);
                }
            }
            Response::TopKDelta {
                session,
                epoch,
                added,
                evicted,
                watermark,
            } => {
                w.put_u8(RESP_TOPK_DELTA);
                w.put_str(session);
                w.put_u64(*epoch);
                w.put_u64_slice(added);
                w.put_u64_slice(evicted);
                w.put_f64(*watermark);
            }
        }
        *out = w.into_bytes();
    }

    /// Decode a response payload (kind tag + fields).
    ///
    /// # Errors
    /// Unknown kind tags and malformed payloads (see [`Request::decode`]).
    pub fn decode(payload: &[u8]) -> Result<Response, String> {
        let mut r = PayloadReader::new(payload);
        let resp = match r.u8()? {
            RESP_OK => Response::Ok,
            RESP_ERROR => Response::Error { message: r.str()? },
            RESP_INGESTED => Response::Ingested {
                rows_seen: r.u64()?,
            },
            RESP_FROZEN => Response::Frozen(FrozenSketch {
                sketch: r.matrix()?,
                shift_bound: r.f64()?,
                shrinks: r.u64()?,
                rows_seen: r.u64()?,
                sketch_bytes: r.u64()?,
            }),
            RESP_SELECTED => Response::Selected {
                indices: r.u64_slice()?,
                weights: r.f32_slice()?,
            },
            RESP_STATS => Response::Stats {
                pairs: get_pairs(&mut r)?,
            },
            RESP_CHECKPOINTED => Response::Checkpointed {
                path: r.str()?,
                wal_seq: r.u64()?,
            },
            RESP_METRICS => {
                let counters = get_pairs(&mut r)?;
                let gauges = get_pairs(&mut r)?;
                let n = r.u32()? as usize;
                let mut hists = Vec::with_capacity(n.min(4096));
                for _ in 0..n {
                    let name = r.str()?;
                    hists.push((
                        name,
                        HistogramStats {
                            count: r.u64()?,
                            sum: r.u64()?,
                            max: r.u64()?,
                            mean: r.f64()?,
                            p50: r.u64()?,
                            p99: r.u64()?,
                        },
                    ));
                }
                Response::Metrics {
                    counters,
                    gauges,
                    hists,
                }
            }
            RESP_TRACE => {
                let n = r.u32()? as usize;
                let mut spans = Vec::with_capacity(n.min(4096));
                for _ in 0..n {
                    spans.push(SpanRecord {
                        name: r.str()?,
                        trace_id: r.u64()?,
                        span_id: r.u64()?,
                        parent_id: r.u64()?,
                        start_unix_ns: r.u64()?,
                        dur_ns: r.u64()?,
                        pid: r.u32()?,
                        tid: r.u32()?,
                    });
                }
                Response::Trace { spans }
            }
            RESP_TOPK_DELTA => Response::TopKDelta {
                session: r.str()?,
                epoch: r.u64()?,
                added: r.u64_slice()?,
                evicted: r.u64_slice()?,
                watermark: r.f64()?,
            },
            other => return Err(format!("unknown response tag {other}")),
        };
        r.finish()?;
        Ok(resp)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn round_trip_request(req: Request) {
        let payload = req.encode();
        let frame = encode_frame(req.opcode(), 0, &payload);
        let mut cursor = &frame[..];
        let back = read_frame(&mut cursor).unwrap().unwrap();
        assert_eq!(back.opcode, req.opcode());
        let decoded = Request::decode(back.opcode, &back.payload).unwrap();
        assert_eq!(decoded, req);
    }

    #[test]
    fn all_requests_round_trip() {
        round_trip_request(Request::CreateSession {
            name: "s1".into(),
            ell: 8,
            d: 64,
            shards: 4,
        });
        round_trip_request(Request::IngestBatch {
            session: "s1".into(),
            shard: 2,
            rows: Matrix::from_fn(3, 5, |r, c| (r * 5 + c) as f32 * 0.5),
        });
        round_trip_request(Request::MergeSketch {
            session: "s1".into(),
            shard: 0,
            state: SketchState {
                ell: 2,
                d: 3,
                next_row: 1,
                shrink_count: 4,
                rows_seen: 17,
                delta_sum: 0.25,
                energy_seen: 9.5,
                buf: vec![1.0, 2.0, 3.0, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0],
            },
        });
        round_trip_request(Request::Freeze {
            session: "s1".into(),
        });
        round_trip_request(Request::Score {
            session: "s1".into(),
            shard: 1,
            batch: ScoreBatch {
                indices: vec![10, 11],
                labels: vec![0, 3],
                norms: vec![1.5, 0.25],
                losses: vec![2.0, 0.5],
                zhat: Matrix::from_fn(2, 4, |r, c| (r + c) as f32),
            },
        });
        round_trip_request(Request::TopK {
            session: "s1".into(),
            method: "sage".into(),
            k: 100,
            num_classes: 10,
            seed: 7,
        });
        round_trip_request(Request::Checkpoint {
            session: "s1".into(),
        });
        round_trip_request(Request::Stats { session: "".into() });
        round_trip_request(Request::CloseSession {
            session: "s1".into(),
        });
        round_trip_request(Request::MetricsSnapshot {
            prefix: "service.".into(),
        });
        round_trip_request(Request::TraceExport);
        round_trip_request(Request::Subscribe {
            session: "s1".into(),
            method: "sage".into(),
            k: 50,
            num_classes: 10,
            seed: 7,
        });
        round_trip_request(Request::Unsubscribe {
            session: "s1".into(),
        });
    }

    #[test]
    fn all_responses_round_trip() {
        let responses = vec![
            Response::Ok,
            Response::Error {
                message: "nope".into(),
            },
            Response::Ingested { rows_seen: 42 },
            Response::Frozen(FrozenSketch {
                sketch: Matrix::from_fn(2, 3, |r, c| (r * 3 + c) as f32),
                shift_bound: 1.25,
                shrinks: 3,
                rows_seen: 99,
                sketch_bytes: 48,
            }),
            Response::Selected {
                indices: vec![5, 1, 9],
                weights: vec![],
            },
            Response::Stats {
                pairs: vec![("a.rows".into(), 10), ("a.batches".into(), 2)],
            },
            Response::Checkpointed {
                path: "/tmp/x.sagesess".into(),
                wal_seq: 17,
            },
            Response::Metrics {
                counters: vec![("service.server.requests".into(), 12)],
                gauges: vec![("service.ingest.queue_depth".into(), 3)],
                hists: vec![(
                    "service.server.handle.ns".into(),
                    HistogramStats {
                        count: 12,
                        sum: 24_000,
                        max: 9_000,
                        mean: 2_000.0,
                        p50: 1_024,
                        p99: 8_192,
                    },
                )],
            },
            Response::Trace {
                spans: vec![SpanRecord {
                    name: "serve.freeze".into(),
                    trace_id: 0xaa,
                    span_id: 0xbb,
                    parent_id: 0x11,
                    start_unix_ns: 1_000,
                    dur_ns: 250,
                    pid: 7,
                    tid: 3,
                }],
            },
            Response::TopKDelta {
                session: "s1".into(),
                epoch: 3,
                added: vec![42, 7],
                evicted: vec![5],
                watermark: 0.75,
            },
        ];
        for resp in responses {
            let payload = resp.encode();
            let back = Response::decode(&payload).unwrap();
            assert_eq!(back, resp);
            assert_eq!(resp.status() == 0, !matches!(resp, Response::Error { .. }));
        }
    }

    #[test]
    fn trace_extension_round_trips_and_is_checksummed() {
        let payload = Request::Freeze {
            session: "abc".into(),
        }
        .encode();
        let ctx = TraceCtx {
            trace_id: 0xdead_beef_cafe_f00d,
            span_id: 0x0123_4567_89ab_cdef,
        };
        let frame = encode_frame_traced(op::FREEZE, 0, &payload, Some(ctx));
        let mut cursor = &frame[..];
        let back = read_frame(&mut cursor).unwrap().unwrap();
        assert_eq!(back.trace, Some(ctx));
        assert_eq!(back.opcode, op::FREEZE);
        assert_eq!(back.payload, payload);
        // Flip a bit inside the extension: the checksum must catch it.
        let mut torn = frame.clone();
        torn[HEADER_LEN + 3] ^= 0x40;
        let mut cursor = &torn[..];
        assert!(read_frame(&mut cursor).is_err());
    }

    #[test]
    fn untraced_encoding_is_byte_identical_to_legacy() {
        // The trace extension must be strictly additive: a frame without it
        // is the historical wire format, which the documented example frames
        // in docs/PROTOCOL.md pin byte for byte.
        let payload = Request::Freeze {
            session: "abc".into(),
        }
        .encode();
        assert_eq!(
            encode_frame(op::FREEZE, 0, &payload),
            encode_frame_traced(op::FREEZE, 0, &payload, None)
        );
        let frame = encode_frame(op::FREEZE, 0, &payload);
        assert_eq!(frame[7], 0, "flags byte must stay 0 without extension");
        let mut cursor = &frame[..];
        assert_eq!(read_frame(&mut cursor).unwrap().unwrap().trace, None);
    }

    #[test]
    fn unknown_flag_bits_rejected() {
        let payload = Request::Freeze { session: "x".into() }.encode();
        let mut frame = encode_frame(op::FREEZE, 0, &payload);
        frame[7] = 0x02; // reserved bit; fix the checksum so only flags differ
        let body_len = frame.len() - 8;
        let sum = fnv64(&frame[..body_len]);
        frame[body_len..].copy_from_slice(&sum.to_le_bytes());
        let mut cursor = &frame[..];
        assert!(read_frame(&mut cursor).unwrap_err().contains("flags"));
    }

    #[test]
    fn corruption_is_detected() {
        let payload = Request::Freeze {
            session: "abc".into(),
        }
        .encode();
        let mut frame = encode_frame(op::FREEZE, 0, &payload);
        let mid = frame.len() / 2;
        frame[mid] ^= 0xFF;
        let mut cursor = &frame[..];
        assert!(read_frame(&mut cursor).is_err());
    }

    #[test]
    fn truncation_is_detected() {
        let payload = Request::Freeze {
            session: "abc".into(),
        }
        .encode();
        let frame = encode_frame(op::FREEZE, 0, &payload);
        let mut cursor = &frame[..frame.len() - 3];
        assert!(read_frame(&mut cursor).is_err());
    }

    #[test]
    fn clean_eof_is_none() {
        let empty: &[u8] = &[];
        let mut cursor = empty;
        assert_eq!(read_frame(&mut cursor).unwrap(), None);
    }

    #[test]
    fn version_mismatch_rejected() {
        let payload = Request::Freeze { session: "x".into() }.encode();
        let mut frame = encode_frame(op::FREEZE, 0, &payload);
        frame[4] = 99; // bump version; checksum covers it, so fix checksum
        let body_len = frame.len() - 8;
        let sum = fnv64(&frame[..body_len]);
        let end = frame.len();
        frame[body_len..end].copy_from_slice(&sum.to_le_bytes());
        let mut cursor = &frame[..];
        assert!(read_frame(&mut cursor).unwrap_err().contains("version"));
    }

    #[test]
    fn oversized_payload_rejected() {
        // Hand-craft a header announcing an over-cap payload.
        let mut frame = Vec::new();
        frame.extend_from_slice(MAGIC);
        frame.extend_from_slice(&VERSION.to_le_bytes());
        frame.push(op::FREEZE);
        frame.push(0);
        frame.extend_from_slice(&0u16.to_le_bytes());
        frame.extend_from_slice(&(u32::MAX).to_le_bytes());
        let mut cursor = &frame[..];
        assert!(read_frame(&mut cursor).unwrap_err().contains("cap"));
    }

    #[test]
    fn topk_delta_tag_is_detectable() {
        let delta = Response::TopKDelta {
            session: "s".into(),
            epoch: 1,
            added: vec![1],
            evicted: vec![],
            watermark: 0.5,
        };
        assert!(Response::is_topk_delta(&delta.encode()));
        assert!(!Response::is_topk_delta(&Response::Ok.encode()));
        assert!(!Response::is_topk_delta(&[]));
    }

    #[test]
    fn apply_topk_delta_matches_contract() {
        let mut sel = vec![3u64, 9, 1, 7];
        apply_topk_delta(&mut sel, &[5, 2], &[9, 7]).unwrap();
        assert_eq!(sel, vec![3, 1, 5, 2]);
        // Snapshot form: evict everything, add the full new list.
        let mut sel = vec![3u64, 1, 5, 2];
        apply_topk_delta(&mut sel, &[8, 6, 4], &[3, 1, 5, 2]).unwrap();
        assert_eq!(sel, vec![8, 6, 4]);
        // Empty delta is the identity.
        apply_topk_delta(&mut sel, &[], &[]).unwrap();
        assert_eq!(sel, vec![8, 6, 4]);
        // Malformed deltas are rejected and leave the base untouched.
        assert!(apply_topk_delta(&mut sel, &[], &[99]).is_err());
        assert!(apply_topk_delta(&mut sel, &[8], &[]).is_err());
        assert_eq!(sel, vec![8, 6, 4]);
    }

    #[test]
    fn frame_decoder_matches_blocking_reader_byte_by_byte() {
        let payload = Request::Subscribe {
            session: "s1".into(),
            method: "sage".into(),
            k: 10,
            num_classes: 4,
            seed: 0,
        }
        .encode();
        let ctx = TraceCtx {
            trace_id: 0x1111,
            span_id: 0x2222,
        };
        let mut stream = encode_frame(op::SUBSCRIBE, 0, &payload);
        stream.extend_from_slice(&encode_frame_traced(op::FREEZE, 0, b"", Some(ctx)));

        let mut dec = FrameDecoder::new();
        let mut frames = Vec::new();
        for &b in &stream {
            dec.extend(&[b]);
            while let Some(f) = dec.next_frame().unwrap() {
                frames.push(f);
            }
        }
        assert_eq!(frames.len(), 2);
        assert_eq!(frames[0].opcode, op::SUBSCRIBE);
        assert_eq!(frames[0].payload, payload);
        assert_eq!(frames[0].trace, None);
        assert_eq!(frames[1].opcode, op::FREEZE);
        assert_eq!(frames[1].trace, Some(ctx));
        assert_eq!(dec.buffered(), 0);

        // The whole stream in one extend drains identically.
        let mut dec = FrameDecoder::new();
        dec.extend(&stream);
        assert_eq!(dec.next_frame().unwrap().unwrap().opcode, op::SUBSCRIBE);
        assert_eq!(dec.next_frame().unwrap().unwrap().opcode, op::FREEZE);
        assert!(dec.next_frame().unwrap().is_none());
    }

    #[test]
    fn frame_decoder_tears_like_the_blocking_reader() {
        let payload = Request::Freeze { session: "x".into() }.encode();
        let mut frame = encode_frame(op::FREEZE, 0, &payload);
        let mid = frame.len() / 2;
        frame[mid] ^= 0xFF;
        let mut dec = FrameDecoder::new();
        dec.extend(&frame);
        assert!(dec.next_frame().is_err());

        let mut dec = FrameDecoder::new();
        dec.extend(b"NOPE");
        dec.extend(&[0u8; 10]);
        assert!(dec.next_frame().unwrap_err().contains("magic"));
    }

    #[test]
    fn frame_decoder_compacts_consumed_prefix() {
        let frame = encode_frame(op::FREEZE, 0, &Request::Freeze { session: "x".into() }.encode());
        let mut dec = FrameDecoder::new();
        for _ in 0..300 {
            dec.extend(&frame);
            assert!(dec.next_frame().unwrap().is_some());
        }
        assert_eq!(dec.buffered(), 0);
        // The internal buffer must not have grown to 300 × frame size.
        assert!(dec.buf.len() < frame.len() * 4 + 8192);
    }

    #[test]
    fn frame_decoder_releases_capacity_after_giant_frame() {
        let big = encode_frame(op::INGEST_BATCH, 0, &vec![0xABu8; 16 << 20]);
        let small = encode_frame(op::FREEZE, 0, &Request::Freeze { session: "x".into() }.encode());
        let mut dec = FrameDecoder::new();
        dec.extend(&big);
        assert!(dec.capacity() >= 16 << 20);
        let f = dec.next_frame().unwrap().unwrap();
        assert_eq!(f.payload.len(), 16 << 20);
        // Fully drained: the keep-capacity watermark must release the
        // 16 MiB now, not hold it for the connection's lifetime.
        assert!(
            dec.capacity() <= DECODER_KEEP_CAPACITY,
            "decoder still pins {} bytes",
            dec.capacity()
        );
        // Steady-state small frames decode fine and never re-inflate it.
        for _ in 0..64 {
            dec.extend(&small);
            let f = dec.next_frame().unwrap().unwrap();
            assert_eq!(f.opcode, op::FREEZE);
        }
        assert!(dec.capacity() <= DECODER_KEEP_CAPACITY);
    }

    #[test]
    fn into_variants_match_allocating_encoders_byte_for_byte() {
        let resp = Response::Stats {
            pairs: vec![("rows".into(), 7), ("shards".into(), 2)],
        };
        // Start from a dirty buffer with stale bytes: _into must clear it.
        let mut payload = vec![0xFFu8; 64];
        resp.encode_into(&mut payload);
        assert_eq!(payload, resp.encode());

        let trace = Some(TraceCtx {
            trace_id: 0x0123_4567_89ab_cdef,
            span_id: 0xfedc_ba98_7654_3210,
        });
        let mut frame = vec![9u8; 3];
        encode_frame_traced_into(&mut frame, op::STATS, 0, &payload, trace);
        assert_eq!(frame, encode_frame_traced(op::STATS, 0, &payload, trace));

        let mut untraced = Vec::new();
        encode_frame_into(&mut untraced, op::STATS, 0, &payload);
        assert_eq!(untraced, encode_frame(op::STATS, 0, &payload));
    }

    #[test]
    fn payload_reader_rejects_trailing_bytes() {
        let mut payload = Request::Freeze { session: "x".into() }.encode();
        payload.push(0);
        assert!(Request::decode(op::FREEZE, &payload)
            .unwrap_err()
            .contains("trailing"));
    }
}
