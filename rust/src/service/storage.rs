//! Pluggable durable storage for the service's WAL segments.
//!
//! The [`StorageBackend`] trait is deliberately object-store shaped: flat
//! string keys with `/` separators (no directory semantics beyond listing
//! by prefix), whole-object atomic replacement, and an append stream for
//! log segments. A local filesystem implementation ([`LocalDirBackend`])
//! backs production today; an S3/GCS-style implementation only needs to
//! map the same seven operations onto multipart uploads, which is why the
//! WAL layer (`service::wal`) never touches `std::fs` directly.
//!
//! Two implementations ship:
//!
//! * [`LocalDirBackend`] — keys are paths under a root directory.
//!   `put_atomic` is temp-file + fsync + rename (a crash mid-write can
//!   never damage the previous object), and append handles expose a
//!   cloned-descriptor [`SyncHandle`] so a group-commit leader can fsync
//!   outside the appender's lock.
//! * [`MemStorage`] — an in-memory map for unit tests; `sync` is a no-op.
//!
//! Durability vocabulary: `append` + `flush` make bytes visible to a
//! re-reader of the same backend; only [`SyncHandle::sync`] (fsync) makes
//! them survive a process or host crash on the local backend.

use std::collections::BTreeMap;
use std::fs::{File, OpenOptions};
use std::io::Write;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};

/// One durable object namespace (a directory tree or a bucket).
///
/// Keys are relative, `/`-separated, and never start with `/`. All methods
/// are safe to call from multiple threads; per-key append streams are
/// single-writer by construction (the WAL holds one handle per shard).
pub trait StorageBackend: Send + Sync {
    /// Human-readable backend identity for logs.
    fn kind(&self) -> &'static str;

    /// Atomically replace the object at `key` with `bytes`: after a crash
    /// at any point, a reader sees either the old object or the new one,
    /// never a prefix.
    fn put_atomic(&self, key: &str, bytes: &[u8]) -> Result<(), String>;

    /// Full object contents, or `None` if the key does not exist.
    fn read(&self, key: &str) -> Result<Option<Vec<u8>>, String>;

    /// All keys starting with `prefix`, lexicographically sorted.
    fn list(&self, prefix: &str) -> Result<Vec<String>, String>;

    /// Remove the object. Removing a missing key is not an error.
    fn delete(&self, key: &str) -> Result<(), String>;

    /// Shrink the object to `len` bytes (torn-tail repair). The key must
    /// exist.
    fn truncate(&self, key: &str, len: u64) -> Result<(), String>;

    /// Object size in bytes, or `None` if the key does not exist.
    fn size(&self, key: &str) -> Result<Option<u64>, String>;

    /// Open `key` for appending, creating it (and any parent namespace)
    /// if missing. Writes go to the current end of the object.
    fn open_append(&self, key: &str) -> Result<Box<dyn AppendHandle>, String>;
}

/// A single-writer append stream over one object.
pub trait AppendHandle: Send {
    /// Buffer `bytes` at the end of the object.
    fn append(&mut self, bytes: &[u8]) -> Result<(), String>;

    /// Make appended bytes visible to readers of the same backend (not
    /// necessarily crash-durable — that is [`SyncHandle::sync`]).
    fn flush(&mut self) -> Result<(), String>;

    /// An independent crash-durability handle for this object, usable from
    /// another thread while appends continue (group commit: the leader
    /// fsyncs on the syncer while followers keep writing under the lock).
    fn syncer(&self) -> Result<Arc<dyn SyncHandle>, String>;
}

/// Crash-durability barrier for one object: on return, every byte flushed
/// before the call survives a process or OS crash.
pub trait SyncHandle: Send + Sync {
    fn sync(&self) -> Result<(), String>;
}

// ---------------------------------------------------------------------------
// Local filesystem backend
// ---------------------------------------------------------------------------

/// fsync a directory so a just-created, just-renamed, or just-removed
/// entry survives a host crash: fdatasync on the file covers its bytes,
/// but the directory block that *names* the file must reach disk too, or
/// power loss can make a durably-written object vanish from its parent.
pub(crate) fn fsync_dir(dir: &Path) -> Result<(), String> {
    File::open(dir)
        .and_then(|f| f.sync_all())
        .map_err(|e| format!("fsync {}: {e}", dir.display()))
}

/// [`StorageBackend`] over a root directory; keys map to relative paths.
pub struct LocalDirBackend {
    root: PathBuf,
}

impl LocalDirBackend {
    /// Root the backend at `root`, creating the directory if missing.
    pub fn create(root: &Path) -> Result<Self, String> {
        std::fs::create_dir_all(root).map_err(|e| format!("{}: {e}", root.display()))?;
        Ok(Self {
            root: root.to_path_buf(),
        })
    }

    fn path_of(&self, key: &str) -> PathBuf {
        self.root.join(key)
    }

    fn walk(&self, dir: &Path, out: &mut Vec<String>) -> Result<(), String> {
        let entries = match std::fs::read_dir(dir) {
            Ok(entries) => entries,
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(()),
            Err(e) => return Err(format!("{}: {e}", dir.display())),
        };
        for entry in entries {
            let entry = entry.map_err(|e| format!("{}: {e}", dir.display()))?;
            let path = entry.path();
            if path.is_dir() {
                self.walk(&path, out)?;
            } else if let Ok(rel) = path.strip_prefix(&self.root) {
                // Keys use `/` regardless of host separator.
                let key: Vec<String> = rel
                    .components()
                    .map(|c| c.as_os_str().to_string_lossy().into_owned())
                    .collect();
                out.push(key.join("/"));
            }
        }
        Ok(())
    }
}

impl StorageBackend for LocalDirBackend {
    fn kind(&self) -> &'static str {
        "local-dir"
    }

    fn put_atomic(&self, key: &str, bytes: &[u8]) -> Result<(), String> {
        let path = self.path_of(key);
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent).map_err(|e| format!("{}: {e}", parent.display()))?;
        }
        let tmp = path.with_extension("tmp");
        let mut f = File::create(&tmp).map_err(|e| format!("{}: {e}", tmp.display()))?;
        f.write_all(bytes).map_err(|e| format!("{}: {e}", tmp.display()))?;
        f.sync_all().map_err(|e| format!("{}: {e}", tmp.display()))?;
        drop(f);
        std::fs::rename(&tmp, &path).map_err(|e| format!("{}: {e}", path.display()))?;
        // The rename itself must survive a host crash, not just the bytes.
        match path.parent() {
            Some(parent) => fsync_dir(parent),
            None => Ok(()),
        }
    }

    fn read(&self, key: &str) -> Result<Option<Vec<u8>>, String> {
        match std::fs::read(self.path_of(key)) {
            Ok(bytes) => Ok(Some(bytes)),
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => Ok(None),
            Err(e) => Err(format!("{key}: {e}")),
        }
    }

    fn list(&self, prefix: &str) -> Result<Vec<String>, String> {
        let mut keys = Vec::new();
        let root = self.root.clone();
        self.walk(&root, &mut keys)?;
        keys.retain(|k| k.starts_with(prefix));
        keys.sort();
        Ok(keys)
    }

    fn delete(&self, key: &str) -> Result<(), String> {
        let path = self.path_of(key);
        match std::fs::remove_file(&path) {
            Ok(()) => match path.parent() {
                // Persist the removal: a deleted WAL segment that
                // reappears after power loss would be replayed again
                // (harmless under watermarks, but not what we promised).
                Some(parent) => fsync_dir(parent),
                None => Ok(()),
            },
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => Ok(()),
            Err(e) => Err(format!("{key}: {e}")),
        }
    }

    fn truncate(&self, key: &str, len: u64) -> Result<(), String> {
        let f = OpenOptions::new()
            .write(true)
            .open(self.path_of(key))
            .map_err(|e| format!("{key}: {e}"))?;
        f.set_len(len).map_err(|e| format!("{key}: {e}"))?;
        f.sync_all().map_err(|e| format!("{key}: {e}"))
    }

    fn size(&self, key: &str) -> Result<Option<u64>, String> {
        match std::fs::metadata(self.path_of(key)) {
            Ok(m) => Ok(Some(m.len())),
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => Ok(None),
            Err(e) => Err(format!("{key}: {e}")),
        }
    }

    fn open_append(&self, key: &str) -> Result<Box<dyn AppendHandle>, String> {
        let path = self.path_of(key);
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent).map_err(|e| format!("{}: {e}", parent.display()))?;
        }
        let file = OpenOptions::new()
            .create(true)
            .append(true)
            .open(&path)
            .map_err(|e| format!("{}: {e}", path.display()))?;
        // A freshly created segment must be durably *named* before any
        // record in it is acked: without the directory fsync, a host
        // crash can drop the whole file even though its bytes were
        // fdatasync'd.
        if let Some(parent) = path.parent() {
            fsync_dir(parent)?;
        }
        Ok(Box::new(LocalAppend {
            file,
            key: key.to_string(),
        }))
    }
}

struct LocalAppend {
    file: File,
    key: String,
}

impl AppendHandle for LocalAppend {
    fn append(&mut self, bytes: &[u8]) -> Result<(), String> {
        self.file
            .write_all(bytes)
            .map_err(|e| format!("{}: {e}", self.key))
    }

    fn flush(&mut self) -> Result<(), String> {
        // `File` writes are unbuffered; flush is a no-op kept for trait
        // symmetry with buffered backends.
        Ok(())
    }

    fn syncer(&self) -> Result<Arc<dyn SyncHandle>, String> {
        let clone = self
            .file
            .try_clone()
            .map_err(|e| format!("{}: {e}", self.key))?;
        Ok(Arc::new(LocalSync {
            file: clone,
            key: self.key.clone(),
        }))
    }
}

struct LocalSync {
    file: File,
    key: String,
}

impl SyncHandle for LocalSync {
    fn sync(&self) -> Result<(), String> {
        self.file
            .sync_data()
            .map_err(|e| format!("{}: {e}", self.key))
    }
}

// ---------------------------------------------------------------------------
// In-memory backend (tests)
// ---------------------------------------------------------------------------

type MemMap = Arc<Mutex<BTreeMap<String, Vec<u8>>>>;

/// In-memory [`StorageBackend`] for unit tests. Always "durable": there is
/// no crash boundary, so `sync` is a no-op and `put_atomic` is a plain map
/// insert.
#[derive(Default)]
pub struct MemStorage {
    objects: MemMap,
}

impl MemStorage {
    pub fn new() -> Self {
        Self::default()
    }
}

impl StorageBackend for MemStorage {
    fn kind(&self) -> &'static str {
        "mem"
    }

    fn put_atomic(&self, key: &str, bytes: &[u8]) -> Result<(), String> {
        self.objects
            .lock()
            .unwrap()
            .insert(key.to_string(), bytes.to_vec());
        Ok(())
    }

    fn read(&self, key: &str) -> Result<Option<Vec<u8>>, String> {
        Ok(self.objects.lock().unwrap().get(key).cloned())
    }

    fn list(&self, prefix: &str) -> Result<Vec<String>, String> {
        Ok(self
            .objects
            .lock()
            .unwrap()
            .keys()
            .filter(|k| k.starts_with(prefix))
            .cloned()
            .collect())
    }

    fn delete(&self, key: &str) -> Result<(), String> {
        self.objects.lock().unwrap().remove(key);
        Ok(())
    }

    fn truncate(&self, key: &str, len: u64) -> Result<(), String> {
        let mut map = self.objects.lock().unwrap();
        let obj = map.get_mut(key).ok_or_else(|| format!("{key}: missing"))?;
        obj.truncate(len as usize);
        Ok(())
    }

    fn size(&self, key: &str) -> Result<Option<u64>, String> {
        Ok(self.objects.lock().unwrap().get(key).map(|v| v.len() as u64))
    }

    fn open_append(&self, key: &str) -> Result<Box<dyn AppendHandle>, String> {
        self.objects
            .lock()
            .unwrap()
            .entry(key.to_string())
            .or_default();
        Ok(Box::new(MemAppend {
            objects: Arc::clone(&self.objects),
            key: key.to_string(),
        }))
    }
}

struct MemAppend {
    objects: MemMap,
    key: String,
}

impl AppendHandle for MemAppend {
    fn append(&mut self, bytes: &[u8]) -> Result<(), String> {
        let mut map = self.objects.lock().unwrap();
        map.entry(self.key.clone())
            .or_default()
            .extend_from_slice(bytes);
        Ok(())
    }

    fn flush(&mut self) -> Result<(), String> {
        Ok(())
    }

    fn syncer(&self) -> Result<Arc<dyn SyncHandle>, String> {
        Ok(Arc::new(MemSync))
    }
}

struct MemSync;

impl SyncHandle for MemSync {
    fn sync(&self) -> Result<(), String> {
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn temp_root(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!(
            "sage-storage-{tag}-{}-{:?}",
            std::process::id(),
            std::thread::current().id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        dir
    }

    fn exercise(storage: &dyn StorageBackend) {
        // put_atomic / read / size
        storage.put_atomic("a/one.bin", b"hello").unwrap();
        storage.put_atomic("a/one.bin", b"hello2").unwrap();
        assert_eq!(storage.read("a/one.bin").unwrap().unwrap(), b"hello2");
        assert_eq!(storage.size("a/one.bin").unwrap(), Some(6));
        assert_eq!(storage.read("a/absent").unwrap(), None);
        assert_eq!(storage.size("a/absent").unwrap(), None);

        // append streams survive handle reopen and interleave with reads
        let mut h = storage.open_append("a/log.bin").unwrap();
        h.append(b"abc").unwrap();
        h.append(b"def").unwrap();
        h.flush().unwrap();
        h.syncer().unwrap().sync().unwrap();
        drop(h);
        assert_eq!(storage.read("a/log.bin").unwrap().unwrap(), b"abcdef");
        let mut h = storage.open_append("a/log.bin").unwrap();
        h.append(b"ghi").unwrap();
        h.flush().unwrap();
        drop(h);
        assert_eq!(storage.read("a/log.bin").unwrap().unwrap(), b"abcdefghi");

        // truncate repairs a torn tail
        storage.truncate("a/log.bin", 4).unwrap();
        assert_eq!(storage.read("a/log.bin").unwrap().unwrap(), b"abcd");

        // list is prefix-filtered and sorted
        storage.put_atomic("b/two.bin", b"x").unwrap();
        let all = storage.list("").unwrap();
        assert_eq!(all, vec!["a/log.bin", "a/one.bin", "b/two.bin"]);
        assert_eq!(storage.list("a/").unwrap(), vec!["a/log.bin", "a/one.bin"]);

        // delete is idempotent
        storage.delete("b/two.bin").unwrap();
        storage.delete("b/two.bin").unwrap();
        assert_eq!(storage.read("b/two.bin").unwrap(), None);
    }

    #[test]
    fn local_dir_backend_contract() {
        let root = temp_root("local");
        let storage = LocalDirBackend::create(&root).unwrap();
        exercise(&storage);
        // No stray temp files once atomic puts complete.
        let leftovers = storage.list("").unwrap();
        assert!(
            leftovers.iter().all(|k| !k.ends_with(".tmp")),
            "temp files leaked: {leftovers:?}"
        );
        let _ = std::fs::remove_dir_all(&root);
    }

    #[test]
    fn mem_backend_contract() {
        exercise(&MemStorage::new());
    }

    #[test]
    fn local_put_atomic_leaves_old_object_on_missing_rename() {
        // Simulate the crash window: a partial temp file next to a good
        // object must never shadow it, and the next put cleans it up.
        let root = temp_root("atomic");
        let storage = LocalDirBackend::create(&root).unwrap();
        storage.put_atomic("ck/state.bin", b"good").unwrap();
        std::fs::write(root.join("ck/state.tmp"), b"par").unwrap();
        assert_eq!(storage.read("ck/state.bin").unwrap().unwrap(), b"good");
        storage.put_atomic("ck/state.bin", b"better").unwrap();
        assert_eq!(storage.read("ck/state.bin").unwrap().unwrap(), b"better");
        let _ = std::fs::remove_dir_all(&root);
    }
}
