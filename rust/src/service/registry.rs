//! Concurrent session registry — the server-side state of `sage-serve`.
//!
//! A [`Session`] promotes the pipeline's shard-local FD sketches from local
//! variables to a served, sessioned resource: `shards` independent sketch
//! slots fed through ONE bounded ingest channel (backpressure: producers
//! block when the queue is full; the per-session ingest worker drains it),
//! then frozen by merging the shard sketches **in shard order** — exactly
//! the merge `pipeline::run_selection` performs, so a session fed the same
//! gradient stream produces a byte-identical sketch. Phase-II scoring
//! accumulates per-shard [`AgreementScorer`]s the same way, making served
//! TopK queries reproduce offline selection exactly.
//!
//! # Sharded registry
//!
//! The [`SessionRegistry`] is an array of `2^k` independent shards, each a
//! `RwLock<BTreeMap>` of sessions, keyed by the FNV-64 hash of the session
//! name. Requests for different sessions contend only when their names hash
//! to the same registry shard, so throughput scales with connection threads
//! instead of serializing on one global mutex. Invariants:
//!
//! * **No cross-shard lock is ever held.** Stats and spill candidate scans
//!   visit shards one at a time; fleet-wide accounting reads per-shard
//!   atomics and the reservation budgets, never a second lock.
//! * **Admission is exact and lock-free.** Session slots, resident sketch
//!   bytes, and resident Phase-II scorer bytes are reserved against
//!   [`ByteBudget`]s whose `reserve` checks the cap and commits in a single
//!   CAS — concurrent admissions can never jointly exceed a budget.
//! * A session's reservations are released when its last `Arc` drops
//!   (in-flight requests included), so budget can never be reclaimed while
//!   a request still touches the session.
//!
//! # Scorer-state admission and spill
//!
//! Phase-II scorer state is `O(Nℓ)` per session — the one structure that
//! would otherwise break SAGE's constant-memory story in a long-lived
//! server. It is admission-controlled like sketch bytes:
//!
//! * `CreateSession` reserves the per-session baseline (`shards × 8ℓ`
//!   consensus accumulators) and rejects when the scorer budget is full.
//! * Each `Score` batch reserves `rows × (ENTRY_BYTES + 4ℓ)` **before**
//!   applying; over-budget batches are rejected with a
//!   `scorer admission rejected` error frame.
//! * On rejection, if a checkpoint dir is configured, the registry spills
//!   the least-recently-active other session's Phase-II state to its
//!   `.sagesess` file, drops it from memory, and retries (see
//!   [`SessionRegistry::score`]). Spilled state reloads transparently on
//!   that session's next `Score`/`TopK` (re-reserving budget, which may in
//!   turn spill someone else). Without a checkpoint dir the rejection is
//!   final and the client must finalize, close, or raise the budget.
//! * Finalizing scores (first `TopK`) converts raw scorer state into the
//!   score cache, which is never larger, so finalize always *shrinks* the
//!   accounted footprint.
//!
//! Determinism contract: one producer per shard slot. Concurrent producers
//! on the *same* shard are accepted but interleave nondeterministically.

use super::checkpoint::SessionCheckpoint;
use super::protocol::{
    encode_ingest_batch, encode_merge_sketch, encode_score, fnv64, op, FrozenSketch, Request,
    ScoreBatch,
};
use super::storage::{LocalDirBackend, StorageBackend};
use super::wal::{Durability, Wal, WalConfig, WalFaultPlan, WalRecord};
use crate::baselines::{select_weighted, SelectionInputs};
use crate::config::Method;
use crate::selection::{
    scorer_state_bytes, AgreementScorer, ScoreEntry, ScorerState, Scores, ScoresState,
    ENTRY_BYTES,
};
use crate::sketch::{FdSketch, SketchState};
use crate::tensor::{ComputeBackend, Matrix};
use crate::util::channel::{bounded, Sender};
use crate::util::metrics::{global as metrics, Counter};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, OnceLock, RwLock};
use std::thread::JoinHandle;

/// Registry knobs (admission control + backpressure depth + sharding).
#[derive(Clone, Debug)]
pub struct RegistryConfig {
    /// Maximum concurrently resident sessions.
    pub max_sessions: usize,
    /// Maximum total resident sketch-buffer bytes across sessions
    /// (each session accounts `shards × 2ℓ × D × 4`).
    pub max_resident_bytes: usize,
    /// Maximum total resident Phase-II scorer bytes across sessions
    /// (per-entry cost `ENTRY_BYTES + 4ℓ`; see `selection::scorer`).
    pub max_scorer_bytes: usize,
    /// Bounded ingest queue depth per session (backpressure).
    pub ingest_queue_depth: usize,
    /// Registry shard count; rounded up to a power of two in
    /// `[1, MAX_REGISTRY_SHARDS]`.
    pub registry_shards: usize,
    /// Where `Checkpoint` ops persist sessions and where score caches are
    /// spilled under scorer-budget pressure (None = both disabled).
    pub checkpoint_dir: Option<PathBuf>,
    /// Write-ahead-log durability for mutating ops (`--durability`).
    /// Anything but `None` requires `checkpoint_dir` (the WAL lives under
    /// it) and is enabled by calling [`SessionRegistry::open_wal`] after
    /// [`SessionRegistry::recover`].
    pub durability: Durability,
    /// Per-WAL-shard live-segment bytes that trigger compaction
    /// (`--wal-compact-mb`; 0 = never compact).
    pub wal_compact_bytes: u64,
    /// Crash-injection plan for the durability test harness.
    pub wal_fault: WalFaultPlan,
}

impl Default for RegistryConfig {
    fn default() -> Self {
        Self {
            max_sessions: 64,
            max_resident_bytes: 1 << 30,
            max_scorer_bytes: 1 << 30,
            ingest_queue_depth: 8,
            registry_shards: 8,
            checkpoint_dir: None,
            durability: Durability::None,
            wal_compact_bytes: 64 << 20,
            wal_fault: WalFaultPlan {
                abort_at: None,
                torn_at: None,
            },
        }
    }
}

/// Upper bound on registry shards (gauge names are interned per shard).
pub const MAX_REGISTRY_SHARDS: usize = 256;

fn normalize_shard_count(n: usize) -> usize {
    n.clamp(1, MAX_REGISTRY_SHARDS)
        .next_power_of_two()
        .min(MAX_REGISTRY_SHARDS)
}

/// Exact lock-free cap accounting. `reserve` checks the cap and commits in
/// one CAS, so concurrent admissions can never jointly exceed the budget;
/// `release` saturates at zero.
pub struct ByteBudget {
    cap: usize,
    used: AtomicUsize,
}

impl ByteBudget {
    pub fn new(cap: usize) -> Self {
        Self {
            cap,
            used: AtomicUsize::new(0),
        }
    }

    pub fn cap(&self) -> usize {
        self.cap
    }

    pub fn used(&self) -> usize {
        self.used.load(Ordering::Relaxed)
    }

    /// Atomically reserve `n` units; false (nothing committed) if the cap
    /// would be exceeded.
    #[must_use]
    pub fn reserve(&self, n: usize) -> bool {
        self.used
            .fetch_update(Ordering::Relaxed, Ordering::Relaxed, |u| {
                u.checked_add(n).filter(|&t| t <= self.cap)
            })
            .is_ok()
    }

    pub fn release(&self, n: usize) {
        let _ = self
            .used
            .fetch_update(Ordering::Relaxed, Ordering::Relaxed, |u| {
                Some(u.saturating_sub(n))
            });
    }

    /// Swap an `old` reservation for a `new` one without a cap check —
    /// used only where the new footprint replaces the old (finalize), which
    /// by construction never grows.
    fn rebalance(&self, old: usize, new: usize) {
        if new >= old {
            self.used.fetch_add(new - old, Ordering::Relaxed);
        } else {
            self.release(old - new);
        }
    }
}

/// The three admission budgets, shared by the registry and every session
/// (sessions release through their `Drop`).
#[derive(Clone)]
struct Budgets {
    /// Unit: sessions.
    slots: Arc<ByteBudget>,
    /// Unit: resident sketch-buffer bytes.
    sketch: Arc<ByteBudget>,
    /// Unit: resident Phase-II scorer bytes.
    scorer: Arc<ByteBudget>,
}

/// Per-session counters, reported by the `Stats` wire op (prefixed
/// `service.session.<name>.` in the response). Fleet-wide aggregates go to
/// the global metrics registry under fixed `service.*` names instead —
/// global counter names are interned forever, so they never embed
/// client-chosen session names.
#[derive(Default)]
pub struct SessionStats {
    pub rows_enqueued: AtomicU64,
    pub rows_applied: AtomicU64,
    pub batches: AtomicU64,
    pub merges: AtomicU64,
    pub scored_entries: AtomicU64,
    pub topk_queries: AtomicU64,
}

type IngestMsg = (usize, Matrix);

/// Hard caps on session shape. The protocol carries `ell`/`d`/`shards` as
/// u32, so admission math must be overflow-proof against hostile values;
/// under these caps `shards × 2ℓ × D × 4` stays well below `usize::MAX`.
pub const MAX_ELL: usize = 1 << 16;
pub const MAX_DIM: usize = 1 << 28;
pub const MAX_SHARDS: usize = 4096;

/// Error-message prefix of a scorer-budget rejection — the marker the
/// registry's spill-on-pressure retry loop matches on, and the retryable
/// signal documented in docs/ARCHITECTURE.md.
pub const SCORER_ADMISSION: &str = "scorer admission rejected";

/// Validated resident-byte cost of a session (`shards × 2ℓ × D × 4`).
fn session_bytes(ell: usize, d: usize, shards: usize) -> Result<usize, String> {
    if ell == 0 || d == 0 || shards == 0 {
        return Err("ell, d and shards must all be positive".into());
    }
    if ell > MAX_ELL || d > MAX_DIM || shards > MAX_SHARDS {
        return Err(format!(
            "session shape rejected: ell {ell} (max {MAX_ELL}), d {d} (max {MAX_DIM}), \
             shards {shards} (max {MAX_SHARDS})"
        ));
    }
    shards
        .checked_mul(2)
        .and_then(|v| v.checked_mul(ell))
        .and_then(|v| v.checked_mul(d))
        .and_then(|v| v.checked_mul(4))
        .ok_or_else(|| "session byte accounting overflow".to_string())
}

/// Scorer-budget baseline a session reserves at creation: one empty
/// [`AgreementScorer`] (`8ℓ` accumulator bytes) per shard slot.
fn baseline_scorer_bytes(ell: usize, shards: usize) -> usize {
    shards.saturating_mul(scorer_state_bytes(0, ell))
}

/// All Phase-II state of a session, guarded by ONE mutex so scoring,
/// finalizing, spilling, and checkpointing can never deadlock on partial
/// lock orders. Lock order within a session: `phase2` before `frozen`
/// before `sketches` (and never the reverse).
struct Phase2 {
    /// Per-shard scorer slots; all `Some` until finalize takes them.
    scorers: Vec<Option<AgreementScorer>>,
    /// Finalized score cache (first TopK fills it).
    scores: Option<Scores>,
    /// Where spilled Phase-II state lives on disk; `scorers`/`scores` are
    /// empty while `Some`.
    spilled: Option<PathBuf>,
}

/// Accounted resident bytes of a session's Phase-II state.
fn phase2_bytes(p: &Phase2) -> usize {
    let mut total: usize = p.scorers.iter().flatten().map(|s| s.state_bytes()).sum();
    if let Some(scores) = &p.scores {
        total = total.saturating_add(scores.state_bytes());
    }
    total
}

fn fresh_scorers(ell: usize, shards: usize) -> Vec<Option<AgreementScorer>> {
    (0..shards).map(|_| Some(AgreementScorer::new(ell))).collect()
}

/// Rebuild Phase-II state from a checkpoint. Legacy (v1) checkpoints carry
/// no Phase-II section; scoring then starts fresh.
fn restore_phase2(
    ck: &SessionCheckpoint,
    ell: usize,
    shards: usize,
) -> Result<(Vec<Option<AgreementScorer>>, Option<Scores>), String> {
    let scorers = if ck.scorers.is_empty() {
        fresh_scorers(ell, shards)
    } else {
        if ck.scorers.len() != shards {
            return Err(format!(
                "checkpoint '{}': {} scorer slots for {} shards",
                ck.name,
                ck.scorers.len(),
                shards
            ));
        }
        let mut slots = Vec::with_capacity(shards);
        for slot in &ck.scorers {
            slots.push(match slot {
                Some(st) => {
                    if st.ell as usize != ell {
                        return Err(format!("checkpoint '{}': scorer ell drift", ck.name));
                    }
                    Some(AgreementScorer::from_state(st)?)
                }
                None => None,
            });
        }
        slots
    };
    let scores = match &ck.scores {
        Some(st) => {
            if st.ell as usize != ell {
                return Err(format!("checkpoint '{}': scores ell drift", ck.name));
            }
            Some(Scores::from_state(st)?)
        }
        None => None,
    };
    Ok((scorers, scores))
}

/// Accounted Phase-II bytes a checkpoint will occupy once restored — must
/// agree exactly with `phase2_bytes(restore_phase2(ck))`.
fn checkpoint_scorer_bytes(ck: &SessionCheckpoint, ell: usize, shards: usize) -> usize {
    let mut total = if ck.scorers.is_empty() {
        baseline_scorer_bytes(ell, shards)
    } else {
        ck.scorers
            .iter()
            .flatten()
            .map(|st| scorer_state_bytes(st.indices.len(), ell))
            .sum()
    };
    if let Some(sc) = &ck.scores {
        total =
            total.saturating_add(crate::selection::scores_state_bytes(sc.alphas.len(), ell));
    }
    total
}

fn scorer_admission_error(name: &str, need: usize, budget: &ByteBudget) -> String {
    format!(
        "{SCORER_ADMISSION}: session '{name}' needs {need} more scorer bytes \
         ({}/{} in use; raise --max-scorer-mb, close sessions, or configure \
         --checkpoint-dir so idle score caches can spill)",
        budget.used(),
        budget.cap()
    )
}

/// Observer of session lifecycle events the push-subscription layer cares
/// about. Installed once via [`SessionRegistry::set_watcher`]; callbacks
/// run on the mutating request's thread *after* the mutation committed and
/// outside all registry locks, so implementations may take their own locks
/// but must stay cheap (the subscription hub just flips a dirty bit and
/// signals its notifier thread).
pub trait RegistryWatcher: Send + Sync {
    /// A committed mutation (Freeze / Score / finalizing TopK) may have
    /// changed `session`'s selection.
    fn selection_dirty(&self, session: &str);
    /// The session was closed; subscriptions on it are now dangling.
    fn session_closed(&self, session: &str);
}

/// One served sketch session.
pub struct Session {
    name: String,
    ell: usize,
    d: usize,
    shards: usize,
    ingest_tx: Mutex<Option<Sender<IngestMsg>>>,
    worker: Mutex<Option<JoinHandle<()>>>,
    sketches: Arc<Mutex<Vec<FdSketch>>>,
    frozen: Mutex<Option<FrozenSketch>>,
    phase2: Mutex<Phase2>,
    stats: Arc<SessionStats>,
    /// Shared admission budgets; this session's reservations are released
    /// in `Drop` (slot, sketch bytes, resident Phase-II bytes).
    budgets: Budgets,
    /// Sketch bytes reserved for this session at admission.
    sketch_reserved: usize,
    /// Registry activity clock value at last use (spill LRU order).
    last_active: AtomicU64,
    /// Whether a Checkpoint op explicitly persisted this session. Spill
    /// files are transient (deleted on reload and on close) UNLESS the
    /// client explicitly checkpointed — then the `.sagesess` file is the
    /// client's durable state and is left alone.
    explicitly_checkpointed: std::sync::atomic::AtomicBool,
    /// WAL replay watermark: highest log sequence number whose effect is
    /// in this session's state (0 without a WAL). Embedded in checkpoints
    /// so recovery replays only the records a snapshot doesn't cover.
    wal_seq: AtomicU64,
    /// Serializes (apply + WAL append) against checkpoint/spill snapshots,
    /// so a snapshot's state always matches its embedded watermark
    /// exactly. Never held while spilling *other* sessions (registry
    /// retry loops drop it between attempts), so gates never nest.
    wal_gate: Mutex<()>,
    /// Set by `top_k` when a call actually finalized scores — the one
    /// TopK that mutates state and therefore must be logged.
    just_finalized: AtomicBool,
    /// Registry runs with a WAL (`durability != none`): `.sagesess` files
    /// under the checkpoint dir are then recovery state managed by the
    /// registry — never deleted on unspill (a compaction checkpoint may be
    /// the only copy of compacted records), always deleted on close (a
    /// closed session must not resurrect after its Close record is
    /// compacted away).
    durable: bool,
    /// Fleet-wide aggregates (fixed names — global counters are interned
    /// forever, so they must NOT embed client-chosen session names).
    c_rows: &'static Counter,
    c_batches: &'static Counter,
    c_scored: &'static Counter,
    /// Kernel backend for finalize (consensus matvec) and the selection
    /// rules — the registry's configured backend; bit-identical to serial,
    /// so served TopK matches offline selection for ANY worker count.
    compute: Arc<dyn ComputeBackend>,
}

impl Session {
    /// New active session with per-shard sketches and a running ingest
    /// worker fed by a bounded channel. The caller must already hold
    /// budget reservations of `sketch_reserved` sketch bytes, one session
    /// slot, and `phase2_bytes` of the initial Phase-II state.
    #[allow(clippy::too_many_arguments)]
    fn new_active(
        name: &str,
        ell: usize,
        d: usize,
        shards: usize,
        queue_depth: usize,
        shard_sketches: Vec<FdSketch>,
        budgets: Budgets,
        sketch_reserved: usize,
        compute: Arc<dyn ComputeBackend>,
        durable: bool,
    ) -> Session {
        debug_assert_eq!(shard_sketches.len(), shards);
        let stats = Arc::new(SessionStats::default());
        let sketches = Arc::new(Mutex::new(shard_sketches));
        let (tx, rx) = bounded::<IngestMsg>(queue_depth.max(1));
        let w_sketches = sketches.clone();
        let w_stats = stats.clone();
        let c_rows_applied = metrics().counter("service.ingest.rows_applied");
        let worker = std::thread::spawn(move || {
            // close-then-drain: after Freeze closes the channel, recv keeps
            // returning queued batches until empty, so no acked ingest is
            // ever lost (see util::channel close semantics).
            while let Some((shard, rows)) = rx.recv() {
                let n = rows.rows() as u64;
                w_sketches.lock().unwrap()[shard].insert_batch(&rows);
                w_stats.rows_applied.fetch_add(n, Ordering::Relaxed);
                c_rows_applied.add(n);
            }
        });
        Session {
            name: name.to_string(),
            ell,
            d,
            shards,
            ingest_tx: Mutex::new(Some(tx)),
            worker: Mutex::new(Some(worker)),
            sketches,
            frozen: Mutex::new(None),
            phase2: Mutex::new(Phase2 {
                scorers: fresh_scorers(ell, shards),
                scores: None,
                spilled: None,
            }),
            stats,
            budgets,
            sketch_reserved,
            last_active: AtomicU64::new(0),
            explicitly_checkpointed: std::sync::atomic::AtomicBool::new(false),
            wal_seq: AtomicU64::new(0),
            wal_gate: Mutex::new(()),
            just_finalized: AtomicBool::new(false),
            durable,
            c_rows: metrics().counter("service.ingest.rows_enqueued"),
            c_batches: metrics().counter("service.ingest.batches"),
            c_scored: metrics().counter("service.score.entries"),
            compute,
        }
    }

    /// Rebuild an already-frozen session (checkpoint recovery): no ingest
    /// worker; Phase-II state starts fresh and is overwritten by
    /// `from_checkpoint` when the checkpoint carries scorer state.
    #[allow(clippy::too_many_arguments)]
    fn new_frozen(
        name: &str,
        ell: usize,
        d: usize,
        shards: usize,
        info: FrozenSketch,
        budgets: Budgets,
        sketch_reserved: usize,
        compute: Arc<dyn ComputeBackend>,
        durable: bool,
    ) -> Session {
        Session {
            name: name.to_string(),
            ell,
            d,
            shards,
            ingest_tx: Mutex::new(None),
            worker: Mutex::new(None),
            sketches: Arc::new(Mutex::new(Vec::new())),
            frozen: Mutex::new(Some(info)),
            phase2: Mutex::new(Phase2 {
                scorers: fresh_scorers(ell, shards),
                scores: None,
                spilled: None,
            }),
            stats: Arc::new(SessionStats::default()),
            budgets,
            sketch_reserved,
            last_active: AtomicU64::new(0),
            explicitly_checkpointed: std::sync::atomic::AtomicBool::new(false),
            wal_seq: AtomicU64::new(0),
            wal_gate: Mutex::new(()),
            just_finalized: AtomicBool::new(false),
            durable,
            c_rows: metrics().counter("service.ingest.rows_enqueued"),
            c_batches: metrics().counter("service.ingest.batches"),
            c_scored: metrics().counter("service.score.entries"),
            compute,
        }
    }

    pub fn name(&self) -> &str {
        &self.name
    }

    pub fn ell(&self) -> usize {
        self.ell
    }

    pub fn dim(&self) -> usize {
        self.d
    }

    pub fn shards(&self) -> usize {
        self.shards
    }

    /// Resident sketch-buffer bytes this session accounts for (shapes are
    /// validated at admission, so this cannot overflow; saturate anyway).
    pub fn resident_bytes(&self) -> usize {
        self.shards
            .saturating_mul(2)
            .saturating_mul(self.ell)
            .saturating_mul(self.d)
            .saturating_mul(4)
    }

    /// Accounted resident Phase-II scorer bytes (0 while spilled).
    pub fn scorer_bytes(&self) -> usize {
        phase2_bytes(&self.phase2.lock().unwrap())
    }

    /// Whether this session's Phase-II state currently lives on disk.
    pub fn is_spilled(&self) -> bool {
        self.phase2.lock().unwrap().spilled.is_some()
    }

    pub fn is_frozen(&self) -> bool {
        self.frozen.lock().unwrap().is_some()
    }

    fn touch(&self, tick: u64) {
        self.last_active.store(tick, Ordering::Relaxed);
    }

    fn last_active(&self) -> u64 {
        self.last_active.load(Ordering::Relaxed)
    }

    /// Whether spilling this session would free actual scored state (not
    /// just the empty-scorer baseline).
    fn has_spillable_scores(&self) -> bool {
        let p = self.phase2.lock().unwrap();
        p.spilled.is_none()
            && (p.scores.is_some() || p.scorers.iter().flatten().any(|s| s.count() > 0))
    }

    /// Enqueue raw gradient rows into one shard slot. Blocks when the
    /// bounded ingest queue is full (backpressure propagates to the TCP
    /// connection). Returns total rows acked so far.
    ///
    /// # Errors
    /// Shard index out of range, row dimension mismatch, or a frozen
    /// session.
    pub fn ingest(&self, shard: usize, rows: Matrix) -> Result<u64, String> {
        if shard >= self.shards {
            return Err(format!(
                "shard {shard} out of range (session '{}' has {} shards)",
                self.name, self.shards
            ));
        }
        if rows.cols() != self.d {
            return Err(format!(
                "ingest rows have {} cols, session dim is {}",
                rows.cols(),
                self.d
            ));
        }
        let tx = match self.ingest_tx.lock().unwrap().as_ref() {
            Some(tx) => tx.clone(),
            None => return Err(format!("session '{}' is frozen", self.name)),
        };
        let n = rows.rows() as u64;
        tx.send((shard, rows))
            .map_err(|_| format!("session '{}' was frozen during ingest", self.name))?;
        // Post-send depth: how far behind the drain worker is running. A
        // gauge (last-writer-wins) plus a histogram so /metrics exposes
        // both the instantaneous and the distributional view.
        let depth = tx.len() as u64;
        metrics().gauge("service.ingest.queue_depth").set(depth);
        metrics().histogram("service.ingest.queue_depth.dist").record(depth);
        self.c_rows.add(n);
        self.c_batches.inc();
        self.stats.batches.fetch_add(1, Ordering::Relaxed);
        Ok(self.stats.rows_enqueued.fetch_add(n, Ordering::Relaxed) + n)
    }

    /// Merge a client-side FD sketch into one shard slot (FD mergeability:
    /// the combined guarantee degrades by at most the sum of both
    /// certificates). Deterministic for a fixed call sequence.
    ///
    /// # Errors
    /// Shard index out of range, dimension mismatch, invalid sketch state,
    /// or a frozen session.
    pub fn merge_sketch(&self, shard: usize, state: &SketchState) -> Result<(), String> {
        if shard >= self.shards {
            return Err(format!("shard {shard} out of range"));
        }
        if state.d as usize != self.d {
            return Err(format!(
                "sketch state dim {} != session dim {}",
                state.d, self.d
            ));
        }
        let mut other = FdSketch::from_state_with(state, self.compute.clone())?;
        let mut guard = self.sketches.lock().unwrap();
        if guard.is_empty() {
            return Err(format!("session '{}' is frozen", self.name));
        }
        guard[shard].merge(&mut other);
        drop(guard);
        self.stats.merges.fetch_add(1, Ordering::Relaxed);
        metrics().counter("service.merge.requests").inc();
        Ok(())
    }

    /// Freeze: stop ingest, drain the queue (close-then-drain), join the
    /// worker, merge shard sketches in shard order, cache the frozen S.
    /// Idempotent — every scoring client calls it to fetch S.
    ///
    /// # Errors
    /// A panicked ingest worker, or a session with no sketch state.
    pub fn freeze(&self) -> Result<FrozenSketch, String> {
        let mut guard = self.frozen.lock().unwrap();
        if let Some(info) = guard.as_ref() {
            return Ok(info.clone());
        }
        if let Some(tx) = self.ingest_tx.lock().unwrap().take() {
            tx.close();
        }
        if let Some(worker) = self.worker.lock().unwrap().take() {
            worker
                .join()
                .map_err(|_| format!("session '{}': ingest worker panicked", self.name))?;
        }
        let mut shard_sketches = {
            let mut g = self.sketches.lock().unwrap();
            std::mem::take(&mut *g)
        };
        if shard_sketches.is_empty() {
            return Err(format!("session '{}' has no sketch state", self.name));
        }
        // Same merge the offline pipeline performs: base = shard 0 (NOT an
        // empty sketch — that would pre-shrink shard 0 and change the
        // result), then fold the rest in shard order.
        let mut merged = shard_sketches.remove(0);
        for mut s in shard_sketches {
            merged.merge(&mut s);
        }
        let sketch = merged.sketch();
        let info = FrozenSketch {
            sketch,
            shift_bound: merged.shift_bound(),
            shrinks: merged.shrink_count(),
            rows_seen: merged.rows_seen(),
            sketch_bytes: merged.memory_bytes() as u64,
        };
        *guard = Some(info.clone());
        Ok(info)
    }

    /// Accumulate one Phase-II scoring batch into a shard's scorer. The
    /// batch's byte cost is reserved against the scorer budget **before**
    /// it is applied; rejected batches leave no partial state.
    ///
    /// # Errors
    /// Shard range / shape mismatches, an unfrozen session, already
    /// finalized scores, or a [`SCORER_ADMISSION`]-prefixed budget
    /// rejection (retryable through [`SessionRegistry::score`], which
    /// spills idle sessions).
    pub fn score(&self, shard: usize, batch: &ScoreBatch) -> Result<(), String> {
        if shard >= self.shards {
            return Err(format!("shard {shard} out of range"));
        }
        if self.frozen.lock().unwrap().is_none() {
            return Err(format!(
                "session '{}': Score requires Freeze first",
                self.name
            ));
        }
        let n = batch.indices.len();
        if batch.labels.len() != n
            || batch.norms.len() != n
            || batch.losses.len() != n
            || batch.zhat.rows() != n
        {
            return Err("score batch: field lengths disagree".into());
        }
        if batch.zhat.cols() != self.ell {
            return Err(format!(
                "score batch: projections have dim {}, session ℓ is {}",
                batch.zhat.cols(),
                self.ell
            ));
        }
        let indices: Vec<usize> = batch.indices.iter().map(|&i| i as usize).collect();
        let delta = n.saturating_mul(ENTRY_BYTES + 4 * self.ell);
        let mut p = self.phase2.lock().unwrap();
        if p.spilled.is_some() {
            self.unspill(&mut p)?;
        }
        if p.scorers.len() != self.shards {
            return Err(format!(
                "session '{}': scorer state unavailable",
                self.name
            ));
        }
        match p.scorers[shard].as_mut() {
            Some(scorer) => {
                if !self.budgets.scorer.reserve(delta) {
                    metrics().counter("service.admission.rejected.scorer").inc();
                    return Err(scorer_admission_error(
                        &self.name,
                        delta,
                        &self.budgets.scorer,
                    ));
                }
                scorer.add_batch(&indices, &batch.labels, &batch.zhat, &batch.norms, &batch.losses);
            }
            None => {
                return Err(format!(
                    "session '{}': scores already finalized",
                    self.name
                ))
            }
        }
        drop(p);
        self.stats
            .scored_entries
            .fetch_add(n as u64, Ordering::Relaxed);
        self.c_scored.add(n as u64);
        Ok(())
    }

    /// Online selection query: finalize scores on first call (merging
    /// shard scorers in shard order — the offline merge), then run the
    /// selection rule. Repeated queries with different `(method, k)` reuse
    /// the cached scores. Finalizing releases the raw-scorer budget excess
    /// (the cache is never larger).
    ///
    /// # Errors
    /// An unfrozen session, GLISTER (needs a validation split the service
    /// does not hold), no scored examples, or a [`SCORER_ADMISSION`]
    /// rejection while reloading spilled state (retryable through
    /// [`SessionRegistry::top_k`]).
    pub fn top_k(
        &self,
        method: Method,
        k: usize,
        num_classes: usize,
        seed: u64,
    ) -> Result<(Vec<usize>, Option<Vec<f32>>), String> {
        if self.frozen.lock().unwrap().is_none() {
            return Err(format!(
                "session '{}': TopK requires Freeze first",
                self.name
            ));
        }
        if method == Method::Glister {
            return Err("GLISTER needs a validation split; unsupported by the service".into());
        }
        let mut p = self.phase2.lock().unwrap();
        if p.spilled.is_some() {
            self.unspill(&mut p)?;
        }
        if p.scores.is_none() {
            let total: u64 = p.scorers.iter().flatten().map(|sc| sc.count()).sum();
            if total == 0 {
                return Err(format!(
                    "session '{}': no scored examples — run Score first",
                    self.name
                ));
            }
            let before = phase2_bytes(&p);
            let slots = std::mem::take(&mut p.scorers);
            let mut acc: Option<AgreementScorer> = None;
            let mut missing = false;
            for slot in slots {
                match slot {
                    Some(scorer) => {
                        acc = Some(match acc {
                            None => scorer,
                            Some(mut merged) => {
                                merged.merge(scorer);
                                merged
                            }
                        });
                    }
                    None => missing = true,
                }
            }
            // Slots stay taken after finalize: later Score calls get the
            // "already finalized" error rather than silently diverging.
            p.scorers = (0..self.shards).map(|_| None).collect();
            let acc = match (missing, acc) {
                (false, Some(acc)) => acc,
                _ => {
                    // Inconsistent slot state (only reachable from a
                    // hand-crafted checkpoint): drop what we took and keep
                    // the accounting exact.
                    self.budgets.scorer.release(before);
                    return Err(format!("session '{}': scorer state missing", self.name));
                }
            };
            p.scores = Some(acc.finalize_with(self.compute.as_ref()));
            let after = phase2_bytes(&p);
            self.budgets.scorer.rebalance(before, after);
            // Only the finalizing TopK mutates state; the registry's WAL
            // wrapper reads this flag to decide whether to log the call.
            self.just_finalized.store(true, Ordering::Relaxed);
        }
        let scores = p.scores.as_ref().unwrap();
        let inputs = SelectionInputs {
            scores,
            val_consensus: None,
            num_classes,
            seed,
            compute: self.compute.as_ref(),
        };
        self.stats.topk_queries.fetch_add(1, Ordering::Relaxed);
        Ok(select_weighted(method, &inputs, k))
    }

    /// Non-mutating selection preview for push subscriptions: what would
    /// TopK return *right now*? Exports the Phase-II state bit-exactly
    /// under the lock (scorer/scores round-trips are rank-preserving by
    /// construction — see `AgreementScorer::export_state`), then rebuilds,
    /// merges in shard order, finalizes, and selects entirely outside the
    /// lock, so a large preview never stalls ingest or scoring. The final
    /// preview after the last Score batch is therefore byte-identical to
    /// the finalize-based TopK and to offline `run_selection`.
    ///
    /// Returns `(selected indices, watermark)` where the watermark is the
    /// minimum consensus-agreement α over the selection (NaN when empty).
    /// `None` when no preview exists yet: unfrozen, nothing scored, a
    /// GLISTER subscription, or state currently spilled to disk (a spilled
    /// idle session must not be pulled back just to diff a preview — the
    /// next mutation unspills it anyway and re-marks the subscription
    /// dirty).
    pub fn preview_selection(
        &self,
        method: Method,
        k: usize,
        num_classes: usize,
        seed: u64,
    ) -> Option<(Vec<u64>, f64)> {
        if method == Method::Glister || self.frozen.lock().unwrap().is_none() {
            return None;
        }
        enum Snap {
            Finalized(ScoresState),
            Raw(Vec<ScorerState>),
        }
        let snap = {
            let p = self.phase2.lock().unwrap();
            if p.spilled.is_some() {
                return None;
            }
            if let Some(scores) = &p.scores {
                Snap::Finalized(scores.export_state())
            } else {
                let states: Vec<ScorerState> =
                    p.scorers.iter().flatten().map(|s| s.export_state()).collect();
                if states.iter().map(|s| s.count).sum::<u64>() == 0 {
                    return None;
                }
                Snap::Raw(states)
            }
        };
        let scores = match snap {
            Snap::Finalized(state) => Scores::from_state(&state).ok()?,
            Snap::Raw(states) => {
                // Shard-order merge — the same fold `top_k` performs.
                let mut acc: Option<AgreementScorer> = None;
                for state in &states {
                    let scorer = AgreementScorer::from_state(state).ok()?;
                    acc = Some(match acc {
                        None => scorer,
                        Some(mut merged) => {
                            merged.merge(scorer);
                            merged
                        }
                    });
                }
                acc?.finalize_with(self.compute.as_ref())
            }
        };
        let inputs = SelectionInputs {
            scores: &scores,
            val_consensus: None,
            num_classes,
            seed,
            compute: self.compute.as_ref(),
        };
        let (indices, _) = select_weighted(method, &inputs, k);
        let alpha_of: std::collections::HashMap<usize, f32> = scores
            .entries
            .iter()
            .map(|e: &ScoreEntry| (e.index, e.alpha))
            .collect();
        let mut watermark = f64::INFINITY;
        for i in &indices {
            if let Some(&a) = alpha_of.get(i) {
                watermark = watermark.min(a as f64);
            }
        }
        if !watermark.is_finite() {
            watermark = f64::NAN;
        }
        Some((indices.iter().map(|&i| i as u64).collect(), watermark))
    }

    /// Counter snapshot for the `Stats` wire op.
    pub fn stats_pairs(&self) -> Vec<(String, u64)> {
        let p = format!("service.session.{}", self.name);
        let s = &self.stats;
        let (scorer_bytes, spilled, finalized) = {
            let p2 = self.phase2.lock().unwrap();
            (phase2_bytes(&p2), p2.spilled.is_some(), p2.scores.is_some())
        };
        vec![
            (format!("{p}.ell"), self.ell as u64),
            (format!("{p}.d"), self.d as u64),
            (format!("{p}.shards"), self.shards as u64),
            (format!("{p}.resident_bytes"), self.resident_bytes() as u64),
            (format!("{p}.scorer_bytes"), scorer_bytes as u64),
            (format!("{p}.spilled"), u64::from(spilled)),
            (format!("{p}.scores_finalized"), u64::from(finalized)),
            (format!("{p}.frozen"), u64::from(self.is_frozen())),
            (format!("{p}.wal_seq"), self.wal_seq.load(Ordering::Relaxed)),
            (
                format!("{p}.rows_enqueued"),
                s.rows_enqueued.load(Ordering::Relaxed),
            ),
            (
                format!("{p}.rows_applied"),
                s.rows_applied.load(Ordering::Relaxed),
            ),
            (format!("{p}.batches"), s.batches.load(Ordering::Relaxed)),
            (format!("{p}.merges"), s.merges.load(Ordering::Relaxed)),
            (
                format!("{p}.scored_entries"),
                s.scored_entries.load(Ordering::Relaxed),
            ),
            (
                format!("{p}.topk_queries"),
                s.topk_queries.load(Ordering::Relaxed),
            ),
        ]
    }

    /// Block until every acked ingest batch has been applied to its shard
    /// sketch (bounded wait) — checkpoint consistency helper.
    fn quiesce(&self, timeout: std::time::Duration) -> Result<(), String> {
        let start = std::time::Instant::now();
        loop {
            let enq = self.stats.rows_enqueued.load(Ordering::Relaxed);
            let app = self.stats.rows_applied.load(Ordering::Relaxed);
            if app >= enq {
                return Ok(());
            }
            if start.elapsed() > timeout {
                return Err(format!(
                    "session '{}': quiesce timed out ({app}/{enq} rows applied)",
                    self.name
                ));
            }
            std::thread::sleep(std::time::Duration::from_millis(2));
        }
    }

    /// Build a checkpoint from already-locked Phase-II state. When the
    /// Phase-II state is itself spilled, it is carried through from disk
    /// unchanged so a Checkpoint op can never lose spilled scorer state.
    fn checkpoint_locked(&self, p: &Phase2) -> Result<SessionCheckpoint, String> {
        let frozen = self.frozen.lock().unwrap().clone();
        let shard_states = if frozen.is_some() {
            Vec::new()
        } else {
            let guard = self.sketches.lock().unwrap();
            guard.iter().map(|s| s.export_state()).collect()
        };
        let (scorers, scores) = match &p.spilled {
            Some(path) => {
                let ck = SessionCheckpoint::load(path)?;
                (ck.scorers, ck.scores)
            }
            None => (
                p.scorers
                    .iter()
                    .map(|slot| slot.as_ref().map(|s| s.export_state()))
                    .collect(),
                p.scores.as_ref().map(|s| s.export_state()),
            ),
        };
        Ok(SessionCheckpoint {
            name: self.name.clone(),
            ell: self.ell as u32,
            d: self.d as u32,
            shards: self.shards as u32,
            shard_states,
            frozen,
            scorers,
            scores,
            wal_seq: self.wal_seq.load(Ordering::Relaxed),
        })
    }

    /// Highest WAL sequence number reflected in this session's state
    /// (0 when the WAL is disabled or nothing was logged yet).
    fn wal_watermark(&self) -> u64 {
        self.wal_seq.load(Ordering::Relaxed)
    }

    /// Record that this session's state now reflects WAL record `seq`.
    /// Monotone: replay and live traffic can never move it backwards.
    fn note_wal_seq(&self, seq: u64) {
        self.wal_seq.fetch_max(seq, Ordering::Relaxed);
    }

    /// Snapshot into a checkpoint (quiesces acked ingest first). Includes
    /// the full Phase-II state, so recovery restores scoring bit-exactly.
    /// Taken under the WAL gate so the image always matches its embedded
    /// watermark: no record can land between the watermark read and the
    /// state snapshot.
    ///
    /// # Errors
    /// Quiesce timeout, or an unreadable spill file.
    pub fn to_checkpoint(&self) -> Result<SessionCheckpoint, String> {
        let _gate = self.wal_gate.lock().unwrap();
        self.quiesce(std::time::Duration::from_secs(10))?;
        let p = self.phase2.lock().unwrap();
        self.checkpoint_locked(&p)
    }

    /// Snapshot and save this session's checkpoint into `dir`, all under
    /// the WAL gate: the saved image matches its embedded watermark
    /// exactly, and two concurrent savers (explicit Checkpoint vs. WAL
    /// compaction) can never race on the same temp file. Returns the file
    /// path and the watermark that was persisted.
    fn checkpoint_to(&self, dir: &Path) -> Result<(PathBuf, u64), String> {
        let _gate = self.wal_gate.lock().unwrap();
        self.quiesce(std::time::Duration::from_secs(10))?;
        let ck = {
            let p = self.phase2.lock().unwrap();
            self.checkpoint_locked(&p)?
        };
        let path = dir.join(format!("{}.sagesess", self.name));
        ck.save(&path)?;
        Ok((path, ck.wal_seq))
    }

    /// Spill this session's Phase-II state to its `.sagesess` file in
    /// `dir` and drop it from memory, releasing its scorer-budget
    /// reservation. Returns the bytes freed (0 when already spilled or
    /// nothing is resident). The state reloads transparently on the next
    /// `Score`/`TopK`.
    ///
    /// # Errors
    /// Quiesce timeout or a failed checkpoint write (state then stays
    /// resident).
    pub fn spill_scores(&self, dir: &Path) -> Result<usize, String> {
        // Under the WAL gate: a spill image taken mid-(apply, append)
        // would snapshot state beyond its watermark and double-apply on
        // replay. Spilled sessions are frozen and every later mutation
        // unspills first, so the file's watermark stays authoritative for
        // as long as the file is the in-disk copy.
        let _gate = self.wal_gate.lock().unwrap();
        self.quiesce(std::time::Duration::from_secs(10))?;
        let mut p = self.phase2.lock().unwrap();
        if p.spilled.is_some() {
            return Ok(0);
        }
        let resident = phase2_bytes(&p);
        if resident == 0 {
            return Ok(0);
        }
        let ck = self.checkpoint_locked(&p)?;
        let path = dir.join(format!("{}.sagesess", self.name));
        ck.save(&path)?;
        self.budgets.scorer.release(resident);
        p.scorers = Vec::new();
        p.scores = None;
        p.spilled = Some(path);
        metrics().counter("service.registry.spills").inc();
        metrics()
            .counter("service.registry.spill_bytes")
            .add(resident as u64);
        Ok(resident)
    }

    /// Reload spilled Phase-II state (caller holds the `phase2` lock),
    /// re-reserving its scorer budget. The budget is reserved from the
    /// checkpoint's *lengths* BEFORE the scorer structures are
    /// materialized, so a failed reservation never transiently exceeds the
    /// cap by the session's full footprint. A transient spill file (one
    /// the client never explicitly checkpointed) is deleted after a
    /// successful reload — the disk copy is no longer authoritative and
    /// must not resurrect stale state on a later restart.
    fn unspill(&self, p: &mut Phase2) -> Result<(), String> {
        let path = match &p.spilled {
            Some(path) => path.clone(),
            None => return Ok(()),
        };
        let ck = SessionCheckpoint::load(&path)?;
        if ck.ell as usize != self.ell
            || ck.d as usize != self.d
            || ck.shards as usize != self.shards
        {
            return Err(format!(
                "spilled state {} does not match session '{}'",
                path.display(),
                self.name
            ));
        }
        let bytes = checkpoint_scorer_bytes(&ck, self.ell, self.shards);
        if !self.budgets.scorer.reserve(bytes) {
            metrics().counter("service.admission.rejected.scorer").inc();
            return Err(scorer_admission_error(&self.name, bytes, &self.budgets.scorer));
        }
        let (scorers, scores) = match restore_phase2(&ck, self.ell, self.shards) {
            Ok(restored) => restored,
            Err(e) => {
                self.budgets.scorer.release(bytes);
                return Err(e);
            }
        };
        p.scorers = scorers;
        p.scores = scores;
        p.spilled = None;
        // Durable mode keeps the file: a WAL compaction may have made this
        // checkpoint the only copy of its already-deleted records. Replay
        // stays correct because the in-memory watermark never regresses.
        if !self.durable && !self.explicitly_checkpointed.load(Ordering::Relaxed) {
            let _ = std::fs::remove_file(&path);
        }
        metrics().counter("service.registry.unspills").inc();
        metrics()
            .counter("service.registry.unspill_bytes")
            .add(bytes as u64);
        Ok(())
    }

    /// Rebuild from a checkpoint (inverse of [`Session::to_checkpoint`]).
    /// The caller must already hold the matching budget reservations.
    fn from_checkpoint(
        ck: &SessionCheckpoint,
        queue_depth: usize,
        budgets: Budgets,
        sketch_reserved: usize,
        compute: Arc<dyn ComputeBackend>,
        durable: bool,
    ) -> Result<Session, String> {
        let (ell, d, shards) = (ck.ell as usize, ck.d as usize, ck.shards as usize);
        session_bytes(ell, d, shards)?; // validate recovered shapes too
        let (scorers, scores) = restore_phase2(ck, ell, shards)?;
        let session = if let Some(frozen) = &ck.frozen {
            Session::new_frozen(
                &ck.name,
                ell,
                d,
                shards,
                frozen.clone(),
                budgets,
                sketch_reserved,
                compute,
                durable,
            )
        } else {
            if ck.shard_states.len() != shards {
                return Err(format!(
                    "checkpoint '{}': {} shard states for {} shards",
                    ck.name,
                    ck.shard_states.len(),
                    shards
                ));
            }
            let mut sketches = Vec::with_capacity(shards);
            for st in &ck.shard_states {
                if st.ell as usize != ell || st.d as usize != d {
                    return Err(format!("checkpoint '{}': shard state dims drift", ck.name));
                }
                sketches.push(FdSketch::from_state_with(st, compute.clone())?);
            }
            Session::new_active(
                &ck.name,
                ell,
                d,
                shards,
                queue_depth,
                sketches,
                budgets,
                sketch_reserved,
                compute,
                durable,
            )
        };
        *session.phase2.lock().unwrap() = Phase2 {
            scorers,
            scores,
            spilled: None,
        };
        // Resume the watermark so replay skips records this image covers.
        session.wal_seq.store(ck.wal_seq, Ordering::Relaxed);
        // The file this session was recovered from may be a client's
        // explicit checkpoint — never treat it as a transient spill file.
        session
            .explicitly_checkpointed
            .store(true, Ordering::Relaxed);
        Ok(session)
    }
}

impl Drop for Session {
    fn drop(&mut self) {
        if let Some(tx) = self.ingest_tx.lock().unwrap().take() {
            tx.close();
        }
        if let Some(worker) = self.worker.lock().unwrap().take() {
            let _ = worker.join();
        }
        // Release this session's admission reservations. `get_mut` cannot
        // block (we hold the only reference) and tolerates poisoning.
        let resident = {
            let p = match self.phase2.get_mut() {
                Ok(p) => p,
                Err(e) => e.into_inner(),
            };
            phase2_bytes(p)
        };
        self.budgets.scorer.release(resident);
        self.budgets.sketch.release(self.sketch_reserved);
        self.budgets.slots.release(1);
    }
}

fn valid_session_name(name: &str) -> bool {
    !name.is_empty()
        && name.len() <= 64
        && name
            .chars()
            .all(|c| c.is_ascii_alphanumeric() || matches!(c, '.' | '_' | '-'))
}

/// One registry shard: an independent session map plus lock-free occupancy
/// counters so fleet-wide accounting never takes a second lock.
#[derive(Default)]
struct RegistryShard {
    sessions: RwLock<BTreeMap<String, Arc<Session>>>,
    session_count: AtomicUsize,
    sketch_bytes: AtomicUsize,
}

/// Sharded concurrent registry of live sessions with exact lock-free
/// admission control (see the module docs for the invariants).
pub struct SessionRegistry {
    cfg: RegistryConfig,
    shards: Vec<RegistryShard>,
    budgets: Budgets,
    /// Monotonic activity clock ordering sessions for spill (LRU-ish).
    clock: AtomicU64,
    /// Kernel backend every session runs its contractions on (FD shrink,
    /// finalize matvec, selection rules). Serial by default; the server
    /// threads its shared `tensor::ParallelBackend` in. Bit-identical
    /// results across backends keep served ≡ offline selection exact.
    compute: Arc<dyn ComputeBackend>,
    /// Write-ahead log, set once by [`SessionRegistry::open_wal`] *after*
    /// checkpoint recovery and replay. While unset (the default, and for
    /// the whole of replay) mutating ops skip logging entirely, so replay
    /// can drive the normal code paths without re-appending records.
    wal: OnceLock<Arc<Wal>>,
    /// Push-subscription observer (see [`RegistryWatcher`]), set once by
    /// the serving layer. Unset for offline/test registries — callbacks
    /// then cost one relaxed load.
    watcher: OnceLock<Arc<dyn RegistryWatcher>>,
}

impl SessionRegistry {
    pub fn new(cfg: RegistryConfig) -> Self {
        Self::with_compute(cfg, crate::tensor::serial())
    }

    /// Registry over an explicit kernel backend (see the `compute` field).
    pub fn with_compute(cfg: RegistryConfig, compute: Arc<dyn ComputeBackend>) -> Self {
        let count = normalize_shard_count(cfg.registry_shards);
        let budgets = Budgets {
            slots: Arc::new(ByteBudget::new(cfg.max_sessions)),
            sketch: Arc::new(ByteBudget::new(cfg.max_resident_bytes)),
            scorer: Arc::new(ByteBudget::new(cfg.max_scorer_bytes)),
        };
        Self {
            cfg,
            shards: (0..count).map(|_| RegistryShard::default()).collect(),
            budgets,
            clock: AtomicU64::new(1),
            compute,
            wal: OnceLock::new(),
            watcher: OnceLock::new(),
        }
    }

    /// Install the push-subscription observer. One-shot: later calls are
    /// ignored (the serving layer owns the single hub for this registry).
    pub fn set_watcher(&self, watcher: Arc<dyn RegistryWatcher>) {
        let _ = self.watcher.set(watcher);
    }

    fn notify_dirty(&self, name: &str) {
        if let Some(w) = self.watcher.get() {
            w.selection_dirty(name);
        }
    }

    /// The WAL handle, if durability is enabled and replay has finished.
    fn wal_handle(&self) -> Option<&Arc<Wal>> {
        self.wal.get()
    }

    /// Whether sessions run under durable-mode file-lifecycle rules. True
    /// from construction whenever the config asks for a WAL, *not* only
    /// after `open_wal`: sessions rebuilt during replay must already keep
    /// their compaction checkpoints alive across unspill.
    fn durable(&self) -> bool {
        self.cfg.durability != Durability::None
    }

    pub fn config(&self) -> &RegistryConfig {
        &self.cfg
    }

    /// Actual registry shard count (power of two).
    pub fn registry_shards(&self) -> usize {
        self.shards.len()
    }

    /// Which registry shard `name` lives in (FNV-64 of the name, masked).
    pub fn shard_index(&self, name: &str) -> usize {
        fnv64(name.as_bytes()) as usize & (self.shards.len() - 1)
    }

    pub fn session_count(&self) -> usize {
        self.budgets.slots.used()
    }

    /// Total resident sketch bytes across live sessions.
    pub fn resident_bytes(&self) -> usize {
        self.budgets.sketch.used()
    }

    /// Total resident Phase-II scorer bytes across live sessions.
    pub fn scorer_bytes(&self) -> usize {
        self.budgets.scorer.used()
    }

    /// Mirror shard `i`'s occupancy into the process-global metrics
    /// gauges. The Stats wire op reads the registry-local atomics directly
    /// (a test registry must not see another registry's numbers); the
    /// global gauges exist for the operator-facing `metrics::report()`
    /// dump (`SAGE_METRICS=1`), which has no reference to the registry.
    fn publish_shard_gauges(&self, i: usize) {
        let shard = &self.shards[i];
        metrics()
            .gauge(&format!("service.registry.shard.{i}.sessions"))
            .set(shard.session_count.load(Ordering::Relaxed) as u64);
        metrics()
            .gauge(&format!("service.registry.shard.{i}.sketch_bytes"))
            .set(shard.sketch_bytes.load(Ordering::Relaxed) as u64);
    }

    /// Admission-controlled session creation: reserves one session slot,
    /// the session's sketch bytes, and its scorer baseline, all exactly
    /// (single-CAS budgets), before touching the (single) registry shard
    /// the name hashes to.
    ///
    /// # Errors
    /// Invalid name/shape, duplicate name, or any exhausted budget
    /// (messages all contain `admission`).
    pub fn create(&self, name: &str, ell: usize, d: usize, shards: usize) -> Result<(), String> {
        if !valid_session_name(name) {
            return Err(format!(
                "invalid session name '{name}' (want [A-Za-z0-9._-], ≤ 64 chars)"
            ));
        }
        let new_bytes = session_bytes(ell, d, shards)?;
        let scorer_baseline = baseline_scorer_bytes(ell, shards);
        if !self.budgets.slots.reserve(1) {
            metrics().counter("service.admission.rejected.slots").inc();
            return Err(format!(
                "admission rejected: {} sessions resident (max {})",
                self.budgets.slots.used(),
                self.cfg.max_sessions
            ));
        }
        if !self.budgets.sketch.reserve(new_bytes) {
            self.budgets.slots.release(1);
            metrics().counter("service.admission.rejected.sketch").inc();
            return Err(format!(
                "admission rejected: {new_bytes} sketch bytes would exceed budget \
                 ({}/{} in use)",
                self.budgets.sketch.used(),
                self.cfg.max_resident_bytes
            ));
        }
        if !self.budgets.scorer.reserve(scorer_baseline) {
            self.budgets.sketch.release(new_bytes);
            self.budgets.slots.release(1);
            metrics().counter("service.admission.rejected.scorer").inc();
            return Err(format!(
                "admission rejected: session '{name}' needs {scorer_baseline} scorer \
                 bytes, {}/{} in use (raise --max-scorer-mb)",
                self.budgets.scorer.used(),
                self.cfg.max_scorer_bytes
            ));
        }
        let idx = self.shard_index(name);
        let shard = &self.shards[idx];
        {
            let mut guard = shard.sessions.write().unwrap();
            if guard.contains_key(name) {
                drop(guard);
                self.budgets.scorer.release(scorer_baseline);
                self.budgets.sketch.release(new_bytes);
                self.budgets.slots.release(1);
                return Err(format!("session '{name}' already exists"));
            }
            let sketches = (0..shards)
                .map(|_| FdSketch::with_backend(ell, d, self.compute.clone()))
                .collect();
            let session = Session::new_active(
                name,
                ell,
                d,
                shards,
                self.cfg.ingest_queue_depth,
                sketches,
                self.budgets.clone(),
                new_bytes,
                self.compute.clone(),
                self.durable(),
            );
            if let Some(wal) = self.wal_handle() {
                let payload = Request::CreateSession {
                    name: name.to_string(),
                    ell: ell as u32,
                    d: d as u32,
                    shards: shards as u32,
                }
                .encode();
                match wal.append(idx, op::CREATE_SESSION, &payload) {
                    Ok(seq) => session.note_wal_seq(seq),
                    Err(e) => {
                        // Dropping the unpublished session releases its
                        // budget reservations (Session::drop).
                        drop(guard);
                        drop(session);
                        return Err(e);
                    }
                }
            }
            guard.insert(name.to_string(), Arc::new(session));
            shard.session_count.fetch_add(1, Ordering::Relaxed);
            shard.sketch_bytes.fetch_add(new_bytes, Ordering::Relaxed);
        }
        self.publish_shard_gauges(idx);
        metrics().counter("service.registry.sessions_created").inc();
        Ok(())
    }

    /// Look up a live session (bumps its activity clock for spill order).
    ///
    /// # Errors
    /// Unknown session name.
    pub fn get(&self, name: &str) -> Result<Arc<Session>, String> {
        let session = self.shards[self.shard_index(name)]
            .sessions
            .read()
            .unwrap()
            .get(name)
            .cloned()
            .ok_or_else(|| format!("unknown session '{name}'"))?;
        session.touch(self.clock.fetch_add(1, Ordering::Relaxed));
        Ok(session)
    }

    /// [`Session::preview_selection`] by name — the subscription hub's
    /// entry point. `None` for unknown sessions and un-previewable state.
    /// Touches the activity clock, so actively-subscribed sessions stay
    /// late in the spill LRU order.
    pub fn preview_selection(
        &self,
        name: &str,
        method: Method,
        k: usize,
        num_classes: usize,
        seed: u64,
    ) -> Option<(Vec<u64>, f64)> {
        self.get(name)
            .ok()?
            .preview_selection(method, k, num_classes, seed)
    }

    /// Remove a session. Its admission reservations (slot, sketch bytes,
    /// scorer bytes) are released when the last `Arc` reference — in-flight
    /// requests included — goes away, via `Session::drop`, which also joins
    /// the ingest worker.
    ///
    /// # Errors
    /// Unknown session name.
    pub fn close(&self, name: &str) -> Result<(), String> {
        let idx = self.shard_index(name);
        let shard = &self.shards[idx];
        let session = shard
            .sessions
            .read()
            .unwrap()
            .get(name)
            .cloned()
            .ok_or_else(|| format!("unknown session '{name}'"))?;
        // The Close record goes in *before* the map removal: if the append
        // fails the session stays live, so the log never claims a close
        // that did not happen. It is appended under the session's WAL gate
        // — not the shard map lock — so a sync-mode group-commit fsync
        // never blocks unrelated session lookups on this shard.
        if let Some(wal) = self.wal_handle() {
            let _gate = session.wal_gate.lock().unwrap();
            let payload = Request::CloseSession {
                session: name.to_string(),
            }
            .encode();
            let seq = wal.append(idx, op::CLOSE_SESSION, &payload)?;
            session.note_wal_seq(seq);
        }
        let removed = {
            let mut guard = shard.sessions.write().unwrap();
            // Remove only the session we logged against: a concurrent
            // close-then-create may have replaced the entry, and the log
            // says the newcomer (whose Create sorts after our Close) is
            // alive.
            let same = guard
                .get(name)
                .is_some_and(|live| Arc::ptr_eq(live, &session));
            if same {
                guard.remove(name)
            } else {
                None
            }
        };
        match removed {
            Some(session) => {
                shard.session_count.fetch_sub(1, Ordering::Relaxed);
                shard
                    .sketch_bytes
                    .fetch_sub(session.resident_bytes(), Ordering::Relaxed);
                // A transient spill file must not outlive its session — a
                // later restart would resurrect a session the client
                // closed. Explicit checkpoints are durable and stay...
                // except in durable mode, where a WAL compaction may since
                // have deleted this session's records: once the Close
                // record itself is compacted away, a surviving `.sagesess`
                // would resurrect the session, so durable close always
                // removes the file.
                let transient_spill = session.is_spilled()
                    && !session.explicitly_checkpointed.load(Ordering::Relaxed);
                if self.durable() || transient_spill {
                    if let Some(dir) = &self.cfg.checkpoint_dir {
                        let _ = std::fs::remove_file(dir.join(format!("{name}.sagesess")));
                    }
                }
                drop(session);
                self.publish_shard_gauges(idx);
                metrics().counter("service.registry.sessions_closed").inc();
                if let Some(w) = self.watcher.get() {
                    w.session_closed(name);
                }
                Ok(())
            }
            // Lost a race with a concurrent close of the same session: it
            // is gone either way (the winner did the bookkeeping), and a
            // duplicate Close record replays as a no-op.
            None => Ok(()),
        }
    }

    /// Durable ingest: apply through [`Session::ingest`], then append the
    /// batch to the WAL under the session's gate (apply → append → ack; a
    /// snapshot taken under the same gate therefore always matches its
    /// watermark). Without a WAL this is exactly the session call.
    ///
    /// # Errors
    /// Everything [`Session::ingest`] returns, plus WAL append failures
    /// (the op *was* applied, but durability can no longer be promised —
    /// the WAL poisons itself and refuses all later mutating ops).
    pub fn ingest(&self, name: &str, shard: usize, rows: Matrix) -> Result<u64, String> {
        let session = self.get(name)?;
        let Some(wal) = self.wal_handle() else {
            return session.ingest(shard, rows);
        };
        let payload = encode_ingest_batch(name, shard as u32, &rows);
        let gate = session.wal_gate.lock().unwrap();
        let acked = session.ingest(shard, rows)?;
        let seq = wal.append(self.shard_index(name), op::INGEST_BATCH, &payload)?;
        session.note_wal_seq(seq);
        drop(gate);
        self.maybe_compact();
        Ok(acked)
    }

    /// Durable sketch merge (see [`SessionRegistry::ingest`] for the WAL
    /// ordering contract).
    ///
    /// # Errors
    /// Everything [`Session::merge_sketch`] returns, plus WAL append
    /// failures.
    pub fn merge_sketch(&self, name: &str, shard: usize, state: &SketchState) -> Result<(), String> {
        let session = self.get(name)?;
        let Some(wal) = self.wal_handle() else {
            return session.merge_sketch(shard, state);
        };
        let payload = encode_merge_sketch(name, shard as u32, state);
        let gate = session.wal_gate.lock().unwrap();
        session.merge_sketch(shard, state)?;
        let seq = wal.append(self.shard_index(name), op::MERGE_SKETCH, &payload)?;
        session.note_wal_seq(seq);
        drop(gate);
        self.maybe_compact();
        Ok(())
    }

    /// Durable freeze. Only the actual active→frozen transition is logged
    /// — the call is idempotent, and replaying a second Freeze against the
    /// rebuilt state would be a harmless but noisy no-op.
    ///
    /// # Errors
    /// Everything [`Session::freeze`] returns, plus WAL append failures.
    pub fn freeze(&self, name: &str) -> Result<FrozenSketch, String> {
        let session = self.get(name)?;
        let Some(wal) = self.wal_handle() else {
            let info = session.freeze()?;
            self.notify_dirty(name);
            return Ok(info);
        };
        let info = {
            let _gate = session.wal_gate.lock().unwrap();
            let was_frozen = session.is_frozen();
            let info = session.freeze()?;
            if !was_frozen {
                let payload = Request::Freeze {
                    session: name.to_string(),
                }
                .encode();
                let seq = wal.append(self.shard_index(name), op::FREEZE, &payload)?;
                session.note_wal_seq(seq);
            }
            info
        };
        self.notify_dirty(name);
        Ok(info)
    }

    /// Score with spill-on-pressure: on a scorer-budget rejection, spill
    /// the least-recently-active *other* session's Phase-II state to the
    /// checkpoint dir and retry. Bounded retries; without a checkpoint dir
    /// the first rejection is final. Each attempt holds the session's WAL
    /// gate only for (apply + append) — never across a spill of another
    /// session, which takes *that* session's gate (no lock-order cycle).
    ///
    /// # Errors
    /// Everything [`Session::score`] returns; a [`SCORER_ADMISSION`] error
    /// only after no further session can be spilled; WAL append failures.
    pub fn score(&self, name: &str, shard: usize, batch: &ScoreBatch) -> Result<(), String> {
        let session = self.get(name)?;
        let wal = self.wal_handle().cloned();
        let payload = wal.as_ref().map(|_| {
            encode_score(
                name,
                shard as u32,
                &batch.indices,
                &batch.labels,
                &batch.norms,
                &batch.losses,
                &batch.zhat,
            )
        });
        let mut last = String::new();
        for _ in 0..64 {
            let outcome = {
                let _gate = session.wal_gate.lock().unwrap();
                match session.score(shard, batch) {
                    Ok(()) => match (wal.as_ref(), payload.as_deref()) {
                        (Some(wal), Some(payload)) => wal
                            .append(self.shard_index(name), op::SCORE, payload)
                            .map(|seq| session.note_wal_seq(seq)),
                        _ => Ok(()),
                    },
                    other => other,
                }
            };
            match outcome {
                Err(e) if e.starts_with(SCORER_ADMISSION) => {
                    if !self.spill_one(name) {
                        return Err(e);
                    }
                    last = e;
                }
                other => {
                    self.maybe_compact();
                    if other.is_ok() {
                        self.notify_dirty(name);
                    }
                    return other;
                }
            }
        }
        Err(last)
    }

    /// TopK with spill-on-pressure (reloading this session's spilled state
    /// may need budget another session is holding — see
    /// [`SessionRegistry::score`]). Only the *finalizing* call mutates
    /// state, so only that call is logged: the session's `just_finalized`
    /// flag is cleared before and swapped after the attempt.
    ///
    /// # Errors
    /// Everything [`Session::top_k`] returns; a [`SCORER_ADMISSION`] error
    /// only after no further session can be spilled; WAL append failures.
    pub fn top_k(
        &self,
        name: &str,
        method: Method,
        k: usize,
        num_classes: usize,
        seed: u64,
    ) -> Result<(Vec<usize>, Option<Vec<f32>>), String> {
        let session = self.get(name)?;
        let wal = self.wal_handle().cloned();
        let mut last = String::new();
        for _ in 0..64 {
            let outcome = {
                let _gate = session.wal_gate.lock().unwrap();
                session.just_finalized.store(false, Ordering::Relaxed);
                match session.top_k(method, k, num_classes, seed) {
                    Ok(result) => {
                        let finalized = session.just_finalized.swap(false, Ordering::Relaxed);
                        match (finalized, wal.as_ref()) {
                            (true, Some(wal)) => {
                                let payload = Request::TopK {
                                    session: name.to_string(),
                                    method: method.name().to_string(),
                                    k: k as u64,
                                    num_classes: num_classes as u32,
                                    seed,
                                }
                                .encode();
                                wal.append(self.shard_index(name), op::TOP_K, &payload)
                                    .map(|seq| {
                                        session.note_wal_seq(seq);
                                        result
                                    })
                            }
                            _ => Ok(result),
                        }
                    }
                    Err(e) => Err(e),
                }
            };
            match outcome {
                Err(e) if e.starts_with(SCORER_ADMISSION) => {
                    if !self.spill_one(name) {
                        return Err(e);
                    }
                    last = e;
                }
                other => {
                    self.maybe_compact();
                    if other.is_ok() {
                        self.notify_dirty(name);
                    }
                    return other;
                }
            }
        }
        Err(last)
    }

    /// Spill the least-recently-active session (excluding `exclude`) that
    /// holds actual scored state. Returns false when spilling is disabled
    /// (no checkpoint dir) or no candidate freed anything.
    fn spill_one(&self, exclude: &str) -> bool {
        let dir = match &self.cfg.checkpoint_dir {
            Some(dir) => dir.clone(),
            None => return false,
        };
        // Candidate scan visits shards one at a time — no cross-shard lock.
        let mut candidates: Vec<(u64, Arc<Session>)> = Vec::new();
        for shard in &self.shards {
            let guard = shard.sessions.read().unwrap();
            for (name, session) in guard.iter() {
                if name != exclude && session.has_spillable_scores() {
                    candidates.push((session.last_active(), session.clone()));
                }
            }
        }
        candidates.sort_by_key(|(tick, _)| *tick);
        for (_, session) in candidates {
            match session.spill_scores(&dir) {
                Ok(freed) if freed > 0 => {
                    crate::log_info!(
                        "spilled {} scorer bytes of session '{}' under budget pressure",
                        freed,
                        session.name()
                    );
                    return true;
                }
                Ok(_) => continue,
                Err(e) => {
                    crate::log_warn!("spill of session '{}' failed: {e}", session.name());
                    continue;
                }
            }
        }
        false
    }

    /// Persist one session into the configured checkpoint directory.
    /// Returns the file path and the WAL watermark embedded in the image
    /// (0 without a WAL) — the `Checkpointed` wire reply carries both.
    ///
    /// # Errors
    /// No checkpoint dir configured, unknown session, quiesce timeout, or
    /// a failed write.
    pub fn checkpoint(&self, name: &str) -> Result<(PathBuf, u64), String> {
        let dir = self
            .cfg
            .checkpoint_dir
            .as_ref()
            .ok_or_else(|| "server has no --checkpoint-dir configured".to_string())?
            .clone();
        let session = self.get(name)?;
        let (path, wal_seq) = session.checkpoint_to(&dir)?;
        // From here on the file is the client's durable state: spill
        // reloads and CloseSession must leave it in place (non-durable
        // mode; durable close always removes it — see `close`).
        session
            .explicitly_checkpointed
            .store(true, Ordering::Relaxed);
        metrics().counter("service.registry.checkpoints").inc();
        Ok((path, wal_seq))
    }

    /// Recover every `*.sagesess` session from `dir` (server restart).
    /// Returns the number of sessions recovered; unreadable files and
    /// sessions that no longer fit the admission budgets are skipped with
    /// a warning so one bad checkpoint can't block startup.
    pub fn recover(&self, dir: &Path) -> usize {
        let entries = match std::fs::read_dir(dir) {
            Ok(e) => e,
            Err(_) => return 0,
        };
        let mut recovered = 0usize;
        for entry in entries.filter_map(|e| e.ok()) {
            let path = entry.path();
            if path.extension().map(|e| e != "sagesess").unwrap_or(true) {
                continue;
            }
            match SessionCheckpoint::load(&path) {
                Ok(ck) => match self.admit_recovered(&ck) {
                    Ok(()) => recovered += 1,
                    Err(e) => {
                        crate::log_warn!("recovery skipped session '{}': {e}", ck.name)
                    }
                },
                Err(e) => crate::log_warn!("recovery: unreadable {}: {e}", path.display()),
            }
        }
        recovered
    }

    /// Admit one recovered checkpoint under the same budgets as `create`.
    fn admit_recovered(&self, ck: &SessionCheckpoint) -> Result<(), String> {
        if !valid_session_name(&ck.name) {
            return Err(format!("invalid session name '{}'", ck.name));
        }
        let (ell, d, shards) = (ck.ell as usize, ck.d as usize, ck.shards as usize);
        let new_bytes = session_bytes(ell, d, shards)?;
        let scorer_bytes = checkpoint_scorer_bytes(ck, ell, shards);
        if !self.budgets.slots.reserve(1) {
            return Err("admission: session slots exhausted".into());
        }
        if !self.budgets.sketch.reserve(new_bytes) {
            self.budgets.slots.release(1);
            return Err("admission: sketch budget exhausted".into());
        }
        if !self.budgets.scorer.reserve(scorer_bytes) {
            self.budgets.sketch.release(new_bytes);
            self.budgets.slots.release(1);
            return Err("admission: scorer budget exhausted".into());
        }
        let release_all = |budgets: &Budgets| {
            budgets.scorer.release(scorer_bytes);
            budgets.sketch.release(new_bytes);
            budgets.slots.release(1);
        };
        let session = match Session::from_checkpoint(
            ck,
            self.cfg.ingest_queue_depth,
            self.budgets.clone(),
            new_bytes,
            self.compute.clone(),
            self.durable(),
        ) {
            Ok(session) => session,
            Err(e) => {
                release_all(&self.budgets);
                return Err(e);
            }
        };
        let idx = self.shard_index(&ck.name);
        let shard = &self.shards[idx];
        {
            let mut guard = shard.sessions.write().unwrap();
            if guard.contains_key(&ck.name) {
                // Dropping the freshly built session releases its budgets.
                return Err(format!("session '{}' already exists", ck.name));
            }
            guard.insert(ck.name.clone(), Arc::new(session));
            shard.session_count.fetch_add(1, Ordering::Relaxed);
            shard.sketch_bytes.fetch_add(new_bytes, Ordering::Relaxed);
        }
        self.publish_shard_gauges(idx);
        Ok(())
    }

    /// Open the write-ahead log in the checkpoint directory, replay every
    /// surviving record on top of the recovered checkpoints, compact the
    /// replayed segments into fresh checkpoints, and only then arm live
    /// logging. Call once at startup, after [`SessionRegistry::recover`];
    /// while replay runs the WAL handle is still unset, so the normal
    /// create / ingest / score paths it drives do not re-append records.
    /// Returns the highest sequence number the log has ever assigned.
    /// No-op returning 0 with `--durability none`.
    ///
    /// # Errors
    /// Durability without a checkpoint dir, an unusable WAL directory, or
    /// a double open. Per-record replay failures and a failed startup
    /// compaction only WARN — one bad record or full disk must not block
    /// startup.
    pub fn open_wal(&self) -> Result<u64, String> {
        if self.cfg.durability == Durability::None {
            return Ok(0);
        }
        let dir = self
            .cfg
            .checkpoint_dir
            .as_ref()
            .ok_or_else(|| {
                "durability requires --checkpoint-dir (the WAL lives beside the checkpoints)"
                    .to_string()
            })?
            .clone();
        let storage: Arc<dyn StorageBackend> = Arc::new(LocalDirBackend::create(&dir)?);
        // Seed the sequence counter above every recovered watermark: a
        // compact-then-restart cycle can leave no surviving segment
        // records while checkpoints still carry high `wal_seq` marks, and
        // a fresh acked record assigned a seq at or below a watermark
        // would be silently skipped by the next replay (a lost write).
        let mut seq_floor = 0u64;
        for shard in &self.shards {
            for session in shard.sessions.read().unwrap().values() {
                seq_floor = seq_floor.max(session.wal_watermark());
            }
        }
        let wal_cfg = WalConfig {
            shards: self.shards.len(),
            durability: self.cfg.durability,
            compact_bytes: self.cfg.wal_compact_bytes,
            seq_floor,
            fault: self.cfg.wal_fault,
        };
        let (wal, records) = Wal::open(storage, &wal_cfg)?;
        let wal = Arc::new(wal);
        let start = std::time::Instant::now();
        let total = records.len();
        let mut applied = 0usize;
        for record in &records {
            match self.replay_record(record) {
                Ok(true) => applied += 1,
                Ok(false) => {}
                Err(e) => crate::log_warn!(
                    "WAL replay skipped record {} (op {}): {e}",
                    record.seq,
                    record.op
                ),
            }
        }
        metrics()
            .counter("service.wal.replayed_records")
            .add(applied as u64);
        metrics()
            .histogram("service.wal.replay.ns")
            .record(start.elapsed().as_nanos() as u64);
        if total > 0 {
            crate::log_info!(
                "WAL replay: applied {applied}/{total} records (last seq {})",
                wal.last_seq()
            );
        }
        // Fold the replayed segments into checkpoints, then delete them:
        // every resident session is re-saved with a watermark covering all
        // replayed records, so the old segments are dead weight. Crashing
        // in between is safe — replay is idempotent under watermarks — and
        // a failed fold just retains the segments for the next restart.
        if wal.has_stale_segments() {
            match self.checkpoint_all_resident() {
                Ok(()) => match wal.purge_stale_segments() {
                    Ok(purged) if purged > 0 => {
                        metrics().counter("service.wal.compactions").inc();
                        crate::log_info!(
                            "WAL startup compaction: purged {purged} replayed segments"
                        );
                    }
                    Ok(_) => {}
                    Err(e) => crate::log_warn!("WAL startup compaction: purge failed: {e}"),
                },
                Err(e) => crate::log_warn!(
                    "WAL startup compaction skipped: {e} (replayed segments retained)"
                ),
            }
        }
        let last = wal.last_seq();
        self.wal
            .set(wal)
            .map_err(|_| "WAL already open for this registry".to_string())?;
        Ok(last)
    }

    /// Resolve the session a replayed record targets: `None` when the
    /// session is gone (closed later in the log) or its checkpoint
    /// watermark already covers the record.
    fn replay_target(&self, name: &str, seq: u64) -> Option<Arc<Session>> {
        let idx = self.shard_index(name);
        let session = self.shards[idx].sessions.read().unwrap().get(name).cloned();
        session.filter(|s| s.wal_watermark() < seq)
    }

    /// Apply one replayed WAL record through the normal (non-logging)
    /// paths — replay in global `seq` order reproduces a valid serial
    /// history, budgets and spill-on-pressure included. Returns whether
    /// the record mutated state (`false` = covered by a watermark or the
    /// session no longer exists).
    fn replay_record(&self, record: &WalRecord) -> Result<bool, String> {
        let req = Request::decode(record.op, &record.payload)?;
        match req {
            Request::CreateSession {
                name,
                ell,
                d,
                shards,
            } => {
                let idx = self.shard_index(&name);
                let exists = self.shards[idx]
                    .sessions
                    .read()
                    .unwrap()
                    .contains_key(&name);
                if exists {
                    // Rebuilt from a checkpoint whose watermark may still
                    // predate this record; bump it so later records for
                    // this session replay exactly once.
                    self.get(&name)?.note_wal_seq(record.seq);
                    return Ok(false);
                }
                self.create(&name, ell as usize, d as usize, shards as usize)?;
                self.get(&name)?.note_wal_seq(record.seq);
                Ok(true)
            }
            Request::IngestBatch {
                session,
                shard,
                rows,
            } => match self.replay_target(&session, record.seq) {
                None => Ok(false),
                Some(s) => {
                    s.ingest(shard as usize, rows)?;
                    s.note_wal_seq(record.seq);
                    Ok(true)
                }
            },
            Request::MergeSketch {
                session,
                shard,
                state,
            } => match self.replay_target(&session, record.seq) {
                None => Ok(false),
                Some(s) => {
                    s.merge_sketch(shard as usize, &state)?;
                    s.note_wal_seq(record.seq);
                    Ok(true)
                }
            },
            Request::Freeze { session } => match self.replay_target(&session, record.seq) {
                None => Ok(false),
                Some(s) => {
                    s.freeze()?;
                    s.note_wal_seq(record.seq);
                    Ok(true)
                }
            },
            Request::Score {
                session,
                shard,
                batch,
            } => match self.replay_target(&session, record.seq) {
                None => Ok(false),
                Some(_) => {
                    self.score(&session, shard as usize, &batch)?;
                    self.get(&session)?.note_wal_seq(record.seq);
                    Ok(true)
                }
            },
            Request::TopK {
                session,
                method,
                k,
                num_classes,
                seed,
            } => match self.replay_target(&session, record.seq) {
                None => Ok(false),
                Some(_) => {
                    let method = Method::parse(&method)?;
                    self.top_k(&session, method, k as usize, num_classes as usize, seed)?;
                    self.get(&session)?.note_wal_seq(record.seq);
                    Ok(true)
                }
            },
            Request::CloseSession { session } => {
                let idx = self.shard_index(&session);
                let exists = self.shards[idx]
                    .sessions
                    .read()
                    .unwrap()
                    .contains_key(&session);
                if !exists {
                    return Ok(false);
                }
                self.close(&session)?;
                Ok(true)
            }
            other => Err(format!("non-mutating op {} in the WAL", other.opcode())),
        }
    }

    /// Re-checkpoint every resident (non-spilled) session — the compaction
    /// write barrier. Spilled sessions are skipped: their on-disk image
    /// already carries a watermark covering all their records (every
    /// mutation unspills first), and durable-mode unspill never deletes
    /// it.
    fn checkpoint_all_resident(&self) -> Result<(), String> {
        let dir = self
            .cfg
            .checkpoint_dir
            .as_ref()
            .ok_or_else(|| "no checkpoint dir".to_string())?
            .clone();
        for shard in &self.shards {
            let sessions: Vec<Arc<Session>> =
                shard.sessions.read().unwrap().values().cloned().collect();
            for session in sessions {
                if session.is_spilled() {
                    continue;
                }
                session.checkpoint_to(&dir)?;
            }
        }
        Ok(())
    }

    /// Inline compaction: when a WAL shard crosses its size threshold,
    /// rotate it onto a fresh segment, fold the live state into
    /// checkpoints, and delete the sealed segments. Runs on the mutating
    /// path that crossed the threshold (after its gate is released); the
    /// per-shard CAS slot keeps each shard single-flight. Crash-safe at
    /// every step: segments are deleted only after every resident session
    /// persisted a covering watermark, and any failure just retains them.
    fn maybe_compact(&self) {
        let Some(wal) = self.wal_handle() else { return };
        let mut claimed: Vec<usize> = Vec::new();
        for shard in 0..self.shards.len() {
            if wal.wants_compaction(shard) && wal.begin_compaction(shard) {
                claimed.push(shard);
            }
        }
        if claimed.is_empty() {
            return;
        }
        let mut sealed: Vec<String> = Vec::new();
        let mut rotate_failed = false;
        for &shard in &claimed {
            match wal.rotate(shard) {
                Ok(keys) => sealed.extend(keys),
                Err(e) => {
                    crate::log_warn!("WAL compaction: rotate of shard {shard} failed: {e}");
                    rotate_failed = true;
                }
            }
        }
        if !sealed.is_empty() && !rotate_failed {
            let folded = self
                .checkpoint_all_resident()
                .and_then(|()| wal.delete_segments(&sealed));
            // (rotate() already counted service.wal.compactions per shard.)
            match folded {
                Ok(()) => {
                    crate::log_info!(
                        "WAL compaction: folded state and deleted {} sealed segments",
                        sealed.len()
                    );
                    // The fresh checkpoints also cover anything a previous
                    // failed compaction left behind — retry those now.
                    match wal.purge_stale_segments() {
                        Ok(0) => {}
                        Ok(n) => crate::log_info!(
                            "WAL compaction: purged {n} previously retained segments"
                        ),
                        Err(e) => crate::log_warn!(
                            "WAL compaction: retained-segment purge failed: {e}"
                        ),
                    }
                }
                Err(e) => {
                    // Hand the sealed keys back for a later retry: the
                    // rotation already reset the shard's byte counter, so
                    // wants_compaction alone would never refire for them
                    // and they would linger on disk until a restart.
                    let n = sealed.len();
                    wal.retain_stale(sealed);
                    crate::log_warn!(
                        "WAL compaction deferred: {e} ({n} sealed segments retained; \
                         replay still covers them)"
                    );
                }
            }
        }
        for &shard in &claimed {
            wal.end_compaction(shard);
        }
    }

    /// Stats for the wire op: one session's counters, or (empty name)
    /// registry-level counters — budgets, per-registry-shard occupancy —
    /// plus every session's counters. Never holds more than one shard lock
    /// at a time.
    ///
    /// # Errors
    /// Unknown session name (non-empty `session` only).
    pub fn stats_pairs(&self, session: &str) -> Result<Vec<(String, u64)>, String> {
        if !session.is_empty() {
            return Ok(self.get(session)?.stats_pairs());
        }
        let mut pairs = vec![
            (
                "service.registry.sessions".to_string(),
                self.session_count() as u64,
            ),
            (
                "service.registry.resident_bytes".to_string(),
                self.resident_bytes() as u64,
            ),
            (
                "service.registry.scorer_bytes".to_string(),
                self.scorer_bytes() as u64,
            ),
            (
                "service.registry.max_sessions".to_string(),
                self.cfg.max_sessions as u64,
            ),
            (
                "service.registry.max_resident_bytes".to_string(),
                self.cfg.max_resident_bytes as u64,
            ),
            (
                "service.registry.max_scorer_bytes".to_string(),
                self.cfg.max_scorer_bytes as u64,
            ),
            (
                "service.registry.shards".to_string(),
                self.shards.len() as u64,
            ),
            // Which kernel dispatch tier serves this registry's sessions
            // (0 = scalar, 1 = simd), so deployments can audit that a host
            // actually runs the tier they expect. Host capability flags ride
            // along: `simd_available` says the binary *could* run the SIMD
            // tier here even if the active tier was forced to scalar.
            (
                "service.registry.kernel_tier".to_string(),
                self.compute.dispatch().tier().index(),
            ),
            (
                "service.registry.kernel_avx2".to_string(),
                u64::from(crate::tensor::kernels::avx2_detected()),
            ),
            (
                "service.registry.kernel_simd_available".to_string(),
                u64::from(crate::tensor::kernels::simd_dispatch().is_some()),
            ),
        ];
        for (i, shard) in self.shards.iter().enumerate() {
            pairs.push((
                format!("service.registry.shard.{i}.sessions"),
                shard.session_count.load(Ordering::Relaxed) as u64,
            ));
            pairs.push((
                format!("service.registry.shard.{i}.sketch_bytes"),
                shard.sketch_bytes.load(Ordering::Relaxed) as u64,
            ));
        }
        if let Some(wal) = self.wal_handle() {
            pairs.push(("service.wal.last_seq".to_string(), wal.last_seq()));
            pairs.push((
                "service.wal.durability".to_string(),
                match wal.durability() {
                    Durability::None => 0,
                    Durability::Async => 1,
                    Durability::Sync => 2,
                },
            ));
        }
        pairs.extend(metrics().snapshot_counters("service.server."));
        pairs.extend(metrics().snapshot_counters("service.registry."));
        pairs.extend(metrics().snapshot_counters("service.wal."));
        for shard in &self.shards {
            let sessions: Vec<Arc<Session>> =
                shard.sessions.read().unwrap().values().cloned().collect();
            for s in sessions {
                pairs.extend(s.stats_pairs());
            }
        }
        Ok(pairs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    fn random_rows(rng: &mut Pcg64, n: usize, d: usize) -> Matrix {
        Matrix::from_fn(n, d, |_, _| rng.normal_f32())
    }

    fn score_batch(n: usize, ell: usize, start: u64) -> ScoreBatch {
        let mut zhat = Matrix::zeros(n, ell);
        for i in 0..n {
            zhat.set(i, (i + start as usize) % ell, 1.0);
        }
        ScoreBatch {
            indices: (start..start + n as u64).collect(),
            labels: vec![0; n],
            norms: vec![1.0; n],
            losses: vec![1.0; n],
            zhat,
        }
    }

    #[test]
    fn ingest_freeze_matches_local_sketch_exactly() {
        let reg = SessionRegistry::new(RegistryConfig::default());
        reg.create("s", 4, 8, 2).unwrap();
        let session = reg.get("s").unwrap();

        let mut rng = Pcg64::seeded(11);
        let a = random_rows(&mut rng, 37, 8);
        let b = random_rows(&mut rng, 21, 8);
        session.ingest(0, a.clone()).unwrap();
        session.ingest(1, b.clone()).unwrap();
        let frozen = session.freeze().unwrap();

        // Local replica of what the offline pipeline computes.
        let mut s0 = FdSketch::new(4, 8);
        let mut s1 = FdSketch::new(4, 8);
        s0.insert_batch(&a);
        s1.insert_batch(&b);
        s0.merge(&mut s1);
        assert_eq!(frozen.sketch.as_slice(), s0.sketch().as_slice());
        assert_eq!(frozen.rows_seen, 58);
        assert_eq!(frozen.shrinks, s0.shrink_count());
    }

    #[test]
    fn freeze_is_idempotent_and_blocks_ingest() {
        let reg = SessionRegistry::new(RegistryConfig::default());
        reg.create("s", 2, 4, 1).unwrap();
        let session = reg.get("s").unwrap();
        session.ingest(0, Matrix::from_fn(3, 4, |r, c| (r + c) as f32)).unwrap();
        let f1 = session.freeze().unwrap();
        let f2 = session.freeze().unwrap();
        assert_eq!(f1.sketch.as_slice(), f2.sketch.as_slice());
        let err = session
            .ingest(0, Matrix::zeros(1, 4))
            .unwrap_err();
        assert!(err.contains("frozen"), "{err}");
    }

    #[test]
    fn admission_control_rejects_and_recovers_budget() {
        let cfg = RegistryConfig {
            max_sessions: 1,
            ..Default::default()
        };
        let reg = SessionRegistry::new(cfg);
        reg.create("a", 2, 4, 1).unwrap();
        let err = reg.create("b", 2, 4, 1).unwrap_err();
        assert!(err.contains("admission"), "{err}");
        reg.close("a").unwrap();
        reg.create("b", 2, 4, 1).unwrap();

        let tiny = RegistryConfig {
            max_resident_bytes: 100,
            ..Default::default()
        };
        let reg2 = SessionRegistry::new(tiny);
        // 1 shard × 2·2·4·4 = 64 bytes fits; a second does not.
        reg2.create("x", 2, 4, 1).unwrap();
        let err2 = reg2.create("y", 2, 4, 1).unwrap_err();
        assert!(err2.contains("admission"), "{err2}");
    }

    #[test]
    fn byte_budget_is_exact_under_concurrency() {
        let budget = Arc::new(ByteBudget::new(1000));
        std::thread::scope(|scope| {
            for _ in 0..8 {
                let budget = budget.clone();
                scope.spawn(move || {
                    for _ in 0..1000 {
                        if budget.reserve(7) {
                            budget.release(7);
                        }
                    }
                });
            }
        });
        assert_eq!(budget.used(), 0);
        assert!(budget.reserve(1000));
        assert!(!budget.reserve(1));
        budget.release(2000); // saturates, no underflow
        assert_eq!(budget.used(), 0);
    }

    #[test]
    fn sessions_spread_across_registry_shards() {
        let reg = SessionRegistry::new(RegistryConfig::default());
        assert_eq!(reg.registry_shards(), 8);
        for i in 0..16 {
            reg.create(&format!("spread-{i}"), 2, 4, 1).unwrap();
        }
        assert_eq!(reg.session_count(), 16);
        let pairs = reg.stats_pairs("").unwrap();
        let occupied = (0..reg.registry_shards())
            .filter(|i| {
                pairs
                    .iter()
                    .any(|(n, v)| n == &format!("service.registry.shard.{i}.sessions") && *v > 0)
            })
            .count();
        // FNV spreads 16 names over 8 shards; ≥2 occupied is guaranteed
        // unless the hash is catastrophically broken.
        assert!(occupied >= 2, "only {occupied} shards occupied");
        // Per-shard counters sum to the global count.
        let total: u64 = pairs
            .iter()
            .filter(|(n, _)| {
                n.starts_with("service.registry.shard.") && n.ends_with(".sessions")
            })
            .map(|(_, v)| *v)
            .sum();
        assert_eq!(total, 16);
        // Closing releases the right shard's accounting.
        for i in 0..16 {
            reg.close(&format!("spread-{i}")).unwrap();
        }
        assert_eq!(reg.session_count(), 0);
        assert_eq!(reg.resident_bytes(), 0);
        assert_eq!(reg.scorer_bytes(), 0);
    }

    #[test]
    fn shard_count_is_normalized_to_power_of_two() {
        assert_eq!(normalize_shard_count(0), 1);
        assert_eq!(normalize_shard_count(1), 1);
        assert_eq!(normalize_shard_count(5), 8);
        assert_eq!(normalize_shard_count(8), 8);
        assert_eq!(normalize_shard_count(1000), 256);
        let reg = SessionRegistry::new(RegistryConfig {
            registry_shards: 3,
            ..Default::default()
        });
        assert_eq!(reg.registry_shards(), 4);
        let name = "anywhere";
        assert!(reg.shard_index(name) < 4);
    }

    #[test]
    fn bad_inputs_are_rejected_loudly() {
        let reg = SessionRegistry::new(RegistryConfig::default());
        assert!(reg.create("bad name!", 2, 4, 1).is_err());
        assert!(reg.create("ok", 0, 4, 1).is_err());
        reg.create("ok", 2, 4, 2).unwrap();
        let s = reg.get("ok").unwrap();
        assert!(s.ingest(5, Matrix::zeros(1, 4)).is_err()); // shard range
        assert!(s.ingest(0, Matrix::zeros(1, 3)).is_err()); // dim
        assert!(s.score(0, &ScoreBatch {
            indices: vec![0],
            labels: vec![0],
            norms: vec![1.0],
            losses: vec![1.0],
            zhat: Matrix::zeros(1, 2),
        })
        .is_err()); // not frozen
        assert!(reg.get("missing").is_err());
        assert!(reg.close("missing").is_err());
    }

    #[test]
    fn duplicate_session_rejected() {
        let reg = SessionRegistry::new(RegistryConfig::default());
        reg.create("dup", 2, 4, 1).unwrap();
        assert!(reg.create("dup", 2, 4, 1).unwrap_err().contains("exists"));
        // The failed create must not leak budget.
        assert_eq!(reg.session_count(), 1);
        reg.close("dup").unwrap();
        assert_eq!(reg.session_count(), 0);
        assert_eq!(reg.scorer_bytes(), 0);
    }

    #[test]
    fn stats_pairs_report_progress() {
        let reg = SessionRegistry::new(RegistryConfig::default());
        reg.create("st", 2, 4, 1).unwrap();
        let s = reg.get("st").unwrap();
        s.ingest(0, Matrix::from_fn(5, 4, |r, c| (r * 4 + c) as f32))
            .unwrap();
        s.freeze().unwrap();
        let pairs = s.stats_pairs();
        let get = |k: &str| {
            pairs
                .iter()
                .find(|(n, _)| n.ends_with(k))
                .map(|(_, v)| *v)
                .unwrap()
        };
        assert_eq!(get(".rows_enqueued"), 5);
        assert_eq!(get(".rows_applied"), 5);
        assert_eq!(get(".frozen"), 1);
        assert_eq!(get(".spilled"), 0);
        let all = reg.stats_pairs("").unwrap();
        assert!(all.iter().any(|(n, v)| n == "service.registry.sessions" && *v == 1));
        assert!(all
            .iter()
            .any(|(n, _)| n == "service.registry.max_scorer_bytes"));
        assert!(all.iter().any(|(n, _)| n == "service.registry.shards"));
        // Kernel-tier audit rows: tier index matches the registry's own
        // backend, and the capability flags are 0/1.
        let tier = all
            .iter()
            .find(|(n, _)| n == "service.registry.kernel_tier")
            .map(|(_, v)| *v)
            .unwrap();
        assert_eq!(tier, reg.compute.dispatch().tier().index());
        for flag in ["service.registry.kernel_avx2", "service.registry.kernel_simd_available"] {
            let v = all.iter().find(|(n, _)| n == flag).map(|(_, v)| *v).unwrap();
            assert!(v <= 1, "{flag} must be a 0/1 flag, got {v}");
        }
    }

    #[test]
    fn scorer_budget_admission_create_and_score_time() {
        // ℓ=4: baseline 8ℓ = 32 bytes per shard slot; entries cost
        // ENTRY_BYTES + 4ℓ = 40 bytes each. Cap 100 fits one 1-shard
        // session (32) + one entry (40) but not a 4-shard session (128)
        // or a second entry (112 > 100).
        let reg = SessionRegistry::new(RegistryConfig {
            max_scorer_bytes: 100,
            ..Default::default()
        });
        let err = reg.create("big", 4, 8, 4).unwrap_err();
        assert!(err.contains("scorer"), "{err}");
        assert_eq!(reg.scorer_bytes(), 0); // nothing leaked

        reg.create("ok", 4, 8, 1).unwrap();
        assert_eq!(reg.scorer_bytes(), 32);
        let s = reg.get("ok").unwrap();
        s.ingest(0, Matrix::from_fn(2, 8, |r, c| (r + c) as f32))
            .unwrap();
        s.freeze().unwrap();
        s.score(0, &score_batch(1, 4, 0)).unwrap();
        assert_eq!(reg.scorer_bytes(), 72);
        let err2 = s.score(0, &score_batch(1, 4, 1)).unwrap_err();
        assert!(err2.starts_with(SCORER_ADMISSION), "{err2}");
        assert_eq!(reg.scorer_bytes(), 72); // rejected batch left no state

        // Finalizing shrinks the accounted footprint (cache ≤ raw).
        let (idx, _) = s.top_k(Method::Sage, 1, 2, 0).unwrap();
        assert_eq!(idx.len(), 1);
        assert!(reg.scorer_bytes() < 72, "{}", reg.scorer_bytes());

        // Closing releases everything.
        drop(s);
        reg.close("ok").unwrap();
        assert_eq!(reg.scorer_bytes(), 0);
    }

    #[test]
    fn spill_on_pressure_frees_reloads_and_preserves_ranks() {
        let dir = std::env::temp_dir().join(format!("sage_spill_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        // Cap 200, ℓ=4 (baseline 32, entry 40): session A with 3 entries
        // is resident at 152; creating B adds 32 (184); B's first scored
        // entry (40) would hit 224 > 200 and must spill A.
        let reg = SessionRegistry::new(RegistryConfig {
            max_scorer_bytes: 200,
            checkpoint_dir: Some(dir.clone()),
            ..Default::default()
        });
        for name in ["a", "b"] {
            reg.create(name, 4, 8, 1).unwrap();
            let s = reg.get(name).unwrap();
            s.ingest(0, Matrix::from_fn(2, 8, |r, c| (r + c) as f32))
                .unwrap();
            s.freeze().unwrap();
        }
        reg.score("a", 0, &score_batch(3, 4, 0)).unwrap();
        assert_eq!(reg.scorer_bytes(), 152 + 32);

        // Expected ranks for A, computed on a local replica.
        let expected = {
            let mut local = AgreementScorer::new(4);
            let b = score_batch(3, 4, 0);
            let idx: Vec<usize> = b.indices.iter().map(|&i| i as usize).collect();
            local.add_batch(&idx, &b.labels, &b.zhat, &b.norms, &b.losses);
            let scores = local.finalize();
            let inputs = SelectionInputs {
                scores: &scores,
                val_consensus: None,
                num_classes: 2,
                seed: 0,
                compute: &crate::tensor::SerialBackend,
            };
            select_weighted(Method::Sage, &inputs, 2).0
        };

        // B's score triggers the spill of A (the least-recently-active
        // session holding scored state).
        reg.score("b", 0, &score_batch(1, 4, 0)).unwrap();
        let a = reg.get("a").unwrap();
        assert!(a.is_spilled());
        assert_eq!(a.scorer_bytes(), 0);
        assert!(dir.join("a.sagesess").exists());

        // TopK on A transparently reloads its state (spilling B in turn)
        // and returns the same ranks as the never-spilled replica. The
        // transient spill file is consumed by the reload — it must not
        // linger to resurrect stale state after a restart.
        let (idx, _) = reg.top_k("a", Method::Sage, 2, 2, 0).unwrap();
        assert_eq!(idx, expected);
        assert!(!reg.get("a").unwrap().is_spilled());
        assert!(!dir.join("a.sagesess").exists());
        assert!(reg.get("b").unwrap().is_spilled());
        assert!(dir.join("b.sagesess").exists());

        // And B reloads the same way for its own query (re-spilling A).
        let (idx_b, _) = reg.top_k("b", Method::Sage, 1, 2, 0).unwrap();
        assert_eq!(idx_b.len(), 1);
        assert!(!dir.join("b.sagesess").exists());
        assert!(reg.get("a").unwrap().is_spilled());

        // Closing a spilled-but-never-checkpointed session removes its
        // spill file: a restart must not resurrect a closed session.
        reg.close("a").unwrap();
        assert!(!dir.join("a.sagesess").exists());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn spill_without_checkpoint_dir_is_a_final_rejection() {
        let reg = SessionRegistry::new(RegistryConfig {
            max_scorer_bytes: 100,
            ..Default::default()
        });
        reg.create("only", 4, 8, 1).unwrap();
        let s = reg.get("only").unwrap();
        s.ingest(0, Matrix::from_fn(2, 8, |r, c| (r + c) as f32))
            .unwrap();
        s.freeze().unwrap();
        reg.score("only", 0, &score_batch(1, 4, 0)).unwrap();
        let err = reg.score("only", 0, &score_batch(1, 4, 1)).unwrap_err();
        assert!(err.starts_with(SCORER_ADMISSION), "{err}");
    }

    #[test]
    fn checkpoint_restores_scorer_state_bit_exactly() {
        let dir = std::env::temp_dir().join(format!("sage_reg_ckpt_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let cfg = RegistryConfig {
            checkpoint_dir: Some(dir.clone()),
            ..Default::default()
        };
        let reg = SessionRegistry::new(cfg.clone());
        reg.create("ck", 4, 8, 2).unwrap();
        let s = reg.get("ck").unwrap();
        let mut rng = Pcg64::seeded(3);
        s.ingest(0, random_rows(&mut rng, 12, 8)).unwrap();
        s.ingest(1, random_rows(&mut rng, 9, 8)).unwrap();
        s.freeze().unwrap();
        s.score(0, &score_batch(4, 4, 0)).unwrap();
        s.score(1, &score_batch(3, 4, 4)).unwrap();
        reg.checkpoint("ck").unwrap();
        let (expected, _) = s.top_k(Method::Sage, 3, 2, 7).unwrap();
        drop(s);

        let reg2 = SessionRegistry::new(cfg);
        assert_eq!(reg2.recover(&dir), 1);
        let (got, _) = reg2.top_k("ck", Method::Sage, 3, 2, 7).unwrap();
        assert_eq!(got, expected);
        // Recovered scorer bytes are accounted.
        assert!(reg2.scorer_bytes() > 0);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn wal_replay_after_drop_is_bit_exact() {
        let dir = std::env::temp_dir().join(format!("sage_reg_wal_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let cfg = RegistryConfig {
            checkpoint_dir: Some(dir.clone()),
            durability: Durability::Sync,
            ..Default::default()
        };
        let reg = SessionRegistry::new(cfg.clone());
        assert_eq!(reg.open_wal().unwrap(), 0);
        reg.create("w", 4, 8, 2).unwrap();
        let mut rng = Pcg64::seeded(9);
        reg.ingest("w", 0, random_rows(&mut rng, 12, 8)).unwrap();
        reg.ingest("w", 1, random_rows(&mut rng, 7, 8)).unwrap();
        reg.freeze("w").unwrap();
        reg.score("w", 0, &score_batch(4, 4, 0)).unwrap();
        reg.score("w", 1, &score_batch(3, 4, 4)).unwrap();
        let (expected, _) = reg.top_k("w", Method::Sage, 3, 2, 7).unwrap();
        // A created-then-closed session must not resurrect on replay.
        reg.create("gone", 2, 4, 1).unwrap();
        reg.close("gone").unwrap();
        let live = reg.get("w").unwrap().to_checkpoint().unwrap();
        assert!(live.wal_seq > 0, "live state should carry a watermark");
        drop(reg);

        // Simulated crash: no checkpoint was ever written, so recovery
        // finds nothing and replay rebuilds everything from the log alone.
        let reg2 = SessionRegistry::new(cfg);
        assert_eq!(reg2.recover(&dir), 0, "no .sagesess files expected");
        assert!(reg2.open_wal().unwrap() >= live.wal_seq);
        assert!(reg2.get("gone").is_err(), "closed session resurrected");
        let replayed = reg2.get("w").unwrap().to_checkpoint().unwrap();
        assert_eq!(replayed, live, "replayed state must be bit-exact");
        let (got, _) = reg2.top_k("w", Method::Sage, 3, 2, 7).unwrap();
        assert_eq!(got, expected);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn wal_replay_skips_records_covered_by_a_checkpoint() {
        let dir = std::env::temp_dir().join(format!("sage_reg_walck_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let cfg = RegistryConfig {
            checkpoint_dir: Some(dir.clone()),
            durability: Durability::Sync,
            ..Default::default()
        };
        let reg = SessionRegistry::new(cfg.clone());
        reg.open_wal().unwrap();
        reg.create("c", 4, 8, 1).unwrap();
        let mut rng = Pcg64::seeded(4);
        reg.ingest("c", 0, random_rows(&mut rng, 10, 8)).unwrap();
        let (_, ck_seq) = reg.checkpoint("c").unwrap();
        assert!(ck_seq > 0);
        // One more batch after the checkpoint: replay must apply exactly
        // this record on top of the image — not the pre-checkpoint ones
        // (double-applying an ingest would visibly change rows_seen).
        reg.ingest("c", 0, random_rows(&mut rng, 5, 8)).unwrap();
        reg.freeze("c").unwrap();
        let live = reg.get("c").unwrap().to_checkpoint().unwrap();
        drop(reg);

        let reg2 = SessionRegistry::new(cfg);
        assert_eq!(reg2.recover(&dir), 1);
        reg2.open_wal().unwrap();
        let replayed = reg2.get("c").unwrap().to_checkpoint().unwrap();
        assert_eq!(replayed, live);
        let frozen = reg2.get("c").unwrap().freeze().unwrap();
        assert_eq!(frozen.rows_seen, 15);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn acked_writes_survive_a_compact_then_restart_cycle() {
        // Compaction deletes every sealed segment, so a restart may find
        // no surviving records while the checkpoints carry high `wal_seq`
        // watermarks. The sequence counter must resume above them: a
        // fresh acked record with a seq at or below a watermark would be
        // silently skipped by the next replay — a lost durable write.
        let dir = std::env::temp_dir().join(format!("sage_reg_walcycle_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let cfg = RegistryConfig {
            checkpoint_dir: Some(dir.clone()),
            durability: Durability::Sync,
            wal_compact_bytes: 256,
            ..Default::default()
        };
        let mut rng = Pcg64::seeded(11);
        let reg = SessionRegistry::new(cfg.clone());
        reg.open_wal().unwrap();
        reg.create("s", 4, 8, 1).unwrap();
        // Big enough to cross --wal-compact-mb: the inline compaction
        // checkpoints the session and deletes the sealed segments.
        reg.ingest("s", 0, random_rows(&mut rng, 20, 8)).unwrap();
        drop(reg);

        let reg2 = SessionRegistry::new(cfg.clone());
        assert_eq!(reg2.recover(&dir), 1);
        let watermark = reg2.get("s").unwrap().wal_watermark();
        assert!(watermark > 0, "checkpoint should carry a watermark");
        assert!(
            reg2.open_wal().unwrap() >= watermark,
            "seq counter must resume above the recovered watermark"
        );
        // A small acked ingest that does NOT trigger another compaction
        // (so only its WAL record, not a checkpoint, makes it durable).
        reg2.ingest("s", 0, random_rows(&mut rng, 2, 8)).unwrap();
        let live = reg2.get("s").unwrap().to_checkpoint().unwrap();
        assert!(live.wal_seq > watermark);
        drop(reg2);

        let reg3 = SessionRegistry::new(cfg);
        assert_eq!(reg3.recover(&dir), 1);
        reg3.open_wal().unwrap();
        let replayed = reg3.get("s").unwrap().to_checkpoint().unwrap();
        assert_eq!(
            replayed, live,
            "acked post-compaction ingest was lost on replay"
        );
        assert_eq!(reg3.get("s").unwrap().freeze().unwrap().rows_seen, 22);
        let _ = std::fs::remove_dir_all(&dir);
    }
}
