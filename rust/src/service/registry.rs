//! Concurrent session registry — the server-side state of `sage-serve`.
//!
//! A [`Session`] promotes the pipeline's shard-local FD sketches from local
//! variables to a served, sessioned resource: `shards` independent sketch
//! slots fed through ONE bounded ingest channel (backpressure: producers
//! block when the queue is full; the per-session ingest worker drains it),
//! then frozen by merging the shard sketches **in shard order** — exactly
//! the merge `pipeline::run_selection` performs, so a session fed the same
//! gradient stream produces a byte-identical sketch. Phase-II scoring
//! accumulates per-shard [`AgreementScorer`]s the same way, making served
//! TopK queries reproduce offline selection exactly.
//!
//! The [`SessionRegistry`] enforces admission control (max sessions, max
//! resident ℓ×D sketch bytes) and owns persistence/recovery through
//! `service::checkpoint`.
//!
//! Determinism contract: one producer per shard slot. Concurrent producers
//! on the *same* shard are accepted but interleave nondeterministically.

use super::checkpoint::SessionCheckpoint;
use super::protocol::{FrozenSketch, ScoreBatch};
use crate::baselines::{select_weighted, SelectionInputs};
use crate::config::Method;
use crate::selection::{AgreementScorer, Scores};
use crate::sketch::{FdSketch, SketchState};
use crate::tensor::Matrix;
use crate::util::channel::{bounded, Sender};
use crate::util::metrics::{global as metrics, Counter};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

/// Registry knobs (admission control + backpressure depth).
#[derive(Clone, Debug)]
pub struct RegistryConfig {
    /// Maximum concurrently resident sessions.
    pub max_sessions: usize,
    /// Maximum total resident sketch-buffer bytes across sessions
    /// (each session accounts `shards × 2ℓ × D × 4`).
    pub max_resident_bytes: usize,
    /// Bounded ingest queue depth per session (backpressure).
    pub ingest_queue_depth: usize,
    /// Where `Checkpoint` ops persist sessions (None = op disabled).
    pub checkpoint_dir: Option<PathBuf>,
}

impl Default for RegistryConfig {
    fn default() -> Self {
        Self {
            max_sessions: 64,
            max_resident_bytes: 1 << 30,
            ingest_queue_depth: 8,
            checkpoint_dir: None,
        }
    }
}

/// Per-session counters, reported by the `Stats` wire op (prefixed
/// `service.session.<name>.` in the response). Fleet-wide aggregates go to
/// the global metrics registry under fixed `service.*` names instead —
/// global counter names are interned forever, so they never embed
/// client-chosen session names.
#[derive(Default)]
pub struct SessionStats {
    pub rows_enqueued: AtomicU64,
    pub rows_applied: AtomicU64,
    pub batches: AtomicU64,
    pub merges: AtomicU64,
    pub scored_entries: AtomicU64,
    pub topk_queries: AtomicU64,
}

type IngestMsg = (usize, Matrix);

/// Hard caps on session shape. The protocol carries `ell`/`d`/`shards` as
/// u32, so admission math must be overflow-proof against hostile values;
/// under these caps `shards × 2ℓ × D × 4` stays well below `usize::MAX`.
pub const MAX_ELL: usize = 1 << 16;
pub const MAX_DIM: usize = 1 << 28;
pub const MAX_SHARDS: usize = 4096;

/// Validated resident-byte cost of a session (`shards × 2ℓ × D × 4`).
fn session_bytes(ell: usize, d: usize, shards: usize) -> Result<usize, String> {
    if ell == 0 || d == 0 || shards == 0 {
        return Err("ell, d and shards must all be positive".into());
    }
    if ell > MAX_ELL || d > MAX_DIM || shards > MAX_SHARDS {
        return Err(format!(
            "session shape rejected: ell {ell} (max {MAX_ELL}), d {d} (max {MAX_DIM}), \
             shards {shards} (max {MAX_SHARDS})"
        ));
    }
    shards
        .checked_mul(2)
        .and_then(|v| v.checked_mul(ell))
        .and_then(|v| v.checked_mul(d))
        .and_then(|v| v.checked_mul(4))
        .ok_or_else(|| "session byte accounting overflow".to_string())
}

/// One served sketch session.
pub struct Session {
    name: String,
    ell: usize,
    d: usize,
    shards: usize,
    ingest_tx: Mutex<Option<Sender<IngestMsg>>>,
    worker: Mutex<Option<JoinHandle<()>>>,
    sketches: Arc<Mutex<Vec<FdSketch>>>,
    frozen: Mutex<Option<FrozenSketch>>,
    scorers: Mutex<Vec<Option<AgreementScorer>>>,
    scores: Mutex<Option<Scores>>,
    stats: Arc<SessionStats>,
    /// Fleet-wide aggregates (fixed names — global counters are interned
    /// forever, so they must NOT embed client-chosen session names).
    c_rows: &'static Counter,
    c_batches: &'static Counter,
    c_scored: &'static Counter,
}

impl Session {
    /// New active session with per-shard sketches and a running ingest
    /// worker fed by a bounded channel.
    fn new_active(
        name: &str,
        ell: usize,
        d: usize,
        shards: usize,
        queue_depth: usize,
        shard_sketches: Vec<FdSketch>,
    ) -> Session {
        debug_assert_eq!(shard_sketches.len(), shards);
        let stats = Arc::new(SessionStats::default());
        let sketches = Arc::new(Mutex::new(shard_sketches));
        let (tx, rx) = bounded::<IngestMsg>(queue_depth.max(1));
        let w_sketches = sketches.clone();
        let w_stats = stats.clone();
        let c_rows_applied = metrics().counter("service.ingest.rows_applied");
        let worker = std::thread::spawn(move || {
            // close-then-drain: after Freeze closes the channel, recv keeps
            // returning queued batches until empty, so no acked ingest is
            // ever lost (see util::channel close semantics).
            while let Some((shard, rows)) = rx.recv() {
                let n = rows.rows() as u64;
                w_sketches.lock().unwrap()[shard].insert_batch(&rows);
                w_stats.rows_applied.fetch_add(n, Ordering::Relaxed);
                c_rows_applied.add(n);
            }
        });
        Session {
            name: name.to_string(),
            ell,
            d,
            shards,
            ingest_tx: Mutex::new(Some(tx)),
            worker: Mutex::new(Some(worker)),
            sketches,
            frozen: Mutex::new(None),
            scorers: Mutex::new((0..shards).map(|_| Some(AgreementScorer::new(ell))).collect()),
            scores: Mutex::new(None),
            stats,
            c_rows: metrics().counter("service.ingest.rows_enqueued"),
            c_batches: metrics().counter("service.ingest.batches"),
            c_scored: metrics().counter("service.score.entries"),
        }
    }

    /// Rebuild an already-frozen session (checkpoint recovery): no ingest
    /// worker, scoring starts fresh against the recovered sketch.
    fn new_frozen(name: &str, ell: usize, d: usize, shards: usize, info: FrozenSketch) -> Session {
        Session {
            name: name.to_string(),
            ell,
            d,
            shards,
            ingest_tx: Mutex::new(None),
            worker: Mutex::new(None),
            sketches: Arc::new(Mutex::new(Vec::new())),
            frozen: Mutex::new(Some(info)),
            scorers: Mutex::new((0..shards).map(|_| Some(AgreementScorer::new(ell))).collect()),
            scores: Mutex::new(None),
            stats: Arc::new(SessionStats::default()),
            c_rows: metrics().counter("service.ingest.rows_enqueued"),
            c_batches: metrics().counter("service.ingest.batches"),
            c_scored: metrics().counter("service.score.entries"),
        }
    }

    pub fn name(&self) -> &str {
        &self.name
    }

    pub fn ell(&self) -> usize {
        self.ell
    }

    pub fn dim(&self) -> usize {
        self.d
    }

    pub fn shards(&self) -> usize {
        self.shards
    }

    /// Resident sketch-buffer bytes this session accounts for (shapes are
    /// validated at admission, so this cannot overflow; saturate anyway).
    pub fn resident_bytes(&self) -> usize {
        self.shards
            .saturating_mul(2)
            .saturating_mul(self.ell)
            .saturating_mul(self.d)
            .saturating_mul(4)
    }

    pub fn is_frozen(&self) -> bool {
        self.frozen.lock().unwrap().is_some()
    }

    /// Enqueue raw gradient rows into one shard slot. Blocks when the
    /// bounded ingest queue is full (backpressure propagates to the TCP
    /// connection). Returns total rows acked so far.
    pub fn ingest(&self, shard: usize, rows: Matrix) -> Result<u64, String> {
        if shard >= self.shards {
            return Err(format!(
                "shard {shard} out of range (session '{}' has {} shards)",
                self.name, self.shards
            ));
        }
        if rows.cols() != self.d {
            return Err(format!(
                "ingest rows have {} cols, session dim is {}",
                rows.cols(),
                self.d
            ));
        }
        let tx = match self.ingest_tx.lock().unwrap().as_ref() {
            Some(tx) => tx.clone(),
            None => return Err(format!("session '{}' is frozen", self.name)),
        };
        let n = rows.rows() as u64;
        tx.send((shard, rows))
            .map_err(|_| format!("session '{}' was frozen during ingest", self.name))?;
        self.c_rows.add(n);
        self.c_batches.inc();
        self.stats.batches.fetch_add(1, Ordering::Relaxed);
        Ok(self.stats.rows_enqueued.fetch_add(n, Ordering::Relaxed) + n)
    }

    /// Merge a client-side FD sketch into one shard slot (FD mergeability:
    /// the combined guarantee degrades by at most the sum of both
    /// certificates). Deterministic for a fixed call sequence.
    pub fn merge_sketch(&self, shard: usize, state: &SketchState) -> Result<(), String> {
        if shard >= self.shards {
            return Err(format!("shard {shard} out of range"));
        }
        if state.d as usize != self.d {
            return Err(format!(
                "sketch state dim {} != session dim {}",
                state.d, self.d
            ));
        }
        let mut other = FdSketch::from_state(state)?;
        let mut guard = self.sketches.lock().unwrap();
        if guard.is_empty() {
            return Err(format!("session '{}' is frozen", self.name));
        }
        guard[shard].merge(&mut other);
        drop(guard);
        self.stats.merges.fetch_add(1, Ordering::Relaxed);
        metrics().counter("service.merge.requests").inc();
        Ok(())
    }

    /// Freeze: stop ingest, drain the queue (close-then-drain), join the
    /// worker, merge shard sketches in shard order, cache the frozen S.
    /// Idempotent — every scoring client calls it to fetch S.
    pub fn freeze(&self) -> Result<FrozenSketch, String> {
        let mut guard = self.frozen.lock().unwrap();
        if let Some(info) = guard.as_ref() {
            return Ok(info.clone());
        }
        if let Some(tx) = self.ingest_tx.lock().unwrap().take() {
            tx.close();
        }
        if let Some(worker) = self.worker.lock().unwrap().take() {
            worker
                .join()
                .map_err(|_| format!("session '{}': ingest worker panicked", self.name))?;
        }
        let mut shard_sketches = {
            let mut g = self.sketches.lock().unwrap();
            std::mem::take(&mut *g)
        };
        if shard_sketches.is_empty() {
            return Err(format!("session '{}' has no sketch state", self.name));
        }
        // Same merge the offline pipeline performs: base = shard 0 (NOT an
        // empty sketch — that would pre-shrink shard 0 and change the
        // result), then fold the rest in shard order.
        let mut merged = shard_sketches.remove(0);
        for mut s in shard_sketches {
            merged.merge(&mut s);
        }
        let sketch = merged.sketch();
        let info = FrozenSketch {
            sketch,
            shift_bound: merged.shift_bound(),
            shrinks: merged.shrink_count(),
            rows_seen: merged.rows_seen(),
            sketch_bytes: merged.memory_bytes() as u64,
        };
        *guard = Some(info.clone());
        Ok(info)
    }

    /// Accumulate one Phase-II scoring batch into a shard's scorer.
    pub fn score(&self, shard: usize, batch: &ScoreBatch) -> Result<(), String> {
        if shard >= self.shards {
            return Err(format!("shard {shard} out of range"));
        }
        if self.frozen.lock().unwrap().is_none() {
            return Err(format!(
                "session '{}': Score requires Freeze first",
                self.name
            ));
        }
        let n = batch.indices.len();
        if batch.labels.len() != n
            || batch.norms.len() != n
            || batch.losses.len() != n
            || batch.zhat.rows() != n
        {
            return Err("score batch: field lengths disagree".into());
        }
        if batch.zhat.cols() != self.ell {
            return Err(format!(
                "score batch: projections have dim {}, session ℓ is {}",
                batch.zhat.cols(),
                self.ell
            ));
        }
        let indices: Vec<usize> = batch.indices.iter().map(|&i| i as usize).collect();
        let mut guard = self.scorers.lock().unwrap();
        match guard[shard].as_mut() {
            Some(scorer) => {
                scorer.add_batch(&indices, &batch.labels, &batch.zhat, &batch.norms, &batch.losses);
            }
            None => {
                return Err(format!(
                    "session '{}': scores already finalized",
                    self.name
                ))
            }
        }
        drop(guard);
        self.stats
            .scored_entries
            .fetch_add(n as u64, Ordering::Relaxed);
        self.c_scored.add(n as u64);
        Ok(())
    }

    /// Online selection query: finalize scores on first call (merging
    /// shard scorers in shard order — the offline merge), then run the
    /// selection rule. Repeated queries with different `(method, k)` reuse
    /// the cached scores.
    pub fn top_k(
        &self,
        method: Method,
        k: usize,
        num_classes: usize,
        seed: u64,
    ) -> Result<(Vec<usize>, Option<Vec<f32>>), String> {
        if self.frozen.lock().unwrap().is_none() {
            return Err(format!(
                "session '{}': TopK requires Freeze first",
                self.name
            ));
        }
        if method == Method::Glister {
            return Err("GLISTER needs a validation split; unsupported by the service".into());
        }
        let mut cache = self.scores.lock().unwrap();
        if cache.is_none() {
            let mut slots = self.scorers.lock().unwrap();
            let total: u64 = slots
                .iter()
                .map(|s| s.as_ref().map(|sc| sc.count()).unwrap_or(0))
                .sum();
            if total == 0 {
                return Err(format!(
                    "session '{}': no scored examples — run Score first",
                    self.name
                ));
            }
            let mut acc: Option<AgreementScorer> = None;
            for slot in slots.iter_mut() {
                let scorer = slot
                    .take()
                    .ok_or_else(|| "scorer state missing".to_string())?;
                acc = Some(match acc {
                    None => scorer,
                    Some(mut merged) => {
                        merged.merge(scorer);
                        merged
                    }
                });
            }
            drop(slots);
            let scores = acc
                .ok_or_else(|| "session has no shards".to_string())?
                .finalize();
            *cache = Some(scores);
        }
        let scores = cache.as_ref().unwrap();
        let inputs = SelectionInputs {
            scores,
            val_consensus: None,
            num_classes,
            seed,
        };
        self.stats.topk_queries.fetch_add(1, Ordering::Relaxed);
        Ok(select_weighted(method, &inputs, k))
    }

    /// Counter snapshot for the `Stats` wire op.
    pub fn stats_pairs(&self) -> Vec<(String, u64)> {
        let p = format!("service.session.{}", self.name);
        let s = &self.stats;
        vec![
            (format!("{p}.ell"), self.ell as u64),
            (format!("{p}.d"), self.d as u64),
            (format!("{p}.shards"), self.shards as u64),
            (format!("{p}.resident_bytes"), self.resident_bytes() as u64),
            (format!("{p}.frozen"), u64::from(self.is_frozen())),
            (
                format!("{p}.rows_enqueued"),
                s.rows_enqueued.load(Ordering::Relaxed),
            ),
            (
                format!("{p}.rows_applied"),
                s.rows_applied.load(Ordering::Relaxed),
            ),
            (format!("{p}.batches"), s.batches.load(Ordering::Relaxed)),
            (format!("{p}.merges"), s.merges.load(Ordering::Relaxed)),
            (
                format!("{p}.scored_entries"),
                s.scored_entries.load(Ordering::Relaxed),
            ),
            (
                format!("{p}.topk_queries"),
                s.topk_queries.load(Ordering::Relaxed),
            ),
        ]
    }

    /// Block until every acked ingest batch has been applied to its shard
    /// sketch (bounded wait) — checkpoint consistency helper.
    fn quiesce(&self, timeout: std::time::Duration) -> Result<(), String> {
        let start = std::time::Instant::now();
        loop {
            let enq = self.stats.rows_enqueued.load(Ordering::Relaxed);
            let app = self.stats.rows_applied.load(Ordering::Relaxed);
            if app >= enq {
                return Ok(());
            }
            if start.elapsed() > timeout {
                return Err(format!(
                    "session '{}': quiesce timed out ({app}/{enq} rows applied)",
                    self.name
                ));
            }
            std::thread::sleep(std::time::Duration::from_millis(2));
        }
    }

    /// Snapshot into a checkpoint (quiesces acked ingest first).
    pub fn to_checkpoint(&self) -> Result<SessionCheckpoint, String> {
        self.quiesce(std::time::Duration::from_secs(10))?;
        let frozen = self.frozen.lock().unwrap().clone();
        let shard_states = if frozen.is_some() {
            Vec::new()
        } else {
            let guard = self.sketches.lock().unwrap();
            guard.iter().map(|s| s.export_state()).collect()
        };
        Ok(SessionCheckpoint {
            name: self.name.clone(),
            ell: self.ell as u32,
            d: self.d as u32,
            shards: self.shards as u32,
            shard_states,
            frozen,
        })
    }

    /// Rebuild from a checkpoint (inverse of [`Session::to_checkpoint`]).
    fn from_checkpoint(ck: &SessionCheckpoint, queue_depth: usize) -> Result<Session, String> {
        let (ell, d, shards) = (ck.ell as usize, ck.d as usize, ck.shards as usize);
        session_bytes(ell, d, shards)?; // validate recovered shapes too
        if let Some(frozen) = &ck.frozen {
            return Ok(Session::new_frozen(&ck.name, ell, d, shards, frozen.clone()));
        }
        if ck.shard_states.len() != shards {
            return Err(format!(
                "checkpoint '{}': {} shard states for {} shards",
                ck.name,
                ck.shard_states.len(),
                shards
            ));
        }
        let mut sketches = Vec::with_capacity(shards);
        for st in &ck.shard_states {
            if st.ell as usize != ell || st.d as usize != d {
                return Err(format!("checkpoint '{}': shard state dims drift", ck.name));
            }
            sketches.push(FdSketch::from_state(st)?);
        }
        Ok(Session::new_active(
            &ck.name,
            ell,
            d,
            shards,
            queue_depth,
            sketches,
        ))
    }
}

impl Drop for Session {
    fn drop(&mut self) {
        if let Some(tx) = self.ingest_tx.lock().unwrap().take() {
            tx.close();
        }
        if let Some(worker) = self.worker.lock().unwrap().take() {
            let _ = worker.join();
        }
    }
}

fn valid_session_name(name: &str) -> bool {
    !name.is_empty()
        && name.len() <= 64
        && name
            .chars()
            .all(|c| c.is_ascii_alphanumeric() || matches!(c, '.' | '_' | '-'))
}

/// Concurrent registry of live sessions with admission control.
pub struct SessionRegistry {
    cfg: RegistryConfig,
    sessions: Mutex<BTreeMap<String, Arc<Session>>>,
}

impl SessionRegistry {
    pub fn new(cfg: RegistryConfig) -> Self {
        Self {
            cfg,
            sessions: Mutex::new(BTreeMap::new()),
        }
    }

    pub fn config(&self) -> &RegistryConfig {
        &self.cfg
    }

    pub fn session_count(&self) -> usize {
        self.sessions.lock().unwrap().len()
    }

    /// Total resident sketch bytes across live sessions.
    pub fn resident_bytes(&self) -> usize {
        self.sessions
            .lock()
            .unwrap()
            .values()
            .map(|s| s.resident_bytes())
            .sum()
    }

    /// Admission-controlled session creation.
    pub fn create(&self, name: &str, ell: usize, d: usize, shards: usize) -> Result<(), String> {
        if !valid_session_name(name) {
            return Err(format!(
                "invalid session name '{name}' (want [A-Za-z0-9._-], ≤ 64 chars)"
            ));
        }
        let new_bytes = session_bytes(ell, d, shards)?;
        let mut guard = self.sessions.lock().unwrap();
        if guard.contains_key(name) {
            return Err(format!("session '{name}' already exists"));
        }
        if guard.len() >= self.cfg.max_sessions {
            return Err(format!(
                "admission rejected: {} sessions resident (max {})",
                guard.len(),
                self.cfg.max_sessions
            ));
        }
        let used: usize = guard.values().map(|s| s.resident_bytes()).sum();
        if used + new_bytes > self.cfg.max_resident_bytes {
            return Err(format!(
                "admission rejected: {new_bytes} sketch bytes would exceed budget \
                 ({used}/{} in use)",
                self.cfg.max_resident_bytes
            ));
        }
        let sketches = (0..shards).map(|_| FdSketch::new(ell, d)).collect();
        let session = Session::new_active(
            name,
            ell,
            d,
            shards,
            self.cfg.ingest_queue_depth,
            sketches,
        );
        guard.insert(name.to_string(), Arc::new(session));
        metrics().counter("service.registry.sessions_created").inc();
        Ok(())
    }

    pub fn get(&self, name: &str) -> Result<Arc<Session>, String> {
        self.sessions
            .lock()
            .unwrap()
            .get(name)
            .cloned()
            .ok_or_else(|| format!("unknown session '{name}'"))
    }

    /// Remove a session and release its admission budget. The session's
    /// ingest worker is joined by `Session::drop` once the last `Arc`
    /// reference (in-flight requests included) goes away.
    pub fn close(&self, name: &str) -> Result<(), String> {
        let removed = self.sessions.lock().unwrap().remove(name);
        match removed {
            Some(_) => {
                metrics().counter("service.registry.sessions_closed").inc();
                Ok(())
            }
            None => Err(format!("unknown session '{name}'")),
        }
    }

    /// Persist one session into the configured checkpoint directory.
    pub fn checkpoint(&self, name: &str) -> Result<PathBuf, String> {
        let dir = self
            .cfg
            .checkpoint_dir
            .as_ref()
            .ok_or_else(|| "server has no --checkpoint-dir configured".to_string())?
            .clone();
        let session = self.get(name)?;
        let ck = session.to_checkpoint()?;
        let path = dir.join(format!("{name}.sagesess"));
        ck.save(&path)?;
        metrics().counter("service.registry.checkpoints").inc();
        Ok(path)
    }

    /// Recover every `*.sagesess` session from `dir` (server restart).
    /// Returns the number of sessions recovered; unreadable files are
    /// skipped with a warning so one bad checkpoint can't block startup.
    pub fn recover(&self, dir: &Path) -> usize {
        let entries = match std::fs::read_dir(dir) {
            Ok(e) => e,
            Err(_) => return 0,
        };
        let mut recovered = 0usize;
        for entry in entries.filter_map(|e| e.ok()) {
            let path = entry.path();
            if path.extension().map(|e| e != "sagesess").unwrap_or(true) {
                continue;
            }
            match SessionCheckpoint::load(&path) {
                Ok(ck) => {
                    match Session::from_checkpoint(&ck, self.cfg.ingest_queue_depth) {
                        Ok(session) => {
                            let mut guard = self.sessions.lock().unwrap();
                            let used: usize =
                                guard.values().map(|s| s.resident_bytes()).sum();
                            if guard.len() < self.cfg.max_sessions
                                && used + session.resident_bytes()
                                    <= self.cfg.max_resident_bytes
                                && !guard.contains_key(&ck.name)
                            {
                                guard.insert(ck.name.clone(), Arc::new(session));
                                recovered += 1;
                            } else {
                                crate::log_warn!(
                                    "recovery skipped session '{}' (admission)",
                                    ck.name
                                );
                            }
                        }
                        Err(e) => {
                            crate::log_warn!("recovery: bad session in {}: {e}", path.display())
                        }
                    }
                }
                Err(e) => crate::log_warn!("recovery: unreadable {}: {e}", path.display()),
            }
        }
        recovered
    }

    /// Stats for the wire op: one session's counters, or (empty name)
    /// registry-level counters plus every session's counters.
    pub fn stats_pairs(&self, session: &str) -> Result<Vec<(String, u64)>, String> {
        if !session.is_empty() {
            return Ok(self.get(session)?.stats_pairs());
        }
        let mut pairs = vec![
            (
                "service.registry.sessions".to_string(),
                self.session_count() as u64,
            ),
            (
                "service.registry.resident_bytes".to_string(),
                self.resident_bytes() as u64,
            ),
            (
                "service.registry.max_sessions".to_string(),
                self.cfg.max_sessions as u64,
            ),
            (
                "service.registry.max_resident_bytes".to_string(),
                self.cfg.max_resident_bytes as u64,
            ),
        ];
        pairs.extend(metrics().snapshot_counters("service.server."));
        pairs.extend(metrics().snapshot_counters("service.registry."));
        let sessions: Vec<Arc<Session>> =
            self.sessions.lock().unwrap().values().cloned().collect();
        for s in sessions {
            pairs.extend(s.stats_pairs());
        }
        Ok(pairs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    fn random_rows(rng: &mut Pcg64, n: usize, d: usize) -> Matrix {
        Matrix::from_fn(n, d, |_, _| rng.normal_f32())
    }

    #[test]
    fn ingest_freeze_matches_local_sketch_exactly() {
        let reg = SessionRegistry::new(RegistryConfig::default());
        reg.create("s", 4, 8, 2).unwrap();
        let session = reg.get("s").unwrap();

        let mut rng = Pcg64::seeded(11);
        let a = random_rows(&mut rng, 37, 8);
        let b = random_rows(&mut rng, 21, 8);
        session.ingest(0, a.clone()).unwrap();
        session.ingest(1, b.clone()).unwrap();
        let frozen = session.freeze().unwrap();

        // Local replica of what the offline pipeline computes.
        let mut s0 = FdSketch::new(4, 8);
        let mut s1 = FdSketch::new(4, 8);
        s0.insert_batch(&a);
        s1.insert_batch(&b);
        s0.merge(&mut s1);
        assert_eq!(frozen.sketch.as_slice(), s0.sketch().as_slice());
        assert_eq!(frozen.rows_seen, 58);
        assert_eq!(frozen.shrinks, s0.shrink_count());
    }

    #[test]
    fn freeze_is_idempotent_and_blocks_ingest() {
        let reg = SessionRegistry::new(RegistryConfig::default());
        reg.create("s", 2, 4, 1).unwrap();
        let session = reg.get("s").unwrap();
        session.ingest(0, Matrix::from_fn(3, 4, |r, c| (r + c) as f32)).unwrap();
        let f1 = session.freeze().unwrap();
        let f2 = session.freeze().unwrap();
        assert_eq!(f1.sketch.as_slice(), f2.sketch.as_slice());
        let err = session
            .ingest(0, Matrix::zeros(1, 4))
            .unwrap_err();
        assert!(err.contains("frozen"), "{err}");
    }

    #[test]
    fn admission_control_rejects_and_recovers_budget() {
        let cfg = RegistryConfig {
            max_sessions: 1,
            ..Default::default()
        };
        let reg = SessionRegistry::new(cfg);
        reg.create("a", 2, 4, 1).unwrap();
        let err = reg.create("b", 2, 4, 1).unwrap_err();
        assert!(err.contains("admission"), "{err}");
        reg.close("a").unwrap();
        reg.create("b", 2, 4, 1).unwrap();

        let tiny = RegistryConfig {
            max_resident_bytes: 100,
            ..Default::default()
        };
        let reg2 = SessionRegistry::new(tiny);
        // 1 shard × 2·2·4·4 = 64 bytes fits; a second does not.
        reg2.create("x", 2, 4, 1).unwrap();
        let err2 = reg2.create("y", 2, 4, 1).unwrap_err();
        assert!(err2.contains("admission"), "{err2}");
    }

    #[test]
    fn bad_inputs_are_rejected_loudly() {
        let reg = SessionRegistry::new(RegistryConfig::default());
        assert!(reg.create("bad name!", 2, 4, 1).is_err());
        assert!(reg.create("ok", 0, 4, 1).is_err());
        reg.create("ok", 2, 4, 2).unwrap();
        let s = reg.get("ok").unwrap();
        assert!(s.ingest(5, Matrix::zeros(1, 4)).is_err()); // shard range
        assert!(s.ingest(0, Matrix::zeros(1, 3)).is_err()); // dim
        assert!(s.score(0, &ScoreBatch {
            indices: vec![0],
            labels: vec![0],
            norms: vec![1.0],
            losses: vec![1.0],
            zhat: Matrix::zeros(1, 2),
        })
        .is_err()); // not frozen
        assert!(reg.get("missing").is_err());
        assert!(reg.close("missing").is_err());
    }

    #[test]
    fn duplicate_session_rejected() {
        let reg = SessionRegistry::new(RegistryConfig::default());
        reg.create("dup", 2, 4, 1).unwrap();
        assert!(reg.create("dup", 2, 4, 1).unwrap_err().contains("exists"));
    }

    #[test]
    fn stats_pairs_report_progress() {
        let reg = SessionRegistry::new(RegistryConfig::default());
        reg.create("st", 2, 4, 1).unwrap();
        let s = reg.get("st").unwrap();
        s.ingest(0, Matrix::from_fn(5, 4, |r, c| (r * 4 + c) as f32))
            .unwrap();
        s.freeze().unwrap();
        let pairs = s.stats_pairs();
        let get = |k: &str| {
            pairs
                .iter()
                .find(|(n, _)| n.ends_with(k))
                .map(|(_, v)| *v)
                .unwrap()
        };
        assert_eq!(get(".rows_enqueued"), 5);
        assert_eq!(get(".rows_applied"), 5);
        assert_eq!(get(".frozen"), 1);
        let all = reg.stats_pairs("").unwrap();
        assert!(all.iter().any(|(n, v)| n == "service.registry.sessions" && *v == 1));
    }
}
