//! `sage-serve` TCP server with two interchangeable I/O engines:
//!
//! - `--io threads` — thread-per-connection on `util::threadpool`,
//!   blocking reads/writes. Portable; concurrency is capped by the pool.
//! - `--io epoll` — the readiness-driven reactor in `service::reactor`:
//!   one event-loop thread multiplexes every connection over raw epoll
//!   (`util::sys`), registry dispatch runs on a compute pool, and
//!   concurrent connections are bounded by memory, not threads.
//!
//! `--io auto` (the default) picks epoll where the kernel supports it and
//! falls back to threads elsewhere. Both engines speak the identical wire
//! protocol against the shared [`SessionRegistry`] and produce
//! byte-identical responses; the integration suite runs under both.
//!
//! Backpressure composes end-to-end in both engines. Threaded: a full
//! per-session ingest queue blocks the connection thread in
//! `Session::ingest`, which stops reading from the socket, which fills the
//! kernel TCP window, which blocks the producer. Reactor: the bounded
//! per-connection outbox throttles reads past its high watermark to the
//! same effect (see `service::reactor`).
//!
//! Connection shedding is part of the wire contract (documented in
//! docs/PROTOCOL.md §"Connection rejection and retry"): when the threaded
//! engine's pool is saturated, a shed connection receives exactly one
//! error frame — opcode 0, status 1, message prefixed `connection
//! rejected` — and is then closed. Clients retry with exponential backoff
//! (`client::ServiceClient::request_with_retry`); the
//! `service.server.rejected_connections` counter makes shedding observable
//! through the Stats op. The reactor does not shed at accept — load shows
//! up as queueing in `sage.reactor.dispatch.ns` instead.
//!
//! Push subscriptions (Subscribe/Unsubscribe, `service::subs`) work under
//! both engines: the reactor interleaves TopKDelta frames through each
//! connection's outbox; the threaded engine drains a per-connection push
//! queue between requests and on idle ticks. On shutdown, subscribers
//! receive a final GoingAway error frame before the socket closes.

use super::metrics_http;
use super::protocol::{
    encode_frame_traced_into, op, read_frame_event, write_frame, ReadEvent, Request, Response,
    MAX_PAYLOAD,
};
use super::reactor::{self, ReactorConfig};
use super::registry::{RegistryConfig, SessionRegistry};
use super::subs::{PushOutcome, PushSink, SubscriptionHub};
use crate::config::Method;
use crate::util::bufpool;
use crate::util::metrics::global as metrics;
use crate::util::metrics::Histogram;
use crate::util::sys::{self, EventFd};
use crate::util::threadpool::ThreadPool;
use crate::util::trace;
use std::collections::VecDeque;
use std::io::Write;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, OnceLock};
use std::thread::JoinHandle;
use std::time::Instant;

/// Which I/O engine drives the server.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum IoMode {
    /// Epoll where supported (Linux), threads elsewhere.
    Auto,
    /// Thread-per-connection (portable fallback).
    Threads,
    /// Readiness-driven reactor (requires Linux epoll).
    Epoll,
}

impl IoMode {
    pub fn parse(s: &str) -> Result<IoMode, String> {
        match s.to_ascii_lowercase().as_str() {
            "auto" => Ok(IoMode::Auto),
            "threads" => Ok(IoMode::Threads),
            "epoll" => Ok(IoMode::Epoll),
            other => Err(format!(
                "unknown io mode '{other}' (expected auto, threads, or epoll)"
            )),
        }
    }

    /// Engine selection from the `SAGE_SERVE_IO` environment variable
    /// (`auto` when unset or unparseable). This backs
    /// `ServerConfig::default()`, so in-process servers — integration
    /// tests in particular — honor the CI io-matrix without plumbing;
    /// the explicit `sage serve --io` flag still wins.
    pub fn from_env() -> IoMode {
        match std::env::var("SAGE_SERVE_IO") {
            Ok(s) => IoMode::parse(&s).unwrap_or(IoMode::Auto),
            Err(_) => IoMode::Auto,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            IoMode::Auto => "auto",
            IoMode::Threads => "threads",
            IoMode::Epoll => "epoll",
        }
    }

    /// Collapse `Auto` onto a concrete engine for this host.
    fn resolved(self) -> IoMode {
        match self {
            IoMode::Auto => {
                if sys::epoll_supported() {
                    IoMode::Epoll
                } else {
                    IoMode::Threads
                }
            }
            concrete => concrete,
        }
    }
}

/// Server knobs.
#[derive(Clone, Debug)]
pub struct ServerConfig {
    /// Bind address; port 0 picks a free port (see [`Server::local_addr`]).
    pub addr: String,
    /// Thread budget. Threaded engine: connection-handler threads
    /// (thread-per-connection, pooled). Reactor: one event-loop thread
    /// plus `threads - 1` dispatch workers — the same total, so the two
    /// engines are comparable at equal `--threads`.
    pub threads: usize,
    /// I/O engine selection (see [`IoMode`]).
    pub io: IoMode,
    /// Kernel-backend workers for the compute hot paths (FD shrink,
    /// finalize matvec, selection rules): ≤ 1 runs the serial reference,
    /// otherwise a shared `tensor::ParallelBackend` pool of this size —
    /// a *separate* pool from the connection threads, shared by every
    /// session. Results are bit-identical across all settings, so this
    /// never perturbs the served ≡ offline exactness guarantee.
    pub compute_workers: usize,
    /// Bind address for the Prometheus `/metrics` + `/healthz` HTTP
    /// endpoint (`None` = no exposition endpoint). Under the reactor this
    /// listener is multiplexed on the event loop; the threaded engine
    /// runs a dedicated acceptor thread.
    pub metrics_addr: Option<String>,
    /// Requests whose registry dispatch takes at least this many
    /// milliseconds get a WARN log line carrying the op name and trace ID
    /// (0 = disabled).
    pub slow_op_ms: u64,
    /// Reactor gathered writes: drain each connection's outbox with one
    /// `writev(2)` over an iovec batch instead of one `write(2)` per
    /// frame. On by default; `SAGE_REACTOR_WRITEV=0|false|off` restores
    /// the per-frame baseline (which `sage bench serve` measures the
    /// batched path against). Wire bytes are identical either way.
    pub writev: bool,
    /// `SO_SNDBUF` for accepted protocol sockets (`None` = kernel
    /// default). Tests set tiny values to force short writes through the
    /// partial-write resume path.
    pub sndbuf: Option<usize>,
    pub registry: RegistryConfig,
}

/// `SAGE_REACTOR_WRITEV=0|false|off` disables gathered writes; anything
/// else — including unset — enables them.
fn writev_from_env() -> bool {
    match std::env::var("SAGE_REACTOR_WRITEV") {
        Ok(v) => !matches!(v.to_ascii_lowercase().as_str(), "0" | "false" | "off"),
        Err(_) => true,
    }
}

impl Default for ServerConfig {
    fn default() -> Self {
        Self {
            addr: "127.0.0.1:7009".to_string(),
            threads: 16,
            io: IoMode::from_env(),
            compute_workers: 1,
            metrics_addr: None,
            slow_op_ms: 0,
            writev: writev_from_env(),
            sndbuf: None,
            registry: RegistryConfig::default(),
        }
    }
}

/// A bound (not yet serving) server.
pub struct Server {
    listener: TcpListener,
    metrics_listener: Option<TcpListener>,
    registry: Arc<SessionRegistry>,
    hub: Arc<SubscriptionHub>,
    threads: usize,
    io: IoMode,
    slow_op_ms: u64,
    writev: bool,
    sndbuf: Option<usize>,
    /// Shutdown wake-up for engines that poll readiness (`None` when the
    /// platform has no eventfd — shutdown falls back to a self-connect).
    wake: Option<Arc<EventFd>>,
}

impl Server {
    /// Bind the listener, build the registry, and recover any checkpointed
    /// sessions from the configured directory.
    pub fn bind(cfg: &ServerConfig) -> Result<Server, String> {
        let io = cfg.io.resolved();
        if io == IoMode::Epoll && !sys::epoll_supported() {
            return Err("io mode 'epoll' requires Linux; use --io threads".to_string());
        }
        let listener =
            TcpListener::bind(&cfg.addr).map_err(|e| format!("bind {}: {e}", cfg.addr))?;
        let metrics_listener = match &cfg.metrics_addr {
            Some(addr) => Some(
                TcpListener::bind(addr).map_err(|e| format!("bind metrics {addr}: {e}"))?,
            ),
            None => None,
        };
        // One kernel backend for the whole server: every session's shrink,
        // finalize, and selection rules run on this shared pool.
        let compute = crate::tensor::compute_backend(cfg.compute_workers);
        let registry = Arc::new(SessionRegistry::with_compute(cfg.registry.clone(), compute));
        if let Some(dir) = &cfg.registry.checkpoint_dir {
            let n = registry.recover(dir);
            if n > 0 {
                crate::log_info!("recovered {n} session(s) from {}", dir.display());
            }
        }
        // WAL replay rides on top of the recovered checkpoints; only after
        // it finishes does the registry start logging live traffic.
        let last_seq = registry.open_wal()?;
        if last_seq > 0 {
            crate::log_info!(
                "WAL open: durability={}, last seq {last_seq}",
                cfg.registry.durability.name()
            );
        }
        // The subscription hub watches the registry for selection changes
        // in every mode; it only does work once something subscribes.
        let hub = SubscriptionHub::new(&registry);
        let wake = EventFd::new().ok().map(Arc::new);
        Ok(Server {
            listener,
            metrics_listener,
            registry,
            hub,
            threads: cfg.threads.max(1),
            io,
            slow_op_ms: cfg.slow_op_ms,
            writev: cfg.writev,
            sndbuf: cfg.sndbuf,
            wake,
        })
    }

    pub fn local_addr(&self) -> SocketAddr {
        self.listener.local_addr().expect("listener has local addr")
    }

    /// Bound address of the `/metrics` endpoint, when configured (port 0
    /// in `metrics_addr` resolves here, like [`Server::local_addr`]).
    pub fn metrics_addr(&self) -> Option<SocketAddr> {
        self.metrics_listener.as_ref().and_then(|l| l.local_addr().ok())
    }

    pub fn registry(&self) -> Arc<SessionRegistry> {
        self.registry.clone()
    }

    /// The concrete engine this server will run (`Auto` already resolved).
    pub fn io_mode(&self) -> IoMode {
        self.io
    }

    /// Serve until `stop` flips (the engines differ in how they notice:
    /// the reactor via its wake eventfd, the threaded accept loop via an
    /// eventfd-assisted epoll where available or a wake-up connection
    /// otherwise). Blocks the calling thread.
    pub fn run(self, stop: Arc<AtomicBool>) -> Result<(), String> {
        match self.io {
            IoMode::Epoll => self.run_reactor(stop),
            _ => self.run_threads(stop),
        }
    }

    fn run_reactor(self, stop: Arc<AtomicBool>) -> Result<(), String> {
        let wake = self
            .wake
            .clone()
            .ok_or_else(|| "io mode 'epoll' needs an eventfd (unsupported here)".to_string())?;
        let hub = self.hub.clone();
        let result = reactor::run(
            ReactorConfig {
                listener: self.listener,
                metrics_listener: self.metrics_listener,
                registry: self.registry,
                hub: hub.clone(),
                wake,
                threads: self.threads,
                slow_op_ms: self.slow_op_ms,
                writev: self.writev,
                sndbuf: self.sndbuf,
            },
            stop,
        );
        hub.shutdown();
        result
    }

    fn run_threads(self, stop: Arc<AtomicBool>) -> Result<(), String> {
        let Server {
            listener,
            metrics_listener,
            registry,
            hub,
            threads,
            slow_op_ms,
            sndbuf,
            wake,
            ..
        } = self;
        let pool = ThreadPool::new(threads);
        if let Ok(addr) = listener.local_addr() {
            crate::log_info!("sage-serve listening on {addr} ({threads} connection threads)");
        }
        let metrics_join = metrics_listener.map(|l| {
            if let Ok(addr) = l.local_addr() {
                crate::log_info!("metrics exposition on http://{addr}/metrics");
            }
            metrics_http::spawn(l, stop.clone())
        });

        // Prefer an eventfd-assisted nonblocking accept loop (Linux):
        // shutdown is then a single eventfd write instead of a throwaway
        // self-connect. Elsewhere, block in accept and rely on the wake-up
        // connection from `ServerHandle`.
        let epoll_accept = wake.as_deref().and_then(|w| epoll_for_accept(&listener, w));
        match epoll_accept {
            Some(ep) => {
                let mut events = vec![sys::Event::zeroed(); 64];
                loop {
                    if stop.load(Ordering::Relaxed) {
                        break;
                    }
                    if let Err(e) = ep.wait(&mut events, 500) {
                        crate::log_warn!("accept epoll_wait: {e}");
                        break;
                    }
                    if let Some(w) = &wake {
                        w.drain();
                    }
                    if stop.load(Ordering::Relaxed) {
                        break;
                    }
                    loop {
                        match listener.accept() {
                            Ok((stream, _)) => spawn_conn(
                                &pool, stream, &registry, &hub, &stop, slow_op_ms, sndbuf,
                            ),
                            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
                            Err(e) => {
                                crate::log_warn!("accept failed: {e}");
                                break;
                            }
                        }
                    }
                }
            }
            None => {
                for incoming in listener.incoming() {
                    if stop.load(Ordering::Relaxed) {
                        break;
                    }
                    match incoming {
                        Ok(stream) => {
                            spawn_conn(&pool, stream, &registry, &hub, &stop, slow_op_ms, sndbuf)
                        }
                        Err(e) => {
                            crate::log_warn!("accept failed: {e}");
                        }
                    }
                }
            }
        }
        // Subscribers get their GoingAway frame before connection threads
        // exit: frames land in the per-connection push queues here and the
        // final drain in `handle_connection` writes them out. (Idempotent
        // with `ServerHandle::stop_and_join`, which broadcasts first.)
        hub.going_away();
        hub.shutdown();
        if let Some(join) = metrics_join {
            let _ = join.join();
        }
        Ok(())
    }

    /// Serve in a background thread; returns a handle that can stop the
    /// server and exposes the bound address (tests, examples, embedding).
    pub fn spawn(self) -> ServerHandle {
        let addr = self.local_addr();
        let metrics_addr = self.metrics_addr();
        let registry = self.registry();
        let hub = self.hub.clone();
        let wake = self.wake.clone();
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = stop.clone();
        let join = std::thread::spawn(move || {
            if let Err(e) = self.run(stop2) {
                crate::log_warn!("server exited: {e}");
            }
        });
        ServerHandle {
            addr,
            metrics_addr,
            registry,
            hub,
            wake,
            stop,
            join: Some(join),
        }
    }
}

/// Build the threaded engine's accept epoll (nonblocking listener + wake
/// eventfd) where the platform supports it.
#[cfg(target_os = "linux")]
fn epoll_for_accept(listener: &TcpListener, wake: &EventFd) -> Option<sys::Epoll> {
    use std::os::unix::io::AsRawFd;
    let ep = sys::Epoll::new().ok()?;
    listener.set_nonblocking(true).ok()?;
    ep.add(listener.as_raw_fd(), 0, sys::EPOLLIN).ok()?;
    ep.add(wake.as_raw_fd(), 1, sys::EPOLLIN).ok()?;
    Some(ep)
}

#[cfg(not(target_os = "linux"))]
fn epoll_for_accept(_listener: &TcpListener, _wake: &EventFd) -> Option<sys::Epoll> {
    None
}

/// Shrink the socket's kernel send buffer when the operator asked for one
/// (test harnesses use tiny buffers to force short writes).
#[cfg(unix)]
fn apply_sndbuf(stream: &TcpStream, sndbuf: Option<usize>) {
    use std::os::unix::io::AsRawFd;
    if let Some(bytes) = sndbuf {
        if let Err(e) = sys::set_sndbuf(stream.as_raw_fd(), bytes) {
            crate::log_debug!("SO_SNDBUF({bytes}) failed: {e}");
        }
    }
}

#[cfg(not(unix))]
fn apply_sndbuf(_stream: &TcpStream, _sndbuf: Option<usize>) {}

/// Accept-side handoff to the connection pool, with the graceful-rejection
/// error frame when the pool is saturated or shut down.
fn spawn_conn(
    pool: &ThreadPool,
    stream: TcpStream,
    registry: &Arc<SessionRegistry>,
    hub: &Arc<SubscriptionHub>,
    stop: &Arc<AtomicBool>,
    slow_op_ms: u64,
    sndbuf: Option<usize>,
) {
    metrics().counter("service.server.connections").inc();
    apply_sndbuf(&stream, sndbuf);
    let registry = registry.clone();
    let hub = hub.clone();
    let conn_stop = stop.clone();
    let reject_stream = stream.try_clone().ok();
    let submitted =
        pool.try_execute(move || handle_connection(stream, registry, hub, conn_stop, slow_op_ms));
    if let Err(reason) = submitted {
        // Graceful rejection: tell the peer and keep the acceptor
        // alive and non-blocking. The operator sees the
        // rejected-connection counter climb.
        metrics().counter("service.server.rejected_connections").inc();
        crate::log_warn!("connection rejected: {reason}");
        if let Some(mut s) = reject_stream {
            let resp = Response::Error {
                message: format!("connection rejected: {reason}"),
            };
            let _ = write_frame(&mut s, 0, resp.status(), &resp.encode());
        }
    }
}

/// Handle to a background server (see [`Server::spawn`]).
pub struct ServerHandle {
    addr: SocketAddr,
    metrics_addr: Option<SocketAddr>,
    registry: Arc<SessionRegistry>,
    hub: Arc<SubscriptionHub>,
    wake: Option<Arc<EventFd>>,
    stop: Arc<AtomicBool>,
    join: Option<JoinHandle<()>>,
}

impl ServerHandle {
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Bound `/metrics` endpoint address, when configured.
    pub fn metrics_addr(&self) -> Option<SocketAddr> {
        self.metrics_addr
    }

    pub fn registry(&self) -> Arc<SessionRegistry> {
        self.registry.clone()
    }

    /// Live subscriptions across all connections (observability/tests).
    pub fn subscription_count(&self) -> usize {
        self.hub.subscription_count()
    }

    /// Stop accepting, wake the engine, and join the server thread.
    /// In-flight requests finish; subscribers receive a final GoingAway
    /// frame before their connections close.
    pub fn shutdown(mut self) {
        self.stop_and_join();
    }

    fn stop_and_join(&mut self) {
        if self.join.is_none() {
            return;
        }
        // Broadcast GoingAway *before* flipping stop so connections still
        // in their serve loops deliver it on their final drain.
        self.hub.going_away();
        self.stop.store(true, Ordering::Relaxed);
        if let Some(w) = &self.wake {
            w.wake();
        }
        // Self-connect covers engines without an eventfd, and is harmless
        // otherwise (the accept paths re-check stop before handling).
        let _ = TcpStream::connect(self.addr);
        if let Some(m) = self.metrics_addr {
            let _ = TcpStream::connect(m);
        }
        if let Some(join) = self.join.take() {
            let _ = join.join();
        }
    }
}

impl Drop for ServerHandle {
    fn drop(&mut self) {
        self.stop_and_join();
    }
}

/// Per-op server latency histograms, interned once (the op set is fixed,
/// so the name set is bounded). `decode`/`handle`/`encode`/`write` split
/// one request's wall clock into its four server-side stages; `per_op` is
/// the handle stage broken out by opcode. Shared with the reactor so both
/// engines report identical series.
pub(crate) struct ServerHists {
    pub(crate) decode: &'static Histogram,
    pub(crate) handle: &'static Histogram,
    pub(crate) encode: &'static Histogram,
    pub(crate) write: &'static Histogram,
    pub(crate) per_op: Vec<&'static Histogram>,
}

pub(crate) fn server_hists() -> &'static ServerHists {
    static HISTS: OnceLock<ServerHists> = OnceLock::new();
    HISTS.get_or_init(|| {
        let reg = metrics();
        ServerHists {
            decode: reg.histogram("service.server.decode.ns"),
            handle: reg.histogram("service.server.handle.ns"),
            encode: reg.histogram("service.server.encode.ns"),
            write: reg.histogram("service.server.write.ns"),
            per_op: (0..=op::UNSUBSCRIBE)
                .map(|code| {
                    reg.histogram(&format!("service.server.op.{}.ns", op::name(code)))
                })
                .collect(),
        }
    })
}

/// Monotone connection IDs for the threaded engine's subscription
/// identity. Disjoint from nothing in particular — each server's hub only
/// ever sees IDs from the one engine driving it.
static NEXT_CONN_ID: AtomicU64 = AtomicU64::new(1);

/// Queued push frames under this many bytes are accepted; past it the
/// sink reports Busy and the hub coalesces (mirrors the reactor's sink
/// budget, scaled to the threaded drain cadence).
const PUSH_QUEUE_BYTES: usize = 256 << 10;

/// The threaded engine's [`PushSink`]: a bounded queue of encoded frames
/// drained by the connection thread between requests, on idle ticks, and
/// once after its serve loop exits (so a shutdown GoingAway still lands).
struct ThreadPusher {
    queue: Mutex<VecDeque<Vec<u8>>>,
    bytes: AtomicUsize,
    gone: AtomicBool,
}

impl ThreadPusher {
    fn new() -> ThreadPusher {
        ThreadPusher {
            queue: Mutex::new(VecDeque::new()),
            bytes: AtomicUsize::new(0),
            gone: AtomicBool::new(false),
        }
    }

    fn take_all(&self) -> Vec<Vec<u8>> {
        let mut q = self.queue.lock().unwrap();
        let drained: Vec<Vec<u8>> = q.drain(..).collect();
        let bytes: usize = drained.iter().map(|f| f.len()).sum();
        drop(q);
        self.bytes.fetch_sub(bytes, Ordering::Relaxed);
        drained
    }
}

impl PushSink for ThreadPusher {
    fn try_push(&self, frame: Vec<u8>) -> PushOutcome {
        if self.gone.load(Ordering::Acquire) {
            bufpool::global().put(frame);
            return PushOutcome::Gone;
        }
        if self.bytes.load(Ordering::Relaxed) > PUSH_QUEUE_BYTES {
            bufpool::global().put(frame);
            return PushOutcome::Busy;
        }
        self.bytes.fetch_add(frame.len(), Ordering::Relaxed);
        self.queue.lock().unwrap().push_back(frame);
        PushOutcome::Sent
    }
}

/// Write every queued push frame to the socket. `false` means the peer is
/// gone (the caller breaks its serve loop).
fn drain_pusher(stream: &mut TcpStream, pusher: &Option<Arc<ThreadPusher>>) -> bool {
    let Some(p) = pusher else { return true };
    for frame in p.take_all() {
        let ok = stream.write_all(&frame).is_ok();
        bufpool::global().put(frame);
        if !ok {
            p.gone.store(true, Ordering::Release);
            return false;
        }
    }
    true
}

/// [`write_frame_traced`](super::protocol::write_frame_traced), but the
/// frame is assembled in a pooled buffer instead of a fresh allocation —
/// the steady-state response path allocates nothing.
fn write_pooled_frame(
    stream: &mut TcpStream,
    opcode: u8,
    status: u16,
    payload: &[u8],
    trace: Option<trace::TraceCtx>,
) -> Result<(), String> {
    if payload.len() > MAX_PAYLOAD {
        return Err(format!(
            "frame payload {} bytes exceeds the {MAX_PAYLOAD}-byte wire cap; \
             split the batch into smaller blocks",
            payload.len()
        ));
    }
    let mut frame = bufpool::global().take();
    encode_frame_traced_into(&mut frame, opcode, status, payload, trace);
    let result = stream
        .write_all(&frame)
        .and_then(|()| stream.flush())
        .map_err(|e| format!("frame write: {e}"));
    bufpool::global().put(frame);
    result
}

/// One connection: request/response frames until EOF, a framing error, or
/// server shutdown (polled between frames via the socket read timeout).
/// Subscribe/Unsubscribe are intercepted here (they bind to *this*
/// connection's push queue); everything else goes through [`dispatch`].
fn handle_connection(
    mut stream: TcpStream,
    registry: Arc<SessionRegistry>,
    hub: Arc<SubscriptionHub>,
    stop: Arc<AtomicBool>,
    slow_op_ms: u64,
) {
    let _ = stream.set_nonblocking(false); // accepted from a nonblocking listener
    let _ = stream.set_nodelay(true);
    let _ = stream.set_read_timeout(Some(std::time::Duration::from_millis(200)));
    let peer = stream
        .peer_addr()
        .map(|a| a.to_string())
        .unwrap_or_else(|_| "?".to_string());
    let conn_id = NEXT_CONN_ID.fetch_add(1, Ordering::Relaxed);
    let gauge = metrics().gauge("sage.server.connections");
    gauge.add(1);
    let hists = server_hists();
    let mut pusher: Option<Arc<ThreadPusher>> = None;
    loop {
        if stop.load(Ordering::Relaxed) {
            break;
        }
        let frame = match read_frame_event(&mut stream) {
            Ok(ReadEvent::Frame(f)) => f,
            Ok(ReadEvent::Eof) => break, // clean close between requests
            Ok(ReadEvent::Idle) => {
                // Timeout between frames: poll stop, deliver pushes.
                if !drain_pusher(&mut stream, &pusher) {
                    break;
                }
                continue;
            }
            Err(e) => {
                crate::log_debug!("connection {peer}: {e}");
                break;
            }
        };
        metrics().counter("service.server.requests").inc();
        let opcode = frame.opcode;
        // A traced frame makes the client's span the parent of one
        // server-side root span covering decode → handle → encode → write.
        let _request_span = frame
            .trace
            .map(|ctx| trace::adopt(&format!("serve.{}", op::name(opcode)), ctx));

        let t = Instant::now();
        let decoded = {
            let _s = trace::span("serve.decode");
            Request::decode(opcode, &frame.payload)
        };
        hists.decode.record(t.elapsed().as_nanos() as u64);
        // `Request::decode` copies what it needs; the wire payload buffer
        // goes straight back to the pool.
        bufpool::global().put(frame.payload);

        let t = Instant::now();
        let response = match decoded {
            Ok(Request::Subscribe {
                session,
                method,
                k,
                num_classes,
                seed,
            }) => {
                let _s = trace::span("serve.handle");
                let sink = pusher
                    .get_or_insert_with(|| Arc::new(ThreadPusher::new()))
                    .clone();
                match hub.subscribe(
                    conn_id,
                    sink,
                    &session,
                    &method,
                    k as usize,
                    num_classes as usize,
                    seed,
                ) {
                    Ok(()) => Response::Ok,
                    Err(message) => Response::Error { message },
                }
            }
            Ok(Request::Unsubscribe { session }) => {
                let _s = trace::span("serve.handle");
                hub.unsubscribe(conn_id, &session);
                Response::Ok
            }
            Ok(request) => {
                let _s = trace::span("serve.handle");
                dispatch(&registry, request)
            }
            Err(e) => Response::Error {
                message: format!("bad request: {e}"),
            },
        };
        let handle_ns = t.elapsed().as_nanos() as u64;
        hists.handle.record(handle_ns);
        if let Some(h) = hists.per_op.get(opcode as usize) {
            h.record(handle_ns);
        }
        if slow_op_ms > 0 && handle_ns >= slow_op_ms.saturating_mul(1_000_000) {
            crate::log_warn!(
                "slow op {}: {:.1}ms (threshold {slow_op_ms}ms) trace={:016x}",
                op::name(opcode),
                handle_ns as f64 / 1e6,
                frame.trace.map(|c| c.trace_id).unwrap_or(0)
            );
        }
        if matches!(response, Response::Error { .. }) {
            metrics().counter("service.server.errors").inc();
        }

        let t = Instant::now();
        let mut payload = bufpool::global().take();
        {
            let _s = trace::span("serve.encode");
            response.encode_into(&mut payload);
        }
        hists.encode.record(t.elapsed().as_nanos() as u64);

        let t = Instant::now();
        // Echo the request's trace context on the response — error frames
        // included — so the client can stitch causality across failures.
        let written = {
            let _s = trace::span("serve.write");
            write_pooled_frame(&mut stream, opcode, response.status(), &payload, frame.trace)
        };
        bufpool::global().put(payload);
        hists.write.record(t.elapsed().as_nanos() as u64);
        if written.is_err() {
            break; // peer went away mid-response
        }
        // Push frames ride between responses, never inside one.
        if !drain_pusher(&mut stream, &pusher) {
            break;
        }
    }
    // Final drain: a shutdown broadcast enqueues GoingAway before `stop`
    // flips, so it is sitting in the queue by the time the loop exits.
    let _ = drain_pusher(&mut stream, &pusher);
    if let Some(p) = &pusher {
        p.gone.store(true, Ordering::Release);
    }
    hub.drop_conn(conn_id);
    gauge.sub(1);
}

/// Apply one request to the registry.
///
/// Subscribe/Unsubscribe never reach the registry — both engines bind
/// them to connection state before dispatch — so here they only answer
/// with an error (e.g. a frame replayed against a raw dispatch harness).
pub fn dispatch(registry: &SessionRegistry, request: Request) -> Response {
    let _s = trace::span(registry_span_name(&request));
    let result = match request {
        Request::CreateSession {
            name,
            ell,
            d,
            shards,
        } => registry
            .create(&name, ell as usize, d as usize, shards as usize)
            .map(|()| Response::Ok),
        // Mutating ops go through the registry wrappers, which append to
        // the WAL under the session's gate when durability is on.
        Request::IngestBatch {
            session,
            shard,
            rows,
        } => registry
            .ingest(&session, shard as usize, rows)
            .map(|rows_seen| Response::Ingested { rows_seen }),
        Request::MergeSketch {
            session,
            shard,
            state,
        } => registry
            .merge_sketch(&session, shard as usize, &state)
            .map(|()| Response::Ok),
        Request::Freeze { session } => registry.freeze(&session).map(Response::Frozen),
        // Score and TopK go through the registry (not the session) so the
        // scorer-budget spill-on-pressure path can evict idle sessions.
        Request::Score {
            session,
            shard,
            batch,
        } => registry
            .score(&session, shard as usize, &batch)
            .map(|()| Response::Ok),
        Request::TopK {
            session,
            method,
            k,
            num_classes,
            seed,
        } => Method::parse(&method).and_then(|method| {
            let (indices, weights) =
                registry.top_k(&session, method, k as usize, num_classes as usize, seed)?;
            Ok(Response::Selected {
                indices: indices.iter().map(|&i| i as u64).collect(),
                weights: weights.unwrap_or_default(),
            })
        }),
        Request::Checkpoint { session } => {
            registry
                .checkpoint(&session)
                .map(|(path, wal_seq)| Response::Checkpointed {
                    path: path.display().to_string(),
                    wal_seq,
                })
        }
        Request::Stats { session } => registry
            .stats_pairs(&session)
            .map(|pairs| Response::Stats { pairs }),
        Request::CloseSession { session } => registry.close(&session).map(|()| Response::Ok),
        Request::MetricsSnapshot { prefix } => {
            let reg = metrics();
            Ok(Response::Metrics {
                counters: reg.snapshot_counters(&prefix),
                gauges: reg.snapshot_gauges(&prefix),
                hists: reg.snapshot_histograms(&prefix),
            })
        }
        Request::TraceExport => Ok(Response::Trace {
            spans: trace::collect(),
        }),
        Request::Subscribe { .. } | Request::Unsubscribe { .. } => {
            Err("subscription ops require a push-capable connection".to_string())
        }
    };
    match result {
        Ok(resp) => resp,
        Err(message) => Response::Error { message },
    }
}

/// Trace span name for one registry dispatch (the `registry.<op>` level of
/// the `serve.<op>` → `registry.<op>` → `kernel.<op>` hierarchy).
fn registry_span_name(request: &Request) -> &'static str {
    match request {
        Request::CreateSession { .. } => "registry.create",
        Request::IngestBatch { .. } => "registry.ingest",
        Request::MergeSketch { .. } => "registry.merge_sketch",
        Request::Freeze { .. } => "registry.freeze",
        Request::Score { .. } => "registry.score",
        Request::TopK { .. } => "registry.top_k",
        Request::Checkpoint { .. } => "registry.checkpoint",
        Request::Stats { .. } => "registry.stats",
        Request::CloseSession { .. } => "registry.close",
        Request::MetricsSnapshot { .. } => "registry.metrics_snapshot",
        Request::TraceExport => "registry.trace_export",
        Request::Subscribe { .. } => "registry.subscribe",
        Request::Unsubscribe { .. } => "registry.unsubscribe",
    }
}
