//! `sage-serve` TCP server: thread-per-connection on `util::threadpool`,
//! speaking the length-prefixed `service::protocol` frames against the
//! shared [`SessionRegistry`].
//!
//! Backpressure composes end-to-end: a full per-session ingest queue blocks
//! the connection thread in `Session::ingest`, which stops reading from the
//! socket, which fills the kernel TCP window, which blocks the producer.
//! When the connection pool itself is saturated or shut down, the acceptor
//! never blocks: `ThreadPool::try_execute` fails fast and the new
//! connection is rejected with an error frame, keeping accept (and
//! shutdown) responsive no matter the load.
//!
//! Connection shedding is part of the wire contract (documented in
//! docs/PROTOCOL.md §"Connection rejection and retry"): a shed connection
//! receives exactly one error frame — opcode 0, status 1, message prefixed
//! `connection rejected` — and is then closed. Clients retry with
//! exponential backoff (`client::ServiceClient::request_with_retry`); the
//! `service.server.rejected_connections` counter makes shedding observable
//! through the Stats op.

use super::protocol::{read_frame_event, write_frame, ReadEvent, Request, Response};
use super::registry::{RegistryConfig, SessionRegistry};
use crate::config::Method;
use crate::util::metrics::global as metrics;
use crate::util::threadpool::ThreadPool;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;

/// Server knobs.
#[derive(Clone, Debug)]
pub struct ServerConfig {
    /// Bind address; port 0 picks a free port (see [`Server::local_addr`]).
    pub addr: String,
    /// Connection-handler threads (thread-per-connection, pooled).
    pub threads: usize,
    /// Kernel-backend workers for the compute hot paths (FD shrink,
    /// finalize matvec, selection rules): ≤ 1 runs the serial reference,
    /// otherwise a shared `tensor::ParallelBackend` pool of this size —
    /// a *separate* pool from the connection threads, shared by every
    /// session. Results are bit-identical across all settings, so this
    /// never perturbs the served ≡ offline exactness guarantee.
    pub compute_workers: usize,
    pub registry: RegistryConfig,
}

impl Default for ServerConfig {
    fn default() -> Self {
        Self {
            addr: "127.0.0.1:7009".to_string(),
            threads: 16,
            compute_workers: 1,
            registry: RegistryConfig::default(),
        }
    }
}

/// A bound (not yet serving) server.
pub struct Server {
    listener: TcpListener,
    registry: Arc<SessionRegistry>,
    threads: usize,
}

impl Server {
    /// Bind the listener, build the registry, and recover any checkpointed
    /// sessions from the configured directory.
    pub fn bind(cfg: &ServerConfig) -> Result<Server, String> {
        let listener =
            TcpListener::bind(&cfg.addr).map_err(|e| format!("bind {}: {e}", cfg.addr))?;
        // One kernel backend for the whole server: every session's shrink,
        // finalize, and selection rules run on this shared pool.
        let compute = crate::tensor::compute_backend(cfg.compute_workers);
        let registry = Arc::new(SessionRegistry::with_compute(cfg.registry.clone(), compute));
        if let Some(dir) = &cfg.registry.checkpoint_dir {
            let n = registry.recover(dir);
            if n > 0 {
                crate::log_info!("recovered {n} session(s) from {}", dir.display());
            }
        }
        Ok(Server {
            listener,
            registry,
            threads: cfg.threads.max(1),
        })
    }

    pub fn local_addr(&self) -> SocketAddr {
        self.listener.local_addr().expect("listener has local addr")
    }

    pub fn registry(&self) -> Arc<SessionRegistry> {
        self.registry.clone()
    }

    /// Accept loop. Blocks the calling thread until `stop` flips (a wake-up
    /// connection is enough to re-check it) or the listener dies. Open
    /// connections poll `stop` between frames, so dropping the pool on exit
    /// cannot deadlock on an idle client.
    pub fn run(self, stop: Arc<AtomicBool>) -> Result<(), String> {
        let pool = ThreadPool::new(self.threads);
        crate::log_info!(
            "sage-serve listening on {} ({} connection threads)",
            self.local_addr(),
            self.threads
        );
        for incoming in self.listener.incoming() {
            if stop.load(Ordering::Relaxed) {
                break;
            }
            let stream = match incoming {
                Ok(s) => s,
                Err(e) => {
                    crate::log_warn!("accept failed: {e}");
                    continue;
                }
            };
            metrics().counter("service.server.connections").inc();
            let registry = self.registry.clone();
            let conn_stop = stop.clone();
            let reject_stream = stream.try_clone().ok();
            let submitted =
                pool.try_execute(move || handle_connection(stream, registry, conn_stop));
            if let Err(reason) = submitted {
                // Graceful rejection: tell the peer and keep the acceptor
                // alive and non-blocking. The operator sees the
                // rejected-connection counter climb.
                metrics().counter("service.server.rejected_connections").inc();
                crate::log_warn!("connection rejected: {reason}");
                if let Some(mut s) = reject_stream {
                    let resp = Response::Error {
                        message: format!("connection rejected: {reason}"),
                    };
                    let _ = write_frame(&mut s, 0, resp.status(), &resp.encode());
                }
            }
        }
        Ok(())
    }

    /// Serve in a background thread; returns a handle that can stop the
    /// server and exposes the bound address (tests, examples, embedding).
    pub fn spawn(self) -> ServerHandle {
        let addr = self.local_addr();
        let registry = self.registry();
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = stop.clone();
        let join = std::thread::spawn(move || {
            if let Err(e) = self.run(stop2) {
                crate::log_warn!("server exited: {e}");
            }
        });
        ServerHandle {
            addr,
            registry,
            stop,
            join: Some(join),
        }
    }
}

/// Handle to a background server (see [`Server::spawn`]).
pub struct ServerHandle {
    addr: SocketAddr,
    registry: Arc<SessionRegistry>,
    stop: Arc<AtomicBool>,
    join: Option<JoinHandle<()>>,
}

impl ServerHandle {
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    pub fn registry(&self) -> Arc<SessionRegistry> {
        self.registry.clone()
    }

    /// Stop accepting, wake the accept loop, and join the acceptor thread.
    /// In-flight connections finish their current request on pool threads.
    pub fn shutdown(mut self) {
        self.stop_and_join();
    }

    fn stop_and_join(&mut self) {
        if self.join.is_none() {
            return;
        }
        self.stop.store(true, Ordering::Relaxed);
        // Wake the blocking accept with a throwaway connection.
        let _ = TcpStream::connect(self.addr);
        if let Some(join) = self.join.take() {
            let _ = join.join();
        }
    }
}

impl Drop for ServerHandle {
    fn drop(&mut self) {
        self.stop_and_join();
    }
}

/// One connection: request/response frames until EOF, a framing error, or
/// server shutdown (polled between frames via the socket read timeout).
fn handle_connection(mut stream: TcpStream, registry: Arc<SessionRegistry>, stop: Arc<AtomicBool>) {
    let _ = stream.set_nodelay(true);
    let _ = stream.set_read_timeout(Some(std::time::Duration::from_millis(200)));
    let peer = stream
        .peer_addr()
        .map(|a| a.to_string())
        .unwrap_or_else(|_| "?".to_string());
    loop {
        if stop.load(Ordering::Relaxed) {
            break;
        }
        let frame = match read_frame_event(&mut stream) {
            Ok(ReadEvent::Frame(f)) => f,
            Ok(ReadEvent::Eof) => break, // clean close between requests
            Ok(ReadEvent::Idle) => continue, // timeout between frames: poll stop
            Err(e) => {
                crate::log_debug!("connection {peer}: {e}");
                break;
            }
        };
        metrics().counter("service.server.requests").inc();
        let opcode = frame.opcode;
        let response = match Request::decode(opcode, &frame.payload) {
            Ok(request) => dispatch(&registry, request),
            Err(e) => Response::Error {
                message: format!("bad request: {e}"),
            },
        };
        if matches!(response, Response::Error { .. }) {
            metrics().counter("service.server.errors").inc();
        }
        let payload = response.encode();
        if write_frame(&mut stream, opcode, response.status(), &payload).is_err() {
            break; // peer went away mid-response
        }
    }
}

/// Apply one request to the registry.
pub fn dispatch(registry: &SessionRegistry, request: Request) -> Response {
    let result = match request {
        Request::CreateSession {
            name,
            ell,
            d,
            shards,
        } => registry
            .create(&name, ell as usize, d as usize, shards as usize)
            .map(|()| Response::Ok),
        Request::IngestBatch {
            session,
            shard,
            rows,
        } => registry.get(&session).and_then(|s| {
            s.ingest(shard as usize, rows)
                .map(|rows_seen| Response::Ingested { rows_seen })
        }),
        Request::MergeSketch {
            session,
            shard,
            state,
        } => registry
            .get(&session)
            .and_then(|s| s.merge_sketch(shard as usize, &state).map(|()| Response::Ok)),
        Request::Freeze { session } => registry
            .get(&session)
            .and_then(|s| s.freeze().map(Response::Frozen)),
        // Score and TopK go through the registry (not the session) so the
        // scorer-budget spill-on-pressure path can evict idle sessions.
        Request::Score {
            session,
            shard,
            batch,
        } => registry
            .score(&session, shard as usize, &batch)
            .map(|()| Response::Ok),
        Request::TopK {
            session,
            method,
            k,
            num_classes,
            seed,
        } => Method::parse(&method).and_then(|method| {
            let (indices, weights) =
                registry.top_k(&session, method, k as usize, num_classes as usize, seed)?;
            Ok(Response::Selected {
                indices: indices.iter().map(|&i| i as u64).collect(),
                weights: weights.unwrap_or_default(),
            })
        }),
        Request::Checkpoint { session } => registry.checkpoint(&session).map(|path| {
            Response::Checkpointed {
                path: path.display().to_string(),
            }
        }),
        Request::Stats { session } => registry
            .stats_pairs(&session)
            .map(|pairs| Response::Stats { pairs }),
        Request::CloseSession { session } => registry.close(&session).map(|()| Response::Ok),
    };
    match result {
        Ok(resp) => resp,
        Err(message) => Response::Error { message },
    }
}
