//! `sage-serve` TCP server: thread-per-connection on `util::threadpool`,
//! speaking the length-prefixed `service::protocol` frames against the
//! shared [`SessionRegistry`].
//!
//! Backpressure composes end-to-end: a full per-session ingest queue blocks
//! the connection thread in `Session::ingest`, which stops reading from the
//! socket, which fills the kernel TCP window, which blocks the producer.
//! When the connection pool itself is saturated or shut down, the acceptor
//! never blocks: `ThreadPool::try_execute` fails fast and the new
//! connection is rejected with an error frame, keeping accept (and
//! shutdown) responsive no matter the load.
//!
//! Connection shedding is part of the wire contract (documented in
//! docs/PROTOCOL.md §"Connection rejection and retry"): a shed connection
//! receives exactly one error frame — opcode 0, status 1, message prefixed
//! `connection rejected` — and is then closed. Clients retry with
//! exponential backoff (`client::ServiceClient::request_with_retry`); the
//! `service.server.rejected_connections` counter makes shedding observable
//! through the Stats op.

use super::metrics_http;
use super::protocol::{
    op, read_frame_event, write_frame, write_frame_traced, ReadEvent, Request, Response,
};
use super::registry::{RegistryConfig, SessionRegistry};
use crate::config::Method;
use crate::util::metrics::global as metrics;
use crate::util::metrics::Histogram;
use crate::util::threadpool::ThreadPool;
use crate::util::trace;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, OnceLock};
use std::thread::JoinHandle;
use std::time::Instant;

/// Server knobs.
#[derive(Clone, Debug)]
pub struct ServerConfig {
    /// Bind address; port 0 picks a free port (see [`Server::local_addr`]).
    pub addr: String,
    /// Connection-handler threads (thread-per-connection, pooled).
    pub threads: usize,
    /// Kernel-backend workers for the compute hot paths (FD shrink,
    /// finalize matvec, selection rules): ≤ 1 runs the serial reference,
    /// otherwise a shared `tensor::ParallelBackend` pool of this size —
    /// a *separate* pool from the connection threads, shared by every
    /// session. Results are bit-identical across all settings, so this
    /// never perturbs the served ≡ offline exactness guarantee.
    pub compute_workers: usize,
    /// Bind address for the Prometheus `/metrics` + `/healthz` HTTP
    /// endpoint (`None` = no exposition endpoint).
    pub metrics_addr: Option<String>,
    /// Requests whose registry dispatch takes at least this many
    /// milliseconds get a WARN log line carrying the op name and trace ID
    /// (0 = disabled).
    pub slow_op_ms: u64,
    pub registry: RegistryConfig,
}

impl Default for ServerConfig {
    fn default() -> Self {
        Self {
            addr: "127.0.0.1:7009".to_string(),
            threads: 16,
            compute_workers: 1,
            metrics_addr: None,
            slow_op_ms: 0,
            registry: RegistryConfig::default(),
        }
    }
}

/// A bound (not yet serving) server.
pub struct Server {
    listener: TcpListener,
    metrics_listener: Option<TcpListener>,
    registry: Arc<SessionRegistry>,
    threads: usize,
    slow_op_ms: u64,
}

impl Server {
    /// Bind the listener, build the registry, and recover any checkpointed
    /// sessions from the configured directory.
    pub fn bind(cfg: &ServerConfig) -> Result<Server, String> {
        let listener =
            TcpListener::bind(&cfg.addr).map_err(|e| format!("bind {}: {e}", cfg.addr))?;
        let metrics_listener = match &cfg.metrics_addr {
            Some(addr) => Some(
                TcpListener::bind(addr).map_err(|e| format!("bind metrics {addr}: {e}"))?,
            ),
            None => None,
        };
        // One kernel backend for the whole server: every session's shrink,
        // finalize, and selection rules run on this shared pool.
        let compute = crate::tensor::compute_backend(cfg.compute_workers);
        let registry = Arc::new(SessionRegistry::with_compute(cfg.registry.clone(), compute));
        if let Some(dir) = &cfg.registry.checkpoint_dir {
            let n = registry.recover(dir);
            if n > 0 {
                crate::log_info!("recovered {n} session(s) from {}", dir.display());
            }
        }
        // WAL replay rides on top of the recovered checkpoints; only after
        // it finishes does the registry start logging live traffic.
        let last_seq = registry.open_wal()?;
        if last_seq > 0 {
            crate::log_info!(
                "WAL open: durability={}, last seq {last_seq}",
                cfg.registry.durability.name()
            );
        }
        Ok(Server {
            listener,
            metrics_listener,
            registry,
            threads: cfg.threads.max(1),
            slow_op_ms: cfg.slow_op_ms,
        })
    }

    pub fn local_addr(&self) -> SocketAddr {
        self.listener.local_addr().expect("listener has local addr")
    }

    /// Bound address of the `/metrics` endpoint, when configured (port 0
    /// in `metrics_addr` resolves here, like [`Server::local_addr`]).
    pub fn metrics_addr(&self) -> Option<SocketAddr> {
        self.metrics_listener.as_ref().and_then(|l| l.local_addr().ok())
    }

    pub fn registry(&self) -> Arc<SessionRegistry> {
        self.registry.clone()
    }

    /// Accept loop. Blocks the calling thread until `stop` flips (a wake-up
    /// connection is enough to re-check it) or the listener dies. Open
    /// connections poll `stop` between frames, so dropping the pool on exit
    /// cannot deadlock on an idle client.
    pub fn run(self, stop: Arc<AtomicBool>) -> Result<(), String> {
        let pool = ThreadPool::new(self.threads);
        crate::log_info!(
            "sage-serve listening on {} ({} connection threads)",
            self.local_addr(),
            self.threads
        );
        let metrics_join = self.metrics_listener.map(|listener| {
            if let Ok(addr) = listener.local_addr() {
                crate::log_info!("metrics exposition on http://{addr}/metrics");
            }
            metrics_http::spawn(listener, stop.clone())
        });
        let slow_op_ms = self.slow_op_ms;
        for incoming in self.listener.incoming() {
            if stop.load(Ordering::Relaxed) {
                break;
            }
            let stream = match incoming {
                Ok(s) => s,
                Err(e) => {
                    crate::log_warn!("accept failed: {e}");
                    continue;
                }
            };
            metrics().counter("service.server.connections").inc();
            let registry = self.registry.clone();
            let conn_stop = stop.clone();
            let reject_stream = stream.try_clone().ok();
            let submitted =
                pool.try_execute(move || handle_connection(stream, registry, conn_stop, slow_op_ms));
            if let Err(reason) = submitted {
                // Graceful rejection: tell the peer and keep the acceptor
                // alive and non-blocking. The operator sees the
                // rejected-connection counter climb.
                metrics().counter("service.server.rejected_connections").inc();
                crate::log_warn!("connection rejected: {reason}");
                if let Some(mut s) = reject_stream {
                    let resp = Response::Error {
                        message: format!("connection rejected: {reason}"),
                    };
                    let _ = write_frame(&mut s, 0, resp.status(), &resp.encode());
                }
            }
        }
        if let Some(join) = metrics_join {
            let _ = join.join();
        }
        Ok(())
    }

    /// Serve in a background thread; returns a handle that can stop the
    /// server and exposes the bound address (tests, examples, embedding).
    pub fn spawn(self) -> ServerHandle {
        let addr = self.local_addr();
        let metrics_addr = self.metrics_addr();
        let registry = self.registry();
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = stop.clone();
        let join = std::thread::spawn(move || {
            if let Err(e) = self.run(stop2) {
                crate::log_warn!("server exited: {e}");
            }
        });
        ServerHandle {
            addr,
            metrics_addr,
            registry,
            stop,
            join: Some(join),
        }
    }
}

/// Handle to a background server (see [`Server::spawn`]).
pub struct ServerHandle {
    addr: SocketAddr,
    metrics_addr: Option<SocketAddr>,
    registry: Arc<SessionRegistry>,
    stop: Arc<AtomicBool>,
    join: Option<JoinHandle<()>>,
}

impl ServerHandle {
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Bound `/metrics` endpoint address, when configured.
    pub fn metrics_addr(&self) -> Option<SocketAddr> {
        self.metrics_addr
    }

    pub fn registry(&self) -> Arc<SessionRegistry> {
        self.registry.clone()
    }

    /// Stop accepting, wake the accept loop, and join the acceptor thread.
    /// In-flight connections finish their current request on pool threads.
    pub fn shutdown(mut self) {
        self.stop_and_join();
    }

    fn stop_and_join(&mut self) {
        if self.join.is_none() {
            return;
        }
        self.stop.store(true, Ordering::Relaxed);
        // Wake the blocking accepts with throwaway connections (the metrics
        // acceptor runs its own loop on the same stop flag).
        let _ = TcpStream::connect(self.addr);
        if let Some(m) = self.metrics_addr {
            let _ = TcpStream::connect(m);
        }
        if let Some(join) = self.join.take() {
            let _ = join.join();
        }
    }
}

impl Drop for ServerHandle {
    fn drop(&mut self) {
        self.stop_and_join();
    }
}

/// Per-op server latency histograms, interned once (the op set is fixed,
/// so the name set is bounded). `decode`/`handle`/`encode`/`write` split
/// one request's wall clock into its four server-side stages; `per_op` is
/// the handle stage broken out by opcode.
struct ServerHists {
    decode: &'static Histogram,
    handle: &'static Histogram,
    encode: &'static Histogram,
    write: &'static Histogram,
    per_op: Vec<&'static Histogram>,
}

fn server_hists() -> &'static ServerHists {
    static HISTS: OnceLock<ServerHists> = OnceLock::new();
    HISTS.get_or_init(|| {
        let reg = metrics();
        ServerHists {
            decode: reg.histogram("service.server.decode.ns"),
            handle: reg.histogram("service.server.handle.ns"),
            encode: reg.histogram("service.server.encode.ns"),
            write: reg.histogram("service.server.write.ns"),
            per_op: (0..=op::TRACE_EXPORT)
                .map(|code| {
                    reg.histogram(&format!("service.server.op.{}.ns", op::name(code)))
                })
                .collect(),
        }
    })
}

/// One connection: request/response frames until EOF, a framing error, or
/// server shutdown (polled between frames via the socket read timeout).
fn handle_connection(
    mut stream: TcpStream,
    registry: Arc<SessionRegistry>,
    stop: Arc<AtomicBool>,
    slow_op_ms: u64,
) {
    let _ = stream.set_nodelay(true);
    let _ = stream.set_read_timeout(Some(std::time::Duration::from_millis(200)));
    let peer = stream
        .peer_addr()
        .map(|a| a.to_string())
        .unwrap_or_else(|_| "?".to_string());
    let hists = server_hists();
    loop {
        if stop.load(Ordering::Relaxed) {
            break;
        }
        let frame = match read_frame_event(&mut stream) {
            Ok(ReadEvent::Frame(f)) => f,
            Ok(ReadEvent::Eof) => break, // clean close between requests
            Ok(ReadEvent::Idle) => continue, // timeout between frames: poll stop
            Err(e) => {
                crate::log_debug!("connection {peer}: {e}");
                break;
            }
        };
        metrics().counter("service.server.requests").inc();
        let opcode = frame.opcode;
        // A traced frame makes the client's span the parent of one
        // server-side root span covering decode → handle → encode → write.
        let _request_span = frame
            .trace
            .map(|ctx| trace::adopt(&format!("serve.{}", op::name(opcode)), ctx));

        let t = Instant::now();
        let decoded = {
            let _s = trace::span("serve.decode");
            Request::decode(opcode, &frame.payload)
        };
        hists.decode.record(t.elapsed().as_nanos() as u64);

        let t = Instant::now();
        let response = match decoded {
            Ok(request) => {
                let _s = trace::span("serve.handle");
                dispatch(&registry, request)
            }
            Err(e) => Response::Error {
                message: format!("bad request: {e}"),
            },
        };
        let handle_ns = t.elapsed().as_nanos() as u64;
        hists.handle.record(handle_ns);
        if let Some(h) = hists.per_op.get(opcode as usize) {
            h.record(handle_ns);
        }
        if slow_op_ms > 0 && handle_ns >= slow_op_ms.saturating_mul(1_000_000) {
            crate::log_warn!(
                "slow op {}: {:.1}ms (threshold {slow_op_ms}ms) trace={:016x}",
                op::name(opcode),
                handle_ns as f64 / 1e6,
                frame.trace.map(|c| c.trace_id).unwrap_or(0)
            );
        }
        if matches!(response, Response::Error { .. }) {
            metrics().counter("service.server.errors").inc();
        }

        let t = Instant::now();
        let payload = {
            let _s = trace::span("serve.encode");
            response.encode()
        };
        hists.encode.record(t.elapsed().as_nanos() as u64);

        let t = Instant::now();
        // Echo the request's trace context on the response — error frames
        // included — so the client can stitch causality across failures.
        let written = {
            let _s = trace::span("serve.write");
            write_frame_traced(&mut stream, opcode, response.status(), &payload, frame.trace)
        };
        hists.write.record(t.elapsed().as_nanos() as u64);
        if written.is_err() {
            break; // peer went away mid-response
        }
    }
}

/// Apply one request to the registry.
pub fn dispatch(registry: &SessionRegistry, request: Request) -> Response {
    let _s = trace::span(registry_span_name(&request));
    let result = match request {
        Request::CreateSession {
            name,
            ell,
            d,
            shards,
        } => registry
            .create(&name, ell as usize, d as usize, shards as usize)
            .map(|()| Response::Ok),
        // Mutating ops go through the registry wrappers, which append to
        // the WAL under the session's gate when durability is on.
        Request::IngestBatch {
            session,
            shard,
            rows,
        } => registry
            .ingest(&session, shard as usize, rows)
            .map(|rows_seen| Response::Ingested { rows_seen }),
        Request::MergeSketch {
            session,
            shard,
            state,
        } => registry
            .merge_sketch(&session, shard as usize, &state)
            .map(|()| Response::Ok),
        Request::Freeze { session } => registry.freeze(&session).map(Response::Frozen),
        // Score and TopK go through the registry (not the session) so the
        // scorer-budget spill-on-pressure path can evict idle sessions.
        Request::Score {
            session,
            shard,
            batch,
        } => registry
            .score(&session, shard as usize, &batch)
            .map(|()| Response::Ok),
        Request::TopK {
            session,
            method,
            k,
            num_classes,
            seed,
        } => Method::parse(&method).and_then(|method| {
            let (indices, weights) =
                registry.top_k(&session, method, k as usize, num_classes as usize, seed)?;
            Ok(Response::Selected {
                indices: indices.iter().map(|&i| i as u64).collect(),
                weights: weights.unwrap_or_default(),
            })
        }),
        Request::Checkpoint { session } => {
            registry
                .checkpoint(&session)
                .map(|(path, wal_seq)| Response::Checkpointed {
                    path: path.display().to_string(),
                    wal_seq,
                })
        }
        Request::Stats { session } => registry
            .stats_pairs(&session)
            .map(|pairs| Response::Stats { pairs }),
        Request::CloseSession { session } => registry.close(&session).map(|()| Response::Ok),
        Request::MetricsSnapshot { prefix } => {
            let reg = metrics();
            Ok(Response::Metrics {
                counters: reg.snapshot_counters(&prefix),
                gauges: reg.snapshot_gauges(&prefix),
                hists: reg.snapshot_histograms(&prefix),
            })
        }
        Request::TraceExport => Ok(Response::Trace {
            spans: trace::collect(),
        }),
    };
    match result {
        Ok(resp) => resp,
        Err(message) => Response::Error { message },
    }
}

/// Trace span name for one registry dispatch (the `registry.<op>` level of
/// the `serve.<op>` → `registry.<op>` → `kernel.<op>` hierarchy).
fn registry_span_name(request: &Request) -> &'static str {
    match request {
        Request::CreateSession { .. } => "registry.create",
        Request::IngestBatch { .. } => "registry.ingest",
        Request::MergeSketch { .. } => "registry.merge_sketch",
        Request::Freeze { .. } => "registry.freeze",
        Request::Score { .. } => "registry.score",
        Request::TopK { .. } => "registry.top_k",
        Request::Checkpoint { .. } => "registry.checkpoint",
        Request::Stats { .. } => "registry.stats",
        Request::CloseSession { .. } => "registry.close",
        Request::MetricsSnapshot { .. } => "registry.metrics_snapshot",
        Request::TraceExport => "registry.trace_export",
    }
}
