//! Push-subscription hub: turns registry mutations into coalesced
//! `TopKDelta` push frames, independently of which I/O engine (threaded or
//! reactor) owns the sockets.
//!
//! Data flow:
//!
//! ```text
//! Freeze/Score/TopK wrapper ──▶ RegistryWatcher::selection_dirty
//!        (request thread)            │  flips the sub's dirty bit
//!                                    ▼
//!                            notifier thread ──▶ Session::preview_selection
//!                              (one per hub)       (bit-exact snapshot,
//!                                    │              finalized off-lock)
//!                                    ▼
//!                         diff vs. last delivered ──▶ PushSink::try_push
//! ```
//!
//! Coalescing contract: a subscription has at most ONE pending delta at
//! any time. Deltas are cumulative from the last *delivered* selection to
//! the current one, so when a slow subscriber's write queue is full
//! ([`PushOutcome::Busy`]) the hub simply leaves the dirty bit set and
//! retries later — the retried delta is recomputed fresh and spans every
//! change since the last successful push. Epochs advance only on
//! successful enqueue; a subscriber can observe epoch gaps in *time* but
//! never in sequence (epochs it receives are consecutive), and the ordered
//! reconstruction (`protocol::apply_topk_delta`) is exact at every epoch.

use super::protocol::{apply_topk_delta, encode_frame, encode_frame_into, op, Response};
use super::registry::{RegistryWatcher, SessionRegistry};
use crate::config::Method;
use crate::util::metrics::global as metrics;
use std::collections::HashSet;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Condvar, Mutex, Weak};
use std::thread::JoinHandle;
use std::time::Duration;

/// Result of offering one encoded frame to a subscriber's write path.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PushOutcome {
    /// Enqueued (or written) — the delta is considered delivered.
    Sent,
    /// The connection's bounded write queue is over its watermark; the hub
    /// keeps the subscription dirty and retries after a drain or timeout.
    Busy,
    /// The connection is gone; the hub drops the subscription.
    Gone,
}

/// A connection's push channel. Implementations must be nonblocking: the
/// notifier thread calls this for every dirty subscription and must never
/// stall behind one slow peer.
pub trait PushSink: Send + Sync {
    fn try_push(&self, frame: Vec<u8>) -> PushOutcome;
}

/// The message GoingAway frames carry (docs/PROTOCOL.md §5). Prefix-matched
/// by `client::is_going_away`, mirroring the `connection rejected` contract.
pub const GOING_AWAY: &str = "going away";

/// Build the GoingAway error frame broadcast to subscribers on shutdown:
/// opcode 0, status 1 — the same unsolicited-error shape as connection
/// rejection, so pre-subscription clients already parse it.
pub fn going_away_frame() -> Vec<u8> {
    let resp = Response::Error {
        message: format!("{GOING_AWAY}: server shutting down"),
    };
    encode_frame(0, resp.status(), &resp.encode())
}

struct Subscription {
    conn: u64,
    session: String,
    method: Method,
    k: usize,
    num_classes: usize,
    seed: u64,
    sink: Arc<dyn PushSink>,
    /// Last delta sequence number successfully enqueued (0 = none yet).
    epoch: u64,
    /// The selection as of `epoch` — the client's reconstructed state.
    last: Vec<u64>,
    /// A mutation happened since the last successful push attempt.
    dirty: bool,
}

#[derive(Default)]
struct HubState {
    subs: Vec<Subscription>,
}

/// Shared core of the hub; also the [`RegistryWatcher`] installed into the
/// registry (which holds it for the registry's lifetime — the core keeps
/// only a `Weak` registry reference back, so there is no cycle).
pub struct HubCore {
    registry: Weak<SessionRegistry>,
    state: Mutex<HubState>,
    wake: Condvar,
    stop: AtomicBool,
}

impl RegistryWatcher for HubCore {
    fn selection_dirty(&self, session: &str) {
        let mut st = self.state.lock().unwrap();
        let mut hit = false;
        for sub in st.subs.iter_mut() {
            if sub.session == session {
                sub.dirty = true;
                hit = true;
            }
        }
        drop(st);
        if hit {
            self.wake.notify_all();
        }
    }

    fn session_closed(&self, session: &str) {
        let mut st = self.state.lock().unwrap();
        let before = st.subs.len();
        st.subs.retain(|s| s.session != session);
        let dropped = before - st.subs.len();
        drop(st);
        if dropped > 0 {
            metrics()
                .gauge("sage.server.subscriptions")
                .sub(dropped as u64);
        }
    }
}

/// How long the notifier sleeps with nothing dirty. Also the retry cadence
/// for Busy subscribers whose connection never reports a drain.
const IDLE_TICK: Duration = Duration::from_millis(25);

/// Owner handle: spawns the notifier thread on construction, joins it on
/// [`SubscriptionHub::shutdown`] (or drop).
pub struct SubscriptionHub {
    core: Arc<HubCore>,
    notifier: Mutex<Option<JoinHandle<()>>>,
}

impl SubscriptionHub {
    /// Create the hub for `registry` and install it as the registry's
    /// watcher. One hub per registry.
    pub fn new(registry: &Arc<SessionRegistry>) -> Arc<SubscriptionHub> {
        let core = Arc::new(HubCore {
            registry: Arc::downgrade(registry),
            state: Mutex::new(HubState::default()),
            wake: Condvar::new(),
            stop: AtomicBool::new(false),
        });
        registry.set_watcher(core.clone());
        let worker = core.clone();
        let notifier = std::thread::Builder::new()
            .name("sage-subs".into())
            .spawn(move || notifier_loop(worker))
            .expect("spawn subscription notifier");
        Arc::new(SubscriptionHub {
            core,
            notifier: Mutex::new(Some(notifier)),
        })
    }

    /// Register (or re-register) a subscription. Validates the session and
    /// method eagerly so the client's Subscribe response carries the error.
    /// Re-subscribing the same (connection, session) replaces the selection
    /// parameters and restarts the delta stream from epoch 1.
    pub fn subscribe(
        &self,
        conn: u64,
        sink: Arc<dyn PushSink>,
        session: &str,
        method: &str,
        k: usize,
        num_classes: usize,
        seed: u64,
    ) -> Result<(), String> {
        let method = Method::parse(method)?;
        if method == Method::Glister {
            return Err("GLISTER needs a validation split; unsupported by the service".into());
        }
        let registry = self
            .core
            .registry
            .upgrade()
            .ok_or_else(|| "server shutting down".to_string())?;
        registry.get(session)?; // unknown sessions fail the Subscribe itself
        let mut st = self.core.state.lock().unwrap();
        let replaced = st
            .subs
            .iter()
            .position(|s| s.conn == conn && s.session == session);
        let sub = Subscription {
            conn,
            session: session.to_string(),
            method,
            k,
            num_classes,
            seed,
            sink,
            epoch: 0,
            last: Vec::new(),
            // Dirty from birth: if the session already has a selection the
            // subscriber gets its baseline snapshot delta immediately.
            dirty: true,
        };
        match replaced {
            Some(i) => st.subs[i] = sub,
            None => {
                st.subs.push(sub);
                metrics().gauge("sage.server.subscriptions").add(1);
            }
        }
        drop(st);
        self.core.wake.notify_all();
        Ok(())
    }

    /// Remove one subscription. Ok even if it does not exist (unsubscribe
    /// races a close); returns whether one was removed.
    pub fn unsubscribe(&self, conn: u64, session: &str) -> bool {
        let mut st = self.core.state.lock().unwrap();
        let before = st.subs.len();
        st.subs.retain(|s| !(s.conn == conn && s.session == session));
        let removed = before != st.subs.len();
        drop(st);
        if removed {
            metrics().gauge("sage.server.subscriptions").sub(1);
        }
        removed
    }

    /// Drop every subscription owned by a connection (connection closed).
    pub fn drop_conn(&self, conn: u64) {
        let mut st = self.core.state.lock().unwrap();
        let before = st.subs.len();
        st.subs.retain(|s| s.conn != conn);
        let dropped = before - st.subs.len();
        drop(st);
        if dropped > 0 {
            metrics()
                .gauge("sage.server.subscriptions")
                .sub(dropped as u64);
        }
    }

    /// A connection's write queue drained below its low watermark: retry
    /// any Busy subscriptions now instead of waiting out the idle tick.
    pub fn kick(&self) {
        self.core.wake.notify_all();
    }

    /// Live subscription count (tests / bench).
    pub fn subscription_count(&self) -> usize {
        self.core.state.lock().unwrap().subs.len()
    }

    /// Broadcast the GoingAway frame to every subscriber's sink (best
    /// effort — Busy or Gone sinks are skipped) and drop all
    /// subscriptions. Called by both server modes at shutdown, before
    /// connections close.
    pub fn going_away(&self) {
        let frame = going_away_frame();
        let subs = {
            let mut st = self.core.state.lock().unwrap();
            std::mem::take(&mut st.subs)
        };
        if !subs.is_empty() {
            metrics()
                .gauge("sage.server.subscriptions")
                .sub(subs.len() as u64);
        }
        // One frame per *connection*, not per subscription — a client with
        // several sessions subscribed gets a single GoingAway.
        let mut seen = HashSet::new();
        for sub in subs {
            if seen.insert(sub.conn) {
                let _ = sub.sink.try_push(frame.clone());
            }
        }
    }

    /// Stop the notifier thread and join it.
    pub fn shutdown(&self) {
        self.core.stop.store(true, Ordering::Relaxed);
        self.core.wake.notify_all();
        if let Some(join) = self.notifier.lock().unwrap().take() {
            let _ = join.join();
        }
    }
}

impl Drop for SubscriptionHub {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// One claimed unit of notifier work: recompute this subscription's
/// preview and push the delta.
struct WorkItem {
    conn: u64,
    session: String,
    method: Method,
    k: usize,
    num_classes: usize,
    seed: u64,
    sink: Arc<dyn PushSink>,
    epoch: u64,
    last: Vec<u64>,
}

fn notifier_loop(core: Arc<HubCore>) {
    loop {
        // Claim dirty subscriptions (clearing their bits — a mutation
        // racing the preview sets them again, forcing a recompute).
        let work: Vec<WorkItem> = {
            let mut st = core.state.lock().unwrap();
            loop {
                if core.stop.load(Ordering::Relaxed) {
                    return;
                }
                if st.subs.iter().any(|s| s.dirty) {
                    break;
                }
                let (guard, _) = core.wake.wait_timeout(st, IDLE_TICK).unwrap();
                st = guard;
                if core.stop.load(Ordering::Relaxed) {
                    return;
                }
            }
            st.subs
                .iter_mut()
                .filter(|s| s.dirty)
                .map(|s| {
                    s.dirty = false;
                    WorkItem {
                        conn: s.conn,
                        session: s.session.clone(),
                        method: s.method,
                        k: s.k,
                        num_classes: s.num_classes,
                        seed: s.seed,
                        sink: s.sink.clone(),
                        epoch: s.epoch,
                        last: s.last.clone(),
                    }
                })
                .collect()
        };
        let Some(registry) = core.registry.upgrade() else {
            return;
        };
        for item in work {
            // Preview outside the hub lock: kernels may run here, and
            // Subscribe/Unsubscribe must never wait on them.
            let Some((cur, watermark)) =
                registry.preview_selection(&item.session, item.method, item.k, item.num_classes, item.seed)
            else {
                // Unknown session (closed mid-flight — session_closed has
                // or will drop the sub) or nothing previewable yet; either
                // way there is nothing to push and the next mutation
                // re-marks the subscription dirty.
                continue;
            };
            if cur == item.last {
                continue; // mutation did not move the selection
            }
            let (added, evicted) = diff_selection(&item.last, &cur);
            let resp = Response::TopKDelta {
                session: item.session.clone(),
                epoch: item.epoch + 1,
                added,
                evicted,
                watermark,
            };
            // Push frames ride the Subscribe opcode with ok status; clients
            // demux on the payload kind tag (protocol docs §3.14). Both the
            // payload and the frame come from (and, on Busy/Gone, return
            // to) the buffer pool.
            let pool = crate::util::bufpool::global();
            let mut payload = pool.take();
            resp.encode_into(&mut payload);
            let mut frame = pool.take();
            encode_frame_into(&mut frame, op::SUBSCRIBE, 0, &payload);
            pool.put(payload);
            let outcome = item.sink.try_push(frame);
            let mut st = core.state.lock().unwrap();
            let Some(sub) = st
                .subs
                .iter_mut()
                .find(|s| s.conn == item.conn && s.session == item.session)
            else {
                continue; // unsubscribed while we computed
            };
            // A re-subscribe may have reset the stream while we worked;
            // only commit against the epoch we computed from.
            if sub.epoch != item.epoch {
                continue;
            }
            match outcome {
                PushOutcome::Sent => {
                    sub.epoch += 1;
                    sub.last = cur;
                    metrics().counter("service.subs.deltas_sent").inc();
                }
                PushOutcome::Busy => {
                    // Coalesce: stay dirty, retry after a drain kick or the
                    // idle tick. The eventual delta covers this change too.
                    sub.dirty = true;
                    metrics().counter("service.subs.deltas_coalesced").inc();
                }
                PushOutcome::Gone => {
                    let conn = sub.conn;
                    st.subs.retain(|s| s.conn != conn);
                    drop(st);
                    metrics().gauge("sage.server.subscriptions").sub(1);
                    continue;
                }
            }
        }
    }
}

/// Diff two selections into (added, evicted) such that the ordered
/// reconstruction (`apply_topk_delta`) is exact. When the retained prefix
/// reordered (possible for rules whose order is score-dependent), fall
/// back to a full snapshot delta — evict everything, add the new list —
/// which is always exact.
fn diff_selection(last: &[u64], cur: &[u64]) -> (Vec<u64>, Vec<u64>) {
    let last_set: HashSet<u64> = last.iter().copied().collect();
    let cur_set: HashSet<u64> = cur.iter().copied().collect();
    let added: Vec<u64> = cur.iter().copied().filter(|i| !last_set.contains(i)).collect();
    let evicted: Vec<u64> = last.iter().copied().filter(|i| !cur_set.contains(i)).collect();
    let mut recon = last.to_vec();
    let valid = apply_topk_delta(&mut recon, &added, &evicted).is_ok();
    if valid && recon == cur {
        (added, evicted)
    } else {
        (cur.to_vec(), last.to_vec())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::service::protocol::FrameDecoder;
    use crate::service::registry::RegistryConfig;
    use crate::tensor::Matrix;
    use std::sync::Mutex as StdMutex;

    /// Sink that records every pushed frame; can be switched to Busy/Gone.
    struct RecordingSink {
        frames: StdMutex<Vec<Vec<u8>>>,
        mode: StdMutex<PushOutcome>,
    }

    impl RecordingSink {
        fn new() -> Arc<RecordingSink> {
            Arc::new(RecordingSink {
                frames: StdMutex::new(Vec::new()),
                mode: StdMutex::new(PushOutcome::Sent),
            })
        }
        fn set_mode(&self, mode: PushOutcome) {
            *self.mode.lock().unwrap() = mode;
        }
        fn deltas(&self) -> Vec<Response> {
            self.frames
                .lock()
                .unwrap()
                .iter()
                .map(|bytes| {
                    let mut dec = FrameDecoder::new();
                    dec.extend(bytes);
                    let frame = dec.next_frame().unwrap().unwrap();
                    Response::decode(&frame.payload).unwrap()
                })
                .collect()
        }
    }

    impl PushSink for RecordingSink {
        fn try_push(&self, frame: Vec<u8>) -> PushOutcome {
            let mode = *self.mode.lock().unwrap();
            if mode == PushOutcome::Sent {
                self.frames.lock().unwrap().push(frame);
            }
            mode
        }
    }

    fn scored_registry() -> Arc<SessionRegistry> {
        let registry = Arc::new(SessionRegistry::new(RegistryConfig::default()));
        registry.create("s", 4, 8, 1).unwrap();
        registry
            .ingest("s", 0, Matrix::from_fn(6, 8, |r, c| ((r * 13 + c * 7) % 5) as f32 - 2.0))
            .unwrap();
        registry.freeze("s").unwrap();
        registry
    }

    fn score_one(registry: &SessionRegistry, start: u64, n: usize) {
        let batch = crate::service::protocol::ScoreBatch {
            indices: (start..start + n as u64).collect(),
            labels: (0..n as u32).map(|i| i % 3).collect(),
            norms: (0..n).map(|i| 1.0 + i as f32 * 0.25).collect(),
            losses: (0..n).map(|i| 0.5 + i as f32 * 0.125).collect(),
            zhat: Matrix::from_fn(n, 4, |r, c| {
                let v = ((r * 5 + c * 3 + start as usize) % 7) as f32 - 3.0;
                v / 4.0
            }),
        };
        registry.score("s", 0, &batch).unwrap();
    }

    fn wait_for<F: Fn() -> bool>(what: &str, cond: F) {
        for _ in 0..400 {
            if cond() {
                return;
            }
            std::thread::sleep(Duration::from_millis(5));
        }
        panic!("timed out waiting for {what}");
    }

    #[test]
    fn deltas_reconstruct_to_the_served_selection() {
        let registry = scored_registry();
        let hub = SubscriptionHub::new(&registry);
        let sink = RecordingSink::new();
        hub.subscribe(1, sink.clone(), "s", "sage", 4, 3, 0).unwrap();

        score_one(&registry, 0, 6);
        wait_for("first delta", || !sink.deltas().is_empty());
        score_one(&registry, 6, 6);
        score_one(&registry, 12, 6);
        let (offline, _) = registry.top_k("s", Method::Sage, 4, 3, 0).unwrap();
        let expect: Vec<u64> = offline.iter().map(|&i| i as u64).collect();
        wait_for("converged reconstruction", || {
            let mut recon: Vec<u64> = Vec::new();
            for d in sink.deltas() {
                if let Response::TopKDelta { added, evicted, .. } = d {
                    apply_topk_delta(&mut recon, &added, &evicted).unwrap();
                }
            }
            recon == expect
        });
        // Epochs delivered are consecutive starting at 1.
        let epochs: Vec<u64> = sink
            .deltas()
            .iter()
            .filter_map(|d| match d {
                Response::TopKDelta { epoch, .. } => Some(*epoch),
                _ => None,
            })
            .collect();
        assert_eq!(epochs, (1..=epochs.len() as u64).collect::<Vec<_>>());
        hub.shutdown();
    }

    #[test]
    fn busy_sink_coalesces_and_recovers() {
        let registry = scored_registry();
        let hub = SubscriptionHub::new(&registry);
        let sink = RecordingSink::new();
        sink.set_mode(PushOutcome::Busy);
        hub.subscribe(1, sink.clone(), "s", "sage", 3, 3, 0).unwrap();

        score_one(&registry, 0, 5);
        score_one(&registry, 5, 5);
        score_one(&registry, 10, 5);
        // Busy the whole time: nothing delivered, subscription survives.
        std::thread::sleep(Duration::from_millis(120));
        assert!(sink.deltas().is_empty());
        assert_eq!(hub.subscription_count(), 1);

        sink.set_mode(PushOutcome::Sent);
        hub.kick();
        wait_for("coalesced catch-up delta", || !sink.deltas().is_empty());
        // The catch-up must reconstruct to the full current selection in
        // ONE delta (epoch 1 — nothing was delivered while busy).
        let deltas = sink.deltas();
        let Response::TopKDelta { epoch, added, evicted, .. } = &deltas[0] else {
            panic!("expected TopKDelta");
        };
        assert_eq!(*epoch, 1);
        assert!(evicted.is_empty());
        let (offline, _) = registry.top_k("s", Method::Sage, 3, 3, 0).unwrap();
        let expect: Vec<u64> = offline.iter().map(|&i| i as u64).collect();
        assert_eq!(added, &expect);
        hub.shutdown();
    }

    #[test]
    fn gone_sink_and_close_drop_subscriptions() {
        let registry = scored_registry();
        let hub = SubscriptionHub::new(&registry);
        let sink = RecordingSink::new();
        sink.set_mode(PushOutcome::Gone);
        hub.subscribe(1, sink.clone(), "s", "sage", 3, 3, 0).unwrap();
        score_one(&registry, 0, 5);
        wait_for("gone sink dropped", || hub.subscription_count() == 0);

        let sink2 = RecordingSink::new();
        hub.subscribe(2, sink2, "s", "sage", 3, 3, 0).unwrap();
        assert_eq!(hub.subscription_count(), 1);
        registry.close("s").unwrap();
        assert_eq!(hub.subscription_count(), 0);
        hub.shutdown();
    }

    #[test]
    fn subscribe_validates_session_and_method() {
        let registry = scored_registry();
        let hub = SubscriptionHub::new(&registry);
        let sink = RecordingSink::new();
        assert!(hub
            .subscribe(1, sink.clone(), "nope", "sage", 3, 3, 0)
            .unwrap_err()
            .contains("unknown session"));
        assert!(hub
            .subscribe(1, sink.clone(), "s", "glister", 3, 3, 0)
            .is_err());
        assert!(hub.subscribe(1, sink, "s", "not-a-method", 3, 3, 0).is_err());
        assert_eq!(hub.subscription_count(), 0);
        hub.shutdown();
    }

    #[test]
    fn going_away_broadcasts_once_per_connection() {
        let registry = scored_registry();
        let hub = SubscriptionHub::new(&registry);
        registry.create("s2", 4, 8, 1).unwrap();
        let sink = RecordingSink::new();
        hub.subscribe(1, sink.clone(), "s", "sage", 3, 3, 0).unwrap();
        hub.subscribe(1, sink.clone(), "s2", "sage", 3, 3, 0).unwrap();
        hub.going_away();
        assert_eq!(hub.subscription_count(), 0);
        let frames = sink.frames.lock().unwrap();
        assert_eq!(frames.len(), 1, "one GoingAway per connection");
        let mut dec = FrameDecoder::new();
        dec.extend(&frames[0]);
        let frame = dec.next_frame().unwrap().unwrap();
        assert_eq!(frame.opcode, 0);
        assert_eq!(frame.status, 1);
        match Response::decode(&frame.payload).unwrap() {
            Response::Error { message } => assert!(message.starts_with(GOING_AWAY)),
            other => panic!("expected Error frame, got {other:?}"),
        }
        hub.shutdown();
    }

    #[test]
    fn diff_falls_back_to_snapshot_on_reorder() {
        // Same membership, different order: member-diff is empty, so the
        // snapshot fallback must engage to keep reconstruction exact.
        let (added, evicted) = diff_selection(&[1, 2, 3], &[3, 2, 1]);
        assert_eq!(added, vec![3, 2, 1]);
        assert_eq!(evicted, vec![1, 2, 3]);
        let mut recon = vec![1, 2, 3];
        apply_topk_delta(&mut recon, &added, &evicted).unwrap();
        assert_eq!(recon, vec![3, 2, 1]);
    }
}
