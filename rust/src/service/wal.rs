//! Per-shard write-ahead log for durable ingest.
//!
//! Every state-mutating wire op (CreateSession, IngestBatch, MergeSketch,
//! Freeze, Score, TopK, CloseSession) is appended here *after* it applies
//! and *before* it is acknowledged: an acked op is in the log, an unacked
//! op may be lost and retried — the wire protocol's existing at-least-once
//! contract (docs/PROTOCOL.md §5). Because FD insertion, shard-order
//! merges, and scorer accumulation are deterministic (the paper's core
//! guarantee; enforced bit-for-bit by `tests/kernel_determinism.rs`),
//! replaying the log on top of the newest checkpoint reproduces session
//! state *exactly* — durability comes for free from determinism.
//!
//! ## Record format (docs/PROTOCOL.md §9, golden-tested)
//!
//! ```text
//! len      u32   byte length of seq + op + payload (= 9 + payload len)
//! seq      u64   global monotone sequence number (1-based)
//! op       u8    wire opcode of the logged request
//! payload  …     Request::encode() bytes (the wire payload codec)
//! fnv64    u64   FNV-1a 64 checksum of len + seq + op + payload
//! ```
//!
//! `seq` is global across all shards, so per-session replay watermarks in
//! checkpoints stay valid even if the shard count changes between runs;
//! each shard's segment holds a strictly increasing subsequence and replay
//! merges all shards by `seq`.
//!
//! ## Segments, torn tails, compaction
//!
//! Records append to `wal/shard-NNN/segment-<first_seq>.sagewal` objects
//! behind a [`StorageBackend`]. On open, every existing segment is scanned
//! record by record: the first invalid record (bad length, checksum
//! mismatch, sequence regression) marks a torn tail, which is truncated
//! with a WARN — never a panic — and any later segments in that shard are
//! dropped. Compaction (`--wal-compact-mb`) rotates a shard to a fresh
//! segment *first*, then checkpoints the shard's sessions (whose embedded
//! `wal_seq` watermarks then cover every record in the old segments), then
//! deletes the old segments — crash-safe in any interleaving because
//! replay skips records at or below a session's watermark.
//!
//! ## Group commit
//!
//! With `--durability sync`, an appender must not return before its record
//! is fsynced, but concurrent appenders share one fsync: the first waiter
//! becomes the leader, snapshots the shard's last appended seq, fsyncs on
//! a cloned descriptor *outside* the shard lock (so followers keep
//! appending), then publishes the synced watermark and wakes everyone at
//! or below it. `--durability async` flushes without fsync (survives a
//! process crash, not a host crash); `none` disables the WAL.

use crate::service::protocol::{fnv64, MAX_PAYLOAD};
use crate::service::storage::{AppendHandle, StorageBackend, SyncHandle};
use crate::util::metrics::{global as metrics, Counter, Histogram};
use crate::{log_error, log_warn};
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::Instant;

/// Fixed per-record overhead: `len` prefix + `seq` + `op` + `fnv64`.
pub const RECORD_OVERHEAD: usize = 4 + 8 + 1 + 8;

/// Durability level for acknowledged mutating ops.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum Durability {
    /// No WAL: a crash loses everything since the last explicit checkpoint.
    #[default]
    None,
    /// Append + flush before ack: survives a process crash, not a host
    /// crash (the OS page cache holds the tail).
    Async,
    /// Append + group-commit fsync before ack: survives host crashes.
    Sync,
}

impl Durability {
    pub fn parse(s: &str) -> Result<Self, String> {
        match s {
            "none" => Ok(Durability::None),
            "async" => Ok(Durability::Async),
            "sync" => Ok(Durability::Sync),
            other => Err(format!("unknown durability '{other}' (none|async|sync)")),
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Durability::None => "none",
            Durability::Async => "async",
            Durability::Sync => "sync",
        }
    }
}

/// Crash-injection hooks for the durability test harness: the process
/// aborts (SIGABRT, no destructors) at an exact global record boundary.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct WalFaultPlan {
    /// Abort immediately after record `seq` is appended and synced.
    pub abort_at: Option<u64>,
    /// Write only a prefix of record `seq` (a torn tail), sync, and abort.
    pub torn_at: Option<u64>,
}

impl WalFaultPlan {
    /// Read the plan from `SAGE_WAL_ABORT_AT` / `SAGE_WAL_TORN_AT` (used by
    /// the `sage serve` subprocess tests in `tests/integration_durability`).
    pub fn from_env() -> Self {
        fn get(name: &str) -> Option<u64> {
            std::env::var(name).ok().and_then(|v| v.parse().ok())
        }
        Self {
            abort_at: get("SAGE_WAL_ABORT_AT"),
            torn_at: get("SAGE_WAL_TORN_AT"),
        }
    }
}

/// Open-time configuration (carried in `RegistryConfig`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct WalConfig {
    /// Writer shard count — the registry's (normalized) shard count.
    pub shards: usize,
    pub durability: Durability,
    /// Per-shard segment bytes that trigger compaction (0 = never).
    pub compact_bytes: u64,
    /// Sequence floor: `open` never hands out a seq at or below this. The
    /// registry passes the highest `wal_seq` watermark across recovered
    /// checkpoints — after a compact-then-restart cycle no segment records
    /// may survive while checkpoints still carry high watermarks, and a
    /// fresh acked record assigned a seq at or below a watermark would be
    /// silently skipped by the next replay (a lost durable write).
    pub seq_floor: u64,
    pub fault: WalFaultPlan,
}

/// One decoded log record.
#[derive(Clone, Debug, PartialEq)]
pub struct WalRecord {
    pub seq: u64,
    pub op: u8,
    pub payload: Vec<u8>,
}

/// Serialize one record (see the module docs for the layout).
pub fn encode_record(seq: u64, op: u8, payload: &[u8]) -> Vec<u8> {
    let len = 9 + payload.len();
    let mut out = Vec::with_capacity(4 + len + 8);
    out.extend_from_slice(&(len as u32).to_le_bytes());
    out.extend_from_slice(&seq.to_le_bytes());
    out.push(op);
    out.extend_from_slice(payload);
    let sum = fnv64(&out);
    out.extend_from_slice(&sum.to_le_bytes());
    out
}

/// Decode the record at the head of `buf`. `Ok(None)` on empty input
/// (clean segment end); `Ok(Some((record, consumed_bytes)))` on success.
///
/// # Errors
/// Anything torn: a truncated length prefix, an implausible length, a
/// truncated body or checksum, or a checksum mismatch.
pub fn decode_record(buf: &[u8]) -> Result<Option<(WalRecord, usize)>, String> {
    if buf.is_empty() {
        return Ok(None);
    }
    if buf.len() < 4 {
        return Err("truncated length prefix".into());
    }
    let len = u32::from_le_bytes(buf[0..4].try_into().unwrap()) as usize;
    if !(9..=MAX_PAYLOAD + 9).contains(&len) {
        return Err(format!("implausible record length {len}"));
    }
    let total = 4 + len + 8;
    if buf.len() < total {
        return Err(format!(
            "truncated record ({} of {total} bytes)",
            buf.len()
        ));
    }
    let stored = u64::from_le_bytes(buf[4 + len..total].try_into().unwrap());
    if fnv64(&buf[..4 + len]) != stored {
        return Err("record checksum mismatch".into());
    }
    let seq = u64::from_le_bytes(buf[4..12].try_into().unwrap());
    if seq == 0 {
        return Err("record sequence 0".into());
    }
    let op = buf[12];
    Ok(Some((
        WalRecord {
            seq,
            op,
            payload: buf[13..4 + len].to_vec(),
        },
        total,
    )))
}

/// Scan a whole segment: the valid record prefix, the byte offset where
/// validity ends, and — if the tail is torn — why.
fn scan_segment(bytes: &[u8]) -> (Vec<WalRecord>, usize, Option<String>) {
    let mut records: Vec<WalRecord> = Vec::new();
    let mut pos = 0usize;
    loop {
        match decode_record(&bytes[pos..]) {
            Ok(None) => return (records, pos, None),
            Ok(Some((rec, consumed))) => {
                if let Some(last) = records.last() {
                    if rec.seq <= last.seq {
                        return (
                            records,
                            pos,
                            Some(format!(
                                "sequence regression ({} after {})",
                                rec.seq, last.seq
                            )),
                        );
                    }
                }
                records.push(rec);
                pos += consumed;
            }
            Err(reason) => return (records, pos, Some(reason)),
        }
    }
}

fn segment_key(shard: usize, first_seq: u64) -> String {
    format!("wal/shard-{shard:03}/segment-{first_seq:020}.sagewal")
}

struct ShardState {
    writer: Box<dyn AppendHandle>,
    syncer: Arc<dyn SyncHandle>,
    /// Segments owned since open / the last rotation (last = current).
    keys: Vec<String>,
    /// Bytes appended since the last rotation.
    bytes: u64,
    /// Highest seq appended to this shard.
    last_seq: u64,
    /// Highest seq known fsynced on this shard.
    synced_seq: u64,
    /// A group-commit leader is fsyncing off-lock.
    sync_in_flight: bool,
}

struct WalShard {
    state: Mutex<ShardState>,
    commit_cv: Condvar,
    compacting: AtomicBool,
}

/// The write-ahead log: one appender per registry shard over a shared
/// [`StorageBackend`], with a global sequence counter.
pub struct Wal {
    storage: Arc<dyn StorageBackend>,
    durability: Durability,
    compact_bytes: u64,
    fault: WalFaultPlan,
    next_seq: AtomicU64,
    /// Poisoned by an append/fsync failure: the log can no longer promise
    /// durability, so every later mutating op is refused until restart.
    failed: AtomicBool,
    shards: Vec<WalShard>,
    /// Segment keys that predate this open — replayed, then deleted by the
    /// registry's startup compaction once covering checkpoints exist.
    stale: Mutex<Vec<String>>,
    c_records: &'static Counter,
    c_bytes: &'static Counter,
    h_append: &'static Histogram,
    h_fsync: &'static Histogram,
}

impl Wal {
    /// Open (or create) the log under `storage`: scan every existing
    /// segment, truncate torn tails, and return the surviving records
    /// sorted by `seq` for replay, alongside the ready-to-append log.
    ///
    /// # Errors
    /// Storage failures. Torn tails are repaired, never errors.
    pub fn open(
        storage: Arc<dyn StorageBackend>,
        cfg: &WalConfig,
    ) -> Result<(Self, Vec<WalRecord>), String> {
        let m = metrics();
        let c_truncated = m.counter("service.wal.truncated_tails");
        let mut dirs: BTreeMap<String, Vec<String>> = BTreeMap::new();
        for key in storage.list("wal/")? {
            let dir = key
                .rsplit_once('/')
                .map(|(d, _)| d.to_string())
                .unwrap_or_default();
            dirs.entry(dir).or_default().push(key);
        }
        let mut records: Vec<WalRecord> = Vec::new();
        let mut stale: Vec<String> = Vec::new();
        let mut max_seq = 0u64;
        for (dir, keys) in &dirs {
            let mut torn_in_dir = false;
            for key in keys {
                if torn_in_dir {
                    // Segments after a torn one cannot be trusted: the
                    // shard's suffix is gone from the torn offset onward.
                    log_warn!("wal: dropping segment {key} after a torn predecessor in {dir}");
                    storage.delete(key)?;
                    continue;
                }
                let bytes = storage.read(key)?.unwrap_or_default();
                if bytes.is_empty() {
                    // Empty segments carry nothing and could collide with
                    // the fresh segment name chosen below.
                    storage.delete(key)?;
                    continue;
                }
                let (recs, valid, torn) = scan_segment(&bytes);
                if let Some(reason) = torn {
                    log_warn!(
                        "wal: torn tail in {key} at byte {valid} ({reason}); truncating \
                         {} invalid bytes",
                        bytes.len() - valid
                    );
                    c_truncated.inc();
                    torn_in_dir = true;
                    if valid == 0 {
                        storage.delete(key)?;
                        continue;
                    }
                    storage.truncate(key, valid as u64)?;
                }
                max_seq = recs.iter().map(|r| r.seq).fold(max_seq, u64::max);
                records.extend(recs);
                stale.push(key.clone());
            }
        }
        let next = max_seq.max(cfg.seq_floor) + 1;
        let mut shards = Vec::with_capacity(cfg.shards);
        for i in 0..cfg.shards {
            let key = segment_key(i, next);
            let writer = storage.open_append(&key)?;
            let syncer = writer.syncer()?;
            shards.push(WalShard {
                state: Mutex::new(ShardState {
                    writer,
                    syncer,
                    keys: vec![key],
                    bytes: 0,
                    last_seq: 0,
                    synced_seq: 0,
                    sync_in_flight: false,
                }),
                commit_cv: Condvar::new(),
                compacting: AtomicBool::new(false),
            });
        }
        records.sort_by_key(|r| r.seq);
        Ok((
            Self {
                storage,
                durability: cfg.durability,
                compact_bytes: cfg.compact_bytes,
                fault: cfg.fault,
                next_seq: AtomicU64::new(next),
                failed: AtomicBool::new(false),
                shards,
                stale: Mutex::new(stale),
                c_records: m.counter("service.wal.records"),
                c_bytes: m.counter("service.wal.bytes"),
                h_append: m.histogram("service.wal.append.ns"),
                h_fsync: m.histogram("service.wal.fsync.ns"),
            },
            records,
        ))
    }

    pub fn durability(&self) -> Durability {
        self.durability
    }

    /// Highest sequence number handed out so far (0 = empty log).
    pub fn last_seq(&self) -> u64 {
        self.next_seq.load(Ordering::Relaxed) - 1
    }

    /// Segment keys that predate this open (already replayed).
    pub fn has_stale_segments(&self) -> bool {
        !self.stale.lock().unwrap().is_empty()
    }

    /// Delete the pre-open segments. Call only after every live session is
    /// re-checkpointed (watermarks then cover all replayed records). On a
    /// failure, the keys not yet deleted go back on the stale list so a
    /// later pass can retry.
    pub fn purge_stale_segments(&self) -> Result<usize, String> {
        let keys = std::mem::take(&mut *self.stale.lock().unwrap());
        for (i, key) in keys.iter().enumerate() {
            if let Err(e) = self.storage.delete(key) {
                self.stale.lock().unwrap().extend_from_slice(&keys[i..]);
                return Err(e);
            }
        }
        Ok(keys.len())
    }

    /// Put sealed segment keys back on the stale list so a later
    /// compaction (or the next startup purge) retries their deletion.
    /// Needed when a compaction's checkpoint or delete step fails after
    /// `rotate` already sealed them: the rotation reset the shard's byte
    /// counter, so `wants_compaction` alone would never refire for them.
    pub fn retain_stale(&self, keys: Vec<String>) {
        self.stale.lock().unwrap().extend(keys);
    }

    /// Append one record for `op` to `shard` and honor the durability
    /// level before returning its sequence number.
    ///
    /// # Errors
    /// Storage append/fsync failures — which also poison the log: state
    /// already applied in memory can no longer be promised durable, so all
    /// later appends are refused until the process restarts and replays.
    pub fn append(&self, shard: usize, op: u8, payload: &[u8]) -> Result<u64, String> {
        if self.failed.load(Ordering::Relaxed) {
            return Err("wal: poisoned by an earlier append failure; restart to recover".into());
        }
        let t0 = Instant::now();
        let sh = &self.shards[shard % self.shards.len()];
        let mut st = sh.state.lock().unwrap();
        let seq = self.next_seq.fetch_add(1, Ordering::Relaxed);
        let frame = encode_record(seq, op, payload);

        if self.fault.torn_at == Some(seq) {
            // Fault injection: persist a prefix of the record, then die —
            // recovery must truncate this tail with a WARN.
            let cut = (frame.len() * 2 / 3).max(1);
            let _ = st.writer.append(&frame[..cut]);
            let _ = st.writer.flush();
            let _ = st.syncer.sync();
            log_error!("wal: fault injection — torn write at record {seq}; aborting");
            std::process::abort();
        }

        if let Err(e) = st
            .writer
            .append(&frame)
            .and_then(|()| st.writer.flush())
        {
            self.failed.store(true, Ordering::Relaxed);
            return Err(format!("wal append (seq {seq}): {e}"));
        }
        st.bytes += frame.len() as u64;
        st.last_seq = seq;
        self.c_records.inc();
        self.c_bytes.add(frame.len() as u64);

        if self.fault.abort_at == Some(seq) {
            let _ = st.syncer.sync();
            log_error!("wal: fault injection — abort after record {seq}");
            std::process::abort();
        }

        if self.durability == Durability::Sync {
            // Group commit: first un-synced waiter leads, fsyncs off-lock.
            loop {
                if st.synced_seq >= seq {
                    break;
                }
                if !st.sync_in_flight {
                    st.sync_in_flight = true;
                    let target = st.last_seq;
                    let syncer = Arc::clone(&st.syncer);
                    drop(st);
                    let f0 = Instant::now();
                    let res = syncer.sync();
                    self.h_fsync.record(f0.elapsed().as_nanos() as u64);
                    st = sh.state.lock().unwrap();
                    st.sync_in_flight = false;
                    if res.is_ok() && st.synced_seq < target {
                        st.synced_seq = target;
                    }
                    sh.commit_cv.notify_all();
                    if let Err(e) = res {
                        self.failed.store(true, Ordering::Relaxed);
                        return Err(format!("wal fsync (seq {seq}): {e}"));
                    }
                } else {
                    st = sh.commit_cv.wait(st).unwrap();
                }
            }
        }
        drop(st);
        self.h_append.record(t0.elapsed().as_nanos() as u64);
        Ok(seq)
    }

    /// True when `shard` has outgrown `--wal-compact-mb` and no compaction
    /// is already running there.
    pub fn wants_compaction(&self, shard: usize) -> bool {
        if self.compact_bytes == 0 || self.failed.load(Ordering::Relaxed) {
            return false;
        }
        let sh = &self.shards[shard % self.shards.len()];
        !sh.compacting.load(Ordering::Relaxed)
            && sh.state.lock().unwrap().bytes >= self.compact_bytes
    }

    /// Claim the compaction slot for `shard` (false = already claimed).
    pub fn begin_compaction(&self, shard: usize) -> bool {
        self.shards[shard % self.shards.len()]
            .compacting
            .compare_exchange(false, true, Ordering::Acquire, Ordering::Relaxed)
            .is_ok()
    }

    pub fn end_compaction(&self, shard: usize) {
        self.shards[shard % self.shards.len()]
            .compacting
            .store(false, Ordering::Release);
    }

    /// Swap `shard` onto a fresh segment and return the old segment keys.
    /// New appends land in the fresh segment immediately; the caller then
    /// checkpoints the shard's sessions (covering every old record) before
    /// deleting the returned keys — crash-safe in either order because
    /// replay skips records at or below each session's watermark.
    pub fn rotate(&self, shard: usize) -> Result<Vec<String>, String> {
        let sh = &self.shards[shard % self.shards.len()];
        let mut st = sh.state.lock().unwrap();
        if st.bytes == 0 && st.keys.len() == 1 {
            return Ok(Vec::new()); // nothing to compact; avoid a key collision
        }
        st.writer.flush()?;
        st.syncer.sync()?;
        let key = segment_key(shard, self.next_seq.load(Ordering::Relaxed));
        let writer = self.storage.open_append(&key)?;
        let syncer = writer.syncer()?;
        let old = std::mem::take(&mut st.keys);
        st.writer = writer;
        st.syncer = syncer;
        st.keys = vec![key];
        st.bytes = 0;
        let last = st.last_seq;
        if st.synced_seq < last {
            st.synced_seq = last;
        }
        sh.commit_cv.notify_all();
        metrics().counter("service.wal.compactions").inc();
        Ok(old)
    }

    /// Delete retired segment objects (post-checkpoint compaction step).
    pub fn delete_segments(&self, keys: &[String]) -> Result<(), String> {
        for key in keys {
            self.storage.delete(key)?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::service::storage::MemStorage;

    fn cfg(shards: usize, durability: Durability) -> WalConfig {
        WalConfig {
            shards,
            durability,
            compact_bytes: 0,
            seq_floor: 0,
            fault: WalFaultPlan::default(),
        }
    }

    #[test]
    fn record_codec_round_trips_and_matches_the_documented_layout() {
        let payload = vec![0xAAu8, 0xBB, 0xCC];
        let frame = encode_record(7, 2, &payload);
        assert_eq!(frame.len(), RECORD_OVERHEAD + payload.len());
        // len prefix counts seq + op + payload.
        assert_eq!(&frame[0..4], &12u32.to_le_bytes());
        assert_eq!(&frame[4..12], &7u64.to_le_bytes());
        assert_eq!(frame[12], 2);
        assert_eq!(&frame[13..16], &payload[..]);
        let sum = fnv64(&frame[..16]);
        assert_eq!(&frame[16..24], &sum.to_le_bytes());
        let (rec, consumed) = decode_record(&frame).unwrap().unwrap();
        assert_eq!(consumed, frame.len());
        assert_eq!(rec, WalRecord { seq: 7, op: 2, payload });
        assert_eq!(decode_record(&[]).unwrap(), None);
    }

    #[test]
    fn corrupt_and_truncated_records_are_rejected_loudly() {
        let frame = encode_record(1, 4, b"abcdef");
        let mut flipped = frame.clone();
        flipped[15] ^= 0x10;
        assert!(decode_record(&flipped).unwrap_err().contains("checksum"));
        assert!(decode_record(&frame[..frame.len() - 2])
            .unwrap_err()
            .contains("truncated"));
        assert!(decode_record(&frame[..3]).unwrap_err().contains("length"));
        let mut huge = frame.clone();
        huge[0..4].copy_from_slice(&u32::MAX.to_le_bytes());
        assert!(decode_record(&huge).unwrap_err().contains("implausible"));
    }

    #[test]
    fn scan_stops_at_the_first_invalid_record() {
        let mut seg = Vec::new();
        seg.extend_from_slice(&encode_record(1, 1, b"one"));
        seg.extend_from_slice(&encode_record(2, 2, b"two"));
        let good_len = seg.len();
        let mut torn = encode_record(3, 2, b"three");
        torn.truncate(torn.len() - 5);
        seg.extend_from_slice(&torn);
        let (recs, valid, reason) = scan_segment(&seg);
        assert_eq!(recs.len(), 2);
        assert_eq!(valid, good_len);
        assert!(reason.unwrap().contains("truncated"));

        // A sequence regression is corruption, not a merge point.
        let mut seg = Vec::new();
        seg.extend_from_slice(&encode_record(5, 1, b"a"));
        seg.extend_from_slice(&encode_record(4, 1, b"b"));
        let (recs, _, reason) = scan_segment(&seg);
        assert_eq!(recs.len(), 1);
        assert!(reason.unwrap().contains("regression"));
    }

    #[test]
    fn append_reopen_replays_in_global_seq_order_across_shards() {
        let storage = Arc::new(MemStorage::new());
        let (wal, replay) = Wal::open(storage.clone(), &cfg(2, Durability::Sync)).unwrap();
        assert!(replay.is_empty());
        // Interleave shards; seqs are global and monotone.
        let s1 = wal.append(0, 1, b"create").unwrap();
        let s2 = wal.append(1, 2, b"ingest-b").unwrap();
        let s3 = wal.append(0, 2, b"ingest-a").unwrap();
        assert!(s1 < s2 && s2 < s3);
        assert_eq!(wal.last_seq(), s3);
        drop(wal);

        let (wal2, replay) = Wal::open(storage, &cfg(2, Durability::Sync)).unwrap();
        let seqs: Vec<u64> = replay.iter().map(|r| r.seq).collect();
        assert_eq!(seqs, vec![s1, s2, s3]);
        assert_eq!(replay[0].payload, b"create");
        assert!(wal2.has_stale_segments());
        // New appends continue the global sequence past everything seen.
        assert_eq!(wal2.append(0, 4, b"freeze").unwrap(), s3 + 1);
    }

    #[test]
    fn torn_tail_is_truncated_on_open_and_appends_continue() {
        let storage = Arc::new(MemStorage::new());
        let (wal, _) = Wal::open(storage.clone(), &cfg(1, Durability::Async)).unwrap();
        wal.append(0, 1, b"alpha").unwrap();
        wal.append(0, 2, b"beta").unwrap();
        drop(wal);
        // Tear the tail mid-record, as a crash mid-write would.
        let key = storage.list("wal/").unwrap().pop().unwrap();
        let bytes = storage.read(&key).unwrap().unwrap();
        storage.truncate(&key, bytes.len() as u64 - 3).unwrap();

        let (wal, replay) = Wal::open(storage.clone(), &cfg(1, Durability::Async)).unwrap();
        assert_eq!(replay.len(), 1, "torn second record must be dropped");
        assert_eq!(replay[0].payload, b"alpha");
        // The torn bytes are gone from storage (idempotent re-open).
        let repaired = storage.read(&key).unwrap().unwrap();
        let (recs, valid, reason) = scan_segment(&repaired);
        assert_eq!((recs.len(), valid == repaired.len(), reason), (1, true, None));
        // The log stays writable and sequences continue after the tear.
        assert_eq!(wal.append(0, 4, b"gamma").unwrap(), 2);
    }

    #[test]
    fn bit_flip_in_the_middle_truncates_from_the_flip_point() {
        let storage = Arc::new(MemStorage::new());
        let (wal, _) = Wal::open(storage.clone(), &cfg(1, Durability::Async)).unwrap();
        wal.append(0, 1, b"first").unwrap();
        let boundary = {
            let key = storage.list("wal/").unwrap().pop().unwrap();
            storage.read(&key).unwrap().unwrap().len()
        };
        wal.append(0, 2, b"second").unwrap();
        wal.append(0, 2, b"third").unwrap();
        drop(wal);
        let key = storage.list("wal/").unwrap().pop().unwrap();
        let mut bytes = storage.read(&key).unwrap().unwrap();
        bytes[boundary + 6] ^= 0x01; // corrupt the second record
        storage.put_atomic(&key, &bytes).unwrap();

        let (_, replay) = Wal::open(storage.clone(), &cfg(1, Durability::Async)).unwrap();
        assert_eq!(replay.len(), 1, "records after a corrupt one are dropped");
        assert_eq!(replay[0].payload, b"first");
        assert_eq!(
            storage.size(&key).unwrap(),
            Some(boundary as u64),
            "segment truncated exactly at the corruption boundary"
        );
    }

    #[test]
    fn rotation_retires_old_segments_and_keeps_new_records() {
        let storage = Arc::new(MemStorage::new());
        let mut c = cfg(1, Durability::Sync);
        c.compact_bytes = 1; // any record triggers
        let (wal, _) = Wal::open(storage.clone(), &cfg(1, Durability::Sync)).unwrap();
        assert!(!wal.wants_compaction(0), "compaction disabled at 0 bytes");
        drop(wal);
        let (wal, _) = Wal::open(storage.clone(), &c).unwrap();
        wal.append(0, 1, b"old-1").unwrap();
        wal.append(0, 2, b"old-2").unwrap();
        assert!(wal.wants_compaction(0));
        assert!(wal.begin_compaction(0));
        assert!(!wal.begin_compaction(0), "slot is exclusive");
        let old = wal.rotate(0).unwrap();
        assert_eq!(old.len(), 1);
        wal.append(0, 2, b"new-1").unwrap();
        wal.delete_segments(&old).unwrap();
        wal.end_compaction(0);
        drop(wal);

        let (_, replay) = Wal::open(storage, &c).unwrap();
        assert_eq!(replay.len(), 1, "only the post-rotation record survives");
        assert_eq!(replay[0].payload, b"new-1");
        assert_eq!(replay[0].seq, 3, "global seq is preserved across rotation");
    }

    #[test]
    fn seq_floor_keeps_fresh_records_above_recovered_watermarks() {
        // After compaction deletes every sealed segment, an open finds no
        // surviving records — the floor (the registry's max checkpoint
        // watermark) must still carry the counter forward, or fresh acked
        // records would be skipped by the next replay.
        let storage = Arc::new(MemStorage::new());
        let mut c = cfg(1, Durability::Sync);
        c.seq_floor = 41;
        let (wal, replay) = Wal::open(storage.clone(), &c).unwrap();
        assert!(replay.is_empty());
        assert_eq!(wal.last_seq(), 41);
        assert_eq!(wal.append(0, 2, b"post-compaction").unwrap(), 42);
        drop(wal);

        // Surviving records win when they sit above the floor.
        c.seq_floor = 7;
        let (wal, replay) = Wal::open(storage, &c).unwrap();
        assert_eq!(replay.len(), 1);
        assert_eq!(wal.append(0, 2, b"next").unwrap(), 43);
    }

    #[test]
    fn retained_sealed_keys_join_the_stale_set_and_purge_together() {
        let storage = Arc::new(MemStorage::new());
        let (wal, _) = Wal::open(storage.clone(), &cfg(1, Durability::Async)).unwrap();
        wal.append(0, 1, b"r").unwrap();
        drop(wal);
        let (wal, _) = Wal::open(storage, &cfg(1, Durability::Async)).unwrap();
        assert!(wal.has_stale_segments());
        // A compaction whose fold failed hands its sealed keys back.
        wal.retain_stale(vec!["wal/shard-000/segment-x.sagewal".into()]);
        assert_eq!(wal.purge_stale_segments().unwrap(), 2);
        assert!(!wal.has_stale_segments());
    }

    #[test]
    fn group_commit_is_consistent_under_concurrent_appenders() {
        let storage = Arc::new(MemStorage::new());
        let (wal, _) = Wal::open(storage.clone(), &cfg(2, Durability::Sync)).unwrap();
        let wal = Arc::new(wal);
        let threads: Vec<_> = (0..4usize)
            .map(|t| {
                let wal = Arc::clone(&wal);
                std::thread::spawn(move || {
                    (0..25)
                        .map(|i| wal.append(t % 2, 2, format!("t{t}-{i}").as_bytes()).unwrap())
                        .collect::<Vec<u64>>()
                })
            })
            .collect();
        let mut all: Vec<u64> = threads
            .into_iter()
            .flat_map(|t| t.join().unwrap())
            .collect();
        all.sort_unstable();
        let want: Vec<u64> = (1..=100).collect();
        assert_eq!(all, want, "seqs are dense and unique");
        drop(wal);
        let (_, replay) = Wal::open(storage, &cfg(2, Durability::Sync)).unwrap();
        assert_eq!(replay.len(), 100);
        assert!(replay.windows(2).all(|w| w[0].seq < w[1].seq));
    }

    #[test]
    fn durability_parses_and_defaults_off() {
        // The aborts themselves are covered by the subprocess tests in
        // tests/integration_durability.rs.
        assert_eq!(WalFaultPlan::default().abort_at, None);
        assert_eq!(WalFaultPlan::default().torn_at, None);
        assert_eq!(Durability::parse("sync").unwrap(), Durability::Sync);
        assert_eq!(Durability::parse("async").unwrap(), Durability::Async);
        assert_eq!(Durability::parse("none").unwrap(), Durability::None);
        assert_eq!(Durability::default(), Durability::None);
        assert!(Durability::parse("paranoid").is_err());
        assert_eq!(Durability::Sync.name(), "sync");
    }
}
