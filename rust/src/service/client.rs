//! Blocking client for `sage-serve` — one TCP connection, strict
//! request/response alternation. Used by the `sage ingest` / `sage query`
//! subcommands, `examples/service_roundtrip.rs`, and the integration tests.
//!
//! Typical producer flow (one client per shard for deterministic results):
//!
//! ```text
//! create_session("run1", ell, d, shards)      (once)
//! ingest("run1", shard, grads) ...            (Phase I, per batch)
//! freeze("run1") -> S                         (idempotent; fetches S)
//! score("run1", shard, block) ...             (Phase II, per batch)
//! top_k("run1", "sage", k, classes, seed)     (online selection query)
//! ```
//!
//! # Saturated-server backoff contract
//!
//! A server whose connection pool is saturated ACCEPTS the TCP connection,
//! writes exactly one error frame (opcode 0, status 1, message prefixed
//! `connection rejected`) and closes it — see docs/PROTOCOL.md
//! §"Connection rejection and retry". That frame is a *retryable* signal:
//! close the socket, wait `base × 2^attempt` (capped), reconnect, resend.
//! [`request_with_retry`] implements the contract for one-shot requests;
//! [`is_rejection`] classifies error messages for long-lived clients that
//! manage their own connections. Application errors (status 1 on the
//! echoed request opcode) are never retryable.

use super::protocol::{
    encode_ingest_batch, encode_score, op, read_frame, read_frame_event, write_frame_traced,
    Frame, FrozenSketch, ReadEvent, Request, Response,
};
use super::subs::GOING_AWAY;
use crate::pipeline::ScoreBlock;
use crate::sketch::FdSketch;
use crate::tensor::Matrix;
use crate::util::metrics::HistogramStats;
use crate::util::trace::{self, SpanRecord};
use std::collections::VecDeque;
use std::net::TcpStream;
use std::time::Duration;

/// Whether an error message is the server's retryable connection-shed
/// signal (see the module docs' backoff contract).
pub fn is_rejection(message: &str) -> bool {
    message.starts_with("connection rejected")
}

/// Whether an error message is the server's shutdown notice (the final
/// unsolicited frame a subscriber receives before its connection closes —
/// docs/PROTOCOL.md §5). Not retryable against the same server instance;
/// reconnect-and-resubscribe clients should back off first.
pub fn is_going_away(message: &str) -> bool {
    message.starts_with(GOING_AWAY)
}

/// One decoded push notification (see [`ServiceClient::poll_delta`]):
/// apply `added`/`evicted` to the reconstructed selection with
/// `protocol::apply_topk_delta`. Epochs count from 1 per subscription and
/// arrive consecutively; a gap means frames were lost (impossible on one
/// healthy TCP connection).
#[derive(Clone, Debug, PartialEq)]
pub struct TopKDeltaEvent {
    pub session: String,
    pub epoch: u64,
    pub added: Vec<u64>,
    pub evicted: Vec<u64>,
    /// Smallest consensus score among the currently selected entries
    /// (NaN when the selection is empty or scores are non-finite).
    pub watermark: f64,
}

/// Ceiling on the exponential backoff between retry attempts.
const RETRY_BACKOFF_CAP: std::time::Duration = std::time::Duration::from_secs(2);

/// One-shot request with the documented saturated-server backoff: connect,
/// send `request`, and on a connection-shed rejection (or a transport
/// error, which shedding can race into — the server may reset the socket
/// before the rejection frame is read) close, wait `base × 2^attempt`
/// (capped at 2 s), and retry on a fresh connection.
///
/// Transport errors are retried too, so reserve this helper for idempotent
/// requests (CreateSession, Freeze, TopK, Stats, Checkpoint, Close);
/// a retried `IngestBatch`/`Score` whose first attempt was applied but
/// whose response was lost would double-count.
///
/// Like [`ServiceClient::request`], a non-rejection application error
/// frame is returned as `Ok(Response::Error { .. })` without retrying
/// (resending would yield the same error) — match on the response.
///
/// # Errors
/// Only exhaustion: the last rejection/connect/transport error once
/// `attempts` are used up.
pub fn request_with_retry(
    addr: &str,
    request: &Request,
    attempts: u32,
    base: std::time::Duration,
) -> Result<Response, String> {
    let attempts = attempts.max(1);
    let mut last = String::from("no attempts made");
    for attempt in 0..attempts {
        if attempt > 0 {
            let backoff = base
                .saturating_mul(1u32 << (attempt - 1).min(16))
                .min(RETRY_BACKOFF_CAP);
            std::thread::sleep(backoff);
        }
        match ServiceClient::connect(addr) {
            Ok(mut client) => match client.request(request) {
                Ok(Response::Error { message }) if is_rejection(&message) => last = message,
                Ok(response) => return Ok(response),
                Err(e) => last = e,
            },
            Err(e) => last = e,
        }
    }
    Err(format!("request failed after {attempts} attempts: {last}"))
}

/// Blocking `sage-serve` client (not thread-safe; one per connection).
///
/// After a [`ServiceClient::subscribe`], the connection also carries
/// *unsolicited* TopKDelta push frames. They may interleave ahead of any
/// response the client is waiting on; the request path stashes them (in
/// arrival order) and [`ServiceClient::poll_delta`] drains the stash
/// before reading the socket, so pushes are never lost or reordered.
pub struct ServiceClient {
    stream: TcpStream,
    /// Push frames that arrived while waiting for a response.
    deltas: VecDeque<TopKDeltaEvent>,
}

impl ServiceClient {
    /// Open one connection (TCP_NODELAY — the protocol is request/response).
    ///
    /// # Errors
    /// Connection failures (the OS error, prefixed with the address).
    pub fn connect(addr: &str) -> Result<ServiceClient, String> {
        let stream = TcpStream::connect(addr).map_err(|e| format!("connect {addr}: {e}"))?;
        let _ = stream.set_nodelay(true);
        Ok(ServiceClient {
            stream,
            deltas: VecDeque::new(),
        })
    }

    /// Send one request and wait for its response frame.
    pub fn request(&mut self, request: &Request) -> Result<Response, String> {
        let payload = request.encode();
        self.roundtrip(request.opcode(), &payload)
    }

    /// Whether a received frame is an unsolicited TopKDelta push (carried
    /// on the Subscribe opcode with status 0 and the delta kind tag).
    fn is_push_frame(frame: &Frame) -> bool {
        frame.opcode == op::SUBSCRIBE
            && frame.status == 0
            && Response::is_topk_delta(&frame.payload)
    }

    fn stash_push(&mut self, frame: &Frame) -> Result<(), String> {
        match Response::decode(&frame.payload)? {
            Response::TopKDelta {
                session,
                epoch,
                added,
                evicted,
                watermark,
            } => {
                self.deltas.push_back(TopKDeltaEvent {
                    session,
                    epoch,
                    added,
                    evicted,
                    watermark,
                });
                Ok(())
            }
            other => Err(format!("push frame decoded as {other:?}")),
        }
    }

    /// Write one pre-encoded request payload and read its response. When a
    /// trace is active on this thread (see `util::trace`), a `client.<op>`
    /// span wraps the round trip and its context rides the frame's trace
    /// extension, so the server's `serve.<op>` span becomes its child.
    /// Push frames that arrive first are stashed for
    /// [`ServiceClient::poll_delta`].
    fn roundtrip(&mut self, opcode: u8, payload: &[u8]) -> Result<Response, String> {
        let _span = trace::span(&format!("client.{}", op::name(opcode)));
        write_frame_traced(&mut self.stream, opcode, 0, payload, trace::current())?;
        let frame = loop {
            let frame = read_frame(&mut self.stream)?
                .ok_or_else(|| "server closed the connection".to_string())?;
            if Self::is_push_frame(&frame) {
                self.stash_push(&frame)?;
                continue;
            }
            break frame;
        };
        let response = Response::decode(&frame.payload)?;
        // Error frames may carry opcode 0 (e.g. pool rejection before the
        // request was read) — surface the message rather than the mismatch.
        if frame.opcode != opcode && !matches!(response, Response::Error { .. }) {
            return Err(format!(
                "response opcode {} for request {opcode}",
                frame.opcode
            ));
        }
        Ok(response)
    }

    /// Like [`ServiceClient::request`], but application errors become `Err`.
    fn expect(&mut self, request: &Request) -> Result<Response, String> {
        match self.request(request)? {
            Response::Error { message } => Err(message),
            resp => Ok(resp),
        }
    }

    /// Raw-payload variant of [`ServiceClient::expect`] for the hot ops
    /// (payload already serialized from borrowed data — no matrix clones).
    fn expect_raw(&mut self, opcode: u8, payload: &[u8]) -> Result<Response, String> {
        match self.roundtrip(opcode, payload)? {
            Response::Error { message } => Err(message),
            resp => Ok(resp),
        }
    }

    pub fn create_session(
        &mut self,
        name: &str,
        ell: usize,
        d: usize,
        shards: usize,
    ) -> Result<(), String> {
        self.expect(&Request::CreateSession {
            name: name.to_string(),
            ell: ell as u32,
            d: d as u32,
            shards: shards as u32,
        })
        .map(|_| ())
    }

    /// Stream one `[b × d]` block of gradient rows into a shard slot.
    /// Returns total rows the session has acked.
    pub fn ingest(&mut self, session: &str, shard: usize, rows: &Matrix) -> Result<u64, String> {
        let payload = encode_ingest_batch(session, shard as u32, rows);
        match self.expect_raw(op::INGEST_BATCH, &payload)? {
            Response::Ingested { rows_seen } => Ok(rows_seen),
            other => Err(format!("unexpected ingest response {other:?}")),
        }
    }

    /// Merge a locally-built FD sketch into a shard slot.
    pub fn merge_sketch(
        &mut self,
        session: &str,
        shard: usize,
        sketch: &FdSketch,
    ) -> Result<(), String> {
        self.expect(&Request::MergeSketch {
            session: session.to_string(),
            shard: shard as u32,
            state: sketch.export_state(),
        })
        .map(|_| ())
    }

    /// Freeze the session (idempotent) and fetch the frozen sketch S.
    pub fn freeze(&mut self, session: &str) -> Result<FrozenSketch, String> {
        match self.expect(&Request::Freeze {
            session: session.to_string(),
        })? {
            Response::Frozen(frozen) => Ok(frozen),
            other => Err(format!("unexpected freeze response {other:?}")),
        }
    }

    /// Stream one Phase-II scoring block (borrowed straight from
    /// `pipeline::phase2_score_stream` — only the small index vector is
    /// converted; the ẑ matrix is serialized without cloning).
    pub fn score(
        &mut self,
        session: &str,
        shard: usize,
        block: &ScoreBlock<'_>,
    ) -> Result<(), String> {
        let indices: Vec<u64> = block.indices.iter().map(|&i| i as u64).collect();
        let payload = encode_score(
            session,
            shard as u32,
            &indices,
            block.labels,
            block.norms,
            block.losses,
            block.zhat,
        );
        self.expect_raw(op::SCORE, &payload).map(|_| ())
    }

    /// Online selection query against the session's finalized scores.
    pub fn top_k(
        &mut self,
        session: &str,
        method: &str,
        k: usize,
        num_classes: usize,
        seed: u64,
    ) -> Result<(Vec<usize>, Option<Vec<f32>>), String> {
        match self.expect(&Request::TopK {
            session: session.to_string(),
            method: method.to_string(),
            k: k as u64,
            num_classes: num_classes as u32,
            seed,
        })? {
            Response::Selected { indices, weights } => Ok((
                indices.iter().map(|&i| i as usize).collect(),
                if weights.is_empty() {
                    None
                } else {
                    Some(weights)
                },
            )),
            other => Err(format!("unexpected topk response {other:?}")),
        }
    }

    /// Register this connection for push TopKDelta frames whenever
    /// `session`'s selection under `(method, k, num_classes, seed)`
    /// changes (Freeze/Score/TopK mutations). Deltas arrive unsolicited;
    /// read them with [`ServiceClient::poll_delta`] and fold them into a
    /// local selection with `protocol::apply_topk_delta`. Re-subscribing
    /// the same session replaces the parameters and restarts epochs.
    pub fn subscribe(
        &mut self,
        session: &str,
        method: &str,
        k: usize,
        num_classes: usize,
        seed: u64,
    ) -> Result<(), String> {
        self.expect(&Request::Subscribe {
            session: session.to_string(),
            method: method.to_string(),
            k: k as u64,
            num_classes: num_classes as u32,
            seed,
        })
        .map(|_| ())
    }

    /// Stop push deltas for `session` on this connection. Succeeds even
    /// if no such subscription exists (unsubscribe races session close).
    pub fn unsubscribe(&mut self, session: &str) -> Result<(), String> {
        self.expect(&Request::Unsubscribe {
            session: session.to_string(),
        })
        .map(|_| ())
    }

    /// Next push delta, waiting up to `timeout`: drains the stash filled
    /// during request/response exchanges first, then reads the socket.
    /// `Ok(None)` = nothing arrived within the timeout. A GoingAway frame
    /// (server shutdown — see [`is_going_away`]) or an unexpected frame
    /// surfaces as `Err`.
    pub fn poll_delta(&mut self, timeout: Duration) -> Result<Option<TopKDeltaEvent>, String> {
        if let Some(event) = self.deltas.pop_front() {
            return Ok(Some(event));
        }
        // read_frame_event treats a timeout with no frame in progress as
        // Idle; a timeout mid-frame is a framing error (the server never
        // stalls inside one push frame).
        self.stream
            .set_read_timeout(Some(timeout.max(Duration::from_millis(1))))
            .map_err(|e| format!("set read timeout: {e}"))?;
        let event = read_frame_event(&mut self.stream);
        let _ = self.stream.set_read_timeout(None);
        match event? {
            ReadEvent::Idle => Ok(None),
            ReadEvent::Eof => Err("server closed the connection".to_string()),
            ReadEvent::Frame(frame) if Self::is_push_frame(&frame) => {
                self.stash_push(&frame)?;
                Ok(self.deltas.pop_front())
            }
            ReadEvent::Frame(frame) => match Response::decode(&frame.payload)? {
                Response::Error { message } => Err(message),
                other => Err(format!("unexpected frame while polling deltas: {other:?}")),
            },
        }
    }

    /// Persist the session server-side. Returns the checkpoint path and
    /// the WAL sequence watermark it covers (0 with `--durability none`).
    pub fn checkpoint(&mut self, session: &str) -> Result<(String, u64), String> {
        match self.expect(&Request::Checkpoint {
            session: session.to_string(),
        })? {
            Response::Checkpointed { path, wal_seq } => Ok((path, wal_seq)),
            other => Err(format!("unexpected checkpoint response {other:?}")),
        }
    }

    /// Per-session counters; `None` = server-wide stats.
    pub fn stats(&mut self, session: Option<&str>) -> Result<Vec<(String, u64)>, String> {
        match self.expect(&Request::Stats {
            session: session.unwrap_or("").to_string(),
        })? {
            Response::Stats { pairs } => Ok(pairs),
            other => Err(format!("unexpected stats response {other:?}")),
        }
    }

    /// Server-side metrics snapshot: counters, gauges, and histogram
    /// summaries (p50/p99/max/mean) whose names start with `prefix`
    /// (empty prefix = everything). See docs/OBSERVABILITY.md for the
    /// metric catalog.
    #[allow(clippy::type_complexity)]
    pub fn metrics_snapshot(
        &mut self,
        prefix: &str,
    ) -> Result<
        (
            Vec<(String, u64)>,
            Vec<(String, u64)>,
            Vec<(String, HistogramStats)>,
        ),
        String,
    > {
        match self.expect(&Request::MetricsSnapshot {
            prefix: prefix.to_string(),
        })? {
            Response::Metrics {
                counters,
                gauges,
                hists,
            } => Ok((counters, gauges, hists)),
            other => Err(format!("unexpected metrics response {other:?}")),
        }
    }

    /// Drain the server's recorded trace spans (the server-side half of
    /// `sage trace export` — merge with local `trace::collect()` and feed
    /// `trace::chrome_trace_json` for a Chrome-loadable timeline).
    pub fn trace_export(&mut self) -> Result<Vec<SpanRecord>, String> {
        match self.expect(&Request::TraceExport)? {
            Response::Trace { spans } => Ok(spans),
            other => Err(format!("unexpected trace response {other:?}")),
        }
    }

    pub fn close_session(&mut self, session: &str) -> Result<(), String> {
        self.expect(&Request::CloseSession {
            session: session.to_string(),
        })
        .map(|_| ())
    }
}
