//! Minimal HTTP/1.0 metrics exposition endpoint for `sage serve
//! --metrics-addr HOST:PORT` — just enough HTTP for a Prometheus scraper
//! or `curl`, from scratch like the rest of the stack (no hyper offline).
//!
//! Routes:
//!
//! - `GET /metrics` — the process metrics registry in Prometheus text
//!   format 0.0.4 (`util::metrics::Registry::render_prometheus`): counters,
//!   gauges, and histograms with cumulative `_bucket`/`_sum`/`_count`
//!   series derived from the log-linear bucket layout.
//! - `GET /healthz` — `ok` while the server is up (liveness probe).
//!
//! Everything else is a 404; non-GET methods get a 405. One short-lived
//! connection per request (`Connection: close` semantics), handled by a
//! small bounded worker pool ([`SCRAPE_WORKERS`] threads) so a slow or
//! silent peer cannot wedge the acceptor. When the pool's queue is full —
//! a scrape storm — excess connections are shed immediately with a `503`
//! and the `service.metrics_http.rejected` counter climbs; the endpoint
//! is not on the data path and never blocks it.

use crate::util::metrics;
use crate::util::threadpool::ThreadPool;
use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

/// Scrape handler threads. Two is plenty for Prometheus-cadence polling;
/// the bounded queue behind them (see `ThreadPool`) absorbs bursts and
/// anything past it is shed with a 503 rather than piling up threads.
const SCRAPE_WORKERS: usize = 2;

/// Canned shed response, written inline on the acceptor thread when the
/// scrape pool is saturated.
const BUSY_RESPONSE: &[u8] = b"HTTP/1.0 503 Service Unavailable\r\n\
    Content-Type: text/plain; charset=utf-8\r\n\
    Content-Length: 5\r\nConnection: close\r\n\r\nbusy\n";

/// Accept loop for the metrics endpoint. Mirrors the main server's
/// shutdown protocol: blocks in `accept`, re-checks `stop` per connection,
/// and is woken by a throwaway connection (see `ServerHandle`).
pub fn spawn(listener: TcpListener, stop: Arc<AtomicBool>) -> JoinHandle<()> {
    std::thread::spawn(move || {
        let pool = ThreadPool::new(SCRAPE_WORKERS);
        for incoming in listener.incoming() {
            if stop.load(Ordering::Relaxed) {
                break;
            }
            match incoming {
                Ok(stream) => {
                    let reject = stream.try_clone().ok();
                    if pool.try_execute(move || handle(stream)).is_err() {
                        shed(reject);
                    }
                }
                Err(e) => crate::log_warn!("metrics accept failed: {e}"),
            }
        }
    })
}

/// Scrape-storm overflow: answer 503 without ever handing the connection
/// a thread. Short write timeout — a peer too slow to take 100 bytes is
/// dropped, not waited on.
fn shed(stream: Option<TcpStream>) {
    metrics::global().counter("service.metrics_http.rejected").inc();
    if let Some(mut s) = stream {
        let _ = s.set_write_timeout(Some(Duration::from_millis(500)));
        let _ = s.write_all(BUSY_RESPONSE);
    }
}

/// Render the full HTTP/1.0 response for one request head (request line +
/// headers as read off the socket). Shared by the threaded handler below
/// and the reactor's nonblocking HTTP connection state machine, so both
/// I/O engines serve byte-identical scrapes.
pub(crate) fn respond(head: &str) -> Vec<u8> {
    let mut parts = head.lines().next().unwrap_or("").split_whitespace();
    let method = parts.next().unwrap_or("");
    let path = parts.next().unwrap_or("");
    let (status, content_type, body) = route(method, path);
    format!(
        "HTTP/1.0 {status}\r\nContent-Type: {content_type}\r\n\
         Content-Length: {}\r\nConnection: close\r\n\r\n{body}",
        body.len()
    )
    .into_bytes()
}

fn handle(mut stream: TcpStream) {
    let _ = stream.set_read_timeout(Some(Duration::from_millis(500)));
    let _ = stream.set_write_timeout(Some(Duration::from_secs(2)));
    // One read is enough for any real scraper's GET; we only need the
    // request line and tolerate unread trailing headers.
    let mut buf = [0u8; 4096];
    let n = match stream.read(&mut buf) {
        Ok(n) if n > 0 => n,
        _ => return,
    };
    let head = String::from_utf8_lossy(&buf[..n]);
    let response = respond(&head);
    let _ = stream.write_all(&response);
    let _ = stream.flush();
}

fn route(method: &str, path: &str) -> (&'static str, &'static str, String) {
    if method != "GET" {
        return (
            "405 Method Not Allowed",
            "text/plain; charset=utf-8",
            "method not allowed\n".to_string(),
        );
    }
    match path {
        "/metrics" => (
            "200 OK",
            "text/plain; version=0.0.4; charset=utf-8",
            metrics::global().render_prometheus(),
        ),
        "/healthz" => ("200 OK", "text/plain; charset=utf-8", "ok\n".to_string()),
        _ => (
            "404 Not Found",
            "text/plain; charset=utf-8",
            "not found\n".to_string(),
        ),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn get(addr: std::net::SocketAddr, path: &str) -> String {
        let mut s = TcpStream::connect(addr).unwrap();
        s.write_all(format!("GET {path} HTTP/1.0\r\nHost: x\r\n\r\n").as_bytes())
            .unwrap();
        let mut out = String::new();
        s.read_to_string(&mut out).unwrap();
        out
    }

    #[test]
    fn serves_metrics_healthz_and_404() {
        metrics::global().counter("service.test.http_exposition").inc();
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let stop = Arc::new(AtomicBool::new(false));
        let join = spawn(listener, stop.clone());

        let health = get(addr, "/healthz");
        assert!(health.starts_with("HTTP/1.0 200 OK"), "{health}");
        assert!(health.ends_with("ok\n"), "{health}");

        let scrape = get(addr, "/metrics");
        assert!(scrape.starts_with("HTTP/1.0 200 OK"), "{scrape}");
        assert!(scrape.contains("text/plain; version=0.0.4"), "{scrape}");
        assert!(
            scrape.contains("# TYPE service_test_http_exposition counter"),
            "{scrape}"
        );

        let missing = get(addr, "/nope");
        assert!(missing.starts_with("HTTP/1.0 404"), "{missing}");

        stop.store(true, Ordering::Relaxed);
        let _ = TcpStream::connect(addr); // wake the acceptor
        join.join().unwrap();
    }

    /// A scrape storm: with every worker wedged on a silent peer and the
    /// pool queue full, further connections must be shed with a 503 —
    /// never queued without bound, never given a new thread — and the
    /// endpoint must recover once the storm passes.
    #[test]
    fn scrape_storm_sheds_with_503_and_recovers() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let stop = Arc::new(AtomicBool::new(false));
        let join = spawn(listener, stop.clone());

        // Wedge both workers and fill the bounded queue: silent
        // connections hold a worker for the full read timeout, and the
        // queued ones keep the pool saturated behind them.
        let stalls: Vec<TcpStream> = (0..SCRAPE_WORKERS * 5)
            .map(|_| TcpStream::connect(addr).unwrap())
            .collect();
        std::thread::sleep(Duration::from_millis(100));

        // Storm requests while saturated: every response must be a clean
        // 200 or an immediate 503 — nothing hangs, nothing is dropped
        // without an answer (the peer FIN after `get` counts as answered).
        let started = std::time::Instant::now();
        let mut shed_seen = false;
        for _ in 0..4 {
            let resp = get(addr, "/healthz");
            assert!(
                resp.is_empty()
                    || resp.starts_with("HTTP/1.0 200")
                    || resp.starts_with("HTTP/1.0 503"),
                "unexpected storm response: {resp:?}"
            );
            if resp.starts_with("HTTP/1.0 503") {
                shed_seen = true;
            }
        }
        assert!(
            shed_seen,
            "saturated pool never shed a request with 503"
        );
        assert!(
            started.elapsed() < Duration::from_secs(5),
            "storm responses were not prompt: {:?}",
            started.elapsed()
        );

        // Storm over: stalled peers hang up, workers drain, and a fresh
        // scrape succeeds again.
        drop(stalls);
        let deadline = std::time::Instant::now() + Duration::from_secs(10);
        loop {
            let resp = get(addr, "/healthz");
            if resp.starts_with("HTTP/1.0 200") {
                break;
            }
            assert!(
                std::time::Instant::now() < deadline,
                "endpoint did not recover after the storm: {resp:?}"
            );
            std::thread::sleep(Duration::from_millis(50));
        }

        stop.store(true, Ordering::Relaxed);
        let _ = TcpStream::connect(addr);
        join.join().unwrap();
    }
}
