//! Minimal HTTP/1.0 metrics exposition endpoint for `sage serve
//! --metrics-addr HOST:PORT` — just enough HTTP for a Prometheus scraper
//! or `curl`, from scratch like the rest of the stack (no hyper offline).
//!
//! Routes:
//!
//! - `GET /metrics` — the process metrics registry in Prometheus text
//!   format 0.0.4 (`util::metrics::Registry::render_prometheus`): counters,
//!   gauges, and histograms with cumulative `_bucket`/`_sum`/`_count`
//!   series derived from the log-linear bucket layout.
//! - `GET /healthz` — `ok` while the server is up (liveness probe).
//!
//! Everything else is a 404; non-GET methods get a 405. One short-lived
//! connection per request (`Connection: close` semantics), handled inline
//! on the acceptor thread — a scrape is tiny and the endpoint is not on
//! the data path.

use crate::util::metrics;
use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

/// Accept loop for the metrics endpoint. Mirrors the main server's
/// shutdown protocol: blocks in `accept`, re-checks `stop` per connection,
/// and is woken by a throwaway connection (see `ServerHandle`).
pub fn spawn(listener: TcpListener, stop: Arc<AtomicBool>) -> JoinHandle<()> {
    std::thread::spawn(move || {
        for incoming in listener.incoming() {
            if stop.load(Ordering::Relaxed) {
                break;
            }
            match incoming {
                Ok(stream) => handle(stream),
                Err(e) => crate::log_warn!("metrics accept failed: {e}"),
            }
        }
    })
}

/// Render the full HTTP/1.0 response for one request head (request line +
/// headers as read off the socket). Shared by the threaded handler below
/// and the reactor's nonblocking HTTP connection state machine, so both
/// I/O engines serve byte-identical scrapes.
pub(crate) fn respond(head: &str) -> Vec<u8> {
    let mut parts = head.lines().next().unwrap_or("").split_whitespace();
    let method = parts.next().unwrap_or("");
    let path = parts.next().unwrap_or("");
    let (status, content_type, body) = route(method, path);
    format!(
        "HTTP/1.0 {status}\r\nContent-Type: {content_type}\r\n\
         Content-Length: {}\r\nConnection: close\r\n\r\n{body}",
        body.len()
    )
    .into_bytes()
}

fn handle(mut stream: TcpStream) {
    let _ = stream.set_read_timeout(Some(Duration::from_millis(500)));
    let _ = stream.set_write_timeout(Some(Duration::from_secs(2)));
    // One read is enough for any real scraper's GET; we only need the
    // request line and tolerate unread trailing headers.
    let mut buf = [0u8; 4096];
    let n = match stream.read(&mut buf) {
        Ok(n) if n > 0 => n,
        _ => return,
    };
    let head = String::from_utf8_lossy(&buf[..n]);
    let response = respond(&head);
    let _ = stream.write_all(&response);
    let _ = stream.flush();
}

fn route(method: &str, path: &str) -> (&'static str, &'static str, String) {
    if method != "GET" {
        return (
            "405 Method Not Allowed",
            "text/plain; charset=utf-8",
            "method not allowed\n".to_string(),
        );
    }
    match path {
        "/metrics" => (
            "200 OK",
            "text/plain; version=0.0.4; charset=utf-8",
            metrics::global().render_prometheus(),
        ),
        "/healthz" => ("200 OK", "text/plain; charset=utf-8", "ok\n".to_string()),
        _ => (
            "404 Not Found",
            "text/plain; charset=utf-8",
            "not found\n".to_string(),
        ),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn get(addr: std::net::SocketAddr, path: &str) -> String {
        let mut s = TcpStream::connect(addr).unwrap();
        s.write_all(format!("GET {path} HTTP/1.0\r\nHost: x\r\n\r\n").as_bytes())
            .unwrap();
        let mut out = String::new();
        s.read_to_string(&mut out).unwrap();
        out
    }

    #[test]
    fn serves_metrics_healthz_and_404() {
        metrics::global().counter("service.test.http_exposition").inc();
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let stop = Arc::new(AtomicBool::new(false));
        let join = spawn(listener, stop.clone());

        let health = get(addr, "/healthz");
        assert!(health.starts_with("HTTP/1.0 200 OK"), "{health}");
        assert!(health.ends_with("ok\n"), "{health}");

        let scrape = get(addr, "/metrics");
        assert!(scrape.starts_with("HTTP/1.0 200 OK"), "{scrape}");
        assert!(scrape.contains("text/plain; version=0.0.4"), "{scrape}");
        assert!(
            scrape.contains("# TYPE service_test_http_exposition counter"),
            "{scrape}"
        );

        let missing = get(addr, "/nope");
        assert!(missing.starts_with("HTTP/1.0 404"), "{missing}");

        stop.store(true, Ordering::Relaxed);
        let _ = TcpStream::connect(addr); // wake the acceptor
        join.join().unwrap();
    }
}
