//! Frequent Directions (FD) gradient sketching — Algorithm 1, Phase I.
//!
//! [`FdSketch`] maintains the deterministic `ℓ × D` sketch of the streamed
//! per-example gradient rowspace in `O(ℓD)` memory. This implementation is
//! the standard buffered 2ℓ variant [Liberty 2013; Ghashami et al. 2015]:
//! rows accumulate in a `2ℓ × D` buffer and, when it fills, a *shrink*
//! contracts low-energy directions:
//!
//! ```text
//! S = U Σ Vᵀ;  δ = σ_ℓ²;  Σ' = sqrt(max(Σ² − δ, 0));  S ← Σ' Vᵀ
//! ```
//!
//! The shrink is implemented without a `2ℓ × D` SVD via the Gram trick
//! (DESIGN.md §1): `eig(S Sᵀ) = (λ = σ², U)` on the tiny `2ℓ × 2ℓ` Gram,
//! then `S' = R S` with `R = diag(√max(λ−δ,0)/λ) Uᵀ` — numerically identical
//! and MXU-friendly: both the Gram and the `R S` contraction are the L1
//! Pallas kernels, pluggable here through [`ShrinkBackend`].
//!
//! Guarantee (quoted in the paper): for the matrix `G` of all streamed rows
//! and any `k < ℓ`, `0 ⪯ GᵀG − SᵀS ⪯ (2/ℓ)‖G − G_k‖_F² I`. The property
//! tests in this module verify it directly; [`FdSketch::shift_bound`]
//! exposes the tighter online certificate `Σ δ_shrinks`.

use crate::linalg::eigh_jacobi;
use crate::tensor::{ComputeBackend, Matrix};
use std::sync::Arc;

/// Backend for the O(ℓD) shrink contractions — **widened** into the full
/// [`tensor::ComputeBackend`] kernel layer: beyond the original
/// `gram` / `apply_rot` pair it now also covers the Phase-II projection
/// (`scores = G·Sᵀ`), the consensus matvec, and batched row-norm/energy
/// accumulation, so one backend object serves the whole two-pass pipeline.
/// The default is the serial reference ([`CpuShrinkBackend`]); the runtime
/// swaps in the AOT-compiled Pallas kernels (`runtime::XlaShrinkBackend`)
/// for the shrink pair, and `tensor::ParallelBackend` parallelizes every op
/// with bit-identical results.
///
/// [`tensor::ComputeBackend`]: crate::tensor::ComputeBackend
pub use crate::tensor::ComputeBackend as ShrinkBackend;

/// Pure-Rust shrink contractions (the serial reference backend) — identical
/// to [`crate::tensor::SerialBackend`]; the name survives the
/// [`ShrinkBackend`] widening for callers that ask for "the CPU shrink".
#[derive(Default, Debug, Clone, Copy)]
pub struct CpuShrinkBackend;

impl ComputeBackend for CpuShrinkBackend {
    fn name(&self) -> &'static str {
        "cpu-serial"
    }
}

/// Complete serializable state of an [`FdSketch`] — the wire/checkpoint
/// form used by the service's `MergeSketch` op and session persistence.
/// `buf` is the full `2ℓ × d` row buffer (rows `[0, next_row)` live).
#[derive(Clone, Debug, PartialEq)]
pub struct SketchState {
    pub ell: u32,
    pub d: u32,
    pub next_row: u32,
    pub shrink_count: u64,
    pub rows_seen: u64,
    pub delta_sum: f64,
    pub energy_seen: f64,
    pub buf: Vec<f32>,
}

/// Streaming Frequent-Directions sketch of gradient rows.
pub struct FdSketch {
    ell: usize,
    d: usize,
    /// `2ℓ × d` row buffer; rows `[0, next_row)` are live.
    buf: Matrix,
    next_row: usize,
    shrink_count: u64,
    rows_seen: u64,
    /// Σ of shrink deltas — the online covariance-error certificate.
    delta_sum: f64,
    /// Σ‖g‖² of all inserted rows (for error ratios in reports).
    energy_seen: f64,
    backend: Arc<dyn ShrinkBackend>,
}

impl FdSketch {
    /// New sketch with the pure-Rust serial backend.
    pub fn new(ell: usize, d: usize) -> Self {
        Self::with_backend(ell, d, crate::tensor::serial())
    }

    pub fn with_backend(ell: usize, d: usize, backend: Arc<dyn ShrinkBackend>) -> Self {
        assert!(ell > 0 && d > 0, "ell and d must be positive");
        Self {
            ell,
            d,
            buf: Matrix::zeros(2 * ell, d),
            next_row: 0,
            shrink_count: 0,
            rows_seen: 0,
            delta_sum: 0.0,
            energy_seen: 0.0,
            backend,
        }
    }

    pub fn ell(&self) -> usize {
        self.ell
    }

    pub fn dim(&self) -> usize {
        self.d
    }

    pub fn rows_seen(&self) -> u64 {
        self.rows_seen
    }

    pub fn shrink_count(&self) -> u64 {
        self.shrink_count
    }

    /// Online certificate: `GᵀG − SᵀS ⪯ delta_sum · I` at any point.
    pub fn shift_bound(&self) -> f64 {
        self.delta_sum
    }

    /// Total squared norm streamed in (denominator for relative error).
    pub fn energy_seen(&self) -> f64 {
        self.energy_seen
    }

    /// Memory footprint in bytes — the paper's O(ℓD) claim, measurable.
    pub fn memory_bytes(&self) -> usize {
        self.buf.as_slice().len() * std::mem::size_of::<f32>()
    }

    /// The one place the shrink schedule lives: shrink when the buffer is
    /// full, copy the row in, bump the counters, fold in its energy. Both
    /// ingest paths ([`FdSketch::insert`], [`FdSketch::insert_batch`]) call
    /// this, so they cannot drift apart.
    fn insert_row_with_energy(&mut self, row: &[f32], energy: f64) {
        if self.next_row == 2 * self.ell {
            self.shrink();
        }
        self.buf.row_mut(self.next_row).copy_from_slice(row);
        self.next_row += 1;
        self.rows_seen += 1;
        self.energy_seen += energy;
    }

    /// Stream one gradient row into the sketch (Algorithm 1 line 5). The
    /// energy uses the backend's dispatch tier — the same f64 dot kernel
    /// as the batched path, so single-row and batch ingest agree per tier.
    pub fn insert(&mut self, row: &[f32]) {
        assert_eq!(row.len(), self.d, "row dim mismatch");
        self.insert_row_with_energy(row, self.backend.dispatch().dot_f64(row, row));
    }

    /// Stream a batch `[b × d]` of rows: batched row-energy accumulation
    /// through the kernel backend, then the same per-row schedule as
    /// [`FdSketch::insert`] (bit-identical result — per-row energies use
    /// the same f64 kernel, summed in row order).
    pub fn insert_batch(&mut self, rows: &Matrix) {
        assert_eq!(rows.cols(), self.d, "batch dim mismatch");
        let energies = self.backend.row_energies(rows);
        for r in 0..rows.rows() {
            self.insert_row_with_energy(rows.row(r), energies[r]);
        }
    }

    /// The shrink step (Algorithm 1 lines 6-8), via the Gram trick.
    fn shrink(&mut self) {
        let _t = crate::util::metrics::ScopedTimer::new(
            crate::util::metrics::global().histogram("sketch.shrink.ns"),
        );
        let m = self.next_row; // rows currently live (== 2ℓ on the hot path)
        debug_assert!(m > self.ell);
        let live = self.buf.slice_rows(0, m);
        let gram = self.backend.gram(&live);

        // Tiny symmetric eig in f64 (m ≤ 2ℓ ≤ 512).
        let gram64: Vec<f64> = gram.as_slice().iter().map(|&v| v as f64).collect();
        let (lam, u) = eigh_jacobi(&gram64, m);

        // δ = σ_ℓ² = λ_{ℓ-1} (0-indexed ℓ-th largest); clamp negatives.
        let delta = lam.get(self.ell - 1).copied().unwrap_or(0.0).max(0.0);
        self.delta_sum += delta;

        // R[j, :] = sqrt(max(λ_j − δ, 0) / λ_j) * u_j  (rows of eigh output).
        let mut rot = Matrix::zeros(self.ell, m);
        for j in 0..self.ell.min(m) {
            let l = lam[j].max(0.0);
            if l <= 1e-30 {
                continue; // direction already empty
            }
            let scale = (((l - delta).max(0.0)) / l).sqrt() as f32;
            if scale == 0.0 {
                continue;
            }
            let dst = rot.row_mut(j);
            for k in 0..m {
                dst[k] = scale * (u[j * m + k] as f32);
            }
        }

        let new_top = self.backend.apply_rot(&rot, &live);
        for r in 0..self.ell {
            self.buf.row_mut(r).copy_from_slice(new_top.row(r));
        }
        for r in self.ell..2 * self.ell {
            self.buf.row_mut(r).fill(0.0);
        }
        self.next_row = self.ell;
        self.shrink_count += 1;
    }

    /// Finalize into the frozen `ℓ × d` sketch (Algorithm 1 line 12).
    /// The sketch remains usable for further inserts afterwards.
    pub fn sketch(&mut self) -> Matrix {
        if self.next_row > self.ell {
            self.shrink();
        }
        self.buf.slice_rows(0, self.ell)
    }

    /// Export the complete sketch state (wire transfer / checkpointing).
    /// `from_state(&sk.export_state())` reproduces the sketch bit-exactly,
    /// including the online error certificate.
    pub fn export_state(&self) -> SketchState {
        SketchState {
            ell: self.ell as u32,
            d: self.d as u32,
            next_row: self.next_row as u32,
            shrink_count: self.shrink_count,
            rows_seen: self.rows_seen,
            delta_sum: self.delta_sum,
            energy_seen: self.energy_seen,
            buf: self.buf.as_slice().to_vec(),
        }
    }

    /// Rebuild a sketch from an exported state (pure-Rust serial backend).
    ///
    /// # Errors
    /// Rejects states with zero `ell`/`d`, a buffer whose length is not
    /// `2ℓ × d`, or `next_row > 2ℓ`.
    pub fn from_state(state: &SketchState) -> Result<FdSketch, String> {
        Self::from_state_with(state, crate::tensor::serial())
    }

    /// [`FdSketch::from_state`] with an explicit kernel backend (the
    /// service recovers sessions onto its configured backend; results are
    /// bit-identical across backends by the determinism contract).
    ///
    /// # Errors
    /// Same validation as [`FdSketch::from_state`].
    pub fn from_state_with(
        state: &SketchState,
        backend: Arc<dyn ShrinkBackend>,
    ) -> Result<FdSketch, String> {
        let (ell, d) = (state.ell as usize, state.d as usize);
        if ell == 0 || d == 0 {
            return Err("sketch state: ell and d must be positive".into());
        }
        if state.buf.len() != 2 * ell * d {
            return Err(format!(
                "sketch state: buffer has {} values, expected {}",
                state.buf.len(),
                2 * ell * d
            ));
        }
        if state.next_row as usize > 2 * ell {
            return Err(format!(
                "sketch state: next_row {} > 2ℓ = {}",
                state.next_row,
                2 * ell
            ));
        }
        Ok(FdSketch {
            ell,
            d,
            buf: Matrix::from_vec(2 * ell, d, state.buf.clone()),
            next_row: state.next_row as usize,
            shrink_count: state.shrink_count,
            rows_seen: state.rows_seen,
            delta_sum: state.delta_sum,
            energy_seen: state.energy_seen,
            backend,
        })
    }

    /// Merge another FD sketch (mergeability property): inserting the other
    /// sketch's rows preserves the summed guarantee up to 2× the bound.
    /// This is how shard-local sketches combine in the pipeline.
    pub fn merge(&mut self, other: &mut FdSketch) {
        assert_eq!(self.d, other.d, "merge dim mismatch");
        let s = other.sketch();
        let mut inserted = 0u64;
        for r in 0..s.rows() {
            let row = s.row(r);
            if row.iter().any(|&v| v != 0.0) {
                self.insert(row);
                inserted += 1;
            }
        }
        // Adopt the other stream's certificate and stats (rows were already
        // counted as sketch rows above; track source stream size instead).
        self.rows_seen = self.rows_seen - inserted + other.rows_seen;
        self.energy_seen = self.energy_seen
            - s.as_slice().iter().map(|&v| (v as f64) * (v as f64)).sum::<f64>()
            + other.energy_seen;
        self.delta_sum += other.delta_sum;
    }
}

/// `‖GᵀG − SᵀS‖₂` via Jacobi eig of the d×d difference (test/report helper —
/// O(d³), only for small-d validation).
pub fn covariance_error(g: &Matrix, s: &Matrix) -> f64 {
    assert_eq!(g.cols(), s.cols());
    let d = g.cols();
    let gtg = g.transpose().gram(); // (Gᵀ)(Gᵀ)ᵀ = GᵀG
    let sts = s.transpose().gram();
    let diff: Vec<f64> = gtg
        .as_slice()
        .iter()
        .zip(sts.as_slice())
        .map(|(&a, &b)| a as f64 - b as f64)
        .collect();
    let (lam, _) = eigh_jacobi(&diff, d);
    lam.iter().fold(0.0f64, |acc, &l| acc.max(l.abs()))
}

/// Smallest eigenvalue of `GᵀG − SᵀS` (PSD check in tests).
pub fn covariance_diff_min_eig(g: &Matrix, s: &Matrix) -> f64 {
    let d = g.cols();
    let gtg = g.transpose().gram();
    let sts = s.transpose().gram();
    let diff: Vec<f64> = gtg
        .as_slice()
        .iter()
        .zip(sts.as_slice())
        .map(|(&a, &b)| a as f64 - b as f64)
        .collect();
    let (lam, _) = eigh_jacobi(&diff, d);
    lam.last().copied().unwrap_or(0.0)
}

/// `2/ℓ · ‖G − G_k‖_F²` — the guarantee's RHS, from the spectrum of GᵀG.
pub fn fd_bound(g: &Matrix, ell: usize, k: usize) -> f64 {
    assert!(k < ell);
    let d = g.cols();
    let gtg = g.transpose().gram();
    let gtg64: Vec<f64> = gtg.as_slice().iter().map(|&v| v as f64).collect();
    let (lam, _) = eigh_jacobi(&gtg64, d);
    let tail: f64 = lam.iter().skip(k).map(|&l| l.max(0.0)).sum();
    2.0 / ell as f64 * tail
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::check::forall;
    use crate::util::rng::Pcg64;

    fn lowrankish(rng: &mut Pcg64, n: usize, d: usize, rank: usize, noise: f32) -> Matrix {
        let u = Matrix::from_fn(n, rank, |_, _| rng.normal_f32());
        let v = Matrix::from_fn(rank, d, |_, _| rng.normal_f32());
        let mut g = u.matmul(&v);
        for val in g.as_mut_slice() {
            *val += noise * rng.normal_f32();
        }
        g
    }

    #[test]
    fn guarantee_holds_on_random_streams() {
        forall("fd_guarantee", 12, |rng| {
            let ell = 2 + rng.below(8) as usize;
            let n = 20 + rng.below(100) as usize;
            let d = 4 + rng.below(24) as usize;
            let g = lowrankish(rng, n, d, 3.min(d), 0.05);
            let mut fd = FdSketch::new(ell, d);
            fd.insert_batch(&g);
            let s = fd.sketch();
            assert_eq!(s.rows(), ell);

            let min_eig = covariance_diff_min_eig(&g, &s);
            let err = covariance_error(&g, &s);
            // f32 accumulation slack scales with the Gram magnitude.
            let f32_slack = 1e-6 * g.frobenius_norm().powi(2) + 1e-6;
            assert!(min_eig >= -f32_slack, "not PSD: {min_eig} (slack {f32_slack})");
            let k = 1.max(ell / 2);
            if k < ell {
                assert!(
                    err <= fd_bound(&g, ell, k) * (1.0 + 1e-3) + f32_slack,
                    "bound violated: {err} > {}",
                    fd_bound(&g, ell, k)
                );
            }
        });
    }

    #[test]
    fn shift_bound_dominates_error() {
        forall("fd_shift_bound", 10, |rng| {
            let (ell, n, d) = (4, 80, 16);
            let g = lowrankish(rng, n, d, 4, 0.1);
            let mut fd = FdSketch::new(ell, d);
            fd.insert_batch(&g);
            let s = fd.sketch();
            let err = covariance_error(&g, &s);
            assert!(
                err <= fd.shift_bound() * (1.0 + 1e-3) + 1e-4,
                "{err} > {}",
                fd.shift_bound()
            );
        });
    }

    #[test]
    fn exact_for_rank_below_ell() {
        forall("fd_exact_lowrank", 10, |rng| {
            let (ell, d, r) = (8, 20, 3);
            let g = lowrankish(rng, 40, d, r, 0.0);
            let mut fd = FdSketch::new(ell, d);
            fd.insert_batch(&g);
            let s = fd.sketch();
            let rel = covariance_error(&g, &s) / (g.frobenius_norm().powi(2)).max(1e-12);
            assert!(rel < 1e-4, "relative err {rel}");
        });
    }

    #[test]
    fn matches_python_reference_shrink_semantics() {
        // Shrink leaves ≤ ℓ live rows and zeroes the rest.
        let mut rng = Pcg64::seeded(5);
        let mut fd = FdSketch::new(4, 16);
        for _ in 0..8 {
            let row: Vec<f32> = (0..16).map(|_| rng.normal_f32()).collect();
            fd.insert(&row);
        }
        assert_eq!(fd.next_row, 8);
        let row: Vec<f32> = (0..16).map(|_| rng.normal_f32()).collect();
        fd.insert(&row); // triggers shrink
        assert_eq!(fd.shrink_count(), 1);
        assert_eq!(fd.next_row, 5);
        for r in 5..8 {
            assert!(fd.buf.row(r).iter().all(|&v| v == 0.0));
        }
    }

    #[test]
    fn memory_is_constant_in_n() {
        let mut fd = FdSketch::new(8, 64);
        let m0 = fd.memory_bytes();
        let mut rng = Pcg64::seeded(6);
        for _ in 0..1000 {
            let row: Vec<f32> = (0..64).map(|_| rng.normal_f32()).collect();
            fd.insert(&row);
        }
        assert_eq!(fd.memory_bytes(), m0);
        assert_eq!(fd.memory_bytes(), 2 * 8 * 64 * 4);
        assert_eq!(fd.rows_seen(), 1000);
    }

    #[test]
    fn merge_preserves_guarantee_within_2x() {
        forall("fd_merge", 8, |rng| {
            let (ell, d) = (6, 16);
            let g1 = lowrankish(rng, 50, d, 4, 0.1);
            let g2 = lowrankish(rng, 50, d, 4, 0.1);
            let mut a = FdSketch::new(ell, d);
            let mut b = FdSketch::new(ell, d);
            a.insert_batch(&g1);
            b.insert_batch(&g2);
            a.merge(&mut b);
            assert_eq!(a.rows_seen(), 100);
            let s = a.sketch();
            let g = Matrix::vstack(&[&g1, &g2]);
            let err = covariance_error(&g, &s);
            let min_eig = covariance_diff_min_eig(&g, &s);
            assert!(min_eig >= -1e-2 * err.max(1e-6));
            let k = ell / 2;
            assert!(err <= 2.0 * fd_bound(&g, ell, k) * (1.0 + 1e-3) + 1e-4);
        });
    }

    #[test]
    fn sketch_then_continue_streaming() {
        let mut rng = Pcg64::seeded(9);
        let mut fd = FdSketch::new(4, 8);
        for _ in 0..20 {
            let row: Vec<f32> = (0..8).map(|_| rng.normal_f32()).collect();
            fd.insert(&row);
        }
        let _mid = fd.sketch();
        for _ in 0..20 {
            let row: Vec<f32> = (0..8).map(|_| rng.normal_f32()).collect();
            fd.insert(&row);
        }
        assert_eq!(fd.rows_seen(), 40);
        let s = fd.sketch();
        assert_eq!(s.rows(), 4);
    }

    #[test]
    fn zero_rows_are_harmless() {
        let mut fd = FdSketch::new(2, 4);
        for _ in 0..10 {
            fd.insert(&[0.0; 4]);
        }
        let s = fd.sketch();
        assert!(s.as_slice().iter().all(|&v| v == 0.0));
        assert_eq!(fd.shift_bound(), 0.0);
    }

    #[test]
    #[should_panic]
    fn wrong_dim_panics() {
        let mut fd = FdSketch::new(2, 4);
        fd.insert(&[1.0, 2.0]);
    }

    #[test]
    fn state_round_trip_is_bit_exact_and_streamable() {
        forall("fd_state_rt", 8, |rng| {
            let (ell, d) = (4, 12);
            let mut fd = FdSketch::new(ell, d);
            let n = 5 + rng.below(40) as usize;
            let g = lowrankish(rng, n, d, 3, 0.2);
            fd.insert_batch(&g);
            let state = fd.export_state();
            let mut back = FdSketch::from_state(&state).unwrap();
            assert_eq!(back.rows_seen(), fd.rows_seen());
            assert_eq!(back.shrink_count(), fd.shrink_count());
            assert_eq!(back.shift_bound(), fd.shift_bound());
            assert_eq!(back.buf.as_slice(), fd.buf.as_slice());
            // Continued streaming diverges nowhere: insert the same suffix
            // into both and compare bit-for-bit.
            let extra = lowrankish(rng, 10, d, 3, 0.2);
            fd.insert_batch(&extra);
            back.insert_batch(&extra);
            assert_eq!(back.buf.as_slice(), fd.buf.as_slice());
            assert_eq!(back.sketch().as_slice(), fd.sketch().as_slice());
        });
    }

    #[test]
    fn state_validation_rejects_bad_shapes() {
        let fd = FdSketch::new(3, 5);
        let mut st = fd.export_state();
        st.buf.pop();
        assert!(FdSketch::from_state(&st).is_err());
        let mut st2 = fd.export_state();
        st2.next_row = 7;
        assert!(FdSketch::from_state(&st2).is_err());
        let mut st3 = fd.export_state();
        st3.ell = 0;
        assert!(FdSketch::from_state(&st3).is_err());
    }
}
