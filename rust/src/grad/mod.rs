//! Pure-Rust reference implementation of the L2 model (python/compile/
//! model.py): MLP forward, label-smoothed cross-entropy, *per-example*
//! gradients via manual backprop, and the SGD+momentum train step.
//!
//! Two jobs:
//! 1. **Parity oracle** for the AOT artifacts — integration tests assert the
//!    PJRT-executed HLO matches this implementation to f32 tolerance, which
//!    pins the whole Python→HLO→Rust chain.
//! 2. **Fallback engine** so selection/trainer/benches run end-to-end even
//!    where artifacts for a given shape haven't been compiled (the
//!    `Backend::Reference` path in `trainer`).
//!
//! The parameter layout matches `model.unflatten`: `[W1 (f·h) | b1 (h) |
//! W2 (h·c) | b2 (c)]`, flat f32[D], row-major.

use crate::tensor::{self, Matrix};
use crate::util::rng::Pcg64;

/// MLP shape; mirrors `ModelConfig` in python/compile/model.py.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct MlpSpec {
    pub f: usize,
    pub h: usize,
    pub c: usize,
}

/// Training hyper-parameters baked into the artifacts (manifest values).
#[derive(Clone, Copy, Debug)]
pub struct TrainHyper {
    pub momentum: f32,
    pub weight_decay: f32,
    pub label_smoothing: f32,
}

impl Default for TrainHyper {
    fn default() -> Self {
        Self {
            momentum: 0.9,
            weight_decay: 5e-4,
            label_smoothing: 0.1,
        }
    }
}

impl MlpSpec {
    pub fn new(f: usize, h: usize, c: usize) -> Self {
        Self { f, h, c }
    }

    /// Flat parameter count D.
    pub fn d(&self) -> usize {
        self.f * self.h + self.h + self.h * self.c + self.c
    }

    /// Offsets of (w1, b1, w2, b2) in the flat vector.
    fn offsets(&self) -> (usize, usize, usize, usize) {
        let w1 = 0;
        let b1 = w1 + self.f * self.h;
        let w2 = b1 + self.h;
        let b2 = w2 + self.h * self.c;
        (w1, b1, w2, b2)
    }

    /// He-style init (W1 ~ N(0, √(2/f)), W2 ~ N(0, √(2/h)), biases 0).
    pub fn init_params(&self, rng: &mut Pcg64) -> Vec<f32> {
        let mut p = vec![0.0f32; self.d()];
        let (w1, b1, w2, b2) = self.offsets();
        let s1 = (2.0 / self.f as f64).sqrt() as f32;
        let s2 = (2.0 / self.h as f64).sqrt() as f32;
        rng.fill_normal(&mut p[w1..b1], s1);
        rng.fill_normal(&mut p[w2..b2], s2);
        p
    }

    /// Forward pass for a batch: logits `[n × c]`.
    pub fn forward(&self, params: &[f32], x: &Matrix) -> Matrix {
        assert_eq!(params.len(), self.d(), "param dim");
        assert_eq!(x.cols(), self.f, "feature dim");
        let (hidden, _pre) = self.hidden(params, x);
        self.logits_from_hidden(params, &hidden)
    }

    fn hidden(&self, params: &[f32], x: &Matrix) -> (Matrix, Matrix) {
        let (w1o, b1o, w2o, _) = self.offsets();
        let w1 = &params[w1o..b1o];
        let b1 = &params[b1o..w2o];
        let n = x.rows();
        let mut pre = Matrix::zeros(n, self.h);
        for i in 0..n {
            let xr = x.row(i);
            let out = pre.row_mut(i);
            out.copy_from_slice(b1);
            for (j, &xj) in xr.iter().enumerate() {
                if xj != 0.0 {
                    tensor::axpy(xj, &w1[j * self.h..(j + 1) * self.h], out);
                }
            }
        }
        let mut hidden = pre.clone();
        for v in hidden.as_mut_slice() {
            if *v < 0.0 {
                *v = 0.0;
            }
        }
        (hidden, pre)
    }

    fn logits_from_hidden(&self, params: &[f32], hidden: &Matrix) -> Matrix {
        let (_, _, w2o, b2o) = self.offsets();
        let w2 = &params[w2o..b2o];
        let b2 = &params[b2o..];
        let n = hidden.rows();
        let mut logits = Matrix::zeros(n, self.c);
        for i in 0..n {
            let hr = hidden.row(i);
            let out = logits.row_mut(i);
            out.copy_from_slice(b2);
            for (j, &hj) in hr.iter().enumerate() {
                if hj != 0.0 {
                    tensor::axpy(hj, &w2[j * self.c..(j + 1) * self.c], out);
                }
            }
        }
        logits
    }

    /// Per-example gradients + losses for a batch with one-hot (or soft)
    /// targets `y [n × c]`. Returns `(G [n × D], losses [n])`.
    pub fn per_example_grads(
        &self,
        params: &[f32],
        x: &Matrix,
        y: &Matrix,
        label_smoothing: f32,
    ) -> (Matrix, Vec<f32>) {
        assert_eq!(y.cols(), self.c);
        assert_eq!(x.rows(), y.rows());
        let n = x.rows();
        let (w1o, b1o, w2o, b2o) = self.offsets();
        let w2 = &params[w2o..b2o];
        let (hidden, pre) = self.hidden(params, x);
        let logits = self.logits_from_hidden(params, &hidden);

        let mut g = Matrix::zeros(n, self.d());
        let mut losses = vec![0.0f32; n];
        let mut probs = vec![0.0f32; self.c];
        let mut ys = vec![0.0f32; self.c];
        let mut dpre = vec![0.0f32; self.h];

        for i in 0..n {
            let lr_ = logits.row(i);
            // stable softmax + loss
            let maxv = lr_.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            let mut z = 0.0f64;
            for (k, &v) in lr_.iter().enumerate() {
                let e = ((v - maxv) as f64).exp();
                probs[k] = e as f32;
                z += e;
            }
            let zf = z as f32;
            let logz = (z.ln()) as f32;
            let mut loss = 0.0f64;
            for k in 0..self.c {
                probs[k] /= zf;
                ys[k] = y.get(i, k) * (1.0 - label_smoothing)
                    + label_smoothing / self.c as f32;
                // -ys * log_softmax
                loss -= ys[k] as f64 * ((lr_[k] - maxv - logz) as f64);
            }
            losses[i] = loss as f32;

            // dlogits = softmax - ys
            let grow = g.row_mut(i);
            let hr = hidden.row(i);
            // dW2[j,k] = h_j * dlogits_k ; db2 = dlogits ; dh_j = Σ_k W2[j,k]*dl_k
            for k in 0..self.c {
                let dl = probs[k] - ys[k];
                grow[b2o + k] = dl;
            }
            for j in 0..self.h {
                let hj = hr[j];
                let w2row = &w2[j * self.c..(j + 1) * self.c];
                let mut dh = 0.0f32;
                for k in 0..self.c {
                    let dl = grow[b2o + k];
                    if hj != 0.0 {
                        grow[w2o + j * self.c + k] = hj * dl;
                    }
                    dh += w2row[k] * dl;
                }
                // relu backward through pre-activation
                dpre[j] = if pre.get(i, j) > 0.0 { dh } else { 0.0 };
            }
            // dW1[j,t] = x_j * dpre_t ; db1 = dpre
            let xr = x.row(i);
            for (j, &xj) in xr.iter().enumerate() {
                if xj != 0.0 {
                    let dst = &mut grow[w1o + j * self.h..w1o + (j + 1) * self.h];
                    for (t, dp) in dpre.iter().enumerate() {
                        dst[t] = xj * dp;
                    }
                }
            }
            grow[b1o..w2o].copy_from_slice(&dpre);
        }
        (g, losses)
    }

    /// One SGD+momentum step on a batch (matches model.train_step):
    /// `g = mean-grad + wd·p; m ← μ·m + g; p ← p − lr·m`. Returns mean loss.
    pub fn train_step(
        &self,
        params: &mut [f32],
        mom: &mut [f32],
        x: &Matrix,
        y: &Matrix,
        lr: f32,
        hyper: &TrainHyper,
    ) -> f32 {
        let n = x.rows();
        let (g, losses) = self.per_example_grads(params, x, y, hyper.label_smoothing);
        let inv = 1.0 / n as f32;
        for j in 0..self.d() {
            let mut gj = 0.0f32;
            for i in 0..n {
                gj += g.get(i, j);
            }
            gj = gj * inv + hyper.weight_decay * params[j];
            mom[j] = hyper.momentum * mom[j] + gj;
            params[j] -= lr * mom[j];
        }
        losses.iter().sum::<f32>() * inv
    }

    /// Top-1 accuracy against integer labels.
    pub fn accuracy(&self, params: &[f32], x: &Matrix, labels: &[u32]) -> f64 {
        let logits = self.forward(params, x);
        let mut correct = 0usize;
        for i in 0..x.rows() {
            let row = logits.row(i);
            let mut best = 0usize;
            for k in 1..self.c {
                if row[k] > row[best] {
                    best = k;
                }
            }
            if best as u32 == labels[i] {
                correct += 1;
            }
        }
        correct as f64 / x.rows().max(1) as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::check::forall;

    fn spec() -> MlpSpec {
        MlpSpec::new(6, 5, 4)
    }

    fn rand_batch(rng: &mut Pcg64, s: &MlpSpec, n: usize) -> (Matrix, Matrix) {
        let x = Matrix::from_fn(n, s.f, |_, _| rng.normal_f32());
        let mut y = Matrix::zeros(n, s.c);
        for i in 0..n {
            let c = rng.below(s.c as u64) as usize;
            y.set(i, c, 1.0);
        }
        (x, y)
    }

    #[test]
    fn grads_match_finite_differences() {
        forall("mlp_fd", 6, |rng| {
            let s = spec();
            let mut p = s.init_params(rng);
            for v in p.iter_mut() {
                *v += 0.01 * rng.normal_f32(); // make biases nonzero too
            }
            let (x, y) = rand_batch(rng, &s, 3);
            let (g, losses) = s.per_example_grads(&p, &x, &y, 0.1);
            // Check a handful of coordinates per example with central diffs.
            for i in 0..3 {
                for _ in 0..8 {
                    let j = rng.below(s.d() as u64) as usize;
                    let eps = 1e-3f32;
                    let mut pp = p.clone();
                    pp[j] += eps;
                    let (_, lp) = s.per_example_grads(&pp, &x, &y, 0.1);
                    pp[j] -= 2.0 * eps;
                    let (_, lm) = s.per_example_grads(&pp, &x, &y, 0.1);
                    let fd = (lp[i] - lm[i]) / (2.0 * eps);
                    assert!(
                        (g.get(i, j) - fd).abs() < 5e-3,
                        "ex {i} param {j}: {} vs {}",
                        g.get(i, j),
                        fd
                    );
                    let _ = losses[i];
                }
            }
        });
    }

    #[test]
    fn loss_at_uniform_logits_is_log_c() {
        let s = spec();
        let p = vec![0.0f32; s.d()]; // zero params -> zero logits
        let mut rng = Pcg64::seeded(1);
        let (x, y) = rand_batch(&mut rng, &s, 5);
        let (_, losses) = s.per_example_grads(&p, &x, &y, 0.1);
        for l in losses {
            assert!((l - (s.c as f32).ln()).abs() < 1e-5, "{l}");
        }
    }

    #[test]
    fn train_step_decreases_loss() {
        let s = spec();
        let mut rng = Pcg64::seeded(2);
        let mut p = s.init_params(&mut rng);
        let mut m = vec![0.0f32; s.d()];
        let (x, y) = rand_batch(&mut rng, &s, 16);
        let hyper = TrainHyper::default();
        let first = s.train_step(&mut p, &mut m, &x, &y, 0.05, &hyper);
        let mut last = first;
        for _ in 0..30 {
            last = s.train_step(&mut p, &mut m, &x, &y, 0.05, &hyper);
        }
        assert!(last < first * 0.9, "{last} !< {first}");
    }

    #[test]
    fn train_step_first_update_math() {
        // From zero momentum: m1 = g + wd*p, p1 = p - lr*m1.
        let s = spec();
        let mut rng = Pcg64::seeded(3);
        let p0 = s.init_params(&mut rng);
        let (x, y) = rand_batch(&mut rng, &s, 4);
        let hyper = TrainHyper::default();
        let (g, _) = s.per_example_grads(&p0, &x, &y, hyper.label_smoothing);
        let mut expect_m = vec![0.0f32; s.d()];
        for j in 0..s.d() {
            let mut gj = 0.0;
            for i in 0..4 {
                gj += g.get(i, j);
            }
            expect_m[j] = gj / 4.0 + hyper.weight_decay * p0[j];
        }
        let mut p = p0.clone();
        let mut m = vec![0.0f32; s.d()];
        s.train_step(&mut p, &mut m, &x, &y, 0.1, &hyper);
        for j in 0..s.d() {
            assert!((m[j] - expect_m[j]).abs() < 1e-5);
            assert!((p[j] - (p0[j] - 0.1 * expect_m[j])).abs() < 1e-5);
        }
    }

    #[test]
    fn accuracy_of_perfect_separator() {
        // 1 feature deciding 2 classes via a hand-built network.
        let s = MlpSpec::new(1, 2, 2);
        // W1 = [[1, -1]], b1 = 0, W2 = [[1,0],[0,1]], b2 = 0.
        let mut p = vec![0.0f32; s.d()];
        p[0] = 1.0; // W1[0,0]
        p[1] = -1.0; // W1[0,1]
        let w2o = s.f * s.h + s.h;
        p[w2o] = 1.0; // W2[0,0]
        p[w2o + 3] = 1.0; // W2[1,1]
        let x = Matrix::from_vec(4, 1, vec![2.0, -2.0, 5.0, -1.0]);
        let labels = vec![0u32, 1, 0, 1];
        assert_eq!(s.accuracy(&p, &x, &labels), 1.0);
    }

    #[test]
    fn per_example_grad_mean_equals_batch_direction() {
        // Mean of per-example grads must equal grad of mean loss; verified
        // implicitly by train_step_first_update_math, plus shape checks here.
        let s = spec();
        let mut rng = Pcg64::seeded(5);
        let p = s.init_params(&mut rng);
        let (x, y) = rand_batch(&mut rng, &s, 7);
        let (g, losses) = s.per_example_grads(&p, &x, &y, 0.1);
        assert_eq!(g.rows(), 7);
        assert_eq!(g.cols(), s.d());
        assert_eq!(losses.len(), 7);
        assert!(losses.iter().all(|&l| l.is_finite() && l > 0.0));
    }
}
