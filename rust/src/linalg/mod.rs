//! Small dense linear algebra (from scratch — no LAPACK binding offline).
//!
//! Sized for the coordinator's needs: the matrices here are at most
//! `2ℓ × 2ℓ` (FD shrink Gram, ℓ ≤ 256) or `k × k` for baseline solvers, so
//! clarity and robustness beat asymptotic tricks. Everything runs in f64
//! internally; the f32 world converts at the boundary.
//!
//! * [`eigh_jacobi`] — cyclic Jacobi eigendecomposition of a symmetric
//!   matrix. This is the heart of the FD shrink step: eig(S Sᵀ) gives
//!   σ² = λ and U, from which the shrink rotation is built without ever
//!   running an SVD over the full `2ℓ × D` buffer (see DESIGN.md).
//! * [`cholesky`] / [`solve_spd`] — SPD solves for GradMatch's OMP step.
//! * [`lu_solve`] — general square solves (GRAFT MaxVol updates).

/// Eigendecomposition of a symmetric matrix (dense, row-major, n×n).
///
/// Returns (eigenvalues descending, eigenvectors as rows of length n) such
/// that `A ≈ Σ_j λ_j v_j v_jᵀ`. Cyclic Jacobi with threshold sweeping;
/// converges quadratically, `O(n³)` per sweep, typically 6–10 sweeps.
pub fn eigh_jacobi(a: &[f64], n: usize) -> (Vec<f64>, Vec<f64>) {
    assert_eq!(a.len(), n * n, "eigh_jacobi shape");
    let mut m = a.to_vec();
    // v starts as identity; accumulates rotations. Row i = eigenvector i.
    let mut v = vec![0.0; n * n];
    for i in 0..n {
        v[i * n + i] = 1.0;
    }

    let max_sweeps = 60;
    for _sweep in 0..max_sweeps {
        // Off-diagonal Frobenius mass.
        let mut off = 0.0f64;
        for i in 0..n {
            for j in (i + 1)..n {
                off += m[i * n + j] * m[i * n + j];
            }
        }
        let diag: f64 = (0..n).map(|i| m[i * n + i] * m[i * n + i]).sum();
        if off <= 1e-26 * (diag + off).max(1e-300) {
            break;
        }
        for p in 0..n {
            for q in (p + 1)..n {
                let apq = m[p * n + q];
                if apq.abs() < 1e-300 {
                    continue;
                }
                let app = m[p * n + p];
                let aqq = m[q * n + q];
                let theta = (aqq - app) / (2.0 * apq);
                let t = theta.signum() / (theta.abs() + (theta * theta + 1.0).sqrt());
                let c = 1.0 / (t * t + 1.0).sqrt();
                let s = t * c;
                // Rotate rows/cols p and q of m.
                for k in 0..n {
                    let mkp = m[k * n + p];
                    let mkq = m[k * n + q];
                    m[k * n + p] = c * mkp - s * mkq;
                    m[k * n + q] = s * mkp + c * mkq;
                }
                for k in 0..n {
                    let mpk = m[p * n + k];
                    let mqk = m[q * n + k];
                    m[p * n + k] = c * mpk - s * mqk;
                    m[q * n + k] = s * mpk + c * mqk;
                }
                // Accumulate rotation into v (rows are vectors).
                for k in 0..n {
                    let vpk = v[p * n + k];
                    let vqk = v[q * n + k];
                    v[p * n + k] = c * vpk - s * vqk;
                    v[q * n + k] = s * vpk + c * vqk;
                }
            }
        }
    }

    let mut lam: Vec<f64> = (0..n).map(|i| m[i * n + i]).collect();
    // Sort descending, permuting eigenvector rows along.
    let mut order: Vec<usize> = (0..n).collect();
    order.sort_by(|&i, &j| lam[j].partial_cmp(&lam[i]).unwrap());
    let lam_sorted: Vec<f64> = order.iter().map(|&i| lam[i]).collect();
    let mut v_sorted = vec![0.0; n * n];
    for (row, &src) in order.iter().enumerate() {
        v_sorted[row * n..(row + 1) * n].copy_from_slice(&v[src * n..(src + 1) * n]);
    }
    lam = lam_sorted;
    (lam, v_sorted)
}

/// Cholesky factorization A = L Lᵀ of an SPD matrix (returns L, row-major
/// lower-triangular). Errors if A is not positive definite.
pub fn cholesky(a: &[f64], n: usize) -> Result<Vec<f64>, String> {
    assert_eq!(a.len(), n * n);
    let mut l = vec![0.0; n * n];
    for i in 0..n {
        for j in 0..=i {
            let mut sum = a[i * n + j];
            for k in 0..j {
                sum -= l[i * n + k] * l[j * n + k];
            }
            if i == j {
                if sum <= 0.0 {
                    return Err(format!("not SPD at pivot {i}: {sum}"));
                }
                l[i * n + i] = sum.sqrt();
            } else {
                l[i * n + j] = sum / l[j * n + j];
            }
        }
    }
    Ok(l)
}

/// Solve A x = b for SPD A via Cholesky (with a tiny ridge retry for
/// near-singular Gram systems from OMP).
pub fn solve_spd(a: &[f64], b: &[f64], n: usize) -> Result<Vec<f64>, String> {
    let l = match cholesky(a, n) {
        Ok(l) => l,
        Err(_) => {
            // Ridge fallback: A + 1e-8·tr(A)/n · I.
            let tr: f64 = (0..n).map(|i| a[i * n + i]).sum();
            let ridge = 1e-8 * (tr / n.max(1) as f64).max(1e-12);
            let mut aa = a.to_vec();
            for i in 0..n {
                aa[i * n + i] += ridge;
            }
            cholesky(&aa, n)?
        }
    };
    // Forward solve L y = b.
    let mut y = vec![0.0; n];
    for i in 0..n {
        let mut sum = b[i];
        for k in 0..i {
            sum -= l[i * n + k] * y[k];
        }
        y[i] = sum / l[i * n + i];
    }
    // Back solve Lᵀ x = y.
    let mut x = vec![0.0; n];
    for i in (0..n).rev() {
        let mut sum = y[i];
        for k in (i + 1)..n {
            sum -= l[k * n + i] * x[k];
        }
        x[i] = sum / l[i * n + i];
    }
    Ok(x)
}

/// LU with partial pivoting; solves A x = b for general square A.
pub fn lu_solve(a: &[f64], b: &[f64], n: usize) -> Result<Vec<f64>, String> {
    assert_eq!(a.len(), n * n);
    assert_eq!(b.len(), n);
    let mut lu = a.to_vec();
    let mut piv: Vec<usize> = (0..n).collect();
    for col in 0..n {
        // Pivot.
        let mut pbest = col;
        let mut vbest = lu[col * n + col].abs();
        for r in (col + 1)..n {
            let v = lu[r * n + col].abs();
            if v > vbest {
                vbest = v;
                pbest = r;
            }
        }
        if vbest < 1e-300 {
            return Err(format!("singular at column {col}"));
        }
        if pbest != col {
            for k in 0..n {
                lu.swap(col * n + k, pbest * n + k);
            }
            piv.swap(col, pbest);
        }
        let pivot = lu[col * n + col];
        for r in (col + 1)..n {
            let f = lu[r * n + col] / pivot;
            lu[r * n + col] = f;
            for k in (col + 1)..n {
                lu[r * n + k] -= f * lu[col * n + k];
            }
        }
    }
    // Apply permutation to b.
    let mut x: Vec<f64> = piv.iter().map(|&p| b[p]).collect();
    // Forward.
    for i in 1..n {
        for k in 0..i {
            x[i] -= lu[i * n + k] * x[k];
        }
    }
    // Backward.
    for i in (0..n).rev() {
        for k in (i + 1)..n {
            x[i] -= lu[i * n + k] * x[k];
        }
        x[i] /= lu[i * n + i];
    }
    Ok(x)
}

/// Determinant-magnitude proxy via LU (used by MaxVol tests).
pub fn abs_det(a: &[f64], n: usize) -> f64 {
    let mut lu = a.to_vec();
    let mut det = 1.0f64;
    for col in 0..n {
        let mut pbest = col;
        let mut vbest = lu[col * n + col].abs();
        for r in (col + 1)..n {
            let v = lu[r * n + col].abs();
            if v > vbest {
                vbest = v;
                pbest = r;
            }
        }
        if vbest < 1e-300 {
            return 0.0;
        }
        if pbest != col {
            for k in 0..n {
                lu.swap(col * n + k, pbest * n + k);
            }
        }
        let pivot = lu[col * n + col];
        det *= pivot.abs();
        for r in (col + 1)..n {
            let f = lu[r * n + col] / pivot;
            for k in (col + 1)..n {
                lu[r * n + k] -= f * lu[col * n + k];
            }
        }
    }
    det
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::check::forall;
    use crate::util::rng::Pcg64;

    fn random_symmetric(rng: &mut Pcg64, n: usize) -> Vec<f64> {
        let mut a = vec![0.0; n * n];
        for i in 0..n {
            for j in 0..=i {
                let v = rng.normal();
                a[i * n + j] = v;
                a[j * n + i] = v;
            }
        }
        a
    }

    fn random_spd(rng: &mut Pcg64, n: usize) -> Vec<f64> {
        // B Bᵀ + n·I.
        let b: Vec<f64> = (0..n * n).map(|_| rng.normal()).collect();
        let mut a = vec![0.0; n * n];
        for i in 0..n {
            for j in 0..n {
                let mut s = 0.0;
                for k in 0..n {
                    s += b[i * n + k] * b[j * n + k];
                }
                a[i * n + j] = s + if i == j { n as f64 } else { 0.0 };
            }
        }
        a
    }

    #[test]
    fn eigh_reconstructs_matrix() {
        forall("eigh_reconstruct", 15, |rng| {
            let n = 1 + rng.below(12) as usize;
            let a = random_symmetric(rng, n);
            let (lam, v) = eigh_jacobi(&a, n);
            // A ?= Σ λ_j v_j v_jᵀ
            for i in 0..n {
                for j in 0..n {
                    let mut s = 0.0;
                    for t in 0..n {
                        s += lam[t] * v[t * n + i] * v[t * n + j];
                    }
                    assert!((s - a[i * n + j]).abs() < 1e-8, "({i},{j}): {s} vs {}", a[i * n + j]);
                }
            }
        });
    }

    #[test]
    fn eigh_vectors_orthonormal() {
        forall("eigh_orthonormal", 15, |rng| {
            let n = 2 + rng.below(10) as usize;
            let a = random_symmetric(rng, n);
            let (_lam, v) = eigh_jacobi(&a, n);
            for i in 0..n {
                for j in 0..n {
                    let dot: f64 = (0..n).map(|k| v[i * n + k] * v[j * n + k]).sum();
                    let want = if i == j { 1.0 } else { 0.0 };
                    assert!((dot - want).abs() < 1e-9, "({i},{j}): {dot}");
                }
            }
        });
    }

    #[test]
    fn eigh_sorted_descending() {
        forall("eigh_sorted", 10, |rng| {
            let n = 2 + rng.below(8) as usize;
            let (lam, _) = eigh_jacobi(&random_symmetric(rng, n), n);
            for w in lam.windows(2) {
                assert!(w[0] >= w[1] - 1e-12);
            }
        });
    }

    #[test]
    fn eigh_diagonal_matrix_exact() {
        let a = vec![3.0, 0.0, 0.0, 0.0, -1.0, 0.0, 0.0, 0.0, 7.0];
        let (lam, _) = eigh_jacobi(&a, 3);
        assert!((lam[0] - 7.0).abs() < 1e-12);
        assert!((lam[1] - 3.0).abs() < 1e-12);
        assert!((lam[2] + 1.0).abs() < 1e-12);
    }

    #[test]
    fn cholesky_round_trip() {
        forall("chol", 15, |rng| {
            let n = 1 + rng.below(10) as usize;
            let a = random_spd(rng, n);
            let l = cholesky(&a, n).unwrap();
            for i in 0..n {
                for j in 0..n {
                    let mut s = 0.0;
                    for k in 0..n {
                        s += l[i * n + k] * l[j * n + k];
                    }
                    assert!((s - a[i * n + j]).abs() < 1e-8);
                }
            }
        });
    }

    #[test]
    fn cholesky_rejects_indefinite() {
        let a = vec![1.0, 2.0, 2.0, 1.0]; // eigenvalues 3, -1
        assert!(cholesky(&a, 2).is_err());
    }

    #[test]
    fn solve_spd_matches_direct() {
        forall("solve_spd", 15, |rng| {
            let n = 1 + rng.below(10) as usize;
            let a = random_spd(rng, n);
            let x_true: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            let b: Vec<f64> = (0..n)
                .map(|i| (0..n).map(|j| a[i * n + j] * x_true[j]).sum())
                .collect();
            let x = solve_spd(&a, &b, n).unwrap();
            for i in 0..n {
                assert!((x[i] - x_true[i]).abs() < 1e-6, "{} vs {}", x[i], x_true[i]);
            }
        });
    }

    #[test]
    fn lu_solve_matches_direct() {
        forall("lu_solve", 15, |rng| {
            let n = 1 + rng.below(10) as usize;
            let a: Vec<f64> = (0..n * n).map(|_| rng.normal()).collect();
            let x_true: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            let b: Vec<f64> = (0..n)
                .map(|i| (0..n).map(|j| a[i * n + j] * x_true[j]).sum())
                .collect();
            match lu_solve(&a, &b, n) {
                Ok(x) => {
                    for i in 0..n {
                        assert!((x[i] - x_true[i]).abs() < 1e-5);
                    }
                }
                Err(_) => {} // singular random draw — acceptable
            }
        });
    }

    #[test]
    fn abs_det_identity_and_scaling() {
        let i3 = vec![1.0, 0.0, 0.0, 0.0, 1.0, 0.0, 0.0, 0.0, 1.0];
        assert!((abs_det(&i3, 3) - 1.0).abs() < 1e-12);
        let d = vec![2.0, 0.0, 0.0, 3.0];
        assert!((abs_det(&d, 2) - 6.0).abs() < 1e-12);
        let sing = vec![1.0, 2.0, 2.0, 4.0];
        assert_eq!(abs_det(&sing, 2), 0.0);
    }
}
