//! Config system: INI-style text config (`[section]`, `key = value`, `#`
//! comments) plus the typed [`RunSpec`] the launcher/benches consume.
//! From scratch (no serde/toml offline); values support string, number,
//! bool, and comma lists.

use std::collections::BTreeMap;

/// Parsed raw config: section -> key -> raw string value.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Config {
    sections: BTreeMap<String, BTreeMap<String, String>>,
}

impl Config {
    pub fn parse(text: &str) -> Result<Config, String> {
        let mut cfg = Config::default();
        let mut section = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = raw.split('#').next().unwrap_or("").trim();
            if line.is_empty() {
                continue;
            }
            if let Some(stripped) = line.strip_prefix('[') {
                let name = stripped
                    .strip_suffix(']')
                    .ok_or_else(|| format!("line {}: unterminated section", lineno + 1))?;
                section = name.trim().to_string();
                cfg.sections.entry(section.clone()).or_default();
            } else if let Some((k, v)) = line.split_once('=') {
                cfg.sections
                    .entry(section.clone())
                    .or_default()
                    .insert(k.trim().to_string(), v.trim().to_string());
            } else {
                return Err(format!("line {}: expected 'key = value'", lineno + 1));
            }
        }
        Ok(cfg)
    }

    pub fn load(path: &str) -> Result<Config, String> {
        let text = std::fs::read_to_string(path).map_err(|e| format!("{path}: {e}"))?;
        Config::parse(&text)
    }

    pub fn get(&self, section: &str, key: &str) -> Option<&str> {
        self.sections.get(section)?.get(key).map(|s| s.as_str())
    }

    /// Override/insert a value (CLI `--set section.key=value`).
    pub fn set(&mut self, section: &str, key: &str, value: &str) {
        self.sections
            .entry(section.to_string())
            .or_default()
            .insert(key.to_string(), value.to_string());
    }

    pub fn get_usize(&self, section: &str, key: &str) -> Result<Option<usize>, String> {
        self.get(section, key)
            .map(|v| v.parse().map_err(|e| format!("{section}.{key}: {e}")))
            .transpose()
    }

    pub fn get_f64(&self, section: &str, key: &str) -> Result<Option<f64>, String> {
        self.get(section, key)
            .map(|v| v.parse().map_err(|e| format!("{section}.{key}: {e}")))
            .transpose()
    }

    pub fn get_bool(&self, section: &str, key: &str) -> Result<Option<bool>, String> {
        match self.get(section, key) {
            None => Ok(None),
            Some("true" | "1" | "yes") => Ok(Some(true)),
            Some("false" | "0" | "no") => Ok(Some(false)),
            Some(v) => Err(format!("{section}.{key}: bad bool '{v}'")),
        }
    }

    pub fn get_list(&self, section: &str, key: &str) -> Option<Vec<String>> {
        self.get(section, key).map(|v| {
            v.split(',')
                .map(|s| s.trim().to_string())
                .filter(|s| !s.is_empty())
                .collect()
        })
    }

    pub fn sections(&self) -> impl Iterator<Item = &str> {
        self.sections.keys().map(|s| s.as_str())
    }
}

/// Selection method identifiers (SAGE + all paper baselines).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Method {
    /// SAGE as benchmarked: agreement scoring with per-class consensus
    /// (equals the paper's plain SAGE at ResNet scale; see DESIGN.md §3 —
    /// on a small-D MLP the global consensus is class-dominated, so the
    /// per-class centroid form is the faithful substrate adaptation).
    Sage,
    /// Algorithm 1 lines 14-15/20 verbatim: ONE global consensus direction.
    /// Kept for ablations (`cargo bench --bench ablation`).
    SageGlobal,
    CbSage,
    Random,
    Drop,
    Glister,
    Craig,
    GradMatch,
    Graft,
    GraftWarm,
    Full,
}

impl Method {
    pub fn parse(s: &str) -> Result<Method, String> {
        Ok(match s.to_ascii_lowercase().as_str() {
            "sage" => Method::Sage,
            "sage-global" | "sageglobal" | "sage_global" => Method::SageGlobal,
            "cb-sage" | "cbsage" | "cb_sage" => Method::CbSage,
            "random" => Method::Random,
            "drop" => Method::Drop,
            "glister" => Method::Glister,
            "craig" => Method::Craig,
            "gradmatch" | "grad-match" => Method::GradMatch,
            "graft" => Method::Graft,
            "graft-warm" | "graftwarm" => Method::GraftWarm,
            "full" | "full data" | "full-data" => Method::Full,
            other => return Err(format!("unknown method '{other}'")),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            Method::Sage => "SAGE",
            Method::SageGlobal => "SAGE-global",
            Method::CbSage => "CB-SAGE",
            Method::Random => "Random",
            Method::Drop => "DROP",
            Method::Glister => "GLISTER",
            Method::Craig => "CRAIG",
            Method::GradMatch => "GradMatch",
            Method::Graft => "GRAFT",
            Method::GraftWarm => "GRAFT-Warm",
            Method::Full => "Full data",
        }
    }

    pub fn all_baselines() -> &'static [Method] {
        &[
            Method::Random,
            Method::Drop,
            Method::Glister,
            Method::Craig,
            Method::GradMatch,
            Method::Graft,
            Method::GraftWarm,
        ]
    }
}

/// Fully-resolved run specification for one (dataset, method, fraction, seed).
#[derive(Clone, Debug)]
pub struct RunSpec {
    /// Simulated benchmark name (cifar10/cifar100/fmnist/tinyimagenet/caltech256).
    pub dataset: String,
    /// Artifact/model config name in artifacts/manifest.json.
    pub model: String,
    pub method: Method,
    /// Kept fraction f in (0, 1].
    pub fraction: f64,
    pub seed: u64,
    pub train_examples: usize,
    pub test_examples: usize,
    pub epochs: usize,
    pub base_lr: f64,
    /// FD sketch size ℓ (must match the model config's l).
    pub sketch_size: usize,
    pub threads: usize,
    pub artifacts_dir: String,
}

impl Default for RunSpec {
    fn default() -> Self {
        Self {
            dataset: "cifar10".into(),
            model: "small".into(),
            method: Method::Sage,
            fraction: 0.25,
            seed: 0,
            train_examples: 4096,
            test_examples: 1024,
            epochs: 10,
            base_lr: 0.05,
            sketch_size: 32,
            threads: crate::util::threadpool::default_threads(),
            artifacts_dir: "artifacts".into(),
        }
    }
}

impl RunSpec {
    /// Build from a `[run]` section, falling back to defaults.
    pub fn from_config(cfg: &Config) -> Result<RunSpec, String> {
        let mut spec = RunSpec::default();
        let s = "run";
        if let Some(v) = cfg.get(s, "dataset") {
            spec.dataset = v.to_string();
        }
        if let Some(v) = cfg.get(s, "model") {
            spec.model = v.to_string();
        }
        if let Some(v) = cfg.get(s, "method") {
            spec.method = Method::parse(v)?;
        }
        if let Some(v) = cfg.get_f64(s, "fraction")? {
            spec.fraction = v;
        }
        if let Some(v) = cfg.get_usize(s, "seed")? {
            spec.seed = v as u64;
        }
        if let Some(v) = cfg.get_usize(s, "train_examples")? {
            spec.train_examples = v;
        }
        if let Some(v) = cfg.get_usize(s, "test_examples")? {
            spec.test_examples = v;
        }
        if let Some(v) = cfg.get_usize(s, "epochs")? {
            spec.epochs = v;
        }
        if let Some(v) = cfg.get_f64(s, "base_lr")? {
            spec.base_lr = v;
        }
        if let Some(v) = cfg.get_usize(s, "sketch_size")? {
            spec.sketch_size = v;
        }
        if let Some(v) = cfg.get_usize(s, "threads")? {
            spec.threads = v;
        }
        if let Some(v) = cfg.get(s, "artifacts_dir") {
            spec.artifacts_dir = v.to_string();
        }
        spec.validate()?;
        Ok(spec)
    }

    pub fn validate(&self) -> Result<(), String> {
        if !(self.fraction > 0.0 && self.fraction <= 1.0) {
            return Err(format!("fraction {} not in (0, 1]", self.fraction));
        }
        if self.train_examples == 0 || self.epochs == 0 {
            return Err("train_examples and epochs must be > 0".into());
        }
        if self.sketch_size == 0 {
            return Err("sketch_size must be > 0".into());
        }
        Ok(())
    }

    /// Target subset size k = ceil(f * N).
    pub fn subset_size(&self) -> usize {
        ((self.fraction * self.train_examples as f64).ceil() as usize)
            .clamp(1, self.train_examples)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
# experiment
[run]
dataset = cifar100
method = cb-sage
fraction = 0.15
seed = 3
epochs = 8         # inline comment
sketch_size = 64

[pipeline]
workers = 4
shards = 8
"#;

    #[test]
    fn parses_sections_and_comments() {
        let cfg = Config::parse(SAMPLE).unwrap();
        assert_eq!(cfg.get("run", "dataset"), Some("cifar100"));
        assert_eq!(cfg.get_usize("pipeline", "workers").unwrap(), Some(4));
        assert_eq!(cfg.get("run", "epochs"), Some("8"));
    }

    #[test]
    fn run_spec_from_config() {
        let cfg = Config::parse(SAMPLE).unwrap();
        let spec = RunSpec::from_config(&cfg).unwrap();
        assert_eq!(spec.method, Method::CbSage);
        assert!((spec.fraction - 0.15).abs() < 1e-12);
        assert_eq!(spec.epochs, 8);
        assert_eq!(spec.subset_size(), (0.15f64 * 4096.0).ceil() as usize);
    }

    #[test]
    fn rejects_bad_fraction() {
        let mut cfg = Config::default();
        cfg.set("run", "fraction", "1.5");
        assert!(RunSpec::from_config(&cfg).is_err());
    }

    #[test]
    fn rejects_malformed_lines() {
        assert!(Config::parse("[run").is_err());
        assert!(Config::parse("just words").is_err());
    }

    #[test]
    fn method_parse_round_trip() {
        for m in [
            Method::Sage,
            Method::SageGlobal,
            Method::CbSage,
            Method::Random,
            Method::Drop,
            Method::Glister,
            Method::Craig,
            Method::GradMatch,
            Method::Graft,
            Method::GraftWarm,
            Method::Full,
        ] {
            assert_eq!(Method::parse(m.name()).unwrap(), m);
        }
        assert!(Method::parse("nope").is_err());
    }

    #[test]
    fn set_overrides() {
        let mut cfg = Config::parse(SAMPLE).unwrap();
        cfg.set("run", "dataset", "fmnist");
        assert_eq!(cfg.get("run", "dataset"), Some("fmnist"));
    }

    #[test]
    fn get_list_and_bool() {
        let cfg = Config::parse("[a]\nxs = 1, 2,3\nflag = true\n").unwrap();
        assert_eq!(
            cfg.get_list("a", "xs"),
            Some(vec!["1".into(), "2".into(), "3".into()])
        );
        assert_eq!(cfg.get_bool("a", "flag").unwrap(), Some(true));
        assert_eq!(cfg.get_bool("a", "missing").unwrap(), None);
    }
}
