//! Baseline subset-selection methods — from-scratch re-implementations of
//! every comparator in the paper's evaluation (§3): Random, DROP, GLISTER,
//! CRAIG, GradMatch, GRAFT and GRAFT-Warm.
//!
//! All methods consume the same inputs SAGE does — the sketched per-example
//! projections `z_i = S g_i` (plus labels/norms) — so the comparison
//! isolates the *selection rule*, matching how the paper's harness fixes
//! the training recipe across methods. Where the original operates on
//! full gradients or deep features, the sketched projection is the
//! substituted low-rank surrogate (DESIGN.md §3); each function documents
//! its simplifications.

use crate::config::Method;
use crate::selection::{select_class_balanced, select_top_k, Scores, TopK};
use crate::tensor::{self, ComputeBackend, Matrix};
use crate::util::rng::Pcg64;

/// Everything a selection rule may use.
pub struct SelectionInputs<'a> {
    pub scores: &'a Scores,
    /// Mean *normalized* validation projection (GLISTER's target); computed
    /// by the pipeline from a held-out split.
    pub val_consensus: Option<Vec<f32>>,
    pub num_classes: usize,
    pub seed: u64,
    /// Kernel backend for the rules' `N × ℓ` matrix products (GLISTER /
    /// GradMatch gain scans, CRAIG similarity sweeps, GRAFT's MaxVol
    /// residual scan). Bit-identical across serial/parallel backends, so
    /// selections never depend on the worker count.
    pub compute: &'a dyn ComputeBackend,
}

/// Dispatch a method by name. `k` is the subset budget.
pub fn select(method: Method, inputs: &SelectionInputs, k: usize) -> Vec<usize> {
    select_weighted(method, inputs, k).0
}

/// Like [`select`], additionally returning per-selected-example training
/// weights when the method defines them (CRAIG's facility-location cluster
/// sizes — each selected medoid is weighted by the number of examples it
/// covers, fed to `trainer::train_weighted`).
pub fn select_weighted(
    method: Method,
    inputs: &SelectionInputs,
    k: usize,
) -> (Vec<usize>, Option<Vec<f32>>) {
    let n = inputs.scores.entries.len();
    let k = k.min(n);
    if method == Method::Craig {
        return craig_weighted(inputs, k);
    }
    let indices = select_unweighted(method, inputs, k);
    (indices, None)
}

fn select_unweighted(method: Method, inputs: &SelectionInputs, k: usize) -> Vec<usize> {
    let n = inputs.scores.entries.len();
    let k = k.min(n);
    match method {
        // SAGE-as-benchmarked = per-class consensus (see Method docs);
        // identical to CB-SAGE's selection rule.
        Method::Sage | Method::CbSage => {
            select_class_balanced(inputs.scores, inputs.num_classes, k)
        }
        // Algorithm 1 verbatim: global consensus, plain top-k.
        Method::SageGlobal => select_top_k(inputs.scores, k),
        Method::Random => random(inputs, k),
        Method::Drop => drop_norm_proxy(inputs, k),
        Method::Glister => glister(inputs, k),
        Method::Craig => craig_weighted(inputs, k).0,
        Method::GradMatch => gradmatch(inputs, k),
        Method::Graft => graft(inputs, k, false),
        Method::GraftWarm => graft(inputs, k, true),
        Method::Full => (0..n).map(|r| inputs.scores.entries[r].index).collect(),
    }
}

/// Uniform random subset (the floor every method must beat).
fn random(inputs: &SelectionInputs, k: usize) -> Vec<usize> {
    let mut rng = Pcg64::new(inputs.seed, 0x52414E44);
    let n = inputs.scores.entries.len();
    let rows = rng.sample_indices(n, k);
    let mut out: Vec<usize> = rows
        .into_iter()
        .map(|r| inputs.scores.entries[r].index)
        .collect();
    out.sort_unstable();
    out
}

/// DROP — scalable importance-proxy pruning: a single cheap per-example
/// proxy, no pairwise terms. Implementation: *drop* the highest-loss 20%
/// at the scoring parameters (the unlearnable/noisy tail the proxy flags),
/// then sample the budget uniformly from the survivors — keeping the
/// diversity of random sampling while shedding inconsistent examples.
/// (A raw gradient-norm top-k ranking inverts under label noise; see
/// examples/noise_sweep.rs and the ablation bench.)
fn drop_norm_proxy(inputs: &SelectionInputs, k: usize) -> Vec<usize> {
    const DROP_FRACTION: f64 = 0.2;
    let n = inputs.scores.entries.len();
    let keep_n = ((n as f64 * (1.0 - DROP_FRACTION)) as usize).max(k.min(n));
    // Rows sorted by ascending loss; survivors = first keep_n.
    let mut rows: Vec<usize> = (0..n).collect();
    rows.sort_by(|&a, &b| {
        inputs.scores.entries[a]
            .loss
            .partial_cmp(&inputs.scores.entries[b].loss)
            .unwrap_or(std::cmp::Ordering::Equal)
    });
    rows.truncate(keep_n);
    let mut rng = Pcg64::new(inputs.seed, 0xD80B);
    let picks = rng.sample_indices(rows.len(), k.min(rows.len()));
    let mut out: Vec<usize> = picks
        .into_iter()
        .map(|p| inputs.scores.entries[rows[p]].index)
        .collect();
    out.sort_unstable();
    out
}

/// GLISTER — generalization-based greedy: pick examples whose (sketched)
/// gradients align with the *validation* gradient direction, re-estimating
/// the residual target after each pick (one-step Taylor form of the bilevel
/// objective, on projections).
fn glister(inputs: &SelectionInputs, k: usize) -> Vec<usize> {
    let scores = inputs.scores;
    let n = scores.entries.len();
    // Target: validation consensus; falls back to train consensus.
    let target: Vec<f32> = inputs
        .val_consensus
        .clone()
        .unwrap_or_else(|| scores.consensus.clone());
    let mut residual: Vec<f64> = target.iter().map(|&v| v as f64).collect();
    let mut chosen = vec![false; n];
    let mut out = Vec::with_capacity(k);
    let damp = 1.0 / (k.max(1) as f64);
    for _ in 0..k {
        let rf: Vec<f32> = residual.iter().map(|&v| v as f32).collect();
        // One kernel-layer matvec per pick: gains = Ẑ·r over all rows.
        let gains = inputs.compute.matvec(&scores.zhat, &rf);
        let mut best = usize::MAX;
        let mut best_gain = f32::NEG_INFINITY;
        for (r, &gain) in gains.iter().enumerate() {
            if chosen[r] {
                continue;
            }
            if gain > best_gain {
                best_gain = gain;
                best = r;
            }
        }
        if best == usize::MAX {
            break;
        }
        chosen[best] = true;
        out.push(scores.entries[best].index);
        // Move the target away from the captured direction (greedy residual).
        let zr = scores.zhat.row(best);
        for (j, &v) in zr.iter().enumerate() {
            residual[j] -= damp * v as f64;
        }
    }
    out.sort_unstable();
    out
}

/// CRAIG — facility-location coverage: maximize Σ_i max_{j∈T} sim(i, j)
/// with cosine similarity in the sketched space, via stochastic ("lazier
/// than lazy") greedy [Mirzasoleiman et al. 2015]. Returns (indices,
/// weights): weight_j = |cluster(j)| = #examples whose best selected
/// similarity is achieved by medoid j.
fn craig_weighted(inputs: &SelectionInputs, k: usize) -> (Vec<usize>, Option<Vec<f32>>) {
    let scores = inputs.scores;
    let n = scores.entries.len();
    let mut rng = Pcg64::new(inputs.seed, 0xC4A16);
    // best_sim[i] = max similarity of i to the selected set so far.
    let mut best_sim = vec![f32::NEG_INFINITY; n];
    let mut chosen = vec![false; n];
    let mut out = Vec::with_capacity(k);
    // Stochastic-greedy sample size: (n/k)·ln(1/ε), ε = 0.1 — min 32.
    let sample = (((n as f64 / k.max(1) as f64) * (10.0f64).ln()).ceil() as usize)
        .clamp(32, n);
    // best_medoid[i] = which selected row currently covers example i.
    let mut best_medoid = vec![usize::MAX; n];
    let mut selected_rows: Vec<usize> = Vec::with_capacity(k);
    for _ in 0..k {
        let mut best_row = usize::MAX;
        let mut best_gain = f32::NEG_INFINITY;
        for _ in 0..sample {
            let r = rng.below(n as u64) as usize;
            if chosen[r] {
                continue;
            }
            // Marginal facility-location gain of adding r: one kernel-layer
            // similarity sweep sims = Ẑ·ẑ_r over all rows.
            let sims = inputs.compute.matvec(&scores.zhat, scores.zhat.row(r));
            let mut gain = 0.0f32;
            for (i, &sim) in sims.iter().enumerate() {
                let cur = if best_sim[i] == f32::NEG_INFINITY { 0.0 } else { best_sim[i] };
                if sim > cur {
                    gain += sim - cur;
                }
            }
            if gain > best_gain {
                best_gain = gain;
                best_row = r;
            }
        }
        if best_row == usize::MAX {
            // All sampled rows were chosen; fall back to first unchosen.
            match (0..n).find(|&r| !chosen[r]) {
                Some(r) => best_row = r,
                None => break,
            }
        }
        chosen[best_row] = true;
        out.push(scores.entries[best_row].index);
        selected_rows.push(best_row);
        let sims = inputs.compute.matvec(&scores.zhat, scores.zhat.row(best_row));
        for (i, &sim) in sims.iter().enumerate() {
            if sim > best_sim[i] {
                best_sim[i] = sim;
                best_medoid[i] = best_row;
            }
        }
    }
    // Cluster sizes -> weights, aligned with the (sorted) index order.
    let mut cluster = std::collections::HashMap::new();
    for &m in best_medoid.iter().filter(|&&m| m != usize::MAX) {
        *cluster.entry(m).or_insert(0usize) += 1;
    }
    let mut pairs: Vec<(usize, f32)> = selected_rows
        .iter()
        .map(|&r| (
            scores.entries[r].index,
            cluster.get(&r).copied().unwrap_or(0).max(1) as f32,
        ))
        .collect();
    pairs.sort_unstable_by_key(|&(i, _)| i);
    let indices: Vec<usize> = pairs.iter().map(|&(i, _)| i).collect();
    let weights: Vec<f32> = pairs.iter().map(|&(_, w)| w).collect();
    (indices, Some(weights))
}

/// GradMatch — matching pursuit toward the full-data mean gradient in the
/// sketched space: residual r ← z_Σ − Σ_{j∈T} ⟨proj⟩, greedy argmax ⟨ẑ_i, r⟩.
/// (OMP's per-step least-squares re-solve is replaced by matching pursuit;
/// with normalized atoms the greedy picks coincide in the well-separated
/// regime the paper evaluates.)
fn gradmatch(inputs: &SelectionInputs, k: usize) -> Vec<usize> {
    let scores = inputs.scores;
    let n = scores.entries.len();
    let ell = scores.ell;
    // Target: sum of raw projections  Σ z_i = Σ norm_i · ẑ_i.
    let mut residual = vec![0.0f64; ell];
    for (r, e) in scores.entries.iter().enumerate() {
        let row = scores.zhat.row(r);
        for (j, &v) in row.iter().enumerate() {
            residual[j] += (e.norm * v) as f64;
        }
    }
    let mut chosen = vec![false; n];
    let mut out = Vec::with_capacity(k);
    for _ in 0..k {
        let rf: Vec<f32> = residual.iter().map(|&v| v as f32).collect();
        // Matching-pursuit gain scan through the kernel layer.
        let gains = inputs.compute.matvec(&scores.zhat, &rf);
        let mut best = usize::MAX;
        let mut best_val = f32::NEG_INFINITY;
        for (r, &v) in gains.iter().enumerate() {
            if chosen[r] {
                continue;
            }
            if v > best_val {
                best_val = v;
                best = r;
            }
        }
        if best == usize::MAX {
            break;
        }
        chosen[best] = true;
        out.push(scores.entries[best].index);
        // Subtract the atom's projection onto the residual (matching pursuit).
        let zb = scores.zhat.row(best);
        let coef: f64 = zb
            .iter()
            .zip(residual.iter())
            .map(|(&a, &b)| a as f64 * b)
            .sum();
        let coef = coef.max(0.0); // nonneg weights as in GradMatch
        for (j, &v) in zb.iter().enumerate() {
            residual[j] -= coef * v as f64;
        }
    }
    out.sort_unstable();
    out
}

/// GRAFT — gradient-aware Fast MaxVol: greedy rectangular max-volume row
/// selection on the projected matrix (pivoted Gram–Schmidt: repeatedly take
/// the row with the largest residual after projecting out the span of the
/// selected rows), then fill any budget beyond the rank by the dynamic
/// gradient-alignment adjustment (agreement score α_i; magnitude is NOT
/// used for the fill — under label noise the largest-norm gradients are
/// the mislabeled ones, see examples/noise_sweep.rs). `warm=true` (GRAFT-Warm)
/// restricts MaxVol to a warm candidate pool of the `4k` highest-magnitude
/// rows — the warm-start heuristic of the GRAFT paper.
fn graft(inputs: &SelectionInputs, k: usize, warm: bool) -> Vec<usize> {
    let scores = inputs.scores;
    let n = scores.entries.len();
    let ell = scores.ell;

    // Candidate pool.
    let pool: Vec<usize> = if warm {
        let mut tk = TopK::new((4 * k).min(n));
        for (r, e) in scores.entries.iter().enumerate() {
            tk.push(e.norm, r);
        }
        tk.into_sorted_indices()
    } else {
        (0..n).collect()
    };

    // Raw z rows (magnitude matters for volume): z_i = norm_i * ẑ_i.
    // residual_row[r] kept implicitly: we orthogonalize a working copy.
    let mut work = Matrix::zeros(pool.len(), ell);
    for (p, &r) in pool.iter().enumerate() {
        let e = &scores.entries[r];
        let src = scores.zhat.row(r);
        let dst = work.row_mut(p);
        for (j, &v) in src.iter().enumerate() {
            dst[j] = e.norm * v;
        }
    }

    let mut chosen_pool = vec![false; pool.len()];
    let mut out_rows: Vec<usize> = Vec::with_capacity(k);
    let maxvol_steps = k.min(ell);
    for _ in 0..maxvol_steps {
        // Largest residual row: batched row-energy scan through the kernel
        // layer (‖·‖² — monotone in the norm, same argmax).
        let energies = inputs.compute.row_energies(&work);
        let mut best = usize::MAX;
        let mut best_energy = 0.0f64;
        for (p, &en) in energies.iter().enumerate() {
            if chosen_pool[p] {
                continue;
            }
            if en > best_energy {
                best_energy = en;
                best = p;
            }
        }
        if best == usize::MAX || best_energy < 1e-18 {
            break; // span exhausted
        }
        chosen_pool[best] = true;
        out_rows.push(pool[best]);
        // Orthogonalize remaining rows against the chosen direction: the
        // coefficient scan is one kernel-layer matvec, the rank-1 update a
        // row sweep of axpys.
        let mut q = work.row(best).to_vec();
        // The whole orthogonalization runs on the backend's dispatch tier
        // (matvec above included), so pinned-tier runs stay coherent.
        let d = inputs.compute.dispatch();
        d.normalize_in_place(&mut q);
        let coefs = inputs.compute.matvec(&work, &q);
        for (p, &c) in coefs.iter().enumerate() {
            if chosen_pool[p] {
                continue;
            }
            if c != 0.0 {
                d.axpy(-c, &q, work.row_mut(p));
            }
        }
    }

    // Fill the rest by alignment-adjusted magnitude.
    if out_rows.len() < k {
        let mut tk = TopK::new(k - out_rows.len());
        let in_out: std::collections::HashSet<usize> = out_rows.iter().copied().collect();
        for (r, e) in scores.entries.iter().enumerate() {
            if in_out.contains(&r) {
                continue;
            }
            tk.push(e.alpha, r);
        }
        out_rows.extend(tk.into_sorted_indices());
    }

    let mut out: Vec<usize> = out_rows
        .into_iter()
        .map(|r| scores.entries[r].index)
        .collect();
    out.sort_unstable();
    out.truncate(k);
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::selection::AgreementScorer;
    use crate::util::check::forall;

    fn make_scores(rng: &mut Pcg64, n: usize, ell: usize, classes: u32) -> Scores {
        let mut scorer = AgreementScorer::new(ell);
        let mut z = Matrix::zeros(n, ell);
        let mut norms = vec![0.0f32; n];
        let mut dir = vec![0.0f32; ell];
        rng.fill_normal(&mut dir, 1.0);
        tensor::normalize_in_place(&mut dir);
        for i in 0..n {
            let row = z.row_mut(i);
            for (j, &d) in dir.iter().enumerate() {
                row[j] = d + 0.8 * rng.normal_f32();
            }
            norms[i] = (0.2 + 2.0 * rng.next_f32()) as f32;
            tensor::normalize_in_place(row);
        }
        let idx: Vec<usize> = (0..n).collect();
        let labels: Vec<u32> = (0..n).map(|_| rng.below(classes as u64) as u32).collect();
        scorer.add_batch(&idx, &labels, &z, &norms, &vec![1.0; n]);
        scorer.finalize()
    }

    static SERIAL: crate::tensor::SerialBackend = crate::tensor::SerialBackend;

    fn inputs<'a>(scores: &'a Scores, classes: usize) -> SelectionInputs<'a> {
        SelectionInputs {
            scores,
            val_consensus: None,
            num_classes: classes,
            seed: 7,
            compute: &SERIAL,
        }
    }

    #[test]
    fn every_method_returns_k_unique_valid_indices() {
        forall("baselines_k", 6, |rng| {
            let n = 60 + rng.below(60) as usize;
            let scores = make_scores(rng, n, 8, 4);
            let inp = inputs(&scores, 4);
            let k = 1 + rng.below(40) as usize;
            for m in [
                Method::Sage,
                Method::SageGlobal,
                Method::CbSage,
                Method::Random,
                Method::Drop,
                Method::Glister,
                Method::Craig,
                Method::GradMatch,
                Method::Graft,
                Method::GraftWarm,
            ] {
                let sel = select(m, &inp, k);
                assert_eq!(sel.len(), k, "{m:?}");
                let mut uniq = sel.clone();
                uniq.sort_unstable();
                uniq.dedup();
                assert_eq!(uniq.len(), k, "{m:?} dup indices");
                assert!(uniq.iter().all(|&i| i < n), "{m:?} oob");
            }
        });
    }

    #[test]
    fn random_is_seed_deterministic() {
        let mut rng = Pcg64::seeded(1);
        let scores = make_scores(&mut rng, 100, 8, 4);
        let inp = inputs(&scores, 4);
        let a = select(Method::Random, &inp, 20);
        let b = select(Method::Random, &inp, 20);
        assert_eq!(a, b);
    }

    #[test]
    fn drop_excludes_highest_loss_tail() {
        let mut rng = Pcg64::seeded(2);
        let mut scores = make_scores(&mut rng, 50, 6, 2);
        for e in scores.entries.iter_mut() {
            e.loss = rng.next_f32() * 3.0;
        }
        let inp = inputs(&scores, 2);
        let sel = select(Method::Drop, &inp, 10);
        assert_eq!(sel.len(), 10);
        // Survivor pool = lowest-loss 80%; nothing above that cut is kept.
        let mut losses: Vec<f32> = scores.entries.iter().map(|e| e.loss).collect();
        losses.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let cut = losses[39]; // 80% of 50
        for &i in &sel {
            let e = scores.entries.iter().find(|e| e.index == i).unwrap();
            assert!(e.loss <= cut + 1e-6, "kept loss {} above cut {cut}", e.loss);
        }
        // Deterministic per seed.
        assert_eq!(sel, select(Method::Drop, &inp, 10));
    }

    #[test]
    fn craig_improves_coverage_over_random() {
        // Facility-location objective of CRAIG's pick should beat random's.
        let mut rng = Pcg64::seeded(3);
        let scores = make_scores(&mut rng, 120, 8, 4);
        let inp = inputs(&scores, 4);
        let fl = |sel: &[usize]| -> f64 {
            let rows: Vec<usize> = sel
                .iter()
                .map(|&i| scores.entries.iter().position(|e| e.index == i).unwrap())
                .collect();
            (0..120)
                .map(|i| {
                    rows.iter()
                        .map(|&r| tensor::dot(scores.zhat.row(i), scores.zhat.row(r)) as f64)
                        .fold(f64::NEG_INFINITY, f64::max)
                })
                .sum()
        };
        let c = fl(&select(Method::Craig, &inp, 12));
        let r = fl(&select(Method::Random, &inp, 12));
        assert!(c >= r - 1e-6, "craig {c} < random {r}");
    }

    #[test]
    fn gradmatch_first_pick_matches_sum_direction() {
        // MP's first atom must be argmax ⟨ẑ_i, Σ_j z_j⟩ (the residual starts
        // at the full-gradient sum); later picks diversify by design.
        let mut rng = Pcg64::seeded(4);
        let scores = make_scores(&mut rng, 80, 8, 4);
        let inp = inputs(&scores, 4);
        let sel = select(Method::GradMatch, &inp, 20);
        assert_eq!(sel.len(), 20);
        let mut target = vec![0.0f32; 8];
        for (r, e) in scores.entries.iter().enumerate() {
            for (j, &v) in scores.zhat.row(r).iter().enumerate() {
                target[j] += e.norm * v;
            }
        }
        let best = (0..80)
            .max_by(|&a, &b| {
                tensor::dot(scores.zhat.row(a), &target)
                    .partial_cmp(&tensor::dot(scores.zhat.row(b), &target))
                    .unwrap()
            })
            .unwrap();
        assert!(sel.contains(&scores.entries[best].index));
    }

    #[test]
    fn graft_first_picks_span_distinct_directions() {
        let mut rng = Pcg64::seeded(5);
        let scores = make_scores(&mut rng, 100, 6, 4);
        let inp = inputs(&scores, 4);
        let sel = select(Method::Graft, &inp, 6);
        // Gram of the selected ẑ rows should be well-conditioned (volume > 0).
        let rows: Vec<usize> = sel
            .iter()
            .map(|&i| scores.entries.iter().position(|e| e.index == i).unwrap())
            .collect();
        let mut m = Matrix::zeros(6, 6);
        for (a, &ra) in rows.iter().enumerate() {
            for (b, &rb) in rows.iter().enumerate() {
                m.set(a, b, tensor::dot(scores.zhat.row(ra), scores.zhat.row(rb)));
            }
        }
        let g64: Vec<f64> = m.as_slice().iter().map(|&v| v as f64).collect();
        let det = crate::linalg::abs_det(&g64, 6);
        assert!(det > 1e-8, "volume {det}");
    }

    #[test]
    fn glister_uses_validation_direction() {
        let mut rng = Pcg64::seeded(6);
        let scores = make_scores(&mut rng, 100, 8, 4);
        // Validation consensus = a specific basis direction.
        let mut v = vec![0.0f32; 8];
        v[0] = 1.0;
        let inp = SelectionInputs {
            scores: &scores,
            val_consensus: Some(v),
            num_classes: 4,
            seed: 7,
            compute: &SERIAL,
        };
        let sel = select(Method::Glister, &inp, 10);
        // Selected rows should have above-average first coordinate.
        let mean_sel: f32 = sel
            .iter()
            .map(|&i| {
                let r = scores.entries.iter().position(|e| e.index == i).unwrap();
                scores.zhat.row(r)[0]
            })
            .sum::<f32>()
            / 10.0;
        let mean_all: f32 = (0..100).map(|r| scores.zhat.row(r)[0]).sum::<f32>() / 100.0;
        assert!(mean_sel > mean_all, "{mean_sel} <= {mean_all}");
    }

    #[test]
    fn full_returns_everything() {
        let mut rng = Pcg64::seeded(8);
        let scores = make_scores(&mut rng, 30, 4, 2);
        let inp = inputs(&scores, 2);
        assert_eq!(select(Method::Full, &inp, 5).len(), 30);
    }
}
