//! The [`ComputeBackend`] kernel layer: one trait for every hot contraction
//! (FD shrink Gram + rotation, Phase-II projection, consensus matvec,
//! batched row norms/energies), with a serial reference implementation and
//! a threadpool-parallel implementation that is **bit-identical** to it.
//!
//! # Determinism contract
//!
//! Every operation's output is defined by the serial microkernels in
//! [`kernels`]: each output element is produced by exactly one kernel call
//! with a fixed internal accumulation order. [`ParallelBackend`] splits the
//! output row grid into *fixed, worker-count-independent* chunks
//! ([`kernels::row_chunk`]) and runs the same kernels per chunk, so for any
//! op `B` and inputs `x`:
//!
//! ```text
//! ParallelBackend(w).op(x) ≡ SerialBackend.op(x)   bitwise, ∀ w
//! ```
//!
//! This is what lets the service guarantee "served selection ≡ offline
//! `run_selection`" survive arbitrary `--workers` settings on either side
//! (docs/ARCHITECTURE.md, "Kernel layer & determinism contract").
//!
//! Trait methods have serial default implementations, so narrow backends
//! (e.g. the XLA shrink backend, which accelerates only `gram`/`apply_rot`)
//! widen to the full kernel layer for free.

use super::{kernels, Matrix};
use crate::util::threadpool::ThreadPool;
use crate::util::{metrics, trace};
use std::sync::{Arc, OnceLock};

/// Minimum number of inner-loop multiply-adds before the parallel backend
/// forks; below this the fork/join overhead dominates and the serial
/// kernels run inline (results are identical either way — same kernels).
const PAR_MIN_FLOPS: usize = 1 << 20;

/// Backend over the compute substrate's hot kernels. See the module docs
/// for the determinism contract all implementations must uphold.
pub trait ComputeBackend: Send + Sync {
    /// Human-readable backend name (for logs/benches).
    fn name(&self) -> &'static str {
        "serial"
    }

    /// The kernel dispatch tier this backend computes with. Defaults to
    /// the process-wide table ([`kernels::active`]); pinned backends
    /// ([`PinnedSerialBackend`], [`ParallelBackend::with_dispatch`])
    /// override it so benches and parity tests can hold both tiers side
    /// by side without mutating global state.
    fn dispatch(&self) -> &'static kernels::KernelDispatch {
        kernels::active()
    }

    /// `buf·bufᵀ` for the FD shrink's `m × d` buffer (m = 2ℓ).
    fn gram(&self, buf: &Matrix) -> Matrix {
        self.dispatch().gram(buf)
    }

    /// `rot·buf` for the FD shrink's `ℓ × m` rotation against the buffer.
    fn apply_rot(&self, rot: &Matrix, buf: &Matrix) -> Matrix {
        assert_eq!(rot.cols(), buf.rows(), "apply_rot inner dim");
        let mut out = Matrix::zeros(rot.rows(), buf.cols());
        self.dispatch()
            .matmul_rows(rot, buf, 0, rot.rows(), out.as_mut_slice());
        out
    }

    /// `A·Bᵀ` into a caller-provided output (the Phase-II projection shape
    /// `scores = G·Sᵀ`; callers reuse `out` across batches via
    /// `selection::ProjectionScratch`).
    fn matmul_transb_into(&self, a: &Matrix, b: &Matrix, out: &mut Matrix) {
        assert_eq!(a.cols(), b.cols(), "matmul_transb inner dim");
        assert_eq!((out.rows(), out.cols()), (a.rows(), b.rows()));
        self.dispatch()
            .matmul_transb_rows(a, b, 0, a.rows(), out.as_mut_slice());
    }

    /// Allocating form of [`matmul_transb_into`].
    ///
    /// [`matmul_transb_into`]: ComputeBackend::matmul_transb_into
    fn matmul_transb(&self, a: &Matrix, b: &Matrix) -> Matrix {
        let mut out = Matrix::zeros(a.rows(), b.rows());
        self.matmul_transb_into(a, b, &mut out);
        out
    }

    /// `m·x` — the consensus matvec (`α = Ẑ·u`) and the selection rules'
    /// gain scans over all scored rows.
    fn matvec(&self, m: &Matrix, x: &[f32]) -> Vec<f32> {
        let mut out = vec![0.0f32; m.rows()];
        self.dispatch().matvec_rows(m, x, 0, m.rows(), &mut out);
        out
    }

    /// Per-row squared Euclidean norms in f64 (batched energy accumulation
    /// for the FD certificate and GRAFT's residual scan).
    fn row_energies(&self, m: &Matrix) -> Vec<f64> {
        let mut out = vec![0.0f64; m.rows()];
        self.dispatch().row_energies_rows(m, 0, m.rows(), &mut out);
        out
    }

    /// Normalize every row of `m` in place, returning the pre-normalization
    /// norms (the Phase-II `‖S gᵢ‖` output; zero rows stay zero).
    fn normalize_rows(&self, m: &mut Matrix) -> Vec<f32> {
        let mut norms = vec![0.0f32; m.rows()];
        self.dispatch()
            .normalize_rows_rows(m, 0, m.rows(), &mut norms);
        norms
    }

    /// `acc[j] += Σ_r m[r][j]` in f64, row order fixed — the streaming
    /// consensus accumulator. Serial on every backend by contract: the
    /// row-sequential f64 order is part of the exactness guarantee.
    fn accumulate_col_sums(&self, m: &Matrix, acc: &mut [f64]) {
        self.dispatch().accumulate_col_sums(m, acc);
    }
}

impl std::fmt::Debug for dyn ComputeBackend {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "ComputeBackend({})", self.name())
    }
}

/// Pure-serial reference backend: the trait's default kernels on the
/// process-wide dispatch tier, verbatim.
#[derive(Default, Debug, Clone, Copy)]
pub struct SerialBackend;

impl ComputeBackend for SerialBackend {}

/// Serial backend pinned to an explicit dispatch tier, regardless of the
/// process-wide selection — the handle `sage bench kernels` and the
/// scalar↔SIMD parity tests use to compare tiers within one process.
#[derive(Clone, Copy)]
pub struct PinnedSerialBackend(pub &'static kernels::KernelDispatch);

impl ComputeBackend for PinnedSerialBackend {
    fn name(&self) -> &'static str {
        self.0.isa()
    }

    fn dispatch(&self) -> &'static kernels::KernelDispatch {
        self.0
    }
}

/// The shared serial backend (cheap to clone; used as the default wherever
/// no explicit backend is threaded through).
pub fn serial() -> Arc<dyn ComputeBackend> {
    static SERIAL: OnceLock<Arc<SerialBackend>> = OnceLock::new();
    let backend: Arc<SerialBackend> = SERIAL.get_or_init(|| Arc::new(SerialBackend)).clone();
    backend
}

/// Build the backend for a `--workers`-style setting: serial for ≤ 1,
/// otherwise a [`ParallelBackend`] over a dedicated pool of `workers`
/// threads — wrapped in a [`TimedBackend`] so kernel-layer op timings land
/// in the process metrics registry. Selections are bit-identical across
/// all settings (the wrapper is pure delegation).
pub fn compute_backend(workers: usize) -> Arc<dyn ComputeBackend> {
    let inner: Arc<dyn ComputeBackend> = if workers <= 1 {
        serial()
    } else {
        Arc::new(ParallelBackend::with_threads(workers))
    };
    Arc::new(TimedBackend::new(inner))
}

/// Observability shim over any [`ComputeBackend`]: every op records its
/// wall-clock nanoseconds into a `kernel.<op>.ns` histogram in the global
/// metrics registry, and the coarse shrink/score ops additionally emit a
/// `kernel.<op>` trace span when a trace is active on the calling thread
/// (matvec and the other per-row helpers are called in tight selection
/// loops — spanning each call would flood the trace ring, so they get
/// histograms only).
///
/// The wrapper is **pure delegation**: same kernels, same call order, no
/// math — so the wrapped backend's bit-exactness contract is untouched.
/// `tests/kernel_determinism.rs` runs the full worker grid through it.
pub struct TimedBackend {
    inner: Arc<dyn ComputeBackend>,
    gram_ns: &'static metrics::Histogram,
    apply_rot_ns: &'static metrics::Histogram,
    matmul_transb_ns: &'static metrics::Histogram,
    matvec_ns: &'static metrics::Histogram,
    row_energies_ns: &'static metrics::Histogram,
    normalize_rows_ns: &'static metrics::Histogram,
    col_sums_ns: &'static metrics::Histogram,
}

impl TimedBackend {
    pub fn new(inner: Arc<dyn ComputeBackend>) -> Self {
        let reg = metrics::global();
        Self {
            inner,
            gram_ns: reg.histogram("kernel.gram.ns"),
            apply_rot_ns: reg.histogram("kernel.apply_rot.ns"),
            matmul_transb_ns: reg.histogram("kernel.matmul_transb.ns"),
            matvec_ns: reg.histogram("kernel.matvec.ns"),
            row_energies_ns: reg.histogram("kernel.row_energies.ns"),
            normalize_rows_ns: reg.histogram("kernel.normalize_rows.ns"),
            col_sums_ns: reg.histogram("kernel.accumulate_col_sums.ns"),
        }
    }
}

impl ComputeBackend for TimedBackend {
    fn name(&self) -> &'static str {
        // Transparent: callers (benches, logs) see the real backend.
        self.inner.name()
    }

    fn dispatch(&self) -> &'static kernels::KernelDispatch {
        self.inner.dispatch()
    }

    fn gram(&self, buf: &Matrix) -> Matrix {
        let _s = trace::span("kernel.gram");
        let _t = metrics::ScopedTimer::new(self.gram_ns);
        self.inner.gram(buf)
    }

    fn apply_rot(&self, rot: &Matrix, buf: &Matrix) -> Matrix {
        let _s = trace::span("kernel.apply_rot");
        let _t = metrics::ScopedTimer::new(self.apply_rot_ns);
        self.inner.apply_rot(rot, buf)
    }

    fn matmul_transb_into(&self, a: &Matrix, b: &Matrix, out: &mut Matrix) {
        let _s = trace::span("kernel.matmul_transb");
        let _t = metrics::ScopedTimer::new(self.matmul_transb_ns);
        self.inner.matmul_transb_into(a, b, out);
    }

    fn matmul_transb(&self, a: &Matrix, b: &Matrix) -> Matrix {
        let _s = trace::span("kernel.matmul_transb");
        let _t = metrics::ScopedTimer::new(self.matmul_transb_ns);
        self.inner.matmul_transb(a, b)
    }

    fn matvec(&self, m: &Matrix, x: &[f32]) -> Vec<f32> {
        let _t = metrics::ScopedTimer::new(self.matvec_ns);
        self.inner.matvec(m, x)
    }

    fn row_energies(&self, m: &Matrix) -> Vec<f64> {
        let _t = metrics::ScopedTimer::new(self.row_energies_ns);
        self.inner.row_energies(m)
    }

    fn normalize_rows(&self, m: &mut Matrix) -> Vec<f32> {
        let _t = metrics::ScopedTimer::new(self.normalize_rows_ns);
        self.inner.normalize_rows(m)
    }

    fn accumulate_col_sums(&self, m: &Matrix, acc: &mut [f64]) {
        let _t = metrics::ScopedTimer::new(self.col_sums_ns);
        self.inner.accumulate_col_sums(m, acc)
    }
}

/// Raw output cursor handed to parallel chunks. Each chunk derives a
/// disjoint slice from it (the chunks partition the output row grid), so
/// no two threads ever alias a byte.
#[derive(Clone, Copy)]
struct OutPtr<T>(*mut T);

// SAFETY: chunks write disjoint row ranges (enforced by the fixed row
// grid), and the owning buffer outlives the fork/join region.
unsafe impl<T: Send> Send for OutPtr<T> {}
unsafe impl<T: Send> Sync for OutPtr<T> {}

/// Threadpool-parallel kernel backend. Work splits along the fixed row grid
/// of [`kernels::row_chunk`] and runs the *same* serial microkernels per
/// chunk, so results are bit-identical to [`SerialBackend`] for every
/// worker count (verified per-op by `tests/kernel_determinism.rs`).
pub struct ParallelBackend {
    pool: Arc<ThreadPool>,
    /// Minimum multiply-adds before forking (0 = always fork; tests use
    /// this to force the parallel path on tiny shapes).
    min_flops: usize,
    /// Pinned dispatch tier, or `None` to resolve the process-wide table
    /// lazily (so constructing a backend never forces tier resolution
    /// before the CLI applies `--kernel-tier`).
    dispatch: Option<&'static kernels::KernelDispatch>,
}

impl ParallelBackend {
    /// Wrap a shared pool (the instance `main.rs` / server startup threads
    /// through every layer).
    pub fn new(pool: Arc<ThreadPool>) -> Self {
        Self {
            pool,
            min_flops: PAR_MIN_FLOPS,
            dispatch: None,
        }
    }

    /// Dedicated pool of `threads` workers.
    pub fn with_threads(threads: usize) -> Self {
        Self::new(Arc::new(ThreadPool::new(threads.max(1))))
    }

    /// Override the serial-inline threshold (0 forces every op parallel).
    pub fn with_min_flops(mut self, min_flops: usize) -> Self {
        self.min_flops = min_flops;
        self
    }

    /// Pin an explicit dispatch tier (benches / cross-tier parity tests;
    /// the default follows the process-wide [`kernels::active`] table).
    pub fn with_dispatch(mut self, dispatch: &'static kernels::KernelDispatch) -> Self {
        self.dispatch = Some(dispatch);
        self
    }

    /// The shared pool (e.g. to reuse it for other subsystems).
    pub fn pool(&self) -> &Arc<ThreadPool> {
        &self.pool
    }

    /// Fork `rows` of output across the fixed row grid; `f(r0, r1)` must
    /// write only rows `[r0, r1)` of its output.
    fn for_row_chunks(&self, rows: usize, f: &(dyn Fn(usize, usize) + Sync)) {
        let chunk = kernels::row_chunk(rows);
        let n_chunks = kernels::row_chunks(rows);
        self.pool.run_chunks(n_chunks, &|c| {
            let r0 = c * chunk;
            let r1 = (r0 + chunk).min(rows);
            f(r0, r1);
        });
    }
}

impl ComputeBackend for ParallelBackend {
    fn name(&self) -> &'static str {
        "parallel"
    }

    fn dispatch(&self) -> &'static kernels::KernelDispatch {
        self.dispatch.unwrap_or_else(kernels::active)
    }

    fn gram(&self, buf: &Matrix) -> Matrix {
        let d = self.dispatch();
        let m = buf.rows();
        // Lower-triangle work ≈ m²d/2.
        if m * m * buf.cols() / 2 < self.min_flops || m == 0 {
            return d.gram(buf);
        }
        let mut out = Matrix::zeros(m, m);
        let optr = OutPtr(out.as_mut_slice().as_mut_ptr());
        self.for_row_chunks(m, &|r0, r1| {
            // SAFETY: rows [r0, r1) of `out`; chunks are disjoint and the
            // buffer outlives the fork/join (see OutPtr).
            let slice =
                unsafe { std::slice::from_raw_parts_mut(optr.0.add(r0 * m), (r1 - r0) * m) };
            d.gram_rows(buf, r0, r1, slice);
        });
        kernels::mirror_lower(&mut out);
        out
    }

    fn apply_rot(&self, rot: &Matrix, buf: &Matrix) -> Matrix {
        assert_eq!(rot.cols(), buf.rows(), "apply_rot inner dim");
        let d = self.dispatch();
        let (m, n) = (rot.rows(), buf.cols());
        let mut out = Matrix::zeros(m, n);
        if m * rot.cols() * n < self.min_flops || m == 0 {
            d.matmul_rows(rot, buf, 0, m, out.as_mut_slice());
            return out;
        }
        let optr = OutPtr(out.as_mut_slice().as_mut_ptr());
        self.for_row_chunks(m, &|r0, r1| {
            // SAFETY: disjoint row ranges of `out` (see OutPtr).
            let slice =
                unsafe { std::slice::from_raw_parts_mut(optr.0.add(r0 * n), (r1 - r0) * n) };
            d.matmul_rows(rot, buf, r0, r1, slice);
        });
        out
    }

    fn matmul_transb_into(&self, a: &Matrix, b: &Matrix, out: &mut Matrix) {
        assert_eq!(a.cols(), b.cols(), "matmul_transb inner dim");
        assert_eq!((out.rows(), out.cols()), (a.rows(), b.rows()));
        let d = self.dispatch();
        let (m, n) = (a.rows(), b.rows());
        if m * n * a.cols() < self.min_flops || m == 0 {
            d.matmul_transb_rows(a, b, 0, m, out.as_mut_slice());
            return;
        }
        let optr = OutPtr(out.as_mut_slice().as_mut_ptr());
        self.for_row_chunks(m, &|r0, r1| {
            // SAFETY: disjoint row ranges of `out` (see OutPtr).
            let slice =
                unsafe { std::slice::from_raw_parts_mut(optr.0.add(r0 * n), (r1 - r0) * n) };
            d.matmul_transb_rows(a, b, r0, r1, slice);
        });
    }

    fn matvec(&self, m: &Matrix, x: &[f32]) -> Vec<f32> {
        let d = self.dispatch();
        let rows = m.rows();
        let mut out = vec![0.0f32; rows];
        if rows * m.cols() < self.min_flops || rows == 0 {
            d.matvec_rows(m, x, 0, rows, &mut out);
            return out;
        }
        let optr = OutPtr(out.as_mut_ptr());
        self.for_row_chunks(rows, &|r0, r1| {
            // SAFETY: disjoint element ranges of `out` (see OutPtr).
            let slice = unsafe { std::slice::from_raw_parts_mut(optr.0.add(r0), r1 - r0) };
            d.matvec_rows(m, x, r0, r1, slice);
        });
        out
    }

    fn row_energies(&self, m: &Matrix) -> Vec<f64> {
        let d = self.dispatch();
        let rows = m.rows();
        let mut out = vec![0.0f64; rows];
        if rows * m.cols() < self.min_flops || rows == 0 {
            d.row_energies_rows(m, 0, rows, &mut out);
            return out;
        }
        let optr = OutPtr(out.as_mut_ptr());
        self.for_row_chunks(rows, &|r0, r1| {
            // SAFETY: disjoint element ranges of `out` (see OutPtr).
            let slice = unsafe { std::slice::from_raw_parts_mut(optr.0.add(r0), r1 - r0) };
            d.row_energies_rows(m, r0, r1, slice);
        });
        out
    }

    fn normalize_rows(&self, m: &mut Matrix) -> Vec<f32> {
        let d = self.dispatch();
        let rows = m.rows();
        let cols = m.cols();
        let mut norms = vec![0.0f32; rows];
        if rows * cols < self.min_flops || rows == 0 {
            d.normalize_rows_rows(m, 0, rows, &mut norms);
            return norms;
        }
        let mptr = OutPtr(m.as_mut_slice().as_mut_ptr());
        let nptr = OutPtr(norms.as_mut_ptr());
        self.for_row_chunks(rows, &|r0, r1| {
            // SAFETY: disjoint row ranges of `m` and element ranges of
            // `norms` (see OutPtr). Each chunk row is normalized with the
            // same pinned dispatch the serial path uses.
            let rows_slice =
                unsafe { std::slice::from_raw_parts_mut(mptr.0.add(r0 * cols), (r1 - r0) * cols) };
            let nslice = unsafe { std::slice::from_raw_parts_mut(nptr.0.add(r0), r1 - r0) };
            for (k, chunk_row) in rows_slice.chunks_mut(cols).enumerate() {
                nslice[k] = d.normalize_in_place(chunk_row) as f32;
            }
        });
        norms
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::check::forall;

    fn random_matrix(rng: &mut crate::util::rng::Pcg64, r: usize, c: usize) -> Matrix {
        Matrix::from_fn(r, c, |_, _| rng.normal_f32())
    }

    fn assert_bits_eq(a: &[f32], b: &[f32], what: &str) {
        assert_eq!(a.len(), b.len(), "{what} length");
        for (i, (x, y)) in a.iter().zip(b.iter()).enumerate() {
            assert_eq!(x.to_bits(), y.to_bits(), "{what}[{i}]: {x} vs {y}");
        }
    }

    #[test]
    fn parallel_ops_bit_identical_to_serial() {
        let serial = SerialBackend;
        for workers in [2usize, 3] {
            let par = ParallelBackend::with_threads(workers).with_min_flops(0);
            forall("backend_parity", 6, |rng| {
                let m = 1 + rng.below(33) as usize;
                let d = 1 + rng.below(60) as usize;
                let l = 1 + rng.below(17) as usize;
                let a = random_matrix(rng, m, d);
                let b = random_matrix(rng, l, d);
                assert_bits_eq(
                    par.matmul_transb(&a, &b).as_slice(),
                    serial.matmul_transb(&a, &b).as_slice(),
                    "matmul_transb",
                );
                assert_bits_eq(par.gram(&a).as_slice(), serial.gram(&a).as_slice(), "gram");
                let rot = random_matrix(rng, l, m);
                assert_bits_eq(
                    par.apply_rot(&rot, &a).as_slice(),
                    serial.apply_rot(&rot, &a).as_slice(),
                    "apply_rot",
                );
                let x: Vec<f32> = (0..d).map(|_| rng.normal_f32()).collect();
                assert_bits_eq(&par.matvec(&a, &x), &serial.matvec(&a, &x), "matvec");
                let ep: Vec<f64> = par.row_energies(&a);
                let es: Vec<f64> = serial.row_energies(&a);
                for (x, y) in ep.iter().zip(es.iter()) {
                    assert_eq!(x.to_bits(), y.to_bits(), "row_energies");
                }
                let mut ma = a.clone();
                let mut mb = a.clone();
                let np = par.normalize_rows(&mut ma);
                let ns = serial.normalize_rows(&mut mb);
                assert_bits_eq(&np, &ns, "norms");
                assert_bits_eq(ma.as_slice(), mb.as_slice(), "normalized rows");
            });
        }
    }

    #[test]
    fn pinned_simd_backend_bit_identical_to_pinned_scalar() {
        let Some(simd) = kernels::simd_dispatch() else {
            eprintln!("skip: no SIMD tier on this host");
            return;
        };
        let sc = PinnedSerialBackend(kernels::scalar_dispatch());
        let sv = PinnedSerialBackend(simd);
        let pv = ParallelBackend::with_threads(3)
            .with_min_flops(0)
            .with_dispatch(simd);
        forall("tier_backend_parity", 6, |rng| {
            let m = 1 + rng.below(40) as usize;
            let d = 1 + rng.below(70) as usize;
            let a = random_matrix(rng, m, d);
            let b = random_matrix(rng, 1 + rng.below(9) as usize, d);
            let want = sc.matmul_transb(&a, &b);
            assert_bits_eq(sv.matmul_transb(&a, &b).as_slice(), want.as_slice(), "serial simd");
            assert_bits_eq(pv.matmul_transb(&a, &b).as_slice(), want.as_slice(), "parallel simd");
            assert_bits_eq(sv.gram(&a).as_slice(), sc.gram(&a).as_slice(), "gram");
            let x: Vec<f32> = (0..d).map(|_| rng.normal_f32()).collect();
            assert_bits_eq(&sv.matvec(&a, &x), &sc.matvec(&a, &x), "matvec");
            for (x, y) in sv.row_energies(&a).iter().zip(sc.row_energies(&a).iter()) {
                assert_eq!(x.to_bits(), y.to_bits(), "row_energies");
            }
        });
    }

    #[test]
    fn compute_backend_picks_serial_for_one_worker() {
        assert_eq!(compute_backend(1).name(), "serial");
        assert_eq!(compute_backend(0).name(), "serial");
        assert_eq!(compute_backend(3).name(), "parallel");
    }

    #[test]
    fn gating_keeps_small_ops_inline() {
        // Below the flop threshold the parallel backend runs serial kernels
        // inline — results must (trivially) still match.
        let par = ParallelBackend::with_threads(2);
        let mut rng = crate::util::rng::Pcg64::seeded(11);
        let a = random_matrix(&mut rng, 4, 6);
        let b = random_matrix(&mut rng, 3, 6);
        assert_bits_eq(
            par.matmul_transb(&a, &b).as_slice(),
            SerialBackend.matmul_transb(&a, &b).as_slice(),
            "gated matmul_transb",
        );
    }
}
