//! BLAS-1 style slice helpers — thin forwarders into the active
//! [`kernels::KernelDispatch`] tier, kept as a module so existing call
//! sites (`tensor::dot` et al.) read naturally. The actual loop bodies
//! live in `kernels.rs` (scalar reference + SIMD tiers, bit-identical);
//! nothing in the crate carries a private scalar duplicate anymore, so
//! every dot/axpy user inherits the dispatch tier.

use super::kernels;

/// f32 dot product (active-tier microkernel; fixed multi-accumulator
/// layout, see `kernels.rs` module docs).
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    kernels::active().dot(a, b)
}

/// Dot product with f64 accumulation — for norms/consensus where drift
/// across D ~ 1e5 terms would perturb rankings.
#[inline]
pub fn dot_f64(a: &[f32], b: &[f32]) -> f64 {
    kernels::active().dot_f64(a, b)
}

/// y += alpha * x.
#[inline]
pub fn axpy(alpha: f32, x: &[f32], y: &mut [f32]) {
    kernels::active().axpy(alpha, x, y)
}

/// Euclidean norm (f64 accumulation).
#[inline]
pub fn norm2(x: &[f32]) -> f64 {
    kernels::active().norm2(x)
}

/// x /= ||x||; returns the norm. Zero vectors stay zero (the paper's
/// z_i = 0 convention in Algorithm 1 line 13).
pub fn normalize_in_place(x: &mut [f32]) -> f64 {
    kernels::active().normalize_in_place(x)
}

/// x *= s.
#[inline]
pub fn scale_in_place(x: &mut [f32], s: f32) {
    kernels::active().scale(x, s)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::check::forall;

    #[test]
    fn dot_matches_f64_reference() {
        forall("dot", 30, |rng| {
            let n = rng.below(200) as usize;
            let a: Vec<f32> = (0..n).map(|_| rng.normal_f32()).collect();
            let b: Vec<f32> = (0..n).map(|_| rng.normal_f32()).collect();
            let fast = dot(&a, &b) as f64;
            let slow = dot_f64(&a, &b);
            assert!((fast - slow).abs() < 1e-3 * (1.0 + slow.abs()), "{fast} vs {slow}");
        });
    }

    #[test]
    fn axpy_basic() {
        let x = [1.0, 2.0, 3.0];
        let mut y = [10.0, 20.0, 30.0];
        axpy(2.0, &x, &mut y);
        assert_eq!(y, [12.0, 24.0, 36.0]);
    }

    #[test]
    fn normalize_unit_norm() {
        forall("normalize", 20, |rng| {
            let n = 1 + rng.below(50) as usize;
            let mut x: Vec<f32> = (0..n).map(|_| rng.normal_f32()).collect();
            let pre = norm2(&x);
            let returned = normalize_in_place(&mut x);
            assert!((returned - pre).abs() < 1e-6 * (1.0 + pre));
            if pre > 1e-6 {
                assert!((norm2(&x) - 1.0).abs() < 1e-5);
            }
        });
    }

    #[test]
    fn normalize_zero_stays_zero() {
        let mut x = [0.0f32; 5];
        let n = normalize_in_place(&mut x);
        assert_eq!(n, 0.0);
        assert!(x.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn norm2_pythagoras() {
        assert!((norm2(&[3.0, 4.0]) - 5.0).abs() < 1e-9);
    }

    #[test]
    fn scale_in_place_matches_mul() {
        let mut x = [1.0f32, -2.0, 3.5];
        scale_in_place(&mut x, 2.0);
        assert_eq!(x, [2.0, -4.0, 7.0]);
    }
}
