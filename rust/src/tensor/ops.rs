//! BLAS-1 style slice kernels. `dot`/`axpy` are the two hot primitives of
//! the coordinator-side math; both are written as 4-way unrolled loops the
//! compiler auto-vectorizes (checked via the micro bench in benches/micro).

/// f32 dot product with f32 accumulation in 4 independent lanes (enables
/// SIMD + keeps error acceptable for scoring math; decision-critical norms
/// use `dot_f64`).
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let n = a.len();
    let chunks = n / 4;
    let (mut s0, mut s1, mut s2, mut s3) = (0.0f32, 0.0f32, 0.0f32, 0.0f32);
    for i in 0..chunks {
        let j = i * 4;
        s0 += a[j] * b[j];
        s1 += a[j + 1] * b[j + 1];
        s2 += a[j + 2] * b[j + 2];
        s3 += a[j + 3] * b[j + 3];
    }
    let mut s = (s0 + s1) + (s2 + s3);
    for j in chunks * 4..n {
        s += a[j] * b[j];
    }
    s
}

/// Dot product with f64 accumulation — for norms/consensus where drift
/// across D ~ 1e5 terms would perturb rankings.
#[inline]
pub fn dot_f64(a: &[f32], b: &[f32]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    a.iter()
        .zip(b.iter())
        .map(|(&x, &y)| x as f64 * y as f64)
        .sum()
}

/// y += alpha * x.
#[inline]
pub fn axpy(alpha: f32, x: &[f32], y: &mut [f32]) {
    debug_assert_eq!(x.len(), y.len());
    for (yi, &xi) in y.iter_mut().zip(x.iter()) {
        *yi += alpha * xi;
    }
}

/// Euclidean norm (f64 accumulation).
#[inline]
pub fn norm2(x: &[f32]) -> f64 {
    x.iter().map(|&v| v as f64 * v as f64).sum::<f64>().sqrt()
}

/// x /= ||x||; returns the norm. Zero vectors stay zero (the paper's
/// z_i = 0 convention in Algorithm 1 line 13).
pub fn normalize_in_place(x: &mut [f32]) -> f64 {
    let n = norm2(x);
    if n > 0.0 {
        let inv = (1.0 / n) as f32;
        for v in x.iter_mut() {
            *v *= inv;
        }
    }
    n
}

/// x *= s.
#[inline]
pub fn scale_in_place(x: &mut [f32], s: f32) {
    for v in x.iter_mut() {
        *v *= s;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::check::forall;

    #[test]
    fn dot_matches_f64_reference() {
        forall("dot", 30, |rng| {
            let n = rng.below(200) as usize;
            let a: Vec<f32> = (0..n).map(|_| rng.normal_f32()).collect();
            let b: Vec<f32> = (0..n).map(|_| rng.normal_f32()).collect();
            let fast = dot(&a, &b) as f64;
            let slow = dot_f64(&a, &b);
            assert!((fast - slow).abs() < 1e-3 * (1.0 + slow.abs()), "{fast} vs {slow}");
        });
    }

    #[test]
    fn axpy_basic() {
        let x = [1.0, 2.0, 3.0];
        let mut y = [10.0, 20.0, 30.0];
        axpy(2.0, &x, &mut y);
        assert_eq!(y, [12.0, 24.0, 36.0]);
    }

    #[test]
    fn normalize_unit_norm() {
        forall("normalize", 20, |rng| {
            let n = 1 + rng.below(50) as usize;
            let mut x: Vec<f32> = (0..n).map(|_| rng.normal_f32()).collect();
            let pre = norm2(&x);
            let returned = normalize_in_place(&mut x);
            assert!((returned - pre).abs() < 1e-6 * (1.0 + pre));
            if pre > 1e-6 {
                assert!((norm2(&x) - 1.0).abs() < 1e-5);
            }
        });
    }

    #[test]
    fn normalize_zero_stays_zero() {
        let mut x = [0.0f32; 5];
        let n = normalize_in_place(&mut x);
        assert_eq!(n, 0.0);
        assert!(x.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn norm2_pythagoras() {
        assert!((norm2(&[3.0, 4.0]) - 5.0).abs() < 1e-9);
    }
}
