//! Row-major dense f32 matrix.

use super::ops;

/// Dense row-major matrix of f32.
#[derive(Clone, Debug, PartialEq)]
pub struct Matrix {
    rows: usize,
    cols: usize,
    data: Vec<f32>,
}

impl Matrix {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Self {
            rows,
            cols,
            data: vec![0.0; rows * cols],
        }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Self {
        assert_eq!(data.len(), rows * cols, "shape mismatch");
        Self { rows, cols, data }
    }

    /// Build from a closure over (row, col).
    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> f32) -> Self {
        let mut data = Vec::with_capacity(rows * cols);
        for r in 0..rows {
            for c in 0..cols {
                data.push(f(r, c));
            }
        }
        Self { rows, cols, data }
    }

    pub fn identity(n: usize) -> Self {
        Self::from_fn(n, n, |r, c| if r == c { 1.0 } else { 0.0 })
    }

    #[inline]
    pub fn rows(&self) -> usize {
        self.rows
    }

    #[inline]
    pub fn cols(&self) -> usize {
        self.cols
    }

    #[inline]
    pub fn get(&self, r: usize, c: usize) -> f32 {
        debug_assert!(r < self.rows && c < self.cols);
        self.data[r * self.cols + c]
    }

    #[inline]
    pub fn set(&mut self, r: usize, c: usize, v: f32) {
        debug_assert!(r < self.rows && c < self.cols);
        self.data[r * self.cols + c] = v;
    }

    #[inline]
    pub fn row(&self, r: usize) -> &[f32] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, r: usize) -> &mut [f32] {
        &mut self.data[r * self.cols..(r + 1) * self.cols]
    }

    pub fn as_slice(&self) -> &[f32] {
        &self.data
    }

    pub fn as_mut_slice(&mut self) -> &mut [f32] {
        &mut self.data
    }

    pub fn into_vec(self) -> Vec<f32> {
        self.data
    }

    pub fn fill(&mut self, v: f32) {
        self.data.fill(v);
    }

    /// Cache-blocked tile transpose (32×32 tiles — the naive row-major
    /// version strides the destination by `rows` floats per element and
    /// thrashes for the wide Phase-II shapes).
    pub fn transpose(&self) -> Matrix {
        let mut out = Matrix::zeros(self.cols, self.rows);
        super::kernels::transpose_into(self, &mut out);
        out
    }

    /// C = A @ B (blocked ikj loop — cache-friendly row-major kernel).
    pub fn matmul(&self, b: &Matrix) -> Matrix {
        assert_eq!(self.cols, b.rows, "matmul inner dim");
        let mut out = Matrix::zeros(self.rows, b.cols);
        let n = b.cols;
        for i in 0..self.rows {
            let arow = self.row(i);
            let orow = &mut out.data[i * n..(i + 1) * n];
            for (k, &aik) in arow.iter().enumerate() {
                if aik == 0.0 {
                    continue;
                }
                let brow = &b.data[k * n..(k + 1) * n];
                ops::axpy(aik, brow, orow);
            }
        }
        out
    }

    /// C = A @ Bᵀ — the projection shape (rows of B are the sketch rows).
    /// Runs on the tiled 8-wide microkernel (`tensor::kernels`), the same
    /// code path the `ComputeBackend` layer parallelizes.
    pub fn matmul_transb(&self, b: &Matrix) -> Matrix {
        assert_eq!(self.cols, b.cols, "matmul_transb inner dim");
        let mut out = Matrix::zeros(self.rows, b.rows);
        super::kernels::matmul_transb_rows(self, b, 0, self.rows, &mut out.data);
        out
    }

    /// G = A @ Aᵀ (symmetric Gram; computes the lower triangle once and
    /// mirrors it — `tensor::kernels` tiled microkernel).
    pub fn gram(&self) -> Matrix {
        super::kernels::gram(self)
    }

    /// y = A @ x for a vector x (same active-tier `dot` microkernel as the
    /// `ComputeBackend` matvec, so the two stay bit-identical).
    pub fn matvec(&self, x: &[f32]) -> Vec<f32> {
        assert_eq!(self.cols, x.len(), "matvec dim");
        let mut out = vec![0.0f32; self.rows];
        super::kernels::matvec_rows(self, x, 0, self.rows, &mut out);
        out
    }

    /// y = Aᵀ @ x.
    pub fn matvec_t(&self, x: &[f32]) -> Vec<f32> {
        assert_eq!(self.rows, x.len(), "matvec_t dim");
        let mut out = vec![0.0; self.cols];
        for (i, &xi) in x.iter().enumerate() {
            if xi != 0.0 {
                ops::axpy(xi, self.row(i), &mut out);
            }
        }
        out
    }

    pub fn frobenius_norm(&self) -> f64 {
        self.data.iter().map(|&v| (v as f64) * (v as f64)).sum::<f64>().sqrt()
    }

    pub fn add_assign(&mut self, other: &Matrix) {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        for (a, b) in self.data.iter_mut().zip(other.data.iter()) {
            *a += b;
        }
    }

    pub fn sub(&self, other: &Matrix) -> Matrix {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        let data = self
            .data
            .iter()
            .zip(other.data.iter())
            .map(|(a, b)| a - b)
            .collect();
        Matrix::from_vec(self.rows, self.cols, data)
    }

    pub fn scale(&mut self, s: f32) {
        for v in self.data.iter_mut() {
            *v *= s;
        }
    }

    /// Extract rows [start, end).
    pub fn slice_rows(&self, start: usize, end: usize) -> Matrix {
        assert!(start <= end && end <= self.rows);
        Matrix::from_vec(
            end - start,
            self.cols,
            self.data[start * self.cols..end * self.cols].to_vec(),
        )
    }

    /// Stack rows of `mats` vertically.
    pub fn vstack(mats: &[&Matrix]) -> Matrix {
        assert!(!mats.is_empty());
        let cols = mats[0].cols;
        let rows: usize = mats.iter().map(|m| m.rows).sum();
        let mut data = Vec::with_capacity(rows * cols);
        for m in mats {
            assert_eq!(m.cols, cols, "vstack col mismatch");
            data.extend_from_slice(&m.data);
        }
        Matrix::from_vec(rows, cols, data)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::check::{assert_allclose, forall};

    fn random_matrix(rng: &mut crate::util::rng::Pcg64, r: usize, c: usize) -> Matrix {
        Matrix::from_fn(r, c, |_, _| rng.normal_f32())
    }

    #[test]
    fn identity_matmul_is_noop() {
        forall("identity_matmul", 20, |rng| {
            let r = 1 + rng.below(8) as usize;
            let c = 1 + rng.below(8) as usize;
            let a = random_matrix(rng, r, c);
            let i = Matrix::identity(r);
            let out = i.matmul(&a);
            assert_allclose(out.as_slice(), a.as_slice(), 1e-6, 1e-6, "I@A");
        });
    }

    #[test]
    fn matmul_matches_naive() {
        forall("matmul_naive", 20, |rng| {
            let (m, k, n) = (
                1 + rng.below(7) as usize,
                1 + rng.below(7) as usize,
                1 + rng.below(7) as usize,
            );
            let a = random_matrix(rng, m, k);
            let b = random_matrix(rng, k, n);
            let c = a.matmul(&b);
            for i in 0..m {
                for j in 0..n {
                    let mut acc = 0.0f64;
                    for t in 0..k {
                        acc += a.get(i, t) as f64 * b.get(t, j) as f64;
                    }
                    assert!((c.get(i, j) as f64 - acc).abs() < 1e-4, "({i},{j})");
                }
            }
        });
    }

    #[test]
    fn matmul_transb_matches_explicit_transpose() {
        forall("matmul_transb", 20, |rng| {
            let (m, k, n) = (
                1 + rng.below(6) as usize,
                1 + rng.below(6) as usize,
                1 + rng.below(6) as usize,
            );
            let a = random_matrix(rng, m, k);
            let b = random_matrix(rng, n, k);
            let fast = a.matmul_transb(&b);
            let slow = a.matmul(&b.transpose());
            assert_allclose(fast.as_slice(), slow.as_slice(), 1e-5, 1e-5, "ABt");
        });
    }

    #[test]
    fn gram_matches_matmul_transb_self() {
        forall("gram", 20, |rng| {
            let m = 1 + rng.below(8) as usize;
            let d = 1 + rng.below(20) as usize;
            let a = random_matrix(rng, m, d);
            let g = a.gram();
            let g2 = a.matmul_transb(&a);
            assert_allclose(g.as_slice(), g2.as_slice(), 1e-5, 1e-5, "gram");
        });
    }

    #[test]
    fn transpose_involution() {
        forall("transpose", 10, |rng| {
            let r = 1 + rng.below(9) as usize;
            let c = 1 + rng.below(9) as usize;
            let a = random_matrix(rng, r, c);
            assert_eq!(a.transpose().transpose(), a);
        });
    }

    #[test]
    fn matvec_consistent_with_matmul() {
        forall("matvec", 10, |rng| {
            let (m, k) = (1 + rng.below(6) as usize, 1 + rng.below(6) as usize);
            let a = random_matrix(rng, m, k);
            let x: Vec<f32> = (0..k).map(|_| rng.normal_f32()).collect();
            let xm = Matrix::from_vec(k, 1, x.clone());
            let via_mm = a.matmul(&xm);
            let via_mv = a.matvec(&x);
            assert_allclose(&via_mv, via_mm.as_slice(), 1e-5, 1e-5, "matvec");
        });
    }

    #[test]
    fn matvec_t_consistent() {
        forall("matvec_t", 10, |rng| {
            let (m, k) = (1 + rng.below(6) as usize, 1 + rng.below(6) as usize);
            let a = random_matrix(rng, m, k);
            let x: Vec<f32> = (0..m).map(|_| rng.normal_f32()).collect();
            let got = a.matvec_t(&x);
            let want = a.transpose().matvec(&x);
            assert_allclose(&got, &want, 1e-5, 1e-5, "matvec_t");
        });
    }

    #[test]
    fn slice_and_vstack_round_trip() {
        let a = Matrix::from_fn(6, 3, |r, c| (r * 3 + c) as f32);
        let top = a.slice_rows(0, 2);
        let bottom = a.slice_rows(2, 6);
        let back = Matrix::vstack(&[&top, &bottom]);
        assert_eq!(back, a);
    }

    #[test]
    fn frobenius_of_identity() {
        let i = Matrix::identity(9);
        assert!((i.frobenius_norm() - 3.0).abs() < 1e-12);
    }

    #[test]
    #[should_panic]
    fn matmul_dim_mismatch_panics() {
        let a = Matrix::zeros(2, 3);
        let b = Matrix::zeros(4, 2);
        let _ = a.matmul(&b);
    }
}
