//! Dense f32 matrix/vector substrate (from scratch — no ndarray offline).
//!
//! Row-major [`Matrix`] with the operations the coordinator-side math needs:
//! blocked matmuls (incl. the `A Bᵀ` and `Aᵀ A` forms the FD/selection code
//! uses), row views, norms, and in-place BLAS-1 helpers. Accumulations that
//! feed decisions (norms, dot products) run in f64 to keep the Rust
//! reference numerically comparable to the XLA artifacts.
//!
//! The hot contractions live in [`kernels`] (tiled microkernels organised
//! as runtime-selected **dispatch tiers** — a scalar reference plus an
//! 8-lane SIMD tier, bit-identical to each other) behind the
//! [`ComputeBackend`] layer: [`SerialBackend`] is the reference,
//! [`ParallelBackend`] splits the same kernels over a shared threadpool
//! along fixed, worker-count-independent chunk boundaries — bit-identical
//! results for every worker count AND every tier (the service's exactness
//! guarantee depends on this; see docs/ARCHITECTURE.md §5.1).

mod backend;
pub mod kernels;
mod matrix;
mod ops;

pub use backend::{
    compute_backend, serial, ComputeBackend, ParallelBackend, PinnedSerialBackend, SerialBackend,
    TimedBackend,
};
pub use kernels::{KernelTier, TierChoice};
pub use matrix::Matrix;
pub use ops::{axpy, dot, dot_f64, norm2, normalize_in_place, scale_in_place};
