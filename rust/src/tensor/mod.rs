//! Dense f32 matrix/vector substrate (from scratch — no ndarray offline).
//!
//! Row-major [`Matrix`] with the operations the coordinator-side math needs:
//! blocked matmuls (incl. the `A Bᵀ` and `Aᵀ A` forms the FD/selection code
//! uses), row views, norms, and in-place BLAS-1 helpers. Accumulations that
//! feed decisions (norms, dot products) run in f64 to keep the Rust
//! reference numerically comparable to the XLA artifacts.

mod matrix;
mod ops;

pub use matrix::Matrix;
pub use ops::{dot, dot_f64, norm2, normalize_in_place, axpy, scale_in_place};
