//! Compute microkernels — the single source of truth for every hot
//! contraction in the system (FD shrink, Phase-II projection, consensus
//! matvec, batched row norms/energies) — now organised as **dispatch
//! tiers** behind [`KernelDispatch`]:
//!
//! * **scalar** — plain Rust with a fixed multi-accumulator layout the
//!   compiler auto-vectorizes on whatever the baseline ISA offers.
//! * **simd** — the same kernels written as explicit 8-lane f32 / 4-lane
//!   f64 vectors: AVX2 intrinsics on `x86_64` (runtime-detected), or
//!   `std::simd` when built with the nightly-only `portable-simd` feature.
//!
//! One table is selected at startup ([`active`]; forced with
//! `--kernel-tier` / `SAGE_KERNEL_TIER`) and both [`SerialBackend`] and
//! [`ParallelBackend`] route through it, so the whole method matrix — SAGE
//! shrink/projection and every baseline scan — inherits the tier.
//!
//! # The cross-tier bit-identity contract
//!
//! Results are bit-identical **across tiers**, not just across worker
//! counts. Both tiers implement the *same* fixed accumulation semantics:
//!
//! * f32 dots run [`DOT_STREAMS`] independent streams of [`F32_LANES`]
//!   accumulator lanes (4 × 8 = 32 accumulators — enough independent
//!   add-chains to hide FP-add latency on every ISA), reduced by one fixed
//!   tree; f64 dots use 4 × 4 lanes.
//! * Every multiply-add is an explicit **mul then add** (two IEEE
//!   roundings). The SIMD tier never uses hardware FMA, and Rust never
//!   enables floating-point contraction, so `a*b` then `+` is the same two
//!   rounded ops in both tiers.
//! * `axpy` / `scale` / f64 column accumulation are elementwise — the lane
//!   split cannot reassociate anything.
//! * Tails (`len % block`) fall back to one shared sequential loop.
//!
//! Hence `scalar.op(x) ≡ simd.op(x)` bitwise for every op, which keeps the
//! service's "served selection ≡ offline `run_selection`" guarantee
//! ISA-independent: a server on an AVX2 host serves the exact TopK of a
//! scalar offline run. Enforced per-op by `tests/kernel_determinism.rs`.
//!
//! # Row-grid form
//!
//! Each matrix kernel computes a contiguous row range `[r0, r1)` of its
//! output. The serial [`ComputeBackend`] calls it once with the full
//! range; the parallel backend calls it once per chunk of a **fixed,
//! worker-count-independent row grid** (see [`row_chunk`]). Because every
//! output element is produced by exactly one kernel call with a fixed
//! intra-kernel accumulation order, the split never changes results.
//!
//! [`ComputeBackend`]: super::ComputeBackend
//! [`SerialBackend`]: super::SerialBackend
//! [`ParallelBackend`]: super::ParallelBackend

use super::Matrix;
use crate::util::metrics;
use std::sync::OnceLock;

/// f32 accumulator lanes per stream (one AVX2 `ymm` / `std::simd` `f32x8`).
pub const F32_LANES: usize = 8;
/// f64 accumulator lanes per stream (one AVX2 `ymm` of doubles).
pub const F64_LANES: usize = 4;
/// Independent accumulator streams per dot — four parallel add-chains.
pub const DOT_STREAMS: usize = 4;
/// f32 dot block: elements consumed per unrolled iteration.
const F32_BLOCK: usize = F32_LANES * DOT_STREAMS; // 32
/// f64 dot block.
const F64_BLOCK: usize = F64_LANES * DOT_STREAMS; // 16

// ---------------------------------------------------------------------------
// Tier selection
// ---------------------------------------------------------------------------

/// A dispatch tier: which implementation of the primitive kernels the
/// process runs. Within a build, tiers are bit-identical (module docs).
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum KernelTier {
    /// Auto-vectorized plain Rust (the reference).
    Scalar,
    /// Explicit vector kernels (AVX2 intrinsics or portable `std::simd`).
    Simd,
}

impl KernelTier {
    pub fn name(self) -> &'static str {
        match self {
            KernelTier::Scalar => "scalar",
            KernelTier::Simd => "simd",
        }
    }

    /// Stable numeric encoding for metrics/stats (0 = scalar, 1 = simd).
    pub fn index(self) -> u64 {
        match self {
            KernelTier::Scalar => 0,
            KernelTier::Simd => 1,
        }
    }
}

/// What the user asked for (`--kernel-tier` / `SAGE_KERNEL_TIER`).
#[derive(Clone, Copy, PartialEq, Eq, Debug, Default)]
pub enum TierChoice {
    /// Pick the fastest tier the host supports (the default).
    #[default]
    Auto,
    /// Force the scalar reference tier.
    Scalar,
    /// Force the SIMD tier (error if the host has no SIMD path).
    Simd,
}

impl TierChoice {
    pub fn parse(s: &str) -> Result<Self, String> {
        match s {
            "auto" => Ok(TierChoice::Auto),
            "scalar" => Ok(TierChoice::Scalar),
            "simd" => Ok(TierChoice::Simd),
            other => Err(format!("unknown kernel tier '{other}' (auto|scalar|simd)")),
        }
    }
}

/// Table of primitive kernels for one tier. Constructed only for
/// implementations valid on the running CPU; all higher-level row-grid
/// kernels are methods so every caller inherits the tier.
pub struct KernelDispatch {
    tier: KernelTier,
    /// Human-readable implementation name ("scalar", "avx2", "portable").
    isa: &'static str,
    dot_fn: fn(&[f32], &[f32]) -> f32,
    dot_f64_fn: fn(&[f32], &[f32]) -> f64,
    axpy_fn: fn(f32, &[f32], &mut [f32]),
    scale_fn: fn(&mut [f32], f32),
    col_accum_fn: fn(&[f32], &mut [f64]),
}

static SCALAR: KernelDispatch = KernelDispatch {
    tier: KernelTier::Scalar,
    isa: "scalar",
    dot_fn: scalar::dot,
    dot_f64_fn: scalar::dot_f64,
    axpy_fn: scalar::axpy,
    scale_fn: scalar::scale,
    col_accum_fn: scalar::col_accum,
};

#[cfg(target_arch = "x86_64")]
static AVX2: KernelDispatch = KernelDispatch {
    tier: KernelTier::Simd,
    isa: "avx2",
    dot_fn: avx2::dot,
    dot_f64_fn: avx2::dot_f64,
    axpy_fn: avx2::axpy,
    scale_fn: avx2::scale,
    col_accum_fn: avx2::col_accum,
};

#[cfg(feature = "portable-simd")]
static PORTABLE: KernelDispatch = KernelDispatch {
    tier: KernelTier::Simd,
    isa: "portable",
    dot_fn: portable::dot,
    dot_f64_fn: portable::dot_f64,
    axpy_fn: portable::axpy,
    scale_fn: portable::scale,
    col_accum_fn: portable::col_accum,
};

/// The scalar reference tier (always available).
pub fn scalar_dispatch() -> &'static KernelDispatch {
    &SCALAR
}

/// True when the running CPU reports AVX2.
pub fn avx2_detected() -> bool {
    native_simd().is_some()
}

#[cfg(target_arch = "x86_64")]
fn native_simd() -> Option<&'static KernelDispatch> {
    if std::arch::is_x86_feature_detected!("avx2") {
        Some(&AVX2)
    } else {
        None
    }
}

#[cfg(not(target_arch = "x86_64"))]
fn native_simd() -> Option<&'static KernelDispatch> {
    None
}

#[cfg(feature = "portable-simd")]
fn portable_simd() -> Option<&'static KernelDispatch> {
    Some(&PORTABLE)
}

#[cfg(not(feature = "portable-simd"))]
fn portable_simd() -> Option<&'static KernelDispatch> {
    None
}

/// The SIMD tier for this host, if one exists: AVX2 intrinsics when the
/// CPU reports the feature, else the portable `std::simd` build when the
/// nightly-only `portable-simd` feature is compiled in.
pub fn simd_dispatch() -> Option<&'static KernelDispatch> {
    native_simd().or_else(portable_simd)
}

/// Dispatch table for an explicit tier (`None` when the host lacks it) —
/// how benches and parity tests pin both tiers side by side without
/// touching process-global state.
pub fn for_tier(tier: KernelTier) -> Option<&'static KernelDispatch> {
    match tier {
        KernelTier::Scalar => Some(&SCALAR),
        KernelTier::Simd => simd_dispatch(),
    }
}

static FORCED: OnceLock<TierChoice> = OnceLock::new();
static ACTIVE: OnceLock<&'static KernelDispatch> = OnceLock::new();

/// Force the process-wide tier. Must run before the first [`active`] use
/// (the CLI applies `--kernel-tier` before building any backend); errors
/// if the dispatch was already resolved to something else, or if `simd`
/// is requested on a host with no SIMD path.
pub fn set_tier(choice: TierChoice) -> Result<(), String> {
    if choice == TierChoice::Simd && simd_dispatch().is_none() {
        return Err(
            "kernel tier 'simd' unavailable: host CPU has no AVX2 and the binary was built \
             without the portable-simd feature"
                .into(),
        );
    }
    if FORCED.set(choice).is_err() && *FORCED.get().unwrap() != choice {
        return Err("kernel tier already forced to a different value".into());
    }
    if let Some(active) = ACTIVE.get() {
        let want = resolve(choice);
        if !std::ptr::eq(*active, want) {
            return Err(format!(
                "kernel dispatch already initialized to tier '{}' — set --kernel-tier before \
                 any compute runs",
                active.tier.name()
            ));
        }
    }
    Ok(())
}

fn resolve(choice: TierChoice) -> &'static KernelDispatch {
    match choice {
        TierChoice::Scalar => &SCALAR,
        // `Simd` falls back to scalar (with a warning) instead of panicking
        // so `SAGE_KERNEL_TIER=simd cargo test` degrades gracefully on a
        // host without AVX2; the CLI path errors earlier in `set_tier`.
        TierChoice::Simd => simd_dispatch().unwrap_or_else(|| {
            crate::log_warn!("kernel tier 'simd' unavailable on this host; using scalar");
            &SCALAR
        }),
        TierChoice::Auto => simd_dispatch().unwrap_or(&SCALAR),
    }
}

/// The process-wide dispatch table, resolved once: an explicit
/// [`set_tier`] wins, then the `SAGE_KERNEL_TIER` env var, then auto
/// (SIMD when available). Registers the `sage.kernel.*` observability
/// gauges on first use so every deployment can audit which tier served.
pub fn active() -> &'static KernelDispatch {
    ACTIVE.get_or_init(|| {
        let choice = FORCED
            .get()
            .copied()
            .or_else(|| {
                let v = std::env::var("SAGE_KERNEL_TIER").ok()?;
                match TierChoice::parse(&v) {
                    Ok(c) => Some(c),
                    Err(e) => {
                        crate::log_warn!("SAGE_KERNEL_TIER ignored: {e}");
                        None
                    }
                }
            })
            .unwrap_or(TierChoice::Auto);
        let d = resolve(choice);
        let reg = metrics::global();
        reg.gauge("sage.kernel.tier").set(d.tier.index());
        reg.gauge("sage.kernel.feature.avx2").set(u64::from(avx2_detected()));
        reg.gauge("sage.kernel.feature.simd_available")
            .set(u64::from(simd_dispatch().is_some()));
        d
    })
}

// ---------------------------------------------------------------------------
// Dispatch methods: primitives + row-grid kernels
// ---------------------------------------------------------------------------

/// B-row tile width for [`KernelDispatch::matmul_transb_rows`]: the tile
/// of B rows stays cache-hot while the A rows of the chunk stream past it.
const B_TILE: usize = 8;

impl KernelDispatch {
    pub fn tier(&self) -> KernelTier {
        self.tier
    }

    /// Implementation name ("scalar" | "avx2" | "portable").
    pub fn isa(&self) -> &'static str {
        self.isa
    }

    /// f32 dot product over the fixed 4-stream × 8-lane accumulator grid.
    #[inline]
    pub fn dot(&self, a: &[f32], b: &[f32]) -> f32 {
        debug_assert_eq!(a.len(), b.len());
        (self.dot_fn)(a, b)
    }

    /// f32 inputs, f64 accumulation (4 × 4 lanes) — norms/energies where
    /// drift across D ~ 1e5 terms would perturb rankings.
    #[inline]
    pub fn dot_f64(&self, a: &[f32], b: &[f32]) -> f64 {
        debug_assert_eq!(a.len(), b.len());
        (self.dot_f64_fn)(a, b)
    }

    /// `y += alpha * x` (elementwise — identical in every tier).
    #[inline]
    pub fn axpy(&self, alpha: f32, x: &[f32], y: &mut [f32]) {
        debug_assert_eq!(x.len(), y.len());
        (self.axpy_fn)(alpha, x, y);
    }

    /// `x *= s` (elementwise).
    #[inline]
    pub fn scale(&self, x: &mut [f32], s: f32) {
        (self.scale_fn)(x, s);
    }

    /// Euclidean norm in f64.
    #[inline]
    pub fn norm2(&self, x: &[f32]) -> f64 {
        self.dot_f64(x, x).sqrt()
    }

    /// `x /= ‖x‖`; returns the norm. Zero vectors stay zero (the paper's
    /// `ẑᵢ = 0` convention, Algorithm 1 line 13).
    pub fn normalize_in_place(&self, x: &mut [f32]) -> f64 {
        let n = self.norm2(x);
        if n > 0.0 {
            self.scale(x, (1.0 / n) as f32);
        }
        n
    }

    /// Rows `[r0, r1)` of `C = A·Bᵀ` (the Phase-II projection shape: A =
    /// the `b × D` gradient block, B = the `ℓ × D` sketch) into `out`,
    /// which holds exactly those rows (`(r1-r0) × b.rows()`, row-major).
    /// Each element is one [`KernelDispatch::dot`].
    pub fn matmul_transb_rows(
        &self,
        a: &Matrix,
        b: &Matrix,
        r0: usize,
        r1: usize,
        out: &mut [f32],
    ) {
        let n = b.rows();
        debug_assert_eq!(a.cols(), b.cols(), "matmul_transb inner dim");
        debug_assert_eq!(out.len(), (r1 - r0) * n);
        let mut j0 = 0;
        while j0 < n {
            let j1 = (j0 + B_TILE).min(n);
            for i in r0..r1 {
                let arow = a.row(i);
                let orow = &mut out[(i - r0) * n..(i - r0) * n + n];
                for j in j0..j1 {
                    orow[j] = self.dot(arow, b.row(j));
                }
            }
            j0 = j1;
        }
    }

    /// Rows `[r0, r1)` of the symmetric Gram `G = A·Aᵀ`, lower triangle
    /// only (`j ≤ i`); `out` holds full rows. Callers mirror the strict
    /// upper triangle afterwards with [`mirror_lower`] — a cheap serial
    /// pass that keeps the two triangles bit-identical by construction.
    pub fn gram_rows(&self, a: &Matrix, r0: usize, r1: usize, out: &mut [f32]) {
        let n = a.rows();
        debug_assert_eq!(out.len(), (r1 - r0) * n);
        for i in r0..r1 {
            let arow = a.row(i);
            let orow = &mut out[(i - r0) * n..(i - r0) * n + n];
            let mut j0 = 0;
            while j0 <= i {
                let j1 = (j0 + B_TILE).min(i + 1);
                for j in j0..j1 {
                    orow[j] = self.dot(arow, a.row(j));
                }
                j0 = j1;
            }
        }
    }

    /// Full serial Gram via the row-grid kernel + mirror (the serial
    /// backend's `gram`, and the reference the parallel path must match
    /// bit-for-bit).
    pub fn gram(&self, a: &Matrix) -> Matrix {
        let n = a.rows();
        let mut out = Matrix::zeros(n, n);
        self.gram_rows(a, 0, n, out.as_mut_slice());
        mirror_lower(&mut out);
        out
    }

    /// Rows `[r0, r1)` of `C = A·B` (the FD shrink's `R·S` contraction
    /// shape) into `out` (`(r1-r0) × b.cols()`). Row-major ikj loop: each
    /// output row accumulates `a[i][k] · b_k` with a fixed k order via
    /// [`KernelDispatch::axpy`], so the row split never changes results.
    /// Zero `a[i][k]` terms are skipped (adding `0 · x` is exact for
    /// finite `x`; rotation rows are built finite).
    pub fn matmul_rows(&self, a: &Matrix, b: &Matrix, r0: usize, r1: usize, out: &mut [f32]) {
        let n = b.cols();
        debug_assert_eq!(a.cols(), b.rows(), "matmul inner dim");
        debug_assert_eq!(out.len(), (r1 - r0) * n);
        for i in r0..r1 {
            let arow = a.row(i);
            let orow = &mut out[(i - r0) * n..(i - r0) * n + n];
            orow.fill(0.0);
            for (k, &aik) in arow.iter().enumerate() {
                if aik != 0.0 {
                    self.axpy(aik, b.row(k), orow);
                }
            }
        }
    }

    /// `out[i - r0] = ⟨m_i, x⟩` for rows `[r0, r1)` — the consensus matvec
    /// (`α = Ẑ·u`) and the selection rules' gain scans.
    pub fn matvec_rows(&self, m: &Matrix, x: &[f32], r0: usize, r1: usize, out: &mut [f32]) {
        debug_assert_eq!(m.cols(), x.len(), "matvec dim");
        debug_assert_eq!(out.len(), r1 - r0);
        for i in r0..r1 {
            out[i - r0] = self.dot(m.row(i), x);
        }
    }

    /// `out[i - r0] = ‖m_i‖²` in f64 for rows `[r0, r1)` — the batched
    /// row-energy accumulation under `FdSketch::insert_batch` and GRAFT's
    /// residual scan. Same f64 kernel as the single-row insert path, so
    /// the streamed energy certificate is path-independent.
    pub fn row_energies_rows(&self, m: &Matrix, r0: usize, r1: usize, out: &mut [f64]) {
        debug_assert_eq!(out.len(), r1 - r0);
        for i in r0..r1 {
            let row = m.row(i);
            out[i - r0] = self.dot_f64(row, row);
        }
    }

    /// Normalize rows `[r0, r1)` of `m` in place, recording each row's
    /// pre-normalization Euclidean norm (the Phase-II `‖S gᵢ‖` output).
    pub fn normalize_rows_rows(&self, m: &mut Matrix, r0: usize, r1: usize, norms: &mut [f32]) {
        debug_assert_eq!(norms.len(), r1 - r0);
        for i in r0..r1 {
            norms[i - r0] = self.normalize_in_place(m.row_mut(i)) as f32;
        }
    }

    /// `acc[j] += Σ_rows m[r][j]` in f64, accumulating row-by-row in row
    /// order — the consensus accumulator of `AgreementScorer::add_batch`.
    /// Row-sequential by contract (the row order IS the accumulation order
    /// the exactness guarantee pins down); the per-row column update is
    /// elementwise, so the SIMD tier changes nothing.
    pub fn accumulate_col_sums(&self, m: &Matrix, acc: &mut [f64]) {
        debug_assert_eq!(m.cols(), acc.len());
        for r in 0..m.rows() {
            (self.col_accum_fn)(m.row(r), acc);
        }
    }
}

// ---------------------------------------------------------------------------
// Scalar tier
// ---------------------------------------------------------------------------

/// Plain-Rust reference kernels over the shared accumulator layout. These
/// define the semantics every other tier must reproduce bit-for-bit.
mod scalar {
    use super::{DOT_STREAMS, F32_BLOCK, F32_LANES, F64_BLOCK, F64_LANES};

    /// The fixed f32 reduction tree both tiers share: streams combine
    /// pairwise per lane, then lanes fold with the `(l, l+4)` pattern.
    #[inline]
    pub(super) fn reduce_f32(acc: &[[f32; F32_LANES]; DOT_STREAMS]) -> f32 {
        let mut lane = [0.0f32; F32_LANES];
        for (l, v) in lane.iter_mut().enumerate() {
            *v = (acc[0][l] + acc[1][l]) + (acc[2][l] + acc[3][l]);
        }
        ((lane[0] + lane[4]) + (lane[1] + lane[5])) + ((lane[2] + lane[6]) + (lane[3] + lane[7]))
    }

    /// The fixed f64 reduction tree (4 lanes: fold `(l, l+2)` pairs).
    #[inline]
    pub(super) fn reduce_f64(acc: &[[f64; F64_LANES]; DOT_STREAMS]) -> f64 {
        let mut lane = [0.0f64; F64_LANES];
        for (l, v) in lane.iter_mut().enumerate() {
            *v = (acc[0][l] + acc[1][l]) + (acc[2][l] + acc[3][l]);
        }
        (lane[0] + lane[2]) + (lane[1] + lane[3])
    }

    pub(super) fn dot(a: &[f32], b: &[f32]) -> f32 {
        let n = a.len();
        let blocks = n / F32_BLOCK;
        let mut acc = [[0.0f32; F32_LANES]; DOT_STREAMS];
        for blk in 0..blocks {
            let base = blk * F32_BLOCK;
            for (s, stream) in acc.iter_mut().enumerate() {
                let j = base + s * F32_LANES;
                let aw = &a[j..j + F32_LANES];
                let bw = &b[j..j + F32_LANES];
                for ((t, &x), &y) in stream.iter_mut().zip(aw).zip(bw) {
                    *t += x * y;
                }
            }
        }
        let mut s = reduce_f32(&acc);
        for j in blocks * F32_BLOCK..n {
            s += a[j] * b[j];
        }
        s
    }

    pub(super) fn dot_f64(a: &[f32], b: &[f32]) -> f64 {
        let n = a.len();
        let blocks = n / F64_BLOCK;
        let mut acc = [[0.0f64; F64_LANES]; DOT_STREAMS];
        for blk in 0..blocks {
            let base = blk * F64_BLOCK;
            for (s, stream) in acc.iter_mut().enumerate() {
                let j = base + s * F64_LANES;
                let aw = &a[j..j + F64_LANES];
                let bw = &b[j..j + F64_LANES];
                for ((t, &x), &y) in stream.iter_mut().zip(aw).zip(bw) {
                    *t += x as f64 * y as f64;
                }
            }
        }
        let mut s = reduce_f64(&acc);
        for j in blocks * F64_BLOCK..n {
            s += a[j] as f64 * b[j] as f64;
        }
        s
    }

    pub(super) fn axpy(alpha: f32, x: &[f32], y: &mut [f32]) {
        for (yi, &xi) in y.iter_mut().zip(x.iter()) {
            *yi += alpha * xi;
        }
    }

    pub(super) fn scale(x: &mut [f32], s: f32) {
        for v in x.iter_mut() {
            *v *= s;
        }
    }

    pub(super) fn col_accum(row: &[f32], acc: &mut [f64]) {
        for (a, &v) in acc.iter_mut().zip(row.iter()) {
            *a += v as f64;
        }
    }
}

// ---------------------------------------------------------------------------
// AVX2 tier (x86_64, runtime-detected)
// ---------------------------------------------------------------------------

/// AVX2 intrinsics kernels. Every function mirrors its scalar twin
/// operation-for-operation: same accumulator layout, same mul-then-add
/// (no FMA — `_mm256_fmadd_*` is never used and Rust keeps LLVM's FP
/// contraction off), same reduction tree, same sequential tails — so the
/// outputs are bit-identical to the scalar tier.
#[cfg(target_arch = "x86_64")]
mod avx2 {
    use super::{F32_BLOCK, F64_BLOCK};
    use std::arch::x86_64::*;

    #[target_feature(enable = "avx2")]
    unsafe fn dot_impl(a: &[f32], b: &[f32]) -> f32 {
        let n = a.len();
        let blocks = n / F32_BLOCK;
        let ap = a.as_ptr();
        let bp = b.as_ptr();
        let mut acc0 = _mm256_setzero_ps();
        let mut acc1 = _mm256_setzero_ps();
        let mut acc2 = _mm256_setzero_ps();
        let mut acc3 = _mm256_setzero_ps();
        for blk in 0..blocks {
            let j = blk * F32_BLOCK;
            acc0 = _mm256_add_ps(
                acc0,
                _mm256_mul_ps(_mm256_loadu_ps(ap.add(j)), _mm256_loadu_ps(bp.add(j))),
            );
            acc1 = _mm256_add_ps(
                acc1,
                _mm256_mul_ps(_mm256_loadu_ps(ap.add(j + 8)), _mm256_loadu_ps(bp.add(j + 8))),
            );
            acc2 = _mm256_add_ps(
                acc2,
                _mm256_mul_ps(_mm256_loadu_ps(ap.add(j + 16)), _mm256_loadu_ps(bp.add(j + 16))),
            );
            acc3 = _mm256_add_ps(
                acc3,
                _mm256_mul_ps(_mm256_loadu_ps(ap.add(j + 24)), _mm256_loadu_ps(bp.add(j + 24))),
            );
        }
        // Stream combine, then the fixed (l, l+4) lane tree — the exact
        // shape of scalar::reduce_f32.
        let lane = _mm256_add_ps(_mm256_add_ps(acc0, acc1), _mm256_add_ps(acc2, acc3));
        let q = _mm_add_ps(_mm256_castps256_ps128(lane), _mm256_extractf128_ps::<1>(lane));
        let mut qa = [0.0f32; 4];
        _mm_storeu_ps(qa.as_mut_ptr(), q);
        let mut s = (qa[0] + qa[1]) + (qa[2] + qa[3]);
        for j in blocks * F32_BLOCK..n {
            s += a[j] * b[j];
        }
        s
    }

    #[target_feature(enable = "avx2")]
    unsafe fn dot_f64_impl(a: &[f32], b: &[f32]) -> f64 {
        let n = a.len();
        let blocks = n / F64_BLOCK;
        let ap = a.as_ptr();
        let bp = b.as_ptr();
        let mut acc0 = _mm256_setzero_pd();
        let mut acc1 = _mm256_setzero_pd();
        let mut acc2 = _mm256_setzero_pd();
        let mut acc3 = _mm256_setzero_pd();
        for blk in 0..blocks {
            let j = blk * F64_BLOCK;
            acc0 = _mm256_add_pd(
                acc0,
                _mm256_mul_pd(
                    _mm256_cvtps_pd(_mm_loadu_ps(ap.add(j))),
                    _mm256_cvtps_pd(_mm_loadu_ps(bp.add(j))),
                ),
            );
            acc1 = _mm256_add_pd(
                acc1,
                _mm256_mul_pd(
                    _mm256_cvtps_pd(_mm_loadu_ps(ap.add(j + 4))),
                    _mm256_cvtps_pd(_mm_loadu_ps(bp.add(j + 4))),
                ),
            );
            acc2 = _mm256_add_pd(
                acc2,
                _mm256_mul_pd(
                    _mm256_cvtps_pd(_mm_loadu_ps(ap.add(j + 8))),
                    _mm256_cvtps_pd(_mm_loadu_ps(bp.add(j + 8))),
                ),
            );
            acc3 = _mm256_add_pd(
                acc3,
                _mm256_mul_pd(
                    _mm256_cvtps_pd(_mm_loadu_ps(ap.add(j + 12))),
                    _mm256_cvtps_pd(_mm_loadu_ps(bp.add(j + 12))),
                ),
            );
        }
        let lane = _mm256_add_pd(_mm256_add_pd(acc0, acc1), _mm256_add_pd(acc2, acc3));
        let q = _mm_add_pd(_mm256_castpd256_pd128(lane), _mm256_extractf128_pd::<1>(lane));
        let mut qa = [0.0f64; 2];
        _mm_storeu_pd(qa.as_mut_ptr(), q);
        let mut s = qa[0] + qa[1];
        for j in blocks * F64_BLOCK..n {
            s += a[j] as f64 * b[j] as f64;
        }
        s
    }

    #[target_feature(enable = "avx2")]
    unsafe fn axpy_impl(alpha: f32, x: &[f32], y: &mut [f32]) {
        let n = x.len();
        let blocks = n / 8;
        let va = _mm256_set1_ps(alpha);
        let xp = x.as_ptr();
        let yp = y.as_mut_ptr();
        for blk in 0..blocks {
            let j = blk * 8;
            let v = _mm256_add_ps(
                _mm256_loadu_ps(yp.add(j)),
                _mm256_mul_ps(va, _mm256_loadu_ps(xp.add(j))),
            );
            _mm256_storeu_ps(yp.add(j), v);
        }
        for j in blocks * 8..n {
            y[j] += alpha * x[j];
        }
    }

    #[target_feature(enable = "avx2")]
    unsafe fn scale_impl(x: &mut [f32], s: f32) {
        let n = x.len();
        let blocks = n / 8;
        let vs = _mm256_set1_ps(s);
        let xp = x.as_mut_ptr();
        for blk in 0..blocks {
            let j = blk * 8;
            // Operand order matches the scalar `x * s`.
            _mm256_storeu_ps(xp.add(j), _mm256_mul_ps(_mm256_loadu_ps(xp.add(j)), vs));
        }
        for j in blocks * 8..n {
            x[j] *= s;
        }
    }

    #[target_feature(enable = "avx2")]
    unsafe fn col_accum_impl(row: &[f32], acc: &mut [f64]) {
        let n = row.len();
        let blocks = n / 4;
        let rp = row.as_ptr();
        let ap = acc.as_mut_ptr();
        for blk in 0..blocks {
            let j = blk * 4;
            let v = _mm256_cvtps_pd(_mm_loadu_ps(rp.add(j)));
            _mm256_storeu_pd(ap.add(j), _mm256_add_pd(_mm256_loadu_pd(ap.add(j)), v));
        }
        for j in blocks * 4..n {
            acc[j] += row[j] as f64;
        }
    }

    // Safe wrappers: reachable only through the AVX2 dispatch table, which
    // `simd_dispatch` hands out only after `is_x86_feature_detected!`
    // confirmed the CPU supports it.

    pub(super) fn dot(a: &[f32], b: &[f32]) -> f32 {
        // SAFETY: AVX2 presence verified at dispatch construction.
        unsafe { dot_impl(a, b) }
    }

    pub(super) fn dot_f64(a: &[f32], b: &[f32]) -> f64 {
        // SAFETY: as above.
        unsafe { dot_f64_impl(a, b) }
    }

    pub(super) fn axpy(alpha: f32, x: &[f32], y: &mut [f32]) {
        // SAFETY: as above.
        unsafe { axpy_impl(alpha, x, y) }
    }

    pub(super) fn scale(x: &mut [f32], s: f32) {
        // SAFETY: as above.
        unsafe { scale_impl(x, s) }
    }

    pub(super) fn col_accum(row: &[f32], acc: &mut [f64]) {
        debug_assert!(acc.len() >= row.len());
        // SAFETY: as above.
        unsafe { col_accum_impl(row, acc) }
    }
}

// ---------------------------------------------------------------------------
// Portable std::simd tier (nightly-only `portable-simd` feature)
// ---------------------------------------------------------------------------

/// `std::simd` kernels for non-x86 hosts (NEON et al. via the portable
/// API). Same layout/reduction/tail discipline as the other tiers;
/// `std::simd` element ops are strict IEEE with no contraction, so the
/// bit-identity argument is unchanged. Requires a nightly toolchain:
/// `cargo +nightly build --features portable-simd`.
#[cfg(feature = "portable-simd")]
mod portable {
    use super::{DOT_STREAMS, F32_BLOCK, F32_LANES, F64_BLOCK, F64_LANES};
    use std::simd::{f32x8, f64x4};

    pub(super) fn dot(a: &[f32], b: &[f32]) -> f32 {
        let n = a.len();
        let blocks = n / F32_BLOCK;
        let mut acc = [f32x8::splat(0.0); DOT_STREAMS];
        for blk in 0..blocks {
            let base = blk * F32_BLOCK;
            for (s, stream) in acc.iter_mut().enumerate() {
                let j = base + s * F32_LANES;
                let va = f32x8::from_slice(&a[j..j + F32_LANES]);
                let vb = f32x8::from_slice(&b[j..j + F32_LANES]);
                *stream += va * vb;
            }
        }
        let lane = ((acc[0] + acc[1]) + (acc[2] + acc[3])).to_array();
        let mut s = ((lane[0] + lane[4]) + (lane[1] + lane[5]))
            + ((lane[2] + lane[6]) + (lane[3] + lane[7]));
        for j in blocks * F32_BLOCK..n {
            s += a[j] * b[j];
        }
        s
    }

    pub(super) fn dot_f64(a: &[f32], b: &[f32]) -> f64 {
        let n = a.len();
        let blocks = n / F64_BLOCK;
        let mut acc = [f64x4::splat(0.0); DOT_STREAMS];
        for blk in 0..blocks {
            let base = blk * F64_BLOCK;
            for (s, stream) in acc.iter_mut().enumerate() {
                let j = base + s * F64_LANES;
                let va = f64x4::from_array([
                    a[j] as f64,
                    a[j + 1] as f64,
                    a[j + 2] as f64,
                    a[j + 3] as f64,
                ]);
                let vb = f64x4::from_array([
                    b[j] as f64,
                    b[j + 1] as f64,
                    b[j + 2] as f64,
                    b[j + 3] as f64,
                ]);
                *stream += va * vb;
            }
        }
        let lane = ((acc[0] + acc[1]) + (acc[2] + acc[3])).to_array();
        let mut s = (lane[0] + lane[2]) + (lane[1] + lane[3]);
        for j in blocks * F64_BLOCK..n {
            s += a[j] as f64 * b[j] as f64;
        }
        s
    }

    pub(super) fn axpy(alpha: f32, x: &[f32], y: &mut [f32]) {
        let n = x.len();
        let blocks = n / F32_LANES;
        let va = f32x8::splat(alpha);
        for blk in 0..blocks {
            let j = blk * F32_LANES;
            let v = f32x8::from_slice(&y[j..j + F32_LANES])
                + va * f32x8::from_slice(&x[j..j + F32_LANES]);
            y[j..j + F32_LANES].copy_from_slice(&v.to_array());
        }
        for j in blocks * F32_LANES..n {
            y[j] += alpha * x[j];
        }
    }

    pub(super) fn scale(x: &mut [f32], s: f32) {
        let n = x.len();
        let blocks = n / F32_LANES;
        let vs = f32x8::splat(s);
        for blk in 0..blocks {
            let j = blk * F32_LANES;
            let v = f32x8::from_slice(&x[j..j + F32_LANES]) * vs;
            x[j..j + F32_LANES].copy_from_slice(&v.to_array());
        }
        for j in blocks * F32_LANES..n {
            x[j] *= s;
        }
    }

    pub(super) fn col_accum(row: &[f32], acc: &mut [f64]) {
        let n = row.len();
        let blocks = n / F64_LANES;
        for blk in 0..blocks {
            let j = blk * F64_LANES;
            let v = f64x4::from_array([
                row[j] as f64,
                row[j + 1] as f64,
                row[j + 2] as f64,
                row[j + 3] as f64,
            ]);
            let a = f64x4::from_slice(&acc[j..j + F64_LANES]) + v;
            acc[j..j + F64_LANES].copy_from_slice(&a.to_array());
        }
        for j in blocks * F64_LANES..n {
            acc[j] += row[j] as f64;
        }
    }
}

// ---------------------------------------------------------------------------
// Free-function façade over the active dispatch (Matrix methods, ops, and
// existing call sites route here and inherit the process tier).
// ---------------------------------------------------------------------------

/// f32 dot on the active tier (the microkernel under every contraction).
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    active().dot(a, b)
}

/// f64-accumulated dot on the active tier.
#[inline]
pub fn dot_f64(a: &[f32], b: &[f32]) -> f64 {
    active().dot_f64(a, b)
}

/// `y += alpha·x` on the active tier.
#[inline]
pub fn axpy(alpha: f32, x: &[f32], y: &mut [f32]) {
    active().axpy(alpha, x, y)
}

/// See [`KernelDispatch::matmul_transb_rows`].
pub fn matmul_transb_rows(a: &Matrix, b: &Matrix, r0: usize, r1: usize, out: &mut [f32]) {
    active().matmul_transb_rows(a, b, r0, r1, out)
}

/// See [`KernelDispatch::gram_rows`].
pub fn gram_rows(a: &Matrix, r0: usize, r1: usize, out: &mut [f32]) {
    active().gram_rows(a, r0, r1, out)
}

/// See [`KernelDispatch::gram`].
pub fn gram(a: &Matrix) -> Matrix {
    active().gram(a)
}

/// See [`KernelDispatch::matmul_rows`].
pub fn matmul_rows(a: &Matrix, b: &Matrix, r0: usize, r1: usize, out: &mut [f32]) {
    active().matmul_rows(a, b, r0, r1, out)
}

/// See [`KernelDispatch::matvec_rows`].
pub fn matvec_rows(m: &Matrix, x: &[f32], r0: usize, r1: usize, out: &mut [f32]) {
    active().matvec_rows(m, x, r0, r1, out)
}

/// See [`KernelDispatch::row_energies_rows`].
pub fn row_energies_rows(m: &Matrix, r0: usize, r1: usize, out: &mut [f64]) {
    active().row_energies_rows(m, r0, r1, out)
}

/// See [`KernelDispatch::normalize_rows_rows`].
pub fn normalize_rows_rows(m: &mut Matrix, r0: usize, r1: usize, norms: &mut [f32]) {
    active().normalize_rows_rows(m, r0, r1, norms)
}

/// See [`KernelDispatch::accumulate_col_sums`].
pub fn accumulate_col_sums(m: &Matrix, acc: &mut [f64]) {
    active().accumulate_col_sums(m, acc)
}

// ---------------------------------------------------------------------------
// Row grid + transpose (tier-independent)
// ---------------------------------------------------------------------------

/// Fixed row-chunk size for a `rows`-row output grid. Depends ONLY on the
/// shape — never on the worker count — so the chunk boundaries (and with
/// them the results) are identical for every `--workers` setting.
pub fn row_chunk(rows: usize) -> usize {
    (rows / 64).clamp(4, 256)
}

/// Number of chunks in the fixed row grid over `rows` rows.
pub fn row_chunks(rows: usize) -> usize {
    rows.div_ceil(row_chunk(rows))
}

/// Copy the lower triangle of a square matrix onto its strict upper
/// triangle (the mirror step after [`gram_rows`]).
pub fn mirror_lower(g: &mut Matrix) {
    debug_assert_eq!(g.rows(), g.cols());
    let n = g.rows();
    let data = g.as_mut_slice();
    for i in 0..n {
        for j in 0..i {
            data[j * n + i] = data[i * n + j];
        }
    }
}

/// Cache-blocked transpose tile edge (32×32 f32 tiles = two 4 KiB faces).
const T_TILE: usize = 32;

/// `dst = srcᵀ` via square tiling so both the source rows and destination
/// rows stay within cache lines per tile (the naive row-major transpose
/// strides `dst` by `src.rows()` floats per element). Pure data movement —
/// no tier dependence.
pub fn transpose_into(src: &Matrix, dst: &mut Matrix) {
    let (r, c) = (src.rows(), src.cols());
    debug_assert_eq!((dst.rows(), dst.cols()), (c, r));
    let s = src.as_slice();
    let d = dst.as_mut_slice();
    let mut i0 = 0;
    while i0 < r {
        let i1 = (i0 + T_TILE).min(r);
        let mut j0 = 0;
        while j0 < c {
            let j1 = (j0 + T_TILE).min(c);
            for i in i0..i1 {
                for j in j0..j1 {
                    d[j * r + i] = s[i * c + j];
                }
            }
            j0 = j1;
        }
        i0 = i1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::check::forall;

    fn random_matrix(rng: &mut crate::util::rng::Pcg64, r: usize, c: usize) -> Matrix {
        Matrix::from_fn(r, c, |_, _| rng.normal_f32())
    }

    #[test]
    fn dot_matches_f64_reference() {
        forall("dot", 30, |rng| {
            let n = rng.below(300) as usize;
            let a: Vec<f32> = (0..n).map(|_| rng.normal_f32()).collect();
            let b: Vec<f32> = (0..n).map(|_| rng.normal_f32()).collect();
            let fast = dot(&a, &b) as f64;
            let slow: f64 = a.iter().zip(b.iter()).map(|(&x, &y)| x as f64 * y as f64).sum();
            assert!(
                (fast - slow).abs() < 1e-3 * (1.0 + slow.abs()),
                "{fast} vs {slow}"
            );
        });
    }

    #[test]
    fn tier_selection_is_coherent() {
        // The scalar tier always exists; for_tier round-trips; the active
        // table is one of the two.
        assert_eq!(scalar_dispatch().tier(), KernelTier::Scalar);
        assert!(std::ptr::eq(
            for_tier(KernelTier::Scalar).unwrap(),
            scalar_dispatch()
        ));
        if let Some(simd) = simd_dispatch() {
            assert_eq!(simd.tier(), KernelTier::Simd);
            assert!(std::ptr::eq(for_tier(KernelTier::Simd).unwrap(), simd));
        } else {
            assert!(for_tier(KernelTier::Simd).is_none());
        }
        let act = active();
        assert!(
            std::ptr::eq(act, scalar_dispatch())
                || simd_dispatch().is_some_and(|d| std::ptr::eq(act, d))
        );
        // And first use registered the audit gauges.
        let gauges = crate::util::metrics::global().snapshot_gauges("sage.kernel.");
        assert!(
            gauges.iter().any(|(n, _)| n == "sage.kernel.tier"),
            "tier gauge missing: {gauges:?}"
        );
    }

    #[test]
    fn tier_choice_parses() {
        assert_eq!(TierChoice::parse("auto").unwrap(), TierChoice::Auto);
        assert_eq!(TierChoice::parse("scalar").unwrap(), TierChoice::Scalar);
        assert_eq!(TierChoice::parse("simd").unwrap(), TierChoice::Simd);
        assert!(TierChoice::parse("gpu").is_err());
    }

    /// The heart of the tentpole: every primitive is bit-identical between
    /// the scalar tier and the SIMD tier, for lengths that exercise whole
    /// blocks, ragged tails, and degenerate sizes.
    #[test]
    fn simd_primitives_bit_identical_to_scalar() {
        let Some(simd) = simd_dispatch() else {
            eprintln!("skip: no SIMD tier on this host");
            return;
        };
        let sc = scalar_dispatch();
        forall("tier_parity", 20, |rng| {
            let n = rng.below(200) as usize;
            let a: Vec<f32> = (0..n).map(|_| rng.normal_f32()).collect();
            let b: Vec<f32> = (0..n).map(|_| rng.normal_f32()).collect();
            assert_eq!(
                sc.dot(&a, &b).to_bits(),
                simd.dot(&a, &b).to_bits(),
                "dot n={n}"
            );
            assert_eq!(
                sc.dot_f64(&a, &b).to_bits(),
                simd.dot_f64(&a, &b).to_bits(),
                "dot_f64 n={n}"
            );
            let alpha = rng.normal_f32();
            let mut y1 = b.clone();
            let mut y2 = b.clone();
            sc.axpy(alpha, &a, &mut y1);
            simd.axpy(alpha, &a, &mut y2);
            for (i, (x, y)) in y1.iter().zip(y2.iter()).enumerate() {
                assert_eq!(x.to_bits(), y.to_bits(), "axpy[{i}] n={n}");
            }
            let mut x1 = a.clone();
            let mut x2 = a.clone();
            sc.scale(&mut x1, alpha);
            simd.scale(&mut x2, alpha);
            for (i, (x, y)) in x1.iter().zip(x2.iter()).enumerate() {
                assert_eq!(x.to_bits(), y.to_bits(), "scale[{i}] n={n}");
            }
            let mut n1 = a.clone();
            let mut n2 = a.clone();
            let r1 = sc.normalize_in_place(&mut n1);
            let r2 = simd.normalize_in_place(&mut n2);
            assert_eq!(r1.to_bits(), r2.to_bits(), "norm n={n}");
            for (i, (x, y)) in n1.iter().zip(n2.iter()).enumerate() {
                assert_eq!(x.to_bits(), y.to_bits(), "normalize[{i}] n={n}");
            }
            let mut c1 = vec![0.5f64; n];
            let mut c2 = vec![0.5f64; n];
            (sc.col_accum_fn)(&a, &mut c1);
            (simd.col_accum_fn)(&a, &mut c2);
            for (i, (x, y)) in c1.iter().zip(c2.iter()).enumerate() {
                assert_eq!(x.to_bits(), y.to_bits(), "col_accum[{i}] n={n}");
            }
        });
    }

    #[test]
    fn row_grid_is_worker_count_free() {
        for rows in [1usize, 5, 63, 64, 65, 512, 100_000] {
            let chunk = row_chunk(rows);
            assert!((4..=256).contains(&chunk));
            assert_eq!(row_chunks(rows), rows.div_ceil(chunk));
        }
    }

    #[test]
    fn split_kernel_calls_match_full_range() {
        // The determinism contract at kernel granularity: computing the
        // row grid chunk-by-chunk reproduces the full-range call bit-for-bit.
        forall("kernel_split", 10, |rng| {
            let m = 1 + rng.below(33) as usize;
            let k = 1 + rng.below(70) as usize;
            let n = 1 + rng.below(19) as usize;
            let a = random_matrix(rng, m, k);
            let b = random_matrix(rng, n, k);

            let mut full = vec![0.0f32; m * n];
            matmul_transb_rows(&a, &b, 0, m, &mut full);
            let mut split = vec![0.0f32; m * n];
            let mut r0 = 0;
            while r0 < m {
                let r1 = (r0 + 3).min(m);
                matmul_transb_rows(&a, &b, r0, r1, &mut split[r0 * n..r1 * n]);
                r0 = r1;
            }
            for (x, y) in full.iter().zip(split.iter()) {
                assert_eq!(x.to_bits(), y.to_bits());
            }
        });
    }

    #[test]
    fn gram_matches_matmul_transb_self_bitwise() {
        forall("kernel_gram", 10, |rng| {
            let m = 1 + rng.below(20) as usize;
            let d = 1 + rng.below(40) as usize;
            let a = random_matrix(rng, m, d);
            let g = gram(&a);
            let mut full = vec![0.0f32; m * m];
            matmul_transb_rows(&a, &a, 0, m, &mut full);
            // Lower triangle (incl. diagonal) is computed by the same dot
            // calls; the upper triangle is the mirror.
            for i in 0..m {
                for j in 0..m {
                    let want = if j <= i { full[i * m + j] } else { full[j * m + i] };
                    assert_eq!(g.get(i, j).to_bits(), want.to_bits(), "({i},{j})");
                }
            }
        });
    }

    #[test]
    fn matmul_rows_matches_matrix_matmul() {
        forall("kernel_matmul", 10, |rng| {
            let m = 1 + rng.below(12) as usize;
            let k = 1 + rng.below(12) as usize;
            let n = 1 + rng.below(12) as usize;
            let a = random_matrix(rng, m, k);
            let b = random_matrix(rng, k, n);
            let mut out = vec![0.0f32; m * n];
            matmul_rows(&a, &b, 0, m, &mut out);
            let want = a.matmul(&b);
            for (x, y) in out.iter().zip(want.as_slice()) {
                assert_eq!(x.to_bits(), y.to_bits());
            }
        });
    }

    #[test]
    fn transpose_tiles_match_naive() {
        forall("kernel_transpose", 10, |rng| {
            let r = 1 + rng.below(70) as usize;
            let c = 1 + rng.below(70) as usize;
            let a = random_matrix(rng, r, c);
            let mut t = Matrix::zeros(c, r);
            transpose_into(&a, &mut t);
            for i in 0..r {
                for j in 0..c {
                    assert_eq!(t.get(j, i).to_bits(), a.get(i, j).to_bits());
                }
            }
        });
    }

    #[test]
    fn row_energies_match_dot_f64() {
        forall("kernel_energy", 10, |rng| {
            let m = 1 + rng.below(9) as usize;
            let d = 1 + rng.below(50) as usize;
            let a = random_matrix(rng, m, d);
            let mut en = vec![0.0f64; m];
            row_energies_rows(&a, 0, m, &mut en);
            for (i, &e) in en.iter().enumerate() {
                assert_eq!(e.to_bits(), dot_f64(a.row(i), a.row(i)).to_bits());
            }
        });
    }
}
