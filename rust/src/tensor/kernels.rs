//! Serial compute microkernels — the single source of truth for every hot
//! contraction in the system (FD shrink, Phase-II projection, consensus
//! matvec, batched row norms/energies).
//!
//! Each kernel is written in *row-grid* form: it computes a contiguous row
//! range `[r0, r1)` of its output. The serial [`ComputeBackend`] calls it
//! once with the full range; the parallel backend calls it once per chunk
//! of a **fixed, worker-count-independent row grid** (see [`row_chunk`]).
//! Because every output element is produced by exactly one kernel call with
//! a fixed intra-kernel accumulation order, the split never changes results:
//! parallel output is bit-identical to serial for any worker count.
//!
//! The dot microkernel is [`dot8`]: 8-wide unrolled with 8 independent
//! accumulators, which the compiler auto-vectorizes (two 4-lane or one
//! 8-lane FMA stream); matrix kernels tile their loops so the smaller
//! operand stays cache-resident while the larger one streams.
//!
//! [`ComputeBackend`]: super::ComputeBackend

use super::ops;
use super::Matrix;

/// f32 dot product, 8-wide unrolled with 8 independent accumulators.
/// The multi-accumulator shape both enables SIMD and fixes the reduction
/// tree, so results are reproducible anywhere this kernel runs.
#[inline]
pub fn dot8(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let n = a.len();
    let chunks = n / 8;
    let mut acc = [0.0f32; 8];
    for c in 0..chunks {
        let j = c * 8;
        let aw = &a[j..j + 8];
        let bw = &b[j..j + 8];
        for ((s, &x), &y) in acc.iter_mut().zip(aw.iter()).zip(bw.iter()) {
            *s += x * y;
        }
    }
    let mut s = ((acc[0] + acc[4]) + (acc[1] + acc[5])) + ((acc[2] + acc[6]) + (acc[3] + acc[7]));
    for j in chunks * 8..n {
        s += a[j] * b[j];
    }
    s
}

/// Fixed row-chunk size for a `rows`-row output grid. Depends ONLY on the
/// shape — never on the worker count — so the chunk boundaries (and with
/// them the results) are identical for every `--workers` setting.
pub fn row_chunk(rows: usize) -> usize {
    (rows / 64).clamp(4, 256)
}

/// Number of chunks in the fixed row grid over `rows` rows.
pub fn row_chunks(rows: usize) -> usize {
    rows.div_ceil(row_chunk(rows))
}

/// B-row tile width for [`matmul_transb_rows`]: the tile of B rows stays
/// cache-hot while the A rows of the chunk stream past it.
const B_TILE: usize = 8;

/// Rows `[r0, r1)` of `C = A·Bᵀ` (the Phase-II projection shape: A = the
/// `b × D` gradient block, B = the `ℓ × D` sketch) into `out`, which holds
/// exactly those rows (`(r1-r0) × b.rows()`, row-major). Each element is
/// one [`dot8`].
pub fn matmul_transb_rows(a: &Matrix, b: &Matrix, r0: usize, r1: usize, out: &mut [f32]) {
    let n = b.rows();
    debug_assert_eq!(a.cols(), b.cols(), "matmul_transb inner dim");
    debug_assert_eq!(out.len(), (r1 - r0) * n);
    let mut j0 = 0;
    while j0 < n {
        let j1 = (j0 + B_TILE).min(n);
        for i in r0..r1 {
            let arow = a.row(i);
            let orow = &mut out[(i - r0) * n..(i - r0) * n + n];
            for j in j0..j1 {
                orow[j] = dot8(arow, b.row(j));
            }
        }
        j0 = j1;
    }
}

/// Rows `[r0, r1)` of the symmetric Gram `G = A·Aᵀ`, lower triangle only
/// (`j ≤ i`); `out` holds full rows. Callers mirror the strict upper
/// triangle afterwards with [`mirror_lower`] — a cheap serial pass that
/// keeps the two triangles bit-identical by construction.
pub fn gram_rows(a: &Matrix, r0: usize, r1: usize, out: &mut [f32]) {
    let n = a.rows();
    debug_assert_eq!(out.len(), (r1 - r0) * n);
    for i in r0..r1 {
        let arow = a.row(i);
        let orow = &mut out[(i - r0) * n..(i - r0) * n + n];
        let mut j0 = 0;
        while j0 <= i {
            let j1 = (j0 + B_TILE).min(i + 1);
            for j in j0..j1 {
                orow[j] = dot8(arow, a.row(j));
            }
            j0 = j1;
        }
    }
}

/// Copy the lower triangle of a square matrix onto its strict upper
/// triangle (the mirror step after [`gram_rows`]).
pub fn mirror_lower(g: &mut Matrix) {
    debug_assert_eq!(g.rows(), g.cols());
    let n = g.rows();
    let data = g.as_mut_slice();
    for i in 0..n {
        for j in 0..i {
            data[j * n + i] = data[i * n + j];
        }
    }
}

/// Full serial Gram via the row-grid kernel + mirror (the serial backend's
/// `gram`, and the reference the parallel path must match bit-for-bit).
pub fn gram(a: &Matrix) -> Matrix {
    let n = a.rows();
    let mut out = Matrix::zeros(n, n);
    gram_rows(a, 0, n, out.as_mut_slice());
    mirror_lower(&mut out);
    out
}

/// Rows `[r0, r1)` of `C = A·B` (the FD shrink's `R·S` contraction shape)
/// into `out` (`(r1-r0) × b.cols()`). Row-major ikj loop: each output row
/// accumulates `a[i][k] · b_k` with a fixed k order via `axpy`, so the row
/// split never changes results. Zero `a[i][k]` terms are skipped (adding
/// `0 · x` is exact for finite `x`; rotation rows are built finite).
pub fn matmul_rows(a: &Matrix, b: &Matrix, r0: usize, r1: usize, out: &mut [f32]) {
    let n = b.cols();
    debug_assert_eq!(a.cols(), b.rows(), "matmul inner dim");
    debug_assert_eq!(out.len(), (r1 - r0) * n);
    for i in r0..r1 {
        let arow = a.row(i);
        let orow = &mut out[(i - r0) * n..(i - r0) * n + n];
        orow.fill(0.0);
        for (k, &aik) in arow.iter().enumerate() {
            if aik != 0.0 {
                ops::axpy(aik, b.row(k), orow);
            }
        }
    }
}

/// `out[i - r0] = ⟨m_i, x⟩` for rows `[r0, r1)` — the consensus matvec
/// (`α = Ẑ·u`) and the selection rules' gain scans. One [`dot8`] per row.
pub fn matvec_rows(m: &Matrix, x: &[f32], r0: usize, r1: usize, out: &mut [f32]) {
    debug_assert_eq!(m.cols(), x.len(), "matvec dim");
    debug_assert_eq!(out.len(), r1 - r0);
    for i in r0..r1 {
        out[i - r0] = dot8(m.row(i), x);
    }
}

/// `out[i - r0] = ‖m_i‖²` in f64 for rows `[r0, r1)` — the batched
/// row-energy accumulation under `FdSketch::insert_batch` and GRAFT's
/// residual scan. Same sequential-f64 semantics as `ops::dot_f64(row, row)`
/// so the streamed energy certificate is unchanged by the kernel routing.
pub fn row_energies_rows(m: &Matrix, r0: usize, r1: usize, out: &mut [f64]) {
    debug_assert_eq!(out.len(), r1 - r0);
    for i in r0..r1 {
        let row = m.row(i);
        out[i - r0] = ops::dot_f64(row, row);
    }
}

/// Normalize rows `[r0, r1)` of `m` in place, recording each row's
/// pre-normalization Euclidean norm (the Phase-II `‖S gᵢ‖` output). Zero
/// rows stay zero, matching Algorithm 1's `ẑᵢ = 0` convention.
pub fn normalize_rows_rows(m: &mut Matrix, r0: usize, r1: usize, norms: &mut [f32]) {
    debug_assert_eq!(norms.len(), r1 - r0);
    for i in r0..r1 {
        norms[i - r0] = ops::normalize_in_place(m.row_mut(i)) as f32;
    }
}

/// `acc[j] += Σ_rows m[r][j]` in f64, accumulating row-by-row in row order —
/// the consensus accumulator of `AgreementScorer::add_batch`. Serial by
/// contract: batches are small (≤ the score batch) and the row order IS the
/// accumulation order the exactness guarantee pins down.
pub fn accumulate_col_sums(m: &Matrix, acc: &mut [f64]) {
    debug_assert_eq!(m.cols(), acc.len());
    for r in 0..m.rows() {
        for (j, &v) in m.row(r).iter().enumerate() {
            acc[j] += v as f64;
        }
    }
}

/// Cache-blocked transpose tile edge (32×32 f32 tiles = two 4 KiB faces).
const T_TILE: usize = 32;

/// `dst = srcᵀ` via square tiling so both the source rows and destination
/// rows stay within cache lines per tile (the naive row-major transpose
/// strides `dst` by `src.rows()` floats per element).
pub fn transpose_into(src: &Matrix, dst: &mut Matrix) {
    let (r, c) = (src.rows(), src.cols());
    debug_assert_eq!((dst.rows(), dst.cols()), (c, r));
    let s = src.as_slice();
    let d = dst.as_mut_slice();
    let mut i0 = 0;
    while i0 < r {
        let i1 = (i0 + T_TILE).min(r);
        let mut j0 = 0;
        while j0 < c {
            let j1 = (j0 + T_TILE).min(c);
            for i in i0..i1 {
                for j in j0..j1 {
                    d[j * r + i] = s[i * c + j];
                }
            }
            j0 = j1;
        }
        i0 = i1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::check::forall;

    fn random_matrix(rng: &mut crate::util::rng::Pcg64, r: usize, c: usize) -> Matrix {
        Matrix::from_fn(r, c, |_, _| rng.normal_f32())
    }

    #[test]
    fn dot8_matches_f64_reference() {
        forall("dot8", 30, |rng| {
            let n = rng.below(300) as usize;
            let a: Vec<f32> = (0..n).map(|_| rng.normal_f32()).collect();
            let b: Vec<f32> = (0..n).map(|_| rng.normal_f32()).collect();
            let fast = dot8(&a, &b) as f64;
            let slow = ops::dot_f64(&a, &b);
            assert!(
                (fast - slow).abs() < 1e-3 * (1.0 + slow.abs()),
                "{fast} vs {slow}"
            );
        });
    }

    #[test]
    fn row_grid_is_worker_count_free() {
        for rows in [1usize, 5, 63, 64, 65, 512, 100_000] {
            let chunk = row_chunk(rows);
            assert!((4..=256).contains(&chunk));
            assert_eq!(row_chunks(rows), rows.div_ceil(chunk));
        }
    }

    #[test]
    fn split_kernel_calls_match_full_range() {
        // The determinism contract at kernel granularity: computing the
        // row grid chunk-by-chunk reproduces the full-range call bit-for-bit.
        forall("kernel_split", 10, |rng| {
            let m = 1 + rng.below(33) as usize;
            let k = 1 + rng.below(70) as usize;
            let n = 1 + rng.below(19) as usize;
            let a = random_matrix(rng, m, k);
            let b = random_matrix(rng, n, k);

            let mut full = vec![0.0f32; m * n];
            matmul_transb_rows(&a, &b, 0, m, &mut full);
            let mut split = vec![0.0f32; m * n];
            let mut r0 = 0;
            while r0 < m {
                let r1 = (r0 + 3).min(m);
                matmul_transb_rows(&a, &b, r0, r1, &mut split[r0 * n..r1 * n]);
                r0 = r1;
            }
            for (x, y) in full.iter().zip(split.iter()) {
                assert_eq!(x.to_bits(), y.to_bits());
            }
        });
    }

    #[test]
    fn gram_matches_matmul_transb_self_bitwise() {
        forall("kernel_gram", 10, |rng| {
            let m = 1 + rng.below(20) as usize;
            let d = 1 + rng.below(40) as usize;
            let a = random_matrix(rng, m, d);
            let g = gram(&a);
            let mut full = vec![0.0f32; m * m];
            matmul_transb_rows(&a, &a, 0, m, &mut full);
            // Lower triangle (incl. diagonal) is computed by the same dot8
            // calls; the upper triangle is the mirror.
            for i in 0..m {
                for j in 0..m {
                    let want = if j <= i { full[i * m + j] } else { full[j * m + i] };
                    assert_eq!(g.get(i, j).to_bits(), want.to_bits(), "({i},{j})");
                }
            }
        });
    }

    #[test]
    fn matmul_rows_matches_matrix_matmul() {
        forall("kernel_matmul", 10, |rng| {
            let m = 1 + rng.below(12) as usize;
            let k = 1 + rng.below(12) as usize;
            let n = 1 + rng.below(12) as usize;
            let a = random_matrix(rng, m, k);
            let b = random_matrix(rng, k, n);
            let mut out = vec![0.0f32; m * n];
            matmul_rows(&a, &b, 0, m, &mut out);
            let want = a.matmul(&b);
            for (x, y) in out.iter().zip(want.as_slice()) {
                assert_eq!(x.to_bits(), y.to_bits());
            }
        });
    }

    #[test]
    fn transpose_tiles_match_naive() {
        forall("kernel_transpose", 10, |rng| {
            let r = 1 + rng.below(70) as usize;
            let c = 1 + rng.below(70) as usize;
            let a = random_matrix(rng, r, c);
            let mut t = Matrix::zeros(c, r);
            transpose_into(&a, &mut t);
            for i in 0..r {
                for j in 0..c {
                    assert_eq!(t.get(j, i).to_bits(), a.get(i, j).to_bits());
                }
            }
        });
    }

    #[test]
    fn row_energies_match_dot_f64() {
        forall("kernel_energy", 10, |rng| {
            let m = 1 + rng.below(9) as usize;
            let d = 1 + rng.below(50) as usize;
            let a = random_matrix(rng, m, d);
            let mut en = vec![0.0f64; m];
            row_energies_rows(&a, 0, m, &mut en);
            for (i, &e) in en.iter().enumerate() {
                assert_eq!(e.to_bits(), ops::dot_f64(a.row(i), a.row(i)).to_bits());
            }
        });
    }
}
