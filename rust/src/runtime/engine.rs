//! Single-threaded PJRT engine: loads HLO-text artifacts, compiles them on
//! the CPU PJRT client (lazily, once per artifact), executes with f32
//! host tensors. Not `Send` — the actor in `runtime::actor` owns one of
//! these per runtime thread and serializes access.

use super::manifest::{Manifest, ModelCfg};
// Offline builds use the API-compatible stub; swap to the real PJRT
// bindings by replacing this line with an `xla` crate dependency.
use super::xla_stub as xla;
use crate::log_debug;
use std::collections::HashMap;
use std::path::PathBuf;
use std::time::Instant;

/// A host-side f32 tensor view handed to [`Engine::run`].
pub struct TensorIn<'a> {
    pub data: &'a [f32],
    pub dims: Vec<usize>,
}

impl<'a> TensorIn<'a> {
    pub fn new(data: &'a [f32], dims: &[usize]) -> Self {
        assert_eq!(
            data.len(),
            dims.iter().product::<usize>(),
            "tensor data/shape mismatch"
        );
        Self {
            data,
            dims: dims.to_vec(),
        }
    }
}

/// Owns the PJRT client and the compiled-executable cache.
pub struct Engine {
    client: xla::PjRtClient,
    dir: PathBuf,
    manifest: Manifest,
    cache: HashMap<(String, String), xla::PjRtLoadedExecutable>,
}

impl Engine {
    /// Open the artifacts directory (reads + validates the manifest).
    pub fn new(artifacts_dir: &str) -> Result<Engine, String> {
        let dir = PathBuf::from(artifacts_dir);
        let manifest = Manifest::load(&dir)?;
        let client =
            xla::PjRtClient::cpu().map_err(|e| format!("PJRT CPU client: {e:?}"))?;
        Ok(Engine {
            client,
            dir,
            manifest,
            cache: HashMap::new(),
        })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn cfg(&self, model: &str) -> Result<&ModelCfg, String> {
        self.manifest.get(model)
    }

    fn compile(&mut self, model: &str, artifact: &str) -> Result<(), String> {
        let key = (model.to_string(), artifact.to_string());
        if self.cache.contains_key(&key) {
            return Ok(());
        }
        let cfg = self.manifest.get(model)?;
        let meta = cfg
            .artifacts
            .get(artifact)
            .ok_or_else(|| format!("config '{model}' has no artifact '{artifact}'"))?;
        let path = self.dir.join(&meta.file);
        let start = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(path.to_str().unwrap())
            .map_err(|e| format!("{}: {e:?}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| format!("compile {model}/{artifact}: {e:?}"))?;
        log_debug!(
            "compiled {model}/{artifact} in {:.1} ms",
            start.elapsed().as_secs_f64() * 1e3
        );
        self.cache.insert(key, exe);
        Ok(())
    }

    /// Execute one artifact. Inputs are validated against the manifest;
    /// outputs come back as flat f32 vectors in manifest output order.
    pub fn run(
        &mut self,
        model: &str,
        artifact: &str,
        inputs: &[TensorIn],
    ) -> Result<Vec<Vec<f32>>, String> {
        // Validate shapes first (clearer error than an XLA abort).
        {
            let cfg = self.manifest.get(model)?;
            let meta = cfg
                .artifacts
                .get(artifact)
                .ok_or_else(|| format!("config '{model}' has no artifact '{artifact}'"))?;
            if inputs.len() != meta.inputs.len() {
                return Err(format!(
                    "{model}/{artifact}: {} inputs given, {} expected",
                    inputs.len(),
                    meta.inputs.len()
                ));
            }
            for (i, (got, want)) in inputs.iter().zip(&meta.inputs).enumerate() {
                if &got.dims != want {
                    return Err(format!(
                        "{model}/{artifact} input {i}: shape {:?} != manifest {:?}",
                        got.dims, want
                    ));
                }
            }
        }
        self.compile(model, artifact)?;
        let exe = &self.cache[&(model.to_string(), artifact.to_string())];
        let hist = crate::util::metrics::global()
            .histogram(&format!("runtime.exec.{artifact}.ns"));
        let _timer = crate::util::metrics::ScopedTimer::new(hist);
        crate::util::metrics::global()
            .counter(&format!("runtime.exec.{artifact}.calls"))
            .inc();

        // NOTE: we deliberately avoid `PjRtLoadedExecutable::execute`
        // (literal inputs): the xla crate's C shim `execute` leaks every
        // input device buffer (`buffer.release()` without a matching
        // delete), which OOMs long benchmark runs. Building the input
        // buffers ourselves keeps them owned by `PjRtBuffer` wrappers
        // (freed on Drop) and `execute_b` only borrows them.
        let buffers: Vec<xla::PjRtBuffer> = inputs
            .iter()
            .map(|t| {
                self.client
                    .buffer_from_host_buffer::<f32>(t.data, &t.dims, None)
                    .map_err(|e| format!("host->device {:?}: {e:?}", t.dims))
            })
            .collect::<Result<_, String>>()?;

        let result = exe
            .execute_b::<xla::PjRtBuffer>(&buffers)
            .map_err(|e| format!("execute {model}/{artifact}: {e:?}"))?;
        let lit = result[0][0]
            .to_literal_sync()
            .map_err(|e| format!("fetch {model}/{artifact}: {e:?}"))?;
        // aot.py lowers with return_tuple=True: always a tuple.
        let parts = lit
            .to_tuple()
            .map_err(|e| format!("untuple {model}/{artifact}: {e:?}"))?;
        let meta = &self.manifest.get(model)?.artifacts[artifact];
        if parts.len() != meta.outputs.len() {
            return Err(format!(
                "{model}/{artifact}: {} outputs, manifest says {}",
                parts.len(),
                meta.outputs.len()
            ));
        }
        let mut out = Vec::with_capacity(parts.len());
        for (i, p) in parts.iter().enumerate() {
            let v = p
                .to_vec::<f32>()
                .map_err(|e| format!("{model}/{artifact} output {i}: {e:?}"))?;
            let want: usize = meta.outputs[i].iter().product();
            if v.len() != want {
                return Err(format!(
                    "{model}/{artifact} output {i}: {} elements, manifest says {want}",
                    v.len()
                ));
            }
            out.push(v);
        }
        Ok(out)
    }

    /// Pre-compile a set of artifacts (warm-up before timed runs).
    pub fn warm(&mut self, model: &str, artifacts: &[&str]) -> Result<(), String> {
        for a in artifacts {
            self.compile(model, a)?;
        }
        Ok(())
    }
}
