//! PJRT runtime: load AOT HLO-text artifacts, compile once, execute from
//! the coordinator hot path.
//!
//! Layering:
//! * [`manifest`] — the shape contract written by `python/compile/aot.py`.
//! * [`engine`] — one PJRT CPU client + compiled-executable cache
//!   (not `Send`; thread-confined).
//! * [`actor`] — dedicated runtime thread + cloneable [`EngineHandle`].
//! * [`backend`] — [`ModelBackend`] implementations (XLA + pure-Rust
//!   reference) and the FD [`XlaShrinkBackend`].

pub mod actor;
pub mod backend;
pub mod engine;
pub mod manifest;
pub(crate) mod xla_stub;

pub use actor::{EngineActor, EngineHandle, OwnedTensor};
pub use backend::{ModelBackend, ReferenceModelBackend, XlaModelBackend, XlaShrinkBackend};
pub use engine::{Engine, TensorIn};
pub use manifest::{ArtifactMeta, Manifest, ModelCfg};
